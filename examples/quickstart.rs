//! Quickstart: generate a small diurnal CDN workload, drive the paper's
//! TTL-based autoscaler and the static baseline through the streaming
//! `engine::Engine` — the canonical way to run any policy over any trace
//! — and print the cost comparison.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::EngineBuilder;
use elastictl::trace::{SynthConfig, SynthGenerator};

fn main() {
    // 1. A 2-day synthetic trace with the Akamai-like marginals (Fig. 4)
    //    scaled to laptop size.
    let mut synth = SynthConfig::akamai_like();
    synth.catalogue = 50_000;
    synth.mean_rate = 3.0;
    synth.duration = 2 * elastictl::DAY;
    let trace = SynthGenerator::new(synth).generate();
    println!("trace: {} requests over 2 simulated days", trace.len());

    // 2. Config: ElastiCache-style pricing scaled to the trace (per-byte
    //    price identical to the paper's cache.t2.micro), with the per-miss
    //    cost derived by the paper's §6.1 balance-point rule so the fixed
    //    baseline is a *fair* well-engineered cluster.
    let mut cfg = Config::default();
    cfg.cost.instance.ram_bytes = 40_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
    cfg.cost.miss_cost_dollars =
        elastictl::experiments::calibrate_miss_cost(&cfg, &trace, 8);
    println!("calibrated miss cost: ${:.3e}/miss", cfg.cost.miss_cost_dollars);

    // 3. Run the static baseline and the TTL autoscaler through the same
    //    engine. `EngineBuilder` resolves the policy from the config (the
    //    uniform registry covers every PolicyKind); `offer` steps one
    //    request at a time — the identical path the simulator, the TCP
    //    server and the experiment harness drive. Batch callers can use
    //    `elastictl::engine::run(&cfg, &mut source)` as a one-liner, with
    //    `trace::FileSource` streaming a trace file in constant memory.
    let mut results = Vec::new();
    for policy in [PolicyKind::Fixed, PolicyKind::Ttl] {
        cfg.scaler.policy = policy;
        cfg.scaler.fixed_instances = 8;
        let mut engine = EngineBuilder::new(&cfg).build();
        for r in &trace {
            engine.offer(r);
        }
        results.push(engine.finish());
    }

    println!("\n{:<8} {:>10} {:>12} {:>12} {:>12}", "policy", "miss%", "storage $", "miss $", "total $");
    for r in &results {
        println!(
            "{:<8} {:>10.4} {:>12.6} {:>12.6} {:>12.6}",
            r.policy,
            r.miss_ratio(),
            r.storage_cost,
            r.miss_cost,
            r.total_cost
        );
    }
    let saving = 1.0 - results[1].total_cost / results[0].total_cost;
    println!("\nTTL autoscaling saves {:.1}% vs the fixed-size cluster", 100.0 * saving);
    println!("(paper, 30-day Akamai trace: 17%)");
}
