//! MRC profiling demo (the §3 / Fig. 2 argument): exact Olken profiling
//! with heterogeneous sizes vs SHARDS-style sampling, showing the
//! accuracy collapse the paper uses to justify its O(1) TTL approach.
//!
//! ```bash
//! cargo run --release --example mrc_profiler
//! ```

use elastictl::experiments::{run_fig2, ExpContext, TraceScale};
use elastictl::mrc::{MrcProfiler, OlkenProfiler};
use elastictl::trace::{SynthConfig, SynthGenerator};
use elastictl::util::tempdir::tempdir;

fn main() {
    // 1. Exact profiling: print the miss-ratio curve of a small workload.
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 400.0;
    let trace = SynthGenerator::new(synth).generate();
    let mut olken = OlkenProfiler::sized(1 << 38);
    for r in &trace {
        olken.record(r.obj, r.size_bytes());
    }
    let curve = olken.curve();
    println!("exact MRC ({} requests, {} tracked objects):", trace.len(), olken.tracked());
    println!("{:>14} {:>10}", "cache size", "miss%");
    for mb in [1u64, 4, 16, 64, 256, 1024] {
        let size = mb * 1024 * 1024;
        println!("{:>11} MB {:>10.4}", mb, curve.miss_ratio_at(size));
    }

    // 2. The Fig. 2 sweep: uniform vs heterogeneous-size error.
    let out = tempdir().expect("tempdir");
    let ctx = ExpContext::standard(TraceScale::Smoke, out.path());
    let rep = run_fig2(&ctx, 300_000, &[0.001, 0.01, 0.1]).expect("fig2");
    println!("\n{}", rep.render());
    println!(
        "geometric-mean error inflation from heterogeneous sizes: {:.1}x",
        rep.mean_ratio()
    );
}
