//! The full Fig. 6 scenario as a runnable example: a multi-day diurnal CDN
//! trace through all four policies (fixed / TTL / MRC / ideal TTL), with
//! per-day cumulative cost reporting and the balance diagnostics of
//! Fig. 9.
//!
//! ```bash
//! cargo run --release --example cdn_autoscale [-- days [mean_rate]]
//! ```

use elastictl::experiments::{run_fig6_fig7_headline, run_fig9, ExpContext, TraceScale};
use elastictl::util::tempdir::tempdir;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let days: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3.0);

    // Build a context like the experiment harness', but parameterized.
    let out = tempdir().expect("tempdir");
    let mut ctx = ExpContext::standard(TraceScale::Smoke, out.path());
    let mut synth = TraceScale::Smoke.synth_config();
    synth.duration = days * elastictl::DAY;
    synth.mean_rate = rate;
    ctx.trace = elastictl::trace::SynthGenerator::new(synth).generate();
    println!(
        "trace: {} requests over {days} simulated days (mean {rate} r/s)",
        ctx.trace.len()
    );

    let rep = run_fig6_fig7_headline(&ctx).expect("fig6");
    println!("\n{}", rep.render());

    // Instance-count trajectory of the TTL policy (Fig. 5's consequence).
    println!("TTL policy instances per epoch (first 24):");
    let counts: Vec<String> = rep
        .ttl
        .instances_series
        .samples()
        .iter()
        .take(24)
        .map(|&(_, v)| format!("{v:.0}"))
        .collect();
    println!("  [{}]", counts.join(", "));

    let balance = run_fig9(&ctx).expect("fig9");
    println!("\n{}", balance.render());
    println!("CSV series written under {}", ctx.out_dir.display());
    // Keep the output directory for inspection.
    let kept = out.into_path();
    println!("(kept: {})", kept.display());
}
