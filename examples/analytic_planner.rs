//! L1/L2/L3 integration demo: evaluate the AOT-compiled JAX/Pallas cost
//! model from Rust over PJRT, compare the analytic optimum with what the
//! stochastic-approximation controller converges to on IRM traffic, and
//! cross-check the artifact against the pure-Rust oracle.
//!
//! ```bash
//! make artifacts && cargo run --release --example analytic_planner
//! ```

use elastictl::config::Config;
use elastictl::experiments::{run_irm_convergence, ExpContext, TraceScale};
use elastictl::runtime::{artifacts_dir, reference_curves, BucketedStats, CostCurveModel, Planner};
use elastictl::trace::IrmConfig;
use elastictl::util::tempdir::tempdir;

fn main() {
    let cfg = Config::default();
    let dir = artifacts_dir();

    // 1. Load the artifact (falls back with a message if absent).
    match CostCurveModel::load(&dir, None) {
        Ok(model) => {
            println!(
                "loaded cost_curve artifact from {} (n={}, g={})",
                dir.display(),
                model.n,
                model.g
            );
            // Cross-check against the Rust oracle on a toy population.
            let n = model.n;
            let lam: Vec<f32> = (0..n).map(|i| 1.0 / (1.0 + i as f32)).collect();
            let m = vec![1.4676e-7f32; n];
            let s: Vec<f32> = (0..n).map(|i| 1.0e4 + i as f32).collect();
            let c: Vec<f32> = s.iter().map(|x| x * 8.5085e-15).collect();
            let w = vec![1.0f32; n];
            let t = Planner::t_grid(model.g, cfg.controller.t_max_secs);
            let got = model.evaluate(&lam, &m, &c, &s, &w, &t).expect("evaluate");
            let want = reference_curves(&lam, &m, &c, &s, &w, &t);
            let max_rel = got
                .cost
                .iter()
                .zip(&want.cost)
                .map(|(a, b)| ((a - b) / b.max(1e-30)).abs())
                .fold(0.0f32, f32::max);
            println!("PJRT vs rust-oracle max relative error: {max_rel:.2e}");
            assert!(max_rel < 1e-3, "artifact disagrees with oracle");
        }
        Err(e) => println!("artifact not available ({e}); oracle-only demo"),
    }

    // 2. One planning call on a synthetic epoch.
    let planner = Planner::load(&dir, cfg.controller.t_max_secs);
    let items: Vec<(u32, u32)> = (1..=20_000u32)
        .map(|rank| {
            let count = (3600.0 / rank as f64).ceil() as u32;
            (count, elastictl::trace::object_size(rank as u64, 7) as u32)
        })
        .collect();
    let stats = BucketedStats::build(&items, planner.n_buckets(), 3600.0, &cfg.cost);
    let plan = planner
        .plan(&stats, cfg.cost.instance.ram_bytes)
        .expect("plan");
    println!(
        "planner ({}) says: T* = {:.0}s, predicted cost rate ${:.3e}/s, vsize {:.1} MB -> {} instances",
        if planner.uses_artifact() { "PJRT" } else { "oracle" },
        plan.t_star_secs,
        plan.cost_rate,
        plan.vsize_bytes / 1048576.0,
        plan.instances
    );

    // 3. Validate Proposition 1: SA converges near the model optimum.
    let out = tempdir().expect("tempdir");
    let ctx = ExpContext::standard(TraceScale::Smoke, out.path());
    let irm = IrmConfig {
        catalogue: 10_000,
        alpha: 0.9,
        total_rate: 300.0,
        duration: 4 * elastictl::HOUR,
        seed: 3,
    };
    let rep = run_irm_convergence(&ctx, &irm).expect("irm");
    println!("\n{}", rep.render());
}
