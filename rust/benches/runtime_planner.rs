//! L1/L2/L3 integration bench — latency of one analytic-planner call:
//! bucketing the epoch's popularity estimates, then evaluating the AOT
//! cost-curve artifact on the PJRT CPU client (vs the pure-Rust oracle).
//! The planner runs once per epoch (hourly), so anything under ~100 ms is
//! negligible; the bench verifies that and records the artifact/oracle
//! ratio for EXPERIMENTS.md §Perf.

use elastictl::config::Config;
use elastictl::runtime::{artifacts_dir, BucketedStats, CostCurveModel, Planner};
use elastictl::util::bench::{black_box, Bencher};
use elastictl::util::rng::Pcg;

fn main() {
    let mut b = Bencher::new("runtime_planner");
    let cfg = Config::default();
    let mut rng = Pcg::seed_from_u64(9);

    // Synthetic epoch estimates: 50k distinct objects, Zipf counts.
    let zipf = elastictl::trace::Zipf::new(50_000, 0.9);
    let mut counts = std::collections::HashMap::new();
    for _ in 0..400_000 {
        let o = zipf.sample(&mut rng);
        *counts.entry(o).or_insert(0u32) += 1;
    }
    let mut items: Vec<(u32, u32)> = counts
        .iter()
        .map(|(&o, &c)| (c, elastictl::trace::object_size(o, 7) as u32))
        .collect();
    items.sort_unstable_by(|a, b| b.0.cmp(&a.0));

    // Bucketing cost (plain rust, part of every planner call).
    b.bench("bucketize_50k_items", items.len() as u64, || {
        black_box(BucketedStats::build(&items, 4096, 3600.0, &cfg.cost));
    });

    // Oracle evaluation.
    let oracle = Planner::oracle(4096, 256, cfg.controller.t_max_secs);
    let stats = BucketedStats::build(&items, 4096, 3600.0, &cfg.cost);
    b.bench("oracle_curves_n4096_g256", (4096 * 256) as u64, || {
        black_box(oracle.curves(&stats).unwrap());
    });

    // PJRT artifact evaluation (skipped if `make artifacts` has not run).
    match CostCurveModel::load(artifacts_dir(), None) {
        Ok(model) => {
            let planner_grid = Planner::t_grid(model.g, cfg.controller.t_max_secs);
            let stats_n = BucketedStats::build(&items, model.n, 3600.0, &cfg.cost);
            b.bench(
                &format!("pjrt_curves_n{}_g{}", model.n, model.g),
                (model.n * model.g) as u64,
                || {
                    black_box(
                        model
                            .evaluate(
                                &stats_n.lam,
                                &stats_n.miss_cost,
                                &stats_n.storage_rate,
                                &stats_n.size,
                                &stats_n.weight,
                                &planner_grid,
                            )
                            .unwrap(),
                    );
                },
            );
        }
        Err(e) => println!("# pjrt artifact unavailable ({e}); run `make artifacts`"),
    }
    b.finish();
}
