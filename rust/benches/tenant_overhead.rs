//! Tenant-layer overhead bench — per-request cost of the multi-tenant
//! path (tenant-scoped routing + controller-bank dispatch + per-tenant
//! ledgers) against the single-tenant TTL router over the same workload.
//!
//! Acceptance target: the multi-tenant request path stays O(1) and lands
//! within 25% of the single-tenant `router_overhead` ttl path.

use elastictl::balancer::Balancer;
use elastictl::config::{Config, PolicyKind};
use elastictl::cost::CostTracker;
use elastictl::scaler::make_sizer;
use elastictl::tenant::{TenantSpec, TrafficClass};
use elastictl::trace::{Request, SynthConfig, SynthGenerator};
use elastictl::util::bench::{black_box, Bencher};

fn bench_policy(
    b: &mut Bencher,
    name: &str,
    cfg: &Config,
    trace: &[Request],
    chunk: usize,
) -> f64 {
    let sizer = make_sizer(cfg);
    let mut balancer = Balancer::from_config(cfg, sizer, 8);
    let mut costs = CostTracker::new(cfg.cost.clone());
    for spec in &cfg.tenants {
        costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
    }
    // Warm the structures over the whole trace once.
    for r in trace {
        balancer.handle(r, &mut costs);
    }
    let mut idx = 0usize;
    let mean_ns = b
        .bench(&format!("{name}_10k_requests"), chunk as u64, || {
            for r in &trace[idx..idx + chunk] {
                black_box(balancer.handle(r, &mut costs));
            }
            idx = (idx + chunk) % (trace.len() - chunk).max(1);
        })
        .mean_ns;
    println!(
        "# work_units[{name}] = {:.2}/request, tenants seen = {}",
        balancer.work_units as f64 / balancer.requests as f64,
        balancer
            .tenant_stats()
            .iter()
            .filter(|hm| hm.total() > 0)
            .count()
    );
    mean_ns
}

fn main() {
    let mut b = Bencher::new("tenant_overhead");
    let mut cfg_trace = SynthConfig::tiny();
    cfg_trace.mean_rate = 600.0;
    let single: Vec<Request> = SynthGenerator::new(cfg_trace).generate();
    // Same requests, round-robined across three tenants (tenant-local key
    // spaces, as the mux would produce).
    let multi: Vec<Request> = single
        .iter()
        .enumerate()
        .map(|(i, r)| r.with_tenant((i % 3) as u16))
        .collect();
    let chunk = 10_000.min(single.len() / 2);

    let mut ttl_cfg = Config::with_policy(PolicyKind::Ttl);
    ttl_cfg.cost.instance.ram_bytes = 40_000_000;
    ttl_cfg.scaler.fixed_instances = 8;
    let single_ns = bench_policy(&mut b, "ttl_single_tenant", &ttl_cfg, &single, chunk);

    let mut ten_cfg = Config::with_policy(PolicyKind::TenantTtl);
    ten_cfg.cost.instance.ram_bytes = 40_000_000;
    ten_cfg.scaler.fixed_instances = 8;
    ten_cfg.tenants = vec![
        TenantSpec::new(0, "api")
            .with_multiplier(3.0)
            .with_class(TrafficClass::Interactive),
        TenantSpec::new(1, "web"),
        TenantSpec::new(2, "batch")
            .with_multiplier(0.3)
            .with_class(TrafficClass::Bulk),
    ];
    let multi_ns = bench_policy(&mut b, "tenant_ttl_3_tenants", &ten_cfg, &multi, chunk);

    let ratio = multi_ns / single_ns.max(1e-9);
    println!(
        "# tenant_overhead: multi/single = {ratio:.3} ({})",
        if ratio <= 1.25 {
            "within the 25% O(1) budget"
        } else {
            "EXCEEDS the 25% budget"
        }
    );
    b.finish();
}
