//! §5.1 bench — the O(1) claim of the FIFO-calendar virtual TTL cache:
//! per-request cost must stay flat as the ghost population grows, unlike
//! the exact-calendar (BTreeMap) TTL cache it replaces.

use elastictl::cache::{IdealTtlCache, TtlMode};
use elastictl::config::{ControllerConfig, CostConfig};
use elastictl::util::bench::{black_box, Bencher};
use elastictl::util::rng::Pcg;
use elastictl::vcache::VirtualCache;
use elastictl::SECOND;

fn main() {
    let mut b = Bencher::new("vcache_ops");
    for &population in &[10_000u64, 100_000, 1_000_000] {
        // FIFO-calendar virtual cache (the paper's O(1) design).
        let ctrl = ControllerConfig { t_init_secs: 36_000.0, ..Default::default() };
        let mut vc = VirtualCache::new(&ctrl, CostConfig::default());
        let mut rng = Pcg::seed_from_u64(population);
        let mut now = 0u64;
        for i in 0..population {
            vc.on_request(now, i, 1000);
            now += 1000;
        }
        b.bench(&format!("fifo_ttl_m{}", population), 1000, || {
            for _ in 0..1000 {
                now += 1000;
                let obj = rng.below(population);
                black_box(vc.on_request(now, obj, 1000));
            }
        });

        // Exact-calendar TTL cache (O(log M) reference).
        let mut ideal = IdealTtlCache::new(TtlMode::WithRenewal);
        let mut now2 = 0u64;
        for i in 0..population {
            ideal.on_request(now2, i, 1000, 36_000 * SECOND);
            now2 += 1000;
        }
        let mut rng2 = Pcg::seed_from_u64(population ^ 1);
        b.bench(&format!("exact_calendar_m{}", population), 1000, || {
            for _ in 0..1000 {
                now2 += 1000;
                let obj = rng2.below(population);
                black_box(ideal.on_request(now2, obj, 1000, 36_000 * SECOND));
            }
        });
    }
    b.finish();
}
