//! Fig. 8 bench — throughput of the offline clairvoyant solvers: TTL-OPT
//! (Algorithm 1, linear time) and the Bélády replacement baseline
//! (O(log M) per request). Both must handle multi-million-request traces
//! in seconds to be usable as references.

use elastictl::config::CostConfig;
use elastictl::trace::{SynthConfig, SynthGenerator};
use elastictl::ttlopt::{belady_miss_ratio, next_request_times, solve};
use elastictl::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("ttlopt_offline");
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 500.0;
    let trace = SynthGenerator::new(synth).generate();
    let cost = CostConfig::default();
    println!("# trace: {} requests", trace.len());

    b.bench("next_request_times", trace.len() as u64, || {
        black_box(next_request_times(&trace));
    });

    b.bench("ttlopt_solve", trace.len() as u64, || {
        black_box(solve(&trace, &cost));
    });

    b.bench("belady_50mb", trace.len() as u64, || {
        black_box(belady_miss_ratio(&trace, 50_000_000));
    });
    b.finish();
}
