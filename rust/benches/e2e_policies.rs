//! Fig. 6 end-to-end bench — full simulated epochs under each sizing
//! policy: requests/second of the whole testbed (balancer + cluster +
//! policy + billing), plus the resulting cost summary rows (the bench
//! doubles as a fast regeneration of the headline table at smoke scale).

use elastictl::config::{Config, PolicyKind};
use elastictl::sim::run;
use elastictl::trace::{SynthConfig, SynthGenerator, VecSource};
use elastictl::util::bench::Bencher;
use elastictl::MINUTE;

fn main() {
    let mut b = Bencher::new("e2e_policies");
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 400.0;
    let trace = SynthGenerator::new(synth).generate();
    println!("# trace: {} requests over 2 simulated hours", trace.len());

    for policy in [
        PolicyKind::Fixed,
        PolicyKind::Ttl,
        PolicyKind::Mrc,
        PolicyKind::IdealTtl,
    ] {
        let mut cfg = Config::with_policy(policy);
        cfg.cost.instance.ram_bytes = 40_000_000;
        cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.fixed_instances = 8;
        let mut last = None;
        b.bench(
            &format!("run_{}", policy.as_str()),
            trace.len() as u64,
            || {
                let mut src = VecSource::new(trace.clone());
                last = Some(run(&cfg, &mut src));
            },
        );
        if let Some(res) = &last {
            println!(
                "#   {}: miss_ratio={:.4} total=${:.6} (storage ${:.6} miss ${:.6})",
                res.policy, res.miss_ratio(), res.total_cost, res.storage_cost, res.miss_cost
            );
        }
    }
    b.finish();
}
