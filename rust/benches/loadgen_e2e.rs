//! End-to-end server throughput bench: a real TCP server (`srv` state
//! thread + accept loop) replayed against with the `loadgen` client over
//! 1 and 4 connections, so the row measures the whole pipeline —
//! connect, line parse, state-thread round trip, reply — not just the
//! engine. The 4-connection row is the CI quick-bench gate's floor for
//! concurrent serving throughput.

use elastictl::config::{Config, PolicyKind};
use elastictl::srv::{accept_loop, loadgen, spawn_state};
use elastictl::trace::Request;
use elastictl::util::bench::{black_box, Bencher};
use std::net::TcpListener;

fn main() {
    let mut b = Bencher::new("loadgen_e2e");
    let mut cfg = Config::with_policy(PolicyKind::Fixed);
    cfg.scaler.fixed_instances = 4;
    cfg.cost.instance.ram_bytes = 40_000_000;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = spawn_state(cfg, None).unwrap();
    let tx = server.tx.clone();
    std::thread::spawn(move || {
        let _ = accept_loop(listener, tx);
    });

    // 2000 requests over 200 objects; after the first iteration the
    // cache is warm, so the steady state measures the serving path, not
    // fill behavior.
    let reqs: Vec<Request> =
        (0..2000u64).map(|i| Request::new(i * 1000, i % 200, 1000)).collect();

    for conns in [1usize, 4] {
        b.bench(&format!("replay_{conns}conn_2k_requests"), reqs.len() as u64, || {
            let report = loadgen::run(&addr, &reqs, conns).unwrap();
            black_box(report.requests);
        });
    }
    b.finish();
}
