//! Fig. 1 bench — per-request cost of the three router variants over the
//! same workload: basic (route only), TTL (route + O(1) virtual cache),
//! MRC (route + O(log M) order-statistics tree). The paper's shape:
//! basic ≈ TTL ≫ MRC in throughput; work grows with cache size only for
//! MRC.

use elastictl::balancer::Balancer;
use elastictl::config::{Config, PolicyKind};
use elastictl::cost::CostTracker;
use elastictl::scaler::make_sizer;
use elastictl::trace::{SynthConfig, SynthGenerator};
use elastictl::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("router_overhead");
    let mut cfg_trace = SynthConfig::tiny();
    cfg_trace.mean_rate = 600.0;
    let trace = SynthGenerator::new(cfg_trace).generate();
    let chunk = 10_000.min(trace.len() / 2);

    for policy in [PolicyKind::Fixed, PolicyKind::Ttl, PolicyKind::Mrc] {
        let mut cfg = Config::with_policy(policy);
        cfg.cost.instance.ram_bytes = 40_000_000;
        cfg.scaler.fixed_instances = 8;
        let sizer = make_sizer(&cfg);
        let mut balancer = Balancer::from_config(&cfg, sizer, 8);
        let mut costs = CostTracker::new(cfg.cost.clone());
        // Warm the structures over the whole trace once.
        for r in &trace {
            balancer.handle(r, &mut costs);
        }
        let mut idx = 0usize;
        b.bench(
            &format!("{}_10k_requests", policy.as_str()),
            chunk as u64,
            || {
                for r in &trace[idx..idx + chunk] {
                    black_box(balancer.handle(r, &mut costs));
                }
                idx = (idx + chunk) % (trace.len() - chunk).max(1);
            },
        );
        println!(
            "# work_units[{}] = {:.2}/request",
            policy.as_str(),
            balancer.work_units as f64 / balancer.requests as f64
        );
    }
    b.finish();
}
