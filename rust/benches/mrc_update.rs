//! §3 complexity bench — per-request cost of MRC profiling as the tracked
//! set grows: exact Olken (O(log M)) vs SHARDS sampling (O(log RM)).
//! Regenerates the complexity argument behind Fig. 1 / §2.4.

use elastictl::mrc::{MrcProfiler, OlkenProfiler, ShardsMode, ShardsProfiler};
use elastictl::util::bench::{black_box, Bencher};
use elastictl::util::rng::Pcg;

fn workload(n_objects: u64, n_requests: usize, seed: u64) -> Vec<(u64, u64)> {
    // Zipf-ish accesses over n_objects with heterogeneous sizes.
    let zipf = elastictl::trace::Zipf::new(n_objects, 0.9);
    let mut rng = Pcg::seed_from_u64(seed);
    (0..n_requests)
        .map(|_| {
            let o = zipf.sample(&mut rng);
            (o, elastictl::trace::object_size(o, 7))
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new("mrc_update");
    for &n_objects in &[10_000u64, 100_000, 1_000_000] {
        let reqs = workload(n_objects, 60_000, n_objects);

        let mut olken = OlkenProfiler::sized(1 << 40);
        for &(o, s) in &reqs {
            olken.record(o, s);
        }
        let mut i = 0usize;
        b.bench(&format!("olken_m{}", n_objects), 1000, || {
            for &(o, s) in &reqs[i..i + 1000] {
                black_box(olken.record(o, s));
            }
            i = (i + 1000) % (reqs.len() - 1000);
        });

        let mut shards = ShardsProfiler::new(0.01, 1 << 40, ShardsMode::Sized, 5);
        for &(o, s) in &reqs {
            shards.record(o, s);
        }
        let mut j = 0usize;
        b.bench(&format!("shards_r0.01_m{}", n_objects), 1000, || {
            for &(o, s) in &reqs[j..j + 1000] {
                black_box(shards.record(o, s));
            }
            j = (j + 1000) % (reqs.len() - 1000);
        });
    }
    b.finish();
}
