//! Placement-subsystem overhead on the tenant-tagged request path:
//! requests/second through `Engine::offer` for the multi-tenant policy
//! under each placement kind (shared / hash_slot_pinned /
//! slab_partition), with grant enforcement on so the resident-byte cap
//! compare, ledger accounting and boundary shedding are all in the loop.
//! The CI quick-bench gate tracks these rows against
//! `rust/benches/baseline_placement.json`.

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::EngineBuilder;
use elastictl::placement::PlacementKind;
use elastictl::tenant::TenantSpec;
use elastictl::trace::{Request, SynthConfig, SynthGenerator};
use elastictl::util::bench::{black_box, Bencher};
use elastictl::MINUTE;

fn main() {
    let mut b = Bencher::new("placement_overhead");
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 400.0;
    let base = SynthGenerator::new(synth).generate();
    // Tag the trace across three tenants (the fig10/fig11 shape).
    let trace: Vec<Request> = base
        .iter()
        .enumerate()
        .map(|(i, r)| r.with_tenant((i % 3) as u16))
        .collect();
    println!("# trace: {} tenant-tagged requests over 2 simulated hours", trace.len());

    for placement in [
        PlacementKind::Shared,
        PlacementKind::HashSlotPinned,
        PlacementKind::SlabPartition,
    ] {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.cost.instance.ram_bytes = 40_000_000;
        cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.enforce_grants = true;
        cfg.cluster.placement = placement;
        cfg.tenants = vec![
            TenantSpec::new(0, "a").with_multiplier(2.0).with_reserved_bytes(10_000_000),
            TenantSpec::new(1, "b"),
            TenantSpec::new(2, "c").with_multiplier(0.5),
        ];
        let mut last_requests = 0u64;
        b.bench(
            &format!("offer_enforced_{}", placement.as_str()),
            trace.len() as u64,
            || {
                let mut engine = EngineBuilder::new(&cfg).no_default_probes().build();
                for r in &trace {
                    black_box(engine.offer(r));
                }
                last_requests = engine.requests();
                black_box(engine.finish());
            },
        );
        assert_eq!(last_requests, trace.len() as u64);
    }

    b.finish();
}
