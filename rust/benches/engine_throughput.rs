//! Engine request-path throughput — requests/second through
//! `Engine::offer` for each policy, with and without the default probe
//! set, so future PRs can track the speed of the unified request path.

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::{EngineBuilder, ShardedEngine};
use elastictl::trace::{SynthConfig, SynthGenerator};
use elastictl::util::bench::{black_box, Bencher};
use elastictl::MINUTE;

fn main() {
    let mut b = Bencher::new("engine_throughput");
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 400.0;
    let trace = SynthGenerator::new(synth).generate();
    println!("# trace: {} requests over 2 simulated hours", trace.len());

    for policy in [
        PolicyKind::Fixed,
        PolicyKind::Ttl,
        PolicyKind::Mrc,
        PolicyKind::IdealTtl,
        PolicyKind::TenantTtl,
    ] {
        let mut cfg = Config::with_policy(policy);
        cfg.cost.instance.ram_bytes = 40_000_000;
        cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.fixed_instances = 8;

        let mut last_requests = 0u64;
        b.bench(
            &format!("offer_{}", policy.as_str()),
            trace.len() as u64,
            || {
                // Bare request path: what the server runs.
                let mut engine = EngineBuilder::new(&cfg).no_default_probes().build();
                for r in &trace {
                    black_box(engine.offer(r));
                }
                last_requests = engine.requests();
                black_box(engine.finish());
            },
        );
        assert_eq!(last_requests, trace.len() as u64);
    }

    // Enforcement overhead: the tenant policy with binding grants — the
    // admission compare + outcome feedback must stay O(1) per request
    // (the CI quick-bench gate tracks this row against the committed
    // baseline).
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.cost.instance.ram_bytes = 40_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
    cfg.cost.epoch_us = 10 * MINUTE;
    cfg.scaler.enforce_grants = true;
    let bare_p50 = b
        .bench("offer_tenant_ttl_enforced", trace.len() as u64, || {
            let mut engine = EngineBuilder::new(&cfg).no_default_probes().build();
            for r in &trace {
                black_box(engine.offer(r));
            }
            black_box(engine.finish());
        })
        .p50_ns;

    // Admission-filter overhead: the same enforced run with the
    // Mth-request sketch live — one hash + one packed-nibble bump per
    // request, no allocation. Acceptance bound for the admission layer:
    // within 5% (p50) of the unfiltered enforced row.
    let mut cfg_mth = cfg.clone();
    cfg_mth.admission.filter = elastictl::config::AdmissionKind::MthRequest;
    cfg_mth.admission.m = 2;
    let mth_p50 = b
        .bench("offer_mth_request", trace.len() as u64, || {
            let mut engine = EngineBuilder::new(&cfg_mth).no_default_probes().build();
            for r in &trace {
                black_box(engine.offer(r));
            }
            black_box(engine.finish());
        })
        .p50_ns;
    let overhead_pct = (mth_p50 - bare_p50) / bare_p50 * 100.0;
    println!("# mth_request overhead vs enforced (p50): {overhead_pct:+.2}%");
    assert!(
        overhead_pct < 5.0,
        "mth_request overhead {overhead_pct:.2}% breaches the 5% budget \
         (bare p50 {bare_p50:.0} ns, filtered p50 {mth_p50:.0} ns)"
    );

    // Telemetry overhead: the same enforced run with the registry +
    // decision journal live. The acceptance gate for the telemetry
    // subsystem: pre-resolved handles and 1-in-64 serve-latency sampling
    // must keep the request path within 3% of the untelemetered row.
    let mut cfg_tel = cfg.clone();
    cfg_tel.telemetry.enabled = true;
    let tel_p50 = b
        .bench("offer_with_telemetry", trace.len() as u64, || {
            let mut engine = EngineBuilder::new(&cfg_tel).no_default_probes().build();
            for r in &trace {
                black_box(engine.offer(r));
            }
            black_box(engine.finish());
        })
        .p50_ns;
    // Compare medians — the mean is too noise-sensitive on shared CI
    // runners for a 3% bound over a time-budgeted sample count.
    let overhead_pct = (tel_p50 - bare_p50) / bare_p50 * 100.0;
    println!("# telemetry overhead vs enforced (p50): {overhead_pct:+.2}%");
    assert!(
        overhead_pct < 3.0,
        "telemetry overhead {overhead_pct:.2}% breaches the 3% budget \
         (bare p50 {bare_p50:.0} ns, telemetered p50 {tel_p50:.0} ns)"
    );

    // Probe overhead: the full default observer set on the TTL policy.
    let mut cfg = Config::with_policy(PolicyKind::Ttl);
    cfg.cost.instance.ram_bytes = 40_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
    cfg.cost.epoch_us = 10 * MINUTE;
    b.bench("offer_ttl_default_probes", trace.len() as u64, || {
        let mut engine = EngineBuilder::new(&cfg).build();
        for r in &trace {
            black_box(engine.offer(r));
        }
        black_box(engine.finish());
    });

    // Multicore scaling: the same trace through the sharded engine at
    // one and eight shards. The single-shard row prices the channel +
    // batching overhead of the sharded front; the eight-shard row is the
    // multicore throughput the CI gate tracks (baseline.json "scaling"
    // enforces a minimum 8-vs-1 ratio on runners with >= 8 cores).
    let mut cfg = Config::with_policy(PolicyKind::Ttl);
    cfg.cost.instance.ram_bytes = 40_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
    cfg.cost.epoch_us = 10 * MINUTE;
    let mut tputs = Vec::new();
    let mut sharded8_p50 = 0.0_f64;
    for shards in [1u32, 8] {
        cfg.engine.shards = shards;
        let mut last_processed = 0u64;
        let res = b.bench(&format!("offer_sharded_{shards}"), trace.len() as u64, || {
            let mut engine = ShardedEngine::new(&cfg).expect("the ttl policy shards");
            for r in &trace {
                engine.offer(r);
            }
            last_processed = engine.processed();
            black_box(engine.finish());
        });
        assert_eq!(last_processed, trace.len() as u64);
        tputs.push(res.throughput_per_sec());
        if shards == 8 {
            sharded8_p50 = res.p50_ns;
        }
    }
    println!("# sharded scaling 8-vs-1: {:.2}x", tputs[1] / tputs[0]);

    // Sharded telemetry overhead: the eight-shard run with the per-shard
    // registries, shard-health gauges, and the barrier-merged decision
    // journal live. Same acceptance bound as the monolithic telemetry
    // row: lock-free atomic handles on the worker hot path must keep the
    // sharded request path within 3% (p50) of the untelemetered run.
    cfg.telemetry.enabled = true;
    let tel_p50 = b
        .bench("offer_sharded_8_telemetry", trace.len() as u64, || {
            let mut engine = ShardedEngine::new(&cfg).expect("the ttl policy shards");
            for r in &trace {
                engine.offer(r);
            }
            black_box(engine.finish());
        })
        .p50_ns;
    let overhead_pct = (tel_p50 - sharded8_p50) / sharded8_p50 * 100.0;
    println!("# sharded telemetry overhead vs bare (p50): {overhead_pct:+.2}%");
    assert!(
        overhead_pct < 3.0,
        "sharded telemetry overhead {overhead_pct:.2}% breaches the 3% budget \
         (bare p50 {sharded8_p50:.0} ns, telemetered p50 {tel_p50:.0} ns)"
    );

    b.finish();
}
