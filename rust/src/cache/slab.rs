//! Memcached-style slab cache (§2.1): "Memcached organizes the content
//! into classes of objects with similar sizes, and performs LRU within
//! each class."
//!
//! Size classes grow geometrically (factor 2 from 64 B); each class owns a
//! share of the byte budget proportional to demand (classes acquire pages
//! on first need, first-come-first-served, as in Memcached before
//! automove), which reproduces the *calcification* pathology the paper
//! cites ([15], [25], [34]) as the reason it runs Redis instead.

use super::{EvictionSink, LruCache, Store};
use crate::util::fasthash::FastMap;
use crate::{ObjectId, TenantId};

const MIN_CLASS: u64 = 64;
const GROWTH: f64 = 2.0;
/// Memcached page size: the unit in which classes acquire memory. Small
/// caches shrink the page so at least a handful of pages exist (real
/// Memcached assumes ≥ 64 MB; our tests run tiny instances).
const PAGE: u64 = 1 << 20;

#[inline]
fn page_size_for(capacity: u64) -> u64 {
    (capacity / 4).clamp(MIN_CLASS, PAGE).min(capacity.max(MIN_CLASS))
}

/// Slab-class cache: per-class LRU over a shared page budget.
pub struct SlabCache {
    capacity: u64,
    page: u64,
    classes: Vec<LruCache>, // class i holds objects of chunk size chunk(i)
    class_pages: Vec<u64>,  // pages owned by each class
    pages_total: u64,
    pages_free: u64,
    index: FastMap<ObjectId, u8>, // object -> class
    /// Resident (chunk-rounded) bytes per tenant id. The class LRUs keep
    /// their own tallies too; this aggregate keeps `tenant_bytes()` O(1).
    tenant_bytes: Vec<u64>,
}

impl SlabCache {
    pub fn new(capacity: u64) -> Self {
        let page = page_size_for(capacity);
        let mut chunks = Vec::new();
        let mut c = MIN_CLASS;
        while c < page {
            chunks.push(c);
            c = ((c as f64) * GROWTH) as u64;
        }
        chunks.push(page); // largest class: one object per page
        let nclasses = chunks.len();
        SlabCache {
            capacity,
            page,
            classes: (0..nclasses).map(|_| LruCache::new(0)).collect(),
            class_pages: vec![0; nclasses],
            pages_total: capacity / page,
            pages_free: capacity / page,
            index: FastMap::default(),
            tenant_bytes: Vec::new(),
        }
    }

    #[inline]
    fn add_tenant(&mut self, tenant: TenantId, bytes: u64) {
        let i = tenant as usize;
        if self.tenant_bytes.len() <= i {
            self.tenant_bytes.resize(i + 1, 0);
        }
        self.tenant_bytes[i] += bytes;
    }

    #[inline]
    fn sub_tenant(&mut self, tenant: TenantId, bytes: u64) {
        let slot = &mut self.tenant_bytes[tenant as usize];
        debug_assert!(*slot >= bytes, "tenant {tenant} tally underflow");
        *slot = slot.saturating_sub(bytes);
    }

    /// Chunk size of class `i`.
    fn chunk(&self, i: usize) -> u64 {
        let mut c = MIN_CLASS;
        for _ in 0..i {
            c = ((c as f64) * GROWTH) as u64;
        }
        c.min(self.page)
    }

    /// Class index for an object of `size` bytes, `None` if it exceeds the
    /// largest chunk (Memcached rejects such objects by default).
    fn class_of(&self, size: u64) -> Option<usize> {
        if size > self.page {
            return None;
        }
        let mut c = MIN_CLASS;
        let mut i = 0usize;
        while c < size {
            c = ((c as f64) * GROWTH) as u64;
            i += 1;
        }
        Some(i)
    }

    /// Rounded-up (chunk) size an object occupies — the internal
    /// fragmentation Memcached pays.
    pub fn chunk_size_for(&self, size: u64) -> Option<u64> {
        self.class_of(size).map(|i| self.chunk(i))
    }

    /// Grow class `ci` by one page if any free page remains.
    fn try_grow(&mut self, ci: usize) -> bool {
        if self.pages_free == 0 {
            return false;
        }
        self.pages_free -= 1;
        self.class_pages[ci] += 1;
        let new_cap = self.class_pages[ci] * self.page;
        // LruCache has no resize; rebuild preserving entries and their
        // tenant tags (rare event — page grants happen O(capacity/PAGE)
        // times total).
        let mut rebuilt = LruCache::new(new_cap);
        let entries: Vec<(ObjectId, u64, TenantId)> = self.classes[ci]
            .iter_mru_tagged()
            .collect::<Vec<_>>()
            .into_iter()
            .rev()
            .collect();
        let mut sink = EvictionSink::new();
        for (obj, size, tenant) in entries {
            rebuilt.insert_tagged(obj, size, tenant, &mut sink);
        }
        debug_assert!(sink.is_empty(), "rebuild into a larger class evicted");
        self.classes[ci] = rebuilt;
        true
    }

    /// Bytes used, counting internal fragmentation (chunk-rounded).
    pub fn used_with_fragmentation(&self) -> u64 {
        self.classes
            .iter()
            .enumerate()
            .map(|(i, c)| c.len() as u64 * self.chunk(i))
            .sum()
    }
}

impl Store for SlabCache {
    fn capacity(&self) -> u64 {
        self.capacity
    }

    fn used(&self) -> u64 {
        self.classes.iter().map(|c| c.used()).sum()
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    fn lookup(&mut self, obj: ObjectId) -> bool {
        if let Some(&ci) = self.index.get(&obj) {
            self.classes[ci as usize].lookup(obj)
        } else {
            false
        }
    }

    fn insert(&mut self, obj: ObjectId, size: u64) -> bool {
        if self.class_of(size).is_none() || size > self.capacity {
            return false;
        }
        if self.lookup(obj) {
            return true;
        }
        let mut sink = EvictionSink::new();
        self.insert_tagged(obj, size, 0, &mut sink) > 0
    }

    fn insert_tagged(
        &mut self,
        obj: ObjectId,
        size: u64,
        tenant: TenantId,
        evicted: &mut EvictionSink,
    ) -> u64 {
        let Some(ci) = self.class_of(size) else { return 0 };
        if size > self.capacity {
            return 0;
        }
        if self.lookup(obj) {
            return 0; // refresh only
        }
        let chunk = self.chunk(ci);
        // Ensure the class can hold one more chunk: grow by pages while
        // possible; otherwise the class's own LRU evicts (calcification:
        // pages never move between classes).
        while self.classes[ci].used() + chunk > self.class_pages[ci] * self.page {
            if !self.try_grow(ci) {
                break;
            }
        }
        if self.class_pages[ci] == 0 {
            return 0; // no page ever granted and none free
        }
        let start = evicted.len();
        let added = self.classes[ci].insert_tagged(obj, chunk, tenant, evicted);
        if added > 0 {
            self.index.insert(obj, ci as u8);
            self.add_tenant(tenant, added);
        }
        if evicted.len() > start {
            // Settle the aggregate tallies for what the class LRU shed,
            // and drop the evicted objects from the object → class index.
            let shed: Vec<(TenantId, u64)> = evicted[start..].to_vec();
            for (t, b) in shed {
                self.sub_tenant(t, b);
            }
            self.index.retain(|o, &mut c| {
                c as usize != ci || self.classes[ci].contains(*o)
            });
        }
        added
    }

    fn tenant_bytes(&self, tenant: TenantId) -> u64 {
        self.tenant_bytes.get(tenant as usize).copied().unwrap_or(0)
    }

    fn evict_tenant(&mut self, tenant: TenantId, want: u64) -> u64 {
        // Coldest-first *within each class* (Memcached has no global
        // recency order across classes); classes are drained in index
        // order until enough is freed. The object → class index is
        // settled once at the end, not once per touched class.
        let mut freed = 0u64;
        for class in &mut self.classes {
            if freed >= want {
                break;
            }
            freed += class.evict_tenant(tenant, want - freed);
        }
        if freed > 0 {
            self.sub_tenant(tenant, freed);
            self.index.retain(|o, &mut c| self.classes[c as usize].contains(*o));
        }
        freed
    }

    fn remove_entry(&mut self, obj: ObjectId) -> Option<(u64, TenantId)> {
        if let Some(ci) = self.index.remove(&obj) {
            if let Some((size, tenant)) = self.classes[ci as usize].remove_entry(obj) {
                self.sub_tenant(tenant, size);
                return Some((size, tenant));
            }
        }
        None
    }

    fn contains(&self, obj: ObjectId) -> bool {
        self.index.contains_key(&obj)
    }

    fn clear(&mut self) {
        for (ci, c) in self.classes.iter_mut().enumerate() {
            c.clear();
            self.class_pages[ci] = 0;
        }
        self.pages_free = self.pages_total;
        self.index.clear();
        self.tenant_bytes.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_assignment_rounds_up() {
        let s = SlabCache::new(64 * PAGE);
        assert_eq!(s.class_of(1), Some(0));
        assert_eq!(s.class_of(64), Some(0));
        assert_eq!(s.class_of(65), Some(1));
        assert_eq!(s.chunk_size_for(100), Some(128));
        assert_eq!(s.chunk_size_for(PAGE + 1), None);
    }

    #[test]
    fn tiny_capacity_still_stores() {
        // Regression: a 1000-byte instance must still grant pages.
        let mut s = SlabCache::new(1000);
        assert!(s.insert(1, 100));
        assert!(s.lookup(1));
        assert!(s.used() <= 1000);
    }

    #[test]
    fn basic_hit_miss() {
        let mut s = SlabCache::new(8 * PAGE);
        assert!(!s.lookup(1));
        assert!(s.insert(1, 100));
        assert!(s.lookup(1));
        assert!(s.remove(1));
        assert!(!s.contains(1));
    }

    #[test]
    fn per_class_lru_evicts_within_class() {
        let mut s = SlabCache::new(PAGE); // one page only
        let chunk = s.chunk_size_for(100).unwrap(); // 128
        let fit = (PAGE / chunk) as u64;
        for i in 0..fit + 5 {
            assert!(s.insert(i, 100), "insert {i}");
        }
        // The first few inserted must have been evicted by the class LRU.
        assert!(!s.contains(0));
        assert!(s.contains(fit + 4));
        assert!(s.len() as u64 <= fit);
        // Index stays consistent with residency.
        for i in 0..fit + 5 {
            assert_eq!(s.contains(i), s.lookup(i));
        }
    }

    #[test]
    fn calcification_pages_never_return() {
        // Fill with small objects (class A grabs all pages), then large
        // objects can claim no page and are rejected — the calcification
        // pathology (§6.1's reason to prefer Redis).
        let mut s = SlabCache::new(4 * PAGE);
        let mut i = 0u64;
        while s.pages_free > 0 {
            s.insert(i, 64);
            i += 1;
        }
        assert!(!s.insert(u64::MAX, PAGE / 2), "large class got no page");
        // Small objects still cycle fine.
        assert!(s.insert(u64::MAX - 1, 64));
    }

    #[test]
    fn fragmentation_accounted() {
        let mut s = SlabCache::new(8 * PAGE);
        s.insert(1, 100); // occupies a 128-byte chunk
        assert_eq!(s.used(), 128);
        assert_eq!(s.used_with_fragmentation(), 128);
    }

    #[test]
    fn tenant_tags_survive_chunking_and_page_grants() {
        let mut s = SlabCache::new(4 * PAGE);
        let mut sink = EvictionSink::new();
        // Chunk rounding: a 100-byte object occupies a 128-byte chunk and
        // the tenant tally must count the chunk (tags partition used()).
        assert_eq!(s.insert_tagged(1, 100, 3, &mut sink), 128);
        assert_eq!(s.tenant_bytes(3), 128);
        for i in 10..40u64 {
            s.insert_tagged(i, 100, (i % 2) as TenantId, &mut sink);
        }
        let total: u64 = (0..4).map(|t| s.tenant_bytes(t)).sum();
        assert_eq!(total, s.used());
        // Targeted eviction frees only the target tenant's chunks.
        let t0 = s.tenant_bytes(0);
        let t1 = s.tenant_bytes(1);
        let freed = s.evict_tenant(0, 256);
        assert_eq!(freed, 256);
        assert_eq!(s.tenant_bytes(0), t0 - 256);
        assert_eq!(s.tenant_bytes(1), t1);
        let total: u64 = (0..4).map(|t| s.tenant_bytes(t)).sum();
        assert_eq!(total, s.used());
        // Removal returns the chunk to the owner's tally.
        assert!(s.remove(1));
        assert_eq!(s.tenant_bytes(3), 0);
    }

    #[test]
    fn class_overflow_reports_mixed_tenant_evictions() {
        let mut s = SlabCache::new(PAGE); // one page, one class in play
        let chunk = s.chunk_size_for(100).unwrap();
        let fit = PAGE / chunk;
        let mut sink = EvictionSink::new();
        for i in 0..fit + 5 {
            s.insert_tagged(i, 100, (i % 2) as TenantId, &mut sink);
        }
        let reported: u64 = sink.iter().map(|&(_, b)| b).sum();
        assert_eq!(reported, 5 * chunk, "every class-LRU eviction reported");
        let total: u64 = (0..2).map(|t| s.tenant_bytes(t)).sum();
        assert_eq!(total, s.used());
        // The index dropped the evicted objects.
        for i in 0..fit + 5 {
            assert_eq!(s.contains(i), s.lookup(i));
        }
    }

    #[test]
    fn clear_releases_pages() {
        let mut s = SlabCache::new(2 * PAGE);
        for i in 0..1000u64 {
            s.insert(i, 512);
        }
        s.clear();
        assert_eq!(s.len(), 0);
        assert_eq!(s.pages_free, s.pages_total);
        assert!(s.insert(5, PAGE / 2), "pages reusable after clear");
    }
}
