//! Redis-style sampled-LRU eviction (§2.1): "Redis picks randomly 5
//! objects and evicts the one least recently accessed; if the available
//! space is not sufficient, it repeats the process."
//!
//! Entries live in a dense vector (swap-remove on eviction) so sampling a
//! random resident object is O(1); recency is a logical clock stamped on
//! each access.
//!
//! Placement subsystem: entries carry tenant tags and per-tenant byte
//! tallies; evictions report `(tenant, bytes)` through the caller's
//! [`EvictionSink`]. Protected floors are honored *best-effort*, true to
//! the sampled flavour: among the sampled candidates a non-protected
//! victim is preferred, but if every sample is protected the stalest
//! sample is evicted anyway (forward progress beats a strict guarantee a
//! 5-sample policy cannot give).

use super::{EvictionSink, Store};
use crate::util::fasthash::FastMap;
use crate::util::rng::Pcg;
use crate::{ObjectId, TenantId};

const SAMPLES: usize = 5;

#[derive(Debug, Clone, Copy)]
struct Entry {
    obj: ObjectId,
    size: u64,
    tenant: TenantId,
    last_access: u64,
}

/// Sampled-LRU byte-capacity cache.
pub struct SampledLruCache {
    capacity: u64,
    used: u64,
    entries: Vec<Entry>,
    index: FastMap<ObjectId, u32>,
    clock: u64,
    rng: Pcg,
    evictions: u64,
    /// Resident bytes per tenant id (grown on demand).
    tenant_bytes: Vec<u64>,
    /// Advisory protected floors per tenant id (empty = unpartitioned).
    floors: Vec<u64>,
}

impl SampledLruCache {
    pub fn new(capacity: u64, seed: u64) -> Self {
        SampledLruCache {
            capacity,
            used: 0,
            entries: Vec::new(),
            index: FastMap::default(),
            clock: 0,
            rng: Pcg::seed_from_u64(seed),
            evictions: 0,
            tenant_bytes: Vec::new(),
            floors: Vec::new(),
        }
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    #[inline]
    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    #[inline]
    fn add_tenant(&mut self, tenant: TenantId, bytes: u64) {
        let i = tenant as usize;
        if self.tenant_bytes.len() <= i {
            self.tenant_bytes.resize(i + 1, 0);
        }
        self.tenant_bytes[i] += bytes;
    }

    #[inline]
    fn sub_tenant(&mut self, tenant: TenantId, bytes: u64) {
        let slot = &mut self.tenant_bytes[tenant as usize];
        debug_assert!(*slot >= bytes, "tenant {tenant} tally underflow");
        *slot = slot.saturating_sub(bytes);
    }

    #[inline]
    fn protected(&self, tenant: TenantId) -> bool {
        let floor = self.floors.get(tenant as usize).copied().unwrap_or(0);
        floor > 0 && self.tenant_bytes.get(tenant as usize).copied().unwrap_or(0) <= floor
    }

    /// Remove the entry at dense index `i`, fixing the swapped slot.
    fn take_at(&mut self, i: usize) -> Entry {
        let e = self.entries.swap_remove(i);
        self.index.remove(&e.obj);
        if i < self.entries.len() {
            let moved = self.entries[i].obj;
            self.index.insert(moved, i as u32);
        }
        self.used -= e.size;
        self.sub_tenant(e.tenant, e.size);
        e
    }

    /// Pick the stalest of `SAMPLES` random entries and evict it,
    /// reporting it to the sink. With floors installed, a non-protected
    /// victim is preferred among the samples; the inserting tenant's own
    /// entries are always fair game.
    fn evict_one(&mut self, tenant: TenantId, evicted: &mut EvictionSink) -> bool {
        if self.entries.is_empty() {
            return false;
        }
        let mut victim = usize::MAX;
        let mut oldest = u64::MAX;
        let mut fallback = usize::MAX;
        let mut fallback_oldest = u64::MAX;
        for _ in 0..SAMPLES.min(self.entries.len()) {
            let i = self.rng.below_usize(self.entries.len());
            let e = self.entries[i];
            if e.last_access < fallback_oldest {
                fallback_oldest = e.last_access;
                fallback = i;
            }
            let evictable =
                self.floors.is_empty() || e.tenant == tenant || !self.protected(e.tenant);
            if evictable && e.last_access < oldest {
                oldest = e.last_access;
                victim = i;
            }
        }
        let i = if victim != usize::MAX { victim } else { fallback };
        let e = self.take_at(i);
        self.evictions += 1;
        evicted.push((e.tenant, e.size));
        true
    }
}

impl Store for SampledLruCache {
    fn capacity(&self) -> u64 {
        self.capacity
    }

    fn used(&self) -> u64 {
        self.used
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    fn lookup(&mut self, obj: ObjectId) -> bool {
        let t = self.tick();
        if let Some(&i) = self.index.get(&obj) {
            self.entries[i as usize].last_access = t;
            true
        } else {
            false
        }
    }

    fn insert(&mut self, obj: ObjectId, size: u64) -> bool {
        if size > self.capacity {
            return false;
        }
        if self.lookup(obj) {
            return true;
        }
        let mut sink = EvictionSink::new();
        self.insert_tagged(obj, size, 0, &mut sink) > 0
    }

    fn insert_tagged(
        &mut self,
        obj: ObjectId,
        size: u64,
        tenant: TenantId,
        evicted: &mut EvictionSink,
    ) -> u64 {
        if size > self.capacity {
            return 0;
        }
        if self.lookup(obj) {
            return 0; // refresh only
        }
        while self.used + size > self.capacity {
            if !self.evict_one(tenant, evicted) {
                break;
            }
        }
        let t = self.tick();
        let i = self.entries.len() as u32;
        self.entries.push(Entry { obj, size, tenant, last_access: t });
        self.index.insert(obj, i);
        self.used += size;
        self.add_tenant(tenant, size);
        size
    }

    fn tenant_bytes(&self, tenant: TenantId) -> u64 {
        self.tenant_bytes.get(tenant as usize).copied().unwrap_or(0)
    }

    fn evict_tenant(&mut self, tenant: TenantId, want: u64) -> u64 {
        // Coldest-first within the tenant: collect (last_access, obj),
        // sort ascending, remove until enough is freed. O(n log n), but
        // only ever run at epoch boundaries.
        let mut victims: Vec<(u64, ObjectId, u64)> = self
            .entries
            .iter()
            .filter(|e| e.tenant == tenant)
            .map(|e| (e.last_access, e.obj, e.size))
            .collect();
        victims.sort_unstable();
        let mut freed = 0u64;
        for (_, obj, size) in victims {
            if freed >= want {
                break;
            }
            if let Some(&i) = self.index.get(&obj) {
                self.take_at(i as usize);
                self.evictions += 1;
                freed += size;
            }
        }
        freed
    }

    fn set_tenant_floors(&mut self, floors: &[(TenantId, u64)]) {
        self.floors.clear();
        for &(t, f) in floors {
            let i = t as usize;
            if self.floors.len() <= i {
                self.floors.resize(i + 1, 0);
            }
            self.floors[i] = f;
        }
    }

    fn remove_entry(&mut self, obj: ObjectId) -> Option<(u64, TenantId)> {
        if let Some(&i) = self.index.get(&obj) {
            let e = self.take_at(i as usize);
            Some((e.size, e.tenant))
        } else {
            None
        }
    }

    fn contains(&self, obj: ObjectId) -> bool {
        self.index.contains_key(&obj)
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.index.clear();
        self.used = 0;
        self.tenant_bytes.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::conformance;

    #[test]
    fn conformance_suite() {
        conformance::run_all(|| Box::new(SampledLruCache::new(1000, 3)));
    }

    #[test]
    fn eviction_prefers_stale_entries() {
        // With 5-way sampling, recently touched hot objects should survive
        // much more often than cold ones. Insert hot+cold sets, churn, and
        // check survival bias.
        let mut c = SampledLruCache::new(100 * 10, 9);
        for i in 0..100u64 {
            c.insert(i, 10);
        }
        // Touch the "hot" half often.
        for _ in 0..50 {
            for i in 0..50u64 {
                c.lookup(i);
            }
            // Insert fresh objects to force evictions.
            for j in 0..5u64 {
                c.insert(1000 + j + c.clock, 10);
            }
        }
        let hot_survivors = (0..50u64).filter(|&i| c.contains(i)).count();
        let cold_survivors = (50..100u64).filter(|&i| c.contains(i)).count();
        assert!(
            hot_survivors > cold_survivors + 10,
            "hot={hot_survivors} cold={cold_survivors}"
        );
    }

    #[test]
    fn swap_remove_keeps_index_consistent() {
        let mut c = SampledLruCache::new(1000, 5);
        for i in 0..20u64 {
            c.insert(i, 10);
        }
        // Remove half in arbitrary order, then verify all lookups.
        for i in (0..20u64).step_by(2) {
            assert!(c.remove(i));
        }
        for i in 0..20u64 {
            assert_eq!(c.contains(i), i % 2 == 1, "obj {i}");
            assert_eq!(c.lookup(i), i % 2 == 1);
        }
        assert_eq!(c.len(), 10);
        assert_eq!(c.used(), 100);
    }

    #[test]
    fn repeated_eviction_frees_enough_space() {
        let mut c = SampledLruCache::new(100, 1);
        for i in 0..10u64 {
            c.insert(i, 10);
        }
        assert!(c.insert(42, 73));
        assert!(c.used() <= 100);
        assert!(c.contains(42));
    }

    #[test]
    fn floors_bias_victims_toward_pooled_entries() {
        let mut c = SampledLruCache::new(1000, 7);
        c.set_tenant_floors(&[(1, 400)]);
        let mut sink = EvictionSink::new();
        for i in 0..40u64 {
            c.insert_tagged(i, 10, 1, &mut sink);
        }
        // Tenant 2 churns hard; tenant 1 sits at its floor. The sampled
        // policy is advisory, so allow a small amount of leakage but the
        // overwhelming majority of victims must be pooled (tenant 2).
        for i in 1000..1200u64 {
            c.insert_tagged(i, 10, 2, &mut sink);
        }
        let t1_evicted: u64 = sink.iter().filter(|&&(t, _)| t == 1).map(|&(_, b)| b).sum();
        let t2_evicted: u64 = sink.iter().filter(|&&(t, _)| t == 2).map(|&(_, b)| b).sum();
        assert!(
            t2_evicted > 10 * t1_evicted.max(1),
            "pooled churn must dominate: t1={t1_evicted} t2={t2_evicted}"
        );
        assert!(c.tenant_bytes(1) >= 300, "reservation mostly intact");
    }
}
