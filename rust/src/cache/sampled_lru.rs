//! Redis-style sampled-LRU eviction (§2.1): "Redis picks randomly 5
//! objects and evicts the one least recently accessed; if the available
//! space is not sufficient, it repeats the process."
//!
//! Entries live in a dense vector (swap-remove on eviction) so sampling a
//! random resident object is O(1); recency is a logical clock stamped on
//! each access.

use super::Store;
use crate::ObjectId;
use crate::util::fasthash::FastMap;
use crate::util::rng::Pcg;

const SAMPLES: usize = 5;

#[derive(Debug, Clone, Copy)]
struct Entry {
    obj: ObjectId,
    size: u64,
    last_access: u64,
}

/// Sampled-LRU byte-capacity cache.
pub struct SampledLruCache {
    capacity: u64,
    used: u64,
    entries: Vec<Entry>,
    index: FastMap<ObjectId, u32>,
    clock: u64,
    rng: Pcg,
    evictions: u64,
}

impl SampledLruCache {
    pub fn new(capacity: u64, seed: u64) -> Self {
        SampledLruCache {
            capacity,
            used: 0,
            entries: Vec::new(),
            index: FastMap::default(),
            clock: 0,
            rng: Pcg::seed_from_u64(seed),
            evictions: 0,
        }
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    #[inline]
    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Pick the stalest of `SAMPLES` random entries and evict it.
    fn evict_one(&mut self) -> bool {
        if self.entries.is_empty() {
            return false;
        }
        let mut victim = usize::MAX;
        let mut oldest = u64::MAX;
        for _ in 0..SAMPLES.min(self.entries.len()) {
            let i = self.rng.below_usize(self.entries.len());
            if self.entries[i].last_access < oldest {
                oldest = self.entries[i].last_access;
                victim = i;
            }
        }
        let e = self.entries.swap_remove(victim);
        self.index.remove(&e.obj);
        // Fix the index of the entry swapped into `victim`'s slot.
        if victim < self.entries.len() {
            let moved = self.entries[victim].obj;
            self.index.insert(moved, victim as u32);
        }
        self.used -= e.size;
        self.evictions += 1;
        true
    }
}

impl Store for SampledLruCache {
    fn capacity(&self) -> u64 {
        self.capacity
    }

    fn used(&self) -> u64 {
        self.used
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    #[inline]
    fn lookup(&mut self, obj: ObjectId) -> bool {
        let t = self.tick();
        if let Some(&i) = self.index.get(&obj) {
            self.entries[i as usize].last_access = t;
            true
        } else {
            false
        }
    }

    fn insert(&mut self, obj: ObjectId, size: u64) -> bool {
        if size > self.capacity {
            return false;
        }
        if self.lookup(obj) {
            return true;
        }
        while self.used + size > self.capacity {
            if !self.evict_one() {
                break;
            }
        }
        let t = self.tick();
        let i = self.entries.len() as u32;
        self.entries.push(Entry { obj, size, last_access: t });
        self.index.insert(obj, i);
        self.used += size;
        true
    }

    fn remove(&mut self, obj: ObjectId) -> bool {
        if let Some(i) = self.index.remove(&obj) {
            let i = i as usize;
            let e = self.entries.swap_remove(i);
            if i < self.entries.len() {
                let moved = self.entries[i].obj;
                self.index.insert(moved, i as u32);
            }
            self.used -= e.size;
            true
        } else {
            false
        }
    }

    fn contains(&self, obj: ObjectId) -> bool {
        self.index.contains_key(&obj)
    }

    fn clear(&mut self) {
        self.entries.clear();
        self.index.clear();
        self.used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::conformance;

    #[test]
    fn conformance_suite() {
        conformance::run_all(|| Box::new(SampledLruCache::new(1000, 3)));
    }

    #[test]
    fn eviction_prefers_stale_entries() {
        // With 5-way sampling, recently touched hot objects should survive
        // much more often than cold ones. Insert hot+cold sets, churn, and
        // check survival bias.
        let mut c = SampledLruCache::new(100 * 10, 9);
        for i in 0..100u64 {
            c.insert(i, 10);
        }
        // Touch the "hot" half often.
        for _ in 0..50 {
            for i in 0..50u64 {
                c.lookup(i);
            }
            // Insert fresh objects to force evictions.
            for j in 0..5u64 {
                c.insert(1000 + j + c.clock, 10);
            }
        }
        let hot_survivors = (0..50u64).filter(|&i| c.contains(i)).count();
        let cold_survivors = (50..100u64).filter(|&i| c.contains(i)).count();
        assert!(
            hot_survivors > cold_survivors + 10,
            "hot={hot_survivors} cold={cold_survivors}"
        );
    }

    #[test]
    fn swap_remove_keeps_index_consistent() {
        let mut c = SampledLruCache::new(1000, 5);
        for i in 0..20u64 {
            c.insert(i, 10);
        }
        // Remove half in arbitrary order, then verify all lookups.
        for i in (0..20u64).step_by(2) {
            assert!(c.remove(i));
        }
        for i in 0..20u64 {
            assert_eq!(c.contains(i), i % 2 == 1, "obj {i}");
            assert_eq!(c.lookup(i), i % 2 == 1);
        }
        assert_eq!(c.len(), 10);
        assert_eq!(c.used(), 100);
    }

    #[test]
    fn repeated_eviction_frees_enough_space() {
        let mut c = SampledLruCache::new(100, 1);
        for i in 0..10u64 {
            c.insert(i, 10);
        }
        assert!(c.insert(42, 73));
        assert!(c.used() <= 100);
        assert!(c.contains(42));
    }
}
