//! One physical cache node of the cluster: a [`Store`] plus accounting.

use super::{make_store, EvictionSink, Store};
use crate::config::EvictionKind;
use crate::metrics::HitMiss;
use crate::{ObjectId, TenantId};

/// A cluster node. The paper's instances are Redis `cache.t2.micro` nodes;
/// the store kind and capacity are configurable.
pub struct CacheInstance {
    /// Stable identifier (never reused within a run, so per-server series
    /// in Fig. 9 stay unambiguous across resizes).
    pub id: u32,
    store: Box<dyn Store + Send>,
    pub stats: HitMiss,
    /// Requests routed to this node (hits + misses), for Fig. 9 balance.
    pub requests: u64,
}

impl CacheInstance {
    pub fn new(id: u32, kind: EvictionKind, capacity: u64, seed: u64) -> Self {
        CacheInstance {
            id,
            store: make_store(kind, capacity, seed ^ id as u64),
            stats: HitMiss::default(),
            requests: 0,
        }
    }

    /// Serve a request: lookup, and on miss insert (the balancer fetched
    /// the object from the origin). Returns `true` on hit.
    pub fn serve(&mut self, obj: ObjectId, size: u64) -> bool {
        let mut sink = EvictionSink::new();
        self.serve_tagged(obj, size, 0, &mut sink).0
    }

    /// Tenant-tagged serve: like [`Self::serve`], but the inserted entry
    /// carries `tenant`, and every eviction the insert performed is
    /// appended to `evicted` as `(tenant, bytes)`. Returns
    /// `(hit, bytes added to used())` so the cluster ledger can account
    /// both sides of the move.
    pub fn serve_tagged(
        &mut self,
        obj: ObjectId,
        size: u64,
        tenant: TenantId,
        evicted: &mut EvictionSink,
    ) -> (bool, u64) {
        self.requests += 1;
        let hit = self.store.lookup(obj);
        self.stats.record(hit);
        let added = if hit {
            0
        } else {
            self.store.insert_tagged(obj, size, tenant, evicted)
        };
        (hit, added)
    }

    /// Lookup without insertion (used when the balancer decides the object
    /// is not worth caching).
    pub fn lookup_only(&mut self, obj: ObjectId) -> bool {
        self.requests += 1;
        let hit = self.store.lookup(obj);
        self.stats.record(hit);
        hit
    }

    pub fn used(&self) -> u64 {
        self.store.used()
    }

    pub fn capacity(&self) -> u64 {
        self.store.capacity()
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn contains(&self, obj: ObjectId) -> bool {
        self.store.contains(obj)
    }

    /// Bytes resident for `tenant` on this node.
    pub fn tenant_bytes_of(&self, tenant: TenantId) -> u64 {
        self.store.tenant_bytes(tenant)
    }

    /// Evict up to `want` bytes of `tenant`'s coldest entries; returns
    /// the bytes actually freed (targeted occupancy-cap shedding).
    pub fn evict_tenant(&mut self, tenant: TenantId, want: u64) -> u64 {
        self.store.evict_tenant(tenant, want)
    }

    /// Remove `obj` if resident, returning `(bytes freed, owning tenant)`
    /// so the cluster can debit its resident ledger (lazy TTL expiry).
    pub fn remove_entry(&mut self, obj: ObjectId) -> Option<(u64, TenantId)> {
        self.store.remove_entry(obj)
    }

    /// Install per-tenant protected floors (slab-partition placement).
    pub fn set_tenant_floors(&mut self, floors: &[(TenantId, u64)]) {
        self.store.set_tenant_floors(floors);
    }

    /// Drop all content (e.g. node decommissioned then re-provisioned).
    pub fn clear(&mut self) {
        self.store.clear();
    }

    /// Reset per-epoch counters, keeping content.
    pub fn reset_epoch_stats(&mut self) {
        self.stats = HitMiss::default();
        self.requests = 0;
    }
}

impl std::fmt::Debug for CacheInstance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CacheInstance")
            .field("id", &self.id)
            .field("used", &self.used())
            .field("capacity", &self.capacity())
            .field("stats", &self.stats)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_inserts_on_miss() {
        let mut n = CacheInstance::new(0, EvictionKind::Lru, 1000, 1);
        assert!(!n.serve(1, 100));
        assert!(n.serve(1, 100));
        assert_eq!(n.stats.hits, 1);
        assert_eq!(n.stats.misses, 1);
        assert_eq!(n.requests, 2);
        assert_eq!(n.used(), 100);
    }

    #[test]
    fn lookup_only_does_not_insert() {
        let mut n = CacheInstance::new(0, EvictionKind::Lru, 1000, 1);
        assert!(!n.lookup_only(7));
        assert!(!n.contains(7));
        assert_eq!(n.stats.misses, 1);
    }

    #[test]
    fn epoch_stats_reset_keeps_content() {
        let mut n = CacheInstance::new(3, EvictionKind::Lru, 1000, 1);
        n.serve(1, 10);
        n.reset_epoch_stats();
        assert_eq!(n.stats.total(), 0);
        assert_eq!(n.requests, 0);
        assert!(n.contains(1));
    }

    #[test]
    fn tagged_serve_reports_adds_and_evictions() {
        let mut n = CacheInstance::new(0, EvictionKind::Lru, 100, 1);
        let mut sink = EvictionSink::new();
        let (hit, added) = n.serve_tagged(1, 60, 4, &mut sink);
        assert!(!hit);
        assert_eq!(added, 60);
        assert_eq!(n.tenant_bytes_of(4), 60);
        // A hit adds nothing and evicts nothing.
        let (hit, added) = n.serve_tagged(1, 60, 4, &mut sink);
        assert!(hit);
        assert_eq!(added, 0);
        assert!(sink.is_empty());
        // Overflow by another tenant reports tenant 4's eviction.
        let (hit, added) = n.serve_tagged(2, 80, 7, &mut sink);
        assert!(!hit);
        assert_eq!(added, 80);
        assert_eq!(sink, vec![(4, 60)]);
        assert_eq!(n.tenant_bytes_of(4), 0);
        assert_eq!(n.tenant_bytes_of(7), 80);
    }
}
