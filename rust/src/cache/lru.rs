//! Strict LRU with byte capacity and heterogeneous object sizes.
//!
//! O(1) per operation: a `HashMap<ObjectId, slot>` indexes into a slab of
//! intrusive doubly-linked-list nodes with a free list, so steady-state
//! operation performs **no allocation** — the property the paper leans on
//! when arguing CDN caches must stay O(1) per request (§2.4).

use super::Store;
use crate::util::fasthash::FastMap;
use crate::ObjectId;

const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct Node {
    obj: ObjectId,
    size: u64,
    prev: u32,
    next: u32,
}

/// Byte-capacity LRU cache.
#[derive(Debug)]
pub struct LruCache {
    capacity: u64,
    used: u64,
    map: FastMap<ObjectId, u32>,
    nodes: Vec<Node>,
    free: Vec<u32>,
    head: u32, // most recently used
    tail: u32, // least recently used
    evictions: u64,
}

impl LruCache {
    pub fn new(capacity: u64) -> Self {
        LruCache {
            capacity,
            used: 0,
            map: FastMap::default(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            evictions: 0,
        }
    }

    /// Number of objects evicted to make room since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The least-recently-used object, if any (next eviction victim).
    pub fn lru_object(&self) -> Option<ObjectId> {
        (self.tail != NIL).then(|| self.nodes[self.tail as usize].obj)
    }

    #[inline]
    fn unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.nodes[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.nodes[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    #[inline]
    fn push_front(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    #[inline]
    fn alloc(&mut self, obj: ObjectId, size: u64) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = Node { obj, size, prev: NIL, next: NIL };
                i
            }
            None => {
                let i = self.nodes.len() as u32;
                self.nodes.push(Node { obj, size, prev: NIL, next: NIL });
                i
            }
        }
    }

    fn evict_tail(&mut self) -> Option<(ObjectId, u64)> {
        if self.tail == NIL {
            return None;
        }
        let idx = self.tail;
        let (obj, size) = {
            let n = &self.nodes[idx as usize];
            (n.obj, n.size)
        };
        self.unlink(idx);
        self.map.remove(&obj);
        self.free.push(idx);
        self.used -= size;
        self.evictions += 1;
        Some((obj, size))
    }

    /// Iterate resident objects from MRU to LRU (test/debug helper).
    pub fn iter_mru(&self) -> impl Iterator<Item = (ObjectId, u64)> + '_ {
        struct It<'a> {
            cache: &'a LruCache,
            cur: u32,
        }
        impl<'a> Iterator for It<'a> {
            type Item = (ObjectId, u64);
            fn next(&mut self) -> Option<Self::Item> {
                if self.cur == NIL {
                    return None;
                }
                let n = &self.cache.nodes[self.cur as usize];
                self.cur = n.next;
                Some((n.obj, n.size))
            }
        }
        It { cache: self, cur: self.head }
    }
}

impl Store for LruCache {
    fn capacity(&self) -> u64 {
        self.capacity
    }

    fn used(&self) -> u64 {
        self.used
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    #[inline]
    fn lookup(&mut self, obj: ObjectId) -> bool {
        if let Some(&idx) = self.map.get(&obj) {
            if idx != self.head {
                self.unlink(idx);
                self.push_front(idx);
            }
            true
        } else {
            false
        }
    }

    fn insert(&mut self, obj: ObjectId, size: u64) -> bool {
        if size > self.capacity {
            return false;
        }
        if self.lookup(obj) {
            return true; // refresh only
        }
        while self.used + size > self.capacity {
            if self.evict_tail().is_none() {
                break;
            }
        }
        let idx = self.alloc(obj, size);
        self.map.insert(obj, idx);
        self.push_front(idx);
        self.used += size;
        true
    }

    fn remove(&mut self, obj: ObjectId) -> bool {
        if let Some(idx) = self.map.remove(&obj) {
            let size = self.nodes[idx as usize].size;
            self.unlink(idx);
            self.free.push(idx);
            self.used -= size;
            true
        } else {
            false
        }
    }

    fn contains(&self, obj: ObjectId) -> bool {
        self.map.contains_key(&obj)
    }

    fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::conformance;

    #[test]
    fn conformance_suite() {
        conformance::run_all(|| Box::new(LruCache::new(1000)));
    }

    #[test]
    fn evicts_in_lru_order() {
        let mut c = LruCache::new(30);
        c.insert(1, 10);
        c.insert(2, 10);
        c.insert(3, 10);
        // Touch 1 so 2 becomes LRU.
        assert!(c.lookup(1));
        assert_eq!(c.lru_object(), Some(2));
        c.insert(4, 10); // evicts 2
        assert!(!c.contains(2));
        assert!(c.contains(1) && c.contains(3) && c.contains(4));
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn heterogeneous_sizes_evict_enough() {
        let mut c = LruCache::new(100);
        for i in 0..10u64 {
            c.insert(i, 10);
        }
        // Inserting a 95-byte object must evict until it fits.
        assert!(c.insert(100, 95));
        assert!(c.used() <= 100);
        assert!(c.contains(100));
        // 9 of the 10 small objects must have gone (95+10 > 100).
        assert_eq!(c.len(), 1 + (100 - 95) / 10);
    }

    #[test]
    fn mru_iteration_order() {
        let mut c = LruCache::new(100);
        c.insert(1, 10);
        c.insert(2, 10);
        c.insert(3, 10);
        c.lookup(2);
        let order: Vec<u64> = c.iter_mru().map(|(o, _)| o).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn free_list_reuses_slots() {
        let mut c = LruCache::new(50);
        for round in 0..100u64 {
            for i in 0..5u64 {
                c.insert(round * 5 + i, 10);
            }
        }
        // Slab never exceeds the resident set by more than the churned slots.
        assert!(c.nodes.len() <= 16, "slab grew to {}", c.nodes.len());
    }

    #[test]
    fn remove_middle_keeps_list_consistent() {
        let mut c = LruCache::new(100);
        for i in 0..5u64 {
            c.insert(i, 10);
        }
        assert!(c.remove(2));
        let order: Vec<u64> = c.iter_mru().map(|(o, _)| o).collect();
        assert_eq!(order, vec![4, 3, 1, 0]);
        assert_eq!(c.used(), 40);
    }
}
