//! Strict LRU with byte capacity and heterogeneous object sizes.
//!
//! O(1) per operation: a `HashMap<ObjectId, slot>` indexes into a slab of
//! intrusive doubly-linked-list nodes with a free list, so steady-state
//! operation performs **no allocation** — the property the paper leans on
//! when arguing CDN caches must stay O(1) per request (§2.4).
//!
//! Placement subsystem: every node carries a tenant tag, per-tenant byte
//! tallies are maintained inline, evictions report `(tenant, bytes)`
//! through the caller's [`EvictionSink`], and optional per-tenant
//! protected floors (Memshare-style slab partitions) steer the eviction
//! victim choice away from tenants at or under their reservation.

use super::{EvictionSink, Store};
use crate::util::fasthash::FastMap;
use crate::{ObjectId, TenantId};

const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct Node {
    obj: ObjectId,
    size: u64,
    tenant: TenantId,
    prev: u32,
    next: u32,
}

/// Byte-capacity LRU cache.
#[derive(Debug)]
pub struct LruCache {
    capacity: u64,
    used: u64,
    map: FastMap<ObjectId, u32>,
    nodes: Vec<Node>,
    free: Vec<u32>,
    head: u32, // most recently used
    tail: u32, // least recently used
    evictions: u64,
    /// Resident bytes per tenant id (grown on demand).
    tenant_bytes: Vec<u64>,
    /// Protected byte floors per tenant id (empty = unpartitioned: the
    /// eviction victim is always the strict LRU tail, bit-identical to
    /// the pre-placement cache).
    floors: Vec<u64>,
}

impl LruCache {
    pub fn new(capacity: u64) -> Self {
        LruCache {
            capacity,
            used: 0,
            map: FastMap::default(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            evictions: 0,
            tenant_bytes: Vec::new(),
            floors: Vec::new(),
        }
    }

    /// Number of objects evicted to make room since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The least-recently-used object, if any (next eviction victim of an
    /// unpartitioned cache).
    pub fn lru_object(&self) -> Option<ObjectId> {
        (self.tail != NIL).then(|| self.nodes[self.tail as usize].obj)
    }

    #[inline]
    fn add_tenant(&mut self, tenant: TenantId, bytes: u64) {
        let i = tenant as usize;
        if self.tenant_bytes.len() <= i {
            self.tenant_bytes.resize(i + 1, 0);
        }
        self.tenant_bytes[i] += bytes;
    }

    #[inline]
    fn sub_tenant(&mut self, tenant: TenantId, bytes: u64) {
        let slot = &mut self.tenant_bytes[tenant as usize];
        debug_assert!(*slot >= bytes, "tenant {tenant} tally underflow");
        *slot = slot.saturating_sub(bytes);
    }

    /// Whether `tenant` is protected from cross-tenant eviction: it has a
    /// floor and currently holds no more than it.
    #[inline]
    fn protected(&self, tenant: TenantId) -> bool {
        let floor = self.floors.get(tenant as usize).copied().unwrap_or(0);
        floor > 0 && self.tenant_bytes.get(tenant as usize).copied().unwrap_or(0) <= floor
    }

    #[inline]
    fn unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.nodes[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.nodes[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    #[inline]
    fn push_front(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    #[inline]
    fn alloc(&mut self, obj: ObjectId, size: u64, tenant: TenantId) -> u32 {
        let node = Node { obj, size, tenant, prev: NIL, next: NIL };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = node;
                i
            }
            None => {
                let i = self.nodes.len() as u32;
                self.nodes.push(node);
                i
            }
        }
    }

    /// Evict the node at `idx`, reporting it to the sink.
    fn evict_at(&mut self, idx: u32, evicted: &mut EvictionSink) {
        let (obj, size, tenant) = {
            let n = &self.nodes[idx as usize];
            (n.obj, n.size, n.tenant)
        };
        self.unlink(idx);
        self.map.remove(&obj);
        self.free.push(idx);
        self.used -= size;
        self.sub_tenant(tenant, size);
        self.evictions += 1;
        evicted.push((tenant, size));
    }

    /// Iterate resident objects from MRU to LRU (test/debug helper).
    pub fn iter_mru(&self) -> impl Iterator<Item = (ObjectId, u64)> + '_ {
        self.iter_mru_tagged().map(|(o, s, _)| (o, s))
    }

    /// MRU-to-LRU iteration including the tenant tag (slab-class rebuild
    /// and placement tests).
    pub fn iter_mru_tagged(&self) -> impl Iterator<Item = (ObjectId, u64, TenantId)> + '_ {
        struct It<'a> {
            cache: &'a LruCache,
            cur: u32,
        }
        impl<'a> Iterator for It<'a> {
            type Item = (ObjectId, u64, TenantId);
            fn next(&mut self) -> Option<Self::Item> {
                if self.cur == NIL {
                    return None;
                }
                let n = &self.cache.nodes[self.cur as usize];
                self.cur = n.next;
                Some((n.obj, n.size, n.tenant))
            }
        }
        It { cache: self, cur: self.head }
    }

    /// Remove `obj`, returning its `(size, tenant)` (the slab store needs
    /// both to keep its own tallies exact).
    pub fn remove_entry(&mut self, obj: ObjectId) -> Option<(u64, TenantId)> {
        if let Some(idx) = self.map.remove(&obj) {
            let (size, tenant) = {
                let n = &self.nodes[idx as usize];
                (n.size, n.tenant)
            };
            self.unlink(idx);
            self.free.push(idx);
            self.used -= size;
            self.sub_tenant(tenant, size);
            Some((size, tenant))
        } else {
            None
        }
    }
}

impl Store for LruCache {
    fn capacity(&self) -> u64 {
        self.capacity
    }

    fn used(&self) -> u64 {
        self.used
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    #[inline]
    fn lookup(&mut self, obj: ObjectId) -> bool {
        if let Some(&idx) = self.map.get(&obj) {
            if idx != self.head {
                self.unlink(idx);
                self.push_front(idx);
            }
            true
        } else {
            false
        }
    }

    fn insert(&mut self, obj: ObjectId, size: u64) -> bool {
        if size > self.capacity {
            return false;
        }
        if self.lookup(obj) {
            return true; // refresh only
        }
        let mut sink = EvictionSink::new();
        self.insert_tagged(obj, size, 0, &mut sink) > 0
    }

    fn insert_tagged(
        &mut self,
        obj: ObjectId,
        size: u64,
        tenant: TenantId,
        evicted: &mut EvictionSink,
    ) -> u64 {
        if size > self.capacity {
            return 0;
        }
        if self.lookup(obj) {
            return 0; // refresh only
        }
        if !self.floors.is_empty() && self.used + size > self.capacity {
            // Feasibility first: bytes inside *other* tenants' protected
            // floors are unreclaimable, so an insert that cannot fit even
            // after evicting every pooled byte must be rejected up front —
            // never after flushing other tenants' pooled entries as
            // collateral.
            let protected_others: u64 = self
                .floors
                .iter()
                .enumerate()
                .filter(|&(t, &floor)| t != tenant as usize && floor > 0)
                .map(|(t, &floor)| floor.min(self.tenant_bytes.get(t).copied().unwrap_or(0)))
                .sum();
            if protected_others + size > self.capacity {
                return 0;
            }
        }
        if self.floors.is_empty() {
            // Unpartitioned: evict the strict LRU tail until it fits —
            // bit-identical to the pre-placement cache.
            while self.used + size > self.capacity {
                if self.tail == NIL {
                    break;
                }
                let idx = self.tail;
                self.evict_at(idx, evicted);
            }
        } else if self.used + size > self.capacity {
            // Partitioned: one tail→head sweep evicting pooled entries
            // (owners over their protected floor) and the inserting
            // tenant's own — never restarting at the tail, so an insert
            // costs at most one pass over the protected cold tail.
            // Owners can only *become* protected as the sweep drains
            // their pooled bytes, never the reverse, so a single pass
            // with per-node re-checks is exact.
            let mut cur = self.tail;
            while self.used + size > self.capacity && cur != NIL {
                let node = self.nodes[cur as usize];
                let prev = node.prev;
                if node.tenant == tenant || !self.protected(node.tenant) {
                    self.evict_at(cur, evicted);
                }
                cur = prev;
            }
        }
        if self.used + size > self.capacity {
            // Unreachable after the feasibility check; kept as a guard so
            // a partitioning bug can never overrun the capacity.
            return 0;
        }
        let idx = self.alloc(obj, size, tenant);
        self.map.insert(obj, idx);
        self.push_front(idx);
        self.used += size;
        self.add_tenant(tenant, size);
        size
    }

    fn tenant_bytes(&self, tenant: TenantId) -> u64 {
        self.tenant_bytes.get(tenant as usize).copied().unwrap_or(0)
    }

    fn evict_tenant(&mut self, tenant: TenantId, want: u64) -> u64 {
        let mut freed = 0u64;
        let mut cur = self.tail;
        let mut sink = EvictionSink::new();
        while cur != NIL && freed < want {
            let node = self.nodes[cur as usize];
            if node.tenant == tenant {
                self.evict_at(cur, &mut sink);
                freed += node.size;
            }
            cur = node.prev;
        }
        freed
    }

    fn set_tenant_floors(&mut self, floors: &[(TenantId, u64)]) {
        self.floors.clear();
        for &(t, f) in floors {
            let i = t as usize;
            if self.floors.len() <= i {
                self.floors.resize(i + 1, 0);
            }
            self.floors[i] = f;
        }
    }

    fn remove_entry(&mut self, obj: ObjectId) -> Option<(u64, TenantId)> {
        LruCache::remove_entry(self, obj)
    }

    fn contains(&self, obj: ObjectId) -> bool {
        self.map.contains_key(&obj)
    }

    fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.used = 0;
        self.tenant_bytes.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::conformance;

    #[test]
    fn conformance_suite() {
        conformance::run_all(|| Box::new(LruCache::new(1000)));
    }

    #[test]
    fn evicts_in_lru_order() {
        let mut c = LruCache::new(30);
        c.insert(1, 10);
        c.insert(2, 10);
        c.insert(3, 10);
        // Touch 1 so 2 becomes LRU.
        assert!(c.lookup(1));
        assert_eq!(c.lru_object(), Some(2));
        c.insert(4, 10); // evicts 2
        assert!(!c.contains(2));
        assert!(c.contains(1) && c.contains(3) && c.contains(4));
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn heterogeneous_sizes_evict_enough() {
        let mut c = LruCache::new(100);
        for i in 0..10u64 {
            c.insert(i, 10);
        }
        // Inserting a 95-byte object must evict until it fits.
        assert!(c.insert(100, 95));
        assert!(c.used() <= 100);
        assert!(c.contains(100));
        // 9 of the 10 small objects must have gone (95+10 > 100).
        assert_eq!(c.len(), 1 + (100 - 95) / 10);
    }

    #[test]
    fn mru_iteration_order() {
        let mut c = LruCache::new(100);
        c.insert(1, 10);
        c.insert(2, 10);
        c.insert(3, 10);
        c.lookup(2);
        let order: Vec<u64> = c.iter_mru().map(|(o, _)| o).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn free_list_reuses_slots() {
        let mut c = LruCache::new(50);
        for round in 0..100u64 {
            for i in 0..5u64 {
                c.insert(round * 5 + i, 10);
            }
        }
        // Slab never exceeds the resident set by more than the churned slots.
        assert!(c.nodes.len() <= 16, "slab grew to {}", c.nodes.len());
    }

    #[test]
    fn remove_middle_keeps_list_consistent() {
        let mut c = LruCache::new(100);
        for i in 0..5u64 {
            c.insert(i, 10);
        }
        assert!(c.remove(2));
        let order: Vec<u64> = c.iter_mru().map(|(o, _)| o).collect();
        assert_eq!(order, vec![4, 3, 1, 0]);
        assert_eq!(c.used(), 40);
    }

    #[test]
    fn targeted_eviction_takes_coldest_first() {
        let mut c = LruCache::new(1000);
        let mut sink = EvictionSink::new();
        for i in 0..5u64 {
            c.insert_tagged(i, 10, 1, &mut sink);
            c.insert_tagged(100 + i, 10, 2, &mut sink);
        }
        // Tenant 1's coldest entries are objects 0 and 1.
        assert_eq!(c.evict_tenant(1, 20), 20);
        assert!(!c.contains(0) && !c.contains(1));
        assert!(c.contains(2) && c.contains(3) && c.contains(4));
        // Tenant 2 untouched.
        for i in 0..5u64 {
            assert!(c.contains(100 + i));
        }
        assert_eq!(c.tenant_bytes(1), 30);
        assert_eq!(c.tenant_bytes(2), 50);
    }

    #[test]
    fn floors_protect_reserved_tenants_from_cross_eviction() {
        let mut c = LruCache::new(100);
        c.set_tenant_floors(&[(1, 40)]);
        let mut sink = EvictionSink::new();
        // Tenant 1 holds exactly its floor; its entries are the coldest.
        for i in 0..4u64 {
            c.insert_tagged(i, 10, 1, &mut sink);
        }
        // Tenant 2 fills the pool, then overflows: it must evict its own
        // (pooled) entries, never tenant 1's protected ones.
        for i in 100..110u64 {
            c.insert_tagged(i, 10, 2, &mut sink);
        }
        assert_eq!(c.tenant_bytes(1), 40, "reservation must survive");
        assert!(sink.iter().all(|&(t, _)| t == 2), "{sink:?}");
        assert!(c.used() <= 100);
        // Tenant 1 itself may still churn its own entries past the floor.
        sink.clear();
        assert_eq!(c.insert_tagged(50, 10, 1, &mut sink), 10);
        assert_eq!(c.tenant_bytes(1), 40);
        assert!(sink.iter().any(|&(t, _)| t == 1), "{sink:?}");
        // Clearing the floors restores strict-LRU victims.
        c.set_tenant_floors(&[]);
        sink.clear();
        c.insert_tagged(51, 10, 2, &mut sink);
        assert_eq!(sink.len(), 1);
    }

    #[test]
    fn infeasible_partitioned_insert_evicts_no_collateral() {
        let mut c = LruCache::new(100);
        c.set_tenant_floors(&[(1, 40)]);
        let mut sink = EvictionSink::new();
        for i in 0..4u64 {
            c.insert_tagged(i, 10, 1, &mut sink);
        }
        for i in 10..16u64 {
            c.insert_tagged(i, 10, 3, &mut sink);
        }
        assert!(sink.is_empty());
        // Tenant 2 wants 70 bytes but only 60 pooled bytes exist (tenant
        // 1's 40 are protected): the insert must be rejected *before*
        // flushing tenant 3's pooled entries as collateral.
        assert_eq!(c.insert_tagged(99, 70, 2, &mut sink), 0);
        assert!(sink.is_empty(), "no collateral evictions: {sink:?}");
        assert_eq!(c.tenant_bytes(3), 60);
        assert_eq!(c.tenant_bytes(1), 40);
        assert!(!c.contains(99));
    }

    #[test]
    fn fully_reserved_cache_rejects_foreign_inserts() {
        let mut c = LruCache::new(40);
        c.set_tenant_floors(&[(1, 40)]);
        let mut sink = EvictionSink::new();
        for i in 0..4u64 {
            c.insert_tagged(i, 10, 1, &mut sink);
        }
        // Tenant 2 can evict nothing and holds nothing: the insert is
        // rejected instead of violating tenant 1's reservation.
        assert_eq!(c.insert_tagged(99, 10, 2, &mut sink), 0);
        assert!(!c.contains(99));
        assert_eq!(c.tenant_bytes(1), 40);
    }
}
