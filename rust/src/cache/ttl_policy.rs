//! Real wall-clock TTL expiry for resident entries (server runtime).
//!
//! The engine's TTL controller (§4) steers a *virtual* TTL; the physical
//! stores are capacity-bound LRU variants that never expire anything on
//! their own. A live server wants the classic cache semantics too: an
//! entry older than its TTL must read as a miss. This module supplies
//! that with the lazy check-on-access pattern (no timer wheel, no
//! background scan on the request path): every resident entry carries a
//! [`TtlPolicy`] — its TTL plus the [`Instant`] it was created or last
//! renewed — and the *next access* to an expired entry removes it,
//! counts a miss, and debits the cluster's per-tenant resident ledger so
//! the `Σ tenant_resident == used()` invariant keeps holding.
//!
//! Off by default (`[serve] ttl_expiry_secs = 0`): the simulator and the
//! parity-pinned server never construct an [`ExpiryIndex`], keeping the
//! request path bit-identical.

use crate::ObjectId;
use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Per-entry expiry state: a fixed TTL anchored at the creation (or last
/// renewal) instant. Checked on read; never drives a timer.
#[derive(Debug, Clone, Copy)]
pub struct TtlPolicy {
    /// Time-to-live of the entry.
    pub ttl: Duration,
    /// When the entry was created or last renewed.
    pub creation: Instant,
}

impl TtlPolicy {
    /// A policy expiring `ttl` from now.
    pub fn new(ttl: Duration) -> Self {
        TtlPolicy { ttl, creation: Instant::now() }
    }

    /// Whether the entry has outlived its TTL.
    pub fn is_expired(&self) -> bool {
        self.creation.elapsed() > self.ttl
    }

    /// Time remaining before expiry ([`Duration::ZERO`] once expired).
    pub fn expire_in(&self) -> Duration {
        self.ttl.saturating_sub(self.creation.elapsed())
    }

    /// Renew the policy: the TTL now runs from this instant (TTL caches
    /// in the paper's model renew on every hit, matching the virtual
    /// cache's semantics).
    pub fn touch(&mut self) {
        self.creation = Instant::now();
    }
}

/// Cluster-level index of [`TtlPolicy`]s for resident entries, keyed by
/// scoped object id. The cluster consults it on every access when expiry
/// is enabled; entries evicted by LRU churn leave stale policies behind,
/// which are dropped lazily (on their next access, or by the
/// epoch-boundary [`ExpiryIndex::take_expired`] sweep).
#[derive(Debug)]
pub struct ExpiryIndex {
    ttl: Duration,
    policies: HashMap<ObjectId, TtlPolicy>,
    /// Entries removed because their TTL ran out.
    pub expirations: u64,
    /// Bytes those removals freed.
    pub expired_bytes: u64,
}

impl ExpiryIndex {
    /// An index expiring every entry `ttl` after its last access.
    pub fn new(ttl: Duration) -> Self {
        ExpiryIndex {
            ttl,
            policies: HashMap::new(),
            expirations: 0,
            expired_bytes: 0,
        }
    }

    /// The configured TTL.
    pub fn ttl(&self) -> Duration {
        self.ttl
    }

    /// Policies currently tracked (resident entries plus stale leftovers
    /// awaiting their lazy drop).
    pub fn len(&self) -> usize {
        self.policies.len()
    }

    /// Whether the index tracks no policies.
    pub fn is_empty(&self) -> bool {
        self.policies.is_empty()
    }

    /// The access-path check: `true` means `obj`'s policy had expired —
    /// the caller must remove the resident entry and account the miss.
    /// A live policy is renewed (TTL-on-access); an expired one is
    /// forgotten here so the follow-up insert starts a fresh policy.
    pub fn check_expired(&mut self, obj: ObjectId) -> bool {
        match self.policies.get_mut(&obj) {
            Some(p) if p.is_expired() => {
                self.policies.remove(&obj);
                true
            }
            Some(p) => {
                p.touch();
                false
            }
            None => false,
        }
    }

    /// A fresh entry was inserted: arm its policy.
    pub fn note_insert(&mut self, obj: ObjectId) {
        self.policies.insert(obj, TtlPolicy::new(self.ttl));
    }

    /// Drain every expired policy (epoch-boundary sweep, off the request
    /// path) — returns the object ids so the caller can remove any still
    /// resident copies and debit the ledger.
    pub fn take_expired(&mut self) -> Vec<ObjectId> {
        let expired: Vec<ObjectId> = self
            .policies
            .iter()
            .filter(|(_, p)| p.is_expired())
            .map(|(&o, _)| o)
            .collect();
        for o in &expired {
            self.policies.remove(o);
        }
        expired
    }

    /// Account an expiry-driven removal.
    pub fn record_expiry(&mut self, bytes: u64) {
        self.expirations += 1;
        self.expired_bytes += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_expires_after_ttl() {
        let p = TtlPolicy::new(Duration::from_millis(20));
        assert!(!p.is_expired());
        assert!(p.expire_in() > Duration::ZERO);
        std::thread::sleep(Duration::from_millis(30));
        assert!(p.is_expired());
        assert_eq!(p.expire_in(), Duration::ZERO);
    }

    #[test]
    fn touch_renews_the_clock() {
        let mut p = TtlPolicy::new(Duration::from_millis(40));
        std::thread::sleep(Duration::from_millis(25));
        p.touch();
        std::thread::sleep(Duration::from_millis(25));
        assert!(!p.is_expired(), "renewal must restart the TTL");
    }

    #[test]
    fn index_checks_and_renews_on_access() {
        let mut idx = ExpiryIndex::new(Duration::from_millis(30));
        idx.note_insert(7);
        assert!(!idx.check_expired(7), "fresh entry is live");
        assert!(!idx.check_expired(99), "unknown object is never expired");
        std::thread::sleep(Duration::from_millis(40));
        assert!(idx.check_expired(7), "stale entry expires on access");
        assert!(!idx.check_expired(7), "the expiry dropped the policy");
        assert!(idx.is_empty());
    }

    #[test]
    fn sweep_drains_only_the_expired() {
        let mut idx = ExpiryIndex::new(Duration::from_millis(25));
        idx.note_insert(1);
        idx.note_insert(2);
        assert!(idx.take_expired().is_empty(), "nothing expired yet");
        std::thread::sleep(Duration::from_millis(35));
        idx.note_insert(3);
        let mut gone = idx.take_expired();
        gone.sort_unstable();
        assert_eq!(gone, vec![1, 2]);
        assert_eq!(idx.len(), 1, "the fresh policy survives the sweep");
    }
}
