//! Exact-calendar TTL cache (with or without renewal) — the *ideal* TTL
//! cache of §4: every object is evicted exactly when its timer expires.
//!
//! The calendar is a `BTreeMap<(expiry, obj), ()>`, so each request costs
//! O(log M). This is the reference implementation against which the O(1)
//! FIFO-calendar virtual cache ([`crate::vcache::FifoTtlCache`]) is
//! validated (§5.1: "we compare the TTL based solution corresponding with
//! (7) with our solution achieving O(1) complexity, and we observed no
//! significant difference").

use crate::{ObjectId, TimeUs};
use std::collections::{BTreeMap, HashMap};

/// TTL policy family (§4): with renewal, hits reset the timer; without,
/// the timer set at miss time is untouched by later hits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TtlMode {
    WithRenewal,
    WithoutRenewal,
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    size: u64,
    expiry: TimeUs,
}

/// Exact TTL cache storing metadata only (sizes, not payloads).
#[derive(Debug)]
pub struct IdealTtlCache {
    mode: TtlMode,
    map: HashMap<ObjectId, Entry>,
    calendar: BTreeMap<(TimeUs, ObjectId), ()>,
    used: u64,
}

impl IdealTtlCache {
    pub fn new(mode: TtlMode) -> Self {
        IdealTtlCache {
            mode,
            map: HashMap::new(),
            calendar: BTreeMap::new(),
            used: 0,
        }
    }

    pub fn mode(&self) -> TtlMode {
        self.mode
    }

    /// Bytes of non-expired content (exact, given `expire_until` was called
    /// at the current time).
    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, obj: ObjectId) -> bool {
        self.map.contains_key(&obj)
    }

    /// Evict every entry whose timer expired at or before `now`.
    /// Returns the number of evictions.
    pub fn expire_until(&mut self, now: TimeUs) -> usize {
        let mut n = 0;
        loop {
            let Some((&(exp, obj), _)) = self.calendar.iter().next() else { break };
            if exp > now {
                break;
            }
            self.calendar.remove(&(exp, obj));
            if let Some(e) = self.map.remove(&obj) {
                self.used -= e.size;
                n += 1;
            }
        }
        n
    }

    /// Process a request for `obj` of `size` bytes at `now` with the
    /// current timer `ttl` (µs). Returns `true` on hit.
    ///
    /// Expiry is processed first, so a request arriving after the object's
    /// timer lapsed is a miss even if no eviction event ran in between.
    pub fn on_request(&mut self, now: TimeUs, obj: ObjectId, size: u64, ttl: TimeUs) -> bool {
        self.expire_until(now);
        match self.map.get_mut(&obj) {
            Some(e) => {
                if self.mode == TtlMode::WithRenewal {
                    let old = e.expiry;
                    e.expiry = now + ttl;
                    let new_expiry = e.expiry;
                    self.calendar.remove(&(old, obj));
                    self.calendar.insert((new_expiry, obj), ());
                }
                true
            }
            None => {
                let expiry = now + ttl;
                self.map.insert(obj, Entry { size, expiry });
                self.calendar.insert((expiry, obj), ());
                self.used += size;
                false
            }
        }
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.calendar.clear();
        self.used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SECOND;

    #[test]
    fn miss_then_hit_within_ttl() {
        let mut c = IdealTtlCache::new(TtlMode::WithRenewal);
        assert!(!c.on_request(0, 1, 100, 10 * SECOND));
        assert!(c.on_request(5 * SECOND, 1, 100, 10 * SECOND));
        assert_eq!(c.used(), 100);
    }

    #[test]
    fn expires_exactly_at_timer() {
        let mut c = IdealTtlCache::new(TtlMode::WithRenewal);
        c.on_request(0, 1, 100, 10 * SECOND);
        // at t=10s the entry expires (expiry inclusive)
        assert!(!c.on_request(10 * SECOND, 1, 100, 10 * SECOND));
    }

    #[test]
    fn renewal_extends_lifetime() {
        let mut c = IdealTtlCache::new(TtlMode::WithRenewal);
        c.on_request(0, 1, 100, 10 * SECOND);
        assert!(c.on_request(9 * SECOND, 1, 100, 10 * SECOND)); // renews to 19s
        assert!(c.on_request(18 * SECOND, 1, 100, 10 * SECOND)); // renews to 28s
        assert!(!c.on_request(29 * SECOND, 1, 100, 10 * SECOND));
    }

    #[test]
    fn without_renewal_hits_do_not_extend() {
        let mut c = IdealTtlCache::new(TtlMode::WithoutRenewal);
        c.on_request(0, 1, 100, 10 * SECOND);
        assert!(c.on_request(9 * SECOND, 1, 100, 10 * SECOND)); // hit, no renewal
        // original timer (10s) has lapsed:
        assert!(!c.on_request(11 * SECOND, 1, 100, 10 * SECOND));
    }

    #[test]
    fn used_tracks_unexpired_bytes() {
        let mut c = IdealTtlCache::new(TtlMode::WithRenewal);
        c.on_request(0, 1, 100, 5 * SECOND);
        c.on_request(0, 2, 200, 50 * SECOND);
        assert_eq!(c.used(), 300);
        c.expire_until(10 * SECOND);
        assert_eq!(c.used(), 200);
        assert!(!c.contains(1));
        assert!(c.contains(2));
    }

    #[test]
    fn zero_ttl_stores_nothing_usable() {
        let mut c = IdealTtlCache::new(TtlMode::WithRenewal);
        assert!(!c.on_request(0, 1, 100, 0));
        // expires immediately: next request at any later time misses
        assert!(!c.on_request(1, 1, 100, 0));
    }

    #[test]
    fn many_objects_expire_in_order() {
        let mut c = IdealTtlCache::new(TtlMode::WithRenewal);
        for i in 0..100u64 {
            c.on_request(i * SECOND, i, 10, 50 * SECOND);
        }
        // at t=120s objects with expiry <= 120s are gone: i + 50 <= 120
        c.expire_until(120 * SECOND);
        for i in 0..100u64 {
            assert_eq!(c.contains(i), i + 50 > 120, "obj {i}");
        }
    }
}
