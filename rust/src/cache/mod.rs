//! Physical cache substrates (§2.1): the fixed-size in-memory stores that
//! the cluster's instances run.
//!
//! * [`LruCache`] — strict O(1) LRU over heterogeneous-size objects
//!   (intrusive doubly linked list on a slab, no per-request allocation).
//! * [`SampledLruCache`] — Redis-style eviction: sample 5 random entries,
//!   evict the least recently used, repeat until there is room.
//! * [`SlabCache`] — Memcached-style size classes with per-class LRU.
//! * [`IdealTtlCache`] — an exact-calendar TTL cache (BTreeMap calendar,
//!   O(log M)) used as the ground-truth reference for the O(1)
//!   FIFO-calendar virtual cache of §5.1.
//! * [`CacheInstance`] — one cluster node: an eviction policy plus
//!   hit/miss/byte counters.

mod ideal_ttl;
mod instance;
mod lru;
mod sampled_lru;
mod slab;
mod ttl_policy;

pub use ideal_ttl::{IdealTtlCache, TtlMode};
pub use instance::CacheInstance;
pub use lru::LruCache;
pub use sampled_lru::SampledLruCache;
pub use slab::SlabCache;
pub use ttl_policy::{ExpiryIndex, TtlPolicy};

use crate::{ObjectId, TenantId};

/// Sink for eviction events: every entry a store evicts to make room is
/// reported upward as `(owning tenant, bytes freed)` so the cluster's
/// per-tenant resident ledger stays exact (placement subsystem).
pub type EvictionSink = Vec<(TenantId, u64)>;

/// Common interface of the physical stores. `lookup` returns whether the
/// object was present (a hit) and refreshes recency; `insert` stores the
/// object, evicting as needed; objects larger than the capacity are
/// rejected (never stored) — mirroring Memcached/Redis behaviour.
///
/// Every entry carries a tenant tag: [`Store::insert_tagged`] is the
/// primary insert path (the cluster's), with the untagged [`Store::insert`]
/// kept as the tenant-0 convenience used by standalone callers and tests.
pub trait Store {
    /// Capacity in bytes.
    fn capacity(&self) -> u64;
    /// Bytes currently used.
    fn used(&self) -> u64;
    /// Number of resident objects.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Look up `obj`; on hit, refresh its recency. Returns hit/miss.
    fn lookup(&mut self, obj: ObjectId) -> bool;
    /// Insert `obj` of `size` bytes (no-op if already present, which
    /// refreshes recency instead). Returns false if the object cannot fit
    /// at all.
    fn insert(&mut self, obj: ObjectId, size: u64) -> bool;
    /// Insert `obj` of `size` bytes tagged with `tenant`, appending every
    /// evicted entry's `(tenant, bytes)` to `evicted`. Returns the bytes
    /// this insert added to [`Store::used`] (slab stores round up to a
    /// chunk): 0 when the object was rejected, or was already resident
    /// and only had its recency refreshed.
    fn insert_tagged(
        &mut self,
        obj: ObjectId,
        size: u64,
        tenant: TenantId,
        evicted: &mut EvictionSink,
    ) -> u64;
    /// Bytes currently resident for `tenant` (the instance-local slice of
    /// the cluster ledger).
    fn tenant_bytes(&self, tenant: TenantId) -> u64;
    /// Evict up to `want` bytes of `tenant`'s entries, coldest first.
    /// Returns the bytes actually freed (less than `want` when the tenant
    /// holds fewer). Targeted shedding for resident-byte occupancy caps;
    /// runs at epoch boundaries, not on the request path.
    fn evict_tenant(&mut self, tenant: TenantId, want: u64) -> u64;
    /// Install per-tenant protected byte floors (slab-partition
    /// placement): a tenant holding at most its floor is immune to
    /// cross-tenant eviction; bytes above the floors are pooled and
    /// evictable by anyone. An empty slice clears the partitioning. The
    /// default ignores floors (stores without victim choice, e.g. slab
    /// size classes, fall back to plain behaviour).
    fn set_tenant_floors(&mut self, _floors: &[(TenantId, u64)]) {}
    /// Remove `obj` if present; returns true if it was resident.
    fn remove(&mut self, obj: ObjectId) -> bool {
        self.remove_entry(obj).is_some()
    }
    /// Remove `obj` if present, returning the bytes it freed from
    /// [`Store::used`] and the owning tenant — the lazy TTL expiry path
    /// needs both to debit the cluster's resident ledger exactly.
    fn remove_entry(&mut self, obj: ObjectId) -> Option<(u64, TenantId)>;
    /// Whether `obj` is resident, without touching recency.
    fn contains(&self, obj: ObjectId) -> bool;
    /// Drop everything.
    fn clear(&mut self);
}

/// Build a store of the configured eviction kind.
pub fn make_store(
    kind: crate::config::EvictionKind,
    capacity: u64,
    seed: u64,
) -> Box<dyn Store + Send> {
    use crate::config::EvictionKind::*;
    match kind {
        Lru => Box::new(LruCache::new(capacity)),
        SampledLru => Box::new(SampledLruCache::new(capacity, seed)),
        Slab => Box::new(SlabCache::new(capacity)),
    }
}

#[cfg(test)]
pub(crate) mod conformance {
    //! Shared behavioural tests run against every [`Store`] implementation.
    use super::*;

    pub fn basic_hit_miss(store: &mut dyn Store) {
        assert!(!store.lookup(1), "cold lookup must miss");
        assert!(store.insert(1, 100));
        assert!(store.lookup(1), "must hit after insert");
        // Slab stores round up to a chunk; LRU stores use the exact size.
        assert!((100..=256).contains(&store.used()), "used={}", store.used());
        assert_eq!(store.len(), 1);
        assert!(store.remove(1));
        assert!(!store.remove(1));
        assert!(!store.lookup(1));
        assert_eq!(store.used(), 0);
    }

    pub fn capacity_respected(store: &mut dyn Store) {
        let cap = store.capacity();
        // Fill with objects of cap/10 bytes each; used() never exceeds cap.
        for i in 0..100u64 {
            store.insert(i, cap / 10);
            assert!(store.used() <= cap, "used {} > cap {}", store.used(), cap);
        }
        assert!(store.len() <= 10);
    }

    pub fn oversized_rejected(store: &mut dyn Store) {
        assert!(!store.insert(99, store.capacity() + 1));
        assert!(!store.contains(99));
    }

    pub fn reinsert_refreshes_not_duplicates(store: &mut dyn Store) {
        store.insert(5, 10);
        let used = store.used();
        store.insert(5, 10);
        assert_eq!(store.used(), used);
        assert_eq!(store.len(), 1);
    }

    pub fn clear_resets(store: &mut dyn Store) {
        for i in 0..5u64 {
            store.insert(i, 10);
        }
        store.clear();
        assert_eq!(store.len(), 0);
        assert_eq!(store.used(), 0);
        assert!(!store.contains(0));
        assert_eq!(store.tenant_bytes(0), 0, "clear must reset the tags");
    }

    pub fn tenant_tags_partition_used(store: &mut dyn Store) {
        let mut sink = EvictionSink::new();
        // Interleave three tenants; tags must partition used() exactly.
        for i in 0..9u64 {
            store.insert_tagged(i, 20, (i % 3) as TenantId, &mut sink);
        }
        let total: u64 = (0..3).map(|t| store.tenant_bytes(t)).sum();
        assert_eq!(total, store.used(), "tags must partition used()");
        assert_eq!(store.tenant_bytes(99), 0, "unseen tenant reads zero");
        // Refreshing an existing entry adds nothing.
        let before = store.tenant_bytes(0);
        assert_eq!(store.insert_tagged(0, 20, 0, &mut sink), 0);
        assert_eq!(store.tenant_bytes(0), before);
        // Untagged inserts land on tenant 0.
        let before = store.tenant_bytes(0);
        assert!(store.insert(1000, 20));
        assert!(store.tenant_bytes(0) >= before + 20);
        // Removal gives the bytes back to the owner's tally.
        assert!(store.remove(1000));
        let total: u64 = (0..3).map(|t| store.tenant_bytes(t)).sum();
        assert_eq!(total, store.used());
    }

    pub fn evictions_reported_and_targeted(store: &mut dyn Store) {
        let cap = store.capacity();
        let obj_sz = cap / 10;
        let mut sink = EvictionSink::new();
        // Fill with tenant 1, then overflow with tenant 2: every evicted
        // byte must be reported, and the tallies must stay consistent.
        for i in 0..10u64 {
            store.insert_tagged(i, obj_sz, 1, &mut sink);
        }
        assert!(sink.is_empty(), "no evictions while filling to capacity");
        for i in 100..105u64 {
            store.insert_tagged(i, obj_sz, 2, &mut sink);
        }
        let reported: u64 = sink.iter().map(|&(_, b)| b).sum();
        assert!(reported > 0, "overflow must report evictions");
        let total: u64 = (0..4).map(|t| store.tenant_bytes(t)).sum();
        assert_eq!(total, store.used());
        // Targeted shed: tenant 1 loses bytes, tenant 2 is untouched.
        let t2 = store.tenant_bytes(2);
        let have = store.tenant_bytes(1);
        let freed = store.evict_tenant(1, obj_sz * 2);
        assert!(freed >= obj_sz.min(have), "freed={freed} have={have}");
        assert_eq!(store.tenant_bytes(2), t2);
        assert_eq!(store.tenant_bytes(1), have - freed);
        // Shedding more than the tenant holds frees exactly what it has.
        let rest = store.tenant_bytes(1);
        assert_eq!(store.evict_tenant(1, u64::MAX), rest);
        assert_eq!(store.tenant_bytes(1), 0);
    }

    pub fn remove_entry_reports_owner(store: &mut dyn Store) {
        let mut sink = EvictionSink::new();
        store.insert_tagged(11, 64, 3, &mut sink);
        let used = store.used();
        let (bytes, tenant) = store.remove_entry(11).expect("entry is resident");
        assert_eq!(tenant, 3, "removal must report the owning tenant");
        assert_eq!(store.used(), used - bytes, "removal must free exactly its bytes");
        assert_eq!(store.tenant_bytes(3), 0);
        assert!(store.remove_entry(11).is_none(), "second removal finds nothing");
    }

    pub fn run_all(mk: impl Fn() -> Box<dyn Store + Send>) {
        basic_hit_miss(&mut *mk());
        capacity_respected(&mut *mk());
        oversized_rejected(&mut *mk());
        reinsert_refreshes_not_duplicates(&mut *mk());
        clear_resets(&mut *mk());
        tenant_tags_partition_used(&mut *mk());
        evictions_reported_and_targeted(&mut *mk());
        remove_entry_reports_owner(&mut *mk());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvictionKind;

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [EvictionKind::Lru, EvictionKind::SampledLru, EvictionKind::Slab] {
            let mut s = make_store(kind, 1000, 1);
            assert_eq!(s.capacity(), 1000);
            conformance::basic_hit_miss(&mut *s);
        }
    }
}
