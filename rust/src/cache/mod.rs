//! Physical cache substrates (§2.1): the fixed-size in-memory stores that
//! the cluster's instances run.
//!
//! * [`LruCache`] — strict O(1) LRU over heterogeneous-size objects
//!   (intrusive doubly linked list on a slab, no per-request allocation).
//! * [`SampledLruCache`] — Redis-style eviction: sample 5 random entries,
//!   evict the least recently used, repeat until there is room.
//! * [`SlabCache`] — Memcached-style size classes with per-class LRU.
//! * [`IdealTtlCache`] — an exact-calendar TTL cache (BTreeMap calendar,
//!   O(log M)) used as the ground-truth reference for the O(1)
//!   FIFO-calendar virtual cache of §5.1.
//! * [`CacheInstance`] — one cluster node: an eviction policy plus
//!   hit/miss/byte counters.

mod ideal_ttl;
mod instance;
mod lru;
mod sampled_lru;
mod slab;

pub use ideal_ttl::{IdealTtlCache, TtlMode};
pub use instance::CacheInstance;
pub use lru::LruCache;
pub use sampled_lru::SampledLruCache;
pub use slab::SlabCache;

use crate::ObjectId;

/// Common interface of the physical stores. `lookup` returns whether the
/// object was present (a hit) and refreshes recency; `insert` stores the
/// object, evicting as needed; objects larger than the capacity are
/// rejected (never stored) — mirroring Memcached/Redis behaviour.
pub trait Store {
    /// Capacity in bytes.
    fn capacity(&self) -> u64;
    /// Bytes currently used.
    fn used(&self) -> u64;
    /// Number of resident objects.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Look up `obj`; on hit, refresh its recency. Returns hit/miss.
    fn lookup(&mut self, obj: ObjectId) -> bool;
    /// Insert `obj` of `size` bytes (no-op if already present, which
    /// refreshes recency instead). Returns false if the object cannot fit
    /// at all.
    fn insert(&mut self, obj: ObjectId, size: u64) -> bool;
    /// Remove `obj` if present; returns true if it was resident.
    fn remove(&mut self, obj: ObjectId) -> bool;
    /// Whether `obj` is resident, without touching recency.
    fn contains(&self, obj: ObjectId) -> bool;
    /// Drop everything.
    fn clear(&mut self);
}

/// Build a store of the configured eviction kind.
pub fn make_store(kind: crate::config::EvictionKind, capacity: u64, seed: u64) -> Box<dyn Store + Send> {
    use crate::config::EvictionKind::*;
    match kind {
        Lru => Box::new(LruCache::new(capacity)),
        SampledLru => Box::new(SampledLruCache::new(capacity, seed)),
        Slab => Box::new(SlabCache::new(capacity)),
    }
}

#[cfg(test)]
pub(crate) mod conformance {
    //! Shared behavioural tests run against every [`Store`] implementation.
    use super::*;

    pub fn basic_hit_miss(store: &mut dyn Store) {
        assert!(!store.lookup(1), "cold lookup must miss");
        assert!(store.insert(1, 100));
        assert!(store.lookup(1), "must hit after insert");
        // Slab stores round up to a chunk; LRU stores use the exact size.
        assert!((100..=256).contains(&store.used()), "used={}", store.used());
        assert_eq!(store.len(), 1);
        assert!(store.remove(1));
        assert!(!store.remove(1));
        assert!(!store.lookup(1));
        assert_eq!(store.used(), 0);
    }

    pub fn capacity_respected(store: &mut dyn Store) {
        let cap = store.capacity();
        // Fill with objects of cap/10 bytes each; used() never exceeds cap.
        for i in 0..100u64 {
            store.insert(i, cap / 10);
            assert!(store.used() <= cap, "used {} > cap {}", store.used(), cap);
        }
        assert!(store.len() <= 10);
    }

    pub fn oversized_rejected(store: &mut dyn Store) {
        assert!(!store.insert(99, store.capacity() + 1));
        assert!(!store.contains(99));
    }

    pub fn reinsert_refreshes_not_duplicates(store: &mut dyn Store) {
        store.insert(5, 10);
        let used = store.used();
        store.insert(5, 10);
        assert_eq!(store.used(), used);
        assert_eq!(store.len(), 1);
    }

    pub fn clear_resets(store: &mut dyn Store) {
        for i in 0..5u64 {
            store.insert(i, 10);
        }
        store.clear();
        assert_eq!(store.len(), 0);
        assert_eq!(store.used(), 0);
        assert!(!store.contains(0));
    }

    pub fn run_all(mk: impl Fn() -> Box<dyn Store + Send>) {
        basic_hit_miss(&mut *mk());
        capacity_respected(&mut *mk());
        oversized_rejected(&mut *mk());
        reinsert_refreshes_not_duplicates(&mut *mk());
        clear_resets(&mut *mk());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvictionKind;

    #[test]
    fn factory_builds_all_kinds() {
        for kind in [EvictionKind::Lru, EvictionKind::SampledLru, EvictionKind::Slab] {
            let mut s = make_store(kind, 1000, 1);
            assert_eq!(s.capacity(), 1000);
            conformance::basic_hit_miss(&mut *s);
        }
    }
}
