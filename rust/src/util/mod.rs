//! In-tree replacements for the usual utility crates — the build is fully
//! offline (only the `xla` closure + `anyhow` are vendored), so the
//! project ships its own:
//!
//! * [`fasthash`] — mix64-based hashing for the u64-keyed hot maps;
//! * [`rng`] — PCG-family deterministic RNG (`rand`/`rand_pcg` stand-in);
//! * [`tempdir`] — scoped temporary directories (`tempfile` stand-in);
//! * [`toml_lite`] — the TOML subset the config system needs;
//! * [`bench`] — a criterion-style timing harness for `cargo bench`
//!   targets (`harness = false`);
//! * [`proptest`] — a tiny randomized property-test driver with failure
//!   reporting (shrinking is replaced by seed reporting).

pub mod bench;
pub mod fasthash;
pub mod proptest;
pub mod rng;
pub mod tempdir;
pub mod toml_lite;
