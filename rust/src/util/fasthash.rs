//! Fast hashing for the request-path hash maps (offline stand-in for
//! `fxhash`/`ahash`): object ids are already well-distributed u64 keys,
//! so a single SplitMix64 finalization round replaces SipHash-1-3 on the
//! hot maps (virtual cache ghosts, LRU index, MRC last-access, popularity
//! counters). Measured ≈2× on the router hot path — see EXPERIMENTS.md
//! §Perf.

use std::hash::{BuildHasherDefault, Hasher};

/// Hasher specialized for u64-keyed maps: the last `write_u64` value,
/// mixed. Other writes fold bytes in FNV-style first (used only by tests
/// and string keys, which are off the hot path).
#[derive(Default)]
pub struct Mix64Hasher {
    state: u64,
}

impl Hasher for Mix64Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        crate::mix64(self.state)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Cold path: fold arbitrary bytes (FNV-1a) into the state.
        let mut h = self.state ^ 0xcbf29ce484222325;
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        self.state = h;
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.state = self.state.rotate_left(29) ^ i;
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.write_u64(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

/// `BuildHasher` for the hot maps.
pub type Mix64Build = BuildHasherDefault<Mix64Hasher>;

/// `HashMap` keyed by well-distributed integers on the request path.
pub type FastMap<K, V> = std::collections::HashMap<K, V, Mix64Build>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..10_000u64 {
            m.insert(i, (i * 3) as u32);
        }
        for i in 0..10_000u64 {
            assert_eq!(m.get(&i), Some(&((i * 3) as u32)));
        }
        for i in (0..10_000u64).step_by(2) {
            assert_eq!(m.remove(&i), Some((i * 3) as u32));
        }
        assert_eq!(m.len(), 5_000);
    }

    #[test]
    fn distinct_keys_hash_distinctly() {
        use std::hash::BuildHasher;
        let b = Mix64Build::default();
        let mut seen = std::collections::HashSet::new();
        for i in 0..100_000u64 {
            seen.insert(b.hash_one(i));
        }
        assert_eq!(seen.len(), 100_000, "collisions among sequential keys");
    }

    #[test]
    fn string_keys_also_work() {
        let mut m: std::collections::HashMap<String, u32, Mix64Build> =
            Default::default();
        m.insert("alpha".into(), 1);
        m.insert("beta".into(), 2);
        assert_eq!(m["alpha"], 1);
        assert_eq!(m["beta"], 2);
    }
}
