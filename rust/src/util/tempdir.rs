//! Scoped temporary directories (offline stand-in for `tempfile`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A directory under the system temp root, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh unique directory.
    pub fn new() -> std::io::Result<TempDir> {
        let unique = format!(
            "elastictl-{}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0),
        );
        let path = std::env::temp_dir().join(unique);
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Keep the directory (skip cleanup), returning its path.
    pub fn into_path(mut self) -> PathBuf {
        let p = std::mem::take(&mut self.path);
        std::mem::forget(self);
        p
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        if !self.path.as_os_str().is_empty() {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

/// `tempfile::tempdir()`-compatible helper.
pub fn tempdir() -> std::io::Result<TempDir> {
    TempDir::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let keep;
        {
            let d = tempdir().unwrap();
            keep = d.path().to_path_buf();
            std::fs::write(d.path().join("x"), b"hello").unwrap();
            assert!(keep.exists());
        }
        assert!(!keep.exists(), "dir not removed on drop");
    }

    #[test]
    fn unique_paths() {
        let a = tempdir().unwrap();
        let b = tempdir().unwrap();
        assert_ne!(a.path(), b.path());
    }

    #[test]
    fn into_path_persists() {
        let d = tempdir().unwrap();
        let p = d.into_path();
        assert!(p.exists());
        std::fs::remove_dir_all(&p).unwrap();
    }
}
