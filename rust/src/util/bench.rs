//! Criterion-style micro/meso bench harness for `harness = false` bench
//! targets (offline stand-in for `criterion`).
//!
//! Measures wall-clock over warmup + measured iterations, reports mean /
//! p50 / p99 per-iteration time and derived throughput, and appends
//! machine-readable rows to `target/bench_results.csv` so EXPERIMENTS.md
//! tables can be regenerated.

use std::time::{Duration, Instant};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Interpolated from a log-scale histogram of the samples
    /// ([`crate::metrics::LogHistogram::quantile`]), so the tail estimate
    /// stays meaningful even when fewer than 1000 iterations ran.
    pub p999_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements_per_iter: u64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            self.elements_per_iter as f64 * 1e9 / self.mean_ns
        }
    }

    pub fn render(&self) -> String {
        let tp = if self.elements_per_iter > 1 {
            format!("  ({:>12.0} elem/s)", self.throughput_per_sec())
        } else {
            String::new()
        };
        format!(
            "{:<44} {:>12.1} ns/iter  p50 {:>12.1}  p99 {:>12.1}  p999 {:>12.1}{}",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.p999_ns, tp
        )
    }
}

/// Harness configuration: time-budgeted like criterion.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    results: Vec<BenchResult>,
    suite: String,
}

impl Bencher {
    pub fn new(suite: &str) -> Self {
        // Respect a quick mode for CI: ELASTICTL_BENCH_QUICK=1.
        let quick = std::env::var("ELASTICTL_BENCH_QUICK").ok().as_deref() == Some("1");
        Bencher {
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            min_iters: 10,
            results: Vec::new(),
            suite: suite.to_string(),
        }
    }

    /// Time `f`, which performs `elements` logical operations per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, elements: u64, mut f: F) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure individual iterations.
        let mut samples_ns: Vec<f64> = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure || (samples_ns.len() as u64) < self.min_iters {
            let t = Instant::now();
            f();
            samples_ns.push(t.elapsed().as_nanos() as f64);
            if samples_ns.len() > 5_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let pct = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        // Tail estimate via the interpolated log-histogram quantile: with a
        // time-budgeted sample count the nearest-rank p999 would collapse
        // onto the max; the histogram interpolates within its ~2% buckets.
        let mut hist = crate::metrics::LogHistogram::new(1.02, 60_000_000_000);
        for &ns in &samples_ns {
            hist.inc(ns as u64);
        }
        let result = BenchResult {
            name: format!("{}/{}", self.suite, name),
            iters: samples_ns.len() as u64,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p99_ns: pct(0.99),
            p999_ns: hist.quantile(0.999) as f64,
            elements_per_iter: elements,
        };
        println!("{}", result.render());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Append results to `target/bench_results.csv`, and — when
    /// `ELASTICTL_BENCH_JSON` names a file — write the suite's results
    /// there as a JSON summary (the CI bench-regression gate compares it
    /// against `rust/benches/baseline.json`).
    pub fn finish(self) {
        let path = std::path::Path::new("target").join("bench_results.csv");
        let mut text = String::new();
        let fresh = !path.exists();
        if fresh {
            text.push_str(
                "suite_bench,iters,mean_ns,p50_ns,p99_ns,p999_ns,elements_per_iter,\
                 throughput_per_sec\n",
            );
        }
        for r in &self.results {
            text.push_str(&format!(
                "{},{},{:.1},{:.1},{:.1},{:.1},{},{:.1}\n",
                r.name,
                r.iters,
                r.mean_ns,
                r.p50_ns,
                r.p99_ns,
                r.p999_ns,
                r.elements_per_iter,
                r.throughput_per_sec()
            ));
        }
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            let _ = f.write_all(text.as_bytes());
        }
        if let Ok(json_path) = std::env::var("ELASTICTL_BENCH_JSON") {
            if !json_path.is_empty() {
                let json = self.to_json();
                if let Some(parent) = std::path::Path::new(&json_path).parent() {
                    let _ = std::fs::create_dir_all(parent);
                }
                if let Err(e) = std::fs::write(&json_path, json) {
                    eprintln!("bench: could not write {json_path}: {e}");
                } else {
                    println!("--- JSON summary written to {json_path} ---");
                }
            }
        }
        println!("--- {} benches recorded ---", self.results.len());
    }

    /// The suite's results as a JSON document (hand-rolled — the offline
    /// build has no serde): `{"suite": ..., "results": [{...}, ...]}`.
    fn to_json(&self) -> String {
        let mut s = format!("{{\n  \"suite\": \"{}\",\n  \"results\": [\n", self.suite);
        for (i, r) in self.results.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
                 \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"p999_ns\": {:.1}, \
                 \"elements_per_iter\": {}, \"throughput_per_sec\": {:.1}}}{}\n",
                r.name,
                r.iters,
                r.mean_ns,
                r.p50_ns,
                r.p99_ns,
                r.p999_ns,
                r.elements_per_iter,
                r.throughput_per_sec(),
                if i + 1 < self.results.len() { "," } else { "" },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box
/// wrapper kept for call-site clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("ELASTICTL_BENCH_QUICK", "1");
        let mut b = Bencher::new("selftest");
        let mut acc = 0u64;
        let r = b.bench("mix64", 1, || {
            acc = acc.wrapping_add(black_box(crate::mix64(acc)));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 10);
        assert!(r.p99_ns >= r.p50_ns);
        // Histogram-interpolated tail: within one 2% bucket of the max.
        assert!(r.p999_ns >= r.p50_ns / 1.02, "p999 {} p50 {}", r.p999_ns, r.p50_ns);
    }

    #[test]
    fn json_summary_is_well_formed() {
        let mut b = Bencher::new("jsontest");
        b.results.push(BenchResult {
            name: "jsontest/alpha".into(),
            iters: 3,
            mean_ns: 1500.0,
            p50_ns: 1400.0,
            p99_ns: 2000.0,
            p999_ns: 2100.0,
            elements_per_iter: 100,
        });
        b.results.push(BenchResult {
            name: "jsontest/beta".into(),
            iters: 5,
            mean_ns: 10.0,
            p50_ns: 10.0,
            p99_ns: 11.0,
            p999_ns: 12.0,
            elements_per_iter: 1,
        });
        let json = b.to_json();
        assert!(json.contains("\"suite\": \"jsontest\""), "{json}");
        assert!(json.contains("\"name\": \"jsontest/alpha\""), "{json}");
        // Exactly one separating comma between the two result objects,
        // none after the last (valid JSON).
        assert_eq!(json.matches("},\n").count(), 1, "{json}");
        assert!(json.trim_end().ends_with('}'), "{json}");
    }

    #[test]
    fn throughput_derivation() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1000.0,
            p50_ns: 1000.0,
            p99_ns: 1000.0,
            p999_ns: 1000.0,
            elements_per_iter: 500,
        };
        assert!((r.throughput_per_sec() - 5e8).abs() < 1.0);
    }
}
