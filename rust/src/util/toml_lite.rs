//! The TOML subset the config system needs (offline stand-in for
//! `toml`/`serde`): `[section]` headers and `key = value` pairs where a
//! value is a string (`"..."`), bool, integer or float. Comments (`#`)
//! and blank lines are ignored. Produces a flat
//! `section.key -> value` map; writing is the mirror operation.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    fn render(&self) -> String {
        match self {
            Value::Str(s) => format!("{s:?}"),
            Value::Bool(b) => b.to_string(),
            Value::Int(i) => i.to_string(),
            Value::Float(f) => {
                if f.fract() == 0.0 && f.abs() < 1e15 {
                    format!("{f:.1}")
                } else {
                    format!("{f}")
                }
            }
        }
    }
}

/// Flat document: keys are `section.key` (or bare `key` before any
/// section header).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            entries.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.as_i64()).map(|i| i.max(0) as u64)
    }

    pub fn get_u32(&self, key: &str) -> Option<u32> {
        self.get_u64(key).map(|v| v.min(u32::MAX as u64) as u32)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }

    /// Render grouped by section, sections sorted, keys sorted.
    pub fn render(&self) -> String {
        let mut by_section: BTreeMap<&str, Vec<(&str, &Value)>> = BTreeMap::new();
        for (k, v) in &self.entries {
            match k.rsplit_once('.') {
                Some((sec, key)) => by_section.entry(sec).or_default().push((key, v)),
                None => by_section.entry("").or_default().push((k, v)),
            }
        }
        let mut out = String::new();
        for (sec, kvs) in by_section {
            if !sec.is_empty() {
                out.push_str(&format!("[{sec}]\n"));
            }
            for (k, v) in kvs {
                out.push_str(&format!("{k} = {}\n", v.render()));
            }
            out.push('\n');
        }
        out
    }
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(q) = s.strip_prefix('"') {
        let Some(inner) = q.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string");
        };
        return Ok(Value::Str(inner.replace("\\\"", "\"")));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_scalars() {
        let doc = Doc::parse(
            "# comment\n\
             top = 1\n\
             [cost]\n\
             miss_cost_dollars = 1.4676e-7\n\
             epoch_us = 3600000000\n\
             per_byte = false\n\
             [scaler]\n\
             policy = \"ttl\"\n",
        )
        .unwrap();
        assert_eq!(doc.get("top"), Some(&Value::Int(1)));
        assert!((doc.get_f64("cost.miss_cost_dollars").unwrap() - 1.4676e-7).abs() < 1e-15);
        assert_eq!(doc.get_u64("cost.epoch_us"), Some(3_600_000_000));
        assert_eq!(doc.get_bool("cost.per_byte"), Some(false));
        assert_eq!(doc.get_str("scaler.policy"), Some("ttl"));
    }

    #[test]
    fn round_trip() {
        let mut doc = Doc::default();
        doc.set("a.x", Value::Int(5));
        doc.set("a.y", Value::Float(2.5));
        doc.set("b.name", Value::Str("hello".into()));
        doc.set("b.flag", Value::Bool(true));
        let text = doc.render();
        let back = Doc::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(Doc::parse("[x]\nkey value\n").is_err());
        assert!(Doc::parse("k = \"unterminated\n").is_err());
        assert!(Doc::parse("k = what\n").is_err());
    }

    #[test]
    fn float_render_parses_back_as_float() {
        let mut doc = Doc::default();
        doc.set("s.v", Value::Float(3600.0));
        let text = doc.render();
        let back = Doc::parse(&text).unwrap();
        assert_eq!(back.get_f64("s.v"), Some(3600.0));
    }
}
