//! Deterministic pseudo-random number generation: PCG-XSH-RR 64/32
//! underneath, with the convenience surface the rest of the crate needs
//! (uniform floats, ranges, shuffles, exponentials).
//!
//! All generators in this crate are seeded explicitly so every trace,
//! cluster assignment and experiment is reproducible run-to-run.

/// PCG-XSH-RR 64/32 (O'Neill 2014): 64-bit state, 32-bit output, combined
/// into 64-bit outputs by pairing draws. Small, fast, passes BigCrush for
/// our purposes; period 2^64 per stream.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Seed with SplitMix64 expansion so close seeds diverge immediately.
    pub fn seed_from_u64(seed: u64) -> Self {
        let s0 = crate::mix64(seed);
        let s1 = crate::mix64(seed.wrapping_add(0x9E3779B97F4A7C15));
        let mut rng = Pcg { state: 0, inc: (s1 << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(s0);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// for unbiased results.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Exponentially distributed with rate `rate` (mean 1/rate).
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below_usize(i + 1);
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg::seed_from_u64(7);
        let mut b = Pcg::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seed_from_u64(8);
        assert_ne!(Pcg::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_uniform() {
        let mut r = Pcg::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg::seed_from_u64(2);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "bucket {i}: {c}");
        }
    }

    #[test]
    fn exp_has_right_mean_and_cv() {
        let mut r = Pcg::seed_from_u64(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.exp(2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((var.sqrt() / mean - 1.0).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seed_from_u64(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg::seed_from_u64(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, sorted, "shuffle left the slice sorted");
    }

    #[test]
    #[should_panic]
    fn below_zero_panics() {
        Pcg::seed_from_u64(0).below(0);
    }
}
