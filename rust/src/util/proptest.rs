//! Tiny randomized property-test driver (offline stand-in for
//! `proptest`): run a property against many seeded random inputs; on
//! failure report the seed and iteration so the case can be replayed
//! deterministically.

use super::rng::Pcg;

/// Number of cases per property (override with ELASTICTL_PROPTEST_CASES).
pub fn default_cases() -> u32 {
    std::env::var("ELASTICTL_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` randomized inputs. The property receives a
/// seeded RNG it draws its inputs from; panics are annotated with the
/// failing `(seed, case)` for replay.
pub fn check<F: Fn(&mut Pcg)>(name: &str, base_seed: u64, prop: F) {
    let cases = default_cases();
    for case in 0..cases {
        let seed = crate::mix64(base_seed ^ (case as u64).rotate_left(32));
        let mut rng = Pcg::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            eprintln!(
                "property {name} failed at case {case}/{cases} (replay seed {seed:#x})"
            );
            std::panic::resume_unwind(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::cell::Cell::new(0u32);
        check("trivial", 1, |rng| {
            counter.set(counter.get() + 1);
            let x = rng.below(100);
            assert!(x < 100);
        });
        assert_eq!(counter.get(), default_cases());
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check("fails", 2, |rng| {
            assert!(rng.below(10) < 5, "will fail for some draw");
        });
    }
}
