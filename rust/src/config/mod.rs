//! Configuration system: instance catalog, cost constants, controller and
//! scaler parameters, workload description.
//!
//! Everything is plain-old-data, (de)serializable from a TOML subset
//! ([`crate::util::toml_lite`]), with defaults matching §6.1 of the paper
//! (Amazon ElastiCache `cache.t2.micro`, Oct. 2017 US pricing, one-hour
//! billing epochs, per-miss cost derived from the production 4 GB cache
//! balance-point rule of thumb).

mod instance;

pub use instance::{InstanceCatalog, InstanceType};

use crate::placement::PlacementKind;
use crate::tenant::{TenantSpec, TrafficClass};
use crate::util::toml_lite::{Doc, Value};
use crate::{Result, TenantId, HOUR};
use std::path::Path;

/// Bytes per `reserved_mb` config unit (mebibytes).
const MB: f64 = 1024.0 * 1024.0;

/// Gain (step-size) schedule `ε(n)` for the stochastic-approximation TTL
/// update of §4.1 / eq. (7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GainSchedule {
    /// Constant gain `ε(n) = eps0`. Does not converge w.p.1 but tracks
    /// non-stationary popularities — the mode the paper uses on real traces.
    Constant { eps0: f64 },
    /// Polynomial decay `ε(n) = eps0 / (1 + n)^exponent` with
    /// `0.5 < exponent ≤ 1`, satisfying the Robbins–Monro conditions of
    /// Proposition 1 (Σε = ∞, Σε² < ∞).
    Polynomial { eps0: f64, exponent: f64 },
}

impl GainSchedule {
    /// Gain for the `n`-th update (0-based).
    #[inline]
    pub fn gain(&self, n: u64) -> f64 {
        match *self {
            GainSchedule::Constant { eps0 } => eps0,
            GainSchedule::Polynomial { eps0, exponent } => {
                eps0 / (1.0 + n as f64).powf(exponent)
            }
        }
    }

    /// True if the schedule satisfies the Robbins–Monro conditions.
    pub fn converges_wp1(&self) -> bool {
        match *self {
            GainSchedule::Constant { .. } => false,
            GainSchedule::Polynomial { exponent, .. } => {
                exponent > 0.5 && exponent <= 1.0
            }
        }
    }
}

impl Default for GainSchedule {
    fn default() -> Self {
        // The raw gradient sample (λ̂·m − c_i) is measured in $/s and is
        // tiny in absolute terms (≈1e-9 for this catalog), so a large eps0
        // is required to move T by seconds. See ControllerConfig::normalized
        // for the scale-free alternative.
        GainSchedule::Constant { eps0: 5.0e9 }
    }
}

/// Parameters of the TTL stochastic-approximation controller (§4.1, §5.1).
#[derive(Debug, Clone)]
pub struct ControllerConfig {
    /// Initial timer value, seconds.
    pub t_init_secs: f64,
    /// Projection lower bound, seconds. Proposition 1 permits any closed
    /// interval; a small positive floor keeps `T = 0` from becoming an
    /// absorbing state of the *practical* estimator (at T = 0 no window
    /// can ever record a hit, so every correction is negative and the
    /// iterate can never escape — a pathology of the delayed-measurement
    /// implementation, not of the theory).
    pub t_min_secs: f64,
    /// Projection upper bound `T_max`, seconds (Proposition 1 projects the
    /// iterate onto `[T_min, T_max]`).
    pub t_max_secs: f64,
    /// Gain schedule ε(n).
    pub gain: GainSchedule,
    /// If true, normalise the correction term by an EWMA of its absolute
    /// value, making the update scale-free: `T += ε̃ · corr / ewma(|corr|)`
    /// with `ε̃` in seconds. This keeps the controller robust across cost
    /// catalogs without retuning eps0; disable to run the paper's plain
    /// eq. (7).
    pub normalized: bool,
    /// Step size in seconds used when `normalized` is on.
    pub normalized_step_secs: f64,
    /// EWMA smoothing factor for the correction magnitude (normalised mode).
    pub normalized_ewma_alpha: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            t_init_secs: 60.0,
            t_min_secs: 1.0,
            t_max_secs: 6.0 * 3600.0,
            gain: GainSchedule::default(),
            normalized: true,
            normalized_step_secs: 0.5,
            normalized_ewma_alpha: 0.002,
        }
    }
}

/// Cost model constants (§2.3, §6.1).
#[derive(Debug, Clone)]
pub struct CostConfig {
    /// Instance type used for every node of the homogeneous cluster.
    pub instance: InstanceType,
    /// Billing epoch in microseconds (paper: 1 h minimum billing period).
    pub epoch_us: u64,
    /// Cost charged per miss, dollars. §6.1: 1.4676e-7 $/miss, derived from
    /// the balance-point rule on the production cache.
    pub miss_cost_dollars: f64,
    /// If true, the miss cost is proportional to object size:
    /// `m_o = miss_cost_dollars · s_o / mean_object_bytes` — the
    /// heterogeneous-cost generality of §4. Default: constant per miss.
    pub miss_cost_per_byte: bool,
    /// Mean object size (bytes) used to normalise per-byte miss costs.
    pub mean_object_bytes: f64,
}

impl CostConfig {
    /// Storage cost per byte·second, from the instance hourly price.
    #[inline]
    pub fn storage_cost_per_byte_sec(&self) -> f64 {
        self.instance.dollars_per_hour / (self.instance.ram_bytes as f64 * 3600.0)
    }

    /// Storage cost rate `c_i = s_i · c` ($/s) for an object of `size` bytes.
    #[inline]
    pub fn storage_rate(&self, size: u64) -> f64 {
        size as f64 * self.storage_cost_per_byte_sec()
    }

    /// Miss cost `m_o` for an object of `size` bytes.
    #[inline]
    pub fn miss_cost(&self, size: u64) -> f64 {
        if self.miss_cost_per_byte {
            self.miss_cost_dollars * size as f64 / self.mean_object_bytes
        } else {
            self.miss_cost_dollars
        }
    }
}

impl Default for CostConfig {
    fn default() -> Self {
        CostConfig {
            instance: InstanceType::cache_t2_micro(),
            epoch_us: HOUR,
            miss_cost_dollars: 1.4676e-7,
            miss_cost_per_byte: false,
            mean_object_bytes: 64.0 * 1024.0,
        }
    }
}

/// Which epoch-end sizing policy drives the cluster (§6.1 "previous
/// solutions" + our model-driven ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Static cluster of `fixed_instances` nodes (the paper's baseline).
    Fixed,
    /// Algorithm 2: virtual-TTL-cache-driven sizing (the paper's system).
    Ttl,
    /// Exact-MRC-driven sizing (Olken order-statistics tree, O(log M)/req).
    Mrc,
    /// Ideal vertically scalable TTL cache billed on instantaneous size.
    IdealTtl,
    /// PJRT analytic planner: bucketed IRM model argmin over the AOT cost
    /// curve (our L1/L2 integration; an ablation, not in the paper).
    Analytic,
    /// Multi-tenant Algorithm 2: one TTL controller per tenant, one shared
    /// elastic cluster sized by the cost-aware arbiter
    /// ([`crate::tenant::TenantTtlSizer`]).
    TenantTtl,
}

impl PolicyKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PolicyKind::Fixed => "fixed",
            PolicyKind::Ttl => "ttl",
            PolicyKind::Mrc => "mrc",
            PolicyKind::IdealTtl => "ideal_ttl",
            PolicyKind::Analytic => "analytic",
            PolicyKind::TenantTtl => "tenant_ttl",
        }
    }

    pub fn parse(s: &str) -> Result<PolicyKind> {
        Ok(match s {
            "fixed" => PolicyKind::Fixed,
            "ttl" => PolicyKind::Ttl,
            "mrc" => PolicyKind::Mrc,
            "ideal_ttl" | "ideal-ttl" => PolicyKind::IdealTtl,
            "analytic" => PolicyKind::Analytic,
            "tenant_ttl" | "tenant-ttl" | "tenants" => PolicyKind::TenantTtl,
            other => anyhow::bail!(
                "unknown policy {other} (fixed|ttl|mrc|ideal_ttl|analytic|tenant_ttl)"
            ),
        })
    }
}

/// Scaler parameters.
#[derive(Debug, Clone)]
pub struct ScalerConfig {
    pub policy: PolicyKind,
    /// Number of instances for [`PolicyKind::Fixed`].
    pub fixed_instances: u32,
    /// Hard cap on cluster size for all elastic policies.
    pub max_instances: u32,
    /// Minimum cluster size (the balancer keeps at least one node so the
    /// service stays up even when the optimal size is zero).
    pub min_instances: u32,
    /// Exponential decay applied to the MRC reuse histogram at each epoch
    /// boundary so that sizing tracks the diurnal pattern.
    pub mrc_decay: f64,
    /// Make the multi-tenant arbiter's grants *binding* (the enforcement
    /// loop of [`crate::tenant`]): each epoch, `granted_bytes` becomes a
    /// per-tenant occupancy cap (an admission byte budget on the
    /// balancer's request path) plus a TTL clamp on that tenant's
    /// controller. Off by default: the legacy mode keeps grants as
    /// reporting/diagnostics only, bit-for-bit compatible with the
    /// pre-enforcement request path.
    pub enforce_grants: bool,
}

impl Default for ScalerConfig {
    fn default() -> Self {
        ScalerConfig {
            policy: PolicyKind::Ttl,
            fixed_instances: 8,
            max_instances: 64,
            min_instances: 1,
            mrc_decay: 0.5,
            enforce_grants: false,
        }
    }
}

/// Physical cache eviction policy for the instances (§2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionKind {
    /// Strict LRU (Memcached within a size class; our default).
    Lru,
    /// Redis-style sampled LRU: evict the least recently used of 5 random
    /// entries, repeating until enough space is free.
    SampledLru,
    /// Memcached-style slab allocation: size classes with per-class LRU.
    Slab,
}

impl EvictionKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EvictionKind::Lru => "lru",
            EvictionKind::SampledLru => "sampled_lru",
            EvictionKind::Slab => "slab",
        }
    }

    pub fn parse(s: &str) -> Result<EvictionKind> {
        Ok(match s {
            "lru" => EvictionKind::Lru,
            "sampled_lru" => EvictionKind::SampledLru,
            "slab" => EvictionKind::Slab,
            other => anyhow::bail!("unknown eviction kind {other}"),
        })
    }
}

/// Cluster parameters.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub eviction: EvictionKind,
    /// Redis cluster hash slots (16384 in the spec and in the paper).
    pub hash_slots: u32,
    /// Random seed for slot (re)assignment.
    pub seed: u64,
    /// Physical placement policy (`[placement] policy = "..."` in TOML):
    /// `shared` (default, bit-identical scoped-key routing),
    /// `hash_slot_pinned` (per-tenant instance subsets sized from the
    /// epoch grants) or `slab_partition` (Memshare-style per-tenant byte
    /// floors inside each instance). See [`crate::placement`].
    pub placement: PlacementKind,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            eviction: EvictionKind::Lru,
            hash_slots: 16384,
            seed: 0xC0FFEE,
            placement: PlacementKind::Shared,
        }
    }
}

/// Decision-trace telemetry parameters (`[telemetry]` in TOML). Off by
/// default: the untelemetered request path stays bit-identical (see
/// `engine_parity`), and turning it on costs < 3% throughput (enforced
/// by the `offer_with_telemetry` bench row).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch for the registry + decision journal.
    pub enabled: bool,
    /// Maximum number of [`crate::telemetry::EpochDecisionRecord`]s the
    /// in-memory journal retains (oldest evicted first).
    pub journal_capacity: u32,
    /// If set, `engine::run` writes the retained journal as JSONL here.
    pub journal_path: Option<String>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: false,
            journal_capacity: 1024,
            journal_path: None,
        }
    }
}

/// Server-runtime parameters (`[serve]` in TOML) for the `srv` subsystem.
/// Everything defaults off: a default-config server keeps the manual-epoch,
/// no-expiry, no-checkpoint behavior pinned by `serve_json`/`engine_parity`.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Wall-clock epoch ticker period in seconds. `0` (the default) keeps
    /// manual epochs: nothing bills or resizes until an operator `EPOCH`.
    pub epoch_secs: u64,
    /// Real TTL for resident entries in seconds, expired lazily on access
    /// ([`crate::cache::TtlPolicy`]). `0.0` (the default) disables expiry.
    pub ttl_expiry_secs: f64,
    /// If set, the server journals every closed epoch's billing delta to
    /// this append-only checkpoint file (see `srv::checkpoint`).
    pub checkpoint_path: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { epoch_secs: 0, ttl_expiry_secs: 0.0, checkpoint_path: None }
    }
}

/// Which admission filter gates inserts on the balancer's request path
/// (`[admission] filter = "..."` in TOML). See [`crate::admission`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionKind {
    /// No filter: every policy-admitted miss inserts (the seed path,
    /// bit-identical — the default).
    None,
    /// Cache on Mth request: a fixed-size counting sketch admits a key's
    /// insert on its Mth observed request (Carlsson & Eager).
    MthRequest,
    /// Cost-based keep/drop: admit iff expected miss dollars ≥ expected
    /// storage dollars at the tenant's current TTL (Le Scouarnec et al.).
    KeepCost,
}

impl AdmissionKind {
    /// Stable lowercase name (config files, reports).
    pub fn as_str(self) -> &'static str {
        match self {
            AdmissionKind::None => "none",
            AdmissionKind::MthRequest => "mth_request",
            AdmissionKind::KeepCost => "keep_cost",
        }
    }

    /// Parse the [`Self::as_str`] form back.
    pub fn parse(s: &str) -> Result<AdmissionKind> {
        Ok(match s {
            "none" => AdmissionKind::None,
            "mth_request" | "mth-request" => AdmissionKind::MthRequest,
            "keep_cost" | "keep-cost" => AdmissionKind::KeepCost,
            other => anyhow::bail!(
                "unknown admission filter {other} (none|mth_request|keep_cost)"
            ),
        })
    }
}

/// One tenant's admission overrides (`[tenantN] admission_m = ...` /
/// `keep_threshold = ...`), keyed by tenant id.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionOverride {
    /// The tenant these overrides apply to.
    pub tenant: u16,
    /// Per-tenant M for the Mth-request filter (1..=15).
    pub m: Option<u32>,
    /// Per-tenant threshold for the keep/drop filter (> 0).
    pub keep_threshold: Option<f64>,
}

/// Admission-filter parameters (`[admission]` in TOML). The default
/// (`filter = "none"`) keeps the request path bit-identical to the
/// pre-admission seed loops (pinned by `engine_parity`).
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    /// Which filter gates inserts.
    pub filter: AdmissionKind,
    /// Mth-request filter: admit a key on its Mth observed request.
    /// Bounded by the sketch's 4-bit counter ceiling (1..=15).
    pub m: u32,
    /// Mth-request sketch size in bytes (two 4-bit counters per byte).
    /// A power of two, so the cell index shares its low bits with the
    /// shard router's `hash % shards` — colliding keys co-shard and
    /// per-shard sketches stay bit-identical to the monolithic one.
    pub sketch_bytes: u64,
    /// Keep/drop filter: admit iff
    /// `multiplier × m_o ≥ keep_threshold × s_o × c × T_i`.
    pub keep_threshold: f64,
    /// Per-tenant overrides parsed from the `[tenantN]` sections.
    pub overrides: Vec<AdmissionOverride>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            filter: AdmissionKind::None,
            m: 2,
            sketch_bytes: 32768,
            keep_threshold: 1.0,
            overrides: Vec::new(),
        }
    }
}

/// Execution-shape parameters (`[engine]` in TOML). `shards = 1` (the
/// default) runs the classic single-threaded engine, bit-identical to
/// every seed loop pinned by `engine_parity`; `shards = N` partitions the
/// request path across N worker threads keyed by `hash(tenant, key) % N`,
/// synchronized only at the epoch barrier (see `engine::ShardedEngine`).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Number of shard workers the request path is partitioned across.
    pub shards: u32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { shards: 1 }
    }
}

/// Top-level experiment / run configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub cost: CostConfig,
    pub controller: ControllerConfig,
    pub scaler: ScalerConfig,
    pub cluster: ClusterConfig,
    /// Decision-trace telemetry (`[telemetry]`); disabled by default.
    pub telemetry: TelemetryConfig,
    /// Server-runtime knobs (`[serve]`); everything off by default.
    pub serve: ServeConfig,
    /// Execution shape (`[engine]`); one shard by default.
    pub engine: EngineConfig,
    /// Admission filter (`[admission]`); none by default.
    pub admission: AdmissionConfig,
    /// Tenant roster for the multi-tenant policy. Empty = single-tenant
    /// mode (every request is tenant 0 with multiplier 1.0). In TOML this
    /// is a `[tenant0]` / `[tenant1]` / … section per tenant, each with
    /// optional `id`, `name`, `miss_cost_multiplier` and `class` keys.
    pub tenants: Vec<TenantSpec>,
}

impl Config {
    /// Load a TOML-subset config file; unspecified keys keep defaults.
    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = Doc::parse(text)?;
        let mut cfg = Config::default();

        // [cost]
        if let Some(v) = doc.get_str("cost.instance") {
            let cat = InstanceCatalog::default();
            cfg.cost.instance = cat
                .by_name(v)
                .ok_or_else(|| anyhow::anyhow!("unknown instance type {v}"))?
                .clone();
        }
        if let Some(v) = doc.get_u64("cost.instance_ram_bytes") {
            cfg.cost.instance.ram_bytes = v;
        }
        if let Some(v) = doc.get_f64("cost.instance_dollars_per_hour") {
            cfg.cost.instance.dollars_per_hour = v;
        }
        if let Some(v) = doc.get_u64("cost.epoch_us") {
            cfg.cost.epoch_us = v;
        }
        if let Some(v) = doc.get_f64("cost.miss_cost_dollars") {
            cfg.cost.miss_cost_dollars = v;
        }
        if let Some(v) = doc.get_bool("cost.miss_cost_per_byte") {
            cfg.cost.miss_cost_per_byte = v;
        }
        if let Some(v) = doc.get_f64("cost.mean_object_bytes") {
            cfg.cost.mean_object_bytes = v;
        }

        // [controller]
        if let Some(v) = doc.get_f64("controller.t_init_secs") {
            cfg.controller.t_init_secs = v;
        }
        if let Some(v) = doc.get_f64("controller.t_min_secs") {
            cfg.controller.t_min_secs = v;
        }
        if let Some(v) = doc.get_f64("controller.t_max_secs") {
            cfg.controller.t_max_secs = v;
        }
        if let Some(v) = doc.get_bool("controller.normalized") {
            cfg.controller.normalized = v;
        }
        if let Some(v) = doc.get_f64("controller.normalized_step_secs") {
            cfg.controller.normalized_step_secs = v;
        }
        if let Some(v) = doc.get_f64("controller.normalized_ewma_alpha") {
            cfg.controller.normalized_ewma_alpha = v;
        }
        match (
            doc.get_str("controller.gain_kind"),
            doc.get_f64("controller.gain_eps0"),
            doc.get_f64("controller.gain_exponent"),
        ) {
            (Some("constant"), Some(eps0), _) => {
                cfg.controller.gain = GainSchedule::Constant { eps0 };
            }
            (Some("polynomial"), Some(eps0), Some(exponent)) => {
                cfg.controller.gain = GainSchedule::Polynomial { eps0, exponent };
            }
            (Some(other), _, _) => anyhow::bail!("unknown gain_kind {other}"),
            _ => {}
        }

        // [scaler]
        if let Some(v) = doc.get_str("scaler.policy") {
            cfg.scaler.policy = PolicyKind::parse(v)?;
        }
        if let Some(v) = doc.get_u32("scaler.fixed_instances") {
            cfg.scaler.fixed_instances = v;
        }
        if let Some(v) = doc.get_u32("scaler.max_instances") {
            cfg.scaler.max_instances = v;
        }
        if let Some(v) = doc.get_u32("scaler.min_instances") {
            cfg.scaler.min_instances = v;
        }
        if let Some(v) = doc.get_f64("scaler.mrc_decay") {
            cfg.scaler.mrc_decay = v;
        }
        if let Some(v) = doc.get_bool("scaler.enforce_grants") {
            cfg.scaler.enforce_grants = v;
        }

        // [cluster]
        if let Some(v) = doc.get_str("cluster.eviction") {
            cfg.cluster.eviction = EvictionKind::parse(v)?;
        }
        if let Some(v) = doc.get_u32("cluster.hash_slots") {
            cfg.cluster.hash_slots = v;
        }
        if let Some(v) = doc.get_u64("cluster.seed") {
            cfg.cluster.seed = v;
        }

        // [placement]
        if let Some(v) = doc.get_str("placement.policy") {
            cfg.cluster.placement = PlacementKind::parse(v)?;
        }

        // [telemetry]
        if let Some(v) = doc.get_bool("telemetry.enabled") {
            cfg.telemetry.enabled = v;
        }
        if let Some(v) = doc.get_u32("telemetry.journal_capacity") {
            anyhow::ensure!(v > 0, "telemetry.journal_capacity must be positive");
            cfg.telemetry.journal_capacity = v;
        }
        if let Some(v) = doc.get_str("telemetry.journal_path") {
            cfg.telemetry.journal_path = Some(v.to_string());
        }

        // [serve]
        if let Some(v) = doc.get_u64("serve.epoch_secs") {
            cfg.serve.epoch_secs = v;
        }
        if let Some(v) = doc.get_f64("serve.ttl_expiry_secs") {
            anyhow::ensure!(
                v >= 0.0 && v.is_finite(),
                "serve.ttl_expiry_secs must be a finite non-negative number"
            );
            cfg.serve.ttl_expiry_secs = v;
        }
        if let Some(v) = doc.get_str("serve.checkpoint_path") {
            cfg.serve.checkpoint_path = Some(v.to_string());
        }

        // [engine]
        if let Some(v) = doc.get_u32("engine.shards") {
            anyhow::ensure!(
                (1..=256).contains(&v),
                "engine.shards must lie in 1..=256 (got {v})"
            );
            cfg.engine.shards = v;
        }

        // [admission]
        if let Some(v) = doc.get_str("admission.filter") {
            cfg.admission.filter = AdmissionKind::parse(v)?;
        }
        if let Some(v) = doc.get_u32("admission.m") {
            anyhow::ensure!(
                (1..=15).contains(&v),
                "admission.m must lie in 1..=15 (the sketch's 4-bit counters saturate at 15; got {v})"
            );
            cfg.admission.m = v;
        }
        if let Some(v) = doc.get_u64("admission.sketch_bytes") {
            anyhow::ensure!(
                v.is_power_of_two() && (1024..=(1 << 24)).contains(&v),
                "admission.sketch_bytes must be a power of two in 1024..=16777216 (got {v})"
            );
            cfg.admission.sketch_bytes = v;
        }
        if let Some(v) = doc.get_f64("admission.keep_threshold") {
            anyhow::ensure!(
                v > 0.0 && v.is_finite(),
                "admission.keep_threshold must be a finite positive number"
            );
            cfg.admission.keep_threshold = v;
        }

        // [tenant0], [tenant1], … — one section per tenant. Sections are
        // discovered by scanning the parsed keys, so a gap in the
        // numbering (say, a deleted [tenant1] between [tenant0] and
        // [tenant2]) cannot silently drop the later tenants.
        let mut indices: Vec<u64> = doc
            .entries
            .keys()
            .filter_map(|k| {
                let rest = k.strip_prefix("tenant")?;
                let (idx, _) = rest.split_once('.')?;
                idx.parse::<u64>().ok()
            })
            .collect();
        indices.sort_unstable();
        indices.dedup();
        let mut tenants = Vec::new();
        for i in indices {
            let id = doc.get_u64(&format!("tenant{i}.id")).unwrap_or(i);
            anyhow::ensure!(
                id <= u16::MAX as u64,
                "tenant{i}: id {id} out of range (tenant ids are u16)"
            );
            let name = match doc.get_str(&format!("tenant{i}.name")) {
                Some(s) => s.to_string(),
                None => format!("tenant{i}"),
            };
            let multiplier = doc
                .get_f64(&format!("tenant{i}.miss_cost_multiplier"))
                .unwrap_or(1.0);
            let class = match doc.get_str(&format!("tenant{i}.class")) {
                Some(s) => TrafficClass::parse(s)?,
                None => TrafficClass::Standard,
            };
            let mut spec = TenantSpec::new(id as TenantId, name)
                .with_multiplier(multiplier)
                .with_class(class);
            if let Some(mb) = doc.get_f64(&format!("tenant{i}.reserved_mb")) {
                anyhow::ensure!(
                    mb >= 0.0 && mb.is_finite(),
                    "tenant{i}: reserved_mb must be a finite non-negative number"
                );
                spec = spec.with_reserved_bytes((mb * MB) as u64);
            }
            if let Some(r) = doc.get_f64(&format!("tenant{i}.slo_miss_ratio")) {
                anyhow::ensure!(
                    (0.0..=1.0).contains(&r),
                    "tenant{i}: slo_miss_ratio must lie in [0, 1]"
                );
                spec = spec.with_slo_miss_ratio(r);
            }
            // Per-tenant admission overrides ride in the tenant section
            // but land in cfg.admission (keyed by tenant *id*, so the
            // filter's dense lookup works whatever the section number).
            let m = match doc.get_u32(&format!("tenant{i}.admission_m")) {
                Some(m) => {
                    anyhow::ensure!(
                        (1..=15).contains(&m),
                        "tenant{i}: admission_m must lie in 1..=15 (got {m})"
                    );
                    Some(m)
                }
                None => None,
            };
            let keep_threshold = match doc.get_f64(&format!("tenant{i}.keep_threshold")) {
                Some(th) => {
                    anyhow::ensure!(
                        th > 0.0 && th.is_finite(),
                        "tenant{i}: keep_threshold must be a finite positive number"
                    );
                    Some(th)
                }
                None => None,
            };
            if m.is_some() || keep_threshold.is_some() {
                cfg.admission.overrides.push(AdmissionOverride {
                    tenant: id as u16,
                    m,
                    keep_threshold,
                });
            }
            tenants.push(spec);
        }
        cfg.tenants = tenants;
        Ok(cfg)
    }

    /// Serialize to TOML-subset text (round-trips through
    /// [`Self::from_toml`]).
    pub fn to_toml(&self) -> String {
        let mut doc = Doc::default();
        doc.set("cost.instance", Value::Str(self.cost.instance.name.clone()));
        doc.set(
            "cost.instance_ram_bytes",
            Value::Int(self.cost.instance.ram_bytes as i64),
        );
        doc.set(
            "cost.instance_dollars_per_hour",
            Value::Float(self.cost.instance.dollars_per_hour),
        );
        doc.set("cost.epoch_us", Value::Int(self.cost.epoch_us as i64));
        doc.set(
            "cost.miss_cost_dollars",
            Value::Float(self.cost.miss_cost_dollars),
        );
        doc.set(
            "cost.miss_cost_per_byte",
            Value::Bool(self.cost.miss_cost_per_byte),
        );
        doc.set(
            "cost.mean_object_bytes",
            Value::Float(self.cost.mean_object_bytes),
        );

        doc.set("controller.t_init_secs", Value::Float(self.controller.t_init_secs));
        doc.set("controller.t_min_secs", Value::Float(self.controller.t_min_secs));
        doc.set("controller.t_max_secs", Value::Float(self.controller.t_max_secs));
        doc.set("controller.normalized", Value::Bool(self.controller.normalized));
        doc.set(
            "controller.normalized_step_secs",
            Value::Float(self.controller.normalized_step_secs),
        );
        doc.set(
            "controller.normalized_ewma_alpha",
            Value::Float(self.controller.normalized_ewma_alpha),
        );
        match self.controller.gain {
            GainSchedule::Constant { eps0 } => {
                doc.set("controller.gain_kind", Value::Str("constant".into()));
                doc.set("controller.gain_eps0", Value::Float(eps0));
            }
            GainSchedule::Polynomial { eps0, exponent } => {
                doc.set("controller.gain_kind", Value::Str("polynomial".into()));
                doc.set("controller.gain_eps0", Value::Float(eps0));
                doc.set("controller.gain_exponent", Value::Float(exponent));
            }
        }

        doc.set("scaler.policy", Value::Str(self.scaler.policy.as_str().into()));
        doc.set(
            "scaler.fixed_instances",
            Value::Int(self.scaler.fixed_instances as i64),
        );
        doc.set("scaler.max_instances", Value::Int(self.scaler.max_instances as i64));
        doc.set("scaler.min_instances", Value::Int(self.scaler.min_instances as i64));
        doc.set("scaler.mrc_decay", Value::Float(self.scaler.mrc_decay));
        doc.set(
            "scaler.enforce_grants",
            Value::Bool(self.scaler.enforce_grants),
        );

        doc.set(
            "cluster.eviction",
            Value::Str(self.cluster.eviction.as_str().into()),
        );
        doc.set("cluster.hash_slots", Value::Int(self.cluster.hash_slots as i64));
        doc.set("cluster.seed", Value::Int(self.cluster.seed as i64));

        doc.set(
            "placement.policy",
            Value::Str(self.cluster.placement.as_str().into()),
        );

        doc.set("telemetry.enabled", Value::Bool(self.telemetry.enabled));
        doc.set(
            "telemetry.journal_capacity",
            Value::Int(self.telemetry.journal_capacity as i64),
        );
        if let Some(p) = &self.telemetry.journal_path {
            doc.set("telemetry.journal_path", Value::Str(p.clone()));
        }

        doc.set("serve.epoch_secs", Value::Int(self.serve.epoch_secs as i64));
        doc.set(
            "serve.ttl_expiry_secs",
            Value::Float(self.serve.ttl_expiry_secs),
        );
        if let Some(p) = &self.serve.checkpoint_path {
            doc.set("serve.checkpoint_path", Value::Str(p.clone()));
        }

        doc.set("engine.shards", Value::Int(self.engine.shards as i64));

        doc.set(
            "admission.filter",
            Value::Str(self.admission.filter.as_str().into()),
        );
        doc.set("admission.m", Value::Int(self.admission.m as i64));
        doc.set(
            "admission.sketch_bytes",
            Value::Int(self.admission.sketch_bytes as i64),
        );
        doc.set(
            "admission.keep_threshold",
            Value::Float(self.admission.keep_threshold),
        );

        for (i, t) in self.tenants.iter().enumerate() {
            doc.set(&format!("tenant{i}.id"), Value::Int(t.id as i64));
            doc.set(&format!("tenant{i}.name"), Value::Str(t.name.clone()));
            doc.set(
                &format!("tenant{i}.miss_cost_multiplier"),
                Value::Float(t.miss_cost_multiplier),
            );
            doc.set(
                &format!("tenant{i}.class"),
                Value::Str(t.class.as_str().into()),
            );
            if t.reserved_bytes > 0 {
                doc.set(
                    &format!("tenant{i}.reserved_mb"),
                    Value::Float(t.reserved_bytes as f64 / MB),
                );
            }
            if let Some(r) = t.slo_miss_ratio {
                doc.set(&format!("tenant{i}.slo_miss_ratio"), Value::Float(r));
            }
            if let Some(o) = self.admission.overrides.iter().find(|o| o.tenant == t.id) {
                if let Some(m) = o.m {
                    doc.set(&format!("tenant{i}.admission_m"), Value::Int(m as i64));
                }
                if let Some(th) = o.keep_threshold {
                    doc.set(&format!("tenant{i}.keep_threshold"), Value::Float(th));
                }
            }
        }
        doc.render()
    }

    /// Convenience: a config running the given policy, other fields default.
    pub fn with_policy(policy: PolicyKind) -> Self {
        let mut c = Config::default();
        c.scaler.policy = policy;
        c
    }

    /// Cluster size before the first epoch decision — the single source of
    /// truth shared by the engine builder, the simulator and the server
    /// (previously duplicated in `sim::run` and `serve::ServerState::new`,
    /// where Fixed-vs-elastic semantics could drift apart): Fixed runs at
    /// its static size, elastic policies start at the floor.
    pub fn initial_instances(&self) -> u32 {
        match self.scaler.policy {
            PolicyKind::Fixed => self.scaler.fixed_instances.max(1),
            _ => self.scaler.min_instances.max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::tempdir;

    #[test]
    fn defaults_match_paper_constants() {
        let c = CostConfig::default();
        assert_eq!(c.epoch_us, HOUR);
        assert!((c.miss_cost_dollars - 1.4676e-7).abs() < 1e-12);
        assert_eq!(c.instance.ram_bytes, 555_000_000);
        assert!((c.instance.dollars_per_hour - 0.017).abs() < 1e-9);
        // c = 0.017 / (0.555e9 * 3600) ≈ 8.51e-15 $/byte/s
        let per_bs = c.storage_cost_per_byte_sec();
        assert!((per_bs - 8.508508508508508e-15).abs() / per_bs < 1e-9);
    }

    #[test]
    fn miss_cost_modes() {
        let mut c = CostConfig::default();
        assert_eq!(c.miss_cost(1), c.miss_cost(1 << 20));
        c.miss_cost_per_byte = true;
        c.mean_object_bytes = 1024.0;
        assert!((c.miss_cost(1024) - c.miss_cost_dollars).abs() < 1e-18);
        assert!(c.miss_cost(2048) > c.miss_cost(1024));
    }

    #[test]
    fn gain_schedules() {
        let g = GainSchedule::Constant { eps0: 2.0 };
        assert_eq!(g.gain(0), 2.0);
        assert_eq!(g.gain(1000), 2.0);
        assert!(!g.converges_wp1());

        let p = GainSchedule::Polynomial { eps0: 1.0, exponent: 0.7 };
        assert!(p.converges_wp1());
        assert!(p.gain(10) < p.gain(0));
        // Σ ε²(n) finite requires exponent > 0.5
        let bad = GainSchedule::Polynomial { eps0: 1.0, exponent: 0.4 };
        assert!(!bad.converges_wp1());
    }

    #[test]
    fn toml_round_trip() {
        let mut cfg = Config::default();
        cfg.scaler.policy = PolicyKind::Mrc;
        cfg.controller.t_max_secs = 1234.0;
        cfg.controller.gain = GainSchedule::Polynomial { eps0: 3.0, exponent: 0.8 };
        cfg.cluster.eviction = EvictionKind::Slab;
        cfg.cluster.placement = PlacementKind::HashSlotPinned;
        let text = cfg.to_toml();
        let back = Config::from_toml(&text).unwrap();
        assert_eq!(back.scaler.policy, PolicyKind::Mrc);
        assert_eq!(back.controller.t_max_secs, 1234.0);
        assert_eq!(back.controller.gain, cfg.controller.gain);
        assert_eq!(back.cluster.eviction, EvictionKind::Slab);
        assert_eq!(back.cluster.placement, PlacementKind::HashSlotPinned);
        assert_eq!(back.cost.instance.name, "cache.t2.micro");
    }

    #[test]
    fn placement_section_parses_and_defaults() {
        // Default: shared, bit-identical to the pre-placement cluster.
        assert_eq!(
            Config::from_toml("").unwrap().cluster.placement,
            PlacementKind::Shared
        );
        let cfg = Config::from_toml("[placement]\npolicy = \"slab_partition\"\n").unwrap();
        assert_eq!(cfg.cluster.placement, PlacementKind::SlabPartition);
        let cfg = Config::from_toml("[placement]\npolicy = \"hash_slot_pinned\"\n").unwrap();
        assert_eq!(cfg.cluster.placement, PlacementKind::HashSlotPinned);
        // Bad values error loudly.
        assert!(Config::from_toml("[placement]\npolicy = \"bogus\"\n").is_err());
    }

    #[test]
    fn from_path_reads_partial_config() {
        let dir = tempdir().unwrap();
        let p = dir.path().join("cfg.toml");
        std::fs::write(&p, "[scaler]\npolicy = \"ideal_ttl\"\nfixed_instances = 4\n").unwrap();
        let cfg = Config::from_path(&p).unwrap();
        assert_eq!(cfg.scaler.policy, PolicyKind::IdealTtl);
        assert_eq!(cfg.scaler.fixed_instances, 4);
        // unspecified sections fall back to defaults
        assert_eq!(cfg.cost.epoch_us, HOUR);
    }

    #[test]
    fn bad_values_rejected() {
        assert!(Config::from_toml("[scaler]\npolicy = \"bogus\"\n").is_err());
        assert!(Config::from_toml("[cost]\ninstance = \"cache.none\"\n").is_err());
        assert!(Config::from_toml("[controller]\ngain_kind = \"exp\"\ngain_eps0 = 1.0\n").is_err());
    }

    #[test]
    fn policy_kind_string_round_trip() {
        for p in [
            PolicyKind::Fixed,
            PolicyKind::Ttl,
            PolicyKind::Mrc,
            PolicyKind::IdealTtl,
            PolicyKind::Analytic,
            PolicyKind::TenantTtl,
        ] {
            assert_eq!(PolicyKind::parse(p.as_str()).unwrap(), p);
        }
        assert!(PolicyKind::parse("nope").is_err());
    }

    #[test]
    fn initial_instances_single_source_of_truth() {
        let mut cfg = Config::with_policy(PolicyKind::Fixed);
        cfg.scaler.fixed_instances = 6;
        cfg.scaler.min_instances = 2;
        assert_eq!(cfg.initial_instances(), 6, "Fixed runs at its static size");
        for kind in [
            PolicyKind::Ttl,
            PolicyKind::Mrc,
            PolicyKind::IdealTtl,
            PolicyKind::Analytic,
            PolicyKind::TenantTtl,
        ] {
            cfg.scaler.policy = kind;
            assert_eq!(cfg.initial_instances(), 2, "{kind:?} starts at the floor");
        }
        // Degenerate configs still keep the service up.
        cfg.scaler.min_instances = 0;
        assert_eq!(cfg.initial_instances(), 1);
    }

    #[test]
    fn telemetry_section_round_trips_and_validates() {
        // Off by default, nothing surprising in an empty config.
        let cfg = Config::from_toml("").unwrap();
        assert_eq!(cfg.telemetry, TelemetryConfig::default());
        assert!(!cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.journal_capacity, 1024);
        assert_eq!(cfg.telemetry.journal_path, None);

        let mut cfg = Config::default();
        cfg.telemetry.enabled = true;
        cfg.telemetry.journal_capacity = 64;
        cfg.telemetry.journal_path = Some("out/journal.jsonl".to_string());
        let back = Config::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.telemetry, cfg.telemetry);

        // journal_path is omitted from TOML when unset (and still parses).
        let cfg = Config::default();
        assert!(!cfg.to_toml().contains("journal_path"));
        assert_eq!(
            Config::from_toml(&cfg.to_toml()).unwrap().telemetry,
            TelemetryConfig::default()
        );

        // A zero-capacity journal is rejected loudly.
        assert!(Config::from_toml("[telemetry]\njournal_capacity = 0\n").is_err());
    }

    #[test]
    fn serve_section_round_trips_and_validates() {
        // Everything off by default: manual epochs, no expiry, no checkpoint.
        let cfg = Config::from_toml("").unwrap();
        assert_eq!(cfg.serve, ServeConfig::default());
        assert_eq!(cfg.serve.epoch_secs, 0);
        assert_eq!(cfg.serve.ttl_expiry_secs, 0.0);
        assert_eq!(cfg.serve.checkpoint_path, None);

        let mut cfg = Config::default();
        cfg.serve.epoch_secs = 30;
        cfg.serve.ttl_expiry_secs = 2.5;
        cfg.serve.checkpoint_path = Some("out/ckpt.jsonl".to_string());
        let back = Config::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.serve, cfg.serve);

        // checkpoint_path is omitted from TOML when unset (and still parses).
        let cfg = Config::default();
        assert!(!cfg.to_toml().contains("checkpoint_path"));
        assert_eq!(
            Config::from_toml(&cfg.to_toml()).unwrap().serve,
            ServeConfig::default()
        );

        // A negative or non-finite expiry TTL is rejected loudly.
        assert!(Config::from_toml("[serve]\nttl_expiry_secs = -1.0\n").is_err());
    }

    #[test]
    fn engine_section_round_trips_and_validates() {
        // One shard by default — the bit-identical classic path.
        let cfg = Config::from_toml("").unwrap();
        assert_eq!(cfg.engine, EngineConfig::default());
        assert_eq!(cfg.engine.shards, 1);

        let mut cfg = Config::default();
        cfg.engine.shards = 8;
        let back = Config::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.engine, cfg.engine);

        // Out-of-range shard counts are rejected loudly.
        assert!(Config::from_toml("[engine]\nshards = 0\n").is_err());
        assert!(Config::from_toml("[engine]\nshards = 257\n").is_err());
    }

    #[test]
    fn admission_section_round_trips_and_validates() {
        // No filter by default — the bit-identical seed path.
        let cfg = Config::from_toml("").unwrap();
        assert_eq!(cfg.admission, AdmissionConfig::default());
        assert_eq!(cfg.admission.filter, AdmissionKind::None);
        assert_eq!(cfg.admission.m, 2);
        assert_eq!(cfg.admission.sketch_bytes, 32768);

        let mut cfg = Config::default();
        cfg.admission.filter = AdmissionKind::MthRequest;
        cfg.admission.m = 3;
        cfg.admission.sketch_bytes = 65536;
        cfg.admission.keep_threshold = 0.5;
        let back = Config::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.admission, cfg.admission);

        // The string kinds parse both ways.
        for k in [AdmissionKind::None, AdmissionKind::MthRequest, AdmissionKind::KeepCost] {
            assert_eq!(AdmissionKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(AdmissionKind::parse("bloom").is_err());

        // Out-of-range values error loudly.
        assert!(Config::from_toml("[admission]\nfilter = \"bogus\"\n").is_err());
        assert!(Config::from_toml("[admission]\nm = 0\n").is_err());
        assert!(Config::from_toml("[admission]\nm = 16\n").is_err());
        assert!(Config::from_toml("[admission]\nsketch_bytes = 1000\n").is_err());
        assert!(Config::from_toml("[admission]\nsketch_bytes = 512\n").is_err());
        assert!(Config::from_toml("[admission]\nkeep_threshold = 0.0\n").is_err());
    }

    #[test]
    fn admission_tenant_overrides_round_trip() {
        let cfg = Config::from_toml(
            "[admission]\nfilter = \"mth_request\"\nm = 2\n\
             [tenant0]\nadmission_m = 4\n\
             [tenant1]\nname = \"bulk\"\nkeep_threshold = 2.5\n",
        )
        .unwrap();
        assert_eq!(cfg.admission.overrides.len(), 2);
        assert_eq!(cfg.admission.overrides[0].tenant, 0);
        assert_eq!(cfg.admission.overrides[0].m, Some(4));
        assert_eq!(cfg.admission.overrides[0].keep_threshold, None);
        assert_eq!(cfg.admission.overrides[1].tenant, 1);
        assert_eq!(cfg.admission.overrides[1].keep_threshold, Some(2.5));
        let back = Config::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.admission.overrides, cfg.admission.overrides);
        // Overrides key on the tenant *id*, not the section number.
        let cfg = Config::from_toml("[tenant0]\nid = 9\nadmission_m = 3\n").unwrap();
        assert_eq!(cfg.admission.overrides[0].tenant, 9);
        // Out-of-range overrides error loudly.
        assert!(Config::from_toml("[tenant0]\nadmission_m = 16\n").is_err());
        assert!(Config::from_toml("[tenant0]\nkeep_threshold = -1.0\n").is_err());
    }

    #[test]
    fn tenant_sections_round_trip() {
        let mut cfg = Config::default();
        cfg.scaler.policy = PolicyKind::TenantTtl;
        cfg.scaler.enforce_grants = true;
        cfg.tenants = vec![
            TenantSpec::new(0, "api")
                .with_multiplier(3.0)
                .with_class(TrafficClass::Interactive)
                .with_reserved_bytes(64 * 1024 * 1024)
                .with_slo_miss_ratio(0.05),
            TenantSpec::new(5, "batch")
                .with_multiplier(0.3)
                .with_class(TrafficClass::Bulk),
        ];
        let text = cfg.to_toml();
        let back = Config::from_toml(&text).unwrap();
        assert_eq!(back.scaler.policy, PolicyKind::TenantTtl);
        assert!(back.scaler.enforce_grants);
        assert_eq!(back.tenants, cfg.tenants);
    }

    #[test]
    fn slo_and_reservation_keys_parse_and_validate() {
        let cfg = Config::from_toml(
            "[scaler]\nenforce_grants = true\n\
             [tenant0]\nreserved_mb = 40\nslo_miss_ratio = 0.1\n\
             [tenant1]\nname = \"bulk\"\n",
        )
        .unwrap();
        assert!(cfg.scaler.enforce_grants);
        assert_eq!(cfg.tenants[0].reserved_bytes, 40 * 1024 * 1024);
        assert_eq!(cfg.tenants[0].slo_miss_ratio, Some(0.1));
        // Unset keys keep the no-reservation / no-SLO defaults.
        assert_eq!(cfg.tenants[1].reserved_bytes, 0);
        assert_eq!(cfg.tenants[1].slo_miss_ratio, None);
        // Enforcement stays off unless asked for.
        assert!(!Config::from_toml("").unwrap().scaler.enforce_grants);
        // Out-of-range values error loudly.
        assert!(Config::from_toml("[tenant0]\nslo_miss_ratio = 1.5\n").is_err());
        assert!(Config::from_toml("[tenant0]\nreserved_mb = -3.0\n").is_err());
    }

    #[test]
    fn tenant_sections_defaults_and_errors() {
        let cfg = Config::from_toml(
            "[tenant0]\nmiss_cost_multiplier = 2.0\n[tenant1]\nname = \"web\"\n",
        )
        .unwrap();
        assert_eq!(cfg.tenants.len(), 2);
        assert_eq!(cfg.tenants[0].id, 0);
        assert_eq!(cfg.tenants[0].name, "tenant0");
        assert_eq!(cfg.tenants[0].miss_cost_multiplier, 2.0);
        assert_eq!(cfg.tenants[1].id, 1);
        assert_eq!(cfg.tenants[1].name, "web");
        assert_eq!(cfg.tenants[1].miss_cost_multiplier, 1.0);
        // No tenant sections → single-tenant mode.
        assert!(Config::from_toml("").unwrap().tenants.is_empty());
        // Bad class is rejected.
        assert!(Config::from_toml("[tenant0]\nclass = \"vip\"\n").is_err());
        // Out-of-range ids error loudly instead of clamping.
        assert!(Config::from_toml("[tenant0]\nid = 70000\n").is_err());
        // A numbering gap must not drop the later sections.
        let gappy = Config::from_toml(
            "[tenant0]\nname = \"a\"\n[tenant2]\nname = \"c\"\nmiss_cost_multiplier = 5.0\n",
        )
        .unwrap();
        assert_eq!(gappy.tenants.len(), 2);
        assert_eq!(gappy.tenants[1].id, 2);
        assert_eq!(gappy.tenants[1].name, "c");
        assert_eq!(gappy.tenants[1].miss_cost_multiplier, 5.0);
    }
}
