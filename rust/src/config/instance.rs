//! Cloud cache instance catalog, modelled on Amazon ElastiCache (§2.2,
//! §6.1). Prices are the Oct. 2017 US figures the paper quotes.

/// One purchasable cache node configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceType {
    /// Catalog name, e.g. `cache.t2.micro`.
    pub name: String,
    /// Usable RAM in bytes.
    pub ram_bytes: u64,
    /// Number of vCPUs (relevant for throughput scaling discussions).
    pub vcpus: u32,
    /// On-demand price, dollars per hour.
    pub dollars_per_hour: f64,
}

impl InstanceType {
    /// The instance the paper selects: 0.555 GB RAM, 1 vCPU, $0.017/h.
    /// Small nodes give fine sizing granularity and one vCPU each, which
    /// preserves aggregate throughput while scaling (§6.1).
    pub fn cache_t2_micro() -> Self {
        InstanceType {
            name: "cache.t2.micro".into(),
            ram_bytes: 555_000_000,
            vcpus: 1,
            dollars_per_hour: 0.017,
        }
    }

    /// 3.22 GB / 2 vCPU node (the "bigger instance" §6.1 argues against).
    pub fn cache_t2_medium() -> Self {
        InstanceType {
            name: "cache.t2.medium".into(),
            ram_bytes: 3_220_000_000,
            vcpus: 2,
            dollars_per_hour: 0.068,
        }
    }

    /// 6.05 GB / 2 vCPU node.
    pub fn cache_m4_large() -> Self {
        InstanceType {
            name: "cache.m4.large".into(),
            ram_bytes: 6_050_000_000,
            vcpus: 2,
            dollars_per_hour: 0.156,
        }
    }

    /// Dollars per byte·hour — the granularity-independent storage price.
    pub fn dollars_per_byte_hour(&self) -> f64 {
        self.dollars_per_hour / self.ram_bytes as f64
    }
}

/// The full catalog a user can choose from when configuring the cluster.
#[derive(Debug, Clone)]
pub struct InstanceCatalog {
    pub instances: Vec<InstanceType>,
}

impl Default for InstanceCatalog {
    fn default() -> Self {
        InstanceCatalog {
            instances: vec![
                InstanceType::cache_t2_micro(),
                InstanceType::cache_t2_medium(),
                InstanceType::cache_m4_large(),
            ],
        }
    }
}

impl InstanceCatalog {
    /// Look an instance type up by name.
    pub fn by_name(&self, name: &str) -> Option<&InstanceType> {
        self.instances.iter().find(|i| i.name == name)
    }

    /// The cheapest instance per byte·hour (what a price-driven user picks
    /// absent throughput constraints).
    pub fn cheapest_per_byte(&self) -> Option<&InstanceType> {
        self.instances.iter().min_by(|a, b| {
            a.dollars_per_byte_hour()
                .partial_cmp(&b.dollars_per_byte_hour())
                .unwrap()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        let cat = InstanceCatalog::default();
        assert!(cat.by_name("cache.t2.micro").is_some());
        assert!(cat.by_name("cache.none").is_none());
        assert_eq!(cat.instances.len(), 3);
    }

    #[test]
    fn micro_matches_paper() {
        let m = InstanceType::cache_t2_micro();
        assert_eq!(m.vcpus, 1);
        assert!((m.dollars_per_hour - 0.017).abs() < 1e-12);
        // eight micro nodes ≈ the production 4 GB cache of §6.1
        assert!(8 * m.ram_bytes >= 4_000_000_000);
    }

    #[test]
    fn per_byte_pricing_is_close_to_linear() {
        // [39] (cited in §4.1): prices are almost linear in RAM. Our catalog
        // reflects that: per-byte-hour prices within ~2.5x of each other.
        let cat = InstanceCatalog::default();
        let prices: Vec<f64> = cat
            .instances
            .iter()
            .map(|i| i.dollars_per_byte_hour())
            .collect();
        let lo = prices.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = prices.iter().cloned().fold(0.0, f64::max);
        assert!(hi / lo < 2.5, "hi={hi} lo={lo}");
        assert_eq!(
            cat.cheapest_per_byte().unwrap().name,
            "cache.t2.medium"
        );
    }
}
