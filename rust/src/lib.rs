//! # elastictl — Elastic Provisioning of Cloud Caches: a Cost-aware TTL Approach
//!
//! Reproduction of Carra, Neglia & Michiardi (2018). The library implements
//! the paper's full stack:
//!
//! * a **virtual TTL cache** with renewal whose single timer `T` is adapted
//!   by stochastic approximation to minimise *storage + miss* cost
//!   ([`vcache`]);
//! * an **O(1)** FIFO-calendar implementation of that cache (§5.1 of the
//!   paper) so the load balancer's bookkeeping never exceeds the per-request
//!   complexity of the caches it fronts;
//! * a horizontally scalable cluster of fixed-size physical cache instances
//!   behind a Redis-style 16384-hash-slot **load balancer**
//!   ([`cluster`], [`balancer`], [`cache`]);
//! * the **epoch autoscaler** (Algorithm 2) plus the baselines the paper
//!   compares against: static provisioning, exact-MRC-driven sizing, the
//!   ideal (vertically billed) TTL cache, and the clairvoyant **TTL-OPT**
//!   lower bound (Algorithm 1) ([`scaler`], [`mrc`], [`ttlopt`]);
//! * the **streaming execution engine** ([`engine`]) — the one request
//!   path behind everything above: `EngineBuilder` (config + policy +
//!   probes) produces an `Engine` driven step by step
//!   (`offer`/`advance_to`/`finish`), with a uniform policy registry in
//!   which every [`config::PolicyKind`] is first-class and composable
//!   `Probe` observers for series/balance/tenant diagnostics. The
//!   canonical way to run a policy over a trace:
//!
//!   ```no_run
//!   use elastictl::config::Config;
//!   use elastictl::engine::EngineBuilder;
//!   use elastictl::trace::{FileSource, RequestSource};
//!
//!   let cfg = Config::default();
//!   let mut src = FileSource::open("trace.bin")?; // streams, no Vec in RAM
//!   let mut engine = EngineBuilder::new(&cfg).build();
//!   while let Some(req) = src.next_request() {
//!       engine.offer(&req);
//!   }
//!   let report = engine.finish();
//!   println!("total ${:.4}", report.total_cost);
//!   # Ok::<(), anyhow::Error>(())
//!   ```
//! * a discrete-event **testbed** facade that replays (synthetic) CDN
//!   traces through the engine and bills by ElastiCache-style epochs
//!   ([`sim`], [`trace`], [`cost`]);
//! * a PJRT-backed **analytic planner** that evaluates the paper's IRM cost
//!   model `C(T) = Σ_i c_i + (λ_i m_i − c_i) e^{−λ_i T}` (eq. 4) via an
//!   AOT-compiled JAX/Pallas artifact ([`runtime`]);
//! * a **multi-tenant provisioning layer** ([`tenant`]): a registry of
//!   tenants with per-tenant miss-cost multipliers, traffic classes,
//!   Memshare-style byte reservations and miss-ratio SLOs; a bank of
//!   per-tenant §4 TTL controllers (each converging to its own `T_i`);
//!   and a cost-aware arbiter that folds the per-tenant shadow demands
//!   into one shared cluster sizing decision — requests carry a compact
//!   tenant id end to end (trace format v2, [`trace::TenantMux`],
//!   `(tenant, key)` routing in [`balancer`], per-tenant cost ledgers in
//!   [`cost`], and the `GET <tenant>/<key>` / `STATS <tenant>` /
//!   `SLO <tenant>` serve protocol);
//! * the **per-tenant enforcement loop** (`scaler.enforce_grants`): each
//!   epoch the arbiter's grants become *binding* — an occupancy cap that
//!   binds on **physical resident bytes** (the balancer feeds each
//!   tenant's placement-ledger row to the policy; an insert admits only
//!   while `resident + size ≤ cap`, a refused admission still serves the
//!   miss, and over-cap tenants are shed back under their grant at epoch
//!   boundaries by targeted eviction of their own coldest entries), a
//!   TTL clamp that projects an over-demanding tenant's controller onto
//!   its largest affordable timer, and an SLO feedback term that
//!   escalates a tenant's grant priority while its measured miss ratio
//!   exceeds its configured `slo_miss_ratio`
//!   ([`tenant::TenantEnforcement`], [`engine::SloProbe`]);
//! * the **physical placement subsystem** ([`placement`]): every store
//!   entry carries a tenant tag, evictions report `(tenant, bytes)`
//!   upward, and the cluster maintains a per-tenant resident-bytes
//!   ledger (`Σ per-tenant == used()`); a `PlacementPolicy`
//!   (`[placement]` config section) decides where `(tenant, key)` lives —
//!   `shared` scoped-key hashing (default, bit-identical),
//!   `hash_slot_pinned` per-tenant instance subsets sized from the epoch
//!   grants, or `slab_partition` Memshare-style per-instance byte floors
//!   — surfaced via the `PLACEMENT` serve command, `physical_bytes` in
//!   `STATS <tenant>`, and [`engine::PlacementProbe`];
//! * the **online tenant lifecycle** ([`tenant::Lifecycle`]):
//!   `Admitted → Active → Draining → Retired`, driven mid-run by the
//!   serve protocol's `ADMIT`/`RETIRE` commands, by
//!   [`engine::Engine::admit_tenant`]/[`engine::Engine::retire_tenant`],
//!   or by the **tenant-event lane** of trace format v3
//!   ([`trace::TenantEvent`]; v1/v2 still readable). Retirement drains
//!   rather than drops — the controller leaves the bank at once,
//!   placement pins/floors are released, residents are shed to zero
//!   within [`tenant::MAX_DRAIN_EPOCHS`] boundaries — and ends in a
//!   **billing reconciliation**: each epoch's storage bill is
//!   attributed across tenants by resident bytes
//!   ([`cost::TenantEpochBill`]) with
//!   `Σ per-epoch tenant bills == total cluster bill` exact by
//!   construction, and the departed tenant's ledger closes into a
//!   [`cost::TenantReconciliation`];
//! * the **decision-trace telemetry subsystem** ([`telemetry`]): a
//!   unified registry of counters / gauges / [`metrics::LogHistogram`]-
//!   backed timers with O(1) pre-resolved-handle recording threaded
//!   through the balancer, cluster and epoch pipeline (per-stage epoch
//!   timing included); a bounded per-epoch decision journal
//!   ([`telemetry::EpochDecisionRecord`]: demand → granted,
//!   reserved/pooled split, clamps, shedding, denials, SLO escalation,
//!   billing attribution) surfaced as `RunReport.journal`, as JSONL via
//!   `[telemetry] journal_path`, and over the serve protocol's
//!   `WHY <tenant>` / `METRICS` (Prometheus text) commands — all off by
//!   default so the untelemetered request path stays bit-identical;
//! * the **concurrent server runtime** ([`srv`]): a thread-per-connection
//!   accept loop feeding the single engine-owner state thread over one
//!   mpsc channel (total command order, no async dependency), a
//!   wall-clock epoch ticker (`[serve] epoch_secs`), real-`Instant` TTL
//!   expiry on resident stores, an append-only fsync-per-epoch billing
//!   checkpoint with idempotent `--resume` replay (bit-identical
//!   cumulative bills after a kill, [`srv::checkpoint`]), and a
//!   concurrent trace-replay load generator ([`srv::loadgen`]) behind
//!   `elastictl loadgen`;
//! * the **admission-filter layer** ([`admission`]): config-selectable
//!   O(1) insertion filters under every policy (`[admission] filter =
//!   none|mth_request|keep_cost`) — a cache-on-Mth-request counting
//!   sketch with epoch-boundary aging, and a cost-based keep-vs-drop
//!   decision pricing each insert's expected storage against its miss
//!   dollars at the tenant's current TTL; denials serve the miss
//!   without inserting, counted as `filter_denials` in STATS, the
//!   telemetry registry and the journal's `cause = filter_denied` rows;
//! * the **experiment harness** regenerating every figure of §2/§3/§6
//!   plus the multi-tenant fig10 study, the fig11 SLO-enforcement
//!   study, the fig12 placement-isolation study, the fig13
//!   online-churn study and the fig14 observability study
//!   ([`experiments`]).
//!
//! The prose map of all of this — module layout, the per-request
//! dataflow and the per-epoch control loop — lives in
//! `docs/ARCHITECTURE.md`; the serve wire protocol in
//! `docs/PROTOCOL.md`; the figure-to-claim table in
//! `docs/EXPERIMENTS.md`.
//!
//! Time is measured in microseconds ([`TimeUs`]); object sizes in bytes.

pub mod admission;
pub mod balancer;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod engine;
pub mod experiments;
pub mod metrics;
pub mod mrc;
pub mod placement;
pub mod runtime;
pub mod scaler;
pub mod serve;
pub mod sim;
pub mod srv;
pub mod telemetry;
pub mod tenant;
pub mod trace;
pub mod ttlopt;
pub mod util;
pub mod vcache;

/// Simulation / trace time in microseconds since the start of the trace.
pub type TimeUs = u64;

/// Opaque object (cache key) identifier.
pub type ObjectId = u64;

/// Compact tenant identifier carried by every request (0 = the default
/// tenant of single-workload traces).
pub type TenantId = u16;

/// One microsecond-denominated second.
pub const SECOND: TimeUs = 1_000_000;
/// Microseconds in a minute.
pub const MINUTE: TimeUs = 60 * SECOND;
/// Microseconds in an hour (the paper's billing epoch).
pub const HOUR: TimeUs = 60 * MINUTE;
/// Microseconds in a day (the diurnal period of the Akamai workload).
pub const DAY: TimeUs = 24 * HOUR;

/// Convert a microsecond timestamp to fractional seconds.
#[inline]
pub fn us_to_secs(t: TimeUs) -> f64 {
    t as f64 / SECOND as f64
}

/// Convert fractional seconds to a microsecond timestamp (saturating at 0).
#[inline]
pub fn secs_to_us(s: f64) -> TimeUs {
    if s <= 0.0 {
        0
    } else {
        (s * SECOND as f64).round() as TimeUs
    }
}

/// Crate-wide result alias (errors flow through `anyhow`).
pub type Result<T> = anyhow::Result<T>;

/// Deterministic 64-bit mix used everywhere a hash of an [`ObjectId`] is
/// needed (slot assignment, SHARDS sampling, synthetic size generation).
///
/// SplitMix64 finalizer: fast, stateless and well distributed; using one
/// shared mixer keeps routing and sampling decisions reproducible across
/// runs and across modules.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_conversions_round_trip() {
        for s in [0.0, 0.5, 1.0, 3600.0, 86_400.0] {
            assert!((us_to_secs(secs_to_us(s)) - s).abs() < 1e-6);
        }
        assert_eq!(secs_to_us(-1.0), 0);
        assert_eq!(HOUR, 3_600 * SECOND);
        assert_eq!(DAY, 24 * HOUR);
    }

    #[test]
    fn mix64_is_deterministic_and_spreads() {
        assert_eq!(mix64(42), mix64(42));
        // Consecutive ids land in different hash-slot buckets most of the time.
        let slots: std::collections::HashSet<u64> =
            (0..1000u64).map(|i| mix64(i) % 16384).collect();
        assert!(slots.len() > 900, "got {} distinct slots", slots.len());
    }
}
