//! Fig. 1 — load balancer computational overhead (§2.4).
//!
//! Paper: replaying the trace, the MRC-based balancer nearly doubles CPU
//! load vs. the basic (fixed-route) one, while the TTL balancer stays
//! under ~20%; in closed-loop mode, MRC halves achievable throughput while
//! TTL loses ~8%.
//!
//! Here we run the same three request paths over the same trace chunk and
//! measure wall-clock per request: the per-hour "CPU load" series (left
//! panel) and the normalized closed-loop throughput (right panel).

use super::ExpContext;
use crate::config::{Config, PolicyKind};
use crate::engine::EngineBuilder;
use crate::Result;
use std::time::Instant;

/// One router variant's measurements.
#[derive(Debug, Clone)]
pub struct RouterMeasurement {
    pub name: String,
    /// Seconds of CPU per simulated hour of trace.
    pub cpu_per_hour: Vec<(u64, f64)>,
    /// Requests per wall second, closed loop.
    pub throughput: f64,
    /// Normalized to the basic router.
    pub throughput_norm: f64,
    pub total_work_units: u64,
}

/// Fig. 1 report.
#[derive(Debug)]
pub struct Fig1Report {
    pub variants: Vec<RouterMeasurement>,
}

impl Fig1Report {
    pub fn render(&self) -> String {
        let mut s = String::from(
            "Fig.1 — router overhead (normalized closed-loop throughput)\n",
        );
        for v in &self.variants {
            s.push_str(&format!(
                "  {:<8} throughput={:>10.0} req/s  normalized={:.3}  work_units={}\n",
                v.name, v.throughput, v.throughput_norm, v.total_work_units
            ));
        }
        s.push_str(
            "  paper shape: basic=1.00, ttl≈0.92, mrc≈0.55 (ordering must hold)\n",
        );
        s
    }
}

fn run_variant(cfg: &Config, trace: &[crate::trace::Request], name: &str) -> RouterMeasurement {
    // The bare engine request path (no series probes) at the fixed
    // baseline's initial size, so all variants start from the same
    // cluster shape.
    let mut engine = EngineBuilder::new(cfg)
        .initial_instances(cfg.scaler.fixed_instances)
        .no_default_probes()
        .build();
    let mut cpu_per_hour: Vec<(u64, f64)> = Vec::new();
    let mut hour_end = crate::HOUR;
    let mut hour_cpu = 0.0f64;

    let t_all = Instant::now();
    for r in trace {
        while r.ts >= hour_end {
            cpu_per_hour.push((hour_end, hour_cpu));
            hour_cpu = 0.0;
            hour_end += crate::HOUR;
        }
        // Close elapsed epochs outside the hot window: Fig. 1 measures
        // per-request router overhead, not epoch-boundary billing work.
        engine.advance_to(r.ts);
        let hot = Instant::now();
        engine.offer(r);
        hour_cpu += hot.elapsed().as_secs_f64();
    }
    cpu_per_hour.push((hour_end, hour_cpu));
    let elapsed = t_all.elapsed().as_secs_f64();
    RouterMeasurement {
        name: name.to_string(),
        cpu_per_hour,
        throughput: trace.len() as f64 / elapsed.max(1e-9),
        throughput_norm: 0.0, // filled by caller
        total_work_units: engine.work_units(),
    }
}

/// Run Fig. 1 over (a prefix of) the context trace.
pub fn run_fig1(ctx: &ExpContext, max_requests: usize) -> Result<Fig1Report> {
    let trace = &ctx.trace[..ctx.trace.len().min(max_requests)];

    let mut basic_cfg = ctx.cfg.clone();
    basic_cfg.scaler.policy = PolicyKind::Fixed;
    basic_cfg.scaler.fixed_instances = 8;

    let mut ttl_cfg = ctx.cfg.clone();
    ttl_cfg.scaler.policy = PolicyKind::Ttl;
    ttl_cfg.scaler.fixed_instances = 8;

    let mut mrc_cfg = ctx.cfg.clone();
    mrc_cfg.scaler.policy = PolicyKind::Mrc;
    mrc_cfg.scaler.fixed_instances = 8;

    let mut variants = vec![
        run_variant(&basic_cfg, trace, "basic"),
        run_variant(&ttl_cfg, trace, "ttl"),
        run_variant(&mrc_cfg, trace, "mrc"),
    ];
    let base = variants[0].throughput;
    for v in &mut variants {
        v.throughput_norm = v.throughput / base.max(1e-9);
    }

    // CSVs: per-hour CPU (left panel), throughput bars (right panel).
    let mut rows = Vec::new();
    for v in &variants {
        for &(t, cpu) in &v.cpu_per_hour {
            rows.push(vec![
                v.name.clone(),
                format!("{:.1}", crate::us_to_secs(t) / 3600.0),
                format!("{cpu:.6}"),
            ]);
        }
    }
    ctx.write_csv("fig1_cpu_per_hour.csv", &["variant", "hour", "cpu_seconds"], &rows)?;
    let bar_rows: Vec<Vec<String>> = variants
        .iter()
        .map(|v| {
            vec![
                v.name.clone(),
                format!("{:.1}", v.throughput),
                format!("{:.4}", v.throughput_norm),
            ]
        })
        .collect();
    ctx.write_csv(
        "fig1_throughput.csv",
        &["variant", "req_per_sec", "normalized"],
        &bar_rows,
    )?;

    Ok(Fig1Report { variants })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn ordering_matches_paper_shape() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig1(&ctx, 120_000).unwrap();
        assert_eq!(rep.variants.len(), 3);
        let by_name = |n: &str| rep.variants.iter().find(|v| v.name == n).unwrap();
        let basic = by_name("basic");
        let ttl = by_name("ttl");
        let mrc = by_name("mrc");
        assert_eq!(basic.throughput_norm, 1.0);
        // The MRC router must do strictly more bookkeeping work than TTL,
        // which does more than basic.
        assert!(mrc.total_work_units > ttl.total_work_units);
        assert!(ttl.total_work_units > basic.total_work_units);
        // Throughput ordering: mrc slowest (allow noise margin for ttl).
        assert!(
            mrc.throughput_norm < ttl.throughput_norm,
            "mrc={} ttl={}",
            mrc.throughput_norm,
            ttl.throughput_norm
        );
        assert!(dir.path().join("fig1_throughput.csv").exists());
    }
}
