//! Fig. 12 (ours, beyond the paper) — tenant-aware physical placement on
//! the shared elastic cluster: what actually protects a gold tenant's
//! *residents* when a cheap tenant's insert storm churns the LRUs.
//!
//! Fig. 11 showed grant *enforcement* (admission caps + TTL clamps)
//! holding an SLO. This experiment attacks the layer below: even a
//! well-behaved cheap tenant inserting within its grant physically
//! evicts the gold tenant's working set through shared-LRU interference,
//! because scoped-key hashing spreads every tenant over every instance.
//! The placement subsystem offers two isolation shapes
//! ([`crate::placement`]):
//!
//! * `hash_slot_pinned` — each tenant is pinned to an instance subset
//!   sized from its grant; the storm cannot reach the gold instances.
//! * `slab_partition` — Memshare-style per-instance byte floors; the
//!   storm may only evict *pooled* bytes, never the reserved floors.
//!
//! Four runs over the identical fig11-style trace (gold steady workload,
//! flood spiking ~80× over a huge cold catalogue for 12 hours):
//! `shared`, `hash_slot_pinned` and `slab_partition` with enforcement
//! off (pure placement comparison), plus `shared` with
//! `scaler.enforce_grants = true` to demonstrate the occupancy cap now
//! binding on *physical resident bytes*: at every epoch boundary each
//! capped tenant's ledger row is at or under its grant (admission +
//! targeted shedding — asserted by the smoke test from
//! [`crate::engine::PlacementSample`]s).
//!
//! Expected shape (asserted): during the storm the gold tenant's miss
//! ratio under either placement policy is a fraction of the shared
//! baseline's; measurement starts one epoch after the spike onset
//! (placement reacts at epoch granularity, same honest latency as
//! fig11).

use super::fig11_slo::{fig11_cfg, flood_trace, gold_trace, SPIKE_END, SPIKE_START};
use super::{calibrate_miss_cost, ExpContext, TraceScale};
use crate::config::Config;
use crate::engine::{run, RunReport};
use crate::placement::PlacementKind;
use crate::tenant::{TenantSpec, TrafficClass};
use crate::trace::VecSource;
use crate::{Result, TimeUs, HOUR};

/// Gold tenant id (10× miss cost, reserved floor).
pub const GOLD: u16 = 0;
/// Flood tenant id (cheap, mostly pooled).
pub const FLOOD: u16 = 1;

/// One placement variant's outcome.
#[derive(Debug)]
pub struct Fig12Variant {
    pub name: &'static str,
    pub placement: PlacementKind,
    pub enforce_grants: bool,
    /// Gold's request-weighted miss ratio inside the storm measurement
    /// window (one epoch after onset through the spike end).
    pub gold_storm_miss_ratio: f64,
    pub gold_overall_miss_ratio: f64,
    pub total_cost: f64,
    pub report: RunReport,
}

/// Fig. 12 report.
#[derive(Debug)]
pub struct Fig12Report {
    pub spike_start: TimeUs,
    pub spike_end: TimeUs,
    /// shared / hash_slot_pinned / slab_partition (enforcement off), then
    /// shared_enforced (`scaler.enforce_grants = true`).
    pub variants: Vec<Fig12Variant>,
}

impl Fig12Report {
    pub fn variant(&self, name: &str) -> &Fig12Variant {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .expect("fig12 variant")
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "Fig.12 — tenant-aware physical placement under a cheap tenant's insert storm\n\
             \x20 spike: hours {:.0}–{:.0}; measurement starts one epoch after onset\n",
            crate::us_to_secs(self.spike_start) / 3600.0,
            crate::us_to_secs(self.spike_end) / 3600.0,
        );
        for v in &self.variants {
            s.push_str(&format!(
                "  {:<16} gold storm miss%={:.4} overall={:.4} spurious={} total=${:.4}{}\n",
                v.name,
                v.gold_storm_miss_ratio,
                v.gold_overall_miss_ratio,
                v.report.spurious_misses,
                v.total_cost,
                if v.enforce_grants { "  [enforce_grants]" } else { "" },
            ));
        }
        s.push_str(
            "  expected shape: hash_slot_pinned and slab_partition both cut the gold\n\
             \x20 tenant's storm miss ratio vs shared (LRU interference removed); the\n\
             \x20 enforced run keeps every capped tenant's resident bytes ≤ its grant\n\
             \x20 at every epoch boundary (admission + targeted shedding)\n",
        );
        s
    }
}

/// The fig12 tenant roster: the gold reservation covers its working set
/// with headroom (3 instances worth), the flood keeps one instance.
pub fn fig12_specs(instance_bytes: u64) -> Vec<TenantSpec> {
    vec![
        TenantSpec::new(GOLD, "gold")
            .with_multiplier(10.0)
            .with_class(TrafficClass::Interactive)
            .with_reserved_bytes(3 * instance_bytes),
        TenantSpec::new(FLOOD, "flood")
            .with_multiplier(1.0)
            .with_class(TrafficClass::Bulk)
            .with_reserved_bytes(instance_bytes),
    ]
}

/// Gold's `(requests, misses)` inside the storm measurement window.
fn gold_storm_counts(report: &RunReport, spike_start: TimeUs, spike_end: TimeUs) -> (u64, u64) {
    report
        .slo
        .iter()
        .filter(|s| s.tenant == GOLD && s.t > spike_start + HOUR && s.t <= spike_end)
        .fold((0, 0), |(r, m), s| (r + s.requests, m + s.misses))
}

pub fn run_fig12(ctx: &ExpContext, scale: TraceScale) -> Result<Fig12Report> {
    let seed = 0xF16_12;
    let mut trace = gold_trace(scale, seed);
    trace.extend(flood_trace(scale, seed));
    trace.sort_by_key(|r| r.ts);

    let mut base = fig11_cfg(scale);
    base.cost.miss_cost_dollars = calibrate_miss_cost(&base, &trace, 4);
    base.tenants = fig12_specs(base.cost.instance.ram_bytes);

    let matrix: [(&'static str, PlacementKind, bool); 4] = [
        ("shared", PlacementKind::Shared, false),
        ("hash_slot_pinned", PlacementKind::HashSlotPinned, false),
        ("slab_partition", PlacementKind::SlabPartition, false),
        ("shared_enforced", PlacementKind::Shared, true),
    ];
    let mut variants = Vec::new();
    for (name, placement, enforce) in matrix {
        let mut cfg: Config = base.clone();
        cfg.cluster.placement = placement;
        cfg.scaler.enforce_grants = enforce;
        let report = run(&cfg, &mut VecSource::new(trace.clone()));
        let (req, miss) = gold_storm_counts(&report, SPIKE_START, SPIKE_END);
        let gold_row = report.tenants.iter().find(|t| t.tenant == GOLD);
        variants.push(Fig12Variant {
            name,
            placement,
            enforce_grants: enforce,
            gold_storm_miss_ratio: if req > 0 { miss as f64 / req as f64 } else { 0.0 },
            gold_overall_miss_ratio: gold_row
                .map(|t| {
                    if t.requests > 0 {
                        t.misses as f64 / t.requests as f64
                    } else {
                        0.0
                    }
                })
                .unwrap_or(0.0),
            total_cost: report.total_cost,
            report,
        });
    }

    // CSV artifacts: the per-epoch placement ledger of every run, plus
    // the headline summary.
    let mut rows: Vec<Vec<String>> = Vec::new();
    for v in &variants {
        for s in &v.report.placement {
            rows.push(vec![
                v.name.to_string(),
                format!("{:.3}", crate::us_to_secs(s.t) / 3600.0),
                s.tenant.to_string(),
                s.resident_bytes.to_string(),
                s.granted_bytes.map(|b| b.to_string()).unwrap_or_default(),
                s.cap_bytes.map(|b| b.to_string()).unwrap_or_default(),
            ]);
        }
    }
    ctx.write_csv(
        "fig12_placement_series.csv",
        &["variant", "hour", "tenant", "resident_bytes", "granted_bytes", "cap_bytes"],
        &rows,
    )?;
    ctx.write_csv(
        "fig12_summary.csv",
        &["variant", "gold_storm_miss_ratio", "gold_overall_miss_ratio", "total_usd"],
        &variants
            .iter()
            .map(|v| {
                vec![
                    v.name.to_string(),
                    format!("{:.6}", v.gold_storm_miss_ratio),
                    format!("{:.6}", v.gold_overall_miss_ratio),
                    format!("{:.6}", v.total_cost),
                ]
            })
            .collect::<Vec<_>>(),
    )?;

    Ok(Fig12Report { spike_start: SPIKE_START, spike_end: SPIKE_END, variants })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_isolates_gold_and_caps_bind_on_resident_bytes() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig12(&ctx, TraceScale::Smoke).unwrap();

        // All four runs saw the identical trace.
        let shared = rep.variant("shared");
        let pinned = rep.variant("hash_slot_pinned");
        let partition = rep.variant("slab_partition");
        let enforced = rep.variant("shared_enforced");
        assert_eq!(shared.report.requests, pinned.report.requests);
        assert_eq!(shared.report.requests, partition.report.requests);
        assert_eq!(shared.report.requests, enforced.report.requests);

        // The storm actually hurts under shared placement…
        assert!(
            shared.gold_storm_miss_ratio > 0.2,
            "storm too weak to measure: shared={}",
            shared.gold_storm_miss_ratio
        );
        // …and both placement policies cut the gold tenant's storm miss
        // ratio to a fraction of it.
        assert!(
            pinned.gold_storm_miss_ratio < 0.6 * shared.gold_storm_miss_ratio,
            "pinned {} vs shared {}",
            pinned.gold_storm_miss_ratio,
            shared.gold_storm_miss_ratio
        );
        assert!(
            partition.gold_storm_miss_ratio < 0.6 * shared.gold_storm_miss_ratio,
            "partition {} vs shared {}",
            partition.gold_storm_miss_ratio,
            shared.gold_storm_miss_ratio
        );

        // Enforced run: the occupancy cap binds on *resident bytes* —
        // at every epoch boundary each capped tenant's physical bytes
        // are at or under its grant (admission + targeted shedding).
        let mut capped_flood = 0;
        for s in &enforced.report.placement {
            if let Some(cap) = s.cap_bytes {
                assert!(
                    s.resident_bytes <= cap,
                    "tenant {} resident {} > cap {cap} at t={}",
                    s.tenant,
                    s.resident_bytes,
                    s.t
                );
                if s.tenant == FLOOD {
                    capped_flood += 1;
                }
            }
        }
        assert!(capped_flood > 0, "the flood tenant was never capped");
        // The unenforced runs never cap anyone.
        assert!(shared.report.placement.iter().all(|s| s.cap_bytes.is_none()));

        // Artifacts exist.
        assert!(dir.path().join("fig12_placement_series.csv").exists());
        assert!(dir.path().join("fig12_summary.csv").exists());
    }
}
