//! §4.1 / Proposition 1 validation: under stationary IRM traffic the
//! stochastic-approximation TTL converges to (a neighbourhood of) the
//! minimizer of the analytic cost C(T) — which we obtain independently
//! from the L2/L1 cost-model artifact (or its Rust oracle).

use super::ExpContext;
use crate::config::PolicyKind;
use crate::engine::run;
use crate::runtime::{BucketedStats, Planner};
use crate::trace::{IrmConfig, IrmGenerator};
use crate::Result;

#[derive(Debug)]
pub struct IrmReport {
    /// TTL the controller settled on (mean of the last quarter of samples).
    pub converged_ttl_secs: f64,
    /// Analytic optimum from the planner.
    pub t_star_secs: f64,
    /// Cost rate at the analytic optimum ($/s).
    pub model_cost_rate: f64,
    /// Achieved average cost rate of the ideal TTL run ($/s).
    pub achieved_cost_rate: f64,
    /// Cost rate the model predicts at the *converged* TTL — flatness of
    /// the optimum means this is the fair comparison.
    pub model_cost_at_converged: f64,
    pub used_artifact: bool,
}

impl IrmReport {
    pub fn render(&self) -> String {
        format!(
            "IRM convergence (Prop. 1 validation)\n\
             \x20 SA converged TTL     {:.0}s\n\
             \x20 analytic optimum T*  {:.0}s  (cost rate ${:.3e}/s, via {})\n\
             \x20 model cost @ SA TTL  ${:.3e}/s  (excess {:+.1}%)\n\
             \x20 achieved cost rate   ${:.3e}/s\n",
            self.converged_ttl_secs,
            self.t_star_secs,
            self.model_cost_rate,
            if self.used_artifact { "PJRT artifact" } else { "rust oracle" },
            self.model_cost_at_converged,
            100.0 * (self.model_cost_at_converged / self.model_cost_rate.max(1e-30) - 1.0),
            self.achieved_cost_rate,
        )
    }

    /// Excess of the SA-converged operating point over the model optimum.
    pub fn excess_cost(&self) -> f64 {
        self.model_cost_at_converged / self.model_cost_rate.max(1e-30) - 1.0
    }
}

pub fn run_irm_convergence(ctx: &ExpContext, irm: &IrmConfig) -> Result<IrmReport> {
    // 1) Run the ideal TTL cache with the SA controller on IRM traffic —
    //    through the engine's vertical mode, like every other policy.
    let mut cfg = ctx.cfg.clone();
    cfg.scaler.policy = PolicyKind::IdealTtl;
    let trace = IrmGenerator::new(irm.clone()).generate();
    let mut src = crate::trace::VecSource::new(trace.clone());
    let result = run(&cfg, &mut src);

    let samples = result.ttl_series.samples();
    let tail = &samples[samples.len() * 3 / 4..];
    let converged_ttl_secs = if tail.is_empty() {
        result.ttl_series.mean().unwrap_or(0.0)
    } else {
        tail.iter().map(|&(_, v)| v).sum::<f64>() / tail.len() as f64
    };

    // 2) Analytic optimum from the exact per-rank rates (we know the
    //    generator's λ_i — this is the theory check, not an estimate).
    let planner = Planner::load(crate::runtime::artifacts_dir(), cfg.controller.t_max_secs);
    let n = planner.n_buckets();
    let epoch_secs = crate::us_to_secs(irm.duration);
    let items: Vec<(u32, u32)> = (1..=irm.catalogue)
        .map(|rank| {
            let lam = irm.lambda_of_rank(rank);
            let size = crate::trace::object_size(rank, irm.seed) as u32;
            (((lam * epoch_secs).round() as u32).max(1), size)
        })
        .collect();
    let stats = BucketedStats::build(&items, n, epoch_secs, &cfg.cost);
    let curves = planner.curves(&stats)?;
    let i_star = curves.argmin_cost();
    let t_star_secs = curves.t_grid[i_star] as f64;
    let model_cost_rate = curves.cost[i_star] as f64;

    // Model cost at the SA-converged TTL (nearest grid point).
    let i_conv = curves
        .t_grid
        .iter()
        .enumerate()
        .min_by(|a, b| {
            (*a.1 as f64 - converged_ttl_secs)
                .abs()
                .partial_cmp(&(*b.1 as f64 - converged_ttl_secs).abs())
                .unwrap()
        })
        .map(|(i, _)| i)
        .unwrap_or(0);
    let model_cost_at_converged = curves.cost[i_conv] as f64;

    let achieved_cost_rate = result.total_cost / epoch_secs.max(1.0);

    // CSV: the model curve + the SA trajectory.
    let curve_rows: Vec<Vec<String>> = curves
        .t_grid
        .iter()
        .zip(&curves.cost)
        .map(|(&t, &c)| vec![format!("{t:.2}"), format!("{c:.6e}")])
        .collect();
    ctx.write_csv("irm_cost_curve.csv", &["t_secs", "cost_rate"], &curve_rows)?;
    ctx.write_csv(
        "irm_ttl_trajectory.csv",
        &["t_secs", "ttl_secs"],
        &result.ttl_series.csv_rows(),
    )?;

    Ok(IrmReport {
        converged_ttl_secs,
        t_star_secs,
        model_cost_rate,
        achieved_cost_rate,
        model_cost_at_converged,
        used_artifact: planner.uses_artifact(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn sa_settles_near_model_optimum() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let irm = IrmConfig {
            catalogue: 5_000,
            alpha: 0.9,
            total_rate: 300.0,
            duration: 4 * crate::HOUR,
            seed: 3,
        };
        let rep = run_irm_convergence(&ctx, &irm).unwrap();
        // The cost curve near the optimum is flat; require the operating
        // point to be within 25% of the optimal *cost* (not T itself).
        assert!(
            rep.excess_cost() < 0.25,
            "excess={:.3} (T_sa={:.0}s T*={:.0}s)",
            rep.excess_cost(),
            rep.converged_ttl_secs,
            rep.t_star_secs
        );
        assert!(rep.converged_ttl_secs > 0.0);
    }
}
