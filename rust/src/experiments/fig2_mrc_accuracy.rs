//! Fig. 2 — accuracy of approximate MRC computation through sampling,
//! uniform vs. heterogeneous object sizes (§3).
//!
//! Paper: with uniform sizes the SHARDS-style estimator keeps the mean
//! absolute error below 3·10⁻³ for sampling rates 1e-3..1e-1; with real
//! (heterogeneous) sizes the error grows by an order of magnitude at the
//! same rate, and reaching a target error can require ~100× the sampling.

use super::ExpContext;
use crate::mrc::{MrcProfiler, OlkenProfiler, ShardsMode, ShardsProfiler};
use crate::Result;

/// One (rate, mode) error measurement.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyPoint {
    pub rate: f64,
    /// Control: uniform-size traffic profiled by the published scheme.
    pub uniform_error: f64,
    /// Treatment: the published (uniform-assumption) scheme applied to
    /// heterogeneous-size traffic — the paper's order-of-magnitude blowup.
    pub sized_error: f64,
    /// The byte-weighted sampling extension (reference point; §3 argues it
    /// is not obviously sound, and it still trails the exact profiler).
    pub sized_ext_error: f64,
}

#[derive(Debug)]
pub struct Fig2Report {
    pub points: Vec<AccuracyPoint>,
}

impl Fig2Report {
    pub fn render(&self) -> String {
        let mut s =
            String::from("Fig.2 — approximate MRC error vs sampling rate\n");
        s.push_str("  rate      uniform-err   sized-err    ratio   (byte-ext-err)\n");
        for p in &self.points {
            s.push_str(&format!(
                "  {:<9.4} {:<13.5} {:<12.5} {:<7.1} {:.5}\n",
                p.rate,
                p.uniform_error,
                p.sized_error,
                p.sized_error / p.uniform_error.max(1e-12),
                p.sized_ext_error,
            ));
        }
        s.push_str("  paper shape: sized-err ≈ 10x uniform-err at equal rates\n");
        s
    }

    /// Geometric-mean error ratio across rates.
    pub fn mean_ratio(&self) -> f64 {
        let logs: f64 = self
            .points
            .iter()
            .map(|p| (p.sized_error.max(1e-12) / p.uniform_error.max(1e-12)).ln())
            .sum();
        (logs / self.points.len().max(1) as f64).exp()
    }
}

/// Run Fig. 2 over (a prefix of) the context trace.
pub fn run_fig2(ctx: &ExpContext, max_requests: usize, rates: &[f64]) -> Result<Fig2Report> {
    let trace = &ctx.trace[..ctx.trace.len().min(max_requests)];
    let max_bytes: u64 = 1 << 38;

    // Exact references, computed once. Base 1.05 keeps histogram
    // quantization well below the sampling/assumption errors under study.
    const BASE: f64 = 1.05;
    let mut exact_uniform = OlkenProfiler::new(1 << 26, BASE, true);
    let mut exact_sized = OlkenProfiler::new(max_bytes, BASE, false);
    for r in trace {
        exact_uniform.record(r.obj, 1);
        exact_sized.record(r.obj, r.size_bytes());
    }
    let ref_uniform = exact_uniform.curve();
    let ref_sized = exact_sized.curve();

    // "Meaningful cache sizes" (the paper's error metric): sizes a real
    // deployment would provision — we use [hi/1024, hi], excluding the
    // degenerate head of the curve where a handful of sampled objects
    // dominates and both estimators are pure noise.
    let stats = crate::trace::characterize(trace);
    let obj_hi = stats.distinct_objects.max(2);
    let obj_lo = (obj_hi / 1024).max(8);
    let byte_hi = stats.footprint_bytes.max(2);
    let byte_lo = (byte_hi / 1024).max(1 << 12);

    let mut points = Vec::new();
    for &rate in rates {
        let mut su = ShardsProfiler::with_base(rate, 1 << 26, ShardsMode::Uniform, 77, BASE);
        let mut sa = ShardsProfiler::with_base(rate, max_bytes, ShardsMode::UniformAssumed, 77, BASE);
        let mut ss = ShardsProfiler::with_base(rate, max_bytes, ShardsMode::Sized, 77, BASE);
        for r in trace {
            su.record(r.obj, 1);
            sa.record(r.obj, r.size_bytes());
            ss.record(r.obj, r.size_bytes());
        }
        let uniform_error = ref_uniform.mean_abs_error(&su.curve(), obj_lo, obj_hi);
        let sized_error = ref_sized.mean_abs_error(&sa.curve(), byte_lo, byte_hi);
        let sized_ext_error = ref_sized.mean_abs_error(&ss.curve(), byte_lo, byte_hi);
        points.push(AccuracyPoint { rate, uniform_error, sized_error, sized_ext_error });
    }

    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                format!("{:.5}", p.rate),
                format!("{:.6}", p.uniform_error),
                format!("{:.6}", p.sized_error),
                format!("{:.6}", p.sized_ext_error),
            ]
        })
        .collect();
    ctx.write_csv(
        "fig2_mrc_accuracy.csv",
        &["sampling_rate", "uniform_error", "sized_error", "sized_ext_error"],
        &rows,
    )?;
    Ok(Fig2Report { points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn heterogeneous_sizes_degrade_accuracy() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        // The smoke trace has ~5e4 distinct objects: rates below ~5e-2
        // sample too few objects for ANY estimator, so this unit test uses
        // rates that give the uniform arm a fair shot (the CLI experiment
        // sweeps the paper's full 1e-3..1e-1 range at larger scales).
        //
        // Magnitude note (EXPERIMENTS.md §Fig.2): the paper's 10x blowup
        // needs Akamai-scale size heterogeneity (bytes → tens of MB across
        // 1e8 objects). At smoke scale we require the same *shape*: the
        // heterogeneous arm strictly worse at every rate, and a systematic
        // error floor that persists at rate 1.0 where the uniform arm's
        // error is exactly zero (ratio → ∞).
        let rep = run_fig2(&ctx, 400_000, &[0.05, 0.2, 1.0]).unwrap();
        assert_eq!(rep.points.len(), 3);
        for p in &rep.points {
            assert!(
                p.sized_error > p.uniform_error,
                "rate={}: sized {} must exceed uniform {}",
                p.rate,
                p.sized_error,
                p.uniform_error
            );
        }
        // Rate 1.0 isolates the uniform-size-assumption penalty: no
        // sampling noise, uniform arm exact, sized arm systematically off.
        let full = rep.points.last().unwrap();
        assert!(full.uniform_error < 1e-9, "uniform@1.0={}", full.uniform_error);
        assert!(full.sized_error > 1e-3, "sized@1.0={}", full.sized_error);
        // …while the byte-weighted extension is exact at rate 1.0.
        assert!(full.sized_ext_error < 1e-9);
        // Aggregate inflation across rates (geometric mean; diverges with
        // the rate-1.0 point included).
        assert!(rep.mean_ratio() > 2.0, "ratio={}", rep.mean_ratio());
        // Errors shrink as the rate grows (both arms).
        assert!(rep.points[1].uniform_error <= rep.points[0].uniform_error + 5e-3);
    }
}
