//! fig15 — the admission-policy frontier: cache-on-Mth-request and
//! cost-based keep/drop filters swept against the dynamic-TTL baseline
//! over the storm / churn / one-hit-wonder scenario zoo.
//!
//! Shape target: on a heavy-one-hit-wonder trace the Mth-request filter
//! (swept-best M) is *strictly cheaper in total dollars* than the same
//! dynamic-TTL policy admitting every miss. The sizing path is
//! identical in every variant — the filter gates only the physical
//! insert — so the whole saving shows up as miss dollars: wonders stop
//! evicting the popular core out of the capacity-clamped cluster.

use super::calibrate_miss_cost;
use crate::config::{AdmissionKind, Config, PolicyKind};
use crate::engine::run;
use crate::trace::{Request, VecSource};
use crate::util::rng::Pcg;
use crate::{Result, HOUR};
use std::path::Path;

/// Every request in the zoo is one fixed-size object: the storage-vs-
/// miss arithmetic stays legible and the popular core's byte footprint
/// is exactly `core × OBJ_BYTES`.
const OBJ_BYTES: u32 = 100_000;
/// Scenario length in billing epochs (hours).
const EPOCHS: u64 = 8;
/// Popular-core size: 600 × 100 KB = 60 MB, sized to fit the clamped
/// 4 × 20 MB cluster *only if* the wonder flood is kept out of it.
const CORE_KEYS: u64 = 600;

/// One policy-variant outcome on one scenario.
#[derive(Debug, Clone)]
pub struct Fig15Row {
    pub scenario: &'static str,
    pub variant: String,
    pub storage_dollars: f64,
    pub miss_dollars: f64,
    pub total_dollars: f64,
    pub miss_ratio: f64,
}

/// The full sweep: every (scenario × variant) row.
#[derive(Debug)]
pub struct Fig15Report {
    pub rows: Vec<Fig15Row>,
}

impl Fig15Report {
    /// The `filter = none` dynamic-TTL baseline row of a scenario.
    pub fn baseline(&self, scenario: &str) -> &Fig15Row {
        self.rows
            .iter()
            .find(|r| r.scenario == scenario && r.variant == "none")
            .expect("every scenario runs the baseline")
    }

    /// The cheapest row of a scenario whose variant starts with `prefix`
    /// (`"mth"` sweeps M, `"keep"` sweeps the threshold).
    pub fn best(&self, scenario: &str, prefix: &str) -> &Fig15Row {
        self.rows
            .iter()
            .filter(|r| r.scenario == scenario && r.variant.starts_with(prefix))
            .min_by(|a, b| a.total_dollars.total_cmp(&b.total_dollars))
            .expect("every scenario runs the sweep")
    }

    /// Saving of the swept-best `prefix` variant vs the baseline
    /// (positive = the filter is cheaper).
    pub fn saving(&self, scenario: &str, prefix: &str) -> f64 {
        1.0 - self.best(scenario, prefix).total_dollars
            / self.baseline(scenario).total_dollars.max(1e-12)
    }

    pub fn render(&self) -> String {
        let mut s = String::from(
            "Fig.15 — admission filters vs the dynamic-TTL baseline\n\
             \x20 scenario        variant   storage$   miss$      total$     miss%\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "  {:<15} {:<9} {:<10.4} {:<10.4} {:<10.4} {:.4}\n",
                r.scenario,
                r.variant,
                r.storage_dollars,
                r.miss_dollars,
                r.total_dollars,
                r.miss_ratio,
            ));
        }
        for sc in ["one_hit_wonder", "storm", "churn"] {
            s.push_str(&format!(
                "  {sc}: best mth {} saves {:+.1}%, best keep {} saves {:+.1}% vs baseline\n",
                self.best(sc, "mth").variant,
                100.0 * self.saving(sc, "mth"),
                self.best(sc, "keep").variant,
                100.0 * self.saving(sc, "keep"),
            ));
        }
        s
    }
}

/// The zoo's shared config: a deliberately capacity-clamped elastic
/// cluster (4 × 20 MB at the paper's per-byte price) so an unfiltered
/// wonder flood *must* evict the popular core.
fn fig15_config() -> Config {
    let mut cfg = Config::with_policy(PolicyKind::Ttl);
    cfg.cost.instance.ram_bytes = 20_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 20.0e6 / 555.0e6;
    cfg.scaler.max_instances = 4;
    // 1 MB sketch = 2M nibble counters: keeps the per-epoch wonder volume
    // well under one bump per counter, so collision false-admits stay in
    // the low percent range instead of saturating the default 32 KB table.
    cfg.admission.sketch_bytes = 1 << 20;
    cfg
}

/// Heavy one-hit-wonder mix: `wonder_frac` of the requests touch a key
/// that never recurs; the rest hit the uniform popular core.
fn wonder_trace(seed: u64, n: u64, wonder_frac: f64) -> Vec<Request> {
    let mut rng = Pcg::seed_from_u64(seed);
    let span = EPOCHS * HOUR;
    let mut next_unique = 1u64 << 32;
    let mut out = Vec::with_capacity(n as usize);
    for i in 0..n {
        let obj = if rng.chance(wonder_frac) {
            next_unique += 1;
            next_unique
        } else {
            rng.below(CORE_KEYS)
        };
        out.push(Request::new(i * span / n, obj, OBJ_BYTES));
    }
    out
}

/// Insert storm: calm popular-core traffic, then epochs 3–4 flood 90%
/// wonders (the PR3 storm scenario re-cast as an admission problem).
fn storm_trace(seed: u64, n: u64) -> Vec<Request> {
    let mut rng = Pcg::seed_from_u64(seed);
    let span = EPOCHS * HOUR;
    let mut next_unique = 2u64 << 32;
    let mut out = Vec::with_capacity(n as usize);
    for i in 0..n {
        let ts = i * span / n;
        let epoch = ts / HOUR;
        let frac = if (3..5).contains(&epoch) { 0.9 } else { 0.1 };
        let obj = if rng.chance(frac) {
            next_unique += 1;
            next_unique
        } else {
            rng.below(CORE_KEYS)
        };
        out.push(Request::new(ts, obj, OBJ_BYTES));
    }
    out
}

/// Catalogue churn: the popular core rotates wholesale every two
/// epochs (stressing the sketch's epoch-boundary aging), with a 20%
/// wonder stream on top.
fn churn_trace(seed: u64, n: u64) -> Vec<Request> {
    let mut rng = Pcg::seed_from_u64(seed);
    let span = EPOCHS * HOUR;
    let mut next_unique = 3u64 << 32;
    let mut out = Vec::with_capacity(n as usize);
    for i in 0..n {
        let ts = i * span / n;
        let generation = ts / (2 * HOUR);
        let obj = if rng.chance(0.2) {
            next_unique += 1;
            next_unique
        } else {
            (1 + generation) * 1_000_000 + rng.below(CORE_KEYS)
        };
        out.push(Request::new(ts, obj, OBJ_BYTES));
    }
    out
}

fn run_variant(
    cfg: &Config,
    trace: &[Request],
    scenario: &'static str,
    variant: String,
    filter: AdmissionKind,
    m: u32,
    keep_threshold: f64,
) -> Fig15Row {
    let mut cfg = cfg.clone();
    cfg.admission.filter = filter;
    cfg.admission.m = m;
    cfg.admission.keep_threshold = keep_threshold;
    let rep = run(&cfg, &mut VecSource::new(trace.to_vec()));
    Fig15Row {
        scenario,
        variant,
        storage_dollars: rep.storage_cost,
        miss_dollars: rep.miss_cost,
        total_dollars: rep.total_cost,
        miss_ratio: rep.miss_ratio(),
    }
}

/// Run the full sweep at `n` requests per scenario, writing
/// `fig15_admission.csv` under `out_dir`.
pub fn run_fig15(n: u64, out_dir: impl AsRef<Path>) -> Result<Fig15Report> {
    let out_dir = out_dir.as_ref();
    std::fs::create_dir_all(out_dir).ok();
    let scenarios: [(&'static str, Vec<Request>); 3] = [
        ("one_hit_wonder", wonder_trace(0x15AD_0001, n, 0.7)),
        ("storm", storm_trace(0x15AD_0002, n)),
        ("churn", churn_trace(0x15AD_0003, n)),
    ];
    let mut rows = Vec::new();
    for (name, trace) in &scenarios {
        let (name, trace) = (*name, trace.as_slice());
        let mut cfg = fig15_config();
        // §6.1 balance-point rule against this scenario's own volume, so
        // miss and storage dollars are comparable components.
        cfg.cost.miss_cost_dollars = calibrate_miss_cost(&cfg, trace, 4);
        rows.push(run_variant(&cfg, trace, name, "none".into(), AdmissionKind::None, 2, 1.0));
        for m in [2u32, 3, 4] {
            rows.push(run_variant(
                &cfg,
                trace,
                name,
                format!("mth_m{m}"),
                AdmissionKind::MthRequest,
                m,
                1.0,
            ));
        }
        for thr in [0.5f64, 1.0, 2.0] {
            rows.push(run_variant(
                &cfg,
                trace,
                name,
                format!("keep_t{thr}"),
                AdmissionKind::KeepCost,
                2,
                thr,
            ));
        }
    }
    let report = Fig15Report { rows };
    let csv_rows: Vec<Vec<String>> = report
        .rows
        .iter()
        .map(|r| {
            vec![
                r.scenario.to_string(),
                r.variant.clone(),
                format!("{:.6}", r.storage_dollars),
                format!("{:.6}", r.miss_dollars),
                format!("{:.6}", r.total_dollars),
                format!("{:.6}", r.miss_ratio),
            ]
        })
        .collect();
    crate::metrics::write_csv(
        out_dir.join("fig15_admission.csv"),
        &["scenario", "variant", "storage_usd", "miss_usd", "total_usd", "miss_ratio"],
        &csv_rows,
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mth_request_beats_the_dynamic_ttl_baseline_on_wonders() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let rep = run_fig15(120_000, dir.path()).unwrap();
        // The acceptance shape: swept-best M strictly cheaper than the
        // admit-everything dynamic-TTL baseline on the wonder trace.
        let base = rep.baseline("one_hit_wonder");
        let best = rep.best("one_hit_wonder", "mth");
        assert!(
            best.total_dollars < base.total_dollars,
            "mth {:.6} must beat baseline {:.6}",
            best.total_dollars,
            base.total_dollars
        );
        // The saving is miss dollars: the sizing path (and so the
        // storage bill) is identical by construction.
        assert!(
            (best.storage_dollars - base.storage_dollars).abs()
                <= 1e-9 * base.storage_dollars.max(1.0),
            "storage must not move: {} vs {}",
            best.storage_dollars,
            base.storage_dollars
        );
        assert!(best.miss_ratio < base.miss_ratio);
        assert!(dir.path().join("fig15_admission.csv").exists());
        // Every scenario ran the full 7-variant sweep.
        assert_eq!(rep.rows.len(), 21);
    }
}
