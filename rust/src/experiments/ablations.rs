//! Ablations for the design claims §6.2 makes beyond the headline:
//!
//! * **Billing granularity** — "there is no need for finer-grained
//!   billing periods …, most of the potential improvement is already
//!   achievable with the current offer": sweep the epoch length and show
//!   the TTL policy's total cost is nearly flat.
//! * **Instance granularity** — §6.1 argues for small instances ("fine
//!   granularity when we resize the cluster"): sweep the node size at
//!   constant per-byte price.
//! * **Per-content TTLs** (§7 future work): the forecast-based policy of
//!   [`crate::vcache::per_content`] vs the global-TTL system and the
//!   TTL-OPT bound — quantifying how much of the 66% head-room a simple
//!   forecast recovers.
//! * **Gain schedule** — constant vs Robbins–Monro vs auto-scaled
//!   (Proposition 1's convergence knob).

use super::ExpContext;
use crate::config::{GainSchedule, PolicyKind};
use crate::engine::run;
use crate::trace::VecSource;
use crate::vcache::{run_per_content, PerContentConfig};
use crate::Result;

#[derive(Debug)]
pub struct AblationReport {
    pub rows: Vec<(String, f64, f64, f64)>, // label, storage, miss, total
    pub title: String,
    pub note: String,
}

impl AblationReport {
    pub fn render(&self) -> String {
        let mut s = format!("Ablation — {}\n", self.title);
        s.push_str("  variant                    storage$   miss$      total$\n");
        let base = self.rows.first().map(|r| r.3).unwrap_or(1.0);
        for (label, st, mi, tot) in &self.rows {
            s.push_str(&format!(
                "  {:<26} {:<10.4} {:<10.4} {:<8.4} ({:+.1}%)\n",
                label,
                st,
                mi,
                tot,
                100.0 * (tot / base - 1.0)
            ));
        }
        s.push_str(&format!("  {}\n", self.note));
        s
    }
}

/// Epoch-length sweep under the TTL policy.
pub fn run_epoch_ablation(ctx: &ExpContext) -> Result<AblationReport> {
    let mut rows = Vec::new();
    for (label, epoch_us) in [
        ("epoch 60 min (paper)", crate::HOUR),
        ("epoch 30 min", 30 * crate::MINUTE),
        ("epoch 15 min", 15 * crate::MINUTE),
        ("epoch 120 min", 2 * crate::HOUR),
    ] {
        let mut cfg = ctx.cfg.clone();
        cfg.scaler.policy = PolicyKind::Ttl;
        cfg.cost.epoch_us = epoch_us;
        let res = run(&cfg, &mut VecSource::new(ctx.trace.clone()));
        rows.push((label.to_string(), res.storage_cost, res.miss_cost, res.total_cost));
    }
    let report = AblationReport {
        rows,
        title: "billing-epoch granularity (TTL policy)".into(),
        note: "paper claim: finer billing buys little — totals should be nearly flat".into(),
    };
    ctx.write_csv(
        "ablation_epoch.csv",
        &["variant", "storage_usd", "miss_usd", "total_usd"],
        &report
            .rows
            .iter()
            .map(|(l, s, m, t)| vec![l.clone(), format!("{s:.5}"), format!("{m:.5}"), format!("{t:.5}")])
            .collect::<Vec<_>>(),
    )?;
    Ok(report)
}

/// Instance-size sweep at constant per-byte price.
pub fn run_instance_ablation(ctx: &ExpContext) -> Result<AblationReport> {
    let base_ram = ctx.cfg.cost.instance.ram_bytes;
    let per_byte_hour = ctx.cfg.cost.instance.dollars_per_hour / base_ram as f64;
    let mut rows = Vec::new();
    for (label, factor) in [
        ("1x node (baseline)", 1.0f64),
        ("1/2x node", 0.5),
        ("2x node", 2.0),
        ("4x node", 4.0),
    ] {
        let mut cfg = ctx.cfg.clone();
        cfg.scaler.policy = PolicyKind::Ttl;
        cfg.cost.instance.ram_bytes = (base_ram as f64 * factor) as u64;
        cfg.cost.instance.dollars_per_hour =
            cfg.cost.instance.ram_bytes as f64 * per_byte_hour;
        let res = run(&cfg, &mut VecSource::new(ctx.trace.clone()));
        rows.push((label.to_string(), res.storage_cost, res.miss_cost, res.total_cost));
    }
    let report = AblationReport {
        rows,
        title: "instance granularity at constant per-byte price (TTL policy)".into(),
        note: "paper §6.1: small nodes give finer sizing; big nodes over-provision".into(),
    };
    ctx.write_csv(
        "ablation_instance.csv",
        &["variant", "storage_usd", "miss_usd", "total_usd"],
        &report
            .rows
            .iter()
            .map(|(l, s, m, t)| vec![l.clone(), format!("{s:.5}"), format!("{m:.5}"), format!("{t:.5}")])
            .collect::<Vec<_>>(),
    )?;
    Ok(report)
}

/// Per-content TTL (§7) vs the global-TTL ideal cache vs TTL-OPT.
pub fn run_per_content_ablation(ctx: &ExpContext) -> Result<AblationReport> {
    let mut cfg = ctx.cfg.clone();
    cfg.scaler.policy = PolicyKind::IdealTtl;
    let global = run(&cfg, &mut ctx.source());
    let pc = run_per_content(&PerContentConfig::default(), &ctx.cfg.cost, &ctx.trace);
    let opt = crate::ttlopt::solve(&ctx.trace, &ctx.cfg.cost);

    let rows = vec![
        (
            "global TTL (ideal bill)".to_string(),
            global.storage_cost,
            global.miss_cost,
            global.total_cost,
        ),
        (
            "per-content TTL (forecast)".to_string(),
            pc.storage_cost,
            pc.miss_cost,
            pc.total_cost,
        ),
        (
            "TTL-OPT (clairvoyant)".to_string(),
            opt.storage_cost,
            opt.miss_cost,
            opt.total_cost,
        ),
    ];
    let recovered = if global.total_cost > opt.total_cost {
        (global.total_cost - pc.total_cost) / (global.total_cost - opt.total_cost)
    } else {
        0.0
    };
    let report = AblationReport {
        rows,
        title: "per-content TTLs (§7 future work)".into(),
        note: format!(
            "forecast policy recovers {:.0}% of the global→OPT head-room",
            100.0 * recovered
        ),
    };
    ctx.write_csv(
        "ablation_per_content.csv",
        &["variant", "storage_usd", "miss_usd", "total_usd"],
        &report
            .rows
            .iter()
            .map(|(l, s, m, t)| vec![l.clone(), format!("{s:.5}"), format!("{m:.5}"), format!("{t:.5}")])
            .collect::<Vec<_>>(),
    )?;
    Ok(report)
}

/// Gain-schedule sweep on the ideal TTL cache.
pub fn run_gain_ablation(ctx: &ExpContext) -> Result<AblationReport> {
    let mut rows = Vec::new();
    let variants: Vec<(&str, Box<dyn Fn(&mut crate::config::Config)>)> = vec![
        ("auto-scaled (default)", Box::new(|_c| {})),
        (
            "auto-scaled, RM decay",
            Box::new(|c| {
                c.controller.gain = GainSchedule::Polynomial { eps0: 1.0, exponent: 0.6 }
            }),
        ),
        (
            "plain eq.7, eps 5e9",
            Box::new(|c| {
                c.controller.normalized = false;
                c.controller.gain = GainSchedule::Constant { eps0: 5.0e9 };
            }),
        ),
        (
            "plain eq.7, eps 5e10",
            Box::new(|c| {
                c.controller.normalized = false;
                c.controller.gain = GainSchedule::Constant { eps0: 5.0e10 };
            }),
        ),
    ];
    for (label, mutate) in variants {
        let mut cfg = ctx.cfg.clone();
        cfg.scaler.policy = PolicyKind::IdealTtl;
        mutate(&mut cfg);
        let res = run(&cfg, &mut ctx.source());
        rows.push((label.to_string(), res.storage_cost, res.miss_cost, res.total_cost));
    }
    let report = AblationReport {
        rows,
        title: "controller gain schedule (ideal TTL cache)".into(),
        note: "auto-scaled gain needs no per-catalog eps0 tuning; fixed eps0 is scale-sensitive".into(),
    };
    ctx.write_csv(
        "ablation_gain.csv",
        &["variant", "storage_usd", "miss_usd", "total_usd"],
        &report
            .rows
            .iter()
            .map(|(l, s, m, t)| vec![l.clone(), format!("{s:.5}"), format!("{m:.5}"), format!("{t:.5}")])
            .collect::<Vec<_>>(),
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    fn ctx() -> (crate::util::tempdir::TempDir, ExpContext) {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        (dir, ctx)
    }

    #[test]
    fn epoch_granularity_is_nearly_flat() {
        let (_d, ctx) = ctx();
        let rep = run_epoch_ablation(&ctx).unwrap();
        assert_eq!(rep.rows.len(), 4);
        let base = rep.rows[0].3;
        for (label, _, _, total) in &rep.rows {
            let rel = (total / base - 1.0).abs();
            // §6.2's claim: granularity changes move the needle by little
            // (smoke tolerance: 15%).
            assert!(rel < 0.15, "{label}: {rel:+.3} vs 1h epoch");
        }
    }

    #[test]
    fn per_content_recovers_headroom() {
        let (_d, ctx) = ctx();
        let rep = run_per_content_ablation(&ctx).unwrap();
        let global = rep.rows[0].3;
        let pc = rep.rows[1].3;
        let opt = rep.rows[2].3;
        assert!(opt < pc, "OPT must lower-bound the forecast policy");
        assert!(
            pc < global,
            "per-content {pc} should beat global {global} (paper §7)"
        );
    }

    #[test]
    fn bigger_instances_cost_more() {
        let (_d, ctx) = ctx();
        let rep = run_instance_ablation(&ctx).unwrap();
        let base = rep.rows[0].3;
        let big4 = rep.rows[3].3;
        // 4x nodes quantize the cluster coarsely → over-provisioning.
        assert!(
            big4 > base * 0.98,
            "4x node unexpectedly cheaper: {big4} vs {base}"
        );
    }
}
