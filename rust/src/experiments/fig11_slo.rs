//! Fig. 11 (ours, beyond the paper) — per-tenant SLO enforcement on one
//! shared elastic cluster: closing the loop from the arbiter's grants to
//! the request path.
//!
//! Scenario: a *gold* tenant whose misses cost 10× (think: each miss
//! re-runs an expensive backend query) shares the cluster with a cheap
//! *flood* tenant. Midway through the run the flood tenant's load spikes
//! by ~2 orders of magnitude with a huge, barely-reusable catalogue — the
//! classic noisy-neighbour scan that evicts everyone else's working set.
//!
//! Two runs over the identical trace:
//!
//! * **enforced** — `scaler.enforce_grants = true`: each epoch the
//!   arbiter's grants become per-tenant occupancy caps (admission byte
//!   budgets on the balancer) and TTL clamps on the controller bank, and
//!   the gold tenant's measured miss ratio feeds back into its grant
//!   priority while it exceeds its configured `slo_miss_ratio`.
//! * **baseline** — the same config with enforcement off: grants are
//!   reported but nothing binds, exactly the pre-enforcement system.
//!
//! Expected shape (asserted by the smoke test): during the spike the gold
//! tenant's per-epoch miss ratio stays at or below its SLO in the
//! enforced run, while the unenforced baseline blows through it — the
//! flood tenant's inserts churn the shared LRU instances out from under
//! the gold working set. The SLO target itself is derived from the data
//! (3× the gold tenant's uncontended miss ratio, floored/capped to
//! [0.05, 0.5]) so the experiment is self-calibrating across scales.
//!
//! Measurement starts one epoch after the spike onset: enforcement is
//! epoch-granular, so the first spike epoch runs under the pre-spike
//! grants (the honest reaction latency of the scheme).

use super::{calibrate_miss_cost, ExpContext, TraceScale};
use crate::config::{Config, PolicyKind};
use crate::engine::{run, RunReport, SloSample};
use crate::tenant::{TenantSpec, TrafficClass};
use crate::trace::{Request, SynthConfig, SynthGenerator, VecSource};
use crate::{Result, TimeUs, DAY, HOUR};

/// Gold tenant id (10× miss cost, SLO-tracked).
pub const GOLD: u16 = 0;
/// Flood tenant id (cheap, best-effort).
pub const FLOOD: u16 = 1;

/// Uniform object size: keeps the working-set arithmetic of the scenario
/// deterministic instead of being dominated by a handful of lognormal
/// 5 MB outliers.
const OBJ_BYTES: u32 = 100_000;

/// Spike window within the 2-day trace (shared with fig12's placement
/// study, which replays the same storm).
pub(super) const SPIKE_START: TimeUs = 18 * HOUR;
pub(super) const SPIKE_END: TimeUs = 30 * HOUR;

/// Fig. 11 report.
#[derive(Debug)]
pub struct Fig11Report {
    /// Derived miss-ratio SLO for the gold tenant.
    pub slo_target: f64,
    /// Gold tenant's uncontended (solo-run) miss ratio.
    pub clean_miss_ratio: f64,
    pub spike_start: TimeUs,
    pub spike_end: TimeUs,
    /// Worst gold per-epoch miss ratio inside the measurement window.
    pub enforced_worst: f64,
    pub baseline_worst: f64,
    pub enforced: RunReport,
    pub baseline: RunReport,
}

impl Fig11Report {
    /// Gold samples inside the measurement window (one epoch of reaction
    /// latency after the spike onset, through the spike end).
    pub fn window<'a>(&self, report: &'a RunReport) -> Vec<&'a SloSample> {
        report
            .slo
            .iter()
            .filter(|s| {
                s.tenant == GOLD && s.t > self.spike_start + HOUR && s.t <= self.spike_end
            })
            .collect()
    }

    fn tenant_row(report: &RunReport, tenant: u16) -> (u64, u64, f64) {
        report
            .tenants
            .iter()
            .find(|t| t.tenant == tenant)
            .map(|t| (t.requests, t.misses, t.miss_dollars))
            .unwrap_or((0, 0, 0.0))
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "Fig.11 — per-tenant SLO enforcement (grants → occupancy caps + TTL clamps)\n\
             \x20 gold SLO (derived, 3× uncontended miss ratio {:.4}): {:.4}\n\
             \x20 spike: hours {:.0}–{:.0}; measurement starts one epoch after onset\n",
            self.clean_miss_ratio,
            self.slo_target,
            crate::us_to_secs(self.spike_start) / 3600.0,
            crate::us_to_secs(self.spike_end) / 3600.0,
        );
        for (name, report, worst) in [
            ("enforced", &self.enforced, self.enforced_worst),
            ("baseline", &self.baseline, self.baseline_worst),
        ] {
            let (greq, gmiss, gusd) = Self::tenant_row(report, GOLD);
            let (freq, fmiss, _) = Self::tenant_row(report, FLOOD);
            s.push_str(&format!(
                "  {:<9} gold worst epoch miss%={:.4} ({}) gold misses={}/{} (${:.4}) \
                 flood misses={}/{} total=${:.4}\n",
                name,
                worst,
                if worst <= self.slo_target { "SLO HELD" } else { "SLO VIOLATED" },
                gmiss,
                greq,
                gusd,
                fmiss,
                freq,
                report.total_cost,
            ));
        }
        s.push_str(
            "  expected shape: enforced gold worst ≤ SLO through the spike;\n\
             \x20 the unenforced baseline violates it (shared-LRU interference)\n",
        );
        s
    }
}

/// The fig11 tenant roster.
pub fn fig11_specs(slo: f64) -> Vec<TenantSpec> {
    vec![
        TenantSpec::new(GOLD, "gold")
            .with_multiplier(10.0)
            .with_class(TrafficClass::Interactive)
            .with_reserved_bytes(80 * 1024 * 1024)
            .with_slo_miss_ratio(slo),
        TenantSpec::new(FLOOD, "flood")
            .with_multiplier(1.0)
            .with_class(TrafficClass::Bulk)
            .with_reserved_bytes(40 * 1024 * 1024),
    ]
}

/// Pin every request to the scenario's uniform object size and tag it
/// with `tenant` (shared with fig12/fig13, which replay comparable
/// storms/churn over the same deterministic working-set arithmetic).
pub(super) fn uniform(mut reqs: Vec<Request>, tenant: u16) -> Vec<Request> {
    for r in &mut reqs {
        r.size = OBJ_BYTES;
        r.tenant = tenant;
    }
    reqs
}

pub(super) fn scale_factor(scale: TraceScale) -> f64 {
    match scale {
        TraceScale::Smoke => 1.0,
        TraceScale::Small => 2.0,
        TraceScale::Full => 4.0,
    }
}

/// The gold tenant's steady cacheable workload: small hot catalogue,
/// no churn.
pub(super) fn gold_trace(scale: TraceScale, seed: u64) -> Vec<Request> {
    let f = scale_factor(scale);
    let mut g = SynthConfig::akamai_like();
    g.catalogue = (800.0 * f) as u64;
    g.alpha = 0.9;
    g.mean_rate = 5.0 * f;
    g.diurnal_amplitude = 0.3;
    g.duration = 2 * DAY;
    g.churn_per_day = 0.0;
    g.seed = seed ^ 0x601d;
    uniform(SynthGenerator::new(g).generate(), GOLD)
}

/// The flood tenant: a quiet background scan for the whole run, plus a
/// 12-hour spike of ~80× its quiet volume over a huge cold catalogue.
pub(super) fn flood_trace(scale: TraceScale, seed: u64) -> Vec<Request> {
    let f = scale_factor(scale);
    let mut quiet = SynthConfig::akamai_like();
    quiet.catalogue = (30_000.0 * f) as u64;
    quiet.alpha = 0.8;
    quiet.mean_rate = 0.5 * f;
    quiet.diurnal_amplitude = 0.3;
    quiet.duration = 2 * DAY;
    quiet.churn_per_day = 0.1;
    quiet.seed = seed ^ 0xF100;

    let mut spike = SynthConfig::akamai_like();
    spike.catalogue = (120_000.0 * f) as u64;
    spike.alpha = 0.8;
    spike.mean_rate = 40.0 * f;
    spike.diurnal_amplitude = 0.0;
    spike.duration = SPIKE_END - SPIKE_START;
    spike.churn_per_day = 0.0;
    spike.seed = seed ^ 0x5eed;

    let mut out = uniform(SynthGenerator::new(quiet).generate(), FLOOD);
    let mut burst = uniform(SynthGenerator::new(spike).generate(), FLOOD);
    for r in &mut burst {
        r.ts += SPIKE_START;
    }
    out.extend(burst);
    out
}

/// The shared-cluster config (the tenant roster and `enforce_grants` are
/// filled in per run).
pub(super) fn fig11_cfg(scale: TraceScale) -> Config {
    let f = scale_factor(scale);
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.cost.instance.ram_bytes = (40.0e6 * f) as u64;
    cfg.cost.instance.dollars_per_hour = 0.017 * (40.0e6 * f) / 555.0e6;
    cfg.scaler.max_instances = 6;
    cfg.scaler.min_instances = 1;
    cfg
}

pub fn run_fig11(ctx: &ExpContext, scale: TraceScale) -> Result<Fig11Report> {
    let seed = 0xF16_11;
    let gold = gold_trace(scale, seed);
    let mut trace = gold.clone();
    trace.extend(flood_trace(scale, seed));
    trace.sort_by_key(|r| r.ts);

    // Self-calibration: the gold tenant's uncontended miss ratio under
    // the same enforced config (so self-imposed budget effects are part
    // of the baseline expectation), and the §6.1 balance-point miss cost
    // over the mixed trace's pre-spike prefix.
    let mut cfg = fig11_cfg(scale);
    cfg.cost.miss_cost_dollars = calibrate_miss_cost(&cfg, &trace, 4);
    let mut solo_cfg = cfg.clone();
    solo_cfg.scaler.enforce_grants = true;
    solo_cfg.tenants = vec![fig11_specs(1.0).remove(0)];
    let clean = run(&solo_cfg, &mut VecSource::new(gold));
    let clean_mr = clean.miss_ratio();
    let slo_target = (3.0 * clean_mr).clamp(0.05, 0.5);

    let mut enforced_cfg = cfg.clone();
    enforced_cfg.scaler.enforce_grants = true;
    enforced_cfg.tenants = fig11_specs(slo_target);
    let enforced = run(&enforced_cfg, &mut VecSource::new(trace.clone()));

    let mut baseline_cfg = cfg;
    baseline_cfg.scaler.enforce_grants = false;
    baseline_cfg.tenants = fig11_specs(slo_target);
    let baseline = run(&baseline_cfg, &mut VecSource::new(trace));

    let mut report = Fig11Report {
        slo_target,
        clean_miss_ratio: clean_mr,
        spike_start: SPIKE_START,
        spike_end: SPIKE_END,
        enforced_worst: 0.0,
        baseline_worst: 0.0,
        enforced,
        baseline,
    };
    // One window predicate (`Fig11Report::window`) feeds both the
    // headline numbers and the test's sample inspection.
    let worst = |samples: Vec<&SloSample>| {
        samples.iter().map(|s| s.miss_ratio).fold(0.0, f64::max)
    };
    let enforced_worst = worst(report.window(&report.enforced));
    let baseline_worst = worst(report.window(&report.baseline));
    report.enforced_worst = enforced_worst;
    report.baseline_worst = baseline_worst;

    // CSV artifacts: the full per-epoch SLO series of both runs, plus the
    // headline summary.
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (variant, rep) in [("enforced", &report.enforced), ("baseline", &report.baseline)] {
        for s in &rep.slo {
            rows.push(vec![
                variant.to_string(),
                format!("{:.3}", crate::us_to_secs(s.t) / 3600.0),
                s.tenant.to_string(),
                s.requests.to_string(),
                s.misses.to_string(),
                format!("{:.6}", s.miss_ratio),
                s.slo_miss_ratio.map(|v| format!("{v:.6}")).unwrap_or_default(),
                s.granted_bytes.map(|v| v.to_string()).unwrap_or_default(),
                s.cap_bytes.map(|v| v.to_string()).unwrap_or_default(),
                s.ttl_clamp_secs.map(|v| format!("{v:.3}")).unwrap_or_default(),
                format!("{:.3}", s.boost),
            ]);
        }
    }
    ctx.write_csv(
        "fig11_slo_series.csv",
        &[
            "variant", "hour", "tenant", "requests", "misses", "miss_ratio",
            "slo_miss_ratio", "granted_bytes", "cap_bytes", "ttl_clamp_secs", "boost",
        ],
        &rows,
    )?;
    ctx.write_csv(
        "fig11_summary.csv",
        &["metric", "value"],
        &[
            vec!["slo_target".into(), format!("{:.6}", report.slo_target)],
            vec!["clean_miss_ratio".into(), format!("{:.6}", report.clean_miss_ratio)],
            vec!["enforced_worst".into(), format!("{:.6}", report.enforced_worst)],
            vec!["baseline_worst".into(), format!("{:.6}", report.baseline_worst)],
            vec!["enforced_total_usd".into(), format!("{:.6}", report.enforced.total_cost)],
            vec!["baseline_total_usd".into(), format!("{:.6}", report.baseline.total_cost)],
        ],
    )?;

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enforcement_holds_the_slo_through_the_spike() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig11(&ctx, TraceScale::Smoke).unwrap();

        // Both runs saw the same traffic and produced spike-window
        // measurements.
        assert!(!rep.window(&rep.enforced).is_empty(), "no enforced samples");
        assert!(!rep.window(&rep.baseline).is_empty(), "no baseline samples");
        assert_eq!(rep.enforced.requests, rep.baseline.requests);

        // The headline: the unenforced baseline violates the gold SLO
        // during the cheap tenant's spike; enforcement holds it.
        assert!(
            rep.baseline_worst > rep.slo_target,
            "baseline must violate: worst {} vs slo {}",
            rep.baseline_worst,
            rep.slo_target
        );
        assert!(
            rep.enforced_worst <= rep.slo_target,
            "enforcement must hold the SLO: worst {} vs slo {}",
            rep.enforced_worst,
            rep.slo_target
        );

        // Enforcement visibly engaged: the flood tenant was capped at
        // some point during the enforced run.
        assert!(
            rep.enforced
                .slo
                .iter()
                .any(|s| s.tenant == FLOOD && s.cap_bytes.is_some()),
            "flood tenant was never capped"
        );
        // Artifacts exist.
        assert!(dir.path().join("fig11_slo_series.csv").exists());
        assert!(dir.path().join("fig11_summary.csv").exists());
    }
}
