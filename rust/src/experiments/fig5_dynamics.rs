//! Fig. 5 — the TTL (left) and virtual cache size (right) tracking the
//! diurnal pattern over representative days.

use super::ExpContext;
use crate::config::PolicyKind;
use crate::engine::{run, RunReport};
use crate::Result;

#[derive(Debug)]
pub struct Fig5Report {
    pub result: RunReport,
    /// Peak/trough ratio of the virtual size within each full day.
    pub daily_swings: Vec<f64>,
}

impl Fig5Report {
    pub fn render(&self) -> String {
        let max_vc = self.result.shadow_series.max().unwrap_or(0.0);
        format!(
            "Fig.5 — TTL & virtual-cache-size dynamics\n\
             \x20 ttl samples      {}\n\
             \x20 ttl mean/max     {:.0}s / {:.0}s\n\
             \x20 vcache max       {:.1} MB\n\
             \x20 daily vc swing   {:?}\n\
             \x20 paper shape: both series follow the daily pattern; vc size 0..3.5GB\n",
            self.result.ttl_series.len(),
            self.result.ttl_series.mean().unwrap_or(0.0),
            self.result.ttl_series.max().unwrap_or(0.0),
            max_vc / 1048576.0,
            self.daily_swings
                .iter()
                .map(|x| (x * 10.0).round() / 10.0)
                .collect::<Vec<_>>(),
        )
    }
}

pub fn run_fig5(ctx: &ExpContext) -> Result<Fig5Report> {
    let mut cfg = ctx.cfg.clone();
    cfg.scaler.policy = PolicyKind::Ttl;
    let result = run(&cfg, &mut ctx.source());

    // Daily swing: max/min of the shadow series per full day.
    let mut daily_swings = Vec::new();
    let day = crate::DAY;
    let last = result.shadow_series.last().map(|(t, _)| t).unwrap_or(0);
    let mut d = 0;
    while (d + 1) * day <= last {
        let in_day: Vec<f64> = result
            .shadow_series
            .samples()
            .iter()
            .filter(|&&(t, _)| t >= d * day && t < (d + 1) * day)
            .map(|&(_, v)| v)
            .collect();
        if in_day.len() > 4 {
            let lo = in_day.iter().cloned().fold(f64::INFINITY, f64::min).max(1.0);
            let hi = in_day.iter().cloned().fold(0.0, f64::max);
            daily_swings.push(hi / lo);
        }
        d += 1;
    }

    ctx.write_csv(
        "fig5_ttl.csv",
        &["t_secs", "ttl_secs"],
        &result.ttl_series.csv_rows(),
    )?;
    ctx.write_csv(
        "fig5_vcache_size.csv",
        &["t_secs", "bytes"],
        &result.shadow_series.csv_rows(),
    )?;
    Ok(Fig5Report { result, daily_swings })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn ttl_and_size_track_diurnal_load() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig5(&ctx).unwrap();
        assert!(rep.result.ttl_series.len() > 10);
        assert!(rep.result.shadow_series.max().unwrap() > 0.0);
        // The virtual size must swing within the day (diurnal amplitude
        // 0.75 → load varies ~7x peak/trough; require ≥1.5x swing).
        assert!(!rep.daily_swings.is_empty());
        assert!(
            rep.daily_swings.iter().cloned().fold(0.0, f64::max) > 1.5,
            "swings={:?}",
            rep.daily_swings
        );
    }
}
