//! Fig. 14 (ours, beyond the paper) — the observability study: replay the
//! fig11 SLO-spike scenario with `[telemetry] enabled` and show that the
//! epoch decision journal pinpoints the exact epoch *and* causal decision
//! (grant squeeze vs TTL clamp vs shed) behind the gold tenant's
//! miss-ratio excursion.
//!
//! Fig11 proves enforcement holds the SLO; this experiment proves an
//! operator can find out *why* an excursion happened without re-running
//! anything: the journal record at the boundary that governed the worst
//! window epoch names the corrective decision taken against each tenant
//! (`TenantDecision::cause`), and the registry snapshot ships the run's
//! counters/timers as a flat CSV next to it. The same records answer the
//! live `WHY <tenant>` serve command.

use super::fig11_slo::{
    fig11_cfg, fig11_specs, flood_trace, gold_trace, FLOOD, GOLD, SPIKE_END, SPIKE_START,
};
use super::{calibrate_miss_cost, ExpContext, TraceScale};
use crate::engine::{run, RunReport};
use crate::telemetry::EpochDecisionRecord;
use crate::trace::VecSource;
use crate::{Result, TimeUs, HOUR};

/// Fig. 14 report.
#[derive(Debug)]
pub struct Fig14Report {
    /// Derived gold miss-ratio SLO (fig11's self-calibration).
    pub slo_target: f64,
    /// Epoch-close timestamp of the worst gold window epoch.
    pub worst_t: TimeUs,
    /// The gold tenant's miss ratio in that epoch.
    pub worst_miss_ratio: f64,
    /// Boundary timestamp of the decision that governed the worst epoch.
    pub governing_t: TimeUs,
    /// The journal's causal decision against the gold tenant there.
    pub gold_cause: Option<&'static str>,
    /// The journal's causal decision against the flood tenant there.
    pub flood_cause: Option<&'static str>,
    /// Number of journaled epoch records retained.
    pub journal_len: usize,
    /// The telemetered enforced run.
    pub enforced: RunReport,
}

impl Fig14Report {
    pub fn render(&self) -> String {
        let hour = |t: TimeUs| crate::us_to_secs(t) / 3600.0;
        format!(
            "Fig.14 — decision-trace observability (journal + registry over the fig11 spike)\n\
             \x20 gold SLO {:.4}; journal records {}; telemetry rows {}\n\
             \x20 worst gold window epoch: hour {:.1}, miss ratio {:.4}\n\
             \x20 governing decision at hour {:.1}: gold cause={} flood cause={}\n\
             \x20 (the journal names the epoch and the corrective action — no rerun needed)\n",
            self.slo_target,
            self.journal_len,
            self.enforced.telemetry.len(),
            hour(self.worst_t),
            self.worst_miss_ratio,
            hour(self.governing_t),
            self.gold_cause.unwrap_or("none"),
            self.flood_cause.unwrap_or("none"),
        )
    }
}

/// The newest journal record at or before `t` that carries any tenant
/// rows — the decision in force while the epoch closing at `t` ran.
fn governing_record(journal: &[EpochDecisionRecord], t: TimeUs) -> Option<&EpochDecisionRecord> {
    journal
        .iter()
        .rev()
        .find(|r| r.t < t && !r.tenants.is_empty())
        .or_else(|| journal.iter().rev().find(|r| r.t <= t && !r.tenants.is_empty()))
}

pub fn run_fig14_obs(ctx: &ExpContext, scale: TraceScale) -> Result<Fig14Report> {
    let seed = 0xF16_11;
    let gold = gold_trace(scale, seed);
    let mut trace = gold.clone();
    trace.extend(flood_trace(scale, seed));
    trace.sort_by_key(|r| r.ts);

    // Same self-calibration as fig11: balance-point miss cost, SLO from
    // the gold tenant's uncontended miss ratio.
    let mut cfg = fig11_cfg(scale);
    cfg.cost.miss_cost_dollars = calibrate_miss_cost(&cfg, &trace, 4);
    let mut solo_cfg = cfg.clone();
    solo_cfg.scaler.enforce_grants = true;
    solo_cfg.tenants = vec![fig11_specs(1.0).remove(0)];
    let clean = run(&solo_cfg, &mut VecSource::new(gold));
    let slo_target = (3.0 * clean.miss_ratio()).clamp(0.05, 0.5);

    // The enforced fig11 run, now with the decision trace on: the journal
    // JSONL lands next to the CSV artifacts (nightly soak feeds it to
    // scripts/journal_check.py).
    let mut obs_cfg = cfg;
    obs_cfg.scaler.enforce_grants = true;
    obs_cfg.tenants = fig11_specs(slo_target);
    obs_cfg.telemetry.enabled = true;
    obs_cfg.telemetry.journal_capacity = 4096;
    obs_cfg.telemetry.journal_path = Some(
        ctx.out_dir
            .join("fig14_journal.jsonl")
            .to_string_lossy()
            .into_owned(),
    );
    let enforced = run(&obs_cfg, &mut VecSource::new(trace));

    // The excursion: the worst gold epoch inside fig11's measurement
    // window (one epoch of reaction latency after the spike onset).
    let worst = enforced
        .slo
        .iter()
        .filter(|s| s.tenant == GOLD && s.t > SPIKE_START + HOUR && s.t <= SPIKE_END)
        .max_by(|a, b| a.miss_ratio.total_cmp(&b.miss_ratio))
        .ok_or_else(|| anyhow::anyhow!("no gold sample inside the spike window"))?;
    let (worst_t, worst_miss_ratio) = (worst.t, worst.miss_ratio);

    // The journal record that governed that epoch names the cause.
    let governing = governing_record(&enforced.journal, worst_t)
        .ok_or_else(|| anyhow::anyhow!("no journal record governs t={worst_t}"))?;
    let governing_t = governing.t;
    let gold_cause = governing.tenant(GOLD).and_then(|d| d.cause());
    let flood_cause = governing.tenant(FLOOD).and_then(|d| d.cause());

    // CSV artifacts: the flattened journal, and the registry snapshot.
    let mut rows: Vec<Vec<String>> = Vec::new();
    for rec in &enforced.journal {
        for d in &rec.tenants {
            rows.push(vec![
                format!("{:.3}", crate::us_to_secs(rec.t) / 3600.0),
                rec.epoch.to_string(),
                rec.instances.to_string(),
                d.tenant.to_string(),
                d.demand_bytes.to_string(),
                d.granted_bytes.to_string(),
                d.reserved_bytes.to_string(),
                d.pooled_bytes.to_string(),
                d.cap_bytes.map(|v| v.to_string()).unwrap_or_default(),
                d.ttl_clamp_secs.map(|v| format!("{v:.3}")).unwrap_or_default(),
                d.resident_before_bytes.to_string(),
                d.resident_bytes.to_string(),
                d.shed_bytes.to_string(),
                d.denied_admissions.to_string(),
                format!("{:.3}", d.boost),
                d.cause().unwrap_or("").to_string(),
            ]);
        }
    }
    ctx.write_csv(
        "fig14_journal.csv",
        &[
            "hour", "epoch", "instances", "tenant", "demand_bytes", "granted_bytes",
            "reserved_bytes", "pooled_bytes", "cap_bytes", "ttl_clamp_secs",
            "resident_before_bytes", "resident_bytes", "shed_bytes", "denied_admissions",
            "boost", "cause",
        ],
        &rows,
    )?;
    ctx.write_csv(
        "fig14_telemetry.csv",
        &["metric", "value"],
        &enforced
            .telemetry
            .iter()
            .map(|(k, v)| vec![k.clone(), format!("{v:.6}")])
            .collect::<Vec<_>>(),
    )?;
    ctx.write_csv(
        "fig14_summary.csv",
        &["metric", "value"],
        &[
            vec!["slo_target".into(), format!("{slo_target:.6}")],
            vec!["worst_hour".into(), format!("{:.3}", crate::us_to_secs(worst_t) / 3600.0)],
            vec!["worst_miss_ratio".into(), format!("{worst_miss_ratio:.6}")],
            vec!["gold_cause".into(), gold_cause.unwrap_or("none").into()],
            vec!["flood_cause".into(), flood_cause.unwrap_or("none").into()],
            vec!["journal_records".into(), enforced.journal.len().to_string()],
        ],
    )?;

    Ok(Fig14Report {
        slo_target,
        worst_t,
        worst_miss_ratio,
        governing_t,
        gold_cause,
        flood_cause,
        journal_len: enforced.journal.len(),
        enforced,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_pinpoints_the_excursion_cause() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig14_obs(&ctx, TraceScale::Smoke).unwrap();

        // The decision trace exists and is internally consistent.
        assert!(rep.journal_len > 0, "no journal records");
        for rec in &rep.enforced.journal {
            let granted: u64 = rec.tenants.iter().map(|d| d.granted_bytes).sum();
            assert!(
                granted <= rec.capacity_bytes,
                "arbiter invariant: {granted} > {}",
                rec.capacity_bytes
            );
            for d in &rec.tenants {
                assert!(d.shed_bytes <= d.resident_before_bytes, "{d:?}");
            }
        }
        // The governing record names a corrective decision: during the
        // flood spike the cluster is oversubscribed, so at least one
        // tenant was squeezed, clamped or shed at that boundary.
        assert!(
            rep.gold_cause.is_some() || rep.flood_cause.is_some(),
            "the journal must name a cause for the excursion epoch"
        );
        // The registry snapshot covers the run (requests counter matches
        // the report's own accounting).
        let reqs = rep
            .enforced
            .telemetry
            .iter()
            .find(|(k, _)| k == "elastictl_requests_total")
            .map(|(_, v)| *v);
        assert_eq!(reqs, Some(rep.enforced.requests as f64));
        // Artifacts exist — including the JSONL the soak invariant pass
        // consumes.
        assert!(dir.path().join("fig14_journal.jsonl").exists());
        assert!(dir.path().join("fig14_journal.csv").exists());
        assert!(dir.path().join("fig14_telemetry.csv").exists());
        assert!(dir.path().join("fig14_summary.csv").exists());
    }
}
