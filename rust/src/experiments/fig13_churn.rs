//! Fig. 13 (ours, beyond the paper) — online tenant churn on the shared
//! elastic cluster: what admitting and retiring tenants *mid-run* costs,
//! and what retiring actually reclaims.
//!
//! The paper's controller tracks time-varying demand for a fixed
//! workload population; Carlsson & Eager's dynamic cache-instantiation
//! analysis (PAPERS.md) shows the spin-up/tear-down transient is exactly
//! where cost is won or lost, and Memshare treats tenant arrival and
//! departure as the normal case its arbiter rebalances around. This
//! experiment exercises the full lifecycle subsystem end to end:
//!
//! * a **base** tenant runs a steady cacheable workload for the whole
//!   2-day window;
//! * a **guest** tenant is `ADMIT`ed at hour 6 (via the trace event
//!   lane — the same path as the serve protocol's `ADMIT`), sends
//!   traffic until hour 30, and is `RETIRE`d there.
//!
//! Two measurements per placement policy (`shared`, `hash_slot_pinned`,
//! `slab_partition`):
//!
//! * **spin-up transient** — the guest's per-epoch miss ratio over its
//!   first epochs (cold cache, no grant history) vs its steady-state
//!   mean: the arrival cost the static-population analysis never sees.
//! * **reclaimed-bytes curve** — the guest's resident bytes at every
//!   boundary after the RETIRE: the drain must reach zero within
//!   [`crate::tenant::MAX_DRAIN_EPOCHS`] boundaries and the reconciled
//!   final bill must equal the fold of the guest's per-epoch bills
//!   *exactly* ([`crate::cost::CostTracker::tenant_bills`]).
//!
//! A **static-population baseline** replays the identical requests with
//! both tenants admitted up front and nobody retired: after hour 30 the
//! guest's residents linger in the physical LRUs (nothing reclaims
//! them), which is precisely the tear-down waste the drain removes.

use super::fig11_slo::{scale_factor, uniform};
use super::{calibrate_miss_cost, ExpContext, TraceScale};
use crate::config::{Config, PolicyKind};
use crate::engine::{run, RunReport};
use crate::placement::PlacementKind;
use crate::tenant::{LifecycleState, TenantSpec, TrafficClass, MAX_DRAIN_EPOCHS};
use crate::trace::{EventedVecSource, Request, SynthConfig, SynthGenerator, TenantEvent, VecSource};
use crate::{Result, TimeUs, DAY, HOUR};

/// Steady base tenant id.
pub const BASE: u16 = 0;
/// Churning guest tenant id (admitted and retired mid-run).
pub const GUEST: u16 = 1;

/// When the guest is admitted / retired within the 2-day window.
pub const ADMIT_AT: TimeUs = 6 * HOUR;
/// Retirement boundary of the guest tenant.
pub const RETIRE_AT: TimeUs = 30 * HOUR;

/// One placement policy's churn-run outcome.
#[derive(Debug)]
pub struct Fig13Variant {
    /// Placement policy name.
    pub name: &'static str,
    /// The placement policy the run used.
    pub placement: PlacementKind,
    /// Guest per-epoch miss ratio in its first spin-up epoch with
    /// traffic.
    pub spinup_miss_ratio: f64,
    /// Guest mean per-epoch miss ratio once warm (spin-up epochs
    /// excluded, pre-retirement).
    pub steady_miss_ratio: f64,
    /// Epoch boundaries the drain consumed (≤ K).
    pub drain_epochs: u32,
    /// Guest resident bytes at each boundary from the RETIRE on (the
    /// reclaimed-bytes curve; ends at 0).
    pub reclaimed_curve: Vec<(TimeUs, u64)>,
    /// The guest's reconciled final bill.
    pub final_bill_dollars: f64,
    /// The full churn-run report.
    pub report: RunReport,
}

/// Fig. 13 report: one churn run per placement policy plus the
/// static-population baseline.
#[derive(Debug)]
pub struct Fig13Report {
    /// Guest admission time.
    pub admit_at: TimeUs,
    /// Guest retirement time.
    pub retire_at: TimeUs,
    /// Churn runs, one per placement policy.
    pub variants: Vec<Fig13Variant>,
    /// Guest resident bytes still held by the static baseline two
    /// boundaries after the (unobserved) retirement point.
    pub baseline_lingering_bytes: u64,
    /// The static-population baseline report (shared placement).
    pub baseline: RunReport,
}

impl Fig13Report {
    /// The churn variant run under `name`.
    pub fn variant(&self, name: &str) -> &Fig13Variant {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .expect("fig13 variant")
    }

    /// Human-readable summary.
    pub fn render(&self) -> String {
        let mut s = format!(
            "Fig.13 — online tenant churn: ADMIT at hour {:.0}, RETIRE at hour {:.0}\n",
            crate::us_to_secs(self.admit_at) / 3600.0,
            crate::us_to_secs(self.retire_at) / 3600.0,
        );
        for v in &self.variants {
            s.push_str(&format!(
                "  {:<16} spin-up miss%={:.4} steady={:.4} drain_epochs={} \
                 final_bill=${:.6}\n",
                v.name, v.spinup_miss_ratio, v.steady_miss_ratio, v.drain_epochs,
                v.final_bill_dollars,
            ));
        }
        s.push_str(&format!(
            "  static baseline still holds {} guest bytes two epochs past the \
             retirement point\n\
             \x20 expected shape: the spin-up epoch pays a cold-cache transient \
             (miss% above steady);\n\
             \x20 the drain reclaims every guest byte within {} boundaries and \
             Σ(per-epoch bills) == final bill exactly\n",
            self.baseline_lingering_bytes, MAX_DRAIN_EPOCHS,
        ));
        s
    }
}

/// The guest tenant's spec (2× miss cost, one reserved instance's worth
/// at the given instance size).
pub fn guest_spec(instance_bytes: u64) -> TenantSpec {
    TenantSpec::new(GUEST, "guest")
        .with_multiplier(2.0)
        .with_class(TrafficClass::Standard)
        .with_reserved_bytes(instance_bytes)
}

/// The base tenant's steady cacheable workload (whole window).
fn base_trace(scale: TraceScale, seed: u64) -> Vec<Request> {
    let f = scale_factor(scale);
    let mut g = SynthConfig::akamai_like();
    g.catalogue = (1_000.0 * f) as u64;
    g.alpha = 0.9;
    g.mean_rate = 5.0 * f;
    g.diurnal_amplitude = 0.3;
    g.duration = 2 * DAY;
    g.churn_per_day = 0.0;
    g.seed = seed ^ 0xBA5E;
    uniform(SynthGenerator::new(g).generate(), BASE)
}

/// The guest tenant's workload: a cacheable catalogue active only within
/// its `[ADMIT_AT, RETIRE_AT)` lifetime.
fn guest_trace(scale: TraceScale, seed: u64) -> Vec<Request> {
    let f = scale_factor(scale);
    let mut g = SynthConfig::akamai_like();
    g.catalogue = (800.0 * f) as u64;
    g.alpha = 0.9;
    g.mean_rate = 4.0 * f;
    g.diurnal_amplitude = 0.2;
    g.duration = RETIRE_AT - ADMIT_AT;
    g.churn_per_day = 0.0;
    g.seed = seed ^ 0x6E57;
    let mut reqs = uniform(SynthGenerator::new(g).generate(), GUEST);
    for r in &mut reqs {
        r.ts += ADMIT_AT;
    }
    reqs
}

/// The churn event schedule: admit the guest at hour 6, retire it at
/// hour 30 (the trace event lane `gen-trace --kind churn` writes).
pub fn churn_events(instance_bytes: u64) -> Vec<TenantEvent> {
    let spec = guest_spec(instance_bytes);
    vec![
        TenantEvent::admit(ADMIT_AT, GUEST)
            .with_reserved_bytes(spec.reserved_bytes)
            .with_multiplier(spec.miss_cost_multiplier),
        TenantEvent::retire(RETIRE_AT, GUEST),
    ]
}

/// The merged churn request trace (base + guest, time-ordered).
pub fn churn_trace(scale: TraceScale, seed: u64) -> Vec<Request> {
    let mut trace = base_trace(scale, seed);
    trace.extend(guest_trace(scale, seed));
    trace.sort_by_key(|r| r.ts);
    trace
}

/// The shared-cluster config (placement and roster filled in per run).
fn fig13_cfg(scale: TraceScale) -> Config {
    let f = scale_factor(scale);
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.controller.t_init_secs = 3600.0;
    cfg.cost.instance.ram_bytes = (40.0e6 * f) as u64;
    cfg.cost.instance.dollars_per_hour = 0.017 * (40.0e6 * f) / 555.0e6;
    cfg.scaler.max_instances = 6;
    cfg.scaler.min_instances = 1;
    cfg
}

/// Guest per-epoch `(t, requests, misses)` rows from the SLO record.
fn guest_epochs(report: &RunReport) -> Vec<(TimeUs, u64, u64)> {
    report
        .slo
        .iter()
        .filter(|s| s.tenant == GUEST && s.requests > 0)
        .map(|s| (s.t, s.requests, s.misses))
        .collect()
}

pub fn run_fig13(ctx: &ExpContext, scale: TraceScale) -> Result<Fig13Report> {
    let seed = 0xF16_13;
    let trace = churn_trace(scale, seed);

    let mut base_cfg = fig13_cfg(scale);
    base_cfg.cost.miss_cost_dollars = calibrate_miss_cost(&base_cfg, &trace, 4);
    let instance_bytes = base_cfg.cost.instance.ram_bytes;
    // The churn runs know only the base tenant up front; the guest
    // arrives through the event lane.
    base_cfg.tenants = vec![TenantSpec::new(BASE, "base")];

    let matrix: [(&'static str, PlacementKind); 3] = [
        ("shared", PlacementKind::Shared),
        ("hash_slot_pinned", PlacementKind::HashSlotPinned),
        ("slab_partition", PlacementKind::SlabPartition),
    ];
    let mut variants = Vec::new();
    for (name, placement) in matrix {
        let mut cfg = base_cfg.clone();
        cfg.cluster.placement = placement;
        let mut src =
            EventedVecSource::merged(trace.clone(), churn_events(instance_bytes));
        let report = run(&cfg, &mut src);

        // Spin-up transient vs steady state, from the per-epoch record.
        let epochs = guest_epochs(&report);
        anyhow::ensure!(!epochs.is_empty(), "fig13({name}): guest sent no traffic");
        let (_, r0, m0) = epochs[0];
        let spinup = m0 as f64 / r0 as f64;
        let steady_rows: Vec<_> = epochs
            .iter()
            .skip(2)
            .filter(|&&(t, _, _)| t <= RETIRE_AT)
            .collect();
        let (sr, sm) = steady_rows
            .iter()
            .fold((0u64, 0u64), |(r, m), &&(_, er, em)| (r + er, m + em));
        let steady = if sr > 0 { sm as f64 / sr as f64 } else { 0.0 };

        // Drain audit: the lifecycle record has the Retired transition.
        let retired = report
            .lifecycle
            .iter()
            .find(|s| s.tenant == GUEST && s.state == LifecycleState::Retired)
            .ok_or_else(|| anyhow::anyhow!("fig13({name}): guest never retired"))?;
        let final_bill = retired
            .final_bill_dollars
            .ok_or_else(|| anyhow::anyhow!("fig13({name}): no reconciled bill"))?;
        // Reclaimed-bytes curve: the guest's post-retire ledger rows
        // (placement samples carry only residents > 0; the curve closes
        // with the Retired transition's zero).
        let mut curve: Vec<(TimeUs, u64)> = report
            .placement
            .iter()
            .filter(|s| s.tenant == GUEST && s.t >= RETIRE_AT)
            .map(|s| (s.t, s.resident_bytes))
            .collect();
        curve.push((retired.t, retired.resident_bytes));

        variants.push(Fig13Variant {
            name,
            placement,
            spinup_miss_ratio: spinup,
            steady_miss_ratio: steady,
            drain_epochs: retired.drain_epochs,
            reclaimed_curve: curve,
            final_bill_dollars: final_bill,
            report,
        });
    }

    // Static-population baseline: both tenants rostered up front, nobody
    // retired, identical requests.
    let mut static_cfg = base_cfg.clone();
    static_cfg.tenants =
        vec![TenantSpec::new(BASE, "base"), guest_spec(instance_bytes)];
    let baseline = run(&static_cfg, &mut VecSource::new(trace.clone()));
    // What the baseline still holds for the guest two boundaries past
    // the retirement point (nothing ever reclaims it).
    let probe_at = RETIRE_AT + 2 * static_cfg.cost.epoch_us;
    let baseline_lingering_bytes = baseline
        .placement
        .iter()
        .filter(|s| s.tenant == GUEST && s.t > RETIRE_AT && s.t <= probe_at)
        .map(|s| s.resident_bytes)
        .last()
        .unwrap_or(0);

    // CSV artifacts: the reclaimed-bytes curves plus the headline table.
    let mut rows: Vec<Vec<String>> = Vec::new();
    for v in &variants {
        for &(t, bytes) in &v.reclaimed_curve {
            rows.push(vec![
                v.name.to_string(),
                format!("{:.3}", crate::us_to_secs(t) / 3600.0),
                bytes.to_string(),
            ]);
        }
    }
    ctx.write_csv("fig13_reclaimed_bytes.csv", &["variant", "hour", "guest_bytes"], &rows)?;
    ctx.write_csv(
        "fig13_summary.csv",
        &[
            "variant",
            "spinup_miss_ratio",
            "steady_miss_ratio",
            "drain_epochs",
            "final_bill_usd",
            "total_usd",
        ],
        &variants
            .iter()
            .map(|v| {
                vec![
                    v.name.to_string(),
                    format!("{:.6}", v.spinup_miss_ratio),
                    format!("{:.6}", v.steady_miss_ratio),
                    v.drain_epochs.to_string(),
                    format!("{:.6}", v.final_bill_dollars),
                    format!("{:.6}", v.report.total_cost),
                ]
            })
            .collect::<Vec<_>>(),
    )?;

    Ok(Fig13Report {
        admit_at: ADMIT_AT,
        retire_at: RETIRE_AT,
        variants,
        baseline_lingering_bytes,
        baseline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fold a report's per-tenant epoch bills exactly as the tracker
    /// accumulated them: per epoch in row order, then across epochs.
    fn fold_bills(report: &RunReport, tenant: Option<u16>) -> (f64, f64) {
        let (mut s, mut m) = (0.0, 0.0);
        let (mut se, mut me) = (0.0, 0.0);
        let mut cur = None;
        for b in &report.tenant_bills {
            if let Some(t) = tenant {
                if b.tenant != t {
                    continue;
                }
            }
            if cur != Some(b.t) {
                s += se;
                m += me;
                se = 0.0;
                me = 0.0;
                cur = Some(b.t);
            }
            se += b.storage;
            me += b.miss;
        }
        (s + se, m + me)
    }

    #[test]
    fn churn_drains_reconciles_and_pays_the_spinup_transient() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig13(&ctx, TraceScale::Smoke).unwrap();
        assert_eq!(rep.variants.len(), 3);

        for v in &rep.variants {
            // The spin-up epoch is the cold-cache transient: it misses
            // harder than the warm steady state.
            assert!(
                v.spinup_miss_ratio > v.steady_miss_ratio,
                "{}: spin-up {} should exceed steady {}",
                v.name,
                v.spinup_miss_ratio,
                v.steady_miss_ratio
            );
            // After RETIRE the ledger row reaches 0 within K boundaries,
            // under every placement policy.
            assert!(
                v.drain_epochs <= MAX_DRAIN_EPOCHS,
                "{}: drain took {} epochs",
                v.name,
                v.drain_epochs
            );
            let (_, last) = v.reclaimed_curve.last().unwrap();
            assert_eq!(*last, 0, "{}: drain must end at zero bytes", v.name);
            // The reconciled final bill equals the fold of the guest's
            // per-epoch bills — exact, not approximate.
            let rec = v
                .report
                .reconciliations
                .iter()
                .find(|r| r.tenant == GUEST)
                .expect("guest reconciliation");
            let (s, m) = fold_bills(&v.report, Some(GUEST));
            assert_eq!(rec.storage_dollars, s, "{}: storage fold", v.name);
            assert_eq!(rec.miss_dollars, m, "{}: miss fold", v.name);
            assert_eq!(rec.total_dollars, s + m, "{}: total fold", v.name);
            assert!(rec.total_dollars > 0.0);
            // And the whole cluster bill is the fold of every tenant's
            // bills, bit for bit.
            let (cs, cm) = fold_bills(&v.report, None);
            assert_eq!(
                cs + cm,
                v.report.total_cost,
                "{}: Σ tenant bills != cluster bill",
                v.name
            );
        }

        // The static baseline never reclaims: the guest's bytes linger
        // after its traffic stops, exactly what the drain removes.
        assert!(
            rep.baseline_lingering_bytes > 0,
            "baseline should still hold guest bytes"
        );

        // Artifacts exist.
        assert!(dir.path().join("fig13_reclaimed_bytes.csv").exists());
        assert!(dir.path().join("fig13_summary.csv").exists());
    }
}
