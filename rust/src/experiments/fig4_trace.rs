//! Fig. 4 — trace characterization: requests per object ordered by rank
//! (left) and the request-weighted size CDF (right).

use super::ExpContext;
use crate::trace::{characterize, TraceStats};
use crate::Result;

#[derive(Debug)]
pub struct Fig4Report {
    pub stats: TraceStats,
}

impl Fig4Report {
    pub fn render(&self) -> String {
        let s = &self.stats;
        format!(
            "Fig.4 — trace characterization\n\
             \x20 requests            {}\n\
             \x20 distinct objects    {}\n\
             \x20 reqs/object         {:.1}\n\
             \x20 duration            {:.1} days\n\
             \x20 mean rate           {:.1} req/s\n\
             \x20 size range          {} B .. {:.1} MB (mean {:.1} KB)\n\
             \x20 fitted Zipf alpha   {:.2} (head 200 ranks)\n\
             \x20 paper trace: 2e9 reqs, 1.1e8 objects (~18 reqs/obj), sizes B..tens MB\n",
            s.requests,
            s.distinct_objects,
            s.reqs_per_object(),
            s.duration_us as f64 / crate::DAY as f64,
            s.mean_rate(),
            s.min_size,
            s.max_size as f64 / 1048576.0,
            s.mean_size / 1024.0,
            s.fitted_zipf_alpha(200).unwrap_or(f64::NAN),
        )
    }
}

pub fn run_fig4(ctx: &ExpContext) -> Result<Fig4Report> {
    let stats = characterize(&ctx.trace);
    // Left panel: rank vs frequency (downsampled log grid).
    let mut rank_rows = Vec::new();
    let mut rank = 1usize;
    while rank <= stats.rank_frequency.len() {
        rank_rows.push(vec![
            rank.to_string(),
            stats.rank_frequency[rank - 1].to_string(),
        ]);
        rank = (rank as f64 * 1.3).ceil() as usize;
    }
    ctx.write_csv("fig4_rank_frequency.csv", &["rank", "requests"], &rank_rows)?;
    // Right panel: size CDF.
    let cdf_rows: Vec<Vec<String>> = stats
        .size_cdf
        .iter()
        .map(|&(sz, f)| vec![sz.to_string(), format!("{f:.6}")])
        .collect();
    ctx.write_csv("fig4_size_cdf.csv", &["size_bytes", "cum_fraction"], &cdf_rows)?;
    Ok(Fig4Report { stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn marginals_match_paper_shape() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig4(&ctx).unwrap();
        let s = &rep.stats;
        // Zipf-ish head.
        let alpha = s.fitted_zipf_alpha(200).unwrap();
        assert!((0.5..1.4).contains(&alpha), "alpha={alpha}");
        // Sizes span ≥ 4 orders of magnitude.
        assert!(s.max_size / s.min_size.max(1) > 10_000);
        assert!(dir.path().join("fig4_rank_frequency.csv").exists());
        assert!(dir.path().join("fig4_size_cdf.csv").exists());
    }
}
