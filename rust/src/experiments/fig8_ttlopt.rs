//! Fig. 8 — the clairvoyant TTL-OPT lower bound vs. the practical
//! policies. Paper: TTL-OPT's cumulative cost is about one third of the
//! fixed baseline (≈66% saving head-room).

use super::ExpContext;
use crate::config::PolicyKind;
use crate::engine::run;
use crate::metrics::merged_csv;
use crate::ttlopt::{solve, TtlOptResult};
use crate::Result;

#[derive(Debug)]
pub struct Fig8Report {
    pub fixed_total: f64,
    pub ttl_total: f64,
    pub opt: TtlOptResult,
    pub fixed_instances: u32,
}

impl Fig8Report {
    /// TTL-OPT cost as a fraction of the fixed baseline (paper ≈ 1/3).
    pub fn opt_fraction_of_fixed(&self) -> f64 {
        self.opt.total_cost / self.fixed_total.max(1e-12)
    }

    pub fn render(&self) -> String {
        format!(
            "Fig.8 — clairvoyant TTL-OPT lower bound\n\
             \x20 fixed({} inst) total  ${:.4}\n\
             \x20 ttl total             ${:.4}\n\
             \x20 ttl-opt total         ${:.4}  ({:.0}% of fixed)\n\
             \x20 ttl-opt miss ratio    {:.4}\n\
             \x20 ttl-opt peak bytes    {:.1} MB\n\
             \x20 paper shape: TTL-OPT ≈ 1/3 of the baseline cost\n",
            self.fixed_instances,
            self.fixed_total,
            self.ttl_total,
            self.opt.total_cost,
            100.0 * self.opt_fraction_of_fixed(),
            self.opt.miss_ratio(),
            self.opt.peak_bytes as f64 / 1048576.0,
        )
    }
}

pub fn run_fig8(ctx: &ExpContext) -> Result<Fig8Report> {
    let fixed_instances = super::fig6_costs::calibrate_fixed_instances(&ctx.cfg, &ctx.trace);
    let mut fixed_cfg = ctx.cfg.clone();
    fixed_cfg.scaler.policy = PolicyKind::Fixed;
    fixed_cfg.scaler.fixed_instances = fixed_instances;
    let fixed = run(&fixed_cfg, &mut ctx.source());

    let mut ttl_cfg = ctx.cfg.clone();
    ttl_cfg.scaler.policy = PolicyKind::Ttl;
    let ttl = run(&ttl_cfg, &mut ctx.source());

    let opt = solve(&ctx.trace, &ctx.cfg.cost);

    let mut fixed_t = fixed.total_series.clone();
    fixed_t.name = "fixed".into();
    let mut ttl_t = ttl.total_series.clone();
    ttl_t.name = "ttl".into();
    let mut opt_t = opt.total_series.clone();
    opt_t.name = "ttl_opt".into();
    std::fs::write(
        ctx.out_dir.join("fig8_ttlopt.csv"),
        merged_csv(&[&fixed_t, &ttl_t, &opt_t]),
    )?;

    Ok(Fig8Report {
        fixed_total: fixed.total_cost,
        ttl_total: ttl.total_cost,
        opt,
        fixed_instances,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn ttlopt_is_a_strict_lower_bound() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig8(&ctx).unwrap();
        // TTL-OPT must beat every feasible policy.
        assert!(
            rep.opt.total_cost < rep.ttl_total,
            "opt {} !< ttl {}",
            rep.opt.total_cost,
            rep.ttl_total
        );
        assert!(rep.opt.total_cost < rep.fixed_total);
        // Paper shape: large head-room (≈1/3); smoke tolerance ≤ 0.7.
        assert!(
            rep.opt_fraction_of_fixed() < 0.7,
            "fraction={}",
            rep.opt_fraction_of_fixed()
        );
        assert!(dir.path().join("fig8_ttlopt.csv").exists());
    }
}
