//! Experiment harness: one entry point per figure/table of the paper.
//! See DESIGN.md §5 for the index. Each experiment writes CSV series under
//! an output directory and returns a human-readable report string.

mod ablations;
mod fig10_tenants;
mod fig11_slo;
mod fig12_placement;
mod fig13_churn;
mod fig14_obs;
mod fig15_admission;
mod fig1_overhead;
mod fig2_mrc_accuracy;
mod fig4_trace;
mod fig5_dynamics;
mod fig6_costs;
mod fig8_ttlopt;
mod fig9_balance;
mod irm_convergence;

pub use ablations::{
    run_epoch_ablation, run_gain_ablation, run_instance_ablation, run_per_content_ablation,
    AblationReport,
};
pub use fig10_tenants::{run_fig10, tenant_specs, tenant_trace, Fig10Report, TenantOutcome};
pub use fig11_slo::{fig11_specs, run_fig11, Fig11Report};
pub use fig12_placement::{fig12_specs, run_fig12, Fig12Report, Fig12Variant};
pub use fig13_churn::{
    churn_events, churn_trace, guest_spec, run_fig13, Fig13Report, Fig13Variant,
};
pub use fig14_obs::{run_fig14_obs, Fig14Report};
pub use fig15_admission::{run_fig15, Fig15Report, Fig15Row};
pub use fig1_overhead::run_fig1;
pub use fig2_mrc_accuracy::run_fig2;
pub use fig4_trace::run_fig4;
pub use fig5_dynamics::run_fig5;
pub use fig6_costs::{run_fig6_fig7_headline, Fig6Report};
pub use fig8_ttlopt::run_fig8;
pub use fig9_balance::run_fig9;
pub use irm_convergence::run_irm_convergence;

use crate::config::Config;
use crate::trace::{Request, SynthConfig, SynthGenerator};
use crate::Result;
use std::path::{Path, PathBuf};

/// Shared experiment context: trace + config + output directory.
pub struct ExpContext {
    pub cfg: Config,
    pub trace: Vec<Request>,
    pub out_dir: PathBuf,
}

impl ExpContext {
    /// Build the standard evaluation context: the Akamai-like synthetic
    /// trace (scaled per `scale`) and a config whose instance size is
    /// shrunk so cluster sizes land in the paper's 1–10 range at our
    /// request scale (documented in EXPERIMENTS.md §Calibration).
    pub fn standard(scale: TraceScale, out_dir: impl AsRef<Path>) -> Self {
        let synth = scale.synth_config();
        let trace = SynthGenerator::new(synth).generate();
        let mut cfg = scale.config();
        // §6.1 balance-point rule, applied to the scaled trace exactly as
        // the paper applied it to the production cache: assume the
        // well-engineered static size is 8 nodes, and set the per-miss
        // cost so that storage and miss bills balance there. (The paper's
        // 1.4676e-7 $ was derived the same way from its own trace volume.)
        cfg.cost.miss_cost_dollars = calibrate_miss_cost(&cfg, &trace, 8);
        let out_dir = out_dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&out_dir).ok();
        ExpContext { cfg, trace, out_dir }
    }

    pub fn write_csv(&self, name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
        crate::metrics::write_csv(self.out_dir.join(name), header, rows)
    }

    /// A fresh in-memory source replaying the context trace from the
    /// start (experiments run several policies over the same trace).
    pub fn source(&self) -> crate::trace::VecSource {
        crate::trace::VecSource::new(self.trace.clone())
    }
}

/// Trace scale presets: the paper's trace is 2·10⁹ requests over 30 days;
/// we provide scaled-down variants that preserve the requests/object ratio
/// and diurnal amplitude.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceScale {
    /// ~0.4M requests, 2 simulated days — CI-speed smoke runs.
    Smoke,
    /// ~2.6M requests, 5 simulated days — the "5-day trace" analogue.
    Small,
    /// ~10M requests, 15 simulated days — the Fig. 6 window.
    Full,
}

impl TraceScale {
    pub fn synth_config(self) -> SynthConfig {
        let mut c = SynthConfig::akamai_like();
        match self {
            TraceScale::Smoke => {
                c.catalogue = 20_000;
                c.alpha = 0.95;
                c.mean_rate = 5.0;
                c.duration = 2 * crate::DAY;
                c.churn_per_day = 0.02;
            }
            TraceScale::Small => {
                c.catalogue = 120_000;
                c.alpha = 0.95;
                c.mean_rate = 15.0;
                c.duration = 5 * crate::DAY;
                c.churn_per_day = 0.02;
            }
            TraceScale::Full => {
                c.catalogue = 400_000;
                c.alpha = 0.95;
                c.mean_rate = 25.0;
                c.duration = 15 * crate::DAY;
                c.churn_per_day = 0.02;
            }
        }
        c
    }

    /// Config calibrated to the scale: instance RAM shrunk so the optimal
    /// cluster has ~4–10 nodes (the paper's fixed-8 regime), miss cost per
    /// the §6.1 balance-point rule recomputed in EXPERIMENTS.md.
    pub fn config(self) -> Config {
        let mut cfg = Config::default();
        match self {
            TraceScale::Smoke => {
                cfg.cost.instance.ram_bytes = 40_000_000;
                cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
            }
            TraceScale::Small => {
                cfg.cost.instance.ram_bytes = 80_000_000;
                cfg.cost.instance.dollars_per_hour = 0.017 * 80.0e6 / 555.0e6;
            }
            TraceScale::Full => {
                cfg.cost.instance.ram_bytes = 150_000_000;
                cfg.cost.instance.dollars_per_hour = 0.017 * 150.0e6 / 555.0e6;
            }
        }
        cfg.scaler.max_instances = 64;
        cfg
    }
}

/// The §6.1 rule of thumb as code: replay a prefix of the trace through a
/// fixed cluster of `n_ref` nodes and return the per-miss dollar cost at
/// which the prefix's miss bill equals its storage bill.
pub fn calibrate_miss_cost(cfg: &Config, trace: &[Request], n_ref: u32) -> f64 {
    use crate::config::PolicyKind;
    use crate::trace::VecSource;
    // A prefix long enough to warm the cache and cover several epochs.
    let horizon = (8 * cfg.cost.epoch_us).max(1);
    let cut = trace.partition_point(|r| r.ts < horizon);
    let prefix = &trace[..cut.max(1).min(trace.len())];
    let mut probe_cfg = cfg.clone();
    probe_cfg.scaler.policy = PolicyKind::Fixed;
    probe_cfg.scaler.fixed_instances = n_ref;
    let res = crate::engine::run(&probe_cfg, &mut VecSource::new(prefix.to_vec()));
    if res.misses == 0 {
        return cfg.cost.miss_cost_dollars;
    }
    res.storage_cost / res.misses as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_have_increasing_volume() {
        let a = TraceScale::Smoke.synth_config().expected_requests();
        let b = TraceScale::Small.synth_config().expected_requests();
        let c = TraceScale::Full.synth_config().expected_requests();
        assert!(a < b && b < c, "{a} {b} {c}");
    }

    #[test]
    fn configs_preserve_per_byte_price() {
        for scale in [TraceScale::Smoke, TraceScale::Small, TraceScale::Full] {
            let cfg = scale.config();
            let per_byte = cfg.cost.instance.dollars_per_hour / cfg.cost.instance.ram_bytes as f64;
            let paper = 0.017 / 555.0e6;
            assert!((per_byte - paper).abs() / paper < 1e-9, "{scale:?}");
        }
    }

    #[test]
    fn standard_context_materializes() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        assert!(ctx.trace.len() > 100_000, "len={}", ctx.trace.len());
        ctx.write_csv("t.csv", &["a"], &[vec!["1".into()]]).unwrap();
        assert!(dir.path().join("t.csv").exists());
    }
}
