//! Figs. 6 & 7 + the §6.2 headline table — cumulative costs of the four
//! policies over the trace window.
//!
//! Paper shape targets:
//! * TTL ≈ MRC in total cumulative cost;
//! * both save ≈17% vs. the fixed-size baseline;
//! * the ideal (vertically billed) TTL cache is ≈2% below the practical
//!   TTL system;
//! * Fig. 7: MRC runs fewer instances (lower storage) but pays more
//!   misses; the sums are similar.

use super::ExpContext;
use crate::config::{Config, PolicyKind};
use crate::engine::{run, RunReport};
use crate::metrics::merged_csv;
use crate::trace::VecSource;
use crate::Result;

/// Everything Figs. 6/7 + headline need.
#[derive(Debug)]
pub struct Fig6Report {
    pub fixed: RunReport,
    pub ttl: RunReport,
    pub mrc: RunReport,
    pub ideal: RunReport,
    /// Baseline instance count used for "fixed".
    pub fixed_instances: u32,
}

impl Fig6Report {
    pub fn savings_vs_fixed(&self, r: &RunReport) -> f64 {
        1.0 - r.total_cost / self.fixed.total_cost.max(1e-12)
    }

    /// Gap of practical TTL above ideal TTL (paper: ≈2%).
    pub fn ttl_gap_to_ideal(&self) -> f64 {
        self.ttl.total_cost / self.ideal.total_cost.max(1e-12) - 1.0
    }

    pub fn render(&self) -> String {
        let mut s = String::from(
            "Fig.6/7 + headline — cumulative costs\n\
             \x20 policy     storage$     miss$        total$      miss%   saving-vs-fixed\n",
        );
        for r in [&self.fixed, &self.ttl, &self.mrc, &self.ideal] {
            s.push_str(&format!(
                "  {:<10} {:<12.4} {:<12.4} {:<11.4} {:<7.4} {:+.1}%\n",
                r.policy,
                r.storage_cost,
                r.miss_cost,
                r.total_cost,
                r.miss_ratio(),
                100.0 * self.savings_vs_fixed(r),
            ));
        }
        s.push_str(&format!(
            "  ttl gap above ideal: {:+.1}%\n\
             \x20 paper shape: ttl≈mrc, both ≈17% under fixed, ideal ≈2% under ttl\n",
            100.0 * self.ttl_gap_to_ideal()
        ));
        s
    }
}

/// Pick the fixed baseline per the §6.1 balance-point rule: the static
/// size at which storage cost ≈ miss cost, found by trial runs over a
/// trace prefix (the paper assumes the production 4 GB cache was sized
/// this way).
pub fn calibrate_fixed_instances(cfg: &Config, trace: &[crate::trace::Request]) -> u32 {
    let prefix = &trace[..trace.len().min(300_000)];
    let mut best_n = 8u32;
    let mut best_gap = f64::INFINITY;
    for n in [2u32, 4, 6, 8, 12, 16, 24, 32] {
        if n > cfg.scaler.max_instances {
            break;
        }
        let mut c = cfg.clone();
        c.scaler.policy = PolicyKind::Fixed;
        c.scaler.fixed_instances = n;
        let mut src = VecSource::new(prefix.to_vec());
        let res = run(&c, &mut src);
        let gap = (res.storage_cost - res.miss_cost).abs()
            / (res.storage_cost + res.miss_cost).max(1e-12);
        if gap < best_gap {
            best_gap = gap;
            best_n = n;
        }
    }
    best_n
}

pub fn run_fig6_fig7_headline(ctx: &ExpContext) -> Result<Fig6Report> {
    let fixed_instances = calibrate_fixed_instances(&ctx.cfg, &ctx.trace);

    let run_one = |policy: PolicyKind, fixed_n: u32| -> RunReport {
        let mut cfg = ctx.cfg.clone();
        cfg.scaler.policy = policy;
        cfg.scaler.fixed_instances = fixed_n;
        run(&cfg, &mut ctx.source())
    };

    let fixed = run_one(PolicyKind::Fixed, fixed_instances);
    let ttl = run_one(PolicyKind::Ttl, fixed_instances);
    let mrc = run_one(PolicyKind::Mrc, fixed_instances);
    let ideal = run_one(PolicyKind::IdealTtl, fixed_instances);

    // Fig. 6: cumulative total cost, all four policies on one grid.
    let mut fixed_t = fixed.total_series.clone();
    fixed_t.name = "fixed".into();
    let mut ttl_t = ttl.total_series.clone();
    ttl_t.name = "ttl".into();
    let mut mrc_t = mrc.total_series.clone();
    mrc_t.name = "mrc".into();
    let mut ideal_t = ideal.total_series.clone();
    ideal_t.name = "ideal_ttl".into();
    std::fs::write(
        ctx.out_dir.join("fig6_cumulative_total.csv"),
        merged_csv(&[&fixed_t, &ttl_t, &mrc_t, &ideal_t]),
    )?;

    // Fig. 7: the two components.
    let mut comp = Vec::new();
    for r in [&fixed, &ttl, &mrc, &ideal] {
        let mut st = r.storage_series.clone();
        st.name = format!("{}_storage", r.policy);
        let mut mi = r.miss_series.clone();
        mi.name = format!("{}_miss", r.policy);
        comp.push(st);
        comp.push(mi);
    }
    let refs: Vec<&crate::metrics::TimeSeries> = comp.iter().collect();
    std::fs::write(ctx.out_dir.join("fig7_components.csv"), merged_csv(&refs))?;

    // Headline table.
    let report = Fig6Report { fixed, ttl, mrc, ideal, fixed_instances };
    let rows: Vec<Vec<String>> = [&report.fixed, &report.ttl, &report.mrc, &report.ideal]
        .iter()
        .map(|r| {
            let mut row = r.summary_row();
            row.push(format!("{:.4}", report.savings_vs_fixed(r)));
            row
        })
        .collect();
    ctx.write_csv(
        "headline_table.csv",
        &["policy", "requests", "miss_ratio", "storage_usd", "miss_usd", "total_usd", "saving_vs_fixed"],
        &rows,
    )?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn elastic_policies_beat_fixed_and_ideal_bounds_ttl() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig6_fig7_headline(&ctx).unwrap();

        // The paper's qualitative orderings (smoke-scale tolerances):
        // 1) TTL saves vs fixed.
        assert!(
            rep.savings_vs_fixed(&rep.ttl) > 0.02,
            "ttl saving {:.3} (fixed={:.4} ttl={:.4})",
            rep.savings_vs_fixed(&rep.ttl),
            rep.fixed.total_cost,
            rep.ttl.total_cost
        );
        // 2) MRC lands near TTL (within 30% of each other's total).
        let ratio = rep.ttl.total_cost / rep.mrc.total_cost;
        assert!((0.7..1.4).contains(&ratio), "ttl/mrc={ratio}");
        // 3) Ideal TTL is the cheapest TTL-family run.
        assert!(rep.ideal.total_cost <= rep.ttl.total_cost * 1.02);
        assert!(rep.ttl_gap_to_ideal() > -0.02);
        // Outputs exist.
        assert!(dir.path().join("fig6_cumulative_total.csv").exists());
        assert!(dir.path().join("fig7_components.csv").exists());
        assert!(dir.path().join("headline_table.csv").exists());
    }
}
