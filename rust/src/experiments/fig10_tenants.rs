//! Fig. 10 (ours, beyond the paper) — multi-tenant cost-aware
//! provisioning on one shared elastic cluster.
//!
//! Three tenants with a 10×-spread in per-miss cost (3.0 / 1.0 / 0.3) and
//! deliberately different traffic shapes share one cluster under the
//! [`crate::tenant::TenantTtlSizer`]. Claims demonstrated:
//!
//! * each tenant's §4 controller converges to its *own* TTL — the
//!   expensive-miss tenant holds content much longer than the cheap one;
//! * the aggregate cost of the shared elastic cluster beats the best
//!   *static partition* baseline (each tenant on its own fixed cluster,
//!   sized by an oracle sweep over candidate sizes), because sharing
//!   pools the diurnal valleys and avoids per-tenant integer-instance
//!   quantization (Memshare's argument, applied to elastic TTL sizing).

use super::{calibrate_miss_cost, ExpContext, TraceScale};
use crate::config::PolicyKind;
use crate::engine::{run, RunReport};
use crate::tenant::{TenantSpec, TrafficClass};
use crate::trace::{Request, SynthGenerator, TenantMux, VecSource};
use crate::Result;

/// Candidate per-tenant cluster sizes swept by the static baseline.
const STATIC_CANDIDATES: [u32; 6] = [1, 2, 3, 4, 6, 8];

/// Per-tenant outcome row.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub spec: TenantSpec,
    pub requests: u64,
    pub misses: u64,
    /// Final TTL of this tenant's controller in the shared elastic run.
    pub ttl_secs: f64,
    /// Weighted miss dollars this tenant accrued in the elastic run.
    pub miss_dollars: f64,
    /// Best static cluster size for this tenant alone…
    pub best_static_instances: u32,
    /// …and its total (storage + weighted miss) cost at that size.
    pub best_static_cost: f64,
}

/// Fig. 10 report.
#[derive(Debug)]
pub struct Fig10Report {
    pub outcomes: Vec<TenantOutcome>,
    pub elastic: RunReport,
    /// Aggregate cost of the shared elastic cluster.
    pub elastic_total: f64,
    /// Sum of the per-tenant best static clusters.
    pub static_total: f64,
}

impl Fig10Report {
    /// Fractional saving of the shared elastic cluster vs the best static
    /// per-tenant partition.
    pub fn saving_vs_static(&self) -> f64 {
        1.0 - self.elastic_total / self.static_total.max(1e-12)
    }

    /// max/min spread of the converged per-tenant TTLs.
    pub fn ttl_spread(&self) -> f64 {
        let ttls: Vec<f64> = self.outcomes.iter().map(|o| o.ttl_secs).collect();
        let max = ttls.iter().cloned().fold(f64::MIN, f64::max);
        let min = ttls.iter().cloned().fold(f64::MAX, f64::min);
        max / min.max(1e-9)
    }

    pub fn render(&self) -> String {
        let mut s = String::from(
            "Fig.10 — multi-tenant cost-aware provisioning (shared elastic cluster)\n\
             \x20 tenant  class        xmiss   requests   miss%    ttl_secs   miss$     best-static\n",
        );
        for o in &self.outcomes {
            let miss_ratio = if o.requests == 0 {
                0.0
            } else {
                o.misses as f64 / o.requests as f64
            };
            s.push_str(&format!(
                "  {:<7} {:<12} {:<7.2} {:<10} {:<8.4} {:<10.1} {:<9.4} n={} (${:.4})\n",
                o.spec.name,
                o.spec.class.as_str(),
                o.spec.miss_cost_multiplier,
                o.requests,
                miss_ratio,
                o.ttl_secs,
                o.miss_dollars,
                o.best_static_instances,
                o.best_static_cost,
            ));
        }
        s.push_str(&format!(
            "  ttl spread (max/min): {:.2}×\n\
             \x20 elastic shared total: ${:.4}   best static partition: ${:.4}   saving: {:+.1}%\n\
             \x20 expected shape: distinct per-tenant TTLs (expensive misses → longer T),\n\
             \x20 shared elastic total ≤ best static per-tenant partition\n",
            self.ttl_spread(),
            self.elastic_total,
            self.static_total,
            100.0 * self.saving_vs_static(),
        ));
        s
    }
}

/// The fig10 tenant roster: a 10× miss-cost spread across three classes.
pub fn tenant_specs() -> Vec<TenantSpec> {
    vec![
        TenantSpec::new(0, "api")
            .with_multiplier(3.0)
            .with_class(TrafficClass::Interactive),
        TenantSpec::new(1, "web")
            .with_multiplier(1.0)
            .with_class(TrafficClass::Standard),
        TenantSpec::new(2, "batch")
            .with_multiplier(0.3)
            .with_class(TrafficClass::Bulk),
    ]
}

/// The fig10 workload: three generators with distinct Zipf exponents,
/// catalogue sizes, rates, churn and diurnal amplitudes, muxed into one
/// time-ordered multi-tenant trace.
pub fn tenant_trace(scale: TraceScale, seed: u64) -> Vec<Request> {
    let base = scale.synth_config();
    let mut mux = TenantMux::new();

    // api: small hot catalogue, steep popularity, no churn — the classic
    // cacheable workload, and the one whose misses cost 3×.
    let mut api = base.clone();
    api.catalogue = (base.catalogue / 4).max(1_000);
    api.alpha = 1.05;
    api.mean_rate = base.mean_rate * 0.5;
    api.churn_per_day = 0.0;
    api.seed = seed ^ 0x00A1;

    // web: the standard Akamai-like profile.
    let mut web = base.clone();
    web.mean_rate = base.mean_rate * 0.7;
    web.seed = seed ^ 0x00B2;

    // batch: big cold catalogue, shallow popularity, heavy churn, weak
    // diurnality — caching buys little, and its misses are cheap.
    let mut batch = base.clone();
    batch.catalogue = base.catalogue * 2;
    batch.alpha = 0.6;
    batch.mean_rate = base.mean_rate * 0.35;
    batch.churn_per_day = 0.2;
    batch.diurnal_amplitude = 0.3;
    batch.seed = seed ^ 0x00C3;

    mux.add(0, Box::new(SynthGenerator::new(api)));
    mux.add(1, Box::new(SynthGenerator::new(web)));
    mux.add(2, Box::new(SynthGenerator::new(batch)));
    mux.generate()
}

pub fn run_fig10(ctx: &ExpContext, scale: TraceScale) -> Result<Fig10Report> {
    let specs = tenant_specs();
    let trace = tenant_trace(scale, 0xF16_10);

    // Shared elastic run: one cluster, one controller per tenant.
    let mut cfg = ctx.cfg.clone();
    cfg.scaler.policy = PolicyKind::TenantTtl;
    cfg.tenants = specs.clone();
    cfg.cost.miss_cost_dollars = calibrate_miss_cost(&cfg, &trace, 8);
    let elastic = run(&cfg, &mut VecSource::new(trace.clone()));

    // Static partition baseline: each tenant alone on its own fixed
    // cluster, swept over candidate sizes, billed at the same weighted
    // per-miss cost. The partition is unconstrained, so the sum of the
    // per-tenant optima *is* the best static split.
    let mut outcomes = Vec::new();
    let mut static_total = 0.0;
    for spec in &specs {
        let sub: Vec<Request> = trace.iter().filter(|r| r.tenant == spec.id).copied().collect();
        let mut best_n = STATIC_CANDIDATES[0];
        let mut best_cost = f64::INFINITY;
        for &n in &STATIC_CANDIDATES {
            if n > cfg.scaler.max_instances {
                continue;
            }
            let mut c = cfg.clone();
            c.tenants.clear();
            c.scaler.policy = PolicyKind::Fixed;
            c.scaler.fixed_instances = n;
            c.cost.miss_cost_dollars = cfg.cost.miss_cost_dollars * spec.miss_cost_multiplier;
            let res = run(&c, &mut VecSource::new(sub.clone()));
            if res.total_cost < best_cost {
                best_cost = res.total_cost;
                best_n = n;
            }
        }
        static_total += best_cost;
        let summary = elastic.tenants.iter().find(|t| t.tenant == spec.id);
        outcomes.push(TenantOutcome {
            spec: spec.clone(),
            requests: summary.map(|t| t.requests).unwrap_or(0),
            misses: summary.map(|t| t.misses).unwrap_or(0),
            ttl_secs: summary.and_then(|t| t.ttl_secs).unwrap_or(0.0),
            miss_dollars: summary.map(|t| t.miss_dollars).unwrap_or(0.0),
            best_static_instances: best_n,
            best_static_cost: best_cost,
        });
    }

    let report = Fig10Report {
        elastic_total: elastic.total_cost,
        static_total,
        outcomes,
        elastic,
    };

    // CSV artifacts.
    let rows: Vec<Vec<String>> = report
        .outcomes
        .iter()
        .map(|o| {
            vec![
                o.spec.id.to_string(),
                o.spec.name.clone(),
                o.spec.class.as_str().to_string(),
                format!("{:.3}", o.spec.miss_cost_multiplier),
                o.requests.to_string(),
                o.misses.to_string(),
                format!("{:.3}", o.ttl_secs),
                format!("{:.6}", o.miss_dollars),
                o.best_static_instances.to_string(),
                format!("{:.6}", o.best_static_cost),
            ]
        })
        .collect();
    ctx.write_csv(
        "fig10_tenant_summary.csv",
        &[
            "tenant", "name", "class", "miss_cost_multiplier", "requests", "misses",
            "ttl_secs", "miss_usd", "best_static_n", "best_static_usd",
        ],
        &rows,
    )?;
    ctx.write_csv(
        "fig10_totals.csv",
        &["variant", "total_usd"],
        &[
            vec!["elastic_shared".into(), format!("{:.6}", report.elastic_total)],
            vec!["best_static_partition".into(), format!("{:.6}", report.static_total)],
        ],
    )?;
    let inst_rows: Vec<Vec<String>> = report
        .elastic
        .instances_series
        .samples()
        .iter()
        .map(|&(t, v)| vec![format!("{:.3}", crate::us_to_secs(t) / 3600.0), format!("{v}")])
        .collect();
    ctx.write_csv("fig10_instances.csv", &["hour", "instances"], &inst_rows)?;

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn three_tenants_converge_apart_and_sharing_beats_static() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig10(&ctx, TraceScale::Smoke).unwrap();

        assert_eq!(rep.outcomes.len(), 3);
        for o in &rep.outcomes {
            assert!(o.requests > 10_000, "{:?}", o);
            assert!(o.ttl_secs > 0.0, "{:?}", o);
        }
        // Distinct per-tenant TTLs, ordered by miss-cost economics: the
        // 3× tenant must hold content longer than the 0.3× tenant.
        let by_name = |n: &str| {
            rep.outcomes
                .iter()
                .find(|o| o.spec.name == n)
                .unwrap()
        };
        let api = by_name("api");
        let batch = by_name("batch");
        assert!(
            api.ttl_secs > 1.2 * batch.ttl_secs,
            "api ttl {} should exceed batch ttl {}",
            api.ttl_secs,
            batch.ttl_secs
        );
        assert!(rep.ttl_spread() > 1.3, "spread {}", rep.ttl_spread());
        // The headline: sharing beats the best static partition (2%
        // numerical slack so a marginal smoke run cannot flake the suite;
        // the rendered report states the exact totals).
        assert!(
            rep.elastic_total <= rep.static_total * 1.02,
            "elastic {} vs static {}",
            rep.elastic_total,
            rep.static_total
        );
        // Artifacts exist.
        assert!(dir.path().join("fig10_tenant_summary.csv").exists());
        assert!(dir.path().join("fig10_totals.csv").exists());
        assert!(dir.path().join("fig10_instances.csv").exists());
    }
}
