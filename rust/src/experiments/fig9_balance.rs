//! Fig. 9 — load balance across servers under elastic resizing: min/max
//! slots, misses and requests per server, normalized by the per-server
//! mean. Paper: slots within ±2.5%, misses up to +10%, requests up to
//! +30% of the mean.

use super::ExpContext;
use crate::config::PolicyKind;
use crate::engine::{run, RunReport};
use crate::metrics::merged_csv;
use crate::Result;

#[derive(Debug)]
pub struct Fig9Report {
    pub result: RunReport,
    pub worst_slots: f64,
    pub worst_requests: f64,
    pub worst_misses: f64,
}

impl Fig9Report {
    pub fn render(&self) -> String {
        format!(
            "Fig.9 — per-server balance (max/mean across epochs)\n\
             \x20 slots    max {:.3}\n\
             \x20 misses   max {:.3}\n\
             \x20 requests max {:.3}\n\
             \x20 epochs   {}\n\
             \x20 spurious misses {} ({:.4}% of requests)\n\
             \x20 paper shape: slots tightest (±2.5%), then misses (+10%), requests loosest (+30%)\n",
            self.worst_slots,
            self.worst_misses,
            self.worst_requests,
            self.result.balance.snapshots().len(),
            self.result.spurious_misses,
            100.0 * self.result.spurious_misses as f64 / self.result.requests.max(1) as f64,
        )
    }
}

pub fn run_fig9(ctx: &ExpContext) -> Result<Fig9Report> {
    let mut cfg = ctx.cfg.clone();
    cfg.scaler.policy = PolicyKind::Ttl;
    let result = run(&cfg, &mut ctx.source());
    let (worst_slots, worst_requests, worst_misses) = result.balance.worst();

    let b = &result.balance;
    std::fs::write(
        ctx.out_dir.join("fig9_balance.csv"),
        merged_csv(&[
            &b.slots_min,
            &b.slots_max,
            &b.requests_min,
            &b.requests_max,
            &b.misses_min,
            &b.misses_max,
        ]),
    )?;

    Ok(Fig9Report { result, worst_slots, worst_requests, worst_misses })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::TraceScale;

    #[test]
    fn slots_are_tighter_than_requests() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let ctx = ExpContext::standard(TraceScale::Smoke, dir.path());
        let rep = run_fig9(&ctx).unwrap();
        // Random slot assignment keeps slots close to even…
        assert!(
            rep.worst_slots < 1.5,
            "slots max/mean {}",
            rep.worst_slots
        );
        // …while popularity skew makes request spread the loosest metric
        // (paper shape). Allow equality margins at smoke scale.
        assert!(
            rep.worst_requests >= rep.worst_slots * 0.95,
            "requests {} vs slots {}",
            rep.worst_requests,
            rep.worst_slots
        );
        assert!(dir.path().join("fig9_balance.csv").exists());
    }
}
