//! Decision-trace telemetry: a unified registry of counters / gauges /
//! timers with O(1) hot-path recording, plus the bounded epoch decision
//! journal that makes every epoch's control decisions auditable.
//!
//! Three pillars:
//!
//! * **Registry** ([`TelemetryRegistry`]) — named metrics resolved once
//!   into shared handles ([`Counter`], [`Gauge`], [`Timer`]); recording
//!   through a handle is a relaxed atomic integer store (no string
//!   lookup, no allocation, no locking — handles are `Arc<AtomicU64>`
//!   based and `Send`, so each shard worker of the sharded engine can
//!   own pre-resolved handles while the front merges them at scrape
//!   time). Timers are atomic log-bucket histograms (nanoseconds) that
//!   snapshot into a [`LogHistogram`] for interpolated quantiles
//!   ([`LogHistogram::quantile`]). Registry clones share one underlying
//!   metric table, so a handle resolved through any clone is visible to
//!   every other ([`prometheus_merged`] renders a sharded deployment's
//!   registries as one exposition with `shard="i"` labels plus
//!   cluster-level sums).
//! * **Decision journal** ([`Journal`], [`EpochDecisionRecord`]) — a
//!   bounded ring of per-epoch records: for every tenant, demand →
//!   granted, the reserved/pooled split, the TTL clamp and occupancy cap
//!   in force, bytes shed, admission denials, the SLO escalation level
//!   and the epoch's billing attribution. The engine's `JournalProbe`
//!   assembles one record per closed epoch; `engine::run` writes them as
//!   JSONL when `[telemetry] journal_path` is set; serve answers
//!   `WHY <tenant>` from the live journal.
//! * **Exposition** — [`TelemetryRegistry::prometheus`] renders the
//!   registry in Prometheus text format (histogram buckets, `tenant=`
//!   labels) for the serve `METRICS` command;
//!   [`TelemetryRegistry::snapshot`] yields flat `(metric, value)` rows
//!   for experiment CSV artifacts.
//!
//! Everything here is **off by default** (`[telemetry] enabled`): with
//! telemetry disabled no handle exists, no clock is read and the request
//! path is bit-for-bit the untelemetered one (pinned by `engine_parity`).

#![warn(missing_docs)]

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::metrics::LogHistogram;
use crate::{TenantId, TimeUs};

/// Log base of timer histograms: ~12% bucket resolution.
const TIMER_BASE: f64 = 1.12;
/// Largest resolvable timer sample: 60 s in nanoseconds.
const TIMER_MAX_NS: u64 = 60_000_000_000;

/// A shared registry handle. The registry is internally `Arc`-shared and
/// thread-safe; the `Rc<RefCell<…>>` wrapper survives for the monolithic
/// engine's probe plumbing, which hands one handle around a
/// single-threaded object graph.
pub type SharedRegistry = Rc<RefCell<TelemetryRegistry>>;
/// A shared decision-journal handle.
pub type SharedJournal = Rc<RefCell<Journal>>;

/// Pre-resolved counter handle: recording is one relaxed atomic add, so
/// the handle is `Send` and a shard worker can hold it across threads.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1 (wrapping).
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    /// Add `n` (saturating).
    #[inline]
    pub fn add(&self, n: u64) {
        let mut cur = self.0.load(Relaxed);
        loop {
            let next = cur.saturating_add(n);
            match self.0.compare_exchange_weak(cur, next, Relaxed, Relaxed) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Pre-resolved gauge handle: last-write-wins `f64`, stored bit-cast in
/// an atomic so the handle is `Send`.
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

/// The atomic storage behind a [`Timer`]: log-spaced nanosecond buckets
/// mirroring [`LogHistogram`]'s layout (zero bucket, per-decade buckets,
/// overflow), each an `AtomicU64` count, plus an exact integer sum.
struct AtomicHistogram {
    base: f64,
    ln_base: f64,
    counts: Vec<AtomicU64>,
    zero: AtomicU64,
    overflow: AtomicU64,
    total: AtomicU64,
    sum_ns: AtomicU64,
}

impl AtomicHistogram {
    fn new(base: f64, max_value: u64) -> AtomicHistogram {
        // Same bucket count as `LogHistogram::new` so a snapshot
        // round-trips losslessly through `LogHistogram::from_parts`.
        let nbuckets = LogHistogram::new(base, max_value).num_buckets();
        AtomicHistogram {
            base,
            ln_base: base.ln(),
            counts: (0..nbuckets).map(|_| AtomicU64::new(0)).collect(),
            zero: AtomicU64::new(0),
            overflow: AtomicU64::new(0),
            total: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    fn record(&self, ns: u64) {
        self.total.fetch_add(1, Relaxed);
        self.sum_ns.fetch_add(ns, Relaxed);
        if ns == 0 {
            self.zero.fetch_add(1, Relaxed);
            return;
        }
        let idx = ((ns as f64).ln() / self.ln_base) as usize;
        match self.counts.get(idx) {
            Some(c) => c.fetch_add(1, Relaxed),
            None => self.overflow.fetch_add(1, Relaxed),
        };
    }

    /// Snapshot into a plain [`LogHistogram`] (for quantiles / CDF).
    fn snapshot(&self) -> LogHistogram {
        LogHistogram::from_parts(
            self.base,
            self.counts.iter().map(|c| c.load(Relaxed) as f64).collect(),
            self.zero.load(Relaxed) as f64,
            self.overflow.load(Relaxed) as f64,
        )
    }
}

/// Pre-resolved timer handle: an atomic log-bucket histogram of
/// nanosecond samples plus an exact running sum (Prometheus `_sum`).
/// Recording is three relaxed atomic adds — no lock, `Send` + `Sync`.
#[derive(Clone)]
pub struct Timer {
    hist: Arc<AtomicHistogram>,
}

impl Timer {
    fn new() -> Timer {
        Timer { hist: Arc::new(AtomicHistogram::new(TIMER_BASE, TIMER_MAX_NS)) }
    }

    /// Record one duration sample, in nanoseconds.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.hist.record(ns);
    }

    /// Time `f` and record its wall-clock duration.
    #[inline]
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let t0 = std::time::Instant::now();
        let r = f();
        self.record_ns(t0.elapsed().as_nanos() as u64);
        r
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.hist.total.load(Relaxed)
    }

    /// Sum of recorded samples, nanoseconds.
    pub fn sum_ns(&self) -> f64 {
        self.hist.sum_ns.load(Relaxed) as f64
    }

    /// Interpolated quantile of the recorded samples, nanoseconds.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        self.hist.snapshot().quantile(q)
    }

    /// A point-in-time [`LogHistogram`] snapshot of the samples.
    pub fn histogram(&self) -> LogHistogram {
        self.hist.snapshot()
    }
}

impl std::fmt::Debug for Timer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Timer(count={}, sum_ns={})", self.count(), self.sum_ns())
    }
}

/// One registered metric: a name, an optional `tenant` label and the
/// shared handle.
struct Entry<H> {
    name: String,
    tenant: Option<TenantId>,
    handle: H,
}

/// The metric table behind a registry (shared by every clone).
#[derive(Default)]
struct RegistryInner {
    counters: Vec<Entry<Counter>>,
    gauges: Vec<Entry<Gauge>>,
    timers: Vec<Entry<Timer>>,
}

/// The unified registry: named counters, gauges and timers. Lookup (and
/// therefore locking + allocation) happens only at registration time —
/// the hot path holds pre-resolved lock-free handles. Clones share one
/// underlying table, so a shard worker attaching through its clone makes
/// the handles visible to the front's scrape.
#[derive(Default, Clone)]
pub struct TelemetryRegistry {
    inner: Arc<Mutex<RegistryInner>>,
}

fn resolve<H: Clone + Default>(
    entries: &mut Vec<Entry<H>>,
    name: &str,
    tenant: Option<TenantId>,
) -> H {
    if let Some(e) = entries.iter().find(|e| e.name == name && e.tenant == tenant) {
        return e.handle.clone();
    }
    let handle = H::default();
    entries.push(Entry { name: name.to_string(), tenant, handle: handle.clone() });
    handle
}

impl TelemetryRegistry {
    /// An empty registry.
    pub fn new() -> TelemetryRegistry {
        TelemetryRegistry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RegistryInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Counter {
        resolve(&mut self.lock().counters, name, None)
    }

    /// Get or create the counter `name{tenant="t"}`.
    pub fn tenant_counter(&self, name: &str, tenant: TenantId) -> Counter {
        resolve(&mut self.lock().counters, name, Some(tenant))
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        resolve(&mut self.lock().gauges, name, None)
    }

    /// Get or create the gauge `name{tenant="t"}`.
    pub fn tenant_gauge(&self, name: &str, tenant: TenantId) -> Gauge {
        resolve(&mut self.lock().gauges, name, Some(tenant))
    }

    /// Get or create the timer `name` (nanosecond histogram).
    pub fn timer(&self, name: &str) -> Timer {
        let mut inner = self.lock();
        if let Some(e) = inner.timers.iter().find(|e| e.name == name && e.tenant.is_none()) {
            return e.handle.clone();
        }
        let handle = Timer::new();
        inner.timers.push(Entry { name: name.to_string(), tenant: None, handle: handle.clone() });
        handle
    }

    /// Render the registry in Prometheus text exposition format:
    /// counters and gauges as single samples (with `tenant=` labels
    /// where registered), timers as histograms (`_bucket{le=…}` /
    /// `_sum` / `_count`) plus interpolated `_p50_ns` / `_p99_ns` /
    /// `_p999_ns` gauges.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let label = |t: Option<TenantId>| match t {
            Some(t) => format!("{{tenant=\"{t}\"}}"),
            None => String::new(),
        };
        let inner = self.lock();
        // One `# TYPE` line per metric name (labeled per-tenant series
        // share a name and must not repeat it).
        let mut seen: Vec<&str> = Vec::new();
        for e in &inner.counters {
            if !seen.contains(&e.name.as_str()) {
                seen.push(e.name.as_str());
                let _ = writeln!(out, "# TYPE {} counter", e.name);
            }
            let _ = writeln!(out, "{}{} {}", e.name, label(e.tenant), e.handle.get());
        }
        seen.clear();
        for e in &inner.gauges {
            if !seen.contains(&e.name.as_str()) {
                seen.push(e.name.as_str());
                let _ = writeln!(out, "# TYPE {} gauge", e.name);
            }
            let _ = writeln!(out, "{}{} {}", e.name, label(e.tenant), fmt_f64(e.handle.get()));
        }
        for e in &inner.timers {
            write_timer_exposition(&mut out, &e.name, &e.handle.histogram(), e.handle.sum_ns());
        }
        out
    }

    /// Flat `(metric, value)` rows for CSV artifacts: counters and
    /// gauges as-is (tenant labels folded into the metric name), timers
    /// expanded into `_count` / `_sum_ns` / `_p50_ns` / `_p99_ns` /
    /// `_p999_ns`.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let mut rows: Vec<(String, f64)> = Vec::new();
        let key = |name: &str, t: Option<TenantId>| match t {
            Some(t) => format!("{name}{{tenant={t}}}"),
            None => name.to_string(),
        };
        let inner = self.lock();
        for e in &inner.counters {
            rows.push((key(&e.name, e.tenant), e.handle.get() as f64));
        }
        for e in &inner.gauges {
            rows.push((key(&e.name, e.tenant), e.handle.get()));
        }
        for e in &inner.timers {
            let hist = e.handle.histogram();
            rows.push((format!("{}_count", e.name), hist.total()));
            rows.push((format!("{}_sum_ns", e.name), e.handle.sum_ns()));
            for (suffix, q) in [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)] {
                rows.push((format!("{}_{suffix}_ns", e.name), hist.quantile(q) as f64));
            }
        }
        rows
    }
}

/// One timer's histogram exposition block: moving buckets + `+Inf`,
/// `_sum` / `_count`, and the interpolated quantile gauges.
fn write_timer_exposition(out: &mut String, name: &str, hist: &LogHistogram, sum_ns: f64) {
    let _ = writeln!(out, "# TYPE {name} histogram");
    let total = hist.total();
    // Emit only the buckets where the cumulative count moves (plus
    // +Inf): zero-count runs carry no information and omitting them
    // keeps the wire reply compact.
    let mut prev = 0u64;
    for (edge, frac) in hist.cdf() {
        let cum = (frac * total).round() as u64;
        if cum == prev {
            continue;
        }
        prev = cum;
        let _ = writeln!(out, "{name}_bucket{{le=\"{edge}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", total as u64);
    let _ = writeln!(out, "{name}_sum {}", fmt_f64(sum_ns));
    let _ = writeln!(out, "{name}_count {}", total as u64);
    for (suffix, q) in [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)] {
        let _ = writeln!(out, "# TYPE {name}_{suffix}_ns gauge");
        let _ = writeln!(out, "{name}_{suffix}_ns {}", hist.quantile(q));
    }
}

/// Render a sharded deployment's registries as one Prometheus
/// exposition: the front registry's series verbatim (no `shard` label),
/// then every per-shard counter and gauge twice — once per shard under a
/// `shard="i"` label (tenant labels preserved) and once as the
/// cluster-level sum under the plain name. Shard timers merge into one
/// cluster-level histogram per name: per-shard latency splits would
/// multiply the reply by the shard count for little operator signal.
pub fn prometheus_merged(front: &TelemetryRegistry, shards: &[TelemetryRegistry]) -> String {
    let mut out = front.prometheus();
    let label = |s: usize, t: Option<TenantId>| match t {
        Some(t) => format!("{{shard=\"{s}\",tenant=\"{t}\"}}"),
        None => format!("{{shard=\"{s}\"}}"),
    };
    let sum_label = |t: Option<TenantId>| match t {
        Some(t) => format!("{{tenant=\"{t}\"}}"),
        None => String::new(),
    };
    let counters = collect_rows(shards, |i| &i.counters, |h| h.get() as f64);
    for (name, rows, sums) in counters {
        let _ = writeln!(out, "# TYPE {name} counter");
        for (shard, tenant, v) in rows {
            let _ = writeln!(out, "{name}{} {}", label(shard, tenant), v as u64);
        }
        for (tenant, v) in sums {
            let _ = writeln!(out, "{name}{} {}", sum_label(tenant), v as u64);
        }
    }
    let gauges = collect_rows(shards, |i| &i.gauges, |h| h.get());
    for (name, rows, sums) in gauges {
        let _ = writeln!(out, "# TYPE {name} gauge");
        for (shard, tenant, v) in rows {
            let _ = writeln!(out, "{name}{} {}", label(shard, tenant), fmt_f64(v));
        }
        for (tenant, v) in sums {
            let _ = writeln!(out, "{name}{} {}", sum_label(tenant), fmt_f64(v));
        }
    }
    for (name, hist, sum_ns) in merge_timers(shards) {
        write_timer_exposition(&mut out, &name, &hist, sum_ns);
    }
    out
}

/// Flat merged rows for CSV artifacts / `RunReport.telemetry`: the front
/// registry's rows verbatim, per-shard counter/gauge rows keyed
/// `name{shard=i}` (tenant folded in), cluster-level sums under the
/// plain key, and shard timers merged into one `_count` / `_sum_ns` /
/// quantile set per name.
pub fn snapshot_merged(
    front: &TelemetryRegistry,
    shards: &[TelemetryRegistry],
) -> Vec<(String, f64)> {
    let mut rows = front.snapshot();
    let key = |s: usize, t: Option<TenantId>| match t {
        Some(t) => format!("{{shard={s},tenant={t}}}"),
        None => format!("{{shard={s}}}"),
    };
    let sum_key = |t: Option<TenantId>| match t {
        Some(t) => format!("{{tenant={t}}}"),
        None => String::new(),
    };
    let counters = collect_rows(shards, |i| &i.counters, |h| h.get() as f64);
    let gauges = collect_rows(shards, |i| &i.gauges, |h| h.get());
    for (name, per_shard, sums) in counters.into_iter().chain(gauges) {
        for (shard, tenant, v) in per_shard {
            rows.push((format!("{name}{}", key(shard, tenant)), v));
        }
        for (tenant, v) in sums {
            rows.push((format!("{name}{}", sum_key(tenant)), v));
        }
    }
    for (name, hist, sum_ns) in merge_timers(shards) {
        rows.push((format!("{name}_count"), hist.total()));
        rows.push((format!("{name}_sum_ns"), sum_ns));
        for (suffix, q) in [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)] {
            rows.push((format!("{name}_{suffix}_ns"), hist.quantile(q) as f64));
        }
    }
    rows
}

/// Per-name merged view of one handle kind across shard registries:
/// `(name, [(shard, tenant, value)], [(tenant, Σ value)])`, names and
/// tenants in first-seen order.
type MergedRows =
    Vec<(String, Vec<(usize, Option<TenantId>, f64)>, Vec<(Option<TenantId>, f64)>)>;

fn collect_rows<H>(
    shards: &[TelemetryRegistry],
    pick: fn(&RegistryInner) -> &Vec<Entry<H>>,
    read: fn(&H) -> f64,
) -> MergedRows {
    let mut merged: MergedRows = Vec::new();
    for (shard, reg) in shards.iter().enumerate() {
        let inner = reg.lock();
        for e in pick(&inner) {
            let at = match merged.iter().position(|(n, _, _)| *n == e.name) {
                Some(at) => at,
                None => {
                    merged.push((e.name.clone(), Vec::new(), Vec::new()));
                    merged.len() - 1
                }
            };
            let slot = &mut merged[at];
            let v = read(&e.handle);
            slot.1.push((shard, e.tenant, v));
            match slot.2.iter().position(|(t, _)| *t == e.tenant) {
                Some(at) => slot.2[at].1 += v,
                None => slot.2.push((e.tenant, v)),
            }
        }
    }
    merged
}

/// Merge every shard's timers by name into `(name, histogram, Σ sum_ns)`.
fn merge_timers(shards: &[TelemetryRegistry]) -> Vec<(String, LogHistogram, f64)> {
    let mut merged: Vec<(String, LogHistogram, f64)> = Vec::new();
    for reg in shards {
        let inner = reg.lock();
        for e in &inner.timers {
            let hist = e.handle.histogram();
            let sum = e.handle.sum_ns();
            match merged.iter().position(|(n, _, _)| *n == e.name) {
                Some(at) => {
                    merged[at].1.merge(&hist);
                    merged[at].2 += sum;
                }
                None => merged.push((e.name.clone(), hist, sum)),
            }
        }
    }
    merged
}

/// Trim a float for exposition: integral values print without a
/// fractional part, everything else with enough digits to round-trip
/// operator-level reading.
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.9}")
    }
}

/// One tenant's slice of an epoch decision — what the arbiter granted,
/// what enforcement did about it, and what it cost.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantDecision {
    /// The tenant this row describes.
    pub tenant: TenantId,
    /// Shadow (virtual-cache) demand at the decision, bytes.
    pub demand_bytes: u64,
    /// Bytes granted by the arbiter (reserved floor included).
    pub granted_bytes: u64,
    /// Memshare-style reserved floor from the tenant's spec, bytes.
    pub reserved_bytes: u64,
    /// Grant minus reserved floor: the pooled top-up, bytes.
    pub pooled_bytes: u64,
    /// Occupancy cap in force after the decision (`None` = unenforced).
    pub cap_bytes: Option<u64>,
    /// TTL clamp in force on the tenant's controller, seconds.
    pub ttl_clamp_secs: Option<f64>,
    /// Physical resident bytes before the boundary's shedding.
    pub resident_before_bytes: u64,
    /// Physical resident bytes after the boundary (ledger row).
    pub resident_bytes: u64,
    /// Bytes shed at this boundary to bring the tenant under its cap
    /// (or to drain it).
    pub shed_bytes: u64,
    /// Admissions refused by the occupancy cap during the closed epoch.
    pub denied_admissions: u64,
    /// Inserts refused by the admission filter (`[admission] filter`)
    /// during the closed epoch — disjoint from `denied_admissions`.
    pub filter_denials: u64,
    /// Configured miss-ratio SLO, if any.
    pub slo_miss_ratio: Option<f64>,
    /// Measured physical miss ratio of the last closed epoch with
    /// traffic.
    pub measured_miss_ratio: Option<f64>,
    /// Grant-priority escalation factor (1.0 = compliant/untracked).
    pub boost: f64,
    /// Storage dollars attributed to this tenant for the closed epoch.
    pub bill_storage_dollars: f64,
    /// Miss dollars attributed to this tenant for the closed epoch.
    pub bill_miss_dollars: f64,
    /// Final reconciled lifetime bill, set on the record where the
    /// tenant's retirement completed.
    pub reconciled_dollars: Option<f64>,
}

impl TenantDecision {
    /// The causal decision this epoch took against the tenant, most
    /// severe first: bytes were `shed`, its timer was `ttl_clamp`ed,
    /// its grant was squeezed below demand (`grant_squeeze`), or the
    /// admission filter refused inserts (`filter_denied`). `None` when
    /// the epoch took no corrective action against this tenant.
    pub fn cause(&self) -> Option<&'static str> {
        if self.shed_bytes > 0 {
            Some("shed")
        } else if self.ttl_clamp_secs.is_some() {
            Some("ttl_clamp")
        } else if self.granted_bytes < self.demand_bytes {
            Some("grant_squeeze")
        } else if self.filter_denials > 0 {
            Some("filter_denied")
        } else {
            None
        }
    }

    /// One-line JSON rendering (shared by the JSONL journal and the
    /// serve `WHY` command).
    pub fn to_json(&self) -> String {
        let opt_u = |v: Option<u64>| v.map(|v| v.to_string()).unwrap_or_else(|| "null".into());
        let opt_f = |v: Option<f64>| v.map(|v| format!("{v:.6}")).unwrap_or_else(|| "null".into());
        format!(
            "{{\"tenant\":{},\"demand_bytes\":{},\"granted_bytes\":{},\"reserved_bytes\":{},\
             \"pooled_bytes\":{},\"cap_bytes\":{},\"ttl_clamp_secs\":{},\
             \"resident_before_bytes\":{},\"resident_bytes\":{},\"shed_bytes\":{},\
             \"denied_admissions\":{},\"filter_denials\":{},\
             \"slo_miss_ratio\":{},\"measured_miss_ratio\":{},\
             \"boost\":{:.3},\"bill_storage_dollars\":{:.9},\"bill_miss_dollars\":{:.9},\
             \"reconciled_dollars\":{},\"cause\":{}}}",
            self.tenant,
            self.demand_bytes,
            self.granted_bytes,
            self.reserved_bytes,
            self.pooled_bytes,
            opt_u(self.cap_bytes),
            opt_f(self.ttl_clamp_secs),
            self.resident_before_bytes,
            self.resident_bytes,
            self.shed_bytes,
            self.denied_admissions,
            self.filter_denials,
            opt_f(self.slo_miss_ratio),
            opt_f(self.measured_miss_ratio),
            self.boost,
            self.bill_storage_dollars,
            self.bill_miss_dollars,
            opt_f(self.reconciled_dollars),
            match self.cause() {
                Some(c) => format!("\"{c}\""),
                None => "null".into(),
            },
        )
    }
}

/// One epoch boundary's full decision record.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochDecisionRecord {
    /// Epoch-end timestamp (the boundary the decision was taken at).
    pub t: TimeUs,
    /// Zero-based index of the closed epoch.
    pub epoch: u64,
    /// Instance count after the sizing decision.
    pub instances: u32,
    /// Grantable capacity (`max_instances × instance bytes`) the
    /// arbiter decided against — Σ granted must never exceed it.
    pub capacity_bytes: u64,
    /// Cluster-wide storage dollars billed for the closed epoch.
    pub storage_dollars: f64,
    /// Cluster-wide miss dollars accrued over the closed epoch.
    pub miss_dollars: f64,
    /// Per-tenant decisions, tenant-ascending.
    pub tenants: Vec<TenantDecision>,
}

impl EpochDecisionRecord {
    /// This record's row for `tenant`, if it participated.
    pub fn tenant(&self, tenant: TenantId) -> Option<&TenantDecision> {
        self.tenants.iter().find(|d| d.tenant == tenant)
    }

    /// One-line JSON rendering (one JSONL line per epoch).
    pub fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"t\":{},\"epoch\":{},\"instances\":{},\"capacity_bytes\":{},\
             \"storage_dollars\":{:.9},\"miss_dollars\":{:.9},\"tenants\":[",
            self.t, self.epoch, self.instances, self.capacity_bytes, self.storage_dollars,
            self.miss_dollars,
        );
        for (i, d) in self.tenants.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&d.to_json());
        }
        s.push_str("]}");
        s
    }
}

/// Bounded ring of [`EpochDecisionRecord`]s: the newest `capacity`
/// records are retained (a serve deployment forcing epochs forever must
/// not grow without bound).
#[derive(Debug, Default)]
pub struct Journal {
    records: VecDeque<EpochDecisionRecord>,
    capacity: usize,
}

impl Journal {
    /// A journal retaining at most `capacity` records (min 1).
    pub fn new(capacity: usize) -> Journal {
        Journal { records: VecDeque::new(), capacity: capacity.max(1) }
    }

    /// Append a record, evicting the oldest past capacity.
    pub fn push(&mut self, rec: EpochDecisionRecord) {
        if self.records.len() == self.capacity {
            self.records.pop_front();
        }
        self.records.push_back(rec);
    }

    /// Retained records, oldest first.
    pub fn records(&self) -> impl Iterator<Item = &EpochDecisionRecord> {
        self.records.iter()
    }

    /// Number of retained records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether no record is retained.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The newest record.
    pub fn last(&self) -> Option<&EpochDecisionRecord> {
        self.records.back()
    }

    /// The newest record carrying a row for `tenant`, with that row.
    pub fn last_for(
        &self,
        tenant: TenantId,
    ) -> Option<(&EpochDecisionRecord, &TenantDecision)> {
        self.records
            .iter()
            .rev()
            .find_map(|r| r.tenant(tenant).map(|d| (r, d)))
    }

    /// All retained records as JSONL (one record per line).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            s.push_str(&r.to_json());
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decision(tenant: TenantId) -> TenantDecision {
        TenantDecision {
            tenant,
            demand_bytes: 1000,
            granted_bytes: 1000,
            reserved_bytes: 200,
            pooled_bytes: 800,
            cap_bytes: None,
            ttl_clamp_secs: None,
            resident_before_bytes: 900,
            resident_bytes: 900,
            shed_bytes: 0,
            denied_admissions: 0,
            filter_denials: 0,
            slo_miss_ratio: None,
            measured_miss_ratio: Some(0.25),
            boost: 1.0,
            bill_storage_dollars: 0.001,
            bill_miss_dollars: 0.002,
            reconciled_dollars: None,
        }
    }

    fn record(t: TimeUs, epoch: u64) -> EpochDecisionRecord {
        EpochDecisionRecord {
            t,
            epoch,
            instances: 2,
            capacity_bytes: 10_000,
            storage_dollars: 0.003,
            miss_dollars: 0.004,
            tenants: vec![decision(0), decision(7)],
        }
    }

    #[test]
    fn counters_gauges_timers_share_handles() {
        let reg = TelemetryRegistry::new();
        let a = reg.counter("elastictl_requests_total");
        let b = reg.counter("elastictl_requests_total");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5, "same name resolves to the same cell");
        let g = reg.gauge("elastictl_instances");
        g.set(3.0);
        assert_eq!(reg.gauge("elastictl_instances").get(), 3.0);
        let t = reg.timer("elastictl_epoch_decide_ns");
        t.record_ns(1_000);
        t.record_ns(2_000);
        let t2 = reg.timer("elastictl_epoch_decide_ns");
        assert_eq!(t2.count(), 2);
        assert_eq!(t2.sum_ns(), 3_000.0);
        assert!(t2.quantile_ns(0.5) >= 900 && t2.quantile_ns(0.5) <= 2_300);
        // Labeled handles are distinct per tenant.
        let c0 = reg.tenant_counter("elastictl_denied_total", 0);
        let c1 = reg.tenant_counter("elastictl_denied_total", 1);
        c0.inc();
        assert_eq!(c1.get(), 0);
        assert_eq!(reg.tenant_counter("elastictl_denied_total", 0).get(), 1);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let reg = TelemetryRegistry::new();
        reg.counter("elastictl_requests_total").add(42);
        reg.tenant_gauge("elastictl_granted_bytes", 3).set(1e6);
        let t = reg.timer("elastictl_epoch_decide_ns");
        t.record_ns(1500);
        let text = reg.prometheus();
        assert!(text.contains("# TYPE elastictl_requests_total counter"), "{text}");
        assert!(text.contains("elastictl_requests_total 42"), "{text}");
        assert!(text.contains("elastictl_granted_bytes{tenant=\"3\"} 1000000"), "{text}");
        assert!(text.contains("# TYPE elastictl_epoch_decide_ns histogram"), "{text}");
        assert!(text.contains("elastictl_epoch_decide_ns_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("elastictl_epoch_decide_ns_sum 1500"), "{text}");
        assert!(text.contains("elastictl_epoch_decide_ns_count 1"), "{text}");
        assert!(text.contains("elastictl_epoch_decide_ns_p99_ns "), "{text}");
        // Every line is a comment or `name[{labels}] value`.
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_once(' ')
                        .map(|(m, v)| !m.is_empty() && v.parse::<f64>().is_ok())
                        .unwrap_or(false),
                "unparseable exposition line: {line}"
            );
        }
    }

    #[test]
    fn snapshot_rows_cover_all_kinds() {
        let reg = TelemetryRegistry::new();
        reg.counter("c").add(7);
        reg.tenant_gauge("g", 2).set(0.5);
        reg.timer("t").record_ns(100);
        let rows = reg.snapshot();
        let get = |k: &str| rows.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
        assert_eq!(get("c"), Some(7.0));
        assert_eq!(get("g{tenant=2}"), Some(0.5));
        assert_eq!(get("t_count"), Some(1.0));
        assert_eq!(get("t_sum_ns"), Some(100.0));
        assert!(get("t_p999_ns").is_some());
    }

    #[test]
    fn handles_are_send_and_record_across_threads() {
        let reg = TelemetryRegistry::new();
        let c = reg.counter("elastictl_requests_total");
        let t = reg.timer("elastictl_serve_ns");
        let worker = std::thread::spawn(move || {
            c.inc();
            c.inc();
            t.record_ns(500);
        });
        worker.join().unwrap();
        assert_eq!(reg.counter("elastictl_requests_total").get(), 2);
        assert_eq!(reg.timer("elastictl_serve_ns").count(), 1);
        // Clones share the underlying table: a handle resolved through a
        // clone is visible to the original's scrape.
        let clone = reg.clone();
        clone.counter("elastictl_hits_total").inc();
        assert_eq!(reg.counter("elastictl_hits_total").get(), 1);
    }

    #[test]
    fn merged_exposition_labels_shards_and_sums() {
        let front = TelemetryRegistry::new();
        front.gauge("elastictl_instances").set(2.0);
        let shards: Vec<TelemetryRegistry> =
            (0..2).map(|_| TelemetryRegistry::new()).collect();
        shards[0].counter("elastictl_requests_total").add(3);
        shards[1].counter("elastictl_requests_total").add(5);
        shards[1].tenant_counter("elastictl_denied_total", 7).add(2);
        shards[0].timer("elastictl_serve_ns").record_ns(1_000);
        shards[1].timer("elastictl_serve_ns").record_ns(2_000);
        let text = prometheus_merged(&front, &shards);
        assert!(text.contains("elastictl_instances 2"), "{text}");
        assert!(text.contains("elastictl_requests_total{shard=\"0\"} 3"), "{text}");
        assert!(text.contains("elastictl_requests_total{shard=\"1\"} 5"), "{text}");
        assert!(text.contains("elastictl_requests_total 8"), "{text}");
        assert!(text.contains("elastictl_denied_total{shard=\"1\",tenant=\"7\"} 2"), "{text}");
        assert!(text.contains("elastictl_denied_total{tenant=\"7\"} 2"), "{text}");
        assert!(text.contains("elastictl_serve_ns_count 2"), "{text}");
        // The merged text is still line-parseable exposition.
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_once(' ')
                        .map(|(m, v)| !m.is_empty() && v.parse::<f64>().is_ok())
                        .unwrap_or(false),
                "unparseable exposition line: {line}"
            );
        }
        let rows = snapshot_merged(&front, &shards);
        let get = |k: &str| rows.iter().find(|(n, _)| n == k).map(|(_, v)| *v);
        assert_eq!(get("elastictl_requests_total{shard=0}"), Some(3.0));
        assert_eq!(get("elastictl_requests_total{shard=1}"), Some(5.0));
        assert_eq!(get("elastictl_requests_total"), Some(8.0));
        assert_eq!(get("elastictl_denied_total{shard=1,tenant=7}"), Some(2.0));
        assert_eq!(get("elastictl_serve_ns_count"), Some(2.0));
        assert_eq!(get("elastictl_serve_ns_sum_ns"), Some(3_000.0));
    }

    #[test]
    fn journal_bounds_and_lookup() {
        let mut j = Journal::new(3);
        assert!(j.is_empty());
        for i in 0..5u64 {
            j.push(record(i * 100, i));
        }
        assert_eq!(j.len(), 3, "bounded at capacity");
        assert_eq!(j.records().next().unwrap().epoch, 2, "oldest evicted");
        assert_eq!(j.last().unwrap().epoch, 4);
        let (r, d) = j.last_for(7).unwrap();
        assert_eq!(r.epoch, 4);
        assert_eq!(d.tenant, 7);
        assert!(j.last_for(99).is_none());
    }

    #[test]
    fn decision_cause_priority() {
        let mut d = decision(0);
        assert_eq!(d.cause(), None, "full grant, no action");
        d.filter_denials = 3;
        assert_eq!(d.cause(), Some("filter_denied"));
        d.granted_bytes = 500;
        assert_eq!(d.cause(), Some("grant_squeeze"));
        d.ttl_clamp_secs = Some(60.0);
        assert_eq!(d.cause(), Some("ttl_clamp"));
        d.shed_bytes = 100;
        assert_eq!(d.cause(), Some("shed"));
    }

    #[test]
    fn decision_json_carries_filter_denials() {
        let mut d = decision(0);
        d.filter_denials = 9;
        let json = d.to_json();
        assert!(json.contains("\"filter_denials\":9"), "{json}");
        assert!(json.contains("\"cause\":\"filter_denied\""), "{json}");
    }

    #[test]
    fn record_json_is_one_line_and_balanced() {
        let mut rec = record(3_600_000_000, 0);
        rec.tenants[1].cap_bytes = Some(4096);
        rec.tenants[1].ttl_clamp_secs = Some(12.5);
        rec.tenants[1].granted_bytes = 500;
        let json = rec.to_json();
        assert!(!json.contains('\n'));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(json.contains("\"cap_bytes\":4096"), "{json}");
        assert!(json.contains("\"cap_bytes\":null"), "{json}");
        assert!(json.contains("\"cause\":\"ttl_clamp\""), "{json}");
        assert!(json.contains("\"cause\":null"), "{json}");
        let mut j = Journal::new(8);
        j.push(rec.clone());
        j.push(rec);
        assert_eq!(j.to_jsonl().lines().count(), 2);
    }
}
