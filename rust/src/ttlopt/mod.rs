//! TTL-OPT (§4.2, Algorithm 1): the clairvoyant per-request-optimal TTL
//! policy. Knowing the next request time of each object, store it until
//! then iff the storage cost of the gap is below the miss cost; otherwise
//! serve without storing. Proposition 2 proves this minimizes total cost;
//! it is computable offline in linear time and serves as the lower bound
//! of Fig. 8.
//!
//! A Bélády byte-capacity baseline is included for context (§4.2 notes
//! that under heterogeneous sizes optimal *replacement* is NP-complete;
//! Bélády is the classical uniform-size heuristic).

use crate::config::CostConfig;
use crate::cost::CostTracker;
use crate::metrics::TimeSeries;
use crate::trace::Request;
use crate::{us_to_secs, TimeUs};
use std::collections::HashMap;

/// Result of the clairvoyant solve.
#[derive(Debug)]
pub struct TtlOptResult {
    pub requests: u64,
    pub misses: u64,
    /// Requests served from cache (stored across the preceding gap).
    pub hits: u64,
    pub storage_cost: f64,
    pub miss_cost: f64,
    pub total_cost: f64,
    /// Cumulative total cost sampled at epoch boundaries (Fig. 8).
    pub total_series: TimeSeries,
    /// Peak simultaneous bytes the policy would hold.
    pub peak_bytes: u64,
}

impl TtlOptResult {
    pub fn miss_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.misses as f64 / self.requests as f64
        }
    }
}

/// Compute, for each request index, the timestamp of the *next* request
/// for the same object (`None` for last occurrences) — one backward pass.
pub fn next_request_times(trace: &[Request]) -> Vec<Option<TimeUs>> {
    let mut next: Vec<Option<TimeUs>> = vec![None; trace.len()];
    let mut last_seen: HashMap<u64, TimeUs> = HashMap::new();
    for (i, r) in trace.iter().enumerate().rev() {
        // Tenant-scoped so multi-tenant traces don't alias across tenants.
        let key = crate::tenant::scoped_object(r.tenant, r.obj);
        next[i] = last_seen.get(&key).copied();
        last_seen.insert(key, r.ts);
    }
    next
}

/// Run Algorithm 1 over an in-memory trace.
pub fn solve(trace: &[Request], cost: &CostConfig) -> TtlOptResult {
    let next = next_request_times(trace);
    let mut costs = CostTracker::new(cost.clone());
    let mut total_series = TimeSeries::new("ttlopt_total_cum");
    let epoch_us = cost.epoch_us.max(1);
    let mut epoch_end = epoch_us;

    let mut misses = 0u64;
    let mut hits = 0u64;
    // Objects currently stored until their next request (decided at the
    // previous request). Tracks the instantaneous footprint.
    let mut stored_until: HashMap<u64, (TimeUs, u64)> = HashMap::new();
    let mut cur_bytes = 0u64;
    let mut peak_bytes = 0u64;

    for (i, r) in trace.iter().enumerate() {
        while r.ts >= epoch_end {
            costs.end_epoch_vertical(epoch_end);
            total_series.push(epoch_end, costs.total());
            epoch_end += epoch_us;
        }
        // Was this request covered by a storage decision?
        let key = crate::tenant::scoped_object(r.tenant, r.obj);
        let covered = match stored_until.remove(&key) {
            Some((until, bytes)) => {
                debug_assert!(until == r.ts);
                cur_bytes -= bytes;
                true
            }
            None => false,
        };
        if covered {
            hits += 1;
        } else {
            misses += 1;
            costs.record_miss(r.size_bytes());
        }
        // Decide for the gap to the next request (Algorithm 1 lines 3–8).
        if let Some(t_next) = next[i] {
            let gap_secs = us_to_secs(t_next - r.ts);
            let store_cost = cost.storage_rate(r.size_bytes()) * gap_secs;
            if store_cost < cost.miss_cost(r.size_bytes()) {
                costs.record_storage_dollars(store_cost);
                stored_until.insert(key, (t_next, r.size_bytes()));
                cur_bytes += r.size_bytes();
                peak_bytes = peak_bytes.max(cur_bytes);
            }
        }
    }
    costs.end_epoch_vertical(epoch_end);
    total_series.push(epoch_end, costs.total());

    TtlOptResult {
        requests: trace.len() as u64,
        misses,
        hits,
        storage_cost: costs.storage_total(),
        miss_cost: costs.miss_total(),
        total_cost: costs.total(),
        total_series,
        peak_bytes,
    }
}

/// Bélády's clairvoyant *replacement* baseline at a fixed byte capacity:
/// evict the resident object whose next use is farthest in the future.
/// O(log M) per request via a max-heap on next-use times (lazy deletion).
/// Not cost-optimal under heterogeneous sizes (§4.2 / [24]) — included to
/// contextualize TTL-OPT.
pub fn belady_miss_ratio(trace: &[Request], capacity: u64) -> f64 {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let next = next_request_times(trace);
    // Heap of (next_use_time, obj); stale entries skipped on pop.
    let mut heap: BinaryHeap<(TimeUs, u64)> = BinaryHeap::new();
    let mut resident: HashMap<u64, (u64, TimeUs)> = HashMap::new(); // obj -> (size, next_use)
    let mut used = 0u64;
    let mut misses = 0u64;
    let _ = Reverse(0u8); // keep the import local and explicit

    for (i, r) in trace.iter().enumerate() {
        let nu = next[i].unwrap_or(TimeUs::MAX);
        match resident.get_mut(&r.obj) {
            Some(entry) => {
                entry.1 = nu;
                heap.push((nu, r.obj));
            }
            None => {
                misses += 1;
                if r.size_bytes() <= capacity {
                    while used + r.size_bytes() > capacity {
                        // Evict farthest-next-use resident object.
                        match heap.pop() {
                            Some((t, obj)) => {
                                if resident.get(&obj).map(|e| e.1) == Some(t) {
                                    let (sz, _) = resident.remove(&obj).unwrap();
                                    used -= sz;
                                }
                            }
                            None => break,
                        }
                    }
                    if used + r.size_bytes() <= capacity {
                        resident.insert(r.obj, (r.size_bytes(), nu));
                        heap.push((nu, r.obj));
                        used += r.size_bytes();
                    }
                }
            }
        }
    }
    misses as f64 / trace.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostConfig;
    use crate::SECOND;

    fn req(ts: u64, obj: u64, size: u32) -> Request {
        Request::new(ts, obj, size)
    }

    #[test]
    fn next_request_backward_pass() {
        let trace = vec![req(0, 1, 10), req(5, 2, 10), req(9, 1, 10), req(12, 1, 10)];
        let next = next_request_times(&trace);
        assert_eq!(next, vec![Some(9), None, Some(12), None]);
    }

    #[test]
    fn stores_iff_gap_cheaper_than_miss() {
        let cost = CostConfig::default();
        // Gap so short that storing is cheaper: hit expected.
        let trace = vec![req(0, 1, 1000), req(SECOND, 1, 1000)];
        let res = solve(&trace, &cost);
        assert_eq!(res.misses, 1); // only the cold first request
        assert_eq!(res.hits, 1);
        assert!(res.storage_cost > 0.0);

        // Gap of a year for a big object: storing would cost ≫ miss.
        let trace2 = vec![req(0, 1, 50_000_000), req(365 * crate::DAY, 1, 50_000_000)];
        let res2 = solve(&trace2, &cost);
        assert_eq!(res2.misses, 2);
        assert_eq!(res2.hits, 0);
        assert_eq!(res2.storage_cost, 0.0);
    }

    #[test]
    fn indifference_boundary_prefers_not_storing() {
        // Exactly equal costs: Algorithm 1 uses strict `<`, so no store.
        let mut cost = CostConfig::default();
        cost.miss_cost_dollars = 1.0;
        // pick size/gap so storage == miss exactly: rate*gap = 1.0
        let rate = cost.storage_rate(1_000_000);
        let gap_secs = 1.0 / rate;
        let gap_us = (gap_secs * 1e6) as u64;
        let trace = vec![req(0, 1, 1_000_000), req(gap_us, 1, 1_000_000)];
        let res = solve(&trace, &cost);
        // floating rounding may fall either side of the boundary, but cost
        // must equal min(storage, miss) for the second request:
        let expect = 1.0 + 1.0f64.min(rate * us_to_secs(gap_us));
        assert!((res.total_cost - expect).abs() < 1e-6);
    }

    #[test]
    fn ttlopt_is_a_lower_bound_for_per_object_costs() {
        // For any single-object trace, cost must equal:
        // m + Σ_gaps min(m, c·gap).
        let cost = CostConfig::default();
        let gaps = [1u64, 10, 100, 10_000, 1_000_000];
        let mut t = 0u64;
        let mut trace = vec![req(0, 7, 123_456)];
        for g in gaps {
            t += g * SECOND;
            trace.push(req(t, 7, 123_456));
        }
        let res = solve(&trace, &cost);
        let m = cost.miss_cost(123_456);
        let c = cost.storage_rate(123_456);
        let expect: f64 = m
            + gaps
                .iter()
                .map(|&g| m.min(c * g as f64))
                .sum::<f64>();
        assert!(
            (res.total_cost - expect).abs() < 1e-9,
            "got {} expect {}",
            res.total_cost,
            expect
        );
    }

    #[test]
    fn peak_bytes_tracks_overlapping_storage() {
        let cost = CostConfig::default();
        let trace = vec![
            req(0, 1, 1000),
            req(1, 2, 2000),
            req(2 * SECOND, 1, 1000),
            req(3 * SECOND, 2, 2000),
        ];
        let res = solve(&trace, &cost);
        assert_eq!(res.peak_bytes, 3000);
    }

    #[test]
    fn belady_basic() {
        // Capacity for one object; A B A pattern with tight capacity.
        let trace = vec![
            req(0, 1, 100),
            req(1, 2, 100),
            req(2, 1, 100),
            req(3, 2, 100),
        ];
        // capacity 100: each insert evicts the other → all misses
        let mr_small = belady_miss_ratio(&trace, 100);
        assert_eq!(mr_small, 1.0);
        // capacity 200: both fit → 2 cold misses only
        let mr_big = belady_miss_ratio(&trace, 200);
        assert_eq!(mr_big, 0.5);
    }

    #[test]
    fn belady_beats_or_equals_lru_on_miss_ratio() {
        use crate::cache::{LruCache, Store};
        use crate::trace::{SynthConfig, SynthGenerator};
        let trace = SynthGenerator::new(SynthConfig::tiny()).generate();
        let cap = 50_000_000u64;
        let mut lru = LruCache::new(cap);
        let mut lru_misses = 0u64;
        for r in &trace {
            if !lru.lookup(r.obj) {
                lru_misses += 1;
                lru.insert(r.obj, r.size_bytes());
            }
        }
        let lru_mr = lru_misses as f64 / trace.len() as f64;
        let belady_mr = belady_miss_ratio(&trace, cap);
        assert!(
            belady_mr <= lru_mr + 1e-9,
            "belady {belady_mr} vs lru {lru_mr}"
        );
    }
}
