//! Durable billing for the server runtime: an append-only journal of
//! every closed epoch's billing delta, fsync'd record by record, replayed
//! idempotently on `serve --resume`.
//!
//! ## File format
//!
//! One record per line, length-prefixed so a torn tail is detectable:
//!
//! ```text
//! <decimal byte length of the JSON text> <JSON object>\n
//! ```
//!
//! The JSON object is a superset of the telemetry journal's epoch record:
//!
//! ```text
//! {"v":1,"epoch":N,"t":...,"instances":...,
//!  "storage_dollars":...,"miss_dollars":...,"miss_count":...,
//!  "bills":[{"t":...,"tenant":...,"storage":...,"miss":...},...],
//!  "reconciliations":[{"tenant":...,"at":...,"misses":...,
//!                      "miss_dollars":...,"storage_dollars":...,
//!                      "total_dollars":...},...],
//!  "ledgers":[{"tenant":...,"misses":...,"miss_dollars":...,
//!              "storage_dollars":...},...],
//!  "cum_storage_dollars":...,"cum_miss_dollars":...}
//! ```
//!
//! `epoch` is the cost tracker's 1-based closed-epoch count after the
//! close; `bills` are the epoch's [`TenantEpochBill`] rows;
//! `reconciliations` the tenant close-outs that happened at this
//! boundary; `ledgers` the cumulative per-tenant ledger snapshot taken
//! immediately after the close (open accruals are zero there). Dollars
//! are rendered with Rust's shortest-round-trip `f64` formatting and
//! parsed back with `str::parse::<f64>`, so a resumed tracker's
//! cumulative bills are **bit-identical** to the crashed run's — the
//! `cum_*` fields exist as an independent cross-check
//! (`scripts/journal_check.py`), not as the restore source.
//!
//! A record is durable once its `write` returned: the writer fsyncs
//! (`sync_data`) after every record. A process killed mid-write leaves a
//! torn tail; [`read`] detects it (length prefix vs remaining bytes, or
//! a JSON parse failure) and drops it with a warning instead of
//! crashing — the epoch it described was not durably billed, exactly as
//! if the kill had landed a moment earlier.

use crate::cost::{CostTracker, EpochCosts, TenantEpochBill, TenantLedger, TenantReconciliation};
use crate::engine::{Engine, ShardedEngine};
use crate::{Result, TenantId};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

/// One closed epoch's durable billing delta (see the module doc for the
/// wire schema).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointRecord {
    /// 1-based closed-epoch count after this close.
    pub epoch: u64,
    /// The epoch's cluster-level bill.
    pub costs: EpochCosts,
    /// The epoch's per-tenant bill rows (tenant id ascending).
    pub bills: Vec<TenantEpochBill>,
    /// Tenant close-outs reconciled at this boundary.
    pub reconciliations: Vec<TenantReconciliation>,
    /// Cumulative per-tenant ledger snapshot right after the close.
    pub ledgers: Vec<(TenantId, TenantLedger)>,
    /// Cumulative closed storage dollars (cross-check, not restore source).
    pub cum_storage_dollars: f64,
    /// Cumulative closed miss dollars (cross-check, not restore source).
    pub cum_miss_dollars: f64,
}

impl CheckpointRecord {
    /// Render the record as its one-line JSON wire form.
    pub fn to_json(&self) -> String {
        let mut bills = String::new();
        for (i, b) in self.bills.iter().enumerate() {
            if i > 0 {
                bills.push(',');
            }
            bills.push_str(&format!(
                "{{\"t\":{},\"tenant\":{},\"storage\":{},\"miss\":{}}}",
                b.t, b.tenant, b.storage, b.miss
            ));
        }
        let mut recs = String::new();
        for (i, r) in self.reconciliations.iter().enumerate() {
            if i > 0 {
                recs.push(',');
            }
            recs.push_str(&format!(
                "{{\"tenant\":{},\"at\":{},\"misses\":{},\"miss_dollars\":{},\
                 \"storage_dollars\":{},\"total_dollars\":{}}}",
                r.tenant, r.at, r.misses, r.miss_dollars, r.storage_dollars, r.total_dollars
            ));
        }
        let mut ledgers = String::new();
        for (i, (t, l)) in self.ledgers.iter().enumerate() {
            if i > 0 {
                ledgers.push(',');
            }
            ledgers.push_str(&format!(
                "{{\"tenant\":{},\"misses\":{},\"miss_dollars\":{},\"storage_dollars\":{}}}",
                t, l.misses, l.miss_dollars, l.storage_dollars
            ));
        }
        format!(
            "{{\"v\":1,\"epoch\":{},\"t\":{},\"instances\":{},\"storage_dollars\":{},\
             \"miss_dollars\":{},\"miss_count\":{},\"bills\":[{}],\"reconciliations\":[{}],\
             \"ledgers\":[{}],\"cum_storage_dollars\":{},\"cum_miss_dollars\":{}}}",
            self.epoch,
            self.costs.t,
            self.costs.instances,
            self.costs.storage,
            self.costs.miss,
            self.costs.miss_count,
            bills,
            recs,
            ledgers,
            self.cum_storage_dollars,
            self.cum_miss_dollars,
        )
    }

    /// Parse one record from its JSON wire form.
    pub fn from_json(text: &str) -> Result<CheckpointRecord> {
        let v = Json::parse(text)?;
        anyhow::ensure!(v.get_u64("v")? == 1, "unknown checkpoint record version");
        let mut bills = Vec::new();
        for b in v.get_arr("bills")? {
            bills.push(TenantEpochBill {
                t: b.get_u64("t")?,
                tenant: b.get_u64("tenant")? as TenantId,
                storage: b.get_f64("storage")?,
                miss: b.get_f64("miss")?,
            });
        }
        let mut reconciliations = Vec::new();
        for r in v.get_arr("reconciliations")? {
            reconciliations.push(TenantReconciliation {
                tenant: r.get_u64("tenant")? as TenantId,
                at: r.get_u64("at")?,
                misses: r.get_u64("misses")?,
                miss_dollars: r.get_f64("miss_dollars")?,
                storage_dollars: r.get_f64("storage_dollars")?,
                total_dollars: r.get_f64("total_dollars")?,
            });
        }
        let mut ledgers = Vec::new();
        for l in v.get_arr("ledgers")? {
            ledgers.push((
                l.get_u64("tenant")? as TenantId,
                TenantLedger {
                    misses: l.get_u64("misses")?,
                    miss_dollars: l.get_f64("miss_dollars")?,
                    storage_dollars: l.get_f64("storage_dollars")?,
                },
            ));
        }
        Ok(CheckpointRecord {
            epoch: v.get_u64("epoch")?,
            costs: EpochCosts {
                t: v.get_u64("t")?,
                storage: v.get_f64("storage_dollars")?,
                miss: v.get_f64("miss_dollars")?,
                miss_count: v.get_u64("miss_count")?,
                instances: v.get_u64("instances")? as u32,
            },
            bills,
            reconciliations,
            ledgers,
            cum_storage_dollars: v.get_f64("cum_storage_dollars")?,
            cum_miss_dollars: v.get_f64("cum_miss_dollars")?,
        })
    }
}

/// Append-only, fsync-per-record checkpoint writer.
pub struct CheckpointWriter {
    file: File,
}

impl CheckpointWriter {
    /// Open `path` for appending (created if absent). Records already in
    /// the file are left untouched — replay them first and seed the
    /// [`CheckpointCursor`] from the restored engine.
    pub fn append(path: &Path) -> Result<CheckpointWriter> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(CheckpointWriter { file })
    }

    /// Write one length-prefixed record and fsync it. On return the
    /// epoch is durably billed.
    pub fn write(&mut self, rec: &CheckpointRecord) -> Result<()> {
        let json = rec.to_json();
        let mut buf = Vec::with_capacity(json.len() + 16);
        buf.extend_from_slice(json.len().to_string().as_bytes());
        buf.push(b' ');
        buf.extend_from_slice(json.as_bytes());
        buf.push(b'\n');
        self.file.write_all(&buf)?;
        self.file.sync_data()?;
        Ok(())
    }
}

/// Read every intact record of a checkpoint file. A torn or corrupt tail
/// (kill mid-write) is dropped with a warning on stderr, never an error:
/// the records before it are exactly the durably billed epochs.
pub fn read(path: &Path) -> Result<Vec<CheckpointRecord>> {
    let bytes = std::fs::read(path)?;
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let mut len = 0usize;
        let mut digits = 0usize;
        while pos < bytes.len() && bytes[pos].is_ascii_digit() && digits <= 9 {
            len = len * 10 + (bytes[pos] - b'0') as usize;
            pos += 1;
            digits += 1;
        }
        if digits == 0 || digits > 9 || bytes.get(pos) != Some(&b' ') {
            warn_tail(path, out.len(), "bad length prefix");
            break;
        }
        pos += 1;
        if bytes.get(pos + len) != Some(&b'\n') {
            warn_tail(path, out.len(), "truncated record");
            break;
        }
        let parsed = std::str::from_utf8(&bytes[pos..pos + len])
            .map_err(anyhow::Error::from)
            .and_then(CheckpointRecord::from_json);
        match parsed {
            Ok(rec) => out.push(rec),
            Err(e) => {
                warn_tail(path, out.len(), &format!("unparseable record: {e}"));
                break;
            }
        }
        pos += len + 1;
    }
    Ok(out)
}

fn warn_tail(path: &Path, intact: usize, what: &str) {
    eprintln!(
        "elastictl serve: dropping torn checkpoint tail in {} after {} intact record(s) ({what})",
        path.display(),
        intact
    );
}

/// Replay checkpoint records into a freshly built (or already partially
/// restored) engine. Idempotent: records at or before the engine's
/// closed-epoch count are skipped, so replaying the same file twice — or
/// a file that overlaps what the engine already billed — changes
/// nothing. A gap in the epoch sequence ends the replay there (the
/// records after it cannot be attributed). Returns the number of epochs
/// restored.
pub fn replay(engine: &mut Engine, records: &[CheckpointRecord]) -> u64 {
    let d = collect_replay(engine.costs().epochs(), records);
    let n = d.epochs.len() as u64;
    if n > 0 {
        engine.restore_closed_epochs(&d.epochs, &d.bills, &d.reconciliations, &d.ledgers);
    }
    n
}

/// [`replay`] for the sharded engine (`serve --resume` under
/// `[engine] shards > 1`): the same idempotent cull, restored through
/// [`ShardedEngine::restore_closed_epochs`] so the resumed instance
/// count fans back out across the shard clusters.
pub fn replay_sharded(engine: &mut ShardedEngine, records: &[CheckpointRecord]) -> u64 {
    let d = collect_replay(engine.costs().epochs(), records);
    let n = d.epochs.len() as u64;
    if n > 0 {
        engine.restore_closed_epochs(&d.epochs, &d.bills, &d.reconciliations, &d.ledgers);
    }
    n
}

/// The closed-epoch delta a replay applies: everything past `done`
/// closed epochs, stopping at the first gap in the epoch sequence.
struct ReplayDelta {
    epochs: Vec<EpochCosts>,
    bills: Vec<TenantEpochBill>,
    reconciliations: Vec<TenantReconciliation>,
    ledgers: Vec<(TenantId, TenantLedger)>,
}

fn collect_replay(mut done: u64, records: &[CheckpointRecord]) -> ReplayDelta {
    let mut d = ReplayDelta {
        epochs: Vec::new(),
        bills: Vec::new(),
        reconciliations: Vec::new(),
        ledgers: Vec::new(),
    };
    for r in records {
        if r.epoch <= done {
            continue; // already billed — idempotent resume
        }
        if r.epoch != done + 1 {
            eprintln!(
                "elastictl serve: checkpoint epoch gap ({} then {}), ignoring the rest",
                done, r.epoch
            );
            break;
        }
        done += 1;
        d.epochs.push(r.costs);
        d.bills.extend_from_slice(&r.bills);
        d.reconciliations.extend_from_slice(&r.reconciliations);
        d.ledgers = r.ledgers.clone();
    }
    d
}

/// Cursor over a live engine's cost ledger: remembers how much has been
/// checkpointed and yields one [`CheckpointRecord`] per epoch closed
/// since. The server drains it after every handled message (manual-epoch
/// mode closes at most one epoch per message, so the per-record bill
/// partition is exact).
#[derive(Debug, Default)]
pub struct CheckpointCursor {
    epochs: u64,
    bills: usize,
    reconciliations: usize,
}

impl CheckpointCursor {
    /// Seed the cursor from an engine whose current state is already
    /// durable (a fresh engine, or one just restored by [`replay`]).
    pub fn caught_up(engine: &Engine) -> CheckpointCursor {
        Self::caught_up_costs(engine.costs())
    }

    /// [`Self::caught_up`] from the cost tracker alone — the sharded
    /// front keeps its closed-epoch state outside an [`Engine`].
    pub fn caught_up_costs(costs: &CostTracker) -> CheckpointCursor {
        CheckpointCursor {
            epochs: costs.epochs(),
            bills: costs.tenant_bills().len(),
            reconciliations: costs.reconciliations().len(),
        }
    }

    /// Records for every epoch closed since the last drain.
    pub fn drain(&mut self, engine: &Engine) -> Vec<CheckpointRecord> {
        self.drain_costs(engine.costs(), engine.closed_epochs())
    }

    /// [`Self::drain`] from the cost tracker and closed-epoch rows alone
    /// (the sharded front's durable path).
    pub fn drain_costs(
        &mut self,
        costs: &CostTracker,
        closed: &[EpochCosts],
    ) -> Vec<CheckpointRecord> {
        let mut out = Vec::new();
        while self.epochs < costs.epochs() {
            let e = closed[self.epochs as usize];
            let all_bills = costs.tenant_bills();
            let mut bills = Vec::new();
            while self.bills < all_bills.len() && all_bills[self.bills].t == e.t {
                bills.push(all_bills[self.bills]);
                self.bills += 1;
            }
            let all_recs = costs.reconciliations();
            let mut recs = Vec::new();
            while self.reconciliations < all_recs.len()
                && all_recs[self.reconciliations].at == e.t
            {
                recs.push(all_recs[self.reconciliations]);
                self.reconciliations += 1;
            }
            self.epochs += 1;
            out.push(CheckpointRecord {
                epoch: self.epochs,
                costs: e,
                bills,
                reconciliations: recs,
                ledgers: costs
                    .tenant_ledgers()
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| (i as TenantId, l))
                    .collect(),
                cum_storage_dollars: costs.storage_total(),
                cum_miss_dollars: costs.miss_total(),
            });
        }
        out
    }
}

/// Minimal JSON value for parsing checkpoint records (the offline build
/// carries no serde). Numbers are kept as their source text so `f64`
/// values round-trip bit-exactly through `str::parse`.
#[derive(Debug)]
enum Json {
    Null,
    Bool(bool),
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.i == p.b.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    fn get<'a>(&'a self, key: &str) -> Result<&'a Json> {
        match self {
            Json::Obj(fields) => fields
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => anyhow::bail!("not an object (looking for {key:?})"),
        }
    }

    fn get_u64(&self, key: &str) -> Result<u64> {
        match self.get(key)? {
            Json::Num(n) => Ok(n.parse::<u64>()?),
            other => anyhow::bail!("{key:?} is not an integer: {other:?}"),
        }
    }

    fn get_f64(&self, key: &str) -> Result<f64> {
        match self.get(key)? {
            Json::Num(n) => Ok(n.parse::<f64>()?),
            other => anyhow::bail!("{key:?} is not a number: {other:?}"),
        }
    }

    fn get_arr<'a>(&'a self, key: &str) -> Result<&'a [Json]> {
        match self.get(key)? {
            Json::Arr(items) => Ok(items),
            other => anyhow::bail!("{key:?} is not an array: {other:?}"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        anyhow::ensure!(self.peek() == Some(c), "expected {:?} at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str) -> Result<()> {
        anyhow::ensure!(self.b[self.i..].starts_with(s.as_bytes()), "bad literal at {}", self.i);
        self.i += s.len();
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.lit("false").map(|_| Json::Bool(false)),
            Some(b'n') => self.lit("null").map(|_| Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(char::from), self.i),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        anyhow::ensure!(
            text.parse::<f64>().map(|v| v.is_finite()).unwrap_or(false),
            "bad number {text:?} at byte {start}"
        );
        Ok(Json::Num(text.to_string()))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(c @ (b'"' | b'\\' | b'/')) => out.push(char::from(c)),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    out.push(char::from(c));
                    self.i += 1;
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        self.ws();
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("bad array at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        self.ws();
        let mut fields = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => anyhow::bail!("bad object at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::engine::EngineBuilder;
    use crate::trace::Request;
    use crate::util::tempdir::tempdir;
    use crate::HOUR;

    fn engine(cfg: &Config) -> Engine {
        EngineBuilder::new(cfg).no_default_probes().manual_epochs().build()
    }

    fn drive(e: &mut Engine, keys: std::ops::Range<u64>, close_at: u64) {
        for k in keys {
            e.offer(&Request { ts: close_at.saturating_sub(1), obj: k, size: 1000, tenant: 0 });
        }
        e.force_epoch(close_at);
    }

    #[test]
    fn record_round_trips_through_json() {
        let rec = CheckpointRecord {
            epoch: 3,
            costs: EpochCosts {
                t: 2 * HOUR,
                storage: 0.017 * 3.0,
                miss: 1.4676e-7,
                miss_count: 1,
                instances: 3,
            },
            bills: vec![TenantEpochBill { t: 2 * HOUR, tenant: 1, storage: 0.051, miss: 0.1 }],
            reconciliations: vec![TenantReconciliation {
                tenant: 2,
                at: 2 * HOUR,
                misses: 7,
                miss_dollars: 0.25,
                storage_dollars: 0.5,
                total_dollars: 0.75,
            }],
            ledgers: vec![
                (0, TenantLedger::default()),
                (1, TenantLedger { misses: 9, miss_dollars: 0.1, storage_dollars: 0.051 }),
            ],
            cum_storage_dollars: 0.3 + 0.1 + 0.1, // deliberately non-representable
            cum_miss_dollars: 1.4676e-7,
        };
        let json = rec.to_json();
        let back = CheckpointRecord::from_json(&json).unwrap();
        assert_eq!(back, rec, "{json}");
        // Bit-exactness of the awkward float, not approximate equality.
        assert_eq!(back.cum_storage_dollars.to_bits(), rec.cum_storage_dollars.to_bits());
    }

    #[test]
    fn cursor_yields_one_record_per_closed_epoch() {
        let cfg = Config::with_policy(PolicyKind::Fixed);
        let mut e = engine(&cfg);
        let mut cur = CheckpointCursor::caught_up(&e);
        assert!(cur.drain(&e).is_empty(), "nothing closed yet");
        drive(&mut e, 0..5, HOUR);
        let recs = cur.drain(&e);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].epoch, 1);
        assert_eq!(recs[0].costs.miss_count, 5);
        assert_eq!(recs[0].bills.len(), 1, "single-tenant epoch bills tenant 0");
        assert_eq!(recs[0].cum_storage_dollars, e.costs().storage_total());
        assert!(cur.drain(&e).is_empty(), "drained");
        drive(&mut e, 5..8, 2 * HOUR);
        let recs = cur.drain(&e);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].epoch, 2);
    }

    #[test]
    fn write_read_replay_round_trip_is_bit_identical() {
        let dir = tempdir().unwrap();
        let path = dir.path().join("ckpt.jsonl");
        let cfg = Config::with_policy(PolicyKind::Fixed);

        // Uninterrupted run: two epochs, checkpointed as it goes.
        let mut a = engine(&cfg);
        let mut cur = CheckpointCursor::caught_up(&a);
        let mut w = CheckpointWriter::append(&path).unwrap();
        drive(&mut a, 0..5, HOUR);
        drive(&mut a, 100..104, 2 * HOUR);
        for rec in cur.drain(&a) {
            w.write(&rec).unwrap();
        }

        // "Crashed" process: a fresh engine restored from the file.
        let records = read(&path).unwrap();
        assert_eq!(records.len(), 2);
        let mut b = engine(&cfg);
        assert_eq!(replay(&mut b, &records), 2);
        assert_eq!(b.costs().epochs(), 2);
        assert_eq!(b.costs().storage_total(), a.costs().storage_total());
        assert_eq!(b.costs().miss_total(), a.costs().miss_total());
        assert_eq!(b.costs().tenant_bills(), a.costs().tenant_bills());
        assert_eq!(b.costs().tenant_ledgers(), a.costs().tenant_ledgers());
        assert_eq!(b.instances(), a.instances());

        // Replaying again is a no-op (idempotent resume).
        assert_eq!(replay(&mut b, &records), 0);
        assert_eq!(b.costs().epochs(), 2);

        // Both runs bill the next epoch identically, bit for bit.
        drive(&mut a, 200..203, 3 * HOUR);
        drive(&mut b, 200..203, 3 * HOUR);
        assert_eq!(b.costs().storage_total(), a.costs().storage_total());
        assert_eq!(b.costs().miss_total(), a.costs().miss_total());
        assert_eq!(b.costs().tenant_bills(), a.costs().tenant_bills());
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let dir = tempdir().unwrap();
        let path = dir.path().join("ckpt.jsonl");
        let cfg = Config::with_policy(PolicyKind::Fixed);
        let mut e = engine(&cfg);
        let mut cur = CheckpointCursor::caught_up(&e);
        let mut w = CheckpointWriter::append(&path).unwrap();
        drive(&mut e, 0..3, HOUR);
        drive(&mut e, 3..6, 2 * HOUR);
        for rec in cur.drain(&e) {
            w.write(&rec).unwrap();
        }
        drop(w);
        // Simulate a kill mid-write: chop the last record in half.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 40]).unwrap();
        let records = read(&path).unwrap();
        assert_eq!(records.len(), 1, "only the intact record survives");
        assert_eq!(records[0].epoch, 1);
        // Garbage length prefix: nothing intact, still not an error.
        std::fs::write(&path, b"zzz not a record\n").unwrap();
        assert!(read(&path).unwrap().is_empty());
    }
}
