//! Trace-replay load generator for a live `elastictl serve` endpoint.
//!
//! [`run`] opens N concurrent connections, partitions the trace across
//! them round-robin (request `i` rides connection `i mod N`), and plays
//! each partition synchronously — one `GET`, one reply — so every
//! request yields a true round-trip latency sample. The aggregate report
//! carries throughput (all connections together, wall clock) and
//! p50/p99 latency over the pooled samples.
//!
//! Because the state thread serializes all engine access, replaying the
//! same trace over any number of connections produces the same engine
//! totals — only the interleaving differs — which is exactly what the
//! `srv_concurrent` integration test pins.

use crate::trace::Request;
use crate::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Aggregate result of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Connections the trace was partitioned over.
    pub connections: usize,
    /// Requests successfully round-tripped.
    pub requests: u64,
    /// Replies that came back `HIT`.
    pub hits: u64,
    /// Wall-clock duration of the whole replay (connect to last reply).
    pub elapsed_secs: f64,
    /// Median round-trip latency in microseconds.
    pub p50_us: u64,
    /// 99th-percentile round-trip latency in microseconds.
    pub p99_us: u64,
}

impl LoadgenReport {
    /// Aggregate throughput across all connections.
    pub fn requests_per_sec(&self) -> f64 {
        if self.elapsed_secs > 0.0 {
            self.requests as f64 / self.elapsed_secs
        } else {
            0.0
        }
    }

    /// Fraction of requests served from cache.
    pub fn hit_ratio(&self) -> f64 {
        if self.requests > 0 {
            self.hits as f64 / self.requests as f64
        } else {
            0.0
        }
    }

    /// One-line human summary (the `elastictl loadgen` output).
    pub fn summary(&self) -> String {
        format!(
            "{} requests over {} connections in {:.3}s: {:.0} req/s, \
             hit ratio {:.3}, p50 {}us, p99 {}us",
            self.requests,
            self.connections,
            self.elapsed_secs,
            self.requests_per_sec(),
            self.hit_ratio(),
            self.p50_us,
            self.p99_us,
        )
    }
}

/// What one connection thread brings home.
struct WorkerResult {
    hits: u64,
    latencies_us: Vec<u64>,
}

/// Replay `reqs` against the server at `addr` over `conns` connections.
pub fn run(addr: &str, reqs: &[Request], conns: usize) -> Result<LoadgenReport> {
    anyhow::ensure!(conns > 0, "loadgen needs at least one connection");
    let mut parts: Vec<Vec<Request>> = vec![Vec::new(); conns];
    for (i, r) in reqs.iter().enumerate() {
        parts[i % conns].push(*r);
    }
    let started = Instant::now();
    let mut handles = Vec::new();
    for part in parts {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || worker(&addr, &part)));
    }
    let mut hits = 0u64;
    let mut latencies = Vec::with_capacity(reqs.len());
    for h in handles {
        let res = h.join().map_err(|_| anyhow::anyhow!("loadgen worker panicked"))??;
        hits += res.hits;
        latencies.extend(res.latencies_us);
    }
    let elapsed_secs = started.elapsed().as_secs_f64();
    latencies.sort_unstable();
    Ok(LoadgenReport {
        connections: conns,
        requests: latencies.len() as u64,
        hits,
        elapsed_secs,
        p50_us: percentile(&latencies, 50.0),
        p99_us: percentile(&latencies, 99.0),
    })
}

/// One connection: play a partition synchronously, timing each round trip.
fn worker(addr: &str, part: &[Request]) -> Result<WorkerResult> {
    let sock = TcpStream::connect(addr)?;
    sock.set_nodelay(true)?;
    let mut reader = BufReader::new(sock.try_clone()?);
    let mut sock = sock;
    let mut hits = 0u64;
    let mut latencies_us = Vec::with_capacity(part.len());
    let mut line = String::new();
    for r in part {
        // The wire key is the trace ObjectId in decimal: the server
        // parses numeric keys straight back onto the ObjectId space, so
        // replay touches the same objects the trace did.
        let cmd = if r.tenant == 0 {
            format!("GET {} {}\n", r.obj, r.size)
        } else {
            format!("GET {}/{} {}\n", r.tenant, r.obj, r.size)
        };
        let t0 = Instant::now();
        sock.write_all(cmd.as_bytes())?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            anyhow::bail!("server closed the connection mid-replay");
        }
        latencies_us.push(t0.elapsed().as_micros() as u64);
        if line.trim_end() == "HIT" {
            hits += 1;
        }
    }
    let _ = sock.write_all(b"QUIT\n");
    Ok(WorkerResult { hits, latencies_us })
}

/// Nearest-rank percentile over an ascending-sorted sample.
fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let idx = ((p / 100.0) * (sorted_us.len() - 1) as f64).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::srv::{accept_loop, spawn_state};
    use std::net::TcpListener;

    #[test]
    fn percentiles_are_nearest_rank() {
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 50.0), 50);
        assert_eq!(percentile(&s, 99.0), 99);
        assert_eq!(percentile(&s, 100.0), 100);
        assert_eq!(percentile(&[], 50.0), 0);
        assert_eq!(percentile(&[7], 99.0), 7);
    }

    #[test]
    fn replays_a_trace_over_concurrent_connections() {
        let cfg = Config::with_policy(PolicyKind::Fixed);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = spawn_state(cfg, None).unwrap();
        let tx = server.tx.clone();
        std::thread::spawn(move || {
            let _ = accept_loop(listener, tx);
        });

        // 10 objects touched 4 times each: exactly 10 misses no matter
        // how the 4 connections interleave (the state thread serializes).
        let reqs: Vec<Request> = (0..40u64).map(|i| Request::new(i, i % 10, 100)).collect();
        let report = run(&addr, &reqs, 4).unwrap();
        assert_eq!(report.connections, 4);
        assert_eq!(report.requests, 40);
        assert_eq!(report.hits, 30, "10 distinct objects -> 10 misses");
        assert!(report.elapsed_secs > 0.0);
        assert!(report.requests_per_sec() > 0.0);
        assert!(report.p50_us <= report.p99_us);
        assert!((report.hit_ratio() - 0.75).abs() < 1e-9);
        assert!(report.summary().contains("40 requests over 4 connections"));
    }

    #[test]
    fn zero_connections_is_an_error() {
        assert!(run("127.0.0.1:1", &[], 0).is_err());
    }
}
