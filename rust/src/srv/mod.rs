//! The server runtime: the long-lived, concurrent, durable front for
//! `elastictl serve`.
//!
//! [`crate::serve`] defines the line protocol and the per-command state
//! machine ([`ServerState`]); this module wraps that state machine in
//! the machinery a real deployment needs:
//!
//! * **Concurrency** — a thread-per-connection accept loop (the offline
//!   build carries no async runtime). Clients may pipeline: each
//!   connection thread reads ahead line by line and forwards to the
//!   single state-owner thread, which serializes all engine access (the
//!   analytic policy holds non-`Send` PJRT handles, exactly as in
//!   [`crate::serve::spawn_state`]). Replies return in request order per
//!   connection.
//! * **Wall-clock epochs** — `[serve] epoch_secs = N` (or
//!   `--epoch-secs N`) starts a background ticker that forces an epoch
//!   boundary every N seconds of wall time, through the same code path
//!   as the operator's `EPOCH` command. The default (0) keeps epochs
//!   fully manual, so a default-config server is bit-identical with the
//!   pre-runtime behavior pinned by `serve_json`/`engine_parity`.
//! * **Real TTL expiry** — `[serve] ttl_expiry_secs` arms lazy
//!   `Instant`-based expiry on the resident stores (armed by
//!   [`crate::engine::EngineBuilder`], implemented in
//!   [`crate::cache::ExpiryIndex`] / [`crate::cluster::Cluster`]): an
//!   expired entry is dropped on access (a plain miss, with the resident
//!   ledger debited), and the epoch boundary sweeps what expired
//!   unaccessed.
//! * **Durability** — `[serve] checkpoint_path` (or `--resume PATH`)
//!   journals every closed epoch's billing delta to an append-only,
//!   fsync-per-record file ([`checkpoint`]); on startup the file is
//!   replayed idempotently, so a killed server resumes with cumulative
//!   bills bit-identical to an uninterrupted run. Cache contents and
//!   controller estimators restart cold — the bills are the durable
//!   part; the open (unbilled) epoch at the time of the kill is lost by
//!   design, exactly like a node that died before its boundary.
//! * **Load generation** — [`loadgen`] replays a trace file over N
//!   concurrent connections against a live server and reports aggregate
//!   req/s and p50/p99 latency.

pub mod checkpoint;
pub mod loadgen;

use crate::config::Config;
use crate::serve::ServerState;
use crate::Result;
use checkpoint::{CheckpointCursor, CheckpointWriter};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

/// One message for the state-owner thread.
pub enum Msg {
    /// A protocol line plus the channel its reply goes back on
    /// (`None` = close the connection; only `QUIT` answers that).
    Line(String, mpsc::Sender<Option<String>>),
    /// A wall-clock epoch boundary from the background ticker.
    Tick,
}

/// Command channel to the state-owner thread.
pub type SrvTx = mpsc::Sender<Msg>;

/// A spawned state-owner thread: its command channel plus what the
/// startup replay restored.
pub struct Server {
    /// Send [`Msg`]s here; the state thread exits when every clone of
    /// this sender is dropped (and its checkpoint is already durable —
    /// the writer fsyncs record by record, so there is nothing to flush).
    pub tx: SrvTx,
    /// Closed epochs restored from the checkpoint at startup (0 on a
    /// fresh start or without a checkpoint).
    pub resumed_epochs: u64,
}

/// Spawn the state-owner thread for `cfg`. With a checkpoint path, the
/// file's intact records are replayed into the fresh engine first
/// (idempotently — see [`checkpoint::replay`]) and every epoch closed
/// from then on is appended durably before the next message is handled.
pub fn spawn_state(cfg: Config, ckpt_path: Option<PathBuf>) -> Result<Server> {
    // File work happens on the caller: records and writer are `Send`,
    // the engine (non-`Send` policy state) is built on the state thread.
    let records = match &ckpt_path {
        Some(p) if p.exists() => checkpoint::read(p)?,
        _ => Vec::new(),
    };
    let writer = match &ckpt_path {
        Some(p) => Some(CheckpointWriter::append(p)?),
        None => None,
    };
    let (tx, rx) = mpsc::channel::<Msg>();
    let (ready_tx, ready_rx) = mpsc::channel::<u64>();
    std::thread::spawn(move || state_loop(cfg, records, writer, rx, ready_tx));
    let resumed_epochs = ready_rx.recv().unwrap_or(0);
    Ok(Server { tx, resumed_epochs })
}

fn state_loop(
    cfg: Config,
    records: Vec<checkpoint::CheckpointRecord>,
    writer: Option<CheckpointWriter>,
    rx: mpsc::Receiver<Msg>,
    ready_tx: mpsc::Sender<u64>,
) {
    let mut st = ServerState::new(&cfg);
    let resumed = checkpoint::replay(&mut st.engine, &records);
    if resumed > 0 {
        if let Some(reg) = st.engine.telemetry() {
            reg.borrow_mut().counter("elastictl_resume_epochs_total").add(resumed);
        }
    }
    let _ = ready_tx.send(resumed);
    // Cursor and writer travel together: everything the cursor has
    // drained is on disk.
    let mut durable = writer.map(|w| (w, CheckpointCursor::caught_up(&st.engine)));
    for msg in rx {
        match msg {
            Msg::Line(line, reply) => {
                let text = st.handle_line(&line);
                // Durability barrier *before* the ack: by the time a
                // client sees the reply (an EPOCH's RESIZED in
                // particular), every epoch the command closed is fsync'd.
                flush_closed_epochs(&mut durable, &st);
                let _ = reply.send(text);
            }
            Msg::Tick => {
                // The ticker is the operator's EPOCH on a wall-clock
                // cadence: same code path, reply discarded.
                let _ = st.handle_line("EPOCH");
                if let Some(reg) = st.engine.telemetry() {
                    reg.borrow_mut().counter("elastictl_epoch_ticks_total").inc();
                }
                flush_closed_epochs(&mut durable, &st);
            }
        }
    }
}

/// Append every newly closed epoch to the checkpoint (fsync per record).
fn flush_closed_epochs(
    durable: &mut Option<(CheckpointWriter, CheckpointCursor)>,
    st: &ServerState,
) {
    if let Some((w, cursor)) = durable.as_mut() {
        for rec in cursor.drain(&st.engine) {
            if let Err(e) = w.write(&rec) {
                eprintln!("elastictl serve: checkpoint write failed: {e}");
            }
        }
    }
}

/// Start the wall-clock epoch ticker: a [`Msg::Tick`] every `every`,
/// until the state thread goes away.
pub fn spawn_ticker(tx: SrvTx, every: Duration) {
    std::thread::spawn(move || loop {
        std::thread::sleep(every);
        if tx.send(Msg::Tick).is_err() {
            break;
        }
    });
}

/// Accept connections forever, one handler thread per connection.
pub fn accept_loop(listener: TcpListener, tx: SrvTx) -> Result<()> {
    for stream in listener.incoming() {
        let socket = stream?;
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(socket, tx);
        });
    }
    Ok(())
}

/// Serve one connection: read lines (pipelining is fine — the reader
/// consumes as fast as the state thread answers), forward each to the
/// state owner, write replies back in order.
pub fn handle_conn(socket: TcpStream, tx: SrvTx) -> Result<()> {
    let reader = BufReader::new(socket.try_clone()?);
    let mut w = socket;
    for line in reader.lines() {
        let line = line?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Msg::Line(line, reply_tx))
            .map_err(|_| anyhow::anyhow!("state thread gone"))?;
        match reply_rx.recv()? {
            Some(text) => {
                w.write_all(text.as_bytes())?;
                w.write_all(b"\n")?;
            }
            None => {
                w.write_all(b"BYE\n")?;
                break;
            }
        }
    }
    Ok(())
}

/// Run the server runtime until the listener errors or the process is
/// killed: bind, resume from the checkpoint (CLI `--resume` wins over
/// `[serve] checkpoint_path`), start the ticker when configured, accept.
pub fn serve(cfg: Config, addr: &str, resume: Option<&str>) -> Result<()> {
    let ckpt = resume
        .map(PathBuf::from)
        .or_else(|| cfg.serve.checkpoint_path.as_ref().map(PathBuf::from));
    let epoch_secs = cfg.serve.epoch_secs;
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "elastictl serve: listening on {} (policy={}, tenants={}, epoch_secs={}, checkpoint={})",
        listener.local_addr()?,
        cfg.scaler.policy.as_str(),
        if cfg.tenants.is_empty() { 1 } else { cfg.tenants.len() },
        epoch_secs,
        ckpt.as_deref().map(|p| p.display().to_string()).unwrap_or_else(|| "off".into()),
    );
    let server = spawn_state(cfg, ckpt)?;
    if server.resumed_epochs > 0 {
        eprintln!(
            "elastictl serve: resumed {} closed epoch(s) from checkpoint",
            server.resumed_epochs
        );
    }
    if epoch_secs > 0 {
        spawn_ticker(server.tx.clone(), Duration::from_secs(epoch_secs));
    }
    accept_loop(listener, server.tx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::util::tempdir::tempdir;

    /// Drive one line through the state thread and wait for the reply.
    fn ask(tx: &SrvTx, line: &str) -> Option<String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Msg::Line(line.to_string(), reply_tx)).unwrap();
        reply_rx.recv().unwrap()
    }

    #[test]
    fn state_thread_serves_the_protocol() {
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let server = spawn_state(cfg, None).unwrap();
        assert_eq!(server.resumed_epochs, 0);
        assert_eq!(ask(&server.tx, "GET k 100").unwrap(), "MISS");
        assert_eq!(ask(&server.tx, "GET k 100").unwrap(), "HIT");
        assert!(ask(&server.tx, "EPOCH").unwrap().starts_with("RESIZED"));
        assert!(ask(&server.tx, "QUIT").is_none());
    }

    #[test]
    fn ticks_close_epochs_like_the_epoch_command() {
        let cfg = Config::with_policy(PolicyKind::Fixed);
        let server = spawn_state(cfg, None).unwrap();
        ask(&server.tx, "GET k 100");
        server.tx.send(Msg::Tick).unwrap();
        server.tx.send(Msg::Tick).unwrap();
        // STATS after the ticks: the state thread is serial, so by the
        // time the reply arrives both ticks have been handled.
        let stats = ask(&server.tx, "STATS").unwrap();
        assert!(stats.contains("\"requests\":1"), "{stats}");
    }

    #[test]
    fn checkpointed_kill_and_resume_is_bit_identical() {
        let dir = tempdir().unwrap();
        let interrupted = dir.path().join("interrupted.ckpt");
        let baseline = dir.path().join("baseline.ckpt");
        let cfg = || {
            let mut c = Config::with_policy(PolicyKind::Fixed);
            c.scaler.fixed_instances = 2;
            c
        };
        // Segment 1 keys / segment 2 keys are disjoint and fresh, so the
        // resumed (cold-cache) run misses exactly like the baseline.
        let seg1: Vec<String> = (0..40).map(|i| format!("GET a{i} 1000")).collect();
        let seg2: Vec<String> = (0..40).map(|i| format!("GET b{i} 1000")).collect();

        // Baseline: both segments through one uninterrupted server, with
        // the same epoch boundaries the interrupted run will have.
        let bsrv = spawn_state(cfg(), Some(baseline.clone())).unwrap();
        for line in &seg1 {
            ask(&bsrv.tx, line);
        }
        ask(&bsrv.tx, "EPOCH");
        for line in &seg2 {
            ask(&bsrv.tx, line);
        }
        ask(&bsrv.tx, "EPOCH");
        drop(bsrv.tx); // let the state thread exit

        // Interrupted: segment 1, an EPOCH, then a "kill" (drop the
        // channel — the checkpoint is already fsync'd per record).
        let s1 = spawn_state(cfg(), Some(interrupted.clone())).unwrap();
        for line in &seg1 {
            ask(&s1.tx, line);
        }
        ask(&s1.tx, "EPOCH");
        drop(s1.tx);

        // Resume and finish with segment 2.
        let s2 = spawn_state(cfg(), Some(interrupted.clone())).unwrap();
        assert_eq!(s2.resumed_epochs, 1, "one closed epoch must be restored");
        for line in &seg2 {
            ask(&s2.tx, line);
        }
        ask(&s2.tx, "EPOCH");
        drop(s2.tx);

        // Compare the durable bills: both runs closed the same two
        // epochs, so every cumulative figure must agree bit for bit.
        // Epoch timestamps are wall-clock and legitimately differ — the
        // money and the counts must not.
        let last = |p: &std::path::Path| checkpoint::read(p).unwrap().pop().unwrap();
        let (a, b) = (last(&interrupted), last(&baseline));
        assert_eq!((a.epoch, b.epoch), (2, 2));
        assert_eq!(a.cum_miss_dollars, b.cum_miss_dollars, "bit-identical miss dollars");
        assert_eq!(a.cum_storage_dollars, b.cum_storage_dollars, "bit-identical storage");
        assert_eq!(a.ledgers, b.ledgers, "bit-identical per-tenant ledgers");
        assert_eq!(a.costs.instances, b.costs.instances);
        assert_eq!(a.costs.miss_count, b.costs.miss_count);
        assert_eq!(
            a.bills.iter().map(|x| (x.tenant, x.storage, x.miss)).collect::<Vec<_>>(),
            b.bills.iter().map(|x| (x.tenant, x.storage, x.miss)).collect::<Vec<_>>(),
            "bit-identical final-epoch bill rows"
        );
    }

    #[test]
    fn end_to_end_over_tcp_with_concurrent_connections() {
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = spawn_state(cfg, None).unwrap();
        let tx = server.tx.clone();
        std::thread::spawn(move || {
            let _ = accept_loop(listener, tx);
        });
        let mut handles = Vec::new();
        for c in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                // Per-connection keys: each object's accesses stay on one
                // connection, so every key misses once then hits.
                sock.write_all(format!("GET c{c}k 100\nGET c{c}k 100\nQUIT\n").as_bytes())
                    .unwrap();
                let mut lines = BufReader::new(sock).lines();
                assert_eq!(lines.next().unwrap().unwrap(), "MISS");
                assert_eq!(lines.next().unwrap().unwrap(), "HIT");
                assert_eq!(lines.next().unwrap().unwrap(), "BYE");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = ask(&server.tx, "STATS").unwrap();
        assert!(stats.contains("\"requests\":8"), "{stats}");
        assert!(stats.contains("\"misses\":4"), "{stats}");
    }
}
