//! The server runtime: the long-lived, concurrent, durable front for
//! `elastictl serve`.
//!
//! [`crate::serve`] defines the line protocol and the per-command state
//! machine ([`ServerState`]); this module wraps that state machine in
//! the machinery a real deployment needs:
//!
//! * **Concurrency** — a thread-per-connection accept loop (the offline
//!   build carries no async runtime). Clients may pipeline: each
//!   connection thread reads ahead line by line and forwards to the
//!   single state-owner thread, which serializes all engine access (the
//!   analytic policy holds non-`Send` PJRT handles, exactly as in
//!   [`crate::serve::spawn_state`]). Replies return in request order per
//!   connection.
//! * **Wall-clock epochs** — `[serve] epoch_secs = N` (or
//!   `--epoch-secs N`) starts a background ticker that forces an epoch
//!   boundary every N seconds of wall time, through the same code path
//!   as the operator's `EPOCH` command. The default (0) keeps epochs
//!   fully manual, so a default-config server is bit-identical with the
//!   pre-runtime behavior pinned by `serve_json`/`engine_parity`.
//! * **Real TTL expiry** — `[serve] ttl_expiry_secs` arms lazy
//!   `Instant`-based expiry on the resident stores (armed by
//!   [`crate::engine::EngineBuilder`], implemented in
//!   [`crate::cache::ExpiryIndex`] / [`crate::cluster::Cluster`]): an
//!   expired entry is dropped on access (a plain miss, with the resident
//!   ledger debited), and the epoch boundary sweeps what expired
//!   unaccessed.
//! * **Durability** — `[serve] checkpoint_path` (or `--resume PATH`)
//!   journals every closed epoch's billing delta to an append-only,
//!   fsync-per-record file ([`checkpoint`]); on startup the file is
//!   replayed idempotently, so a killed server resumes with cumulative
//!   bills bit-identical to an uninterrupted run. Cache contents and
//!   controller estimators restart cold — the bills are the durable
//!   part; the open (unbilled) epoch at the time of the kill is lost by
//!   design, exactly like a node that died before its boundary.
//! * **Load generation** — [`loadgen`] replays a trace file over N
//!   concurrent connections against a live server and reports aggregate
//!   req/s and p50/p99 latency.
//! * **Multicore sharding** — `[engine] shards = N` (or `--shards N`)
//!   swaps the single state-owner engine for a [`ShardedEngine`]: N
//!   shard-owner threads each run a disjoint slice of the cluster, and
//!   connection threads route `GET`s straight to the owning shard
//!   ([`ShardRouter`]) with no global lock on the hot path. Control
//!   commands still serialize through one front thread, which runs the
//!   deterministic epoch barrier and the same durable checkpoint path —
//!   and answers the full observability surface: `SLO`, `PLACEMENT` and
//!   `STATS <tenant>` merge one observation round-trip over the shards
//!   (sums over the disjoint slices, spec-wide values once), `WHY`
//!   reads the barrier-merged decision journal, and `METRICS` renders
//!   the merged Prometheus exposition (front series plus per-shard
//!   series under `shard="i"` labels and cluster-level sums).

pub mod checkpoint;
pub mod loadgen;

use crate::config::{Config, PolicyKind};
use crate::engine::{sum_tenant_stats, ShardObservation, ShardRouter, ShardedEngine};
use crate::serve::{fxhash_str, split_tenant_key, ServerState};
use crate::tenant::{LifecycleState, TenantEnforcement, TenantSpec};
use crate::trace::Request;
use crate::{Result, TenantId};
use checkpoint::{CheckpointCursor, CheckpointWriter};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One message for the state-owner thread.
pub enum Msg {
    /// A protocol line plus the channel its reply goes back on
    /// (`None` = close the connection; only `QUIT` answers that).
    Line(String, mpsc::Sender<Option<String>>),
    /// A wall-clock epoch boundary from the background ticker.
    Tick,
}

/// Command channel to the state-owner thread.
pub type SrvTx = mpsc::Sender<Msg>;

/// A spawned state-owner thread: its command channel plus what the
/// startup replay restored.
pub struct Server {
    /// Send [`Msg`]s here; the state thread exits when every clone of
    /// this sender is dropped (and its checkpoint is already durable —
    /// the writer fsyncs record by record, so there is nothing to flush).
    pub tx: SrvTx,
    /// Closed epochs restored from the checkpoint at startup (0 on a
    /// fresh start or without a checkpoint).
    pub resumed_epochs: u64,
}

/// Spawn the state-owner thread for `cfg`. With a checkpoint path, the
/// file's intact records are replayed into the fresh engine first
/// (idempotently — see [`checkpoint::replay`]) and every epoch closed
/// from then on is appended durably before the next message is handled.
pub fn spawn_state(cfg: Config, ckpt_path: Option<PathBuf>) -> Result<Server> {
    // File work happens on the caller: records and writer are `Send`,
    // the engine (non-`Send` policy state) is built on the state thread.
    let records = match &ckpt_path {
        Some(p) if p.exists() => checkpoint::read(p)?,
        _ => Vec::new(),
    };
    let writer = match &ckpt_path {
        Some(p) => Some(CheckpointWriter::append(p)?),
        None => None,
    };
    let (tx, rx) = mpsc::channel::<Msg>();
    let (ready_tx, ready_rx) = mpsc::channel::<u64>();
    std::thread::spawn(move || state_loop(cfg, records, writer, rx, ready_tx));
    let resumed_epochs = ready_rx.recv().unwrap_or(0);
    Ok(Server { tx, resumed_epochs })
}

fn state_loop(
    cfg: Config,
    records: Vec<checkpoint::CheckpointRecord>,
    writer: Option<CheckpointWriter>,
    rx: mpsc::Receiver<Msg>,
    ready_tx: mpsc::Sender<u64>,
) {
    let mut st = ServerState::new(&cfg);
    let resumed = checkpoint::replay(&mut st.engine, &records);
    if resumed > 0 {
        if let Some(reg) = st.engine.telemetry() {
            reg.borrow_mut().counter("elastictl_resume_epochs_total").add(resumed);
        }
    }
    let _ = ready_tx.send(resumed);
    // Cursor and writer travel together: everything the cursor has
    // drained is on disk.
    let mut durable = writer.map(|w| (w, CheckpointCursor::caught_up(&st.engine)));
    for msg in rx {
        match msg {
            Msg::Line(line, reply) => {
                let text = st.handle_line(&line);
                // Durability barrier *before* the ack: by the time a
                // client sees the reply (an EPOCH's RESIZED in
                // particular), every epoch the command closed is fsync'd.
                flush_closed_epochs(&mut durable, &st);
                let _ = reply.send(text);
            }
            Msg::Tick => {
                // The ticker is the operator's EPOCH on a wall-clock
                // cadence: same code path, reply discarded.
                let _ = st.handle_line("EPOCH");
                if let Some(reg) = st.engine.telemetry() {
                    reg.borrow_mut().counter("elastictl_epoch_ticks_total").inc();
                }
                flush_closed_epochs(&mut durable, &st);
            }
        }
    }
}

/// Append every newly closed epoch to the checkpoint (fsync per record).
fn flush_closed_epochs(
    durable: &mut Option<(CheckpointWriter, CheckpointCursor)>,
    st: &ServerState,
) {
    if let Some((w, cursor)) = durable.as_mut() {
        for rec in cursor.drain(&st.engine) {
            if let Err(e) = w.write(&rec) {
                eprintln!("elastictl serve: checkpoint write failed: {e}");
            }
        }
    }
}

/// Start the wall-clock epoch ticker: a [`Msg::Tick`] every `every`,
/// until the state thread goes away.
pub fn spawn_ticker(tx: SrvTx, every: Duration) {
    std::thread::spawn(move || loop {
        std::thread::sleep(every);
        if tx.send(Msg::Tick).is_err() {
            break;
        }
    });
}

/// Accept connections forever, one handler thread per connection.
pub fn accept_loop(listener: TcpListener, tx: SrvTx) -> Result<()> {
    for stream in listener.incoming() {
        let socket = stream?;
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(socket, tx);
        });
    }
    Ok(())
}

/// Serve one connection: read lines (pipelining is fine — the reader
/// consumes as fast as the state thread answers), forward each to the
/// state owner, write replies back in order.
pub fn handle_conn(socket: TcpStream, tx: SrvTx) -> Result<()> {
    let reader = BufReader::new(socket.try_clone()?);
    let mut w = socket;
    for line in reader.lines() {
        let line = line?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Msg::Line(line, reply_tx))
            .map_err(|_| anyhow::anyhow!("state thread gone"))?;
        match reply_rx.recv()? {
            Some(text) => {
                w.write_all(text.as_bytes())?;
                w.write_all(b"\n")?;
            }
            None => {
                w.write_all(b"BYE\n")?;
                break;
            }
        }
    }
    Ok(())
}

/// Run the server runtime until the listener errors or the process is
/// killed: bind, resume from the checkpoint (CLI `--resume` wins over
/// `[serve] checkpoint_path`), start the ticker when configured, accept.
pub fn serve(cfg: Config, addr: &str, resume: Option<&str>) -> Result<()> {
    let ckpt = resume
        .map(PathBuf::from)
        .or_else(|| cfg.serve.checkpoint_path.as_ref().map(PathBuf::from));
    let epoch_secs = cfg.serve.epoch_secs;
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "elastictl serve: listening on {} (policy={}, tenants={}, shards={}, epoch_secs={}, \
         checkpoint={})",
        listener.local_addr()?,
        cfg.scaler.policy.as_str(),
        if cfg.tenants.is_empty() { 1 } else { cfg.tenants.len() },
        cfg.engine.shards,
        epoch_secs,
        ckpt.as_deref().map(|p| p.display().to_string()).unwrap_or_else(|| "off".into()),
    );
    if cfg.engine.shards > 1 {
        return serve_sharded(cfg, listener, ckpt, epoch_secs);
    }
    let server = spawn_state(cfg, ckpt)?;
    if server.resumed_epochs > 0 {
        eprintln!(
            "elastictl serve: resumed {} closed epoch(s) from checkpoint",
            server.resumed_epochs
        );
    }
    if epoch_secs > 0 {
        spawn_ticker(server.tx.clone(), Duration::from_secs(epoch_secs));
    }
    accept_loop(listener, server.tx)
}

/// [`serve`] under `[engine] shards > 1`: N shard-owner threads behind
/// the accept loop. Connection threads serve `GET`s straight off their
/// [`ShardRouter`] clone (the multicore fast path); control lines hop to
/// the front thread, which owns the [`ShardedEngine`], the wall-clock
/// epoch barrier and the durable checkpoint.
fn serve_sharded(
    cfg: Config,
    listener: TcpListener,
    ckpt: Option<PathBuf>,
    epoch_secs: u64,
) -> Result<()> {
    let server = spawn_sharded_state(cfg, ckpt)?;
    if server.resumed_epochs > 0 {
        eprintln!(
            "elastictl serve: resumed {} closed epoch(s) from checkpoint",
            server.resumed_epochs
        );
    }
    if epoch_secs > 0 {
        spawn_ticker(server.tx.clone(), Duration::from_secs(epoch_secs));
    }
    for stream in listener.incoming() {
        let socket = stream?;
        let tx = server.tx.clone();
        let router = server.router.clone();
        let (tenant_routing, start) = (server.tenant_routing, server.start);
        std::thread::spawn(move || {
            let _ = handle_conn_sharded(socket, tx, router, tenant_routing, start);
        });
    }
    Ok(())
}

/// A spawned sharded front: the control-plane channel plus everything a
/// connection thread needs to serve `GET`s without the front.
pub struct ShardedServer {
    /// Control-plane lines and epoch ticks go here.
    pub tx: SrvTx,
    /// Per-connection GET fast path into the shard workers.
    pub router: ShardRouter,
    /// Closed epochs restored from the checkpoint at startup.
    pub resumed_epochs: u64,
    /// Whether `GET <tenant>/<key>` prefixes are interpreted (same rule
    /// as [`ServerState`]).
    pub tenant_routing: bool,
    /// The server's clock origin; request timestamps are micros since
    /// this instant, on every thread.
    pub start: Instant,
}

/// Spawn the sharded front thread for `cfg`, replaying the checkpoint
/// first exactly as [`spawn_state`] does.
pub fn spawn_sharded_state(cfg: Config, ckpt_path: Option<PathBuf>) -> Result<ShardedServer> {
    let records = match &ckpt_path {
        Some(p) if p.exists() => checkpoint::read(p)?,
        _ => Vec::new(),
    };
    let writer = match &ckpt_path {
        Some(p) => Some(CheckpointWriter::append(p)?),
        None => None,
    };
    // Built on the caller so spawn errors surface here; the sharded
    // engine is `Send` (the unshardable policies were rejected above).
    let mut engine = ShardedEngine::new(&cfg)?.manual_epochs();
    let resumed_epochs = checkpoint::replay_sharded(&mut engine, &records);
    if resumed_epochs > 0 {
        if let Some(reg) = engine.telemetry() {
            reg.counter("elastictl_resume_epochs_total").add(resumed_epochs);
        }
    }
    let router = engine.router();
    let tenant_routing =
        !cfg.tenants.is_empty() || cfg.scaler.policy == PolicyKind::TenantTtl;
    let start = Instant::now();
    let (tx, rx) = mpsc::channel::<Msg>();
    std::thread::spawn(move || sharded_state_loop(cfg, engine, writer, rx, start));
    Ok(ShardedServer { tx, router, resumed_epochs, tenant_routing, start })
}

fn sharded_state_loop(
    cfg: Config,
    engine: ShardedEngine,
    writer: Option<CheckpointWriter>,
    rx: mpsc::Receiver<Msg>,
    start: Instant,
) {
    let mut front = ShardedFront::new(&cfg, engine, start);
    let mut durable =
        writer.map(|w| (w, CheckpointCursor::caught_up_costs(front.engine.costs())));
    for msg in rx {
        match msg {
            Msg::Line(line, reply) => {
                let text = front.handle_line(&line);
                flush_sharded_epochs(&mut durable, &front.engine);
                let _ = reply.send(text);
            }
            Msg::Tick => {
                let now = front.now_us();
                front.engine.force_epoch(now);
                if let Some(reg) = front.engine.telemetry() {
                    reg.counter("elastictl_epoch_ticks_total").inc();
                }
                flush_sharded_epochs(&mut durable, &front.engine);
            }
        }
    }
}

/// Append every newly closed epoch to the checkpoint (fsync per record).
fn flush_sharded_epochs(
    durable: &mut Option<(CheckpointWriter, CheckpointCursor)>,
    engine: &ShardedEngine,
) {
    if let Some((w, cursor)) = durable.as_mut() {
        for rec in cursor.drain_costs(engine.costs(), engine.closed_epochs()) {
            if let Err(e) = w.write(&rec) {
                eprintln!("elastictl serve: checkpoint write failed: {e}");
            }
        }
    }
}

/// The sharded control plane: owns the [`ShardedEngine`] and answers
/// the command subset that has a sharded meaning. Per-tenant miss
/// dollars fold into the front tracker only at epoch barriers, so
/// `STATS`' `miss_cost` covers closed epochs (the open epoch's misses
/// land at the next `EPOCH`).
struct ShardedFront {
    engine: ShardedEngine,
    router: ShardRouter,
    /// Registered tenant specs (roster + live ADMITs − RETIREs): seeds
    /// partial `ADMIT` updates the way the monolith's registry does.
    specs: Vec<TenantSpec>,
    tenant_routing: bool,
    start: Instant,
}

impl ShardedFront {
    fn new(cfg: &Config, engine: ShardedEngine, start: Instant) -> ShardedFront {
        let tenant_routing =
            !cfg.tenants.is_empty() || cfg.scaler.policy == PolicyKind::TenantTtl;
        let router = engine.router();
        ShardedFront { engine, router, specs: cfg.tenants.clone(), tenant_routing, start }
    }

    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Handle one protocol line; `None` closes the connection (`QUIT`).
    fn handle_line(&mut self, line: &str) -> Option<String> {
        let mut parts = line.split_ascii_whitespace();
        match parts.next() {
            Some("GET") => {
                let token = match parts.next() {
                    Some(t) => t,
                    None => return Some("ERR missing key".to_string()),
                };
                let size: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(1);
                let req = get_request(token, size, self.tenant_routing, self.now_us());
                Some(get_reply(self.router.get(&req)))
            }
            Some("STATS") => match parts.next() {
                None => Some(self.stats_line()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.tenant_stats_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("SLO") => match parts.next() {
                None => Some("ERR SLO needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.slo_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("PLACEMENT") => Some(self.placement_line()),
            Some("WHY") => match parts.next() {
                None => Some("ERR WHY needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.why_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("METRICS") => Some(self.metrics_block()),
            Some("EPOCH") => {
                let now = self.now_us();
                let n = self.engine.force_epoch(now);
                Some(format!("RESIZED {n}"))
            }
            Some("ADMIT") => match parts.next() {
                None => Some("ERR ADMIT needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.admit_line(tenant, parts)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("RETIRE") => match parts.next() {
                None => Some("ERR RETIRE needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(match self.engine.retire_tenant(tenant) {
                        Ok(()) => {
                            self.specs.retain(|s| s.id != tenant);
                            format!("OK {tenant} draining")
                        }
                        Err(e) => format!("ERR {e}"),
                    }),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("BILL") => match parts.next() {
                None => Some("ERR BILL needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.bill_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("QUIT") => None,
            Some(other) => Some(format!("ERR unknown command {other}")),
            None => Some("ERR empty".to_string()),
        }
    }

    /// Aggregate one-line JSON for `STATS`: the shard counters summed,
    /// plus the billed instance count and the shard fan-out.
    fn stats_line(&mut self) -> String {
        let stats = self.engine.shard_stats();
        let requests: u64 = stats.iter().map(|s| s.requests).sum();
        let misses: u64 = stats.iter().map(|s| s.misses).sum();
        let spurious: u64 = stats.iter().map(|s| s.spurious_misses).sum();
        let filter_denials: u64 = stats.iter().map(|s| s.filter_denials).sum();
        let hm = crate::metrics::HitMiss { hits: requests - misses, misses };
        format!(
            "{{\"requests\":{requests},\"misses\":{misses},\"spurious\":{spurious},\
             \"filter_denials\":{filter_denials},\
             \"miss_ratio\":{},\"instances\":{},\"miss_cost\":{:.9},\"ttl_secs\":null,\
             \"tenants\":{},\"shards\":{}}}",
            hm.try_miss_ratio().map(|r| format!("{r:.6}")).unwrap_or_else(|| "null".into()),
            self.engine.instances(),
            self.engine.costs().miss_total(),
            self.specs.len().max(1),
            self.engine.shards(),
        )
    }

    /// `ADMIT <tenant> [key=value …]` with the same spec-field parsing
    /// and error strings as [`ServerState`]'s admit path.
    fn admit_line<'a>(
        &mut self,
        tenant: TenantId,
        args: impl Iterator<Item = &'a str>,
    ) -> String {
        let mut spec = self
            .specs
            .iter()
            .find(|s| s.id == tenant)
            .cloned()
            .unwrap_or_else(|| TenantSpec::new(tenant, format!("tenant{tenant}")));
        for arg in args {
            let Some((key, value)) = arg.split_once('=') else {
                return format!("ERR bad admit arg {arg} (want key=value)");
            };
            match key {
                "reserved_mb" => match value.parse::<f64>() {
                    Ok(mb) if mb >= 0.0 && mb.is_finite() => {
                        spec.reserved_bytes = (mb * 1024.0 * 1024.0) as u64;
                    }
                    _ => return format!("ERR bad reserved_mb {value}"),
                },
                "slo" => match value.parse::<f64>() {
                    Ok(r) if (0.0..=1.0).contains(&r) => spec.slo_miss_ratio = Some(r),
                    _ => return format!("ERR bad slo {value} (want a miss ratio in [0,1])"),
                },
                "multiplier" => match value.parse::<f64>() {
                    Ok(m) if m > 0.0 && m.is_finite() => spec.miss_cost_multiplier = m,
                    _ => return format!("ERR bad multiplier {value}"),
                },
                "name" => spec.name = value.to_string(),
                other => return format!("ERR unknown admit key {other}"),
            }
        }
        match self.engine.admit_tenant(spec.clone()) {
            Ok(outcome) => {
                self.specs.retain(|s| s.id != tenant);
                self.specs.push(spec);
                format!("OK {tenant} {}", outcome.as_str())
            }
            Err(e) => format!("ERR {e}"),
        }
    }

    /// `STATS <tenant>` over the merged shard observations: requests and
    /// misses are Σ-over-shards cumulative counters, `miss_cost` reads
    /// the front ledger (closed epochs — the open epoch's misses land at
    /// the next `EPOCH`), `physical_bytes` sums the disjoint resident
    /// slices. `ttl_secs` is `null`: each shard's controller estimates
    /// its own TTL on its own slice, so no single figure exists. The
    /// lifecycle gate matches the monolithic reply contract.
    fn tenant_stats_line(&mut self, tenant: TenantId) -> String {
        let obs = self.engine.observe();
        let state = match merged_lifecycle(&obs, tenant) {
            LifecycleGate::Untracked => String::new(),
            LifecycleGate::Unknown => return format!("ERR unknown tenant {tenant}"),
            LifecycleGate::Retired => {
                return format!("ERR unknown tenant {tenant} (retired)");
            }
            LifecycleGate::State(s) => format!(",\"state\":\"{}\"", s.as_str()),
        };
        let stats = sum_tenant_stats(obs.iter().map(|o| o.tenant_stats.as_slice()));
        let hm = stats.get(tenant as usize).copied().unwrap_or_default();
        let ledger = self.engine.costs().tenant_ledger(tenant);
        let physical: u64 = obs
            .iter()
            .flat_map(|o| o.residents.iter())
            .filter(|&&(t, _)| t == tenant)
            .map(|&(_, b)| b)
            .sum();
        format!(
            "{{\"tenant\":{},\"requests\":{},\"misses\":{},\"miss_cost\":{:.9},\
             \"physical_bytes\":{},\"ttl_secs\":null{}}}",
            tenant,
            hm.total(),
            hm.misses,
            ledger.miss_dollars,
            physical,
            state,
        )
    }

    /// `SLO <tenant>` over the merged enforcement rows: same JSON shape
    /// and error string as the monolithic server's, with the per-slice
    /// quantities summed and `measured_miss_ratio` / `boost` taken from
    /// the front's Σ-over-shards window replicas.
    fn slo_line(&mut self, tenant: TenantId) -> String {
        let obs = self.engine.observe();
        let per_shard: Option<Vec<Vec<TenantEnforcement>>> =
            obs.iter().map(|o| o.enforcement.clone()).collect();
        let row = per_shard
            .map(|v| self.engine.merge_enforcement(&v))
            .and_then(|rows| rows.into_iter().find(|r| r.tenant == tenant));
        let Some(row) = row else {
            return format!(
                "ERR no enforcement state (policy {} does not arbitrate tenants, \
                 or tenant {tenant} has never been seen)",
                self.engine.policy_name()
            );
        };
        let opt_u64 = |v: Option<u64>| {
            v.map(|x| x.to_string()).unwrap_or_else(|| "null".into())
        };
        let opt_f64 = |v: Option<f64>| {
            v.map(|x| format!("{x:.6}")).unwrap_or_else(|| "null".into())
        };
        format!(
            "{{\"tenant\":{},\"enforced\":{},\"decided\":{},\"demand_bytes\":{},\
             \"granted_bytes\":{},\"cap_bytes\":{},\"admitted_epoch_bytes\":{},\
             \"denied\":{},\"ttl_clamp_secs\":{},\"slo_miss_ratio\":{},\
             \"measured_miss_ratio\":{},\"in_violation\":{},\"boost\":{:.3}}}",
            row.tenant,
            row.enforced,
            row.decided,
            row.demand_bytes,
            row.granted_bytes,
            opt_u64(row.cap_bytes),
            row.admitted_epoch_bytes,
            row.denied_admissions,
            opt_f64(row.ttl_clamp_secs),
            opt_f64(row.slo_miss_ratio),
            opt_f64(row.measured_miss_ratio),
            row.in_violation(),
            row.boost,
        )
    }

    /// `PLACEMENT` over the merged shard snapshots: resident bytes sum
    /// per tenant, pins re-index into a global instance space (shard
    /// `s`'s instance `i` becomes `Σ earlier shard sizes + i`), the
    /// reported instance count is the billed cluster target — the same
    /// figure the monolithic reply carries.
    fn placement_line(&mut self) -> String {
        let obs = self.engine.observe();
        let policy = obs.first().map(|o| o.placement.policy).unwrap_or_default();
        let mut rows: BTreeMap<TenantId, (u64, Option<Vec<u32>>)> = BTreeMap::new();
        let mut offset = 0u32;
        for o in &obs {
            for r in &o.placement.tenants {
                let entry = rows.entry(r.tenant).or_insert((0, None));
                entry.0 += r.resident_bytes;
                if let Some(pins) = &r.pins {
                    entry
                        .1
                        .get_or_insert_with(Vec::new)
                        .extend(pins.iter().map(|&i| i + offset));
                }
            }
            offset += o.instances;
        }
        let mut tenants = String::new();
        for (i, (tenant, (bytes, pins))) in rows.iter().enumerate() {
            if i > 0 {
                tenants.push(',');
            }
            let pins = match pins {
                Some(p) => format!(
                    "[{}]",
                    p.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
                ),
                None => "null".to_string(),
            };
            tenants.push_str(&format!(
                "{{\"tenant\":{tenant},\"physical_bytes\":{bytes},\"pins\":{pins}}}"
            ));
        }
        format!(
            "{{\"policy\":\"{}\",\"instances\":{},\"tenants\":[{}]}}",
            policy.as_str(),
            self.engine.instances(),
            tenants
        )
    }

    /// `WHY <tenant>` from the barrier-merged decision journal: same
    /// shape and error strings as the monolithic server's.
    fn why_line(&self, tenant: TenantId) -> String {
        let Some(journal) = self.engine.journal() else {
            return "ERR telemetry disabled (set [telemetry] enabled = true)".to_string();
        };
        if journal.is_empty() {
            return "ERR no epoch decision yet (force one with EPOCH)".to_string();
        }
        let Some((rec, dec)) = journal.last_for(tenant) else {
            return format!("ERR no decision recorded for tenant {tenant}");
        };
        format!(
            "{{\"t\":{},\"epoch\":{},\"instances\":{},\"cause\":{},\"decision\":{}}}",
            rec.t,
            rec.epoch,
            rec.instances,
            match dec.cause() {
                Some(c) => format!("\"{c}\""),
                None => "null".into(),
            },
            dec.to_json(),
        )
    }

    /// Merged Prometheus text block for `METRICS`, `# EOF`-terminated
    /// exactly like the monolithic reply.
    fn metrics_block(&self) -> String {
        match self.engine.metrics_text() {
            Some(text) => format!("{text}# EOF"),
            None => "ERR telemetry disabled (set [telemetry] enabled = true)".to_string(),
        }
    }

    /// `BILL <tenant>`: the most recent close-out reconciliation, same
    /// shape and error strings as the monolithic server's.
    fn bill_line(&self, tenant: TenantId) -> String {
        let Some(rec) = self
            .engine
            .costs()
            .reconciliations()
            .iter()
            .rev()
            .find(|r| r.tenant == tenant)
        else {
            return format!(
                "ERR no reconciliation for tenant {tenant} (only a retired tenant \
                 has a closed bill; STATS {tenant} reads the running ledger)"
            );
        };
        format!(
            "{{\"tenant\":{},\"at\":{},\"misses\":{},\"miss_dollars\":{},\
             \"storage_dollars\":{},\"total_dollars\":{}}}",
            rec.tenant,
            rec.at,
            rec.misses,
            rec.miss_dollars,
            rec.storage_dollars,
            rec.total_dollars,
        )
    }
}

/// A tenant's merged lifecycle verdict for `STATS <tenant>`.
enum LifecycleGate {
    /// The policy tracks no lifecycle (legacy zero-row replies).
    Untracked,
    /// No shard knows the tenant.
    Unknown,
    /// Every shard drained it: the documented `(retired)` error.
    Retired,
    /// The live merged state.
    State(LifecycleState),
}

/// Merge per-shard lifecycle states: the shards receive every lifecycle
/// event, so they only disagree transiently while a drain completes on
/// some shards before others — a tenant drained everywhere is `Retired`,
/// drained somewhere is still `Draining`, and an `Active` anywhere wins
/// over `Admitted` (a shard that saw no traffic yet).
fn merged_lifecycle(obs: &[ShardObservation], tenant: TenantId) -> LifecycleGate {
    if obs.iter().all(|o| o.lifecycle.is_none()) {
        return LifecycleGate::Untracked;
    }
    let states: Vec<LifecycleState> = obs
        .iter()
        .filter_map(|o| o.lifecycle.as_ref())
        .filter_map(|rows| rows.iter().find(|(t, _)| *t == tenant))
        .map(|(_, l)| l.state())
        .collect();
    if states.is_empty() {
        return LifecycleGate::Unknown;
    }
    if states.iter().all(|s| *s == LifecycleState::Retired) {
        return LifecycleGate::Retired;
    }
    if states
        .iter()
        .any(|s| matches!(s, LifecycleState::Draining | LifecycleState::Retired))
    {
        return LifecycleGate::State(LifecycleState::Draining);
    }
    if states.iter().any(|s| *s == LifecycleState::Active) {
        return LifecycleGate::State(LifecycleState::Active);
    }
    LifecycleGate::State(LifecycleState::Admitted)
}

/// Build the engine [`Request`] for a `GET <token> <size>` line, with
/// the same tenant-prefix and string-key hashing rules as
/// [`ServerState`]'s GET path.
fn get_request(token: &str, size: u64, tenant_routing: bool, ts: u64) -> Request {
    let (tenant, key) = if tenant_routing { split_tenant_key(token) } else { (0, token) };
    let obj = key.parse::<u64>().unwrap_or_else(|_| crate::mix64(fxhash_str(key)));
    Request { ts, obj, size: size.min(u32::MAX as u64) as u32, tenant }
}

fn get_reply(outcome: Option<crate::engine::GetOutcome>) -> String {
    match outcome {
        Some(o) if o.hit => "HIT".to_string(),
        Some(o) if o.spurious => "SPURIOUS".to_string(),
        Some(_) => "MISS".to_string(),
        None => "ERR shards shut down".to_string(),
    }
}

/// Serve one connection against the sharded runtime: `GET`s are parsed
/// and served right here on the connection thread, straight off the
/// owning shard's channel — N connections drive N shards concurrently.
/// Everything else hops to the front thread.
pub fn handle_conn_sharded(
    socket: TcpStream,
    tx: SrvTx,
    router: ShardRouter,
    tenant_routing: bool,
    start: Instant,
) -> Result<()> {
    let reader = BufReader::new(socket.try_clone()?);
    let mut w = socket;
    for line in reader.lines() {
        let line = line?;
        let text = match fast_get(&line, &router, tenant_routing, start) {
            Some(reply) => Some(reply),
            None => {
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(Msg::Line(line, reply_tx))
                    .map_err(|_| anyhow::anyhow!("state thread gone"))?;
                reply_rx.recv()?
            }
        };
        match text {
            Some(text) => {
                w.write_all(text.as_bytes())?;
                w.write_all(b"\n")?;
            }
            None => {
                w.write_all(b"BYE\n")?;
                break;
            }
        }
    }
    Ok(())
}

/// Serve `line` on the connection thread if it is a well-formed `GET`;
/// `None` means "forward to the front".
fn fast_get(
    line: &str,
    router: &ShardRouter,
    tenant_routing: bool,
    start: Instant,
) -> Option<String> {
    let mut parts = line.split_ascii_whitespace();
    if parts.next() != Some("GET") {
        return None;
    }
    let Some(token) = parts.next() else {
        return Some("ERR missing key".to_string());
    };
    let size: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(1);
    let req = get_request(token, size, tenant_routing, start.elapsed().as_micros() as u64);
    Some(get_reply(router.get(&req)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::util::tempdir::tempdir;

    /// Drive one line through the state thread and wait for the reply.
    fn ask(tx: &SrvTx, line: &str) -> Option<String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Msg::Line(line.to_string(), reply_tx)).unwrap();
        reply_rx.recv().unwrap()
    }

    #[test]
    fn state_thread_serves_the_protocol() {
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let server = spawn_state(cfg, None).unwrap();
        assert_eq!(server.resumed_epochs, 0);
        assert_eq!(ask(&server.tx, "GET k 100").unwrap(), "MISS");
        assert_eq!(ask(&server.tx, "GET k 100").unwrap(), "HIT");
        assert!(ask(&server.tx, "EPOCH").unwrap().starts_with("RESIZED"));
        assert!(ask(&server.tx, "QUIT").is_none());
    }

    #[test]
    fn ticks_close_epochs_like_the_epoch_command() {
        let cfg = Config::with_policy(PolicyKind::Fixed);
        let server = spawn_state(cfg, None).unwrap();
        ask(&server.tx, "GET k 100");
        server.tx.send(Msg::Tick).unwrap();
        server.tx.send(Msg::Tick).unwrap();
        // STATS after the ticks: the state thread is serial, so by the
        // time the reply arrives both ticks have been handled.
        let stats = ask(&server.tx, "STATS").unwrap();
        assert!(stats.contains("\"requests\":1"), "{stats}");
    }

    #[test]
    fn checkpointed_kill_and_resume_is_bit_identical() {
        let dir = tempdir().unwrap();
        let interrupted = dir.path().join("interrupted.ckpt");
        let baseline = dir.path().join("baseline.ckpt");
        let cfg = || {
            let mut c = Config::with_policy(PolicyKind::Fixed);
            c.scaler.fixed_instances = 2;
            c
        };
        // Segment 1 keys / segment 2 keys are disjoint and fresh, so the
        // resumed (cold-cache) run misses exactly like the baseline.
        let seg1: Vec<String> = (0..40).map(|i| format!("GET a{i} 1000")).collect();
        let seg2: Vec<String> = (0..40).map(|i| format!("GET b{i} 1000")).collect();

        // Baseline: both segments through one uninterrupted server, with
        // the same epoch boundaries the interrupted run will have.
        let bsrv = spawn_state(cfg(), Some(baseline.clone())).unwrap();
        for line in &seg1 {
            ask(&bsrv.tx, line);
        }
        ask(&bsrv.tx, "EPOCH");
        for line in &seg2 {
            ask(&bsrv.tx, line);
        }
        ask(&bsrv.tx, "EPOCH");
        drop(bsrv.tx); // let the state thread exit

        // Interrupted: segment 1, an EPOCH, then a "kill" (drop the
        // channel — the checkpoint is already fsync'd per record).
        let s1 = spawn_state(cfg(), Some(interrupted.clone())).unwrap();
        for line in &seg1 {
            ask(&s1.tx, line);
        }
        ask(&s1.tx, "EPOCH");
        drop(s1.tx);

        // Resume and finish with segment 2.
        let s2 = spawn_state(cfg(), Some(interrupted.clone())).unwrap();
        assert_eq!(s2.resumed_epochs, 1, "one closed epoch must be restored");
        for line in &seg2 {
            ask(&s2.tx, line);
        }
        ask(&s2.tx, "EPOCH");
        drop(s2.tx);

        // Compare the durable bills: both runs closed the same two
        // epochs, so every cumulative figure must agree bit for bit.
        // Epoch timestamps are wall-clock and legitimately differ — the
        // money and the counts must not.
        let last = |p: &std::path::Path| checkpoint::read(p).unwrap().pop().unwrap();
        let (a, b) = (last(&interrupted), last(&baseline));
        assert_eq!((a.epoch, b.epoch), (2, 2));
        assert_eq!(a.cum_miss_dollars, b.cum_miss_dollars, "bit-identical miss dollars");
        assert_eq!(a.cum_storage_dollars, b.cum_storage_dollars, "bit-identical storage");
        assert_eq!(a.ledgers, b.ledgers, "bit-identical per-tenant ledgers");
        assert_eq!(a.costs.instances, b.costs.instances);
        assert_eq!(a.costs.miss_count, b.costs.miss_count);
        assert_eq!(
            a.bills.iter().map(|x| (x.tenant, x.storage, x.miss)).collect::<Vec<_>>(),
            b.bills.iter().map(|x| (x.tenant, x.storage, x.miss)).collect::<Vec<_>>(),
            "bit-identical final-epoch bill rows"
        );
    }

    #[test]
    fn end_to_end_over_tcp_with_concurrent_connections() {
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = spawn_state(cfg, None).unwrap();
        let tx = server.tx.clone();
        std::thread::spawn(move || {
            let _ = accept_loop(listener, tx);
        });
        let mut handles = Vec::new();
        for c in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                // Per-connection keys: each object's accesses stay on one
                // connection, so every key misses once then hits.
                sock.write_all(format!("GET c{c}k 100\nGET c{c}k 100\nQUIT\n").as_bytes())
                    .unwrap();
                let mut lines = BufReader::new(sock).lines();
                assert_eq!(lines.next().unwrap().unwrap(), "MISS");
                assert_eq!(lines.next().unwrap().unwrap(), "HIT");
                assert_eq!(lines.next().unwrap().unwrap(), "BYE");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = ask(&server.tx, "STATS").unwrap();
        assert!(stats.contains("\"requests\":8"), "{stats}");
        assert!(stats.contains("\"misses\":4"), "{stats}");
    }

    #[test]
    fn sharded_front_serves_the_control_plane() {
        let mut cfg = Config::with_policy(PolicyKind::Ttl);
        cfg.engine.shards = 4;
        cfg.telemetry.enabled = true;
        let server = spawn_sharded_state(cfg, None).unwrap();
        assert_eq!(server.resumed_epochs, 0);
        assert_eq!(ask(&server.tx, "GET k 100").unwrap(), "MISS");
        assert_eq!(ask(&server.tx, "GET k 100").unwrap(), "HIT");
        assert_eq!(
            ask(&server.tx, "WHY 0").unwrap(),
            "ERR no epoch decision yet (force one with EPOCH)"
        );
        assert!(ask(&server.tx, "EPOCH").unwrap().starts_with("RESIZED"));
        let stats = ask(&server.tx, "STATS").unwrap();
        assert!(stats.contains("\"requests\":2"), "{stats}");
        assert!(stats.contains("\"misses\":1"), "{stats}");
        assert!(stats.contains("\"shards\":4"), "{stats}");
        // WHY after the boundary: tenant 0 was billed, so the journal
        // carries a decision row for it.
        let why = ask(&server.tx, "WHY 0").unwrap();
        assert!(why.starts_with("{\"t\":"), "{why}");
        assert!(why.contains("\"decision\":{\"tenant\":0,"), "{why}");
        // STATS <tenant>: the ttl policy tracks no lifecycle, so the
        // legacy reply shape (no state key) with the summed counters.
        let ts = ask(&server.tx, "STATS 0").unwrap();
        assert!(ts.contains("\"tenant\":0"), "{ts}");
        assert!(ts.contains("\"requests\":2"), "{ts}");
        assert!(ts.contains("\"misses\":1"), "{ts}");
        // PLACEMENT merges the per-shard snapshots.
        let placement = ask(&server.tx, "PLACEMENT").unwrap();
        assert!(placement.starts_with("{\"policy\":\"shared\""), "{placement}");
        // SLO on a non-arbitrating policy: the documented error.
        assert!(
            ask(&server.tx, "SLO 0").unwrap().starts_with("ERR no enforcement state"),
        );
        // METRICS: merged exposition, shard-labeled and EOF-terminated.
        let metrics = ask(&server.tx, "METRICS").unwrap();
        assert!(metrics.contains("elastictl_requests_total{shard=\"0\"}"), "{metrics}");
        assert!(metrics.ends_with("# EOF"), "{metrics}");
        assert!(ask(&server.tx, "FROB").unwrap().starts_with("ERR unknown command"));
        assert!(ask(&server.tx, "QUIT").is_none());
    }

    #[test]
    fn sharded_control_plane_without_telemetry() {
        let mut cfg = Config::with_policy(PolicyKind::Ttl);
        cfg.engine.shards = 2;
        let server = spawn_sharded_state(cfg, None).unwrap();
        ask(&server.tx, "GET k 100");
        ask(&server.tx, "EPOCH");
        assert_eq!(
            ask(&server.tx, "WHY 0").unwrap(),
            "ERR telemetry disabled (set [telemetry] enabled = true)"
        );
        assert_eq!(
            ask(&server.tx, "METRICS").unwrap(),
            "ERR telemetry disabled (set [telemetry] enabled = true)"
        );
        // The observation surface works without telemetry.
        let ts = ask(&server.tx, "STATS 0").unwrap();
        assert!(ts.contains("\"requests\":1"), "{ts}");
        let placement = ask(&server.tx, "PLACEMENT").unwrap();
        assert!(placement.starts_with("{\"policy\":"), "{placement}");
    }

    #[test]
    fn sharded_admit_retire_bill_flow() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.engine.shards = 2;
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 4;
        cfg.tenants = vec![crate::tenant::TenantSpec::new(0, "base")];
        let server = spawn_sharded_state(cfg, None).unwrap();
        assert_eq!(
            ask(&server.tx, "ADMIT 5 reserved_mb=1 multiplier=3.0 name=guest").unwrap(),
            "OK 5 admitted"
        );
        assert_eq!(ask(&server.tx, "GET 5/k1 1000").unwrap(), "MISS");
        assert_eq!(ask(&server.tx, "GET 5/k1 1000").unwrap(), "HIT");
        assert!(
            ask(&server.tx, "BILL 5").unwrap().starts_with("ERR no reconciliation"),
            "live tenants have no closed bill"
        );
        assert_eq!(ask(&server.tx, "RETIRE 5").unwrap(), "OK 5 draining");
        ask(&server.tx, "EPOCH");
        let bill = ask(&server.tx, "BILL 5").unwrap();
        assert!(bill.starts_with('{'), "{bill}");
        assert!(bill.contains("\"tenant\":5"), "{bill}");
        assert!(bill.contains("\"misses\":1"), "{bill}");
        // Error surface matches the monolithic server's strings.
        assert!(ask(&server.tx, "ADMIT nope").unwrap().starts_with("ERR bad tenant"));
        assert!(ask(&server.tx, "ADMIT 6 bogus").unwrap().starts_with("ERR bad admit arg"));
        assert!(ask(&server.tx, "ADMIT 6 slo=7").unwrap().starts_with("ERR bad slo"));
        assert!(ask(&server.tx, "RETIRE 99").unwrap().starts_with("ERR"));
    }

    #[test]
    fn sharded_tcp_gets_run_on_connection_threads() {
        let mut cfg = Config::with_policy(PolicyKind::Ttl);
        cfg.engine.shards = 2;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = spawn_sharded_state(cfg, None).unwrap();
        let (tx, router) = (server.tx.clone(), server.router.clone());
        let (tenant_routing, start) = (server.tenant_routing, server.start);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let socket = stream.unwrap();
                let (tx, router) = (tx.clone(), router.clone());
                std::thread::spawn(move || {
                    let _ = handle_conn_sharded(socket, tx, router, tenant_routing, start);
                });
            }
        });
        let mut handles = Vec::new();
        for c in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut sock = TcpStream::connect(addr).unwrap();
                sock.write_all(format!("GET c{c}k 100\nGET c{c}k 100\nQUIT\n").as_bytes())
                    .unwrap();
                let mut lines = BufReader::new(sock).lines();
                assert_eq!(lines.next().unwrap().unwrap(), "MISS");
                assert_eq!(lines.next().unwrap().unwrap(), "HIT");
                assert_eq!(lines.next().unwrap().unwrap(), "BYE");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = ask(&server.tx, "STATS").unwrap();
        assert!(stats.contains("\"requests\":8"), "{stats}");
        assert!(stats.contains("\"misses\":4"), "{stats}");
    }

    #[test]
    fn sharded_checkpoint_resume_is_bit_identical() {
        let dir = tempdir().unwrap();
        let interrupted = dir.path().join("interrupted.ckpt");
        let baseline = dir.path().join("baseline.ckpt");
        let cfg = || {
            let mut c = Config::with_policy(PolicyKind::Fixed);
            c.scaler.fixed_instances = 2;
            c.engine.shards = 2;
            c
        };
        let seg1: Vec<String> = (0..40).map(|i| format!("GET a{i} 1000")).collect();
        let seg2: Vec<String> = (0..40).map(|i| format!("GET b{i} 1000")).collect();

        // Baseline: both segments through one uninterrupted sharded server.
        let bsrv = spawn_sharded_state(cfg(), Some(baseline.clone())).unwrap();
        for line in &seg1 {
            ask(&bsrv.tx, line);
        }
        ask(&bsrv.tx, "EPOCH");
        for line in &seg2 {
            ask(&bsrv.tx, line);
        }
        ask(&bsrv.tx, "EPOCH");
        drop(bsrv.tx);

        // Interrupted: segment 1, an EPOCH, then a "kill".
        let s1 = spawn_sharded_state(cfg(), Some(interrupted.clone())).unwrap();
        for line in &seg1 {
            ask(&s1.tx, line);
        }
        ask(&s1.tx, "EPOCH");
        drop(s1.tx);

        // Resume and finish with segment 2.
        let s2 = spawn_sharded_state(cfg(), Some(interrupted.clone())).unwrap();
        assert_eq!(s2.resumed_epochs, 1, "one closed epoch must be restored");
        for line in &seg2 {
            ask(&s2.tx, line);
        }
        ask(&s2.tx, "EPOCH");
        drop(s2.tx);

        let last = |p: &std::path::Path| checkpoint::read(p).unwrap().pop().unwrap();
        let (a, b) = (last(&interrupted), last(&baseline));
        assert_eq!((a.epoch, b.epoch), (2, 2));
        assert_eq!(a.cum_miss_dollars, b.cum_miss_dollars, "bit-identical miss dollars");
        assert_eq!(a.cum_storage_dollars, b.cum_storage_dollars, "bit-identical storage");
        assert_eq!(a.ledgers, b.ledgers, "bit-identical per-tenant ledgers");
        assert_eq!(a.costs.instances, b.costs.instances);
        assert_eq!(a.costs.miss_count, b.costs.miss_count);
    }
}
