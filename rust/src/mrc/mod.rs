//! Miss Ratio Curves (§3): the exact Olken profiler extended to
//! heterogeneous object sizes via a weighted order-statistics tree
//! (O(log M) per request — the footnote-1 approach the paper uses), and
//! the SHARDS-style sampled approximation whose accuracy degradation under
//! heterogeneous sizes Fig. 2 demonstrates.

mod olken;
mod shards;

pub use olken::OlkenProfiler;
pub use shards::{ShardsProfiler, ShardsMode};

use crate::metrics::LogHistogram;

/// A miss-ratio curve: for each candidate cache size (bytes), the fraction
/// of requests that would miss under LRU at that size.
#[derive(Debug, Clone)]
pub struct MissRatioCurve {
    /// (cache_size_bytes, miss_ratio) points, size ascending.
    pub points: Vec<(u64, f64)>,
    /// Requests profiled.
    pub requests: f64,
    /// Cold (first-access) misses — unavoidable at any size.
    pub cold_misses: f64,
}

impl MissRatioCurve {
    /// Build the curve from a reuse-distance histogram. A request with
    /// (byte-weighted) reuse distance `d` hits iff the cache size exceeds
    /// `d`; cold misses never hit.
    pub fn from_histogram(hist: &LogHistogram, cold: f64) -> Self {
        let requests = hist.total() + cold;
        let mut points = Vec::with_capacity(hist.num_buckets());
        for i in 0..hist.num_buckets() {
            let size = hist.bucket_lo(i + 1);
            let hits = hist.cumulative_le(size);
            let mr = if requests > 0.0 {
                1.0 - hits / requests
            } else {
                1.0
            };
            points.push((size, mr));
        }
        MissRatioCurve { points, requests, cold_misses: cold }
    }

    /// Miss ratio at `size` bytes (step interpolation; 1.0 below the first
    /// point's size).
    pub fn miss_ratio_at(&self, size: u64) -> f64 {
        match self.points.binary_search_by_key(&size, |&(s, _)| s) {
            Ok(i) => self.points[i].1,
            Err(0) => 1.0,
            Err(i) => self.points[i - 1].1,
        }
    }

    /// Mean absolute error against another curve, evaluated on this
    /// curve's size grid restricted to `[lo, hi]` — the Fig. 2 error
    /// metric ("absolute difference between the exact and the approximated
    /// MRCs over all the meaningful cache sizes, then the mean").
    pub fn mean_abs_error(&self, other: &MissRatioCurve, lo: u64, hi: u64) -> f64 {
        let pts: Vec<&(u64, f64)> = self
            .points
            .iter()
            .filter(|&&(s, _)| s >= lo && s <= hi)
            .collect();
        if pts.is_empty() {
            return 0.0;
        }
        pts.iter()
            .map(|&&(s, mr)| (mr - other.miss_ratio_at(s)).abs())
            .sum::<f64>()
            / pts.len() as f64
    }

    /// The curve is non-increasing in size by construction; expose a check
    /// for property tests.
    pub fn is_monotone(&self) -> bool {
        self.points.windows(2).all(|w| w[1].1 <= w[0].1 + 1e-12)
    }
}

/// Common interface for MRC profilers.
pub trait MrcProfiler {
    /// Record one request; returns the byte-weighted reuse distance if the
    /// object was seen before (`None` for cold misses).
    fn record(&mut self, obj: crate::ObjectId, size: u64) -> Option<u64>;
    /// Build the current miss ratio curve.
    fn curve(&self) -> MissRatioCurve;
    /// Decay accumulated history (epoch boundary).
    fn decay(&mut self, factor: f64);
    /// Requests profiled so far (possibly decayed).
    fn requests(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curve_from_histogram_monotone() {
        let mut h = LogHistogram::new(2.0, 1 << 20);
        for d in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.inc(d);
            }
        }
        let c = MissRatioCurve::from_histogram(&h, 5.0);
        assert!(c.is_monotone());
        assert_eq!(c.requests, 55.0);
        // At a huge size only cold misses remain: 5/55.
        let tail = c.miss_ratio_at(1 << 20);
        assert!((tail - 5.0 / 55.0).abs() < 1e-9, "tail={tail}");
        // Below every distance everything misses.
        assert_eq!(c.miss_ratio_at(1), 1.0 - 0.0 / 55.0);
    }

    #[test]
    fn error_metric_is_zero_for_identical_curves() {
        let mut h = LogHistogram::new(2.0, 1 << 16);
        for d in [5u64, 50, 500] {
            h.inc(d);
        }
        let a = MissRatioCurve::from_histogram(&h, 1.0);
        let b = MissRatioCurve::from_histogram(&h, 1.0);
        assert_eq!(a.mean_abs_error(&b, 1, 1 << 16), 0.0);
    }

    #[test]
    fn error_metric_detects_shift() {
        let mut h1 = LogHistogram::new(2.0, 1 << 16);
        let mut h2 = LogHistogram::new(2.0, 1 << 16);
        for _ in 0..100 {
            h1.inc(100);
            h2.inc(10_000); // same mass at much larger distances
        }
        let a = MissRatioCurve::from_histogram(&h1, 0.0);
        let b = MissRatioCurve::from_histogram(&h2, 0.0);
        assert!(a.mean_abs_error(&b, 1, 1 << 16) > 0.1);
    }
}
