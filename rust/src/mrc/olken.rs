//! Exact MRC profiling for heterogeneous object sizes — Olken's algorithm
//! with an **order-statistics treap** weighted by object size (the
//! footnote-1 technique: `rank(x)` returns the total bytes of objects
//! accessed more recently than `x`). O(log M) per request.
//!
//! Each resident object is a treap node keyed by its last-access sequence
//! number; the subtree aggregates resident bytes. On a re-access, the
//! byte-weighted reuse distance is the sum of weights of keys greater than
//! the object's previous key — exactly the minimum LRU cache size at which
//! that request would have hit.

use super::{MissRatioCurve, MrcProfiler};
use crate::metrics::LogHistogram;
use crate::util::fasthash::FastMap;
use crate::{mix64, ObjectId};

const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct TreapNode {
    key: u64,      // last-access sequence number (unique)
    priority: u64, // heap priority (hash of key)
    weight: u64,   // object size in bytes
    subtree_weight: u64,
    left: u32,
    right: u32,
}

/// Size-weighted order-statistics treap.
struct WeightedTreap {
    nodes: Vec<TreapNode>,
    free: Vec<u32>,
    root: u32,
}

impl WeightedTreap {
    fn new() -> Self {
        WeightedTreap { nodes: Vec::new(), free: Vec::new(), root: NIL }
    }

    #[inline]
    fn weight_of(&self, idx: u32) -> u64 {
        if idx == NIL {
            0
        } else {
            self.nodes[idx as usize].subtree_weight
        }
    }

    #[inline]
    fn update(&mut self, idx: u32) {
        if idx == NIL {
            return;
        }
        let (l, r, w) = {
            let n = &self.nodes[idx as usize];
            (n.left, n.right, n.weight)
        };
        self.nodes[idx as usize].subtree_weight =
            w + self.weight_of(l) + self.weight_of(r);
    }

    fn alloc(&mut self, key: u64, weight: u64) -> u32 {
        let node = TreapNode {
            key,
            priority: mix64(key ^ 0x7E4B_D1C3_5A96_0F2E),
            weight,
            subtree_weight: weight,
            left: NIL,
            right: NIL,
        };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = node;
                i
            }
            None => {
                let i = self.nodes.len() as u32;
                self.nodes.push(node);
                i
            }
        }
    }

    /// Split by key: returns (subtree with keys ≤ k, subtree with keys > k).
    fn split(&mut self, idx: u32, k: u64) -> (u32, u32) {
        if idx == NIL {
            return (NIL, NIL);
        }
        if self.nodes[idx as usize].key <= k {
            let right = self.nodes[idx as usize].right;
            let (a, b) = self.split(right, k);
            self.nodes[idx as usize].right = a;
            self.update(idx);
            (idx, b)
        } else {
            let left = self.nodes[idx as usize].left;
            let (a, b) = self.split(left, k);
            self.nodes[idx as usize].left = b;
            self.update(idx);
            (a, idx)
        }
    }

    /// Merge two treaps where all keys of `a` < all keys of `b`.
    fn merge(&mut self, a: u32, b: u32) -> u32 {
        if a == NIL {
            return b;
        }
        if b == NIL {
            return a;
        }
        if self.nodes[a as usize].priority > self.nodes[b as usize].priority {
            let ar = self.nodes[a as usize].right;
            let m = self.merge(ar, b);
            self.nodes[a as usize].right = m;
            self.update(a);
            a
        } else {
            let bl = self.nodes[b as usize].left;
            let m = self.merge(a, bl);
            self.nodes[b as usize].left = m;
            self.update(b);
            b
        }
    }

    /// Insert a node with a key strictly greater than every existing key
    /// (access sequence numbers are monotone), so this is a merge at the
    /// right spine.
    fn insert_max(&mut self, key: u64, weight: u64) {
        let idx = self.alloc(key, weight);
        self.root = self.merge(self.root, idx);
    }

    /// Total bytes with key strictly greater than `k`.
    fn weight_greater(&mut self, k: u64) -> u64 {
        // Non-destructive walk.
        let mut acc = 0u64;
        let mut cur = self.root;
        while cur != NIL {
            let n = &self.nodes[cur as usize];
            if n.key > k {
                acc += n.weight + self.weight_of(n.right);
                cur = n.left;
            } else {
                cur = n.right;
            }
        }
        acc
    }

    /// Remove the node with exactly key `k` (must exist). Returns weight.
    fn remove(&mut self, k: u64) -> u64 {
        let (le, gt) = self.split(self.root, k);
        let (lt, eq) = self.split(le, k - 1);
        debug_assert!(eq != NIL, "key {k} not present");
        let w = self.nodes[eq as usize].weight;
        debug_assert_eq!(self.nodes[eq as usize].key, k);
        debug_assert!(
            self.nodes[eq as usize].left == NIL && self.nodes[eq as usize].right == NIL
        );
        self.free.push(eq);
        self.root = self.merge(lt, gt);
        w
    }

    fn total_weight(&self) -> u64 {
        self.weight_of(self.root)
    }

    fn len(&self) -> usize {
        self.nodes.len() - self.free.len()
    }
}

/// Exact Olken profiler over heterogeneous sizes.
pub struct OlkenProfiler {
    treap: WeightedTreap,
    last_key: FastMap<ObjectId, u64>,
    seq: u64,
    hist: LogHistogram,
    cold: f64,
    requests: f64,
    /// If true, ignore real sizes and weight every object 1 byte — the
    /// uniform-size mode used as the Fig. 2 control.
    uniform: bool,
}

impl OlkenProfiler {
    /// `max_bytes` bounds the histogram range (largest meaningful cache
    /// size); `hist_base` sets resolution (e.g. 1.3 ≈ 4 buckets/octave).
    pub fn new(max_bytes: u64, hist_base: f64, uniform: bool) -> Self {
        OlkenProfiler {
            treap: WeightedTreap::new(),
            last_key: FastMap::default(),
            seq: 0,
            hist: LogHistogram::new(hist_base, max_bytes),
            cold: 0.0,
            requests: 0.0,
            uniform,
        }
    }

    /// Convenience: byte-weighted profiler with 1.3 base up to 1 TB.
    pub fn sized(max_bytes: u64) -> Self {
        Self::new(max_bytes, 1.3, false)
    }

    /// Resident objects tracked.
    pub fn tracked(&self) -> usize {
        self.treap.len()
    }

    /// Total tracked bytes.
    pub fn tracked_bytes(&self) -> u64 {
        self.treap.total_weight()
    }

    pub fn cold_misses(&self) -> f64 {
        self.cold
    }
}

impl MrcProfiler for OlkenProfiler {
    fn record(&mut self, obj: ObjectId, size: u64) -> Option<u64> {
        let w = if self.uniform { 1 } else { size.max(1) };
        self.seq += 1;
        let key = self.seq;
        self.requests += 1.0;
        let dist = match self.last_key.get(&obj).copied() {
            Some(old_key) => {
                let d = self.treap.weight_greater(old_key);
                self.treap.remove(old_key);
                self.hist.inc(d);
                Some(d)
            }
            None => {
                self.cold += 1.0;
                None
            }
        };
        self.treap.insert_max(key, w);
        self.last_key.insert(obj, key);
        dist
    }

    fn curve(&self) -> MissRatioCurve {
        MissRatioCurve::from_histogram(&self.hist, self.cold)
    }

    fn decay(&mut self, factor: f64) {
        self.hist.decay(factor);
        self.cold *= factor;
        self.requests *= factor;
    }

    fn requests(&self) -> f64 {
        self.requests
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_distance_counts_intervening_bytes() {
        let mut p = OlkenProfiler::sized(1 << 30);
        assert_eq!(p.record(1, 100), None); // cold
        assert_eq!(p.record(2, 200), None);
        assert_eq!(p.record(3, 300), None);
        // Re-access 1: objects 2 and 3 were touched since → 500 bytes.
        assert_eq!(p.record(1, 100), Some(500));
        // Re-access 1 again immediately: nothing in between → 0.
        assert_eq!(p.record(1, 100), Some(0));
        // Re-access 2: 1 and 3 touched since 2's access → 400.
        assert_eq!(p.record(2, 200), Some(400));
        assert_eq!(p.cold_misses(), 3.0);
    }

    #[test]
    fn repeated_accesses_do_not_double_count() {
        let mut p = OlkenProfiler::sized(1 << 30);
        p.record(1, 100);
        p.record(2, 50);
        p.record(2, 50);
        p.record(2, 50);
        // Only one copy of object 2 separates the accesses of 1.
        assert_eq!(p.record(1, 100), Some(50));
        assert_eq!(p.tracked(), 2);
        assert_eq!(p.tracked_bytes(), 150);
    }

    #[test]
    fn uniform_mode_counts_objects() {
        let mut p = OlkenProfiler::new(1 << 20, 2.0, true);
        p.record(1, 12345);
        p.record(2, 999);
        p.record(3, 1);
        assert_eq!(p.record(1, 12345), Some(2)); // two objects in between
    }

    #[test]
    fn curve_matches_brute_force_lru_simulation() {
        // Cross-check: for a small trace, the Olken curve evaluated at size
        // S must equal the miss ratio of an actual LRU(S) simulation.
        use crate::cache::{LruCache, Store};
        let objs: Vec<(u64, u64)> = (0..60)
            .map(|i| {
                let o = crate::mix64(i) % 12;
                (o, 50 + o * 10)
            })
            .collect();
        let mut p = OlkenProfiler::new(1 << 20, 1.05, false);
        for &(o, s) in &objs {
            p.record(o, s);
        }
        let curve = p.curve();
        for cache_size in [100u64, 400, 1000, 4000] {
            let mut lru = LruCache::new(cache_size);
            let mut misses = 0.0;
            for &(o, s) in &objs {
                if !lru.lookup(o) {
                    misses += 1.0;
                    lru.insert(o, s);
                }
            }
            let sim_mr = misses / objs.len() as f64;
            let olken_mr = curve.miss_ratio_at(cache_size);
            // Histogram bucketing introduces bounded quantization error.
            assert!(
                (sim_mr - olken_mr).abs() < 0.12,
                "size={cache_size}: sim={sim_mr} olken={olken_mr}"
            );
        }
    }

    #[test]
    fn treap_internal_consistency_under_churn() {
        let mut p = OlkenProfiler::sized(1 << 30);
        let mut expected_bytes: u64 = 0;
        let mut sizes = std::collections::HashMap::new();
        for i in 0..5000u64 {
            let obj = crate::mix64(i) % 500;
            let size = 10 + obj * 3;
            if !sizes.contains_key(&obj) {
                expected_bytes += size;
                sizes.insert(obj, size);
            }
            p.record(obj, size);
        }
        assert_eq!(p.tracked(), sizes.len());
        assert_eq!(p.tracked_bytes(), expected_bytes);
    }

    #[test]
    fn decay_scales_history() {
        let mut p = OlkenProfiler::sized(1 << 20);
        for i in 0..100u64 {
            p.record(i % 10, 100);
        }
        let r0 = p.requests();
        p.decay(0.25);
        assert!((p.requests() - r0 * 0.25).abs() < 1e-9);
        assert!(p.curve().is_monotone());
    }
}
