//! SHARDS-style approximate MRC via spatial (hash-based) sampling
//! ([38]/[37] as discussed in §3): keep only objects whose key hash falls
//! under a threshold `R·P`, profile them exactly, and scale distances and
//! counts by `1/R`.
//!
//! The Fig. 2 experiment of the paper shows the approximation is excellent
//! under *uniform* sizes (error ≤ 3e-3 for R ∈ [1e-3, 1e-1]) but degrades
//! by an order of magnitude with *heterogeneous* sizes: sampling objects
//! uniformly mis-estimates byte-weighted distances because the rare large
//! objects carry most of the bytes. [`ShardsMode`] selects the control
//! (uniform) vs. treatment (sized) arms of that experiment.

use super::{MissRatioCurve, MrcProfiler, OlkenProfiler};
use crate::{mix64, ObjectId};

/// Which distance weighting the profiler uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardsMode {
    /// Every object weighs 1 unit (the assumption of the published
    /// approximate-MRC schemes); distances are object counts scaled by 1/R.
    /// Accurate when the workload really has uniform sizes — the Fig. 2
    /// control arm.
    Uniform,
    /// Objects weigh their byte size inside the sampled tree; distances
    /// are bytes scaled by 1/R — the "obvious" heterogeneous extension
    /// whose accuracy §3 questions.
    Sized,
    /// The published algorithm applied *as-is* to heterogeneous traffic:
    /// distances in object counts, curve x-axis converted to bytes via the
    /// estimated mean object size — the Fig. 2 treatment arm (this is what
    /// "assume uniform sizes" costs on a real CDN trace).
    UniformAssumed,
}

const HASH_SPACE: u64 = 1 << 24;

/// Fixed-rate SHARDS profiler.
pub struct ShardsProfiler {
    inner: OlkenProfiler,
    threshold: u64,
    rate: f64,
    mode: ShardsMode,
    seed: u64,
    /// All requests seen (sampled or not).
    seen: f64,
    /// Sampled requests.
    sampled: f64,
    /// Mean-object-size estimator over sampled cold misses (used by
    /// [`ShardsMode::UniformAssumed`] to convert object counts to bytes).
    size_sum: f64,
    size_count: f64,
}

impl ShardsProfiler {
    /// `rate` ∈ (0, 1]: fraction of the object population profiled.
    pub fn new(rate: f64, max_bytes: u64, mode: ShardsMode, seed: u64) -> Self {
        Self::with_base(rate, max_bytes, mode, seed, 1.3)
    }

    /// As [`Self::new`] with an explicit reuse-histogram base (finer bases
    /// reduce quantization error at the cost of memory; the Fig. 2
    /// experiment uses 1.05 so sampling/assumption error dominates).
    pub fn with_base(rate: f64, max_bytes: u64, mode: ShardsMode, seed: u64, base: f64) -> Self {
        assert!(rate > 0.0 && rate <= 1.0);
        let scaled_max = (max_bytes as f64 * rate).max(2.0) as u64;
        ShardsProfiler {
            inner: OlkenProfiler::new(
                scaled_max.max(1 << 10),
                base,
                mode != ShardsMode::Sized,
            ),
            threshold: (rate * HASH_SPACE as f64) as u64,
            rate,
            mode,
            seed,
            seen: 0.0,
            sampled: 0.0,
            size_sum: 0.0,
            size_count: 0.0,
        }
    }

    /// Estimated mean object size over the sampled population (bytes).
    pub fn mean_object_size(&self) -> f64 {
        if self.size_count == 0.0 {
            1.0
        } else {
            self.size_sum / self.size_count
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn mode(&self) -> ShardsMode {
        self.mode
    }

    /// Spatial sampling filter: an object is in the sample iff its hash
    /// falls below the threshold — consistent across the whole trace.
    #[inline]
    pub fn is_sampled(&self, obj: ObjectId) -> bool {
        mix64(obj ^ self.seed) % HASH_SPACE < self.threshold
    }

    /// Fraction of requests that entered the sample (diagnostic; should be
    /// ≈ rate for uniform popularity, higher when hot objects are sampled).
    pub fn sample_fraction(&self) -> f64 {
        if self.seen == 0.0 {
            0.0
        } else {
            self.sampled / self.seen
        }
    }
}

impl MrcProfiler for ShardsProfiler {
    fn record(&mut self, obj: ObjectId, size: u64) -> Option<u64> {
        self.seen += 1.0;
        if !self.is_sampled(obj) {
            return None;
        }
        self.sampled += 1.0;
        let dist = self.inner.record(obj, size);
        if dist.is_none() {
            // Cold miss: first sight of this object — update the
            // population mean-size estimate (unbiased: spatial sampling is
            // independent of size).
            self.size_sum += size as f64;
            self.size_count += 1.0;
        }
        dist
    }

    /// Scale the sampled curve back to the full population: distances
    /// stretch by 1/R; in [`ShardsMode::UniformAssumed`] the x-axis is
    /// additionally converted from object counts to bytes via the mean
    /// object size (the uniform-size assumption of the published schemes).
    fn curve(&self) -> MissRatioCurve {
        let sampled = self.inner.curve();
        let x_scale = match self.mode {
            ShardsMode::UniformAssumed => self.mean_object_size() / self.rate,
            _ => 1.0 / self.rate,
        };
        let points = sampled
            .points
            .iter()
            .map(|&(s, mr)| (((s as f64 * x_scale) as u64).max(1), mr))
            .collect();
        MissRatioCurve {
            points,
            requests: sampled.requests / self.rate,
            cold_misses: sampled.cold_misses / self.rate,
        }
    }

    fn decay(&mut self, factor: f64) {
        self.inner.decay(factor);
        self.seen *= factor;
        self.sampled *= factor;
    }

    fn requests(&self) -> f64 {
        self.seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{SynthConfig, SynthGenerator};

    #[test]
    fn rate_one_matches_exact() {
        // R = 1 samples everything: the curve must coincide with Olken's.
        let trace = SynthGenerator::new(SynthConfig::tiny()).generate();
        let mut exact = OlkenProfiler::new(1 << 34, 1.3, false);
        let mut shards = ShardsProfiler::new(1.0, 1 << 34, ShardsMode::Sized, 5);
        for r in &trace {
            exact.record(r.obj, r.size_bytes());
            shards.record(r.obj, r.size_bytes());
        }
        let e = exact.curve();
        let s = shards.curve();
        let err = e.mean_abs_error(&s, 1 << 10, 1 << 32);
        assert!(err < 1e-9, "err={err}");
    }

    #[test]
    fn sampling_fraction_tracks_rate() {
        let mut p = ShardsProfiler::new(0.1, 1 << 30, ShardsMode::Uniform, 3);
        // Uniform object popularity → sampled request fraction ≈ rate.
        for obj in 0..200_000u64 {
            p.record(obj, 100);
        }
        let f = p.sample_fraction();
        assert!((f - 0.1).abs() < 0.01, "fraction={f}");
    }

    #[test]
    fn uniform_mode_is_accurate_at_modest_rates() {
        // The headline property of SHARDS the paper reproduces as its
        // control arm: uniform sizes + 10% sampling ⇒ small error.
        let mut cfg = SynthConfig::tiny();
        cfg.mean_rate = 500.0;
        let trace = SynthGenerator::new(cfg).generate();
        let mut exact = OlkenProfiler::new(1 << 24, 1.3, true);
        let mut approx = ShardsProfiler::new(0.1, 1 << 24, ShardsMode::Uniform, 11);
        for r in &trace {
            exact.record(r.obj, 1);
            approx.record(r.obj, 1);
        }
        // Evaluate over meaningful sizes (≥64 objects); the head of the
        // curve is sampling noise for any estimator.
        let err = exact
            .curve()
            .mean_abs_error(&approx.curve(), 64, 1 << 14);
        assert!(err < 0.05, "uniform-size error {err} too large");
    }

    #[test]
    fn consistent_sampling_is_per_object() {
        let p = ShardsProfiler::new(0.3, 1 << 30, ShardsMode::Sized, 7);
        for obj in 0..1000u64 {
            assert_eq!(p.is_sampled(obj), p.is_sampled(obj));
        }
        let frac = (0..100_000u64).filter(|&o| p.is_sampled(o)).count() as f64 / 1e5;
        assert!((frac - 0.3).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn uniform_assumption_is_systematically_wrong_on_sized_traffic() {
        // The Fig. 2 treatment arm: even at rate 1.0 (no sampling noise at
        // all) the uniform-size assumption misplaces the byte curve.
        let mut cfg = SynthConfig::tiny();
        cfg.mean_rate = 400.0;
        let trace = SynthGenerator::new(cfg).generate();
        let mut exact = OlkenProfiler::new(1 << 38, 1.3, false);
        let mut assumed = ShardsProfiler::new(1.0, 1 << 38, ShardsMode::UniformAssumed, 13);
        let mut sized = ShardsProfiler::new(1.0, 1 << 38, ShardsMode::Sized, 13);
        for r in &trace {
            exact.record(r.obj, r.size_bytes());
            assumed.record(r.obj, r.size_bytes());
            sized.record(r.obj, r.size_bytes());
        }
        let e = exact.curve();
        let hi = 1u64 << 34;
        let err_assumed = e.mean_abs_error(&assumed.curve(), 1 << 22, hi);
        let err_sized = e.mean_abs_error(&sized.curve(), 1 << 22, hi);
        // Byte-weighted extension at rate 1 is exact; uniform-assumption
        // is not.
        assert!(err_sized < 1e-9, "err_sized={err_sized}");
        assert!(
            err_assumed > 10.0 * (err_sized + 1e-4),
            "assumed={err_assumed} sized={err_sized}"
        );
        assert!(assumed.mean_object_size() > 64.0);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_rate() {
        let _ = ShardsProfiler::new(0.0, 1 << 20, ShardsMode::Sized, 1);
    }
}
