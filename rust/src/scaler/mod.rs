//! Epoch-end sizing policies: given what was observed during the epoch,
//! decide `I(k+1)` — the number of instances for the next billing epoch.
//!
//! * [`FixedSizer`] — the paper's baseline: a static cluster.
//! * [`TtlSizer`] — Algorithm 2: `I(k+1) = round(VC.size / S_p)`, with the
//!   virtual cache + stochastic-approximation controller doing the real
//!   work on the request path at O(1).
//! * [`MrcSizer`] — the previously proposed alternative ([35]): profile
//!   the epoch's requests into an exact MRC (O(log M) per request) and
//!   pick the cluster size minimizing predicted storage + miss cost.
//! * [`crate::tenant::TenantTtlSizer`] — the multi-tenant generalization:
//!   one TTL controller per tenant, arbitrated into one shared cluster.
//!
//! The PJRT-backed analytic sizer lives in [`crate::runtime`] and
//! implements the same [`EpochSizer`] trait.

use crate::config::{Config, ControllerConfig, CostConfig, ScalerConfig};
use crate::metrics::Ewma;
use crate::mrc::{MrcProfiler, OlkenProfiler};
use crate::tenant::{
    AdmitOutcome, Lifecycle, TenantAllocation, TenantDemand, TenantEnforcement, TenantSpec,
};
use crate::trace::Request;
use crate::vcache::VirtualCache;
use crate::{TenantId, TimeUs};

/// Per-request work a policy performs, as abstract *work units* — the
/// Fig. 1 CPU-overhead proxy. The basic router (hash + route) costs 1; the
/// TTL policy adds a small constant; the MRC policy adds O(log M).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PolicyWork {
    pub units: u32,
    /// Whether the policy's shadow structure registered a (virtual) hit.
    pub shadow_hit: Option<bool>,
    /// Admission verdict for the balancer: on a physical miss, may the
    /// fetched object be inserted? Enforcing policies
    /// ([`crate::tenant::TenantTtlSizer`]) refuse inserts that would
    /// overrun the tenant's occupancy cap; every other policy always
    /// admits.
    pub admit: bool,
}

impl Default for PolicyWork {
    fn default() -> Self {
        PolicyWork { units: 0, shadow_hit: None, admit: true }
    }
}

/// An epoch-granularity cluster sizing policy.
pub trait EpochSizer {
    /// Called on every request, *before* routing. Must be O(1) for
    /// production-grade policies (the paper's complexity argument, §2.4).
    /// The full request is passed so tenant-aware policies can dispatch
    /// shadow work to the right per-tenant controller.
    fn on_request(&mut self, req: &Request) -> PolicyWork;

    /// Physical-occupancy feedback: the balancer reports the requesting
    /// tenant's current resident bytes (the cluster ledger row)
    /// immediately before each `on_request`, so resident-byte-binding
    /// policies ([`crate::tenant::TenantTtlSizer`] under
    /// `scaler.enforce_grants`) can compare occupancy against the cap in
    /// O(1). Default: ignored.
    fn note_physical(&mut self, _tenant: TenantId, _resident_bytes: u64) {}

    /// Called after the request was physically served, with the physical
    /// outcome and the [`PolicyWork`] this request's `on_request`
    /// returned (admission verdict + shadow outcome). SLO-aware policies
    /// use this to measure per-tenant physical miss ratios and charge
    /// admission budgets; the default is a no-op.
    fn on_served(&mut self, _req: &Request, _hit: bool, _work: &PolicyWork) {}

    /// Called at each epoch boundary; returns the target instance count.
    fn decide(&mut self, now: TimeUs) -> u32;

    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// Current TTL (seconds) if the policy maintains one (Fig. 5 left).
    fn ttl_secs(&self) -> Option<f64> {
        None
    }

    /// The timer governing `tenant`'s inserts, for TTL-pricing admission
    /// filters ([`crate::admission::KeepCostFilter`]). Must be O(1) —
    /// it runs on the request path. Default: the policy-wide timer;
    /// per-tenant-controller policies override with the tenant's own.
    fn tenant_ttl_secs(&self, _tenant: TenantId) -> Option<f64> {
        self.ttl_secs()
    }

    /// Current virtual/profiled size in bytes (Fig. 5 right).
    fn shadow_size(&self) -> Option<u64> {
        None
    }

    /// Per-tenant timers, for policies that run one controller per tenant
    /// (fig10). `None` for tenant-oblivious policies.
    fn tenant_ttls(&self) -> Option<Vec<(TenantId, f64)>> {
        None
    }

    /// Per-tenant enforcement state (grants, caps, clamps, SLO tracking),
    /// for policies that arbitrate tenants. `None` for tenant-oblivious
    /// policies.
    fn enforcement(&self) -> Option<Vec<TenantEnforcement>> {
        None
    }

    // --- online tenant lifecycle (policies that arbitrate tenants) ---

    /// Admit (or update) a tenant mid-run. Tenant-oblivious policies
    /// reject the request with an error.
    fn admit_tenant(&mut self, spec: TenantSpec, _now: TimeUs) -> crate::Result<AdmitOutcome> {
        anyhow::bail!(
            "policy {} does not arbitrate tenants (cannot admit tenant {})",
            self.name(),
            spec.id
        )
    }

    /// Begin retiring a tenant mid-run: its controller leaves the bank
    /// and the balancer drains its residents at the following epoch
    /// boundaries. Tenant-oblivious policies reject the request.
    fn retire_tenant(&mut self, tenant: TenantId, _now: TimeUs) -> crate::Result<()> {
        anyhow::bail!(
            "policy {} does not arbitrate tenants (cannot retire tenant {tenant})",
            self.name()
        )
    }

    /// Tenants currently draining toward retirement (the balancer sheds
    /// each of these to zero resident bytes at every epoch boundary).
    fn draining(&self) -> Vec<TenantId> {
        Vec::new()
    }

    /// The balancer reports that a draining tenant's residents reached
    /// zero at the boundary at `now`. Default: ignored.
    fn note_drained(&mut self, _tenant: TenantId, _now: TimeUs) {}

    /// Drain the queue of tenants whose retirement completed since the
    /// last call (the engine reconciles their bills from this).
    fn take_retired(&mut self) -> Vec<TenantId> {
        Vec::new()
    }

    /// Per-tenant lifecycle records, for policies that track tenant
    /// lifecycles. `None` for tenant-oblivious policies.
    fn lifecycle(&self) -> Option<Vec<(TenantId, Lifecycle)>> {
        None
    }

    /// The spec currently registered for `tenant` (`None` for
    /// tenant-oblivious policies or unknown tenants). Serve's `ADMIT`
    /// seeds partial updates from this so unspecified keys keep their
    /// values.
    fn tenant_spec(&self, _tenant: TenantId) -> Option<TenantSpec> {
        None
    }

    /// Attach telemetry handles ([`crate::telemetry::TelemetryRegistry`]).
    /// Policies that instrument their epoch pipeline (e.g.
    /// [`crate::tenant::TenantTtlSizer`]'s arbiter-sort and grant-apply
    /// timers) resolve their handles here, once; the hot path then
    /// records through the pre-resolved handles at O(1). Default: no-op.
    fn attach_telemetry(&mut self, _registry: &mut crate::telemetry::TelemetryRegistry) {}

    // --- sharded execution (engine::ShardedEngine's epoch barrier) ---

    /// Shard-side half of [`Self::decide`]: run the epoch-boundary shadow
    /// maintenance (expiry, SLO close-out, drain bookkeeping) and report
    /// this shard's per-tenant demand rows *instead of* sizing locally —
    /// the front merges every shard's rows and runs the one arbiter
    /// decision. `None` (the default) declares the policy unshardable
    /// (no demand-row representation of its decision); the engine then
    /// falls back to the single-threaded path.
    fn shard_demands(&mut self, _now: TimeUs) -> Option<Vec<TenantDemand>> {
        None
    }

    /// Shard-side application of the front's decision: this shard's
    /// slice of the merged grants (caps, TTL clamps). Policies whose
    /// [`Self::decide`] carries no grant state need nothing here.
    fn shard_apply_grants(&mut self, _allocs: &[TenantAllocation]) {}
}

/// Static baseline.
pub struct FixedSizer {
    n: u32,
}

impl FixedSizer {
    pub fn new(n: u32) -> Self {
        FixedSizer { n: n.max(1) }
    }
}

impl EpochSizer for FixedSizer {
    fn on_request(&mut self, _req: &Request) -> PolicyWork {
        PolicyWork { units: 1, shadow_hit: None, admit: true }
    }

    fn decide(&mut self, _now: TimeUs) -> u32 {
        self.n
    }

    fn name(&self) -> &'static str {
        "fixed"
    }

    fn shard_demands(&mut self, _now: TimeUs) -> Option<Vec<TenantDemand>> {
        // Static target: nothing to merge, the front pins the size.
        Some(Vec::new())
    }
}

/// Algorithm 2 — the paper's TTL-based scaling.
pub struct TtlSizer {
    vc: VirtualCache,
    instance_bytes: u64,
    min_instances: u32,
    max_instances: u32,
}

impl TtlSizer {
    pub fn new(
        ctrl: &ControllerConfig,
        cost: CostConfig,
        instance_bytes: u64,
        scaler: &ScalerConfig,
    ) -> Self {
        TtlSizer {
            vc: VirtualCache::new(ctrl, cost),
            instance_bytes: instance_bytes.max(1),
            min_instances: scaler.min_instances.max(1),
            max_instances: scaler.max_instances.max(1),
        }
    }

    pub fn from_config(cfg: &Config) -> Self {
        Self::new(
            &cfg.controller,
            cfg.cost.clone(),
            cfg.cost.instance.ram_bytes,
            &cfg.scaler,
        )
    }

    pub fn vcache(&self) -> &VirtualCache {
        &self.vc
    }
}

impl EpochSizer for TtlSizer {
    fn on_request(&mut self, req: &Request) -> PolicyWork {
        // Tenant-scoped like the cluster's routing key, so a mixed trace
        // replayed under the single-controller policy doesn't alias
        // colliding tenant-local ids in the shadow cache.
        let obj = crate::tenant::scoped_object(req.tenant, req.obj);
        let out = self.vc.on_request(req.ts, obj, req.size_bytes());
        // hash + route (1) + vcache list ops (≈2) — constant.
        PolicyWork { units: 3, shadow_hit: Some(out.hit), admit: true }
    }

    fn decide(&mut self, now: TimeUs) -> u32 {
        self.vc.expire(now);
        // Algorithm 2 line 8: ROUND(VC.size / S_p).
        let raw = (self.vc.vsize() as f64 / self.instance_bytes as f64).round() as u32;
        raw.clamp(self.min_instances, self.max_instances)
    }

    fn name(&self) -> &'static str {
        "ttl"
    }

    fn ttl_secs(&self) -> Option<f64> {
        Some(self.vc.ttl_secs())
    }

    fn shadow_size(&self) -> Option<u64> {
        Some(self.vc.vsize())
    }

    fn shard_demands(&mut self, now: TimeUs) -> Option<Vec<TenantDemand>> {
        // The same expiry `decide` would run, then the shard's virtual
        // size as a single pseudo-tenant row: the front's arbiter formula
        // (`round(Σ vsize / S_p)` clamped) is exactly Algorithm 2 line 8
        // applied to the merged shadow size.
        self.vc.expire(now);
        Some(vec![TenantDemand::new(0, self.vc.vsize(), 1.0)])
    }
}

/// MRC-driven sizing ([35] / §3): exact Olken profiling with per-epoch
/// decay, epoch-end cost minimization over candidate cluster sizes.
pub struct MrcSizer {
    profiler: OlkenProfiler,
    cost: CostConfig,
    instance_bytes: u64,
    min_instances: u32,
    max_instances: u32,
    decay: f64,
    /// Requests observed in the current epoch.
    epoch_requests: u64,
    /// Smoothed per-epoch request volume (for predicting next epoch).
    rate_ewma: Ewma,
    /// Smoothed mean request size (for the per-byte miss-cost mode).
    mean_size: Ewma,
    last_size_estimate: u64,
}

impl MrcSizer {
    pub fn new(cost: CostConfig, instance_bytes: u64, scaler: &ScalerConfig) -> Self {
        let max_bytes = instance_bytes.max(1) * scaler.max_instances.max(1) as u64 * 2;
        MrcSizer {
            profiler: OlkenProfiler::sized(max_bytes.max(1 << 20)),
            cost,
            instance_bytes: instance_bytes.max(1),
            min_instances: scaler.min_instances.max(1),
            max_instances: scaler.max_instances.max(1),
            decay: scaler.mrc_decay,
            epoch_requests: 0,
            rate_ewma: Ewma::new(0.3),
            mean_size: Ewma::new(0.05),
            last_size_estimate: 0,
        }
    }

    pub fn from_config(cfg: &Config) -> Self {
        Self::new(cfg.cost.clone(), cfg.cost.instance.ram_bytes, &cfg.scaler)
    }

    /// Predicted total cost for an `n`-instance epoch given the current
    /// curve and traffic estimate.
    fn predicted_cost(&self, n: u32, reqs: f64, mean_size: f64) -> f64 {
        let storage = n as f64
            * self.cost.instance.dollars_per_hour
            * (self.cost.epoch_us as f64 / crate::HOUR as f64);
        let mr = self.profiler.curve().miss_ratio_at(n as u64 * self.instance_bytes);
        let miss = mr * reqs * self.cost.miss_cost(mean_size as u64);
        storage + miss
    }
}

impl EpochSizer for MrcSizer {
    fn on_request(&mut self, req: &Request) -> PolicyWork {
        // The profiler works on the tenant-scoped id so cross-tenant key
        // collisions don't corrupt reuse distances on mixed traces.
        let obj = crate::tenant::scoped_object(req.tenant, req.obj);
        let dist = self.profiler.record(obj, req.size_bytes());
        self.epoch_requests += 1;
        self.mean_size.update(req.size_bytes() as f64);
        // 1 route unit + O(log M) tree units: charge log2(tracked).
        let log_m = (self.profiler.tracked().max(2) as f64).log2() as u32;
        PolicyWork { units: 1 + log_m, shadow_hit: dist.map(|_| true), admit: true }
    }

    fn decide(&mut self, _now: TimeUs) -> u32 {
        let reqs = self.rate_ewma.update(self.epoch_requests as f64);
        self.epoch_requests = 0;
        let mean_size = self.mean_size.get().unwrap_or(64.0 * 1024.0);
        let mut best_n = self.min_instances;
        let mut best_cost = f64::INFINITY;
        for n in self.min_instances..=self.max_instances {
            let c = self.predicted_cost(n, reqs, mean_size);
            if c < best_cost {
                best_cost = c;
                best_n = n;
            }
        }
        self.last_size_estimate = best_n as u64 * self.instance_bytes;
        self.profiler.decay(self.decay);
        best_n
    }

    fn name(&self) -> &'static str {
        "mrc"
    }

    fn shadow_size(&self) -> Option<u64> {
        Some(self.last_size_estimate)
    }
}

/// Build the configured sizer via the engine's uniform policy registry
/// ([`crate::engine::build_sizer`]). Every [`crate::config::PolicyKind`]
/// — `analytic` and `ideal_ttl` included — has a first-class entry, so
/// this can no longer panic. Note that for `ideal_ttl` the returned
/// sizer only carries §6.1 cost semantics when run under the engine's
/// vertical billing mode ([`crate::engine::run`] selects it from the
/// config); see [`crate::engine::build_sizer`]'s billing caveat.
pub fn make_sizer(cfg: &Config) -> Box<dyn EpochSizer> {
    crate::engine::build_sizer(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::{HOUR, SECOND};

    fn req(ts: u64, obj: u64, size: u64) -> Request {
        Request::new(ts, obj, size.min(u32::MAX as u64) as u32)
    }

    #[test]
    fn fixed_sizer_is_constant() {
        let mut s = FixedSizer::new(8);
        for i in 0..100 {
            s.on_request(&req(i, i, 100));
        }
        assert_eq!(s.decide(HOUR), 8);
        assert_eq!(s.decide(2 * HOUR), 8);
        assert_eq!(s.name(), "fixed");
        assert!(s.tenant_ttls().is_none());
    }

    #[test]
    fn ttl_sizer_rounds_vsize_to_instances() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 3600.0; // long TTL: everything sticks
        let mut s = TtlSizer::from_config(&cfg);
        let inst = cfg.cost.instance.ram_bytes;
        // Insert ~2.4 instances worth of distinct bytes.
        let obj_size = inst / 10;
        for i in 0..24u64 {
            s.on_request(&req(i * SECOND, i, obj_size));
        }
        let n = s.decide(30 * SECOND);
        assert_eq!(n, 2, "vsize={} inst={}", s.shadow_size().unwrap(), inst);
        assert!(s.ttl_secs().is_some());
    }

    #[test]
    fn ttl_sizer_respects_bounds() {
        let mut cfg = Config::default();
        cfg.scaler.min_instances = 2;
        cfg.scaler.max_instances = 4;
        cfg.controller.t_init_secs = 3600.0;
        let mut s = TtlSizer::from_config(&cfg);
        // Empty vcache → raw 0 → clamped to 2.
        assert_eq!(s.decide(0), 2);
        // Overfill → clamped to 4.
        let inst = cfg.cost.instance.ram_bytes;
        for i in 0..100u64 {
            s.on_request(&req(i, i, inst / 5));
        }
        assert_eq!(s.decide(SECOND * 200), 4);
    }

    #[test]
    fn mrc_sizer_grows_with_reusable_working_set() {
        let mut cfg = Config::default();
        cfg.scaler.max_instances = 16;
        // Shrink the instance (price scaled per byte like the paper's) so
        // the test's request volume makes misses economically meaningful.
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.cost.instance.dollars_per_hour = 0.017 * 1.0e6 / 555.0e6;
        let mut s = MrcSizer::from_config(&cfg);
        let inst = cfg.cost.instance.ram_bytes;
        // Working set ≈ 3 instances, re-accessed many times: misses are
        // expensive (many requests/epoch), so sizing up must win.
        let nobj = 300u64;
        let obj_size = 3 * inst / nobj;
        for round in 0..20u64 {
            for i in 0..nobj {
                s.on_request(&req(round * SECOND, i, obj_size));
            }
        }
        let n = s.decide(HOUR);
        assert!(n >= 3, "n={n}");
        assert_eq!(s.name(), "mrc");
    }

    #[test]
    fn mrc_sizer_shrinks_for_cold_traffic() {
        let mut cfg = Config::default();
        cfg.scaler.max_instances = 16;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.cost.instance.dollars_per_hour = 0.017 * 1.0e6 / 555.0e6;
        let mut s = MrcSizer::from_config(&cfg);
        // One-hit wonders only: no reuse, caching buys nothing → min size.
        for i in 0..20_000u64 {
            s.on_request(&req(i, i, 100_000));
        }
        assert_eq!(s.decide(HOUR), cfg.scaler.min_instances);
    }

    #[test]
    fn mrc_scopes_colliding_tenant_keys_apart() {
        // The same object id requested by two tenants must profile as two
        // distinct objects (no phantom reuse across tenants).
        let cfg = Config::default();
        let mut s = MrcSizer::from_config(&cfg);
        let a = s.on_request(&req(0, 42, 100).with_tenant(1));
        let b = s.on_request(&req(1, 42, 100).with_tenant(2));
        assert_eq!(a.shadow_hit, None, "first touch is cold");
        assert_eq!(b.shadow_hit, None, "other tenant's touch is still cold");
        let c = s.on_request(&req(2, 42, 100).with_tenant(1));
        assert_eq!(c.shadow_hit, Some(true), "same tenant re-touch reuses");
    }

    #[test]
    fn mrc_work_units_grow_logarithmically() {
        let cfg = Config::default();
        let mut s = MrcSizer::from_config(&cfg);
        let w_small = s.on_request(&req(0, 0, 100)).units;
        for i in 1..10_000u64 {
            s.on_request(&req(i, i, 100));
        }
        let w_large = s.on_request(&req(10_001, 10_001, 100)).units;
        assert!(
            w_large >= w_small + 8,
            "w_small={w_small} w_large={w_large}"
        );
        // …while the TTL sizer stays constant:
        let mut t = TtlSizer::from_config(&cfg);
        let a = t.on_request(&req(0, 0, 100)).units;
        for i in 1..10_000u64 {
            t.on_request(&req(i, i, 100));
        }
        let b = t.on_request(&req(10_001, 10_001, 100)).units;
        assert_eq!(a, b);
    }

    #[test]
    fn factory_builds_each_kind() {
        use crate::config::PolicyKind;
        // Every kind — including the two the pre-engine factory panicked
        // on — now builds through the one registry.
        for (kind, name) in [
            (PolicyKind::Fixed, "fixed"),
            (PolicyKind::Ttl, "ttl"),
            (PolicyKind::Mrc, "mrc"),
            (PolicyKind::TenantTtl, "tenant_ttl"),
            (PolicyKind::Analytic, "analytic"),
            (PolicyKind::IdealTtl, "ideal_ttl"),
        ] {
            let s = make_sizer(&Config::with_policy(kind));
            assert_eq!(s.name(), name);
        }
    }
}
