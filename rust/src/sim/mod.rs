//! The discrete-event testbed (§6.1 substitute, DESIGN.md §3): replays a
//! trace through the real balancer/cluster/policy data structures with
//! epoch billing, producing the series behind Figs. 5–9.
//!
//! Since the engine redesign this module is a thin facade: every entry
//! point drives [`crate::engine::Engine`], the same request path the TCP
//! server and the analytic runtime driver use — there is exactly one
//! epoch-closing loop in the codebase. `SimResult` is the engine's
//! [`crate::engine::RunReport`] under its historical name.

pub use crate::engine::{RunReport as SimResult, TenantSummary};

use crate::config::Config;
use crate::engine::{EngineBuilder, EnginePolicy, VerticalTtl};
use crate::scaler::EpochSizer;
use crate::trace::RequestSource;

/// Run the configured policy over a source. Every [`crate::config::PolicyKind`]
/// — `analytic` and `ideal_ttl` included — goes through the same engine
/// entry point (the pre-engine dispatch panicked on `analytic`).
pub fn run(cfg: &Config, source: &mut dyn RequestSource) -> SimResult {
    crate::engine::run(cfg, source)
}

/// Run a caller-constructed horizontal sizer over a source.
pub fn run_policy(
    cfg: &Config,
    source: &mut dyn RequestSource,
    sizer: Box<dyn EpochSizer>,
    initial_instances: u32,
) -> SimResult {
    let mut engine = EngineBuilder::new(cfg)
        .sizer(sizer)
        .initial_instances(initial_instances)
        .build();
    while let Some(req) = source.next_request() {
        engine.offer(&req);
    }
    engine.finish()
}

/// The *ideal* vertically scaled TTL cache (§6.1 "as a reference"): the
/// engine's vertical billing mode — occupancy billed continuously, no
/// instances, no spurious misses; virtual hits are real hits. Forced to
/// vertical regardless of `cfg.scaler.policy`.
pub fn run_ideal_ttl(cfg: &Config, source: &mut dyn RequestSource) -> SimResult {
    let mut engine = EngineBuilder::new(cfg)
        .policy(EnginePolicy::Vertical(VerticalTtl::from_config(cfg)))
        .build();
    while let Some(req) = source.next_request() {
        engine.offer(&req);
    }
    engine.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::trace::{SynthConfig, SynthGenerator, VecSource};
    use crate::{HOUR, MINUTE};

    fn tiny_cfg(policy: PolicyKind) -> Config {
        let mut cfg = Config::with_policy(policy);
        // Shrink instances so the tiny trace exercises multi-node clusters.
        cfg.cost.instance.ram_bytes = 20_000_000;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.fixed_instances = 4;
        cfg.scaler.max_instances = 32;
        cfg
    }

    fn tiny_trace() -> Vec<crate::trace::Request> {
        SynthGenerator::new(SynthConfig::tiny()).generate()
    }

    #[test]
    fn fixed_run_bills_constant_instances() {
        let cfg = tiny_cfg(PolicyKind::Fixed);
        let trace = tiny_trace();
        let n_epochs_expected =
            (trace.last().unwrap().ts / cfg.cost.epoch_us + 1) as usize;
        let mut src = VecSource::new(trace);
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "fixed");
        assert!(res.requests > 1000);
        assert!(res.instances_series.len() >= n_epochs_expected);
        // Every epoch billed 4 instances.
        for &(_, v) in res.instances_series.samples() {
            assert_eq!(v, 4.0);
        }
        assert!(res.total_cost > 0.0);
        assert!((res.total_cost - (res.storage_cost + res.miss_cost)).abs() < 1e-9);
    }

    #[test]
    fn ttl_run_scales_and_tracks_series() {
        let cfg = tiny_cfg(PolicyKind::Ttl);
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "ttl");
        assert!(!res.ttl_series.is_empty(), "ttl series empty");
        assert!(!res.shadow_series.is_empty());
        // The instance count must not be constant for a diurnal trace with
        // an adapting TTL (the whole point of the paper).
        let vals: Vec<f64> = res
            .instances_series
            .samples()
            .iter()
            .map(|&(_, v)| v)
            .collect();
        let distinct: std::collections::HashSet<u64> =
            vals.iter().map(|v| *v as u64).collect();
        assert!(distinct.len() >= 1); // may settle quickly on tiny traces
    }

    #[test]
    fn mrc_run_completes_with_log_work() {
        let cfg = tiny_cfg(PolicyKind::Mrc);
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "mrc");
        assert!(res.work_units > res.requests, "MRC must cost >1/req");
    }

    #[test]
    fn analytic_run_uses_the_same_entry_point() {
        // The pre-engine dispatch panicked here; now it is a policy like
        // any other.
        let mut cfg = tiny_cfg(PolicyKind::Analytic);
        cfg.cost.instance.ram_bytes = 2_000_000;
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "analytic");
        assert!(res.requests > 1000);
        assert!(res.total_cost > 0.0);
    }

    #[test]
    fn ideal_ttl_bills_instantaneous_occupancy() {
        let mut cfg = tiny_cfg(PolicyKind::IdealTtl);
        cfg.controller.t_init_secs = 600.0;
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "ideal_ttl");
        assert!(res.storage_cost > 0.0, "no storage accrued");
        assert_eq!(res.spurious_misses, 0);
        assert!(res.miss_ratio() > 0.0 && res.miss_ratio() < 1.0);
    }

    #[test]
    fn tenant_ttl_run_reports_per_tenant_summaries() {
        use crate::tenant::TenantSpec;
        use crate::trace::TenantMux;
        let mut cfg = tiny_cfg(PolicyKind::TenantTtl);
        cfg.tenants = vec![
            TenantSpec::new(0, "hot").with_multiplier(2.0),
            TenantSpec::new(1, "cold").with_multiplier(0.5),
        ];
        let mut mux = TenantMux::new();
        let mut s0 = SynthConfig::tiny();
        s0.mean_rate = 60.0;
        s0.seed = 1;
        let mut s1 = SynthConfig::tiny();
        s1.mean_rate = 40.0;
        s1.seed = 2;
        mux.add(0, Box::new(SynthGenerator::new(s0)));
        mux.add(1, Box::new(SynthGenerator::new(s1)));
        let mut src = VecSource::new(mux.generate());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "tenant_ttl");
        assert_eq!(res.tenants.len(), 2, "{:?}", res.tenants);
        for t in &res.tenants {
            assert!(t.requests > 100, "{t:?}");
            assert!(t.ttl_secs.is_some(), "{t:?}");
            assert!(t.miss_dollars > 0.0, "{t:?}");
        }
        let total_reqs: u64 = res.tenants.iter().map(|t| t.requests).sum();
        assert_eq!(total_reqs, res.requests);
        // Weighted billing: per-tenant dollars sum to the aggregate bill.
        let sum: f64 = res.tenants.iter().map(|t| t.miss_dollars).sum();
        assert!((sum - res.miss_cost).abs() < 1e-9);
    }

    #[test]
    fn epoch_billing_counts_all_epochs() {
        // A trace spanning 3 epochs must produce ≥ 3 epoch closures even
        // with long request gaps.
        let cfg = {
            let mut c = tiny_cfg(PolicyKind::Fixed);
            c.cost.epoch_us = HOUR;
            c
        };
        let reqs = vec![
            crate::trace::Request::new(0, 1, 100),
            crate::trace::Request::new(2 * HOUR + MINUTE, 2, 100),
            crate::trace::Request::new(2 * HOUR + 2 * MINUTE, 1, 100),
        ];
        let mut src = VecSource::new(reqs);
        let res = run(&cfg, &mut src);
        assert!(res.storage_series.len() >= 3, "epochs={}", res.storage_series.len());
    }
}
