//! The discrete-event testbed (§6.1 substitute, DESIGN.md §3): replays a
//! trace through the real balancer/cluster/policy data structures with
//! epoch billing, producing the series behind Figs. 5–9.

use crate::balancer::Balancer;
use crate::cluster::BalanceTracker;
use crate::config::{Config, CostConfig, PolicyKind};
use crate::cost::{CostTracker, EpochCosts};
use crate::metrics::TimeSeries;
use crate::scaler::{make_sizer, EpochSizer};
use crate::trace::RequestSource;
use crate::vcache::VirtualCache;
use crate::{TenantId, TimeUs};

/// Per-tenant slice of a run: who asked for what, who missed, what it
/// cost, and where that tenant's timer converged.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSummary {
    pub tenant: TenantId,
    pub requests: u64,
    pub misses: u64,
    /// Weighted miss dollars attributed to this tenant.
    pub miss_dollars: f64,
    /// Final per-tenant TTL, when the policy ran one controller per
    /// tenant.
    pub ttl_secs: Option<f64>,
}

/// Result of one policy run over a trace.
#[derive(Debug)]
pub struct SimResult {
    pub policy: String,
    pub requests: u64,
    pub misses: u64,
    pub spurious_misses: u64,
    pub work_units: u64,
    pub epochs: Vec<EpochCosts>,
    /// Cumulative dollars.
    pub storage_series: TimeSeries,
    pub miss_series: TimeSeries,
    pub total_series: TimeSeries,
    /// Instances active per epoch.
    pub instances_series: TimeSeries,
    /// TTL (s) sampled periodically (TTL-family policies).
    pub ttl_series: TimeSeries,
    /// Virtual/shadow size (bytes) sampled periodically.
    pub shadow_series: TimeSeries,
    /// Fig. 9 balance tracker.
    pub balance: BalanceTracker,
    /// Per-tenant breakdown (one row per tenant that sent traffic).
    pub tenants: Vec<TenantSummary>,
    pub total_cost: f64,
    pub storage_cost: f64,
    pub miss_cost: f64,
}

impl SimResult {
    pub fn miss_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.misses as f64 / self.requests as f64
        }
    }

    /// One summary row for tables: name, requests, miss%, storage, miss$,
    /// total$.
    pub fn summary_row(&self) -> Vec<String> {
        vec![
            self.policy.clone(),
            self.requests.to_string(),
            format!("{:.4}", self.miss_ratio()),
            format!("{:.4}", self.storage_cost),
            format!("{:.4}", self.miss_cost),
            format!("{:.4}", self.total_cost),
        ]
    }
}

/// How often the TTL / shadow-size series are sampled.
const SAMPLE_EVERY: u64 = 4096;

/// Run a policy over a trace source.
pub fn run_policy(
    cfg: &Config,
    source: &mut dyn RequestSource,
    sizer: Box<dyn EpochSizer>,
    initial_instances: u32,
) -> SimResult {
    let name = sizer.name().to_string();
    let mut balancer = Balancer::from_config(cfg, sizer, initial_instances);
    let mut costs = CostTracker::new(cfg.cost.clone());
    for spec in &cfg.tenants {
        costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
    }
    let mut balance = BalanceTracker::new();
    let mut ttl_series = TimeSeries::new(format!("{name}_ttl_secs"));
    let mut shadow_series = TimeSeries::new(format!("{name}_shadow_bytes"));
    let epoch_us = cfg.cost.epoch_us.max(1);

    let mut epoch_end: TimeUs = epoch_us;
    let mut active_instances = balancer.cluster.len() as u32;
    let mut processed: u64 = 0;
    let mut last_ts: TimeUs = 0;

    while let Some(req) = source.next_request() {
        // Close any epochs that elapsed before this request.
        while req.ts >= epoch_end {
            balance.record(epoch_end, &balancer.cluster.balance_snapshot());
            costs.end_epoch(epoch_end, active_instances);
            balancer.cluster.reset_epoch_stats();
            active_instances = balancer.end_epoch(epoch_end);
            epoch_end += epoch_us;
        }
        balancer.handle(&req, &mut costs);
        processed += 1;
        last_ts = req.ts;
        if processed % SAMPLE_EVERY == 0 {
            if let Some(t) = balancer.ttl_secs() {
                ttl_series.push(req.ts, t);
            }
            if let Some(s) = balancer.shadow_size() {
                shadow_series.push(req.ts, s as f64);
            }
        }
    }
    // Bill the final (partial) epoch at full price (§2.3).
    balance.record(epoch_end, &balancer.cluster.balance_snapshot());
    costs.end_epoch(epoch_end.max(last_ts), active_instances);

    // Per-tenant breakdown: requests/misses from the balancer, weighted
    // dollars from the tracker, final timers from the policy (if any).
    let ttls = balancer.tenant_ttls();
    let mut tenants = Vec::new();
    for (i, hm) in balancer.tenant_stats().iter().enumerate() {
        if hm.total() == 0 {
            continue;
        }
        let t = i as TenantId;
        let ledger = costs.tenant_ledger(t);
        let ttl_secs = ttls
            .as_ref()
            .and_then(|v| v.iter().find(|(id, _)| *id == t).map(|&(_, x)| x));
        tenants.push(TenantSummary {
            tenant: t,
            requests: hm.total(),
            misses: hm.misses,
            miss_dollars: ledger.miss_dollars,
            ttl_secs,
        });
    }

    SimResult {
        policy: name,
        requests: balancer.requests,
        misses: balancer.misses,
        spurious_misses: balancer.spurious_misses,
        work_units: balancer.work_units,
        epochs: Vec::new(),
        storage_series: costs.storage_series.clone(),
        miss_series: costs.miss_series.clone(),
        total_series: costs.total_series.clone(),
        instances_series: costs.instances_series.clone(),
        ttl_series,
        shadow_series,
        balance,
        tenants,
        total_cost: costs.total(),
        storage_cost: costs.storage_total(),
        miss_cost: costs.miss_total(),
    }
}

/// Run the configured policy (Fixed/Ttl/Mrc) over a source.
pub fn run(cfg: &Config, source: &mut dyn RequestSource) -> SimResult {
    match cfg.scaler.policy {
        PolicyKind::IdealTtl => run_ideal_ttl(cfg, source),
        PolicyKind::Analytic => panic!("analytic policy: use runtime::run_analytic"),
        _ => {
            let sizer = make_sizer(cfg);
            let initial = match cfg.scaler.policy {
                PolicyKind::Fixed => cfg.scaler.fixed_instances,
                _ => cfg.scaler.min_instances.max(1),
            };
            run_policy(cfg, source, sizer, initial)
        }
    }
}

/// The *ideal* vertically scaled TTL cache (§6.1 "as a reference"): a pure
/// TTL cache billed on instantaneous occupancy — no instances, no epochs'
/// granularity loss, no spurious misses. Virtual hits are real hits.
pub fn run_ideal_ttl(cfg: &Config, source: &mut dyn RequestSource) -> SimResult {
    let cost_cfg: CostConfig = cfg.cost.clone();
    let mut vc = VirtualCache::new(&cfg.controller, cost_cfg.clone());
    let mut costs = CostTracker::new(cost_cfg.clone());
    for spec in &cfg.tenants {
        costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
    }
    let mut ttl_series = TimeSeries::new("ideal_ttl_ttl_secs");
    let mut shadow_series = TimeSeries::new("ideal_ttl_vsize_bytes");
    let per_byte_sec = cost_cfg.storage_cost_per_byte_sec();
    let epoch_us = cost_cfg.epoch_us.max(1);

    let mut epoch_end: TimeUs = epoch_us;
    let mut last_ts: TimeUs = 0;
    let mut requests = 0u64;
    let mut misses = 0u64;

    while let Some(req) = source.next_request() {
        // Storage accrues continuously on the current occupancy.
        let dt_secs = crate::us_to_secs(req.ts.saturating_sub(last_ts));
        costs.record_storage_dollars(vc.vsize() as f64 * per_byte_sec * dt_secs);
        last_ts = req.ts;
        while req.ts >= epoch_end {
            costs.end_epoch_vertical(epoch_end);
            epoch_end += epoch_us;
        }
        // The ideal cache stays per-object; scope keys so multi-tenant
        // traces don't alias across tenants.
        let obj = crate::tenant::scoped_object(req.tenant, req.obj);
        let out = vc.on_request(req.ts, obj, req.size_bytes());
        requests += 1;
        if !out.hit {
            misses += 1;
            costs.record_miss_for(req.tenant, req.size_bytes());
        }
        if requests % SAMPLE_EVERY == 0 {
            ttl_series.push(req.ts, out.ttl_secs);
            shadow_series.push(req.ts, out.vsize as f64);
        }
    }
    costs.end_epoch_vertical(epoch_end.max(last_ts));

    SimResult {
        policy: "ideal_ttl".into(),
        requests,
        misses,
        spurious_misses: 0,
        work_units: requests * 3,
        epochs: Vec::new(),
        storage_series: costs.storage_series.clone(),
        miss_series: costs.miss_series.clone(),
        total_series: costs.total_series.clone(),
        instances_series: costs.instances_series.clone(),
        ttl_series,
        shadow_series,
        balance: BalanceTracker::new(),
        tenants: Vec::new(),
        total_cost: costs.total(),
        storage_cost: costs.storage_total(),
        miss_cost: costs.miss_total(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::trace::{SynthConfig, SynthGenerator, VecSource};
    use crate::{HOUR, MINUTE};

    fn tiny_cfg(policy: PolicyKind) -> Config {
        let mut cfg = Config::with_policy(policy);
        // Shrink instances so the tiny trace exercises multi-node clusters.
        cfg.cost.instance.ram_bytes = 20_000_000;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.fixed_instances = 4;
        cfg.scaler.max_instances = 32;
        cfg
    }

    fn tiny_trace() -> Vec<crate::trace::Request> {
        SynthGenerator::new(SynthConfig::tiny()).generate()
    }

    #[test]
    fn fixed_run_bills_constant_instances() {
        let cfg = tiny_cfg(PolicyKind::Fixed);
        let trace = tiny_trace();
        let n_epochs_expected =
            (trace.last().unwrap().ts / cfg.cost.epoch_us + 1) as usize;
        let mut src = VecSource::new(trace);
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "fixed");
        assert!(res.requests > 1000);
        assert!(res.instances_series.len() >= n_epochs_expected);
        // Every epoch billed 4 instances.
        for &(_, v) in res.instances_series.samples() {
            assert_eq!(v, 4.0);
        }
        assert!(res.total_cost > 0.0);
        assert!((res.total_cost - (res.storage_cost + res.miss_cost)).abs() < 1e-9);
    }

    #[test]
    fn ttl_run_scales_and_tracks_series() {
        let cfg = tiny_cfg(PolicyKind::Ttl);
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "ttl");
        assert!(!res.ttl_series.is_empty(), "ttl series empty");
        assert!(!res.shadow_series.is_empty());
        // The instance count must not be constant for a diurnal trace with
        // an adapting TTL (the whole point of the paper).
        let vals: Vec<f64> = res
            .instances_series
            .samples()
            .iter()
            .map(|&(_, v)| v)
            .collect();
        let distinct: std::collections::HashSet<u64> =
            vals.iter().map(|v| *v as u64).collect();
        assert!(distinct.len() >= 1); // may settle quickly on tiny traces
    }

    #[test]
    fn mrc_run_completes_with_log_work() {
        let cfg = tiny_cfg(PolicyKind::Mrc);
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "mrc");
        assert!(res.work_units > res.requests, "MRC must cost >1/req");
    }

    #[test]
    fn ideal_ttl_bills_instantaneous_occupancy() {
        let mut cfg = tiny_cfg(PolicyKind::IdealTtl);
        cfg.controller.t_init_secs = 600.0;
        let mut src = VecSource::new(tiny_trace());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "ideal_ttl");
        assert!(res.storage_cost > 0.0, "no storage accrued");
        assert_eq!(res.spurious_misses, 0);
        assert!(res.miss_ratio() > 0.0 && res.miss_ratio() < 1.0);
    }

    #[test]
    fn tenant_ttl_run_reports_per_tenant_summaries() {
        use crate::tenant::TenantSpec;
        use crate::trace::TenantMux;
        let mut cfg = tiny_cfg(PolicyKind::TenantTtl);
        cfg.tenants = vec![
            TenantSpec::new(0, "hot").with_multiplier(2.0),
            TenantSpec::new(1, "cold").with_multiplier(0.5),
        ];
        let mut mux = TenantMux::new();
        let mut s0 = SynthConfig::tiny();
        s0.mean_rate = 60.0;
        s0.seed = 1;
        let mut s1 = SynthConfig::tiny();
        s1.mean_rate = 40.0;
        s1.seed = 2;
        mux.add(0, Box::new(SynthGenerator::new(s0)));
        mux.add(1, Box::new(SynthGenerator::new(s1)));
        let mut src = VecSource::new(mux.generate());
        let res = run(&cfg, &mut src);
        assert_eq!(res.policy, "tenant_ttl");
        assert_eq!(res.tenants.len(), 2, "{:?}", res.tenants);
        for t in &res.tenants {
            assert!(t.requests > 100, "{t:?}");
            assert!(t.ttl_secs.is_some(), "{t:?}");
            assert!(t.miss_dollars > 0.0, "{t:?}");
        }
        let total_reqs: u64 = res.tenants.iter().map(|t| t.requests).sum();
        assert_eq!(total_reqs, res.requests);
        // Weighted billing: per-tenant dollars sum to the aggregate bill.
        let sum: f64 = res.tenants.iter().map(|t| t.miss_dollars).sum();
        assert!((sum - res.miss_cost).abs() < 1e-9);
    }

    #[test]
    fn epoch_billing_counts_all_epochs() {
        // A trace spanning 3 epochs must produce ≥ 3 epoch closures even
        // with long request gaps.
        let cfg = {
            let mut c = tiny_cfg(PolicyKind::Fixed);
            c.cost.epoch_us = HOUR;
            c
        };
        let reqs = vec![
            crate::trace::Request::new(0, 1, 100),
            crate::trace::Request::new(2 * HOUR + MINUTE, 2, 100),
            crate::trace::Request::new(2 * HOUR + 2 * MINUTE, 1, 100),
        ];
        let mut src = VecSource::new(reqs);
        let res = run(&cfg, &mut src);
        assert!(res.storage_series.len() >= 3, "epochs={}", res.storage_series.len());
    }
}
