//! `elastictl serve` — a minimal mcrouter-like network front (§6.1: "We
//! have implemented the scheme described in Sect. 5.2 in a custom tool
//! similar to mcrouter").
//!
//! The full line-protocol reference (wire examples, error strings, the
//! operator workflow) lives in `docs/PROTOCOL.md` at the repository
//! root. Summary (one request per line, ASCII over TCP):
//!
//! ```text
//! GET <key> <size>\n          -> HIT | MISS | SPURIOUS\n
//! GET <tenant>/<key> <size>\n -> HIT | MISS | SPURIOUS\n   (tenant ∈ 0..65535)
//! STATS\n                     -> one-line JSON, global counters\n
//! STATS <tenant>\n            -> one-line JSON, that tenant's counters
//!                                (incl. `physical_bytes` + lifecycle
//!                                `state`); `ERR unknown tenant` for a
//!                                tenant the lifecycle layer never admitted
//!                                or already retired\n
//! SLO <tenant>\n              -> one-line JSON, that tenant's enforcement
//!                                state (grant, occupancy cap, TTL clamp,
//!                                measured vs target miss ratio, priority
//!                                boost, denied admissions); `ERR` when the
//!                                policy does not arbitrate tenants
//! PLACEMENT\n                 -> one-line JSON: the placement policy
//!                                (`[placement]` config section) plus every
//!                                active tenant's resident bytes and — for
//!                                hash_slot_pinned — its instance pins
//! ADMIT <tenant> [reserved_mb=X] [slo=Y] [multiplier=Z] [name=N]\n
//!                             -> OK <tenant> admitted|updated|readmitted\n
//! RETIRE <tenant>\n           -> OK <tenant> draining\n  (drains, then
//!                                reconciles the bill at epoch boundaries)
//! BILL <tenant>\n              -> one-line JSON: the retired tenant's
//!                                close-out reconciliation (lifetime misses,
//!                                miss/storage/total dollars, drain time);
//!                                `ERR` while the tenant is live or draining
//! EPOCH\n                     -> RESIZED <n>\n      (forces an epoch boundary)
//! WHY <tenant>\n              -> one-line JSON: the newest epoch decision
//!                                journal record for that tenant, with its
//!                                `cause` (shed | ttl_clamp | grant_squeeze
//!                                | filter_denied | null); `ERR` when
//!                                telemetry is disabled or no epoch has
//!                                closed yet
//! METRICS\n                   -> Prometheus text exposition of the live
//!                                telemetry registry, terminated by a
//!                                `# EOF` line; `ERR` when telemetry is
//!                                disabled
//! QUIT\n                      -> BYE\n (closes the connection)
//! ```
//!
//! `WHY` and `METRICS` require `[telemetry] enabled = true`: the engine
//! then journals one decision record per closed epoch (bounded by
//! `[telemetry] journal_capacity`) and threads pre-resolved registry
//! handles through the request path.
//!
//! `SLO` reads the live enforcement loop (`scaler.enforce_grants` plus
//! `[tenantN] reserved_mb` / `slo_miss_ratio` in the config): the epoch
//! decision that `EPOCH` forces is the moment grants become caps (binding
//! on physical resident bytes, with over-cap tenants shed at the
//! boundary) and TTL clamps, and `SLO` is how an operator watches them
//! bind. `PLACEMENT` is the physical view: who actually holds how many
//! bytes, and where (`shared` spreads every tenant over the slot map;
//! `hash_slot_pinned` confines each tenant to the listed pins;
//! `slab_partition` keeps Memshare-style reserved floors inside every
//! instance). `ADMIT`/`RETIRE` drive the online tenant lifecycle
//! ([`crate::tenant::Lifecycle`]): a retired tenant *drains* — its
//! controller leaves the bank at once, its residents are shed at the
//! following `EPOCH` boundaries, and once the ledger row reads zero its
//! bill is reconciled. Both answer `ERR` on policies that do not
//! arbitrate tenants.
//!
//! Tenant-prefix parsing is enabled only when the server is tenant-aware
//! (a `[tenantN]` roster in the config, or the `tenant_ttl` policy) — a
//! legacy single-tenant deployment keeps its pre-tenant key semantics
//! bit-for-bit, even for keys like `2023/07/28` whose first segment
//! happens to be numeric. On a tenant-aware server, a key prefix that
//! does not parse as a tenant id is still treated as a plain tenant-0
//! key. Malformed input answers an `ERR …` line and keeps the connection
//! open; only `QUIT` (or EOF) closes it.
//!
//! The server drives the same [`Engine`] the simulator uses — the request
//! path is identical; only the transport differs (requests arrive over
//! TCP instead of from a trace source). The engine runs in manual-epoch
//! mode: `EPOCH` is the only thing that closes a billing epoch and
//! applies the sizing decision, so the operator keeps full control of
//! the resize cadence. One OS thread per connection
//! (the build is offline-only, so no async runtime crate; the engine sits
//! behind a state-owner thread exactly as mcrouter's shared routing state
//! does).

use crate::config::Config;
use crate::engine::{Engine, EngineBuilder};
use crate::trace::Request;
use crate::{Result, TenantId};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

/// Shared server state.
pub struct ServerState {
    pub engine: Engine,
    /// Whether `GET <tenant>/<key>` prefixes are interpreted. Off for
    /// legacy single-tenant configs so numeric-prefixed keys keep their
    /// pre-tenant meaning.
    tenant_routing: bool,
    start: std::time::Instant,
}

impl ServerState {
    pub fn new(cfg: &Config) -> Self {
        let tenant_routing = !cfg.tenants.is_empty()
            || cfg.scaler.policy == crate::config::PolicyKind::TenantTtl;
        ServerState {
            // The bare request path: the server reports via STATS, not
            // via sampled figure series. Epochs stay manual — only the
            // operator's EPOCH command bills and resizes, exactly as
            // before the engine port; a GET after an idle hour must not
            // silently close the elapsed epochs.
            engine: EngineBuilder::new(cfg)
                .no_default_probes()
                .manual_epochs()
                .build(),
            tenant_routing,
            start: std::time::Instant::now(),
        }
    }

    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Handle one protocol line; returns the response line, or `None` to
    /// close the connection (only `QUIT` does).
    pub fn handle_line(&mut self, line: &str) -> Option<String> {
        let mut parts = line.split_ascii_whitespace();
        match parts.next() {
            Some("GET") => {
                let token = match parts.next() {
                    Some(t) => t,
                    None => return Some("ERR missing key".to_string()),
                };
                let size: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(1);
                let (tenant, key) = if self.tenant_routing {
                    split_tenant_key(token)
                } else {
                    (0, token)
                };
                // Hash arbitrary string keys onto the ObjectId space.
                let obj = key
                    .parse::<u64>()
                    .unwrap_or_else(|_| crate::mix64(fxhash_str(key)));
                let req = Request {
                    ts: self.now_us(),
                    obj,
                    size: size.min(u32::MAX as u64) as u32,
                    tenant,
                };
                let served = self.engine.offer(&req);
                Some(
                    if served.hit {
                        "HIT"
                    } else if served.spurious {
                        "SPURIOUS"
                    } else {
                        "MISS"
                    }
                    .to_string(),
                )
            }
            Some("STATS") => match parts.next() {
                None => {
                    // `miss_ratio` is `null` before the first request:
                    // "no traffic yet" is not a 100% miss ratio.
                    let hm = crate::metrics::HitMiss {
                        hits: self.engine.requests() - self.engine.misses(),
                        misses: self.engine.misses(),
                    };
                    Some(format!(
                        "{{\"requests\":{},\"misses\":{},\"spurious\":{},\"filter_denials\":{},\
                         \"miss_ratio\":{},\
                         \"instances\":{},\"miss_cost\":{:.9},\"ttl_secs\":{},\"tenants\":{}}}",
                        self.engine.requests(),
                        self.engine.misses(),
                        self.engine.spurious_misses(),
                        self.engine.filter_denials(),
                        hm.try_miss_ratio()
                            .map(|r| format!("{r:.6}"))
                            .unwrap_or_else(|| "null".into()),
                        self.engine.instances(),
                        self.engine.costs().miss_total(),
                        self.engine
                            .ttl_secs()
                            .map(|t| format!("{t:.3}"))
                            .unwrap_or_else(|| "null".into()),
                        self.engine.active_tenants(),
                    ))
                }
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.tenant_stats_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("SLO") => match parts.next() {
                None => Some("ERR SLO needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.slo_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("PLACEMENT") => Some(self.placement_line()),
            Some("ADMIT") => match parts.next() {
                None => Some("ERR ADMIT needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.admit_line(tenant, parts)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("RETIRE") => match parts.next() {
                None => Some("ERR RETIRE needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(match self.engine.retire_tenant(tenant) {
                        Ok(()) => format!("OK {tenant} draining"),
                        Err(e) => format!("ERR {e}"),
                    }),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("BILL") => match parts.next() {
                None => Some("ERR BILL needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.bill_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("EPOCH") => {
                let n = self.engine.force_epoch(self.now_us());
                Some(format!("RESIZED {n}"))
            }
            Some("WHY") => match parts.next() {
                None => Some("ERR WHY needs a tenant id".to_string()),
                Some(t) => match t.parse::<TenantId>() {
                    Ok(tenant) => Some(self.why_line(tenant)),
                    Err(_) => Some(format!("ERR bad tenant {t}")),
                },
            },
            Some("METRICS") => Some(self.metrics_block()),
            Some("QUIT") => None,
            Some(other) => Some(format!("ERR unknown command {other}")),
            None => Some("ERR empty".to_string()),
        }
    }

    /// `ADMIT <tenant> [reserved_mb=X] [slo=Y] [multiplier=Z] [name=N]`:
    /// parse the key=value spec fields and admit (or update / re-admit)
    /// the tenant through the engine. A known tenant's update seeds from
    /// its currently registered spec, so unspecified keys keep their
    /// values (a brand-new tenant starts from defaults).
    fn admit_line<'a>(
        &mut self,
        tenant: TenantId,
        args: impl Iterator<Item = &'a str>,
    ) -> String {
        let mut spec = self
            .engine
            .tenant_spec(tenant)
            .unwrap_or_else(|| crate::tenant::TenantSpec::new(tenant, format!("tenant{tenant}")));
        for arg in args {
            let Some((key, value)) = arg.split_once('=') else {
                return format!("ERR bad admit arg {arg} (want key=value)");
            };
            match key {
                "reserved_mb" => match value.parse::<f64>() {
                    Ok(mb) if mb >= 0.0 && mb.is_finite() => {
                        spec.reserved_bytes = (mb * 1024.0 * 1024.0) as u64;
                    }
                    _ => return format!("ERR bad reserved_mb {value}"),
                },
                "slo" => match value.parse::<f64>() {
                    Ok(r) if (0.0..=1.0).contains(&r) => spec.slo_miss_ratio = Some(r),
                    _ => return format!("ERR bad slo {value} (want a miss ratio in [0,1])"),
                },
                "multiplier" => match value.parse::<f64>() {
                    Ok(m) if m > 0.0 && m.is_finite() => spec.miss_cost_multiplier = m,
                    _ => return format!("ERR bad multiplier {value}"),
                },
                "name" => spec.name = value.to_string(),
                other => return format!("ERR unknown admit key {other}"),
            }
        }
        match self.engine.admit_tenant(spec) {
            Ok(outcome) => format!("OK {tenant} {}", outcome.as_str()),
            Err(e) => format!("ERR {e}"),
        }
    }

    /// One-line JSON for `SLO <tenant>`: the live enforcement state.
    fn slo_line(&self, tenant: TenantId) -> String {
        let Some(row) = self.engine.tenant_enforcement_of(tenant) else {
            return format!(
                "ERR no enforcement state (policy {} does not arbitrate tenants, \
                 or tenant {tenant} has never been seen)",
                self.engine.policy_name()
            );
        };
        let opt_u64 = |v: Option<u64>| {
            v.map(|x| x.to_string()).unwrap_or_else(|| "null".into())
        };
        let opt_f64 = |v: Option<f64>| {
            v.map(|x| format!("{x:.6}")).unwrap_or_else(|| "null".into())
        };
        format!(
            "{{\"tenant\":{},\"enforced\":{},\"decided\":{},\"demand_bytes\":{},\
             \"granted_bytes\":{},\"cap_bytes\":{},\"admitted_epoch_bytes\":{},\
             \"denied\":{},\"ttl_clamp_secs\":{},\"slo_miss_ratio\":{},\
             \"measured_miss_ratio\":{},\"in_violation\":{},\"boost\":{:.3}}}",
            row.tenant,
            row.enforced,
            row.decided,
            row.demand_bytes,
            row.granted_bytes,
            opt_u64(row.cap_bytes),
            row.admitted_epoch_bytes,
            row.denied_admissions,
            opt_f64(row.ttl_clamp_secs),
            opt_f64(row.slo_miss_ratio),
            opt_f64(row.measured_miss_ratio),
            row.in_violation(),
            row.boost,
        )
    }

    /// One-line JSON for `STATS <tenant>`. On a lifecycle-tracking policy
    /// an unknown or retired tenant answers the documented
    /// `ERR unknown tenant` instead of fabricating (or lazily admitting)
    /// a zero row; tenant-oblivious policies keep the legacy zeros so
    /// pre-lifecycle deployments see no behavior change.
    fn tenant_stats_line(&self, tenant: TenantId) -> String {
        let life = self.engine.tenant_lifecycle_of(tenant);
        let state = if self.engine.tenant_lifecycle().is_some() {
            match life {
                None => return format!("ERR unknown tenant {tenant}"),
                Some(l) if l.state() == crate::tenant::LifecycleState::Retired => {
                    return format!("ERR unknown tenant {tenant} (retired)");
                }
                Some(l) => format!(",\"state\":\"{}\"", l.state().as_str()),
            }
        } else {
            String::new()
        };
        let hm = self.engine.tenant_stats_of(tenant);
        let ledger = self.engine.costs().tenant_ledger(tenant);
        let ttl = self
            .engine
            .tenant_ttls()
            .and_then(|v| v.into_iter().find(|(id, _)| *id == tenant))
            .map(|(_, t)| format!("{t:.3}"))
            .unwrap_or_else(|| "null".into());
        format!(
            "{{\"tenant\":{},\"requests\":{},\"misses\":{},\"miss_cost\":{:.9},\
             \"physical_bytes\":{},\"ttl_secs\":{}{}}}",
            tenant,
            hm.total(),
            hm.misses,
            ledger.miss_dollars,
            self.engine.tenant_physical_bytes(tenant),
            ttl,
            state,
        )
    }

    /// One-line JSON for `BILL <tenant>`: the close-out reconciliation
    /// row snapshotted when the tenant finished draining (the most
    /// recent one, should the tenant have been re-admitted and retired
    /// again). Only a retired tenant has one — a live tenant's running
    /// bill is on `STATS <tenant>`.
    fn bill_line(&self, tenant: TenantId) -> String {
        let Some(rec) = self
            .engine
            .costs()
            .reconciliations()
            .iter()
            .rev()
            .find(|r| r.tenant == tenant)
        else {
            return format!(
                "ERR no reconciliation for tenant {tenant} (only a retired tenant \
                 has a closed bill; STATS {tenant} reads the running ledger)"
            );
        };
        format!(
            "{{\"tenant\":{},\"at\":{},\"misses\":{},\"miss_dollars\":{},\
             \"storage_dollars\":{},\"total_dollars\":{}}}",
            rec.tenant,
            rec.at,
            rec.misses,
            rec.miss_dollars,
            rec.storage_dollars,
            rec.total_dollars,
        )
    }

    /// One-line JSON for `WHY <tenant>`: the newest decision-journal
    /// record carrying a row for the tenant, with the causal decision
    /// (`shed` / `ttl_clamp` / `grant_squeeze` / `null`) named.
    fn why_line(&self, tenant: TenantId) -> String {
        let Some(journal) = self.engine.journal() else {
            return "ERR telemetry disabled (set [telemetry] enabled = true)".to_string();
        };
        let journal = journal.borrow();
        if journal.is_empty() {
            return "ERR no epoch decision yet (force one with EPOCH)".to_string();
        }
        let Some((rec, dec)) = journal.last_for(tenant) else {
            return format!("ERR no decision recorded for tenant {tenant}");
        };
        format!(
            "{{\"t\":{},\"epoch\":{},\"instances\":{},\"cause\":{},\"decision\":{}}}",
            rec.t,
            rec.epoch,
            rec.instances,
            match dec.cause() {
                Some(c) => format!("\"{c}\""),
                None => "null".into(),
            },
            dec.to_json(),
        )
    }

    /// Prometheus text block for `METRICS`, `# EOF`-terminated so the
    /// line-oriented client knows where the multi-line reply ends.
    fn metrics_block(&self) -> String {
        match self.engine.metrics_text() {
            Some(text) => format!("{text}# EOF"),
            None => "ERR telemetry disabled (set [telemetry] enabled = true)".to_string(),
        }
    }

    /// One-line JSON for `PLACEMENT`: the physical placement state.
    fn placement_line(&self) -> String {
        let Some(snap) = self.engine.placement_snapshot() else {
            return format!(
                "ERR no placement (policy {} runs no cluster)",
                self.engine.policy_name()
            );
        };
        let mut tenants = String::new();
        for (i, row) in snap.tenants.iter().enumerate() {
            if i > 0 {
                tenants.push(',');
            }
            let pins = match &row.pins {
                Some(p) => format!(
                    "[{}]",
                    p.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
                ),
                None => "null".to_string(),
            };
            tenants.push_str(&format!(
                "{{\"tenant\":{},\"physical_bytes\":{},\"pins\":{}}}",
                row.tenant, row.resident_bytes, pins
            ));
        }
        format!(
            "{{\"policy\":\"{}\",\"instances\":{},\"tenants\":[{}]}}",
            snap.policy.as_str(),
            self.engine.instances(),
            tenants
        )
    }
}

/// Split `5/alpha` into `(5, "alpha")`; tokens without a parseable tenant
/// prefix are plain tenant-0 keys.
pub(crate) fn split_tenant_key(token: &str) -> (TenantId, &str) {
    if let Some((prefix, rest)) = token.split_once('/') {
        if !rest.is_empty() {
            if let Ok(t) = prefix.parse::<TenantId>() {
                return (t, rest);
            }
        }
    }
    (0, token)
}

/// Deterministic string hash (FNV-1a) for non-numeric keys.
pub(crate) fn fxhash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Command channel to the state-owner thread: one protocol line plus a
/// reply channel. The engine's shadow structures hold non-`Send` PJRT
/// handles in the analytic configuration, so a single dedicated thread
/// owns all state (mcrouter's shared routing state, without locks on the
/// request path).
pub type StateTx = mpsc::Sender<(String, mpsc::Sender<Option<String>>)>;

/// Spawn the state-owner thread for `cfg`, returning its command channel.
pub fn spawn_state(cfg: Config) -> StateTx {
    let (tx, rx) = mpsc::channel::<(String, mpsc::Sender<Option<String>>)>();
    std::thread::spawn(move || {
        let mut st = ServerState::new(&cfg);
        for (line, reply) in rx {
            let _ = reply.send(st.handle_line(&line));
        }
    });
    tx
}

/// Run the server until the listener errors or the process is killed.
pub fn serve(cfg: Config, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "elastictl serve: listening on {} (policy={}, tenants={})",
        listener.local_addr()?,
        cfg.scaler.policy.as_str(),
        if cfg.tenants.is_empty() { 1 } else { cfg.tenants.len() },
    );
    let tx = spawn_state(cfg);
    for stream in listener.incoming() {
        let socket = stream?;
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(socket, tx);
        });
    }
    Ok(())
}

fn handle_conn(socket: TcpStream, tx: StateTx) -> Result<()> {
    let reader = BufReader::new(socket.try_clone()?);
    let mut w = socket;
    for line in reader.lines() {
        let line = line?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send((line, reply_tx))
            .map_err(|_| anyhow::anyhow!("state thread gone"))?;
        match reply_rx.recv()? {
            Some(text) => {
                w.write_all(text.as_bytes())?;
                w.write_all(b"\n")?;
            }
            None => {
                w.write_all(b"BYE\n")?;
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::tenant::TenantSpec;

    fn state(policy: PolicyKind) -> ServerState {
        ServerState::new(&Config::with_policy(policy))
    }

    #[test]
    fn get_protocol_hit_miss() {
        let mut st = state(PolicyKind::Ttl);
        assert_eq!(st.handle_line("GET alpha 1000").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET alpha 1000").unwrap(), "HIT");
        assert_eq!(st.handle_line("GET 42 5").unwrap(), "MISS");
    }

    #[test]
    fn stats_and_epoch() {
        let mut st = state(PolicyKind::Ttl);
        st.handle_line("GET k1 100");
        st.handle_line("GET k2 100");
        let stats = st.handle_line("STATS").unwrap();
        assert!(stats.contains("\"requests\":2"), "{stats}");
        assert!(stats.contains("\"misses\":2"));
        assert!(stats.contains("\"tenants\":1"), "{stats}");
        let resp = st.handle_line("EPOCH").unwrap();
        assert!(resp.starts_with("RESIZED "), "{resp}");
    }

    #[test]
    fn ideal_ttl_policy_is_served_not_rejected() {
        // The pre-engine server panicked in `make_sizer` for this policy;
        // the vertical billing mode serves it like any other.
        let mut st = state(PolicyKind::IdealTtl);
        assert_eq!(st.handle_line("GET k 100").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET k 100").unwrap(), "HIT");
        let stats = st.handle_line("STATS").unwrap();
        assert!(stats.contains("\"requests\":2"), "{stats}");
        assert!(st.handle_line("EPOCH").unwrap().starts_with("RESIZED"));
    }

    #[test]
    fn gets_never_close_epochs_implicitly() {
        // The resize/billing cadence belongs to the operator's EPOCH
        // command: request timestamps (wall clock) must not close epochs
        // behind their back, no matter how much time passed.
        let mut st = state(PolicyKind::Ttl);
        st.handle_line("GET k1 100");
        st.handle_line("GET k2 100");
        assert_eq!(st.engine.costs().epochs(), 0, "no implicit epoch closure");
        st.handle_line("EPOCH");
        assert_eq!(st.engine.costs().epochs(), 1, "EPOCH closes exactly one");
    }

    #[test]
    fn errors_and_quit() {
        let mut st = state(PolicyKind::Fixed);
        assert!(st.handle_line("FROB x").unwrap().starts_with("ERR"));
        assert!(st.handle_line("").unwrap().starts_with("ERR"));
        // A malformed GET must answer an error and keep the connection
        // open — only QUIT closes it.
        assert_eq!(st.handle_line("GET").unwrap(), "ERR missing key");
        assert_eq!(st.handle_line("GET k 10").unwrap(), "MISS");
        assert!(st.handle_line("STATS nope").unwrap().starts_with("ERR bad tenant"));
        assert!(st.handle_line("QUIT").is_none());
    }

    #[test]
    fn string_and_numeric_keys_are_distinct_objects() {
        let mut st = state(PolicyKind::Fixed);
        st.handle_line("GET alpha 10");
        assert_eq!(st.handle_line("GET beta 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET alpha 10").unwrap(), "HIT");
        assert_eq!(st.handle_line("GET beta 10").unwrap(), "HIT");
    }

    #[test]
    fn tenant_keys_route_to_distinct_objects() {
        // Tenant routing is on for the tenant policy (or a tenant roster).
        let mut st = state(PolicyKind::TenantTtl);
        assert_eq!(st.handle_line("GET 1/alpha 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET 2/alpha 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET 1/alpha 10").unwrap(), "HIT");
        assert_eq!(st.handle_line("GET 2/alpha 10").unwrap(), "HIT");
        // Bare key == tenant 0; a non-numeric prefix stays a plain key.
        assert_eq!(st.handle_line("GET alpha 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET a/b 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET a/b 10").unwrap(), "HIT");
    }

    #[test]
    fn legacy_servers_keep_numeric_slash_keys_verbatim() {
        // A single-tenant (legacy-config) server must not reinterpret
        // numeric-prefixed keys as tenant routes: `2023/07/28` is one
        // tenant-0 key, exactly as before the tenant protocol existed.
        let mut st = state(PolicyKind::Ttl);
        assert_eq!(st.handle_line("GET 2023/07/28 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET 2023/07/28 10").unwrap(), "HIT");
        let stats = st.handle_line("STATS 2023").unwrap();
        assert!(
            stats.contains("\"requests\":0"),
            "no phantom tenant may accrue traffic: {stats}"
        );
    }

    #[test]
    fn per_tenant_stats_line() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.tenants = vec![
            TenantSpec::new(1, "api").with_multiplier(4.0),
            TenantSpec::new(2, "batch").with_multiplier(0.5),
        ];
        let mut st = ServerState::new(&cfg);
        st.handle_line("GET 1/k1 100");
        st.handle_line("GET 1/k1 100");
        st.handle_line("GET 2/k9 100");
        let s1 = st.handle_line("STATS 1").unwrap();
        assert!(s1.contains("\"tenant\":1"), "{s1}");
        assert!(s1.contains("\"requests\":2"), "{s1}");
        assert!(s1.contains("\"misses\":1"), "{s1}");
        let s2 = st.handle_line("STATS 2").unwrap();
        assert!(s2.contains("\"requests\":1"), "{s2}");
        // Weighted billing: tenant 1's single miss costs 8× tenant 2's.
        let grab = |s: &str| -> f64 {
            let i = s.find("\"miss_cost\":").unwrap() + "\"miss_cost\":".len();
            s[i..].split(',').next().unwrap().parse().unwrap()
        };
        let (m1, m2) = (grab(&s1), grab(&s2));
        // Allow slack for the 9-decimal rendering of ~1e-7 dollar values.
        assert!(
            (m1 / m2 - 8.0).abs() < 0.2,
            "m1={m1} m2={m2} (want 4.0/0.5 = 8×)"
        );
        // Roster tenants carry their lifecycle state.
        assert!(s1.contains("\"state\":\"active\""), "{s1}");
        // A tenant the lifecycle layer never admitted is an error, not a
        // silently fabricated zero row.
        let s9 = st.handle_line("STATS 9").unwrap();
        assert_eq!(s9, "ERR unknown tenant 9");
    }

    #[test]
    fn admit_and_retire_commands_drive_the_lifecycle() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 4;
        cfg.tenants = vec![TenantSpec::new(0, "base")];
        let mut st = ServerState::new(&cfg);
        // Admit a new tenant with spec fields.
        assert_eq!(
            st.handle_line("ADMIT 5 reserved_mb=1 slo=0.2 multiplier=3.0 name=guest")
                .unwrap(),
            "OK 5 admitted"
        );
        let s = st.handle_line("STATS 5").unwrap();
        assert!(s.contains("\"state\":\"admitted\""), "{s}");
        // Its traffic activates it and lands on its own objects.
        assert_eq!(st.handle_line("GET 5/k1 100000").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET 5/k1 100000").unwrap(), "HIT");
        let s = st.handle_line("STATS 5").unwrap();
        assert!(s.contains("\"state\":\"active\""), "{s}");
        assert!(s.contains("\"physical_bytes\":100000"), "{s}");
        // A second ADMIT is a live spec update; unspecified keys keep
        // their values (the partial update must not reset the 3×
        // multiplier or the reservation to defaults).
        assert_eq!(st.handle_line("ADMIT 5 slo=0.5").unwrap(), "OK 5 updated");
        let spec = st.engine.tenant_spec(5).unwrap();
        assert_eq!(spec.miss_cost_multiplier, 3.0, "{spec:?}");
        assert_eq!(spec.reserved_bytes, 1024 * 1024, "{spec:?}");
        assert_eq!(spec.slo_miss_ratio, Some(0.5), "{spec:?}");
        assert_eq!(spec.name, "guest", "{spec:?}");
        // Retire: the tenant drains at the next EPOCH, then reads as
        // unknown (its bill reconciled).
        assert_eq!(st.handle_line("RETIRE 5").unwrap(), "OK 5 draining");
        let s = st.handle_line("STATS 5").unwrap();
        assert!(s.contains("\"state\":\"draining\""), "{s}");
        // While draining its misses are never cached again.
        assert_eq!(st.handle_line("GET 5/k2 100000").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET 5/k2 100000").unwrap(), "MISS");
        st.handle_line("EPOCH");
        assert_eq!(st.engine.tenant_physical_bytes(5), 0, "drain must reclaim");
        assert_eq!(
            st.handle_line("STATS 5").unwrap(),
            "ERR unknown tenant 5 (retired)"
        );
        assert_eq!(st.engine.costs().reconciliations().len(), 1);
        // Re-admission starts a fresh lifecycle.
        assert_eq!(st.handle_line("ADMIT 5").unwrap(), "OK 5 readmitted");
        let s = st.handle_line("STATS 5").unwrap();
        assert!(s.contains("\"state\":\"admitted\""), "{s}");
        // Error surface: bad ids, bad args, double retire, unknown
        // tenants, and tenant-oblivious policies.
        assert!(st.handle_line("ADMIT").unwrap().starts_with("ERR"));
        assert!(st.handle_line("ADMIT nope").unwrap().starts_with("ERR bad tenant"));
        assert!(st.handle_line("ADMIT 6 bogus").unwrap().starts_with("ERR bad admit arg"));
        assert!(st.handle_line("ADMIT 6 slo=7").unwrap().starts_with("ERR bad slo"));
        assert!(st.handle_line("ADMIT 6 frob=1").unwrap().starts_with("ERR unknown admit key"));
        assert!(st.handle_line("RETIRE").unwrap().starts_with("ERR"));
        assert!(st.handle_line("RETIRE nope").unwrap().starts_with("ERR bad tenant"));
        assert!(st.handle_line("RETIRE 99").unwrap().starts_with("ERR"));
        let mut plain = state(PolicyKind::Ttl);
        assert!(plain.handle_line("ADMIT 1").unwrap().starts_with("ERR"));
        assert!(plain.handle_line("RETIRE 1").unwrap().starts_with("ERR"));
    }

    #[test]
    fn bill_command_surfaces_the_reconciliation() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 4;
        cfg.tenants = vec![TenantSpec::new(0, "base")];
        let mut st = ServerState::new(&cfg);
        st.handle_line("ADMIT 5 multiplier=2.0");
        st.handle_line("GET 5/k1 1000");
        st.handle_line("GET 5/k2 1000");
        // Live tenants have no closed bill yet.
        assert!(
            st.handle_line("BILL 5").unwrap().starts_with("ERR no reconciliation"),
        );
        st.handle_line("RETIRE 5");
        st.handle_line("EPOCH");
        let bill = st.handle_line("BILL 5").unwrap();
        assert!(bill.starts_with('{'), "{bill}");
        assert!(bill.contains("\"tenant\":5"), "{bill}");
        assert!(bill.contains("\"misses\":2"), "{bill}");
        // The reply carries the exact ledger fold — the same numbers the
        // reconciliation row holds, rendered shortest-round-trip.
        let rec = st.engine.costs().reconciliations()[0];
        assert!(bill.contains(&format!("\"miss_dollars\":{}", rec.miss_dollars)), "{bill}");
        assert!(bill.contains(&format!("\"total_dollars\":{}", rec.total_dollars)), "{bill}");
        // Error surface: missing/bad ids and never-seen tenants.
        assert_eq!(st.handle_line("BILL").unwrap(), "ERR BILL needs a tenant id");
        assert!(st.handle_line("BILL nope").unwrap().starts_with("ERR bad tenant"));
        assert!(st.handle_line("BILL 42").unwrap().starts_with("ERR no reconciliation"));
    }

    #[test]
    fn slo_command_reports_enforcement_state() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 1;
        cfg.scaler.enforce_grants = true;
        cfg.tenants = vec![
            TenantSpec::new(1, "gold")
                .with_multiplier(10.0)
                .with_slo_miss_ratio(0.2),
            TenantSpec::new(2, "flood").with_multiplier(0.1),
        ];
        let mut st = ServerState::new(&cfg);
        // Pre-decision: state exists but nothing is capped yet.
        let s = st.handle_line("SLO 1").unwrap();
        assert!(s.contains("\"enforced\":true"), "{s}");
        assert!(s.contains("\"decided\":false"), "{s}");
        assert!(s.contains("\"cap_bytes\":null"), "{s}");
        assert!(s.contains("\"slo_miss_ratio\":0.200000"), "{s}");
        // Oversubscribe the 1 MB cluster, then force the epoch decision.
        for i in 0..30 {
            st.handle_line(&format!("GET 2/obj{i} 100000"));
        }
        st.handle_line("GET 1/k 100000");
        st.handle_line("EPOCH");
        let s = st.handle_line("SLO 2").unwrap();
        assert!(s.contains("\"decided\":true"), "{s}");
        assert!(!s.contains("\"cap_bytes\":null"), "squeezed tenant must be capped: {s}");
        assert!(!s.contains("\"ttl_clamp_secs\":null"), "and clamped: {s}");
        // The gold tenant's all-miss warmup epoch reads as a violation.
        let s = st.handle_line("SLO 1").unwrap();
        assert!(s.contains("\"measured_miss_ratio\":1.000000"), "{s}");
        assert!(s.contains("\"in_violation\":true"), "{s}");
        // Errors: bad ids, and policies with no tenant arbitration.
        assert!(st.handle_line("SLO").unwrap().starts_with("ERR"));
        assert!(st.handle_line("SLO nope").unwrap().starts_with("ERR"));
        let mut plain = state(PolicyKind::Ttl);
        assert!(plain.handle_line("SLO 0").unwrap().starts_with("ERR"));
    }

    #[test]
    fn placement_command_reports_physical_state() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.cluster.placement = crate::placement::PlacementKind::HashSlotPinned;
        cfg.tenants = vec![
            TenantSpec::new(1, "api").with_multiplier(2.0),
            TenantSpec::new(2, "batch"),
        ];
        let mut st = ServerState::new(&cfg);
        st.handle_line("GET 1/k1 1000");
        let p = st.handle_line("PLACEMENT").unwrap();
        assert!(p.contains("\"policy\":\"hash_slot_pinned\""), "{p}");
        assert!(p.contains("\"tenant\":1"), "{p}");
        assert!(p.contains("\"physical_bytes\":1000"), "{p}");
        assert!(p.contains("\"pins\":null"), "no pins before the first epoch: {p}");
        // The epoch decision turns grants into pins.
        st.handle_line("EPOCH");
        let p = st.handle_line("PLACEMENT").unwrap();
        assert!(p.contains("\"pins\":["), "pins after the epoch decision: {p}");
        // STATS <tenant> carries the same ledger row.
        let s = st.handle_line("STATS 1").unwrap();
        assert!(s.contains("\"physical_bytes\":1000"), "{s}");
        let s = st.handle_line("STATS 2").unwrap();
        assert!(s.contains("\"physical_bytes\":0"), "{s}");
        // The vertical mode runs no cluster.
        let mut v = state(PolicyKind::IdealTtl);
        assert!(v.handle_line("PLACEMENT").unwrap().starts_with("ERR"));
    }

    #[test]
    fn stats_miss_ratio_is_null_before_traffic() {
        let mut st = state(PolicyKind::Ttl);
        let stats = st.handle_line("STATS").unwrap();
        assert!(stats.contains("\"miss_ratio\":null"), "{stats}");
        st.handle_line("GET k 100");
        let stats = st.handle_line("STATS").unwrap();
        assert!(stats.contains("\"miss_ratio\":1.000000"), "{stats}");
    }

    #[test]
    fn why_and_metrics_commands() {
        // Telemetry off (the default): both commands answer ERR.
        let mut plain = state(PolicyKind::TenantTtl);
        assert!(
            plain.handle_line("WHY 1").unwrap().starts_with("ERR telemetry disabled"),
        );
        assert!(
            plain.handle_line("METRICS").unwrap().starts_with("ERR telemetry disabled"),
        );

        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.telemetry.enabled = true;
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 2;
        cfg.scaler.enforce_grants = true;
        cfg.tenants = vec![
            TenantSpec::new(1, "gold").with_multiplier(10.0),
            TenantSpec::new(2, "flood").with_multiplier(0.1),
        ];
        let mut st = ServerState::new(&cfg);
        assert!(
            st.handle_line("WHY 1").unwrap().starts_with("ERR no epoch decision yet"),
        );
        // Oversubscribe the cluster with flood traffic, then decide.
        for i in 0..30 {
            st.handle_line(&format!("GET 2/obj{i} 100000"));
        }
        st.handle_line("GET 1/k 100000");
        st.handle_line("EPOCH");
        let why = st.handle_line("WHY 2").unwrap();
        assert!(why.starts_with('{'), "{why}");
        assert!(why.contains("\"tenant\":2"), "{why}");
        assert!(why.contains("\"cause\":"), "{why}");
        assert!(why.contains("\"decision\":{"), "{why}");
        assert!(
            st.handle_line("WHY 99").unwrap().starts_with("ERR no decision recorded"),
        );
        let metrics = st.handle_line("METRICS").unwrap();
        assert!(
            metrics.contains("# TYPE elastictl_requests_total counter"),
            "{metrics}"
        );
        assert!(metrics.contains("elastictl_requests_total 31"), "{metrics}");
        assert!(metrics.ends_with("# EOF"), "{metrics}");
        assert!(st.handle_line("WHY").unwrap().starts_with("ERR"));
        assert!(st.handle_line("WHY nope").unwrap().starts_with("ERR bad tenant"));
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = spawn_state(cfg);
        let srv = {
            std::thread::spawn(move || {
                let (socket, _) = listener.accept().unwrap();
                handle_conn(socket, tx).unwrap();
            })
        };
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"GET obj1 500\nGET obj1 500\nGET 3/obj1 500\nSTATS\nQUIT\n")
            .unwrap();
        let mut lines = BufReader::new(sock.try_clone().unwrap()).lines();
        assert_eq!(lines.next().unwrap().unwrap(), "MISS");
        assert_eq!(lines.next().unwrap().unwrap(), "HIT");
        assert_eq!(lines.next().unwrap().unwrap(), "MISS");
        let stats = lines.next().unwrap().unwrap();
        assert!(stats.contains("\"requests\":3"));
        assert_eq!(lines.next().unwrap().unwrap(), "BYE");
        srv.join().unwrap();
    }
}
