//! `elastictl serve` — a minimal mcrouter-like network front (§6.1: "We
//! have implemented the scheme described in Sect. 5.2 in a custom tool
//! similar to mcrouter").
//!
//! Line protocol over TCP (one request per line, ASCII):
//!
//! ```text
//! GET <key> <size>\n   -> HIT | MISS | SPURIOUS\n
//! STATS\n              -> one-line JSON counters\n
//! EPOCH\n              -> RESIZED <n>\n      (forces an epoch boundary)
//! QUIT\n               -> BYE\n (closes the connection)
//! ```
//!
//! The server wraps the same [`Balancer`] the simulator uses — the
//! request path is identical; only the transport differs. One OS thread
//! per connection (the build is offline-only, so no async runtime crate;
//! the shared balancer sits behind a mutex exactly as mcrouter's shared
//! routing state does).

use crate::balancer::Balancer;
use crate::config::Config;
use crate::cost::CostTracker;
use crate::scaler::make_sizer;
use crate::trace::Request;
use crate::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

/// Shared server state.
pub struct ServerState {
    pub balancer: Balancer,
    pub costs: CostTracker,
    start: std::time::Instant,
}

impl ServerState {
    pub fn new(cfg: &Config) -> Self {
        let sizer = make_sizer(cfg);
        let initial = match cfg.scaler.policy {
            crate::config::PolicyKind::Fixed => cfg.scaler.fixed_instances,
            _ => cfg.scaler.min_instances.max(1),
        };
        ServerState {
            balancer: Balancer::from_config(cfg, sizer, initial),
            costs: CostTracker::new(cfg.cost.clone()),
            start: std::time::Instant::now(),
        }
    }

    fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    /// Handle one protocol line; returns the response line, or `None` to
    /// close the connection.
    pub fn handle_line(&mut self, line: &str) -> Option<String> {
        let mut parts = line.split_ascii_whitespace();
        match parts.next() {
            Some("GET") => {
                let key = parts.next()?;
                let size: u64 = parts.next().and_then(|s| s.parse().ok()).unwrap_or(1);
                // Hash arbitrary string keys onto the ObjectId space.
                let obj = key
                    .parse::<u64>()
                    .unwrap_or_else(|_| crate::mix64(fxhash_str(key)));
                let req =
                    Request { ts: self.now_us(), obj, size: size.min(u32::MAX as u64) as u32 };
                let served = self.balancer.handle(&req, &mut self.costs);
                Some(
                    if served.hit {
                        "HIT"
                    } else if served.spurious {
                        "SPURIOUS"
                    } else {
                        "MISS"
                    }
                    .to_string(),
                )
            }
            Some("STATS") => Some(format!(
                "{{\"requests\":{},\"misses\":{},\"spurious\":{},\"instances\":{},\"miss_cost\":{:.9},\"ttl_secs\":{}}}",
                self.balancer.requests,
                self.balancer.misses,
                self.balancer.spurious_misses,
                self.balancer.cluster.len(),
                self.costs.miss_total(),
                self.balancer
                    .ttl_secs()
                    .map(|t| format!("{t:.3}"))
                    .unwrap_or_else(|| "null".into()),
            )),
            Some("EPOCH") => {
                let n = self.balancer.end_epoch(self.now_us());
                Some(format!("RESIZED {n}"))
            }
            Some("QUIT") => None,
            Some(other) => Some(format!("ERR unknown command {other}")),
            None => Some("ERR empty".to_string()),
        }
    }
}

/// Deterministic string hash (FNV-1a) for non-numeric keys.
fn fxhash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Command channel to the state-owner thread: one protocol line plus a
/// reply channel. The balancer's shadow structures hold non-`Send` PJRT
/// handles in the analytic configuration, so a single dedicated thread
/// owns all state (mcrouter's shared routing state, without locks on the
/// request path).
pub type StateTx = mpsc::Sender<(String, mpsc::Sender<Option<String>>)>;

/// Spawn the state-owner thread for `cfg`, returning its command channel.
pub fn spawn_state(cfg: Config) -> StateTx {
    let (tx, rx) = mpsc::channel::<(String, mpsc::Sender<Option<String>>)>();
    std::thread::spawn(move || {
        let mut st = ServerState::new(&cfg);
        for (line, reply) in rx {
            let _ = reply.send(st.handle_line(&line));
        }
    });
    tx
}

/// Run the server until the listener errors or the process is killed.
pub fn serve(cfg: Config, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "elastictl serve: listening on {} (policy={})",
        listener.local_addr()?,
        cfg.scaler.policy.as_str()
    );
    let tx = spawn_state(cfg);
    for stream in listener.incoming() {
        let socket = stream?;
        let tx = tx.clone();
        std::thread::spawn(move || {
            let _ = handle_conn(socket, tx);
        });
    }
    Ok(())
}

fn handle_conn(socket: TcpStream, tx: StateTx) -> Result<()> {
    let reader = BufReader::new(socket.try_clone()?);
    let mut w = socket;
    for line in reader.lines() {
        let line = line?;
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send((line, reply_tx))
            .map_err(|_| anyhow::anyhow!("state thread gone"))?;
        match reply_rx.recv()? {
            Some(text) => {
                w.write_all(text.as_bytes())?;
                w.write_all(b"\n")?;
            }
            None => {
                w.write_all(b"BYE\n")?;
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};

    fn state(policy: PolicyKind) -> ServerState {
        ServerState::new(&Config::with_policy(policy))
    }

    #[test]
    fn get_protocol_hit_miss() {
        let mut st = state(PolicyKind::Ttl);
        assert_eq!(st.handle_line("GET alpha 1000").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET alpha 1000").unwrap(), "HIT");
        assert_eq!(st.handle_line("GET 42 5").unwrap(), "MISS");
    }

    #[test]
    fn stats_and_epoch() {
        let mut st = state(PolicyKind::Ttl);
        st.handle_line("GET k1 100");
        st.handle_line("GET k2 100");
        let stats = st.handle_line("STATS").unwrap();
        assert!(stats.contains("\"requests\":2"), "{stats}");
        assert!(stats.contains("\"misses\":2"));
        let resp = st.handle_line("EPOCH").unwrap();
        assert!(resp.starts_with("RESIZED "), "{resp}");
    }

    #[test]
    fn errors_and_quit() {
        let mut st = state(PolicyKind::Fixed);
        assert!(st.handle_line("FROB x").unwrap().starts_with("ERR"));
        assert!(st.handle_line("").unwrap().starts_with("ERR"));
        assert!(st.handle_line("QUIT").is_none());
        // GET with no key is malformed → connection closes (None).
        assert!(st.handle_line("GET").is_none());
    }

    #[test]
    fn string_and_numeric_keys_are_distinct_objects() {
        let mut st = state(PolicyKind::Fixed);
        st.handle_line("GET alpha 10");
        assert_eq!(st.handle_line("GET beta 10").unwrap(), "MISS");
        assert_eq!(st.handle_line("GET alpha 10").unwrap(), "HIT");
        assert_eq!(st.handle_line("GET beta 10").unwrap(), "HIT");
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader, Write};
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = spawn_state(cfg);
        let srv = {
            std::thread::spawn(move || {
                let (socket, _) = listener.accept().unwrap();
                handle_conn(socket, tx).unwrap();
            })
        };
        let mut sock = TcpStream::connect(addr).unwrap();
        sock.write_all(b"GET obj1 500\nGET obj1 500\nSTATS\nQUIT\n")
            .unwrap();
        let mut lines = BufReader::new(sock.try_clone().unwrap()).lines();
        assert_eq!(lines.next().unwrap().unwrap(), "MISS");
        assert_eq!(lines.next().unwrap().unwrap(), "HIT");
        let stats = lines.next().unwrap().unwrap();
        assert!(stats.contains("\"requests\":2"));
        assert_eq!(lines.next().unwrap().unwrap(), "BYE");
        srv.join().unwrap();
    }
}
