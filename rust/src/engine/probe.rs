//! Composable run observers. The old simulator hard-coded its series
//! sampling (`SAMPLE_EVERY`, the Fig. 9 balance tracker, the per-tenant
//! summary pass) into each hand-rolled loop; probes make those observers
//! pluggable so experiments attach exactly what they need and new
//! diagnostics never fork the request path again.

use super::{Core, Outcome, RunReport, TenantSummary, SAMPLE_EVERY};
use crate::cluster::BalanceTracker;
use crate::cost::CostTracker;
use crate::metrics::TimeSeries;
use crate::trace::Request;
use crate::{TenantId, TimeUs};

/// Read-only view of the engine state, handed to probes at each hook.
pub struct ProbeCtx<'a> {
    pub(crate) core: &'a Core,
    pub(crate) costs: &'a CostTracker,
    /// Requests offered so far (the current request included).
    pub processed: u64,
    /// Instances billed for the currently open epoch.
    pub instances: u32,
}

impl ProbeCtx<'_> {
    /// Current policy TTL, if the policy maintains one (Fig. 5 left).
    pub fn ttl_secs(&self) -> Option<f64> {
        self.core.ttl_secs()
    }

    /// Current virtual/shadow size in bytes (Fig. 5 right).
    pub fn shadow_size(&self) -> Option<u64> {
        self.core.shadow_size()
    }

    /// Per-instance `(slots, requests, misses)` snapshot (cluster runs
    /// only — the vertical mode has no instances to balance).
    pub fn balance_snapshot(&self) -> Option<Vec<(usize, u64, u64)>> {
        match self.core {
            Core::Cluster(b) => Some(b.cluster.balance_snapshot()),
            Core::Vertical { .. } => None,
        }
    }

    /// The run's cost ledger.
    pub fn costs(&self) -> &CostTracker {
        self.costs
    }

    /// Per-tenant traffic/billing/timer rows (one per tenant that sent
    /// traffic; empty for the vertical mode, which is tenant-oblivious).
    pub fn tenant_summaries(&self) -> Vec<TenantSummary> {
        match self.core {
            Core::Cluster(b) => {
                let ttls = b.tenant_ttls();
                let mut out = Vec::new();
                for (i, hm) in b.tenant_stats().iter().enumerate() {
                    if hm.total() == 0 {
                        continue;
                    }
                    let t = i as TenantId;
                    let ledger = self.costs.tenant_ledger(t);
                    let ttl_secs = ttls
                        .as_ref()
                        .and_then(|v| v.iter().find(|(id, _)| *id == t).map(|&(_, x)| x));
                    out.push(TenantSummary {
                        tenant: t,
                        requests: hm.total(),
                        misses: hm.misses,
                        miss_dollars: ledger.miss_dollars,
                        ttl_secs,
                    });
                }
                out
            }
            Core::Vertical { .. } => Vec::new(),
        }
    }
}

/// A run observer attached to an [`super::Engine`]. All hooks default to
/// no-ops; `finish` folds whatever the probe accumulated into the report.
pub trait Probe {
    /// Called after every request is served.
    fn on_request(&mut self, _req: &Request, _outcome: &Outcome, _ctx: &ProbeCtx) {}

    /// Called at each epoch closure, before billing and resizing (so the
    /// closing epoch's per-instance stats are still intact).
    fn on_epoch(&mut self, _epoch_end: TimeUs, _ctx: &ProbeCtx) {}

    /// Fold the probe's observations into the finished report.
    fn finish(self: Box<Self>, _ctx: &ProbeCtx, _report: &mut RunReport) {}
}

/// Samples the policy TTL every `every` requests into the report's
/// `ttl_series` (Fig. 5 left).
pub struct TtlProbe {
    every: u64,
    series: TimeSeries,
}

impl TtlProbe {
    /// Default sampling cadence ([`SAMPLE_EVERY`]).
    pub fn sampled(policy: &str) -> Self {
        Self::with_every(policy, SAMPLE_EVERY)
    }

    pub fn with_every(policy: &str, every: u64) -> Self {
        TtlProbe {
            every: every.max(1),
            series: TimeSeries::new(format!("{policy}_ttl_secs")),
        }
    }
}

impl Probe for TtlProbe {
    fn on_request(&mut self, req: &Request, _outcome: &Outcome, ctx: &ProbeCtx) {
        if ctx.processed % self.every == 0 {
            if let Some(t) = ctx.ttl_secs() {
                self.series.push(req.ts, t);
            }
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.ttl_series = self.series;
    }
}

/// Samples the virtual/shadow size every `every` requests into the
/// report's `shadow_series` (Fig. 5 right).
pub struct ShadowProbe {
    every: u64,
    series: TimeSeries,
}

impl ShadowProbe {
    /// Default cadence; `suffix` names the series (`shadow_bytes` for
    /// cluster runs, `vsize_bytes` for the vertical mode).
    pub fn sampled(policy: &str, suffix: &str) -> Self {
        Self::with_every(policy, suffix, SAMPLE_EVERY)
    }

    pub fn with_every(policy: &str, suffix: &str, every: u64) -> Self {
        ShadowProbe {
            every: every.max(1),
            series: TimeSeries::new(format!("{policy}_{suffix}")),
        }
    }
}

impl Probe for ShadowProbe {
    fn on_request(&mut self, req: &Request, _outcome: &Outcome, ctx: &ProbeCtx) {
        if ctx.processed % self.every == 0 {
            if let Some(s) = ctx.shadow_size() {
                self.series.push(req.ts, s as f64);
            }
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.shadow_series = self.series;
    }
}

/// Records the Fig. 9 per-instance balance snapshot at every epoch
/// boundary.
pub struct BalanceProbe {
    tracker: BalanceTracker,
}

impl BalanceProbe {
    pub fn new() -> Self {
        BalanceProbe { tracker: BalanceTracker::new() }
    }
}

impl Default for BalanceProbe {
    fn default() -> Self {
        Self::new()
    }
}

impl Probe for BalanceProbe {
    fn on_epoch(&mut self, epoch_end: TimeUs, ctx: &ProbeCtx) {
        if let Some(snap) = ctx.balance_snapshot() {
            self.tracker.record(epoch_end, &snap);
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        let me = *self;
        report.balance = me.tracker;
    }
}

/// Fills the report's per-tenant breakdown from the run's final state.
#[derive(Default)]
pub struct TenantProbe;

impl TenantProbe {
    pub fn new() -> Self {
        TenantProbe
    }
}

impl Probe for TenantProbe {
    fn finish(self: Box<Self>, ctx: &ProbeCtx, report: &mut RunReport) {
        report.tenants = ctx.tenant_summaries();
    }
}
