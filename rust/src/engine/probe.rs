//! Composable run observers. The old simulator hard-coded its series
//! sampling (`SAMPLE_EVERY`, the Fig. 9 balance tracker, the per-tenant
//! summary pass) into each hand-rolled loop; probes make those observers
//! pluggable so experiments attach exactly what they need and new
//! diagnostics never fork the request path again.

use super::{Core, Outcome, RunReport, TenantSummary, SAMPLE_EVERY};
use crate::cluster::BalanceTracker;
use crate::cost::CostTracker;
use crate::metrics::{HitMiss, TimeSeries};
use crate::telemetry::{EpochDecisionRecord, SharedJournal, SharedRegistry, TenantDecision};
use crate::tenant::{LifecycleState, TenantEnforcement};
use crate::trace::Request;
use crate::{TenantId, TimeUs};

/// Read-only view of the engine state, handed to probes at each hook.
pub struct ProbeCtx<'a> {
    pub(crate) core: &'a Core,
    pub(crate) costs: &'a CostTracker,
    /// Requests offered so far (the current request included).
    pub processed: u64,
    /// Instances billed for the currently open epoch.
    pub instances: u32,
}

impl ProbeCtx<'_> {
    /// Current policy TTL, if the policy maintains one (Fig. 5 left).
    pub fn ttl_secs(&self) -> Option<f64> {
        self.core.ttl_secs()
    }

    /// Current virtual/shadow size in bytes (Fig. 5 right).
    pub fn shadow_size(&self) -> Option<u64> {
        self.core.shadow_size()
    }

    /// Per-instance `(slots, requests, misses)` snapshot (cluster runs
    /// only — the vertical mode has no instances to balance).
    pub fn balance_snapshot(&self) -> Option<Vec<(usize, u64, u64)>> {
        match self.core {
            Core::Cluster(b) => Some(b.cluster.balance_snapshot()),
            Core::Vertical { .. } => None,
        }
    }

    /// Cumulative per-tenant hit/miss counters, indexed by tenant id
    /// (cluster runs only).
    pub fn tenant_stats(&self) -> Option<&[HitMiss]> {
        match self.core {
            Core::Cluster(b) => Some(b.tenant_stats()),
            Core::Vertical { .. } => None,
        }
    }

    /// Per-tenant enforcement state (grants, caps, clamps, SLO tracking),
    /// when the policy arbitrates tenants.
    pub fn tenant_enforcement(&self) -> Option<Vec<TenantEnforcement>> {
        match self.core {
            Core::Cluster(b) => b.tenant_enforcement(),
            Core::Vertical { .. } => None,
        }
    }

    /// Per-tenant physical resident bytes — the cluster's placement
    /// ledger rows (cluster runs only).
    pub fn tenant_residents(&self) -> Option<Vec<(TenantId, u64)>> {
        match self.core {
            Core::Cluster(b) => Some(b.cluster.tenant_residents()),
            Core::Vertical { .. } => None,
        }
    }

    /// Shedding performed at the most recent epoch boundary:
    /// `(tenant, resident bytes before, bytes freed)` rows for every
    /// tenant the boundary shed (cap enforcement or retirement drains;
    /// cluster runs only). Meaningful inside `on_epoch_applied`.
    pub fn tenant_shed(&self) -> Option<&[(TenantId, u64, u64)]> {
        match self.core {
            Core::Cluster(b) => Some(b.last_epoch_shed()),
            Core::Vertical { .. } => None,
        }
    }

    /// Cumulative admission-filter denials, indexed by tenant id
    /// (cluster runs only; empty when no filter is configured).
    pub fn tenant_filter_denials(&self) -> Option<&[u64]> {
        match self.core {
            Core::Cluster(b) => Some(b.tenant_filter_denials()),
            Core::Vertical { .. } => None,
        }
    }

    /// The run's cost ledger.
    pub fn costs(&self) -> &CostTracker {
        self.costs
    }

    /// Per-tenant traffic/billing/timer rows (one per tenant that sent
    /// traffic; empty for the vertical mode, which is tenant-oblivious).
    pub fn tenant_summaries(&self) -> Vec<TenantSummary> {
        match self.core {
            Core::Cluster(b) => {
                let ttls = b.tenant_ttls();
                let mut out = Vec::new();
                for (i, hm) in b.tenant_stats().iter().enumerate() {
                    if hm.total() == 0 {
                        continue;
                    }
                    let t = i as TenantId;
                    let ledger = self.costs.tenant_ledger(t);
                    let ttl_secs = ttls
                        .as_ref()
                        .and_then(|v| v.iter().find(|(id, _)| *id == t).map(|&(_, x)| x));
                    out.push(TenantSummary {
                        tenant: t,
                        requests: hm.total(),
                        misses: hm.misses,
                        miss_dollars: ledger.miss_dollars,
                        ttl_secs,
                    });
                }
                out
            }
            Core::Vertical { .. } => Vec::new(),
        }
    }
}

/// A run observer attached to an [`super::Engine`]. All hooks default to
/// no-ops; `finish` folds whatever the probe accumulated into the report.
pub trait Probe {
    /// Called after every request is served.
    fn on_request(&mut self, _req: &Request, _outcome: &Outcome, _ctx: &ProbeCtx) {}

    /// Called at each epoch closure, before billing and resizing (so the
    /// closing epoch's per-instance stats are still intact).
    fn on_epoch(&mut self, _epoch_end: TimeUs, _ctx: &ProbeCtx) {}

    /// Called at each epoch boundary *after* the sizing decision was
    /// applied (resize, placement re-pin/re-partition, occupancy-cap
    /// shedding) — the state the next epoch starts from. Not called for
    /// the final partial epoch (`finish` applies no decision).
    fn on_epoch_applied(&mut self, _epoch_end: TimeUs, _ctx: &ProbeCtx) {}

    /// Called on every tenant lifecycle transition the engine performs —
    /// an `ADMIT` (new, update or re-admission), a `RETIRE` (drain
    /// start), and the drain-completion that retires the tenant and
    /// reconciles its bill.
    fn on_lifecycle(&mut self, _event: &LifecycleSample, _ctx: &ProbeCtx) {}

    /// Fold the probe's observations into the finished report.
    fn finish(self: Box<Self>, _ctx: &ProbeCtx, _report: &mut RunReport) {}
}

/// One tenant lifecycle transition as the engine performed it (admit /
/// drain start / retirement). `exp fig13` reads the spin-up and
/// drain-completion timestamps from these.
#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleSample {
    /// Engine clock at the transition.
    pub t: TimeUs,
    /// The tenant transitioning.
    pub tenant: TenantId,
    /// State the tenant is in *after* the transition.
    pub state: LifecycleState,
    /// The tenant's physical resident bytes at the transition (the
    /// cluster ledger row — zero exactly when a retirement completes).
    pub resident_bytes: u64,
    /// Epoch boundaries the drain has consumed so far (bounded by
    /// [`crate::tenant::MAX_DRAIN_EPOCHS`]).
    pub drain_epochs: u32,
    /// The reconciled bill, present only on the final Retired transition
    /// ([`crate::cost::TenantReconciliation::total_dollars`]).
    pub final_bill_dollars: Option<f64>,
}

/// Records every tenant lifecycle transition into the report's
/// `lifecycle` field — the audit trail of a churn run (who joined when,
/// who drained in how many epochs, and what the final bill was).
#[derive(Default)]
pub struct LifecycleProbe {
    samples: Vec<LifecycleSample>,
}

impl LifecycleProbe {
    /// New, empty probe.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Probe for LifecycleProbe {
    fn on_lifecycle(&mut self, event: &LifecycleSample, _ctx: &ProbeCtx) {
        self.samples.push(event.clone());
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.lifecycle = self.samples;
    }
}

/// Samples the policy TTL every `every` requests into the report's
/// `ttl_series` (Fig. 5 left).
pub struct TtlProbe {
    every: u64,
    series: TimeSeries,
}

impl TtlProbe {
    /// Default sampling cadence ([`SAMPLE_EVERY`]).
    pub fn sampled(policy: &str) -> Self {
        Self::with_every(policy, SAMPLE_EVERY)
    }

    /// Sample every `every` requests.
    pub fn with_every(policy: &str, every: u64) -> Self {
        TtlProbe {
            every: every.max(1),
            series: TimeSeries::new(format!("{policy}_ttl_secs")),
        }
    }
}

impl Probe for TtlProbe {
    fn on_request(&mut self, req: &Request, _outcome: &Outcome, ctx: &ProbeCtx) {
        if ctx.processed % self.every == 0 {
            if let Some(t) = ctx.ttl_secs() {
                self.series.push(req.ts, t);
            }
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.ttl_series = self.series;
    }
}

/// Samples the virtual/shadow size every `every` requests into the
/// report's `shadow_series` (Fig. 5 right).
pub struct ShadowProbe {
    every: u64,
    series: TimeSeries,
}

impl ShadowProbe {
    /// Default cadence; `suffix` names the series (`shadow_bytes` for
    /// cluster runs, `vsize_bytes` for the vertical mode).
    pub fn sampled(policy: &str, suffix: &str) -> Self {
        Self::with_every(policy, suffix, SAMPLE_EVERY)
    }

    /// Sample every `every` requests; `suffix` names the series.
    pub fn with_every(policy: &str, suffix: &str, every: u64) -> Self {
        ShadowProbe {
            every: every.max(1),
            series: TimeSeries::new(format!("{policy}_{suffix}")),
        }
    }
}

impl Probe for ShadowProbe {
    fn on_request(&mut self, req: &Request, _outcome: &Outcome, ctx: &ProbeCtx) {
        if ctx.processed % self.every == 0 {
            if let Some(s) = ctx.shadow_size() {
                self.series.push(req.ts, s as f64);
            }
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.shadow_series = self.series;
    }
}

/// Records the Fig. 9 per-instance balance snapshot at every epoch
/// boundary.
pub struct BalanceProbe {
    tracker: BalanceTracker,
}

impl BalanceProbe {
    /// New, empty probe.
    pub fn new() -> Self {
        BalanceProbe { tracker: BalanceTracker::new() }
    }
}

impl Default for BalanceProbe {
    fn default() -> Self {
        Self::new()
    }
}

impl Probe for BalanceProbe {
    fn on_epoch(&mut self, epoch_end: TimeUs, ctx: &ProbeCtx) {
        if let Some(snap) = ctx.balance_snapshot() {
            self.tracker.record(epoch_end, &snap);
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        let me = *self;
        report.balance = me.tracker;
    }
}

/// Fills the report's per-tenant breakdown from the run's final state.
#[derive(Default)]
pub struct TenantProbe;

impl TenantProbe {
    /// New probe.
    pub fn new() -> Self {
        TenantProbe
    }
}

impl Probe for TenantProbe {
    fn finish(self: Box<Self>, ctx: &ProbeCtx, report: &mut RunReport) {
        report.tenants = ctx.tenant_summaries();
    }
}

/// One per-tenant row of an epoch's SLO/enforcement record (fig11 and the
/// `SLO` serve command read the live equivalent).
#[derive(Debug, Clone, PartialEq)]
pub struct SloSample {
    /// Epoch-close timestamp.
    pub t: TimeUs,
    /// The sampled tenant.
    pub tenant: TenantId,
    /// Requests within the closing epoch (not cumulative).
    pub requests: u64,
    /// Misses within the closing epoch (not cumulative).
    pub misses: u64,
    /// Miss ratio of the closing epoch.
    pub miss_ratio: f64,
    /// Configured miss-ratio SLO, if any.
    pub slo_miss_ratio: Option<f64>,
    /// Bytes granted by the decision that was in force during this epoch.
    pub granted_bytes: Option<u64>,
    /// Occupancy cap / admission budget in force during this epoch.
    pub cap_bytes: Option<u64>,
    /// TTL clamp in force during this epoch, seconds.
    pub ttl_clamp_secs: Option<f64>,
    /// Grant-priority boost in force during this epoch.
    pub boost: f64,
}

impl SloSample {
    /// Whether this epoch violated the tenant's SLO.
    pub fn in_violation(&self) -> bool {
        self.slo_miss_ratio.map(|t| self.miss_ratio > t).unwrap_or(false)
    }
}

/// Records, at every epoch boundary, each active tenant's epoch miss
/// ratio next to the enforcement state (grant / cap / clamp / boost) that
/// was in force while the epoch ran — the measurement behind the
/// per-tenant SLO guarantee of `exp fig11`.
#[derive(Default)]
pub struct SloProbe {
    /// Cumulative per-tenant counters at the previous epoch boundary.
    prev: Vec<HitMiss>,
    samples: Vec<SloSample>,
}

impl SloProbe {
    /// New, empty probe.
    pub fn new() -> Self {
        Self::default()
    }
}

/// One per-tenant row of an epoch boundary's physical-placement record:
/// the resident bytes the tenant holds *after* the boundary's placement
/// maintenance (resize, re-pin/re-partition, occupancy-cap shedding),
/// next to the grant/cap of the decision now in force. `exp fig12` and
/// the occupancy-cap acceptance check read this.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementSample {
    /// Epoch-boundary timestamp.
    pub t: TimeUs,
    /// The sampled tenant.
    pub tenant: TenantId,
    /// Physical resident bytes the next epoch starts from.
    pub resident_bytes: u64,
    /// Bytes granted by the decision now in force.
    pub granted_bytes: Option<u64>,
    /// Occupancy cap now in force. Under `scaler.enforce_grants`,
    /// `resident_bytes ≤ cap_bytes` at every boundary: the boundary shed
    /// just reclaimed any overage, and in-epoch admission keeps it bound
    /// until the next boundary.
    pub cap_bytes: Option<u64>,
}

/// Records, at every epoch boundary, each tenant's physical resident
/// bytes (the cluster placement ledger) next to the enforcement state
/// the next epoch starts under.
#[derive(Default)]
pub struct PlacementProbe {
    samples: Vec<PlacementSample>,
}

impl PlacementProbe {
    /// New, empty probe.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Probe for PlacementProbe {
    fn on_epoch_applied(&mut self, epoch_end: TimeUs, ctx: &ProbeCtx) {
        let Some(residents) = ctx.tenant_residents() else {
            return;
        };
        // The decision (grants → caps, pins, floors, shed) was just
        // applied: rows describe the state the next epoch starts under.
        let rows = ctx.tenant_enforcement();
        for (tenant, resident_bytes) in residents {
            let row = rows
                .as_ref()
                .and_then(|v| v.iter().find(|r| r.tenant == tenant));
            self.samples.push(PlacementSample {
                t: epoch_end,
                tenant,
                resident_bytes,
                granted_bytes: row.and_then(|r| {
                    if r.decided { Some(r.granted_bytes) } else { None }
                }),
                cap_bytes: row.and_then(|r| r.cap_bytes),
            });
        }
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.placement = self.samples;
    }
}

/// Assembles one [`EpochDecisionRecord`] per closed epoch — the decision
/// trace behind the serve `WHY` command, the JSONL journal artifact and
/// `exp fig14-obs`. Attached by the engine whenever `[telemetry]
/// enabled` is set; shares the journal ring and registry with the serve
/// loop so live queries and the final report read the same records.
pub struct JournalProbe {
    journal: SharedJournal,
    registry: SharedRegistry,
    /// Grantable capacity the arbiter decides against
    /// (`max_instances × instance bytes`) — stamped on every record so
    /// the journal invariant Σ granted ≤ capacity is self-checking.
    capacity_bytes: u64,
    /// Zero-based index of the next epoch to record.
    epoch: u64,
    /// Cumulative denied admissions per tenant id at the previous
    /// boundary (the enforcement rows expose lifetime totals).
    prev_denied: Vec<u64>,
    /// Cumulative admission-filter denials per tenant id at the
    /// previous boundary (the balancer exposes lifetime totals).
    prev_filter: Vec<u64>,
    /// Tenant-bill rows already attributed to earlier records.
    bills_seen: usize,
    /// Reconciliation rows already attributed to earlier records.
    recons_seen: usize,
    /// Cumulative cluster dollars at the previous boundary.
    prev_storage: f64,
    prev_miss: f64,
}

impl JournalProbe {
    /// New probe writing into `journal`, refreshing exposition gauges in
    /// `registry`, stamping `capacity_bytes` on every record.
    pub fn new(journal: SharedJournal, registry: SharedRegistry, capacity_bytes: u64) -> Self {
        JournalProbe {
            journal,
            registry,
            capacity_bytes,
            epoch: 0,
            prev_denied: Vec::new(),
            prev_filter: Vec::new(),
            bills_seen: 0,
            recons_seen: 0,
            prev_storage: 0.0,
            prev_miss: 0.0,
        }
    }
}

impl Probe for JournalProbe {
    fn on_epoch_applied(&mut self, epoch_end: TimeUs, ctx: &ProbeCtx) {
        let costs = ctx.costs();
        // Ledger rows appended since the previous boundary belong to the
        // epoch that just closed (billing runs before this hook).
        let bills = &costs.tenant_bills()[self.bills_seen..];
        self.bills_seen = costs.tenant_bills().len();
        let recons = &costs.reconciliations()[self.recons_seen..];
        self.recons_seen = costs.reconciliations().len();
        let storage_dollars = costs.storage_total() - self.prev_storage;
        let miss_dollars = costs.miss_total() - self.prev_miss;
        self.prev_storage = costs.storage_total();
        self.prev_miss = costs.miss_total();

        let rows = ctx.tenant_enforcement().unwrap_or_default();
        let residents = ctx.tenant_residents().unwrap_or_default();
        let shed = ctx.tenant_shed().unwrap_or(&[]);
        let filter_totals = ctx.tenant_filter_denials().unwrap_or(&[]);

        // One row per tenant any source mentions (a draining tenant has
        // bills and sheds after its enforcement row is gone; a filter
        // denial can hit a tenant no arbiter tracks).
        let mut ids: Vec<TenantId> = rows
            .iter()
            .map(|r| r.tenant)
            .chain(bills.iter().map(|b| b.tenant))
            .chain(shed.iter().map(|&(t, _, _)| t))
            .chain(recons.iter().map(|r| r.tenant))
            .chain(filter_totals.iter().enumerate().filter_map(|(t, &total)| {
                let prev = self.prev_filter.get(t).copied().unwrap_or(0);
                (total > prev).then_some(t as TenantId)
            }))
            .collect();
        ids.sort_unstable();
        ids.dedup();

        let mut tenants = Vec::with_capacity(ids.len());
        for t in ids {
            let row = rows.iter().find(|r| r.tenant == t);
            let resident_bytes = residents
                .iter()
                .find(|&&(id, _)| id == t)
                .map(|&(_, b)| b)
                .unwrap_or(0);
            let (resident_before_bytes, shed_bytes) = shed
                .iter()
                .find(|&&(id, _, _)| id == t)
                .map(|&(_, before, freed)| (before, freed))
                .unwrap_or((resident_bytes, 0));
            let denied_total = row.map(|r| r.denied_admissions).unwrap_or(0);
            let ti = t as usize;
            if self.prev_denied.len() <= ti {
                self.prev_denied.resize(ti + 1, 0);
            }
            let denied = denied_total.saturating_sub(self.prev_denied[ti]);
            self.prev_denied[ti] = denied_total;
            let filter_total = filter_totals.get(ti).copied().unwrap_or(0);
            if self.prev_filter.len() <= ti {
                self.prev_filter.resize(ti + 1, 0);
            }
            let filter_denials = filter_total.saturating_sub(self.prev_filter[ti]);
            self.prev_filter[ti] = filter_total;
            let granted = row
                .filter(|r| r.decided)
                .map(|r| r.granted_bytes)
                .unwrap_or(0);
            let reserved = row.map(|r| r.reserved_bytes).unwrap_or(0);
            tenants.push(TenantDecision {
                tenant: t,
                demand_bytes: row.map(|r| r.demand_bytes).unwrap_or(0),
                granted_bytes: granted,
                reserved_bytes: reserved,
                pooled_bytes: granted.saturating_sub(reserved),
                cap_bytes: row.and_then(|r| r.cap_bytes),
                ttl_clamp_secs: row.and_then(|r| r.ttl_clamp_secs),
                resident_before_bytes,
                resident_bytes,
                shed_bytes,
                denied_admissions: denied,
                filter_denials,
                slo_miss_ratio: row.and_then(|r| r.slo_miss_ratio),
                measured_miss_ratio: row.and_then(|r| r.measured_miss_ratio),
                boost: row.map(|r| r.boost).unwrap_or(1.0),
                bill_storage_dollars: bills
                    .iter()
                    .filter(|b| b.tenant == t)
                    .map(|b| b.storage)
                    .sum(),
                bill_miss_dollars: bills
                    .iter()
                    .filter(|b| b.tenant == t)
                    .map(|b| b.miss)
                    .sum(),
                reconciled_dollars: recons
                    .iter()
                    .find(|r| r.tenant == t)
                    .map(|r| r.total_dollars),
            });
        }

        // Refresh exposition gauges from the decision now in force; the
        // epoch path tolerates the name lookups the hot path avoids.
        {
            let mut reg = self.registry.borrow_mut();
            reg.gauge("elastictl_instances").set(ctx.instances as f64);
            reg.gauge("elastictl_epochs_closed").set((self.epoch + 1) as f64);
            for d in &tenants {
                reg.tenant_gauge("elastictl_tenant_granted_bytes", d.tenant)
                    .set(d.granted_bytes as f64);
                reg.tenant_gauge("elastictl_tenant_resident_bytes", d.tenant)
                    .set(d.resident_bytes as f64);
                reg.tenant_gauge("elastictl_tenant_boost", d.tenant).set(d.boost);
            }
        }

        self.journal.borrow_mut().push(EpochDecisionRecord {
            t: epoch_end,
            epoch: self.epoch,
            instances: ctx.instances,
            capacity_bytes: self.capacity_bytes,
            storage_dollars,
            miss_dollars,
            tenants,
        });
        self.epoch += 1;
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.journal = self.journal.borrow().records().cloned().collect();
        report.telemetry = self.registry.borrow().snapshot();
    }
}

impl Probe for SloProbe {
    fn on_epoch(&mut self, epoch_end: TimeUs, ctx: &ProbeCtx) {
        let Some(stats) = ctx.tenant_stats() else {
            return;
        };
        // Enforcement rows reflect the decision taken at the *previous*
        // boundary — exactly what governed the epoch that is closing now.
        let rows = ctx.tenant_enforcement();
        for (i, hm) in stats.iter().enumerate() {
            let prev = self.prev.get(i).copied().unwrap_or_default();
            let requests = hm.total() - prev.total();
            if requests == 0 {
                continue;
            }
            let misses = hm.misses - prev.misses;
            let tenant = i as TenantId;
            let row = rows
                .as_ref()
                .and_then(|v| v.iter().find(|r| r.tenant == tenant));
            self.samples.push(SloSample {
                t: epoch_end,
                tenant,
                requests,
                misses,
                miss_ratio: misses as f64 / requests as f64,
                slo_miss_ratio: row.and_then(|r| r.slo_miss_ratio),
                granted_bytes: row.and_then(|r| {
                    if r.decided { Some(r.granted_bytes) } else { None }
                }),
                cap_bytes: row.and_then(|r| r.cap_bytes),
                ttl_clamp_secs: row.and_then(|r| r.ttl_clamp_secs),
                boost: row.map(|r| r.boost).unwrap_or(1.0),
            });
        }
        self.prev = stats.to_vec();
    }

    fn finish(self: Box<Self>, _ctx: &ProbeCtx, report: &mut RunReport) {
        report.slo = self.samples;
    }
}
