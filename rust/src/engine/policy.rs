//! The uniform policy registry: every [`PolicyKind`] builds an
//! [`EnginePolicy`], with no unsupported kind left to panic on (the old
//! `make_sizer` aborted on `analytic` and `ideal_ttl`).
//!
//! Policies come in two billing shapes:
//!
//! * **Horizontal** — an [`EpochSizer`] driving a cluster of fixed-size
//!   instances behind the balancer, billed per epoch (§2.3). Fixed, TTL,
//!   MRC, the per-tenant controller bank and the PJRT analytic planner
//!   all live here.
//! * **Vertical** — the ideal vertically scaled TTL cache of §6.1
//!   ([`VerticalTtl`]), billed on instantaneous occupancy. It implements
//!   [`EpochSizer`] too (its `decide` reports the equivalent instance
//!   count), so it is a first-class citizen of the same registry rather
//!   than a forked simulation loop.

use crate::config::{Config, PolicyKind};
use crate::runtime::AnalyticSizer;
use crate::scaler::{EpochSizer, FixedSizer, MrcSizer, PolicyWork, TtlSizer};
use crate::tenant::TenantTtlSizer;
use crate::trace::Request;
use crate::vcache::VirtualCache;
use crate::TimeUs;

/// A policy plus the billing shape the engine must run it under.
pub enum EnginePolicy {
    /// Cluster of instances behind the balancer, epoch-billed.
    Horizontal(Box<dyn EpochSizer>),
    /// Ideal TTL cache billed on instantaneous occupancy; virtual hits
    /// are real hits (no instances, no spurious misses).
    Vertical(VerticalTtl),
}

impl EnginePolicy {
    /// Policy name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            EnginePolicy::Horizontal(s) => s.name(),
            EnginePolicy::Vertical(v) => v.name(),
        }
    }
}

/// Build the configured policy. Total over [`PolicyKind`] — the compiler
/// enforces that adding a kind extends this registry.
pub fn build_policy(cfg: &Config) -> EnginePolicy {
    match cfg.scaler.policy {
        PolicyKind::Fixed => {
            EnginePolicy::Horizontal(Box::new(FixedSizer::new(cfg.scaler.fixed_instances)))
        }
        PolicyKind::Ttl => EnginePolicy::Horizontal(Box::new(TtlSizer::from_config(cfg))),
        PolicyKind::Mrc => EnginePolicy::Horizontal(Box::new(MrcSizer::from_config(cfg))),
        PolicyKind::TenantTtl => {
            EnginePolicy::Horizontal(Box::new(TenantTtlSizer::from_config(cfg)))
        }
        PolicyKind::Analytic => {
            EnginePolicy::Horizontal(Box::new(AnalyticSizer::from_config(cfg)))
        }
        PolicyKind::IdealTtl => EnginePolicy::Vertical(VerticalTtl::from_config(cfg)),
    }
}

/// Build the configured policy as a bare [`EpochSizer`]. The vertical
/// `ideal_ttl` mode is boxed as-is: it exposes the full sizer surface
/// (ttl/shadow probes, equivalent-instance `decide`).
///
/// **Billing caveat:** driving the `ideal_ttl` sizer through the
/// horizontal cluster path (e.g. `sim::run_policy` or a hand-built
/// `Balancer`) epoch-bills a cluster sized to the ideal cache's
/// occupancy — an Algorithm-2-style approximation, NOT the vertically
/// billed §6.1 reference. For ideal-TTL cost semantics go through
/// [`super::EngineBuilder`] / [`super::run`], which select the vertical
/// billing mode from `cfg.scaler.policy`.
pub fn build_sizer(cfg: &Config) -> Box<dyn EpochSizer> {
    match build_policy(cfg) {
        EnginePolicy::Horizontal(s) => s,
        EnginePolicy::Vertical(v) => Box::new(v),
    }
}

/// The *ideal* vertically scaled TTL cache (§6.1 "as a reference"): a pure
/// TTL cache whose virtual hits are real hits — no instances, no epoch
/// granularity loss, no spurious misses. The engine bills its occupancy
/// continuously instead of per instance-epoch.
pub struct VerticalTtl {
    vc: VirtualCache,
    instance_bytes: u64,
}

impl VerticalTtl {
    /// Build the vertical reference cache from `cfg`'s controller and
    /// cost sections.
    pub fn from_config(cfg: &Config) -> Self {
        VerticalTtl {
            vc: VirtualCache::new(&cfg.controller, cfg.cost.clone()),
            instance_bytes: cfg.cost.instance.ram_bytes.max(1),
        }
    }

    /// Instantaneous occupancy, bytes.
    pub fn vsize(&self) -> u64 {
        self.vc.vsize()
    }

    /// The underlying §4 virtual TTL cache (read-only).
    pub fn vcache(&self) -> &VirtualCache {
        &self.vc
    }
}

impl EpochSizer for VerticalTtl {
    fn on_request(&mut self, req: &Request) -> PolicyWork {
        // Per-object cache; scope keys so multi-tenant traces don't alias
        // across tenants.
        let obj = crate::tenant::scoped_object(req.tenant, req.obj);
        let out = self.vc.on_request(req.ts, obj, req.size_bytes());
        PolicyWork { units: 3, shadow_hit: Some(out.hit), admit: true }
    }

    /// Equivalent instance count of the current occupancy — a diagnostic;
    /// vertical billing never resizes anything.
    fn decide(&mut self, now: TimeUs) -> u32 {
        self.vc.expire(now);
        (self.vc.vsize() as f64 / self.instance_bytes as f64).round() as u32
    }

    fn name(&self) -> &'static str {
        "ideal_ttl"
    }

    fn ttl_secs(&self) -> Option<f64> {
        Some(self.vc.ttl_secs())
    }

    fn shadow_size(&self) -> Option<u64> {
        Some(self.vc.vsize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::SECOND;

    #[test]
    fn registry_builds_every_kind_without_panicking() {
        for (kind, name) in [
            (PolicyKind::Fixed, "fixed"),
            (PolicyKind::Ttl, "ttl"),
            (PolicyKind::Mrc, "mrc"),
            (PolicyKind::TenantTtl, "tenant_ttl"),
            (PolicyKind::Analytic, "analytic"),
            (PolicyKind::IdealTtl, "ideal_ttl"),
        ] {
            let policy = build_policy(&Config::with_policy(kind));
            assert_eq!(policy.name(), name);
            let sizer = build_sizer(&Config::with_policy(kind));
            assert_eq!(sizer.name(), name);
        }
    }

    #[test]
    fn vertical_ttl_exposes_the_sizer_surface() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 3600.0; // sticky ghosts
        cfg.cost.instance.ram_bytes = 10_000;
        let mut v = VerticalTtl::from_config(&cfg);
        let w = v.on_request(&Request::new(0, 1, 6_000));
        assert_eq!(w.shadow_hit, Some(false), "first touch is a miss");
        let w2 = v.on_request(&Request::new(SECOND, 1, 6_000));
        assert_eq!(w2.shadow_hit, Some(true), "virtual hits are real hits");
        v.on_request(&Request::new(SECOND, 2, 6_000));
        assert_eq!(v.shadow_size(), Some(12_000));
        assert!(v.ttl_secs().unwrap() > 0.0);
        // Equivalent instances: 12 KB over 10 KB nodes rounds to 1.
        assert_eq!(v.decide(2 * SECOND), 1);
        // After everything expires the equivalent size collapses.
        assert_eq!(v.decide(2 * crate::DAY), 0);
    }

    #[test]
    fn vertical_ttl_scopes_tenants_apart() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 600.0;
        let mut v = VerticalTtl::from_config(&cfg);
        v.on_request(&Request::new(0, 7, 100).with_tenant(1));
        let w = v.on_request(&Request::new(1, 7, 100).with_tenant(2));
        assert_eq!(w.shadow_hit, Some(false), "tenants must not alias");
    }
}
