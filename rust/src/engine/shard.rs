//! Multicore execution: the hot path partitioned across N shard workers,
//! synchronized only at the epoch barrier.
//!
//! The paper's implementation constraint is O(1) work per request
//! independent of cache size (§5.2); the epoch is the only global
//! synchronization point the algorithms need — Memshare-style arbitration
//! and billing both happen at boundaries. [`ShardedEngine`] exploits
//! exactly that: requests route to `hash(tenant, key) % N` workers
//! ([`shard_of`]), each owning a disjoint slice of cluster instances,
//! placement state and per-tenant shadow/controller state, and the
//! workers communicate with the front only through per-shard FIFO
//! channels. At each epoch boundary the front runs a deterministic
//! barrier:
//!
//! 1. **Collect** — per-shard resident-byte ledgers and coalesced
//!    `(tenant, dollars, count)` miss runs, folded into the front
//!    [`CostTracker`] in fixed shard order (0..N) via
//!    [`CostTracker::record_miss_dollars_run`], so the per-tenant bills
//!    fold exactly as the monolithic engine's would.
//! 2. **Bill** — one `end_epoch_attributed` call at the size that was
//!    active, on the merged residents.
//! 3. **Prepare** — per-shard epoch-stat reset + boundary shadow
//!    maintenance, reporting [`TenantDemand`] rows upward
//!    ([`crate::balancer::Balancer::begin_epoch_shard`]).
//! 4. **Decide** — the rows merge (demand summed, reservation and weight
//!    taken once, first-seen order scanning shards 0..N) into the single
//!    existing arbiter decision.
//! 5. **Apply** — the target instance count and the per-tenant grants
//!    split back out ([`split_even`], grants proportional to per-shard
//!    demand) and every shard resizes, re-pins and sheds
//!    ([`crate::balancer::Balancer::finish_epoch_shard`]).
//! 6. **Reconcile** — a retiring tenant's bill closes once *every* shard
//!    has drained its slice.
//!
//! Observability is shard-native: with `[telemetry] enabled` each
//! worker attaches its balancer to a per-shard [`TelemetryRegistry`]
//! (scraped with `shard="i"` labels plus cluster-level sums through
//! [`crate::telemetry::prometheus_merged`]), the front records
//! shard-health metrics (queue depth, batch occupancy, barrier-wait and
//! merge timers, request imbalance), and the barrier replays the
//! monolithic `JournalProbe` record assembly over the merged state, so
//! the per-epoch decision records are bit-identical to the `shards = 1`
//! journal.
//!
//! With `shards = 1` the classic [`super::Engine`] runs instead (the
//! seed loops stay bit-identical; `engine_parity` pins them); the
//! `sharded_parity` integration test proves `shards = N` reproduces the
//! `shards = 1` epoch rows, grants, bills, totals — and journal records
//! — bit-for-bit.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::balancer::Balancer;
use crate::cluster::BalanceTracker;
use crate::config::{Config, CostConfig, PolicyKind};
use crate::cost::{
    CostTracker, EpochCosts, MissAccountant, TenantEpochBill, TenantLedger, TenantReconciliation,
};
use crate::metrics::{HitMiss, TimeSeries};
use crate::placement::PlacementSnapshot;
use crate::telemetry::{
    self, Counter, EpochDecisionRecord, Gauge, Journal, TelemetryRegistry, TenantDecision, Timer,
};
use crate::tenant::{
    scoped_object, AdmitOutcome, Arbiter, Lifecycle, TenantAllocation, TenantDemand,
    TenantEnforcement, TenantSpec, SLO_BOOST_MAX, SLO_BOOST_STEP,
};
use crate::trace::{Request, TenantEvent, TenantEventKind};
use crate::{mix64, ObjectId, Result, TenantId, TimeUs};

use super::{build_policy, build_sizer, RunReport};

/// Requests buffered per shard before a channel send (amortizes the
/// per-message cost on the trace-replay path; flushed at every barrier,
/// lifecycle or stats round-trip).
const BATCH: usize = 512;

/// Deterministic shard routing: `hash(tenant, key) % shards`. Uses the
/// same tenant-scoped key the balancer routes on, so a `(tenant, key)`
/// pair maps to exactly one shard and each tenant's key space partitions
/// cleanly across all of them.
#[inline]
pub fn shard_of(tenant: TenantId, obj: ObjectId, shards: u32) -> usize {
    (mix64(scoped_object(tenant, obj)) % shards.max(1) as u64) as usize
}

/// Per-shard miss-billing sink: prices each miss exactly as the front
/// tracker would ([`CostTracker::record_miss_for`]'s
/// `miss_cost(size) × weight`) and coalesces consecutive identical
/// charges into `(tenant, dollars, count)` runs. The front replays the
/// runs addend by addend at the barrier, so the fold is bit-identical to
/// the monolithic engine charging the same misses in the same per-shard
/// order.
struct ShardMissLedger {
    cfg: CostConfig,
    weights: Vec<f64>,
    runs: Vec<(TenantId, f64, u64)>,
}

impl ShardMissLedger {
    fn new(cfg: CostConfig, tenants: &[TenantSpec]) -> Self {
        let mut ledger = ShardMissLedger { cfg, weights: Vec::new(), runs: Vec::new() };
        for spec in tenants {
            ledger.set_weight(spec.id, spec.miss_cost_multiplier);
        }
        ledger
    }

    fn set_weight(&mut self, t: TenantId, weight: f64) {
        let i = t as usize;
        if self.weights.len() <= i {
            self.weights.resize(i + 1, 1.0);
        }
        self.weights[i] = weight;
    }

    fn weight(&self, t: TenantId) -> f64 {
        self.weights.get(t as usize).copied().unwrap_or(1.0)
    }

    /// Drain the coalesced runs accumulated since the last barrier.
    fn take_runs(&mut self) -> Vec<(TenantId, f64, u64)> {
        std::mem::take(&mut self.runs)
    }
}

impl MissAccountant for ShardMissLedger {
    fn record_miss_for(&mut self, t: TenantId, size_bytes: u64) {
        let m = self.cfg.miss_cost(size_bytes) * self.weight(t);
        match self.runs.last_mut() {
            Some((lt, ld, count)) if *lt == t && ld.to_bits() == m.to_bits() => *count += 1,
            _ => self.runs.push((t, m, 1)),
        }
    }
}

/// Synchronous outcome of a routed GET (the server's connection threads
/// read this off a [`ShardRouter`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GetOutcome {
    /// The request hit physically on the owning shard.
    pub hit: bool,
    /// §5.2 spurious miss (resident elsewhere on the shard's slice).
    pub spurious: bool,
}

/// One shard's counters and ledgers, snapshotted on demand (the server's
/// STATS surface and the shard-partition property tests).
#[derive(Debug, Clone)]
pub struct ShardStats {
    /// Requests this shard served.
    pub requests: u64,
    /// Physical misses among them.
    pub misses: u64,
    /// §5.2 spurious misses.
    pub spurious_misses: u64,
    /// Inserts suppressed by binding occupancy caps.
    pub denied_admissions: u64,
    /// Inserts suppressed by the admission filter (`[admission] filter`).
    pub filter_denials: u64,
    /// Policy work units performed.
    pub work_units: u64,
    /// Instances this shard currently owns.
    pub instances: u32,
    /// Resident bytes across this shard's instances.
    pub used_bytes: u64,
    /// Per-tenant resident bytes on this shard (id ascending).
    pub tenant_residents: Vec<(TenantId, u64)>,
    /// Per-tenant request totals, indexed by tenant id.
    pub tenant_totals: Vec<u64>,
    /// ADMIT lifecycle messages this shard received.
    pub admit_events: u64,
    /// RETIRE lifecycle messages this shard received.
    pub retire_events: u64,
}

/// Pre-billing barrier snapshot from one shard.
struct ShardCollect {
    residents: Vec<(TenantId, u64)>,
    miss_runs: Vec<(TenantId, f64, u64)>,
    /// Cumulative per-tenant hit/miss counters, indexed by tenant id.
    /// The front differences Σ-over-shards against the previous boundary
    /// to replicate each tenant's SLO measurement window.
    tenant_stats: Vec<HitMiss>,
}

/// Post-apply barrier reply from one shard.
struct ShardApplied {
    retired: Vec<TenantId>,
    /// Post-apply per-tenant resident bytes (the journal's ledger view).
    residents: Vec<(TenantId, u64)>,
    /// This boundary's `(tenant, resident_before, freed)` shed log.
    shed: Vec<(TenantId, u64, u64)>,
    /// Post-apply enforcement rows (`None` = the policy does not
    /// arbitrate tenants).
    enforcement: Option<Vec<TenantEnforcement>>,
    /// Cumulative admission-filter denials, indexed by tenant id — the
    /// front sums the disjoint shard slices and differences against the
    /// previous boundary for the journal rows.
    filter_denials: Vec<u64>,
}

/// Point-in-time observability snapshot of one shard. The front merges
/// these — summing values over the disjoint shard slices, taking
/// spec-wide values once — to answer the server's `SLO`, `PLACEMENT`
/// and `STATS <tenant>` queries at the cost of one round-trip.
#[derive(Debug, Clone)]
pub struct ShardObservation {
    /// Enforcement rows (`None` = the policy does not arbitrate tenants).
    pub enforcement: Option<Vec<TenantEnforcement>>,
    /// Per-tenant lifecycle states (`None` = no lifecycle tracking).
    pub lifecycle: Option<Vec<(TenantId, Lifecycle)>>,
    /// Cumulative per-tenant hit/miss counters, indexed by tenant id.
    pub tenant_stats: Vec<HitMiss>,
    /// Per-tenant resident bytes on this shard (id ascending).
    pub residents: Vec<(TenantId, u64)>,
    /// This shard's placement snapshot.
    pub placement: PlacementSnapshot,
    /// Per-tenant controller TTLs, seconds (`None` = single controller).
    pub ttls: Option<Vec<(TenantId, f64)>>,
    /// Instances this shard's cluster currently owns (pins in
    /// [`Self::placement`] index into them; a merged view offsets each
    /// shard's pins by the preceding shards' counts).
    pub instances: u32,
}

/// Final-drain reply from one shard ([`ShardedEngine::finish`]).
struct ShardFinish {
    residents: Vec<(TenantId, u64)>,
    miss_runs: Vec<(TenantId, f64, u64)>,
    retired: Vec<TenantId>,
    requests: u64,
    misses: u64,
    spurious_misses: u64,
    work_units: u64,
}

/// The shard worker protocol. Every variant travels the shard's FIFO
/// channel, so ordering against buffered request batches is total.
enum ToShard {
    /// Fire-and-forget request batch (trace replay).
    Batch(Vec<Request>),
    /// One synchronous request (the server's GET path).
    Get(Request, Sender<GetOutcome>),
    /// Barrier step 1: residents + miss runs for the closing epoch.
    Collect(Sender<ShardCollect>),
    /// Barrier step 3: reset epoch stats, run boundary shadow
    /// maintenance, report demand rows (`None` = policy cannot shard).
    Prepare(TimeUs, Sender<Option<Vec<TenantDemand>>>),
    /// Barrier step 5: this shard's slice of the decision.
    Apply {
        now: TimeUs,
        target: u32,
        allocs: Vec<TenantAllocation>,
        reply: Sender<ShardApplied>,
    },
    /// Admit (or update) a tenant on this shard; the reply carries the
    /// shard's cumulative hit/miss counters for the tenant so the front
    /// can reset its SLO window replica on readmission.
    Admit(TenantSpec, TimeUs, Sender<Result<(AdmitOutcome, HitMiss)>>),
    /// Begin retiring a tenant on this shard.
    Retire(TenantId, TimeUs, Sender<Result<()>>),
    /// Final partial-epoch snapshot + drain ([`ShardedEngine::finish`]).
    Finish(TimeUs, Sender<ShardFinish>),
    /// Checkpoint restore: adopt this shard's slice of the restored size.
    Resize(u32),
    /// Counter/ledger snapshot.
    Stats(Sender<ShardStats>),
    /// Live observability snapshot (the server's `SLO` / `PLACEMENT` /
    /// `STATS <tenant>` surface).
    Observe(Sender<ShardObservation>),
    /// Exit the worker loop even while [`ShardRouter`] clones (server
    /// connection threads) still hold senders.
    Shutdown,
}

/// The worker body: owns one balancer (cluster slice + placement +
/// policy state) built on-thread from the shared config, and drains its
/// channel until the front drops the sender.
fn worker_loop(
    cfg: Config,
    initial: u32,
    telemetry: Option<TelemetryRegistry>,
    rx: Receiver<ToShard>,
) {
    let mut b = Balancer::from_config(&cfg, build_sizer(&cfg), initial);
    if let Some(mut reg) = telemetry {
        // Pre-resolve this worker's counter/timer handles. The front
        // holds a clone of the same registry, so the scrape sees them
        // live under its `shard="i"` label.
        b.attach_telemetry(&mut reg);
    }
    if cfg.serve.ttl_expiry_secs > 0.0 {
        b.cluster.enable_ttl_expiry(std::time::Duration::from_secs_f64(cfg.serve.ttl_expiry_secs));
    }
    let mut ledger = ShardMissLedger::new(cfg.cost.clone(), &cfg.tenants);
    let mut admit_events = 0u64;
    let mut retire_events = 0u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            ToShard::Batch(reqs) => {
                for req in &reqs {
                    b.handle(req, &mut ledger);
                }
            }
            ToShard::Get(req, reply) => {
                let served = b.handle(&req, &mut ledger);
                let _ = reply.send(GetOutcome { hit: served.hit, spurious: served.spurious });
            }
            ToShard::Collect(reply) => {
                let _ = reply.send(ShardCollect {
                    residents: b.cluster.tenant_residents(),
                    miss_runs: ledger.take_runs(),
                    tenant_stats: b.tenant_stats().to_vec(),
                });
            }
            ToShard::Prepare(now, reply) => {
                b.cluster.reset_epoch_stats();
                let _ = reply.send(b.begin_epoch_shard(now));
            }
            ToShard::Apply { now, target, allocs, reply } => {
                b.finish_epoch_shard(now, target, &allocs);
                let _ = reply.send(ShardApplied {
                    retired: b.take_retired(),
                    residents: b.cluster.tenant_residents(),
                    shed: b.last_epoch_shed().to_vec(),
                    enforcement: b.tenant_enforcement(),
                    filter_denials: b.tenant_filter_denials().to_vec(),
                });
            }
            ToShard::Admit(spec, now, reply) => {
                admit_events += 1;
                let id = spec.id;
                let weight = spec.miss_cost_multiplier;
                let out = b.admit_tenant(spec, now);
                if out.is_ok() {
                    ledger.set_weight(id, weight);
                }
                let _ = reply.send(out.map(|o| (o, b.tenant_stats_of(id))));
            }
            ToShard::Retire(tenant, now, reply) => {
                retire_events += 1;
                let _ = reply.send(b.retire_tenant(tenant, now));
            }
            ToShard::Finish(t_bill, reply) => {
                // Snapshot residents and runs *before* the final drain:
                // the front bills the final partial epoch on the
                // occupancy it actually had, exactly as the monolithic
                // engine does.
                let residents = b.cluster.tenant_residents();
                let miss_runs = ledger.take_runs();
                b.drain_retiring(t_bill);
                let _ = reply.send(ShardFinish {
                    residents,
                    miss_runs,
                    retired: b.take_retired(),
                    requests: b.requests,
                    misses: b.misses,
                    spurious_misses: b.spurious_misses,
                    work_units: b.work_units,
                });
            }
            ToShard::Resize(n) => {
                b.cluster.resize(n);
            }
            ToShard::Stats(reply) => {
                let _ = reply.send(ShardStats {
                    requests: b.requests,
                    misses: b.misses,
                    spurious_misses: b.spurious_misses,
                    denied_admissions: b.denied_admissions,
                    filter_denials: b.filter_denials,
                    work_units: b.work_units,
                    instances: b.cluster.len() as u32,
                    used_bytes: b.cluster.used(),
                    tenant_residents: b.cluster.tenant_residents(),
                    tenant_totals: b.tenant_stats().iter().map(|hm| hm.total()).collect(),
                    admit_events,
                    retire_events,
                });
            }
            ToShard::Observe(reply) => {
                let _ = reply.send(ShardObservation {
                    enforcement: b.tenant_enforcement(),
                    lifecycle: b.lifecycle(),
                    tenant_stats: b.tenant_stats().to_vec(),
                    residents: b.cluster.tenant_residents(),
                    placement: b.cluster.placement_snapshot(),
                    ttls: b.tenant_ttls(),
                    instances: b.cluster.len() as u32,
                });
            }
            ToShard::Shutdown => break,
        }
    }
}

/// The front's epoch-end decider: the one place the merged demand rows
/// become a cluster size + grants. `Fixed` pins the size statically
/// (mirroring [`crate::scaler::FixedSizer`]); the arbiter reproduces
/// the monolithic `ttl`/`tenant_ttl` decision exactly — same
/// `clamp(round(Σdemand / S_p))`, same weighted grant phases.
enum FrontDecider {
    Fixed(u32),
    Arbiter(Arbiter),
}

/// Front-side replica of one tenant's SLO window state. The per-slot
/// `SloState` lives inside each shard's controller bank, where it closes
/// on shard-local windows; the front re-runs the same arithmetic on the
/// Σ-over-shards window, so `measured_miss_ratio` and `boost` in merged
/// enforcement rows and journal records are bit-identical to the
/// monolithic engine's. Maintained whether or not telemetry is on (the
/// server's `SLO` command works without telemetry, as on the monolith).
#[derive(Debug, Clone)]
struct SloReplica {
    target: Option<f64>,
    measured: Option<f64>,
    boost: f64,
}

impl Default for SloReplica {
    fn default() -> Self {
        SloReplica::new(None)
    }
}

impl SloReplica {
    fn new(target: Option<f64>) -> SloReplica {
        SloReplica { target, measured: None, boost: 1.0 }
    }

    /// Mirror of the monolithic `SloState::close_epoch` on an explicit
    /// `(hits, misses)` window: the same integer counts and the same
    /// division give the same bits; quiet windows keep the last
    /// measurement and decay the boost.
    fn close_epoch(&mut self, hits: u64, misses: u64) {
        let total = hits + misses;
        let fresh = if total > 0 { Some(misses as f64 / total as f64) } else { None };
        if fresh.is_some() {
            self.measured = fresh;
        }
        if let Some(target) = self.target {
            match fresh {
                Some(m) if m > target => {
                    self.boost = (self.boost * SLO_BOOST_STEP).min(SLO_BOOST_MAX);
                }
                _ => {
                    self.boost = (self.boost / SLO_BOOST_STEP).max(1.0);
                }
            }
        }
    }
}

/// The front's telemetry state (`[telemetry] enabled` only): the front
/// registry (barrier + decision metrics, no `shard` label), one registry
/// per shard worker (scraped under `shard="i"` labels plus cluster-level
/// sums), the decision-journal ring, and the cursor state the monolithic
/// `JournalProbe` keeps — the barrier's record assembly mirrors it field
/// for field.
struct FrontTelemetry {
    registry: TelemetryRegistry,
    shard_registries: Vec<TelemetryRegistry>,
    journal: Journal,
    /// Grantable capacity stamped on every record
    /// (`max_instances × instance bytes`).
    capacity_bytes: u64,
    /// Zero-based index of the next epoch to record.
    epoch: u64,
    /// Cumulative denied admissions per tenant id at the previous
    /// boundary (the enforcement rows expose lifetime totals).
    prev_denied: Vec<u64>,
    /// Cumulative admission-filter denials (Σ over shards) per tenant
    /// id at the previous boundary.
    prev_filter: Vec<u64>,
    /// Bill / reconciliation rows already attributed to earlier records.
    bills_seen: usize,
    recons_seen: usize,
    /// Cumulative cluster dollars at the previous boundary.
    prev_storage: f64,
    prev_miss: f64,
    /// Shard-health handles: per-shard front-buffer depth at the barrier
    /// and flushed-batch size, the per-shard request counters feeding the
    /// imbalance gauge (max/mean), and the barrier timers.
    queue_depth: Vec<Gauge>,
    batch_occupancy: Vec<Gauge>,
    shard_requests: Vec<Counter>,
    imbalance: Gauge,
    barrier_wait: Timer,
    epoch_merge: Timer,
}

impl FrontTelemetry {
    fn new(cfg: &Config, shards: u32) -> FrontTelemetry {
        let registry = TelemetryRegistry::new();
        let shard_registries: Vec<TelemetryRegistry> =
            (0..shards).map(|_| TelemetryRegistry::new()).collect();
        let queue_depth = shard_registries
            .iter()
            .map(|r| r.gauge("elastictl_shard_queue_depth"))
            .collect();
        let batch_occupancy = shard_registries
            .iter()
            .map(|r| r.gauge("elastictl_shard_batch_occupancy"))
            .collect();
        let shard_requests = shard_registries
            .iter()
            .map(|r| r.counter("elastictl_requests_total"))
            .collect();
        let imbalance = registry.gauge("elastictl_shard_request_imbalance");
        let barrier_wait = registry.timer("elastictl_epoch_barrier_wait_ns");
        let epoch_merge = registry.timer("elastictl_epoch_merge_ns");
        FrontTelemetry {
            registry,
            shard_registries,
            journal: Journal::new(cfg.telemetry.journal_capacity as usize),
            capacity_bytes: (cfg.scaler.max_instances as u64)
                .saturating_mul(cfg.cost.instance.ram_bytes),
            epoch: 0,
            prev_denied: Vec::new(),
            prev_filter: Vec::new(),
            bills_seen: 0,
            recons_seen: 0,
            prev_storage: 0.0,
            prev_miss: 0.0,
            queue_depth,
            batch_occupancy,
            shard_requests,
            imbalance,
            barrier_wait,
            epoch_merge,
        }
    }
}

/// Cloneable per-connection handle: routes one request straight to its
/// owning shard worker, bypassing the front entirely (the server's GET
/// fast path — N connection threads feed N shard channels with no
/// global lock).
#[derive(Clone)]
pub struct ShardRouter {
    txs: Vec<Sender<ToShard>>,
    shards: u32,
}

impl ShardRouter {
    /// Serve one request on its owning shard; `None` if the engine shut
    /// down.
    pub fn get(&self, req: &Request) -> Option<GetOutcome> {
        let s = shard_of(req.tenant, req.obj, self.shards);
        let (rtx, rrx) = mpsc::channel();
        self.txs[s].send(ToShard::Get(*req, rtx)).ok()?;
        rrx.recv().ok()
    }

    /// Number of shards behind this router.
    pub fn shards(&self) -> u32 {
        self.shards
    }
}

/// The sharded request path: the same step API as [`super::Engine`]
/// (`offer` / `advance_to` / `force_epoch` / `finish`), with the hot
/// path fanned across N worker threads and the policy decision,
/// billing, and lifecycle reconciliation kept on the calling thread.
pub struct ShardedEngine {
    txs: Vec<Sender<ToShard>>,
    workers: Vec<JoinHandle<()>>,
    buffers: Vec<Vec<Request>>,
    shards: u32,
    costs: CostTracker,
    decider: FrontDecider,
    policy_name: String,
    epoch_us: TimeUs,
    epoch_end: TimeUs,
    active_instances: u32,
    auto_epochs: bool,
    processed: u64,
    clock: TimeUs,
    epochs: Vec<EpochCosts>,
    /// Every epoch decision's grant rows, in closing order — the
    /// sharded-parity tests compare these across shard counts.
    grants_log: Vec<(TimeUs, Vec<TenantAllocation>)>,
    /// Tenants drained on some-but-not-all shards: `(tenant, shards
    /// reported)`. A bill closes only when the count reaches N.
    pending_retired: Vec<(TenantId, u32)>,
    /// Front-side SLO window replicas, indexed by tenant id. Always
    /// maintained — the `SLO` surface works with telemetry off, exactly
    /// as the monolithic engine's does.
    slo: Vec<SloReplica>,
    /// Σ-over-shards cumulative hit/miss counters at the last boundary
    /// (the replicas' measurement windows difference against these).
    prev_stats: Vec<HitMiss>,
    /// Registries + decision journal (`None` unless `[telemetry]
    /// enabled`).
    obs: Option<FrontTelemetry>,
}

impl ShardedEngine {
    /// Spawn `cfg.engine.shards` workers and assemble the front. Errors
    /// for policies with no per-tenant demand representation (`mrc`,
    /// `analytic`, `ideal_ttl`) — those run with `shards = 1`.
    pub fn new(cfg: &Config) -> Result<ShardedEngine> {
        let shards = cfg.engine.shards.max(1);
        let decider = match cfg.scaler.policy {
            PolicyKind::Fixed => FrontDecider::Fixed(cfg.scaler.fixed_instances.max(1)),
            PolicyKind::Ttl | PolicyKind::TenantTtl => {
                FrontDecider::Arbiter(Arbiter::new(cfg.cost.instance.ram_bytes, &cfg.scaler))
            }
            other => anyhow::bail!(
                "policy {} cannot shard (no per-tenant demand representation); \
                 run with [engine] shards = 1",
                other.as_str()
            ),
        };
        let policy_name = build_policy(cfg).name().to_string();
        let mut costs = CostTracker::new(cfg.cost.clone());
        for spec in &cfg.tenants {
            costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
        }
        // Front SLO replicas, seeded from the config roster; stray
        // tenants grow the vector lazily with best-effort defaults,
        // matching the monolithic bank's lazy admission.
        let mut slo: Vec<SloReplica> = Vec::new();
        for spec in &cfg.tenants {
            let i = spec.id as usize;
            if slo.len() <= i {
                slo.resize_with(i + 1, SloReplica::default);
            }
            slo[i] = SloReplica::new(spec.slo_miss_ratio);
        }
        let prev_stats = vec![HitMiss::default(); slo.len()];
        let obs = cfg.telemetry.enabled.then(|| FrontTelemetry::new(cfg, shards));
        // Shard initial sizes split the monolithic initial size, so a
        // constant-target config never resizes (no slot reshuffles, no
        // spurious misses the monolith would not have had).
        let initial = split_even(cfg.initial_instances(), shards);
        let mut txs = Vec::with_capacity(shards as usize);
        let mut workers = Vec::with_capacity(shards as usize);
        for s in 0..shards {
            let (tx, rx) = mpsc::channel();
            let wcfg = cfg.clone();
            let n0 = initial[s as usize];
            let wreg = obs.as_ref().map(|o| o.shard_registries[s as usize].clone());
            let handle = std::thread::Builder::new()
                .name(format!("elastictl-shard-{s}"))
                .spawn(move || worker_loop(wcfg, n0, wreg, rx))?;
            txs.push(tx);
            workers.push(handle);
        }
        let epoch_us = cfg.cost.epoch_us.max(1);
        Ok(ShardedEngine {
            txs,
            workers,
            buffers: (0..shards).map(|_| Vec::with_capacity(BATCH)).collect(),
            shards,
            costs,
            decider,
            policy_name,
            epoch_us,
            epoch_end: epoch_us,
            active_instances: cfg.initial_instances(),
            auto_epochs: true,
            processed: 0,
            clock: 0,
            epochs: Vec::new(),
            grants_log: Vec::new(),
            pending_retired: Vec::new(),
            slo,
            prev_stats,
            obs,
        })
    }

    /// Close billing epochs only on explicit [`Self::advance_to`] /
    /// [`Self::force_epoch`] calls (the server's operator-driven
    /// cadence), mirroring `EngineBuilder::manual_epochs`.
    pub fn manual_epochs(mut self) -> Self {
        self.auto_epochs = false;
        self
    }

    /// Offer one request: route it to its shard's buffer (flushed at
    /// [`BATCH`] or at any barrier). Epoch closure follows the same
    /// automatic/manual rule as [`super::Engine::offer`].
    pub fn offer(&mut self, req: &Request) {
        if self.auto_epochs {
            self.advance_to(req.ts);
        } else {
            self.clock = self.clock.max(req.ts);
        }
        self.processed += 1;
        let s = shard_of(req.tenant, req.obj, self.shards);
        self.buffers[s].push(*req);
        if self.buffers[s].len() >= BATCH {
            self.flush_shard(s);
        }
    }

    /// Advance billing time to `ts`, closing every epoch that elapsed.
    pub fn advance_to(&mut self, ts: TimeUs) {
        self.clock = self.clock.max(ts);
        while ts >= self.epoch_end {
            let t = self.epoch_end;
            self.close_epoch_at(t);
            self.epoch_end += self.epoch_us;
        }
    }

    /// Force an epoch boundary *now* (the server's `EPOCH` command).
    /// Returns the resulting billed instance count.
    pub fn force_epoch(&mut self, now: TimeUs) -> u32 {
        self.clock = self.clock.max(now);
        let t = self.clock;
        let n = self.close_epoch_at(t);
        self.epoch_end = t + self.epoch_us;
        n
    }

    /// Admit (or update) a tenant on every shard. The shards hold
    /// identical lifecycle state, so their verdicts agree; the first
    /// error (if any) is returned and the weight is only registered on
    /// success, exactly like [`super::Engine::admit_tenant`].
    pub fn admit_tenant(&mut self, spec: TenantSpec) -> Result<AdmitOutcome> {
        self.flush_all();
        let now = self.clock;
        let replies = self.round_trip(|_, reply| ToShard::Admit(spec.clone(), now, reply));
        let mut outcome = None;
        let mut stats = HitMiss::default();
        for r in replies {
            let (o, hm) = r?;
            outcome.get_or_insert(o);
            stats.hits += hm.hits;
            stats.misses += hm.misses;
        }
        let outcome = outcome.expect("at least one shard replied");
        self.costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
        // Keep the front SLO replica in lockstep with the slots: a fresh
        // (re)admission starts a fresh window state — mid-epoch, so the
        // window baseline resets to the tenant's cumulative counters —
        // while an update only retargets it.
        self.grow_tenant_state(spec.id as usize + 1);
        let i = spec.id as usize;
        match outcome {
            AdmitOutcome::Updated => self.slo[i].target = spec.slo_miss_ratio,
            AdmitOutcome::Admitted | AdmitOutcome::Readmitted => {
                self.slo[i] = SloReplica::new(spec.slo_miss_ratio);
                self.prev_stats[i] = stats;
            }
        }
        Ok(outcome)
    }

    /// Begin retiring a tenant on every shard. Each shard drains its own
    /// slice at the following boundaries; the bill reconciles when the
    /// last shard reports the drain complete.
    pub fn retire_tenant(&mut self, tenant: TenantId) -> Result<()> {
        self.flush_all();
        let now = self.clock;
        for r in self.round_trip(|_, reply| ToShard::Retire(tenant, now, reply)) {
            r?;
        }
        Ok(())
    }

    /// Replay one trace lifecycle event (mirrors
    /// [`super::Engine::apply_event`]).
    pub fn apply_event(&mut self, ev: &TenantEvent) -> Result<()> {
        if self.auto_epochs {
            self.advance_to(ev.ts);
        } else {
            self.clock = self.clock.max(ev.ts);
        }
        match ev.kind {
            TenantEventKind::Admit { .. } => {
                let spec = ev.spec().expect("admit events carry a spec");
                self.admit_tenant(spec).map(|_| ())
            }
            TenantEventKind::Retire => self.retire_tenant(ev.tenant),
        }
    }

    /// Restore billing state from a checkpoint's closed epochs (the
    /// server's `--resume` under `--shards`): identical to
    /// [`super::Engine::restore_closed_epochs`], with the restored
    /// instance count split back across the shard clusters.
    pub fn restore_closed_epochs(
        &mut self,
        epochs: &[EpochCosts],
        bills: &[TenantEpochBill],
        reconciliations: &[TenantReconciliation],
        ledgers: &[(TenantId, TenantLedger)],
    ) {
        self.costs
            .restore_closed_epochs(epochs, bills, reconciliations, ledgers);
        self.epochs.extend_from_slice(epochs);
        if let Some(last) = epochs.last() {
            if last.instances > 0 {
                let split = split_even(last.instances, self.shards);
                for (s, tx) in self.txs.iter().enumerate() {
                    let _ = tx.send(ToShard::Resize(split[s]));
                }
                self.active_instances = last.instances;
            }
            self.clock = self.clock.max(last.t);
            self.epoch_end = last.t + self.epoch_us;
        }
    }

    /// Bill the final (partial) epoch at full price, reconcile any drain
    /// still in flight, aggregate the shard counters, and shut the
    /// workers down.
    pub fn finish(mut self) -> RunReport {
        self.flush_all();
        let t_bill = self.epoch_end.max(self.clock);
        let fins = self.round_trip(|_, reply| ToShard::Finish(t_bill, reply));
        for f in &fins {
            for &(tenant, dollars, count) in &f.miss_runs {
                self.costs.record_miss_dollars_run(tenant, dollars, count);
            }
        }
        let residents = merge_residents(fins.iter().map(|f| f.residents.as_slice()));
        let billed = self
            .costs
            .end_epoch_attributed(t_bill, self.active_instances, &residents);
        self.epochs.push(billed);
        let mut done = Vec::new();
        for f in &fins {
            for &tenant in &f.retired {
                if self.note_shard_retired(tenant) {
                    done.push(tenant);
                }
            }
        }
        for tenant in done {
            self.costs.close_tenant(tenant, t_bill);
        }
        // The final partial epoch bills but records no journal entry —
        // the monolithic engine's finish runs no decision either.
        let journal = match &self.obs {
            Some(o) => o.journal.records().cloned().collect(),
            None => Vec::new(),
        };
        let telemetry_rows = match &self.obs {
            Some(o) => telemetry::snapshot_merged(&o.registry, &o.shard_registries),
            None => Vec::new(),
        };
        let report = RunReport {
            policy: self.policy_name.clone(),
            requests: fins.iter().map(|f| f.requests).sum(),
            misses: fins.iter().map(|f| f.misses).sum(),
            spurious_misses: fins.iter().map(|f| f.spurious_misses).sum(),
            work_units: fins.iter().map(|f| f.work_units).sum(),
            epochs: std::mem::take(&mut self.epochs),
            storage_series: self.costs.storage_series.clone(),
            miss_series: self.costs.miss_series.clone(),
            total_series: self.costs.total_series.clone(),
            instances_series: self.costs.instances_series.clone(),
            ttl_series: TimeSeries::new(format!("{}_ttl_secs", self.policy_name)),
            shadow_series: TimeSeries::new(format!("{}_shadow_bytes", self.policy_name)),
            balance: BalanceTracker::new(),
            tenants: Vec::new(),
            slo: Vec::new(),
            placement: Vec::new(),
            lifecycle: Vec::new(),
            tenant_bills: self.costs.tenant_bills().to_vec(),
            reconciliations: self.costs.reconciliations().to_vec(),
            journal,
            telemetry: telemetry_rows,
            total_cost: self.costs.total(),
            storage_cost: self.costs.storage_total(),
            miss_cost: self.costs.miss_total(),
        };
        self.shutdown();
        report
    }

    // --- accessors (the server's STATS surface and the parity tests) ---

    /// Name of the policy the shards run.
    pub fn policy_name(&self) -> &str {
        &self.policy_name
    }

    /// Number of shard workers.
    pub fn shards(&self) -> u32 {
        self.shards
    }

    /// Requests offered to the front so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Instances billed for the currently open epoch.
    pub fn instances(&self) -> u32 {
        self.active_instances
    }

    /// The front cost tracker (read-only).
    pub fn costs(&self) -> &CostTracker {
        &self.costs
    }

    /// Per-epoch cost rows closed so far.
    pub fn closed_epochs(&self) -> &[EpochCosts] {
        &self.epochs
    }

    /// Latest timestamp observed.
    pub fn clock(&self) -> TimeUs {
        self.clock
    }

    /// End of the currently open billing epoch.
    pub fn epoch_end(&self) -> TimeUs {
        self.epoch_end
    }

    /// Every epoch decision's grant rows, in closing order.
    pub fn grants_log(&self) -> &[(TimeUs, Vec<TenantAllocation>)] {
        &self.grants_log
    }

    /// A cloneable GET-path handle (one per server connection thread).
    pub fn router(&self) -> ShardRouter {
        ShardRouter { txs: self.txs.clone(), shards: self.shards }
    }

    /// Snapshot every shard's counters and ledgers (flushes buffered
    /// requests first, so the numbers cover everything offered).
    pub fn shard_stats(&mut self) -> Vec<ShardStats> {
        self.flush_all();
        self.round_trip(|_, reply| ToShard::Stats(reply))
    }

    // --- the observability surface ---

    /// The live epoch decision journal (`None` unless `[telemetry]
    /// enabled`) — the server's `WHY <tenant>` reads this.
    pub fn journal(&self) -> Option<&Journal> {
        self.obs.as_ref().map(|o| &o.journal)
    }

    /// The front telemetry registry (`None` unless `[telemetry]
    /// enabled`). Serve-loop counters (epoch ticks, resumes) register
    /// here and appear unlabeled in the merged exposition.
    pub fn telemetry(&self) -> Option<&TelemetryRegistry> {
        self.obs.as_ref().map(|o| &o.registry)
    }

    /// Merged Prometheus exposition: the front registry's series
    /// verbatim, per-shard series under `shard="i"` labels plus
    /// cluster-level sums (`None` unless `[telemetry] enabled`).
    /// Refreshes the same point-in-time gauges the monolithic
    /// `Engine::metrics_text` refreshes.
    pub fn metrics_text(&self) -> Option<String> {
        let obs = self.obs.as_ref()?;
        obs.registry.gauge("elastictl_instances").set(self.active_instances as f64);
        obs.registry.gauge("elastictl_clock_us").set(self.clock as f64);
        Some(telemetry::prometheus_merged(&obs.registry, &obs.shard_registries))
    }

    /// One observability snapshot per shard, in shard order (flushes
    /// buffered requests first, so the counters and ledgers cover
    /// everything offered).
    pub fn observe(&mut self) -> Vec<ShardObservation> {
        self.flush_all();
        self.round_trip(|_, reply| ToShard::Observe(reply))
    }

    /// Merge per-shard enforcement rows into the monolithic view:
    /// per-slice quantities (demand, grant, cap, physical, admitted,
    /// denied) sum across the disjoint shards; spec-wide values (the
    /// reservation, SLO target and enforce flag every shard repeats) are
    /// taken once; the TTL clamp is the tightest in force; and
    /// `measured_miss_ratio` / `boost` come from the front's SLO window
    /// replicas — the shard-local windows each saw only a slice of the
    /// tenant's traffic.
    pub fn merge_enforcement(
        &self,
        per_shard: &[Vec<TenantEnforcement>],
    ) -> Vec<TenantEnforcement> {
        let mut merged: Vec<TenantEnforcement> = Vec::new();
        for rows in per_shard {
            for r in rows {
                match merged.iter_mut().find(|m| m.tenant == r.tenant) {
                    Some(m) => {
                        m.demand_bytes += r.demand_bytes;
                        m.granted_bytes += r.granted_bytes;
                        m.decided |= r.decided;
                        m.cap_bytes = match (m.cap_bytes, r.cap_bytes) {
                            (Some(a), Some(b)) => Some(a + b),
                            _ => None,
                        };
                        m.physical_bytes += r.physical_bytes;
                        m.admitted_epoch_bytes += r.admitted_epoch_bytes;
                        m.denied_admissions += r.denied_admissions;
                        m.ttl_clamp_secs = match (m.ttl_clamp_secs, r.ttl_clamp_secs) {
                            (Some(a), Some(b)) => Some(a.min(b)),
                            (a, b) => a.or(b),
                        };
                    }
                    None => merged.push(r.clone()),
                }
            }
        }
        for m in &mut merged {
            match self.slo.get(m.tenant as usize) {
                Some(rep) => {
                    m.measured_miss_ratio = rep.measured;
                    m.boost = rep.boost;
                }
                None => {
                    m.measured_miss_ratio = None;
                    m.boost = 1.0;
                }
            }
        }
        merged
    }

    // --- the epoch barrier ---

    /// The deterministic epoch barrier (see the module docs): collect →
    /// bill → prepare → decide → apply → reconcile, every merge in fixed
    /// shard order 0..N.
    fn close_epoch_at(&mut self, t: TimeUs) -> u32 {
        if let Some(obs) = &self.obs {
            for (s, g) in obs.queue_depth.iter().enumerate() {
                g.set(self.buffers[s].len() as f64);
            }
        }
        self.flush_all();
        // 1. Collect, and fold the miss runs in shard order — the exact
        //    per-tenant fold the monolithic engine performed.
        let collects = self.timed_round_trip(|_, reply| ToShard::Collect(reply));
        for c in &collects {
            for &(tenant, dollars, count) in &c.miss_runs {
                self.costs.record_miss_dollars_run(tenant, dollars, count);
            }
        }
        let residents = merge_residents(collects.iter().map(|c| c.residents.as_slice()));
        // Close the front SLO window replicas on the Σ-over-shards
        // hit/miss counters — the same window the monolithic controller
        // bank closes during its decide step.
        let stats = sum_tenant_stats(collects.iter().map(|c| c.tenant_stats.as_slice()));
        self.close_slo_windows(&stats);
        // 2. Bill the closing epoch at the size that was active (§2.3).
        let billed = self
            .costs
            .end_epoch_attributed(t, self.active_instances, &residents);
        self.epochs.push(billed);
        // 3. Boundary shadow maintenance + demand rows.
        let prepared = self.timed_round_trip(|_, reply| ToShard::Prepare(t, reply));
        let shard_rows: Vec<Vec<TenantDemand>> = prepared
            .into_iter()
            .map(|rows| rows.expect("sharded policies report demand rows"))
            .collect();
        // 4. One decision over the merged rows.
        let mut merge_ns = 0u64;
        let t0 = self.obs.is_some().then(Instant::now);
        let merged = merge_demands(&shard_rows);
        let (target, allocs) = match &self.decider {
            FrontDecider::Fixed(n) => (*n, Vec::new()),
            FrontDecider::Arbiter(a) => a.decide(&merged),
        };
        self.grants_log.push((t, allocs.clone()));
        // 5. Fan out: instance target split evenly, grants split
        //    proportional to each shard's share of the tenant's demand.
        let per_shard_allocs = split_allocations(&allocs, &shard_rows);
        let per_shard_targets = split_even(target.max(1), self.shards);
        if let Some(t0) = t0 {
            merge_ns += t0.elapsed().as_nanos() as u64;
        }
        let applied = self.timed_round_trip(|s, reply| ToShard::Apply {
            now: t,
            target: per_shard_targets[s],
            allocs: per_shard_allocs[s].clone(),
            reply,
        });
        // Billing bills the *decision*, not the per-shard floors: each
        // shard cluster floors at one instance, so Σ shard sizes can
        // exceed a small target — the monolithic cluster floors the same
        // decision at one instance total, and so does this.
        self.active_instances = target.max(1);
        let t0 = self.obs.is_some().then(Instant::now);
        // 6. Reconcile: a tenant's bill closes once every shard drained
        //    its slice; order follows the shards' own retirement order.
        let mut done = Vec::new();
        for a in &applied {
            for &tenant in &a.retired {
                if self.note_shard_retired(tenant) {
                    done.push(tenant);
                }
            }
        }
        for tenant in done {
            self.costs.close_tenant(tenant, t);
        }
        // 7. Journal: replay the monolithic `JournalProbe` assembly over
        //    the merged barrier state (no-op with telemetry off).
        self.record_epoch(t, &applied);
        if let (Some(t0), Some(obs)) = (t0, &self.obs) {
            merge_ns += t0.elapsed().as_nanos() as u64;
            obs.epoch_merge.record_ns(merge_ns);
        }
        self.active_instances
    }

    /// [`Self::round_trip`], recorded against the barrier-wait timer
    /// when telemetry is on — the time the front spends blocked on shard
    /// replies (three samples per boundary: collect, prepare, apply).
    fn timed_round_trip<R>(&self, make: impl Fn(usize, Sender<R>) -> ToShard) -> Vec<R> {
        match &self.obs {
            Some(obs) => obs.barrier_wait.time(|| self.round_trip(make)),
            None => self.round_trip(make),
        }
    }

    /// Ensure the per-tenant replica vectors cover tenant ids `< n`.
    fn grow_tenant_state(&mut self, n: usize) {
        if self.slo.len() < n {
            self.slo.resize_with(n, SloReplica::default);
        }
        if self.prev_stats.len() < n {
            self.prev_stats.resize(n, HitMiss::default());
        }
    }

    /// Close every tenant's SLO measurement window on the summed
    /// cumulative counters: the window is the diff against the previous
    /// boundary, bit-identical arithmetic to the monolithic
    /// `SloState::close_epoch` (including the quiet-epoch boost decay).
    fn close_slo_windows(&mut self, stats: &[HitMiss]) {
        let n = stats.len().max(self.slo.len());
        self.grow_tenant_state(n);
        for i in 0..n {
            let cum = stats.get(i).copied().unwrap_or(self.prev_stats[i]);
            let hits = cum.hits - self.prev_stats[i].hits;
            let misses = cum.misses - self.prev_stats[i].misses;
            self.prev_stats[i] = cum;
            self.slo[i].close_epoch(hits, misses);
        }
    }

    /// Replay the monolithic `JournalProbe` record assembly over the
    /// merged barrier state — bills and reconciliations sliced from the
    /// front tracker, enforcement rows merged across shards, sheds and
    /// residents summed — push the record, and refresh the decision
    /// gauges plus the shard-imbalance gauge. No-op with telemetry off.
    fn record_epoch(&mut self, t: TimeUs, applied: &[ShardApplied]) {
        if self.obs.is_none() {
            return;
        }
        let per_shard: Option<Vec<Vec<TenantEnforcement>>> =
            applied.iter().map(|a| a.enforcement.clone()).collect();
        let rows = per_shard.map(|v| self.merge_enforcement(&v)).unwrap_or_default();
        let residents = merge_residents(applied.iter().map(|a| a.residents.as_slice()));
        let shed = merge_shed(applied);
        // Σ-over-shards cumulative filter denials, indexed by tenant id
        // (the shard slices are disjoint, so the sum is the monolithic
        // lifetime total).
        let mut filter_totals: Vec<u64> = Vec::new();
        for a in applied {
            if filter_totals.len() < a.filter_denials.len() {
                filter_totals.resize(a.filter_denials.len(), 0);
            }
            for (i, &v) in a.filter_denials.iter().enumerate() {
                filter_totals[i] += v;
            }
        }
        let instances = self.active_instances;
        let costs = &self.costs;
        let Some(obs) = self.obs.as_mut() else {
            return;
        };
        // Ledger rows appended since the previous boundary belong to the
        // epoch that just closed (billing ran before this).
        let bills = &costs.tenant_bills()[obs.bills_seen..];
        obs.bills_seen = costs.tenant_bills().len();
        let recons = &costs.reconciliations()[obs.recons_seen..];
        obs.recons_seen = costs.reconciliations().len();
        let storage_dollars = costs.storage_total() - obs.prev_storage;
        let miss_dollars = costs.miss_total() - obs.prev_miss;
        obs.prev_storage = costs.storage_total();
        obs.prev_miss = costs.miss_total();
        // One row per tenant any source mentions (a draining tenant has
        // bills and sheds after its enforcement row is gone; a filter
        // denial can hit a tenant no arbiter tracks).
        let mut ids: Vec<TenantId> = rows
            .iter()
            .map(|r| r.tenant)
            .chain(bills.iter().map(|b| b.tenant))
            .chain(shed.iter().map(|&(st, _, _)| st))
            .chain(recons.iter().map(|r| r.tenant))
            .chain(filter_totals.iter().enumerate().filter_map(|(ft, &total)| {
                let prev = obs.prev_filter.get(ft).copied().unwrap_or(0);
                (total > prev).then_some(ft as TenantId)
            }))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        let mut tenants = Vec::with_capacity(ids.len());
        for id in ids {
            let row = rows.iter().find(|r| r.tenant == id);
            let resident_bytes = residents
                .iter()
                .find(|&&(rt, _)| rt == id)
                .map(|&(_, b)| b)
                .unwrap_or(0);
            let (resident_before_bytes, shed_bytes) = shed
                .iter()
                .find(|&&(st, _, _)| st == id)
                .map(|&(_, before, freed)| (before, freed))
                .unwrap_or((resident_bytes, 0));
            let denied_total = row.map(|r| r.denied_admissions).unwrap_or(0);
            let ti = id as usize;
            if obs.prev_denied.len() <= ti {
                obs.prev_denied.resize(ti + 1, 0);
            }
            let denied = denied_total.saturating_sub(obs.prev_denied[ti]);
            obs.prev_denied[ti] = denied_total;
            let filter_total = filter_totals.get(ti).copied().unwrap_or(0);
            if obs.prev_filter.len() <= ti {
                obs.prev_filter.resize(ti + 1, 0);
            }
            let filter_denials = filter_total.saturating_sub(obs.prev_filter[ti]);
            obs.prev_filter[ti] = filter_total;
            let granted = row.filter(|r| r.decided).map(|r| r.granted_bytes).unwrap_or(0);
            let reserved = row.map(|r| r.reserved_bytes).unwrap_or(0);
            tenants.push(TenantDecision {
                tenant: id,
                demand_bytes: row.map(|r| r.demand_bytes).unwrap_or(0),
                granted_bytes: granted,
                reserved_bytes: reserved,
                pooled_bytes: granted.saturating_sub(reserved),
                cap_bytes: row.and_then(|r| r.cap_bytes),
                ttl_clamp_secs: row.and_then(|r| r.ttl_clamp_secs),
                resident_before_bytes,
                resident_bytes,
                shed_bytes,
                denied_admissions: denied,
                filter_denials,
                slo_miss_ratio: row.and_then(|r| r.slo_miss_ratio),
                measured_miss_ratio: row.and_then(|r| r.measured_miss_ratio),
                boost: row.map(|r| r.boost).unwrap_or(1.0),
                bill_storage_dollars: bills
                    .iter()
                    .filter(|b| b.tenant == id)
                    .map(|b| b.storage)
                    .sum(),
                bill_miss_dollars: bills.iter().filter(|b| b.tenant == id).map(|b| b.miss).sum(),
                reconciled_dollars: recons
                    .iter()
                    .find(|r| r.tenant == id)
                    .map(|r| r.total_dollars),
            });
        }
        // Refresh exposition gauges from the decision now in force, as
        // the monolithic probe does.
        obs.registry.gauge("elastictl_instances").set(instances as f64);
        obs.registry.gauge("elastictl_epochs_closed").set((obs.epoch + 1) as f64);
        for d in &tenants {
            obs.registry
                .tenant_gauge("elastictl_tenant_granted_bytes", d.tenant)
                .set(d.granted_bytes as f64);
            obs.registry
                .tenant_gauge("elastictl_tenant_resident_bytes", d.tenant)
                .set(d.resident_bytes as f64);
            obs.registry.tenant_gauge("elastictl_tenant_boost", d.tenant).set(d.boost);
        }
        obs.journal.push(EpochDecisionRecord {
            t,
            epoch: obs.epoch,
            instances,
            capacity_bytes: obs.capacity_bytes,
            storage_dollars,
            miss_dollars,
            tenants,
        });
        obs.epoch += 1;
        // Shard health: request-count imbalance across workers, read off
        // the per-shard counter handles (max/mean; 1.0 = perfectly even).
        let reqs: Vec<u64> = obs.shard_requests.iter().map(|c| c.get()).collect();
        let max = reqs.iter().copied().max().unwrap_or(0) as f64;
        let mean = reqs.iter().sum::<u64>() as f64 / reqs.len().max(1) as f64;
        obs.imbalance.set(if mean > 0.0 { max / mean } else { 1.0 });
    }

    /// Count one shard's completed drain of `tenant`; `true` once every
    /// shard has reported (the bill may close).
    fn note_shard_retired(&mut self, tenant: TenantId) -> bool {
        match self.pending_retired.iter_mut().find(|(t, _)| *t == tenant) {
            Some((_, count)) => {
                *count += 1;
                if *count == self.shards {
                    self.pending_retired.retain(|(t, _)| *t != tenant);
                    true
                } else {
                    false
                }
            }
            None => {
                if self.shards == 1 {
                    true
                } else {
                    self.pending_retired.push((tenant, 1));
                    false
                }
            }
        }
    }

    /// Send one buffered batch to shard `s`.
    fn flush_shard(&mut self, s: usize) {
        if self.buffers[s].is_empty() {
            return;
        }
        if let Some(obs) = &self.obs {
            obs.batch_occupancy[s].set(self.buffers[s].len() as f64);
        }
        let batch = std::mem::replace(&mut self.buffers[s], Vec::with_capacity(BATCH));
        let _ = self.txs[s].send(ToShard::Batch(batch));
    }

    /// Flush every shard's buffer (before any barrier or round-trip, so
    /// channel FIFO order serializes requests before the control
    /// message).
    fn flush_all(&mut self) {
        for s in 0..self.buffers.len() {
            self.flush_shard(s);
        }
    }

    /// One request-reply round to every shard: sends fan out first (the
    /// workers run concurrently), then replies collect in shard order.
    fn round_trip<R>(&self, make: impl Fn(usize, Sender<R>) -> ToShard) -> Vec<R> {
        let mut rxs = Vec::with_capacity(self.txs.len());
        for (s, tx) in self.txs.iter().enumerate() {
            let (rtx, rrx) = mpsc::channel();
            tx.send(make(s, rtx)).expect("shard worker is alive");
            rxs.push(rrx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().expect("shard worker replies"))
            .collect()
    }

    /// Stop the workers and join. An explicit shutdown message (not just
    /// dropping the senders) so live [`ShardRouter`] clones on server
    /// connection threads cannot keep a worker's receive loop alive;
    /// their sends fail cleanly afterwards.
    fn shutdown(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(ToShard::Shutdown);
        }
        self.txs.clear();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Split `total` instances across `shards` as evenly as possible,
/// earlier shards taking the remainder: `Σ = total`, deterministic.
pub fn split_even(total: u32, shards: u32) -> Vec<u32> {
    let shards = shards.max(1);
    let base = total / shards;
    let rem = total % shards;
    (0..shards).map(|s| base + u32::from(s < rem)).collect()
}

/// Split `total` bytes proportionally to `weights` (u128 floor
/// arithmetic, remainder bytes to ascending indices; equal split when
/// every weight is zero). `Σ = total`, deterministic.
pub fn split_proportional(total: u64, weights: &[u64]) -> Vec<u64> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let sum: u128 = weights.iter().map(|&w| w as u128).sum();
    if sum == 0 {
        let base = total / n as u64;
        let rem = (total % n as u64) as usize;
        return (0..n).map(|i| base + u64::from(i < rem)).collect();
    }
    let mut out: Vec<u64> = weights
        .iter()
        .map(|&w| ((total as u128 * w as u128) / sum) as u64)
        .collect();
    let mut rem = total - out.iter().sum::<u64>();
    let mut i = 0;
    while rem > 0 {
        out[i] += 1;
        rem -= 1;
        i = (i + 1) % n;
    }
    out
}

/// Merge per-shard demand rows into the front's arbiter input: demand
/// bytes sum (each shard's shadow cache holds a disjoint slice of the
/// tenant's key space), the reservation and weight are taken *once* from
/// the first shard seen (every shard reports the tenant's full spec
/// values — summing would multiply them by N). Row order is first-seen
/// scanning shards 0..N, which equals every shard's identical
/// registration order — and therefore the monolithic bank's.
fn merge_demands(shard_rows: &[Vec<TenantDemand>]) -> Vec<TenantDemand> {
    let mut merged: Vec<TenantDemand> = Vec::new();
    for rows in shard_rows {
        for d in rows {
            match merged.iter_mut().find(|m| m.tenant == d.tenant) {
                Some(m) => m.demand_bytes += d.demand_bytes,
                None => merged.push(*d),
            }
        }
    }
    merged
}

/// Sum per-shard cumulative hit/miss counter vectors element-wise
/// (tenant-id indexed; shards partition each tenant's key space, so the
/// sums are the monolithic counters exactly).
pub fn sum_tenant_stats<'a>(shards: impl Iterator<Item = &'a [HitMiss]>) -> Vec<HitMiss> {
    let mut out: Vec<HitMiss> = Vec::new();
    for rows in shards {
        if out.len() < rows.len() {
            out.resize(rows.len(), HitMiss::default());
        }
        for (i, hm) in rows.iter().enumerate() {
            out[i].hits += hm.hits;
            out[i].misses += hm.misses;
        }
    }
    out
}

/// Merge per-shard shed reports `(tenant, resident_before, freed)`:
/// shards hold disjoint slices of each tenant's residency, so both the
/// before-bytes and the freed-bytes sum exactly.
fn merge_shed(applied: &[ShardApplied]) -> Vec<(TenantId, u64, u64)> {
    let mut merged: Vec<(TenantId, u64, u64)> = Vec::new();
    for a in applied {
        for &(tenant, before, freed) in &a.shed {
            match merged.iter_mut().find(|(mt, _, _)| *mt == tenant) {
                Some(m) => {
                    m.1 += before;
                    m.2 += freed;
                }
                None => merged.push((tenant, before, freed)),
            }
        }
    }
    merged
}

/// Merge per-shard resident-byte ledgers (disjoint instance slices, so
/// the per-tenant sums are exact u64 arithmetic), id ascending.
fn merge_residents<'a>(
    shards: impl Iterator<Item = &'a [(TenantId, u64)]>,
) -> Vec<(TenantId, u64)> {
    let mut merged: std::collections::BTreeMap<TenantId, u64> = std::collections::BTreeMap::new();
    for rows in shards {
        for &(tenant, bytes) in rows {
            *merged.entry(tenant).or_insert(0) += bytes;
        }
    }
    merged.into_iter().collect()
}

/// Split the front's grant rows back into per-shard allocation lists:
/// each shard holding a demand row for the tenant receives its
/// proportional share of the granted (and reserved) bytes, against its
/// own local demand. Shards without a row receive nothing — applying a
/// grant there would lazily create controller state the monolith never
/// had.
fn split_allocations(
    allocs: &[TenantAllocation],
    shard_rows: &[Vec<TenantDemand>],
) -> Vec<Vec<TenantAllocation>> {
    let n = shard_rows.len();
    let mut out: Vec<Vec<TenantAllocation>> = (0..n).map(|_| Vec::new()).collect();
    for a in allocs {
        let holders: Vec<(usize, &TenantDemand)> = shard_rows
            .iter()
            .enumerate()
            .filter_map(|(s, rows)| {
                rows.iter().find(|d| d.tenant == a.tenant).map(|d| (s, d))
            })
            .collect();
        if holders.is_empty() {
            continue;
        }
        let demands: Vec<u64> = holders.iter().map(|&(_, d)| d.demand_bytes).collect();
        let grants = split_proportional(a.granted_bytes, &demands);
        let reserves = split_proportional(a.reserved_bytes, &demands);
        for (i, &(s, d)) in holders.iter().enumerate() {
            out[s].push(TenantAllocation {
                tenant: a.tenant,
                demand_bytes: d.demand_bytes,
                reserved_bytes: reserves[i],
                granted_bytes: grants[i],
                weight: a.weight,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{MINUTE, SECOND};

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        for shards in [1u32, 2, 3, 4, 8] {
            for tenant in [0u16, 1, 7] {
                for obj in 0u64..200 {
                    let s = shard_of(tenant, obj, shards);
                    assert!(s < shards as usize);
                    assert_eq!(s, shard_of(tenant, obj, shards), "routing must be stable");
                }
            }
        }
    }

    #[test]
    fn split_even_preserves_totals() {
        assert_eq!(split_even(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(split_even(1, 4), vec![1, 0, 0, 0]);
        assert_eq!(split_even(0, 3), vec![0, 0, 0]);
        for total in 0u32..40 {
            for shards in 1u32..9 {
                let split = split_even(total, shards);
                assert_eq!(split.iter().sum::<u32>(), total);
            }
        }
    }

    #[test]
    fn split_proportional_preserves_totals() {
        assert_eq!(split_proportional(100, &[1, 1]), vec![50, 50]);
        assert_eq!(split_proportional(100, &[0, 0]), vec![50, 50]);
        assert_eq!(split_proportional(7, &[]), Vec::<u64>::new());
        for total in [0u64, 1, 7, 100, 1_000_003] {
            for weights in [&[1u64, 2, 3][..], &[0, 0, 5], &[10], &[0, 0, 0, 0]] {
                let split = split_proportional(total, weights);
                assert_eq!(split.iter().sum::<u64>(), total, "weights {weights:?}");
            }
        }
    }

    #[test]
    fn merge_demands_sums_demand_and_takes_reservation_once() {
        let shard0 = vec![
            TenantDemand::new(1, 100, 2.0).with_reserved(512),
            TenantDemand::new(2, 10, 1.0),
        ];
        let shard1 = vec![
            TenantDemand::new(1, 40, 2.0).with_reserved(512),
            TenantDemand::new(3, 5, 0.5),
        ];
        let merged = merge_demands(&[shard0, shard1]);
        assert_eq!(merged.len(), 3);
        assert_eq!(merged[0].tenant, 1);
        assert_eq!(merged[0].demand_bytes, 140, "demand sums across shards");
        assert_eq!(merged[0].reserved_bytes, 512, "reservation taken once, not summed");
        assert_eq!(merged[1].tenant, 2);
        assert_eq!(merged[2].tenant, 3);
    }

    #[test]
    fn split_allocations_skips_shards_without_a_demand_row() {
        let allocs = vec![TenantAllocation {
            tenant: 1,
            demand_bytes: 150,
            reserved_bytes: 0,
            granted_bytes: 150,
            weight: 1.0,
        }];
        let shard_rows = vec![
            vec![TenantDemand::new(1, 100, 1.0)],
            Vec::new(),
            vec![TenantDemand::new(1, 50, 1.0)],
        ];
        let split = split_allocations(&allocs, &shard_rows);
        assert_eq!(split[0].len(), 1);
        assert!(split[1].is_empty(), "no demand row, no grant");
        assert_eq!(split[2].len(), 1);
        assert_eq!(split[0][0].granted_bytes + split[2][0].granted_bytes, 150);
        assert_eq!(split[0][0].granted_bytes, 100, "proportional to local demand");
    }

    #[test]
    fn sharded_engine_rejects_unshardable_policies() {
        for kind in [PolicyKind::Mrc, PolicyKind::Analytic, PolicyKind::IdealTtl] {
            let mut cfg = Config::with_policy(kind);
            cfg.engine.shards = 2;
            assert!(ShardedEngine::new(&cfg).is_err(), "{} must not shard", kind.as_str());
        }
    }

    #[test]
    fn sharded_engine_smoke_run_counts_and_bills() {
        let mut cfg = Config::with_policy(PolicyKind::Fixed);
        cfg.engine.shards = 3;
        cfg.scaler.fixed_instances = 4;
        cfg.cost.epoch_us = MINUTE;
        let mut eng = ShardedEngine::new(&cfg).expect("fixed shards");
        for i in 0..2_000u64 {
            eng.offer(&Request::new(i * (MINUTE / 400), i % 97, 1_000));
        }
        let report = eng.finish();
        assert_eq!(report.policy, "fixed");
        assert_eq!(report.requests, 2_000);
        assert!(report.misses >= 97, "every cold object misses at least once");
        assert!(report.epochs.len() >= 5, "five minutes of trace close five epochs");
        assert!(report.total_cost > 0.0);
        for e in &report.epochs {
            assert_eq!(e.instances, 4, "fixed target bills four instances");
        }
    }

    #[test]
    fn sum_tenant_stats_sums_elementwise_over_ragged_shards() {
        let shard0 = vec![HitMiss { hits: 3, misses: 1 }];
        let shard1 = vec![HitMiss { hits: 2, misses: 2 }, HitMiss { hits: 0, misses: 5 }];
        let sum = sum_tenant_stats([shard0.as_slice(), shard1.as_slice()].into_iter());
        assert_eq!(sum.len(), 2);
        assert_eq!((sum[0].hits, sum[0].misses), (5, 3));
        assert_eq!((sum[1].hits, sum[1].misses), (0, 5));
    }

    #[test]
    fn merge_shed_sums_disjoint_slices() {
        let applied = vec![
            ShardApplied {
                retired: Vec::new(),
                residents: Vec::new(),
                shed: vec![(1, 100, 40), (2, 10, 10)],
                enforcement: None,
            },
            ShardApplied {
                retired: Vec::new(),
                residents: Vec::new(),
                shed: vec![(1, 60, 20)],
                enforcement: None,
            },
        ];
        let merged = merge_shed(&applied);
        assert_eq!(merged, vec![(1, 160, 60), (2, 10, 10)]);
    }

    #[test]
    fn slo_replica_tracks_the_boost_ladder() {
        let mut rep = SloReplica::new(Some(0.25));
        rep.close_epoch(1, 3); // miss ratio 0.75 > target: boost doubles
        assert_eq!(rep.measured, Some(0.75));
        assert_eq!(rep.boost, SLO_BOOST_STEP);
        rep.close_epoch(0, 0); // quiet window: measurement kept, boost decays
        assert_eq!(rep.measured, Some(0.75));
        assert_eq!(rep.boost, 1.0);
        for _ in 0..32 {
            rep.close_epoch(0, 1);
        }
        assert_eq!(rep.boost, SLO_BOOST_MAX, "boost saturates at the cap");
        rep.close_epoch(3, 1); // 0.25 is not > target: decay
        assert_eq!(rep.boost, SLO_BOOST_MAX / SLO_BOOST_STEP);
        let mut untargeted = SloReplica::new(None);
        untargeted.close_epoch(0, 10);
        assert_eq!(untargeted.measured, Some(1.0));
        assert_eq!(untargeted.boost, 1.0, "no target, no boost movement");
    }

    #[test]
    fn sharded_get_path_serves_via_router() {
        let mut cfg = Config::with_policy(PolicyKind::Ttl);
        cfg.engine.shards = 2;
        cfg.cost.epoch_us = MINUTE;
        let mut eng = ShardedEngine::new(&cfg).expect("ttl shards");
        let router = eng.router();
        let first = router.get(&Request::new(SECOND, 42, 100)).expect("worker alive");
        assert!(!first.hit, "cold object misses");
        let second = router.get(&Request::new(2 * SECOND, 42, 100)).expect("worker alive");
        assert!(second.hit, "warm object hits its owning shard");
        let stats = eng.shard_stats();
        assert_eq!(stats.iter().map(|s| s.requests).sum::<u64>(), 2);
        drop(eng); // joins the workers without a finish
    }
}
