//! The streaming execution engine — ONE request path for every way a
//! policy meets a trace.
//!
//! The paper's central claim is an O(1)-per-request provisioning scheme
//! that runs the *same* logic in a simulator and in an mcrouter-like
//! production front (§5.2, §6.1). This module is that shared path: an
//! [`EngineBuilder`] (config + policy + probes) produces an [`Engine`]
//! with a step API —
//!
//! * [`Engine::offer`] — feed one request, get its [`Outcome`];
//! * [`Engine::advance_to`] — close any billing epochs that elapsed;
//! * [`Engine::finish`] — bill the final partial epoch and collect the
//!   [`RunReport`].
//!
//! The discrete-event simulator ([`crate::sim`]), the TCP server
//! ([`crate::serve`]), the analytic runtime driver and the ideal-TTL
//! reference all drive this engine instead of hand-rolling their own
//! epoch loops. Policies come from the uniform registry
//! ([`build_policy`]; every [`crate::config::PolicyKind`] is first-class
//! — the old dispatch panicked on `analytic`); series sampling, Fig. 9 balance
//! tracking and per-tenant summaries are composable [`Probe`]s. Because
//! the engine pulls nothing, any [`crate::trace::RequestSource`] can
//! drive it — including the streaming file readers
//! ([`crate::trace::FileSource`]), so a million-user trace never has to
//! materialize as a `Vec<Request>`.

#![warn(missing_docs)]

mod policy;
mod probe;
mod shard;

pub use policy::{build_policy, build_sizer, EnginePolicy, VerticalTtl};
pub use shard::{
    shard_of, split_even, split_proportional, sum_tenant_stats, GetOutcome, ShardObservation,
    ShardRouter, ShardStats, ShardedEngine,
};
pub use probe::{
    BalanceProbe, JournalProbe, LifecycleProbe, LifecycleSample, PlacementProbe,
    PlacementSample, Probe, ProbeCtx, ShadowProbe, SloProbe, SloSample, TenantProbe, TtlProbe,
};

use crate::balancer::Balancer;
use crate::cluster::BalanceTracker;
use crate::config::Config;
use crate::cost::{CostTracker, EpochCosts, TenantEpochBill, TenantLedger, TenantReconciliation};
use crate::metrics::{HitMiss, TimeSeries};
use crate::placement::PlacementSnapshot;
use crate::scaler::EpochSizer;
use crate::telemetry::{
    EpochDecisionRecord, Journal, SharedJournal, SharedRegistry, TelemetryRegistry, Timer,
};
use crate::tenant::{AdmitOutcome, Lifecycle, TenantEnforcement, TenantSpec};
use crate::trace::{Request, RequestSource, TenantEvent, TenantEventKind, TraceItem};
use crate::{Result, TenantId, TimeUs};

/// How often the default ttl/shadow probes sample their series.
pub const SAMPLE_EVERY: u64 = 4096;

/// Outcome of offering one request to the engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    /// The request hit (physically, or virtually for the vertical mode,
    /// where virtual hits are real hits).
    pub hit: bool,
    /// The miss was *spurious*: the object is resident on some instance,
    /// but slot reassignment routed the request elsewhere (§5.2).
    pub spurious: bool,
    /// Policy work units performed (Fig. 1 proxy).
    pub work_units: u32,
}

/// Per-tenant slice of a run: who asked for what, who missed, what it
/// cost, and where that tenant's timer converged.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSummary {
    /// The tenant this row describes.
    pub tenant: TenantId,
    /// Requests the tenant sent.
    pub requests: u64,
    /// Physical misses among them.
    pub misses: u64,
    /// Weighted miss dollars attributed to this tenant.
    pub miss_dollars: f64,
    /// Final per-tenant TTL, when the policy ran one controller per
    /// tenant.
    pub ttl_secs: Option<f64>,
}

/// Result of one policy run over a request stream.
#[derive(Debug)]
pub struct RunReport {
    /// Name of the policy that ran.
    pub policy: String,
    /// Requests offered.
    pub requests: u64,
    /// Physical misses (spurious included).
    pub misses: u64,
    /// §5.2 spurious misses (resident elsewhere, routed astray).
    pub spurious_misses: u64,
    /// Cumulative policy work units (Fig. 1 proxy).
    pub work_units: u64,
    /// Per-epoch cost rows, in closing order.
    pub epochs: Vec<EpochCosts>,
    /// Cumulative storage dollars sampled at epoch boundaries.
    pub storage_series: TimeSeries,
    /// Cumulative miss dollars sampled at epoch boundaries.
    pub miss_series: TimeSeries,
    /// Cumulative total dollars sampled at epoch boundaries.
    pub total_series: TimeSeries,
    /// Instances active per epoch.
    pub instances_series: TimeSeries,
    /// TTL (s) sampled periodically (TTL-family policies).
    pub ttl_series: TimeSeries,
    /// Virtual/shadow size (bytes) sampled periodically.
    pub shadow_series: TimeSeries,
    /// Fig. 9 balance tracker.
    pub balance: BalanceTracker,
    /// Per-tenant breakdown (one row per tenant that sent traffic).
    pub tenants: Vec<TenantSummary>,
    /// Per-epoch per-tenant SLO/enforcement record (miss ratio vs target,
    /// grants, caps, clamps, boosts) — see [`SloProbe`].
    pub slo: Vec<SloSample>,
    /// Per-epoch per-tenant physical resident bytes (post-boundary
    /// placement maintenance) — see [`PlacementProbe`].
    pub placement: Vec<PlacementSample>,
    /// Tenant lifecycle transitions observed during the run (admissions,
    /// drain starts, retirements with their reconciled bills) — see
    /// [`LifecycleProbe`].
    pub lifecycle: Vec<LifecycleSample>,
    /// Every per-tenant epoch bill in accumulation order; folding these
    /// reproduces the run totals bit-for-bit
    /// ([`crate::cost::CostTracker::tenant_bills`]).
    pub tenant_bills: Vec<TenantEpochBill>,
    /// Closed bills of tenants retired during the run.
    pub reconciliations: Vec<TenantReconciliation>,
    /// The retained epoch decision journal (one record per closed epoch,
    /// newest `[telemetry] journal_capacity` kept) — empty unless
    /// `[telemetry] enabled`. See [`JournalProbe`].
    pub journal: Vec<EpochDecisionRecord>,
    /// Final flat registry snapshot (`(metric, value)` rows, tenant
    /// labels folded into names) — empty unless `[telemetry] enabled`.
    pub telemetry: Vec<(String, f64)>,
    /// Total run cost, dollars (storage + weighted misses).
    pub total_cost: f64,
    /// Storage slice of [`RunReport::total_cost`].
    pub storage_cost: f64,
    /// Miss slice of [`RunReport::total_cost`].
    pub miss_cost: f64,
}

impl RunReport {
    /// Overall miss ratio of the run (0 for an empty run).
    pub fn miss_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.misses as f64 / self.requests as f64
        }
    }

    /// One summary row for tables: name, requests, miss%, storage, miss$,
    /// total$.
    pub fn summary_row(&self) -> Vec<String> {
        vec![
            self.policy.clone(),
            self.requests.to_string(),
            format!("{:.4}", self.miss_ratio()),
            format!("{:.4}", self.storage_cost),
            format!("{:.4}", self.miss_cost),
            format!("{:.4}", self.total_cost),
        ]
    }
}

/// The two billing shapes a policy runs under.
pub(crate) enum Core {
    /// Horizontally scaled cluster behind the balancer, epoch-billed.
    Cluster(Balancer),
    /// The ideal vertically scaled TTL cache (§6.1 reference): billed on
    /// instantaneous occupancy; no instances, no spurious misses.
    Vertical {
        policy: VerticalTtl,
        requests: u64,
        misses: u64,
        work_units: u64,
    },
}

impl Core {
    /// Current policy TTL — the one dispatch shared by [`Engine`] and
    /// [`ProbeCtx`], so STATS and probe samples cannot diverge.
    pub(crate) fn ttl_secs(&self) -> Option<f64> {
        match self {
            Core::Cluster(b) => b.ttl_secs(),
            Core::Vertical { policy, .. } => policy.ttl_secs(),
        }
    }

    /// Current virtual/shadow size in bytes.
    pub(crate) fn shadow_size(&self) -> Option<u64> {
        match self {
            Core::Cluster(b) => b.shadow_size(),
            Core::Vertical { policy, .. } => policy.shadow_size(),
        }
    }
}

/// Builder: config + policy + probes → [`Engine`].
pub struct EngineBuilder {
    cfg: Config,
    policy: Option<EnginePolicy>,
    initial_instances: Option<u32>,
    probes: Vec<Box<dyn Probe>>,
    default_probes: bool,
    auto_epochs: bool,
}

impl EngineBuilder {
    /// Start a builder from `cfg` (policy, probes and initial size can
    /// be overridden before [`EngineBuilder::build`]).
    pub fn new(cfg: &Config) -> Self {
        EngineBuilder {
            cfg: cfg.clone(),
            policy: None,
            initial_instances: None,
            probes: Vec::new(),
            default_probes: true,
            auto_epochs: true,
        }
    }

    /// Override the policy (default: the registry's build for
    /// `cfg.scaler.policy`).
    pub fn policy(mut self, policy: EnginePolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Run a caller-constructed horizontal sizer.
    pub fn sizer(mut self, sizer: Box<dyn EpochSizer>) -> Self {
        self.policy = Some(EnginePolicy::Horizontal(sizer));
        self
    }

    /// Override the pre-first-epoch cluster size (default:
    /// [`Config::initial_instances`]).
    pub fn initial_instances(mut self, n: u32) -> Self {
        self.initial_instances = Some(n);
        self
    }

    /// Attach an extra observer.
    pub fn probe(mut self, probe: Box<dyn Probe>) -> Self {
        self.probes.push(probe);
        self
    }

    /// Drop the default ttl/shadow/balance/tenant probes (bare request
    /// path — what the server and throughput benches want).
    pub fn no_default_probes(mut self) -> Self {
        self.default_probes = false;
        self
    }

    /// Close billing epochs only on explicit [`Engine::advance_to`] /
    /// [`Engine::force_epoch`] calls, never implicitly from request
    /// timestamps. Trace replay wants automatic closure (epochs elapse
    /// with trace time); the TCP server wants this manual mode so the
    /// operator's `EPOCH` command keeps full control of the resize
    /// cadence — a GET after an idle hour must not silently bill and
    /// shrink the cluster. Vertical occupancy still accrues with time.
    pub fn manual_epochs(mut self) -> Self {
        self.auto_epochs = false;
        self
    }

    /// Assemble the [`Engine`].
    pub fn build(self) -> Engine {
        let cfg = self.cfg;
        let policy = self.policy.unwrap_or_else(|| build_policy(&cfg));
        let mut costs = CostTracker::new(cfg.cost.clone());
        for spec in &cfg.tenants {
            costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
        }
        let mut probes = self.probes;
        // Telemetry is opt-in: with `[telemetry] enabled` unset, no
        // registry, journal or probe exists and the request path is the
        // untelemetered one (pinned bit-for-bit by `engine_parity`).
        let (registry, journal) = if cfg.telemetry.enabled {
            let registry: SharedRegistry =
                std::rc::Rc::new(std::cell::RefCell::new(TelemetryRegistry::new()));
            let journal: SharedJournal = std::rc::Rc::new(std::cell::RefCell::new(
                Journal::new(cfg.telemetry.journal_capacity as usize),
            ));
            (Some(registry), Some(journal))
        } else {
            (None, None)
        };
        let (mut core, policy_name) = match policy {
            EnginePolicy::Horizontal(sizer) => {
                let name = sizer.name().to_string();
                let initial = self
                    .initial_instances
                    .unwrap_or_else(|| cfg.initial_instances());
                let mut balancer = Balancer::from_config(&cfg, sizer, initial);
                if cfg.serve.ttl_expiry_secs > 0.0 {
                    // Server runtime: real wall-clock TTL expiry on the
                    // resident stores (`[serve] ttl_expiry_secs`). Off by
                    // default — trace replay and the parity-pinned server
                    // never arm it.
                    balancer.cluster.enable_ttl_expiry(std::time::Duration::from_secs_f64(
                        cfg.serve.ttl_expiry_secs,
                    ));
                }
                if self.default_probes {
                    probes.push(Box::new(TtlProbe::sampled(&name)));
                    probes.push(Box::new(ShadowProbe::sampled(&name, "shadow_bytes")));
                    probes.push(Box::new(BalanceProbe::new()));
                    probes.push(Box::new(TenantProbe::new()));
                    probes.push(Box::new(SloProbe::new()));
                    probes.push(Box::new(PlacementProbe::new()));
                    probes.push(Box::new(LifecycleProbe::new()));
                }
                (Core::Cluster(balancer), name)
            }
            EnginePolicy::Vertical(v) => {
                let name = v.name().to_string();
                if self.default_probes {
                    probes.push(Box::new(TtlProbe::sampled(&name)));
                    probes.push(Box::new(ShadowProbe::sampled(&name, "vsize_bytes")));
                }
                (
                    Core::Vertical { policy: v, requests: 0, misses: 0, work_units: 0 },
                    name,
                )
            }
        };
        let mut billing_timer = None;
        if let (Some(registry), Some(journal)) = (&registry, &journal) {
            if let Core::Cluster(b) = &mut core {
                b.attach_telemetry(&mut registry.borrow_mut());
            }
            billing_timer = Some(registry.borrow_mut().timer("elastictl_epoch_billing_ns"));
            // The arbiter's grantable capacity — Σ granted per record
            // must never exceed it (`scripts/journal_check.py`).
            let capacity_bytes =
                (cfg.scaler.max_instances as u64).saturating_mul(cfg.cost.instance.ram_bytes);
            probes.push(Box::new(JournalProbe::new(
                journal.clone(),
                registry.clone(),
                capacity_bytes,
            )));
        }
        let active_instances = match &core {
            Core::Cluster(b) => b.cluster.len() as u32,
            Core::Vertical { .. } => 0,
        };
        let epoch_us = cfg.cost.epoch_us.max(1);
        Engine {
            core,
            costs,
            probes,
            policy_name,
            epoch_us,
            epoch_end: epoch_us,
            active_instances,
            per_byte_sec: cfg.cost.storage_cost_per_byte_sec(),
            auto_epochs: self.auto_epochs,
            processed: 0,
            clock: 0,
            epochs: Vec::new(),
            telemetry: registry,
            journal,
            billing_timer,
        }
    }
}

/// The unified request path: offer requests, advance billing time, finish
/// into a report.
pub struct Engine {
    core: Core,
    costs: CostTracker,
    probes: Vec<Box<dyn Probe>>,
    policy_name: String,
    epoch_us: TimeUs,
    /// End of the currently open billing epoch.
    epoch_end: TimeUs,
    /// Instances billed for the currently open epoch (0 = vertical).
    active_instances: u32,
    /// $/byte/s for the vertical occupancy bill.
    per_byte_sec: f64,
    /// Whether `offer` closes elapsed epochs implicitly (trace replay)
    /// or leaves closure to explicit `advance_to`/`force_epoch` calls
    /// (the server's operator-driven cadence).
    auto_epochs: bool,
    /// Requests offered so far.
    processed: u64,
    /// Latest timestamp observed (request or explicit advance).
    clock: TimeUs,
    epochs: Vec<EpochCosts>,
    /// The live registry, when `[telemetry] enabled` (shared with the
    /// balancer's pre-resolved handles and the journal probe).
    telemetry: Option<SharedRegistry>,
    /// The live decision journal, when `[telemetry] enabled`.
    journal: Option<SharedJournal>,
    /// Epoch-billing stage timer (`elastictl_epoch_billing_ns`).
    billing_timer: Option<Timer>,
}

impl Engine {
    /// Offer one request: close any elapsed epochs (automatic mode), run
    /// the policy shadow work, serve, account, notify probes.
    pub fn offer(&mut self, req: &Request) -> Outcome {
        if self.auto_epochs {
            self.advance_to(req.ts);
        } else {
            // Manual mode: time (and vertical occupancy dollars) still
            // advance, but epoch closure waits for an explicit call.
            self.accrue(req.ts);
        }
        self.processed += 1;
        let outcome = match &mut self.core {
            Core::Cluster(b) => {
                let served = b.handle(req, &mut self.costs);
                Outcome {
                    hit: served.hit,
                    spurious: served.spurious,
                    work_units: served.work_units,
                }
            }
            Core::Vertical { policy, requests, misses, work_units } => {
                let work = policy.on_request(req);
                let hit = work.shadow_hit.unwrap_or(false);
                *requests += 1;
                *work_units += work.units as u64;
                if !hit {
                    *misses += 1;
                    self.costs.record_miss_for(req.tenant, req.size_bytes());
                }
                Outcome { hit, spurious: false, work_units: work.units }
            }
        };
        let ctx = ProbeCtx {
            core: &self.core,
            costs: &self.costs,
            processed: self.processed,
            instances: self.active_instances,
        };
        for p in &mut self.probes {
            p.on_request(req, &outcome, &ctx);
        }
        outcome
    }

    /// Advance billing time to `ts`, closing every epoch that elapsed.
    /// Idempotent for `ts` at or before the current clock.
    pub fn advance_to(&mut self, ts: TimeUs) {
        self.accrue(ts);
        while ts >= self.epoch_end {
            let t = self.epoch_end;
            self.close_epoch_at(t);
            self.epoch_end += self.epoch_us;
        }
    }

    /// Force an epoch boundary *now* (the server's `EPOCH` command): bill
    /// the open epoch, apply the policy's sizing decision, restart the
    /// epoch clock from `now`. Returns the resulting instance count (the
    /// equivalent count for the vertical mode).
    pub fn force_epoch(&mut self, now: TimeUs) -> u32 {
        self.accrue(now);
        let t = self.clock;
        let n = self.close_epoch_at(t);
        self.epoch_end = t + self.epoch_us;
        match &mut self.core {
            Core::Cluster(_) => n,
            Core::Vertical { policy, .. } => policy.decide(t),
        }
    }

    /// Restore billing state from a checkpoint's closed epochs (the
    /// server's `--resume`; see `srv::checkpoint`): replay the closed
    /// [`EpochCosts`] rows, per-tenant bills, reconciliations and ledger
    /// snapshots into the cost tracker as the exact fold the crashed run
    /// performed, resize the cluster to the last checkpointed instance
    /// count, and restart the epoch clock from the last closed boundary
    /// so numbering continues where the crashed run stopped. Cache
    /// contents and controller estimators restart cold — the bills are
    /// the durable part. Call on a freshly built engine, before any
    /// traffic.
    pub fn restore_closed_epochs(
        &mut self,
        epochs: &[EpochCosts],
        bills: &[TenantEpochBill],
        reconciliations: &[TenantReconciliation],
        ledgers: &[(TenantId, TenantLedger)],
    ) {
        self.costs
            .restore_closed_epochs(epochs, bills, reconciliations, ledgers);
        self.epochs.extend_from_slice(epochs);
        if let Some(last) = epochs.last() {
            if last.instances > 0 {
                if let Core::Cluster(b) = &mut self.core {
                    b.cluster.resize(last.instances);
                    self.active_instances = last.instances;
                }
            }
            // Billing time continues from the last closed boundary; the
            // next epoch opens there, exactly as in the crashed run.
            self.clock = self.clock.max(last.t);
            self.epoch_end = last.t + self.epoch_us;
        }
    }

    /// Admit a tenant mid-run (the serve protocol's `ADMIT`, or a trace
    /// ADMIT event): registers the spec with the policy's controller
    /// bank and the cost ledgers. Errors when the policy does not
    /// arbitrate tenants, or while the tenant is still draining.
    pub fn admit_tenant(&mut self, spec: TenantSpec) -> Result<AdmitOutcome> {
        let now = self.clock;
        let outcome = match &mut self.core {
            Core::Cluster(b) => b.admit_tenant(spec.clone(), now)?,
            Core::Vertical { .. } => anyhow::bail!(
                "policy {} does not arbitrate tenants (cannot admit tenant {})",
                self.policy_name,
                spec.id
            ),
        };
        self.costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
        self.notify_lifecycle(spec.id, None);
        Ok(outcome)
    }

    /// Begin retiring a tenant mid-run (the serve protocol's `RETIRE`,
    /// or a trace RETIRE event). Retirement *drains*, it does not drop:
    /// the tenant's controller leaves the bank immediately, and at each
    /// following epoch boundary the balancer releases its placement
    /// state and sheds its residents until the ledger row reads zero
    /// (within [`crate::tenant::MAX_DRAIN_EPOCHS`] boundaries), at which
    /// point the tenant's bill is reconciled
    /// ([`crate::cost::CostTracker::close_tenant`]).
    pub fn retire_tenant(&mut self, tenant: TenantId) -> Result<()> {
        let now = self.clock;
        match &mut self.core {
            Core::Cluster(b) => b.retire_tenant(tenant, now)?,
            Core::Vertical { .. } => anyhow::bail!(
                "policy {} does not arbitrate tenants (cannot retire tenant {tenant})",
                self.policy_name
            ),
        }
        self.notify_lifecycle(tenant, None);
        Ok(())
    }

    /// Replay one trace lifecycle event (the format-v3 event lane):
    /// advances billing time to the event timestamp, then admits or
    /// retires the tenant.
    pub fn apply_event(&mut self, ev: &TenantEvent) -> Result<()> {
        if self.auto_epochs {
            self.advance_to(ev.ts);
        } else {
            self.accrue(ev.ts);
        }
        match ev.kind {
            TenantEventKind::Admit { .. } => {
                let spec = ev.spec().expect("admit events carry a spec");
                self.admit_tenant(spec).map(|_| ())
            }
            TenantEventKind::Retire => self.retire_tenant(ev.tenant),
        }
    }

    /// Emit the tenant's current lifecycle record to every probe.
    fn notify_lifecycle(&mut self, tenant: TenantId, final_bill_dollars: Option<f64>) {
        let rows = match &self.core {
            Core::Cluster(b) => b.lifecycle(),
            Core::Vertical { .. } => None,
        };
        let Some((_, life)) = rows.and_then(|rows| rows.into_iter().find(|(t, _)| *t == tenant))
        else {
            return;
        };
        let sample = LifecycleSample {
            t: self.clock,
            tenant,
            state: life.state(),
            resident_bytes: self.tenant_physical_bytes(tenant),
            drain_epochs: life.drain_epochs,
            final_bill_dollars,
        };
        let ctx = ProbeCtx {
            core: &self.core,
            costs: &self.costs,
            processed: self.processed,
            instances: self.active_instances,
        };
        for p in &mut self.probes {
            p.on_lifecycle(&sample, &ctx);
        }
    }

    /// Bill the final (partial) epoch at full price (§2.3) and fold every
    /// probe's observations into the report.
    pub fn finish(mut self) -> RunReport {
        {
            let ctx = ProbeCtx {
                core: &self.core,
                costs: &self.costs,
                processed: self.processed,
                instances: self.active_instances,
            };
            for p in &mut self.probes {
                p.on_epoch(self.epoch_end, &ctx);
            }
        }
        let t_bill = self.epoch_end.max(self.clock);
        match &self.core {
            Core::Cluster(b) => {
                let residents = b.cluster.tenant_residents();
                self.epochs.push(self.costs.end_epoch_attributed(
                    t_bill,
                    self.active_instances,
                    &residents,
                ));
            }
            Core::Vertical { .. } => {
                self.epochs.push(self.costs.end_epoch_vertical(t_bill));
            }
        }
        // A retirement still draining at run end completes now: the
        // final epoch was just billed with its residents, so the drain
        // and the billing reconciliation can close the lifecycle before
        // the report — every RETIRE pairs with a reconciliation even
        // when no boundary followed it.
        if let Core::Cluster(b) = &mut self.core {
            b.drain_retiring(t_bill);
        }
        let retired = match &mut self.core {
            Core::Cluster(b) => b.take_retired(),
            Core::Vertical { .. } => Vec::new(),
        };
        for tenant in retired {
            let rec = self.costs.close_tenant(tenant, t_bill);
            self.notify_lifecycle(tenant, Some(rec.total_dollars));
        }

        let mut report = RunReport {
            policy: self.policy_name.clone(),
            requests: self.requests(),
            misses: self.misses(),
            spurious_misses: self.spurious_misses(),
            work_units: self.work_units(),
            epochs: std::mem::take(&mut self.epochs),
            storage_series: self.costs.storage_series.clone(),
            miss_series: self.costs.miss_series.clone(),
            total_series: self.costs.total_series.clone(),
            instances_series: self.costs.instances_series.clone(),
            ttl_series: TimeSeries::new(format!("{}_ttl_secs", self.policy_name)),
            shadow_series: TimeSeries::new(format!("{}_shadow_bytes", self.policy_name)),
            balance: BalanceTracker::new(),
            tenants: Vec::new(),
            slo: Vec::new(),
            placement: Vec::new(),
            lifecycle: Vec::new(),
            tenant_bills: self.costs.tenant_bills().to_vec(),
            reconciliations: self.costs.reconciliations().to_vec(),
            journal: Vec::new(),
            telemetry: Vec::new(),
            total_cost: self.costs.total(),
            storage_cost: self.costs.storage_total(),
            miss_cost: self.costs.miss_total(),
        };
        let probes = std::mem::take(&mut self.probes);
        let ctx = ProbeCtx {
            core: &self.core,
            costs: &self.costs,
            processed: self.processed,
            instances: self.active_instances,
        };
        for p in probes {
            p.finish(&ctx, &mut report);
        }
        report
    }

    /// Vertical mode accrues storage continuously on the instantaneous
    /// occupancy; cluster mode bills per epoch instead.
    fn accrue(&mut self, ts: TimeUs) {
        if let Core::Vertical { policy, .. } = &self.core {
            let dt = crate::us_to_secs(ts.saturating_sub(self.clock));
            self.costs
                .record_storage_dollars(policy.vsize() as f64 * self.per_byte_sec * dt);
        }
        self.clock = self.clock.max(ts);
    }

    /// Close the open epoch at `t`: probes first (per-instance stats still
    /// intact), then bill, then apply the sizing decision.
    fn close_epoch_at(&mut self, t: TimeUs) -> u32 {
        {
            let ctx = ProbeCtx {
                core: &self.core,
                costs: &self.costs,
                processed: self.processed,
                instances: self.active_instances,
            };
            for p in &mut self.probes {
                p.on_epoch(t, &ctx);
            }
        }
        let billing_timer = self.billing_timer.clone();
        match &mut self.core {
            Core::Cluster(b) => {
                // Bill the closing epoch first (attributed across tenants
                // by their resident bytes at the boundary), then apply
                // the sizing decision — which also drains retiring
                // tenants, so their final occupied epoch is on the bill
                // before reconciliation below.
                let residents = b.cluster.tenant_residents();
                let costs = &mut self.costs;
                let instances = self.active_instances;
                let billed = match &billing_timer {
                    Some(timer) => {
                        timer.time(|| costs.end_epoch_attributed(t, instances, &residents))
                    }
                    None => costs.end_epoch_attributed(t, instances, &residents),
                };
                self.epochs.push(billed);
                b.cluster.reset_epoch_stats();
                self.active_instances = b.end_epoch(t);
            }
            Core::Vertical { .. } => {
                self.epochs.push(self.costs.end_epoch_vertical(t));
            }
        }
        // Billing reconciliation: tenants whose drain completed at this
        // boundary get their ledgers closed, and probes see the final
        // Retired transition with the reconciled bill.
        let retired = match &mut self.core {
            Core::Cluster(b) => b.take_retired(),
            Core::Vertical { .. } => Vec::new(),
        };
        for tenant in retired {
            let rec = self.costs.close_tenant(tenant, t);
            self.notify_lifecycle(tenant, Some(rec.total_dollars));
        }
        // Post-decision hook: resize, placement maintenance and
        // occupancy-cap shedding have been applied — probes can observe
        // the state the next epoch starts from.
        {
            let ctx = ProbeCtx {
                core: &self.core,
                costs: &self.costs,
                processed: self.processed,
                instances: self.active_instances,
            };
            for p in &mut self.probes {
                p.on_epoch_applied(t, &ctx);
            }
        }
        self.active_instances
    }

    // --- accessors (the server's STATS surface and probe-free callers) ---

    /// Name of the policy this engine runs.
    pub fn policy_name(&self) -> &str {
        &self.policy_name
    }

    /// Requests served so far.
    pub fn requests(&self) -> u64 {
        match &self.core {
            Core::Cluster(b) => b.requests,
            Core::Vertical { requests, .. } => *requests,
        }
    }

    /// Physical misses so far (spurious included).
    pub fn misses(&self) -> u64 {
        match &self.core {
            Core::Cluster(b) => b.misses,
            Core::Vertical { misses, .. } => *misses,
        }
    }

    /// §5.2 spurious misses so far (0 for the vertical mode).
    pub fn spurious_misses(&self) -> u64 {
        match &self.core {
            Core::Cluster(b) => b.spurious_misses,
            Core::Vertical { .. } => 0,
        }
    }

    /// Inserts refused by the admission filter so far (0 with
    /// `[admission] filter = none`, and for the vertical mode — the
    /// ideal cache admits everything by construction).
    pub fn filter_denials(&self) -> u64 {
        match &self.core {
            Core::Cluster(b) => b.filter_denials,
            Core::Vertical { .. } => 0,
        }
    }

    /// Cumulative policy work units (Fig. 1 proxy).
    pub fn work_units(&self) -> u64 {
        match &self.core {
            Core::Cluster(b) => b.work_units,
            Core::Vertical { work_units, .. } => *work_units,
        }
    }

    /// Live instance count (0 for the vertical mode).
    pub fn instances(&self) -> u32 {
        match &self.core {
            Core::Cluster(b) => b.cluster.len() as u32,
            Core::Vertical { .. } => 0,
        }
    }

    /// The run's cost ledger (read-only).
    pub fn costs(&self) -> &CostTracker {
        &self.costs
    }

    /// Every epoch closed so far, in order — index `i` is the epoch the
    /// cost tracker counts as `i + 1` (restored epochs included). Drained
    /// by [`Self::finish`]; the long-lived server never calls that, so
    /// `srv::checkpoint` cursors over this slice.
    pub fn closed_epochs(&self) -> &[EpochCosts] {
        &self.epochs
    }

    /// Current policy TTL, when the policy maintains one.
    pub fn ttl_secs(&self) -> Option<f64> {
        self.core.ttl_secs()
    }

    /// Current virtual/shadow size in bytes, when the policy tracks one.
    pub fn shadow_size(&self) -> Option<u64> {
        self.core.shadow_size()
    }

    /// Per-tenant timers, when the policy runs one controller per tenant.
    pub fn tenant_ttls(&self) -> Option<Vec<(TenantId, f64)>> {
        match &self.core {
            Core::Cluster(b) => b.tenant_ttls(),
            Core::Vertical { .. } => None,
        }
    }

    /// Per-tenant enforcement state (grants, caps, clamps, SLO tracking),
    /// when the policy arbitrates tenants (`None` otherwise).
    pub fn tenant_enforcement(&self) -> Option<Vec<TenantEnforcement>> {
        match &self.core {
            Core::Cluster(b) => b.tenant_enforcement(),
            Core::Vertical { .. } => None,
        }
    }

    /// Enforcement state for one tenant (`None` when the policy does not
    /// arbitrate tenants or the tenant has never been seen).
    pub fn tenant_enforcement_of(&self, t: TenantId) -> Option<TenantEnforcement> {
        self.tenant_enforcement()?
            .into_iter()
            .find(|row| row.tenant == t)
    }

    /// Per-tenant lifecycle records, when the policy tracks tenant
    /// lifecycles (`None` otherwise).
    pub fn tenant_lifecycle(&self) -> Option<Vec<(TenantId, Lifecycle)>> {
        match &self.core {
            Core::Cluster(b) => b.lifecycle(),
            Core::Vertical { .. } => None,
        }
    }

    /// Lifecycle record of one tenant (`None` when the policy does not
    /// track lifecycles, or the tenant was never admitted).
    pub fn tenant_lifecycle_of(&self, t: TenantId) -> Option<Lifecycle> {
        self.tenant_lifecycle()?
            .into_iter()
            .find(|(id, _)| *id == t)
            .map(|(_, life)| life)
    }

    /// Whether the lifecycle layer knows this tenant (admitted explicitly
    /// or lazily by traffic, in any state). Always `false` for policies
    /// without lifecycle tracking.
    pub fn tenant_known(&self, t: TenantId) -> bool {
        self.tenant_lifecycle_of(t).is_some()
    }

    /// The spec currently registered for `t` (`None` when the policy
    /// keeps no registry, or the tenant was never admitted). Partial
    /// `ADMIT` updates seed from this so unspecified fields keep their
    /// values.
    pub fn tenant_spec(&self, t: TenantId) -> Option<TenantSpec> {
        match &self.core {
            Core::Cluster(b) => b.tenant_spec(t),
            Core::Vertical { .. } => None,
        }
    }

    /// Counters for one tenant (zero if never seen).
    pub fn tenant_stats_of(&self, t: TenantId) -> HitMiss {
        match &self.core {
            Core::Cluster(b) => b.tenant_stats_of(t),
            Core::Vertical { .. } => HitMiss::default(),
        }
    }

    /// Physical resident bytes of one tenant — the cluster placement
    /// ledger row (0 for the vertical mode, which has no instances).
    pub fn tenant_physical_bytes(&self, t: TenantId) -> u64 {
        match &self.core {
            Core::Cluster(b) => b.cluster.tenant_resident_bytes(t),
            Core::Vertical { .. } => 0,
        }
    }

    /// Placement snapshot (policy kind, per-tenant resident bytes and
    /// pins) — the `PLACEMENT` serve command renders this. `None` for the
    /// vertical mode.
    pub fn placement_snapshot(&self) -> Option<PlacementSnapshot> {
        match &self.core {
            Core::Cluster(b) => Some(b.cluster.placement_snapshot()),
            Core::Vertical { .. } => None,
        }
    }

    /// Tenants that have sent traffic so far.
    pub fn active_tenants(&self) -> usize {
        match &self.core {
            Core::Cluster(b) => b.tenant_stats().iter().filter(|hm| hm.total() > 0).count(),
            Core::Vertical { .. } => 0,
        }
    }

    /// The live telemetry registry, when `[telemetry] enabled` (`None`
    /// otherwise — no handle exists, nothing records).
    pub fn telemetry(&self) -> Option<&SharedRegistry> {
        self.telemetry.as_ref()
    }

    /// The live epoch decision journal, when `[telemetry] enabled` —
    /// the serve `WHY` command answers from this ring.
    pub fn journal(&self) -> Option<&SharedJournal> {
        self.journal.as_ref()
    }

    /// Prometheus text exposition of the live registry (the serve
    /// `METRICS` reply body), `None` when telemetry is disabled.
    /// Point-in-time gauges are refreshed before rendering.
    pub fn metrics_text(&self) -> Option<String> {
        let registry = self.telemetry.as_ref()?;
        {
            let mut reg = registry.borrow_mut();
            reg.gauge("elastictl_instances").set(self.instances() as f64);
            reg.gauge("elastictl_clock_us").set(self.clock as f64);
        }
        Some(registry.borrow().prometheus())
    }

    /// Latest timestamp observed.
    pub fn clock(&self) -> TimeUs {
        self.clock
    }

    /// End of the currently open billing epoch.
    pub fn epoch_end(&self) -> TimeUs {
        self.epoch_end
    }
}

/// Drain a source through a freshly built engine — the one-call form every
/// batch consumer (CLI, experiments, tests) uses. Drives the *item*
/// stream, so a format-v3 trace (or an [`crate::trace::EventedVecSource`])
/// admits and retires tenants mid-run; lifecycle events offered to a
/// policy that does not arbitrate tenants are skipped (the request lane
/// still replays in full).
pub fn run(cfg: &Config, source: &mut dyn RequestSource) -> RunReport {
    if cfg.engine.shards > 1 {
        match ShardedEngine::new(cfg) {
            Ok(engine) => return run_sharded(cfg, engine, source),
            Err(e) => {
                eprintln!("engine: falling back to a single shard: {e}");
            }
        }
    }
    let mut engine = EngineBuilder::new(cfg).build();
    while let Some(item) = source.next_item() {
        match item {
            TraceItem::Request(req) => {
                engine.offer(&req);
            }
            TraceItem::Event(ev) => {
                if let Err(e) = engine.apply_event(&ev) {
                    // The request lane still replays in full; surface the
                    // skipped event (tenant-oblivious policies reject
                    // lifecycle events by design, but a failed admit or
                    // retire on a tenant-aware policy is worth seeing).
                    eprintln!(
                        "engine: skipped lifecycle event for tenant {} at t={}: {e}",
                        ev.tenant, ev.ts
                    );
                }
            }
        }
    }
    let report = engine.finish();
    // The journal JSONL artifact: one record per line, written where
    // `[telemetry] journal_path` points (nightly soak feeds this to
    // `scripts/journal_check.py`).
    if let Some(path) = &cfg.telemetry.journal_path {
        let mut body = String::new();
        for rec in &report.journal {
            body.push_str(&rec.to_json());
            body.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("engine: failed to write telemetry journal to {path}: {e}");
        }
    }
    report
}

/// The sharded twin of the [`run`] drain loop (`[engine] shards > 1`):
/// same item stream, same lifecycle-event skip semantics, with the hot
/// path fanned across the shard workers. Probe-derived report sections
/// (ttl/shadow series, balance, per-tenant summaries) stay empty — the
/// counters, epochs, bills, totals, journal and telemetry rows are
/// complete, and the `sharded_parity` test pins them against the
/// single-shard run.
fn run_sharded(
    cfg: &Config,
    mut engine: ShardedEngine,
    source: &mut dyn RequestSource,
) -> RunReport {
    while let Some(item) = source.next_item() {
        match item {
            TraceItem::Request(req) => {
                engine.offer(&req);
            }
            TraceItem::Event(ev) => {
                if let Err(e) = engine.apply_event(&ev) {
                    eprintln!(
                        "engine: skipped lifecycle event for tenant {} at t={}: {e}",
                        ev.tenant, ev.ts
                    );
                }
            }
        }
    }
    let report = engine.finish();
    // Same journal JSONL artifact as the monolithic drain loop.
    if let Some(path) = &cfg.telemetry.journal_path {
        let mut body = String::new();
        for rec in &report.journal {
            body.push_str(&rec.to_json());
            body.push('\n');
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("engine: failed to write telemetry journal to {path}: {e}");
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::trace::{Request, VecSource};
    use crate::{HOUR, MINUTE, SECOND};

    fn tiny_cfg(policy: PolicyKind) -> Config {
        let mut cfg = Config::with_policy(policy);
        cfg.cost.instance.ram_bytes = 20_000_000;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.fixed_instances = 4;
        cfg
    }

    #[test]
    fn offer_reports_hits_and_misses() {
        let mut engine = EngineBuilder::new(&tiny_cfg(PolicyKind::Fixed)).build();
        let miss = engine.offer(&Request::new(0, 1, 1000));
        assert!(!miss.hit);
        let hit = engine.offer(&Request::new(SECOND, 1, 1000));
        assert!(hit.hit);
        assert_eq!(engine.requests(), 2);
        assert_eq!(engine.misses(), 1);
        let report = engine.finish();
        assert_eq!(report.policy, "fixed");
        assert_eq!(report.requests, 2);
        assert!((report.total_cost - (report.storage_cost + report.miss_cost)).abs() < 1e-12);
    }

    #[test]
    fn advance_to_closes_elapsed_epochs() {
        let cfg = tiny_cfg(PolicyKind::Fixed);
        let mut engine = EngineBuilder::new(&cfg).build();
        engine.offer(&Request::new(0, 1, 100));
        // Jump 3 epochs ahead: three closures must be billed.
        engine.advance_to(3 * cfg.cost.epoch_us + 1);
        let report = engine.finish();
        assert_eq!(report.epochs.len(), 4, "3 advanced + 1 final");
        assert!(report.storage_series.len() >= 4);
    }

    #[test]
    fn vertical_mode_bills_occupancy_not_instances() {
        let mut cfg = tiny_cfg(PolicyKind::IdealTtl);
        cfg.controller.t_init_secs = 600.0;
        let mut engine = EngineBuilder::new(&cfg).build();
        engine.offer(&Request::new(0, 1, 1_000_000));
        engine.offer(&Request::new(100 * SECOND, 2, 1_000_000));
        assert_eq!(engine.instances(), 0);
        let report = engine.finish();
        assert_eq!(report.policy, "ideal_ttl");
        assert_eq!(report.spurious_misses, 0);
        assert!(report.storage_cost > 0.0, "occupancy must accrue dollars");
        // 1 MB held 100 s at the catalog's per-byte rate.
        let expect = 1.0e6 * cfg.cost.storage_cost_per_byte_sec() * 100.0;
        assert!((report.storage_cost - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn analytic_policy_runs_through_the_same_entry_point() {
        let mut cfg = tiny_cfg(PolicyKind::Analytic);
        cfg.cost.instance.ram_bytes = 1_000_000;
        let reqs: Vec<Request> = (0..2000u64)
            .map(|i| Request::new(i * SECOND / 2, i % 50, 10_000))
            .collect();
        let report = run(&cfg, &mut VecSource::new(reqs));
        assert_eq!(report.policy, "analytic");
        assert_eq!(report.requests, 2000);
        assert!(report.total_cost > 0.0);
    }

    #[test]
    fn force_epoch_resizes_and_restarts_the_clock() {
        let mut cfg = tiny_cfg(PolicyKind::Ttl);
        cfg.controller.t_init_secs = 7200.0;
        let mut engine = EngineBuilder::new(&cfg).build();
        let inst = cfg.cost.instance.ram_bytes;
        for i in 0..30u64 {
            engine.offer(&Request::new(i * SECOND, i, (inst / 10) as u32));
        }
        let n = engine.force_epoch(40 * SECOND);
        assert!(n >= 2, "n={n}");
        assert_eq!(engine.instances(), n);
        assert_eq!(engine.epoch_end(), 40 * SECOND + cfg.cost.epoch_us);
    }

    #[test]
    fn manual_epochs_close_only_on_explicit_calls() {
        let cfg = tiny_cfg(PolicyKind::Fixed);
        let mut engine = EngineBuilder::new(&cfg).manual_epochs().build();
        // Requests far past several epoch boundaries must not close them.
        engine.offer(&Request::new(0, 1, 100));
        engine.offer(&Request::new(5 * cfg.cost.epoch_us, 2, 100));
        assert_eq!(engine.costs().epochs(), 0, "no implicit closure");
        // The explicit boundary still works.
        let n = engine.force_epoch(5 * cfg.cost.epoch_us + 1);
        assert_eq!(n, 4);
        assert_eq!(engine.costs().epochs(), 1);
        let report = engine.finish();
        assert_eq!(report.epochs.len(), 2, "forced + final");
    }

    #[test]
    fn custom_probe_observes_every_request() {
        struct Counter(std::rc::Rc<std::cell::Cell<u64>>);
        impl Probe for Counter {
            fn on_request(&mut self, _r: &Request, _o: &Outcome, _c: &ProbeCtx) {
                self.0.set(self.0.get() + 1);
            }
        }
        let seen = std::rc::Rc::new(std::cell::Cell::new(0));
        let mut engine = EngineBuilder::new(&tiny_cfg(PolicyKind::Fixed))
            .probe(Box::new(Counter(seen.clone())))
            .build();
        for i in 0..10u64 {
            engine.offer(&Request::new(i, i, 100));
        }
        engine.finish();
        assert_eq!(seen.get(), 10);
    }

    #[test]
    fn admit_and_retire_drain_and_reconcile() {
        use crate::tenant::{AdmitOutcome, LifecycleState, TenantSpec, MAX_DRAIN_EPOCHS};
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0; // sticky ghosts
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.max_instances = 4;
        let mut engine = EngineBuilder::new(&cfg).build();
        let spec = TenantSpec::new(7, "guest").with_multiplier(2.0);
        assert_eq!(engine.admit_tenant(spec.clone()).unwrap(), AdmitOutcome::Admitted);
        assert!(engine.tenant_known(7));
        assert!(!engine.tenant_known(8));
        assert_eq!(
            engine.tenant_lifecycle_of(7).unwrap().state(),
            LifecycleState::Admitted
        );
        // Re-admitting a live tenant is a spec update.
        assert_eq!(engine.admit_tenant(spec).unwrap(), AdmitOutcome::Updated);
        // Traffic activates it and builds residents.
        for i in 0..8u64 {
            engine.offer(&Request::new(i * SECOND, i, 100_000).with_tenant(7));
        }
        assert_eq!(
            engine.tenant_lifecycle_of(7).unwrap().state(),
            LifecycleState::Active
        );
        assert!(engine.tenant_physical_bytes(7) > 0);

        engine.retire_tenant(7).unwrap();
        assert_eq!(
            engine.tenant_lifecycle_of(7).unwrap().state(),
            LifecycleState::Draining
        );
        // A post-retire request is served but never cached again.
        let out = engine.offer(&Request::new(9 * SECOND, 0, 100_000).with_tenant(7));
        assert!(out.hit, "still-resident object hits while draining");
        let miss = engine.offer(&Request::new(10 * SECOND, 999, 100_000).with_tenant(7));
        assert!(!miss.hit);
        let miss2 = engine.offer(&Request::new(11 * SECOND, 999, 100_000).with_tenant(7));
        assert!(!miss2.hit, "denied insert: the retired miss must not cache");

        // The next boundary drains the residents and reconciles the bill.
        engine.advance_to(cfg.cost.epoch_us + 1);
        assert_eq!(engine.tenant_physical_bytes(7), 0, "drain must reclaim everything");
        let life = engine.tenant_lifecycle_of(7).unwrap();
        assert_eq!(life.state(), LifecycleState::Retired);
        assert!(life.drain_epochs <= MAX_DRAIN_EPOCHS, "{life:?}");
        assert!(engine.retire_tenant(7).is_err(), "already retired");
        assert!(engine.retire_tenant(42).is_err(), "unknown tenant");

        let report = engine.finish();
        assert_eq!(report.reconciliations.len(), 1);
        let rec = report.reconciliations[0];
        assert_eq!(rec.tenant, 7);
        assert!(rec.misses > 0);
        assert!(rec.total_dollars > 0.0);
        // The lifecycle audit trail saw every transition, ending Retired
        // with the reconciled bill attached.
        let states: Vec<LifecycleState> = report
            .lifecycle
            .iter()
            .filter(|s| s.tenant == 7)
            .map(|s| s.state)
            .collect();
        assert_eq!(
            states,
            vec![
                LifecycleState::Admitted,
                LifecycleState::Admitted, // spec update keeps the state
                LifecycleState::Draining,
                LifecycleState::Retired,
            ]
        );
        let last = report.lifecycle.iter().rfind(|s| s.tenant == 7).unwrap();
        assert_eq!(last.resident_bytes, 0);
        assert_eq!(last.final_bill_dollars, Some(rec.total_dollars));
        // Σ per-epoch tenant bills == total cluster bill, bit for bit
        // (fold per epoch in bill order, then across epochs).
        let (mut s, mut m) = (0.0, 0.0);
        let (mut se, mut me) = (0.0, 0.0);
        let mut cur = None;
        for b in &report.tenant_bills {
            if cur != Some(b.t) {
                s += se;
                m += me;
                se = 0.0;
                me = 0.0;
                cur = Some(b.t);
            }
            se += b.storage;
            me += b.miss;
        }
        s += se;
        m += me;
        assert_eq!(s + m, report.total_cost, "billing attribution must be exact");
    }

    #[test]
    fn finish_reconciles_a_retirement_in_the_final_partial_epoch() {
        use crate::tenant::LifecycleState;
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.epoch_us = 10 * MINUTE;
        let mut engine = EngineBuilder::new(&cfg).build();
        engine.offer(&Request::new(SECOND, 1, 100_000).with_tenant(2));
        assert!(engine.tenant_physical_bytes(2) > 0);
        // RETIRE with no EPOCH boundary afterwards: finish() must still
        // drain and reconcile.
        engine.retire_tenant(2).unwrap();
        let report = engine.finish();
        assert_eq!(report.reconciliations.len(), 1);
        assert_eq!(report.reconciliations[0].tenant, 2);
        assert!(report.reconciliations[0].total_dollars > 0.0);
        let last = report.lifecycle.iter().rfind(|s| s.tenant == 2).unwrap();
        assert_eq!(last.state, LifecycleState::Retired);
        assert_eq!(last.resident_bytes, 0);
    }

    #[test]
    fn vertical_mode_rejects_lifecycle_calls() {
        use crate::tenant::TenantSpec;
        let mut engine = EngineBuilder::new(&tiny_cfg(PolicyKind::IdealTtl)).build();
        assert!(engine.admit_tenant(TenantSpec::new(1, "x")).is_err());
        assert!(engine.retire_tenant(0).is_err());
        assert!(engine.tenant_lifecycle().is_none());
        // Tenant-oblivious horizontal policies refuse too.
        let mut fixed = EngineBuilder::new(&tiny_cfg(PolicyKind::Fixed)).build();
        assert!(fixed.admit_tenant(TenantSpec::new(1, "x")).is_err());
        assert!(!fixed.tenant_known(0));
    }

    #[test]
    fn run_replays_trace_events_into_lifecycle() {
        use crate::tenant::LifecycleState;
        use crate::trace::{EventedVecSource, TenantEvent};
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.max_instances = 4;
        let reqs: Vec<Request> = (0..30u64)
            .map(|i| Request::new(i * MINUTE, i % 10, 50_000).with_tenant(3))
            .collect();
        let events = vec![
            TenantEvent::admit(0, 3).with_multiplier(2.0),
            TenantEvent::retire(12 * MINUTE, 3),
        ];
        let report = run(&cfg, &mut EventedVecSource::merged(reqs, events));
        assert_eq!(report.requests, 30, "the request lane replays in full");
        let retired = report
            .lifecycle
            .iter()
            .find(|s| s.tenant == 3 && s.state == LifecycleState::Retired)
            .expect("the RETIRE event must drain tenant 3");
        assert_eq!(retired.resident_bytes, 0);
        assert_eq!(report.reconciliations.len(), 1);
        assert_eq!(report.reconciliations[0].tenant, 3);
    }

    #[test]
    fn telemetry_run_records_journal_and_counters() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.telemetry.enabled = true;
        cfg.controller.t_init_secs = 600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.cost.epoch_us = 10 * MINUTE;
        cfg.scaler.max_instances = 4;
        let mut engine = EngineBuilder::new(&cfg).build();
        for i in 0..200u64 {
            let t = (i % 2) as crate::TenantId;
            engine.offer(&Request::new(i * SECOND, i % 20, 50_000).with_tenant(t));
        }
        engine.advance_to(2 * cfg.cost.epoch_us + 1);
        let text = engine.metrics_text().expect("telemetry is enabled");
        assert!(text.contains("elastictl_requests_total 200"), "{text}");
        assert!(engine.journal().is_some());
        let report = engine.finish();
        assert!(!report.journal.is_empty(), "closed epochs must be journaled");
        let cap = 4u64 * 1_000_000;
        for rec in &report.journal {
            assert_eq!(rec.capacity_bytes, cap);
            let granted: u64 = rec.tenants.iter().map(|d| d.granted_bytes).sum();
            assert!(granted <= cap, "arbiter invariant: {granted} > {cap}");
            for d in &rec.tenants {
                assert!(d.shed_bytes <= d.resident_before_bytes, "{d:?}");
            }
        }
        assert!(report
            .telemetry
            .iter()
            .any(|(k, v)| k == "elastictl_requests_total" && *v == 200.0));
        // Telemetry off: no registry, no journal, empty report fields.
        cfg.telemetry.enabled = false;
        let mut plain = EngineBuilder::new(&cfg).build();
        plain.offer(&Request::new(0, 1, 100));
        assert!(plain.metrics_text().is_none());
        assert!(plain.journal().is_none());
        let report = plain.finish();
        assert!(report.journal.is_empty());
        assert!(report.telemetry.is_empty());
    }

    #[test]
    fn empty_run_still_bills_one_epoch() {
        let report = run(&tiny_cfg(PolicyKind::Fixed), &mut VecSource::new(Vec::new()));
        assert_eq!(report.requests, 0);
        assert_eq!(report.epochs.len(), 1);
        assert_eq!(report.miss_ratio(), 0.0);
        assert!(report.storage_cost > 0.0, "the open epoch is billed");
    }

    #[test]
    fn epoch_billing_counts_all_epochs_despite_gaps() {
        let mut cfg = tiny_cfg(PolicyKind::Fixed);
        cfg.cost.epoch_us = HOUR;
        let reqs = vec![
            Request::new(0, 1, 100),
            Request::new(2 * HOUR + MINUTE, 2, 100),
            Request::new(2 * HOUR + 2 * MINUTE, 1, 100),
        ];
        let report = run(&cfg, &mut VecSource::new(reqs));
        assert!(report.storage_series.len() >= 3, "epochs={}", report.storage_series.len());
    }
}
