//! Horizontally scalable cache cluster behind a Redis-style two-step
//! hash-slot scheme (§6.2, Fig. 9): 16384 slots; object keys hash into a
//! slot; each slot is assigned to a server. Adding a server transfers
//! randomly selected slots to it; removing one scatters its slots over
//! the survivors.
//!
//! Slot moves create **spurious misses** (§5.2): the object is resident on
//! the old owner, but requests now route to the new owner, which misses.
//! We model this faithfully — stale copies linger on the old owner until
//! its LRU churns them out.
//!
//! Placement subsystem: requests route through the configured
//! [`PlacementPolicy`] (`[placement]` config section) — `shared` keeps
//! the plain slot-map routing above, `hash_slot_pinned` confines each
//! tenant to an instance subset sized from its grant, `slab_partition`
//! installs Memshare-style per-tenant floors inside every instance. The
//! cluster also maintains the per-tenant **resident-bytes ledger**:
//! every insert is tagged with its tenant, every eviction reports
//! `(tenant, bytes)` back, and the invariant
//! `Σ tenant_resident == used()` holds after every operation
//! ([`Cluster::ledger_residents`], pinned by a property test).

mod balance;

pub use balance::{BalanceSnapshot, BalanceTracker};

use crate::cache::{CacheInstance, EvictionSink, ExpiryIndex};
use crate::config::{ClusterConfig, EvictionKind};
use crate::placement::{
    make_placement, PlacementKind, PlacementPolicy, PlacementSnapshot, PlacementTenantRow,
    TenantGrant,
};
use crate::telemetry::{Counter, TelemetryRegistry};
use crate::util::rng::Pcg;
use crate::{mix64, ObjectId, TenantId};

/// Pre-resolved cluster-level telemetry handles: insert/evict counters
/// recorded on the serve path at O(1) (a `Cell` bump each). Absent by
/// default — the untelemetered serve path does not touch them.
#[derive(Debug, Clone)]
pub struct ClusterTelemetry {
    /// Objects inserted on miss.
    pub inserts: Counter,
    /// Bytes inserted on miss.
    pub inserted_bytes: Counter,
    /// Entries evicted by LRU churn on the serve path.
    pub evictions: Counter,
    /// Bytes evicted by LRU churn on the serve path.
    pub evicted_bytes: Counter,
    /// Entries removed because their real TTL ran out (server runtime).
    pub ttl_expirations: Counter,
    /// Bytes those expiries freed.
    pub ttl_expired_bytes: Counter,
}

impl ClusterTelemetry {
    /// Resolve the cluster's counter handles from `registry` (once, at
    /// attach time — the hot path never does a string lookup).
    pub fn resolve(registry: &mut TelemetryRegistry) -> ClusterTelemetry {
        ClusterTelemetry {
            inserts: registry.counter("elastictl_inserts_total"),
            inserted_bytes: registry.counter("elastictl_inserted_bytes_total"),
            evictions: registry.counter("elastictl_evictions_total"),
            evicted_bytes: registry.counter("elastictl_evicted_bytes_total"),
            ttl_expirations: registry.counter("elastictl_ttl_expirations_total"),
            ttl_expired_bytes: registry.counter("elastictl_ttl_expired_bytes_total"),
        }
    }
}

/// A homogeneous cluster of cache instances plus the slot map.
pub struct Cluster {
    instances: Vec<CacheInstance>,
    /// slot → index into `instances`.
    slot_owner: Vec<u32>,
    hash_slots: u32,
    eviction: EvictionKind,
    capacity_per_instance: u64,
    next_id: u32,
    rng: Pcg,
    /// Cumulative slots moved by resizes (each move risks spurious misses).
    pub slots_moved: u64,
    /// Number of resize events that changed the instance count.
    pub resizes: u64,
    /// Where `(tenant, key)` physically lives (placement subsystem).
    placement: Box<dyn PlacementPolicy>,
    /// Per-tenant resident bytes across all instances, indexed by tenant
    /// id. Invariant: `Σ tenant_resident == used()`.
    tenant_resident: Vec<u64>,
    /// Reusable eviction sink (no per-request allocation).
    evict_buf: EvictionSink,
    /// Insert/evict counters (`None` = telemetry off, zero overhead).
    telemetry: Option<ClusterTelemetry>,
    /// Real TTL expiry for resident entries (`None` = off, the default —
    /// the simulator and the parity-pinned server never arm it).
    expiry: Option<ExpiryIndex>,
}

impl Cluster {
    /// Create a cluster of `n ≥ 1` instances.
    pub fn new(cfg: &ClusterConfig, capacity_per_instance: u64, n: u32) -> Self {
        let n = n.max(1);
        let mut rng = Pcg::seed_from_u64(cfg.seed);
        let mut instances = Vec::with_capacity(n as usize);
        for id in 0..n {
            instances.push(CacheInstance::new(id, cfg.eviction, capacity_per_instance, cfg.seed));
        }
        // Initial assignment: round-robin then shuffle, so each server owns
        // ~slots/n with random placement (as Redis' random assignment).
        let mut slot_owner: Vec<u32> = (0..cfg.hash_slots).map(|s| s % n).collect();
        rng.shuffle(&mut slot_owner);
        Cluster {
            instances,
            slot_owner,
            hash_slots: cfg.hash_slots,
            eviction: cfg.eviction,
            capacity_per_instance,
            next_id: n,
            rng,
            slots_moved: 0,
            resizes: 0,
            placement: make_placement(cfg.placement),
            tenant_resident: Vec::new(),
            evict_buf: EvictionSink::new(),
            telemetry: None,
            expiry: None,
        }
    }

    /// Arm real wall-clock TTL expiry: every resident entry gets a
    /// [`crate::cache::TtlPolicy`] renewed on access and checked lazily
    /// on the next read ([`Self::serve_for`]) — an expired entry is
    /// removed (debiting the resident ledger) before the lookup, so it
    /// counts as a plain miss.
    pub fn enable_ttl_expiry(&mut self, ttl: std::time::Duration) {
        self.expiry = Some(ExpiryIndex::new(ttl));
    }

    /// Expiry counters `(entries expired, bytes freed)` since startup.
    pub fn expiry_stats(&self) -> Option<(u64, u64)> {
        self.expiry.as_ref().map(|e| (e.expirations, e.expired_bytes))
    }

    /// Install pre-resolved telemetry counters on the serve path.
    pub fn set_telemetry(&mut self, telemetry: ClusterTelemetry) {
        self.telemetry = Some(telemetry);
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    pub fn instances(&self) -> &[CacheInstance] {
        &self.instances
    }

    pub fn instances_mut(&mut self) -> &mut [CacheInstance] {
        &mut self.instances
    }

    pub fn capacity_per_instance(&self) -> u64 {
        self.capacity_per_instance
    }

    /// Total bytes resident across instances.
    pub fn used(&self) -> u64 {
        self.instances.iter().map(|i| i.used()).sum()
    }

    /// Hash slot of an object key (two-step scheme, step 1).
    #[inline]
    pub fn slot_of(&self, obj: ObjectId) -> u32 {
        (mix64(obj) % self.hash_slots as u64) as u32
    }

    /// Index of the instance responsible for `obj` under *shared* routing
    /// (step 2). Placement-aware callers use [`Self::route_for`].
    #[inline]
    pub fn route(&self, obj: ObjectId) -> usize {
        self.slot_owner[self.slot_of(obj) as usize] as usize
    }

    /// Placement-aware routing: the instance responsible for `obj` (an
    /// already tenant-scoped id) on behalf of `tenant`. Identical to
    /// [`Self::route`] under the default `shared` placement.
    #[inline]
    pub fn route_for(&self, tenant: TenantId, obj: ObjectId) -> usize {
        let slot = self.slot_of(obj);
        let shared = self.slot_owner[slot as usize] as usize;
        self.placement.route(tenant, slot, shared, self.instances.len())
    }

    #[inline]
    fn ledger_add(&mut self, tenant: TenantId, bytes: u64) {
        let i = tenant as usize;
        if self.tenant_resident.len() <= i {
            self.tenant_resident.resize(i + 1, 0);
        }
        self.tenant_resident[i] += bytes;
    }

    #[inline]
    fn ledger_sub(&mut self, tenant: TenantId, bytes: u64) {
        let slot = &mut self.tenant_resident[tenant as usize];
        debug_assert!(
            *slot >= bytes,
            "tenant {tenant} resident ledger underflow: {} < {bytes}",
            *slot
        );
        *slot = slot.saturating_sub(bytes);
    }

    /// Serve a request through the slot map (tenant 0). Returns `true` on
    /// hit.
    #[inline]
    pub fn serve(&mut self, obj: ObjectId, size: u64) -> bool {
        self.serve_for(0, obj, size)
    }

    /// Tenant-tagged serve: route via the placement policy, look up, and
    /// on miss insert the fetched object tagged with `tenant`, folding
    /// the insert and every eviction it caused into the resident ledger.
    #[inline]
    pub fn serve_for(&mut self, tenant: TenantId, obj: ObjectId, size: u64) -> bool {
        if self.expiry.is_some() {
            self.expire_on_access(tenant, obj);
        }
        let idx = self.route_for(tenant, obj);
        let buf = &mut self.evict_buf;
        buf.clear();
        let (hit, added) = self.instances[idx].serve_tagged(obj, size, tenant, buf);
        if added > 0 {
            self.ledger_add(tenant, added);
            if let Some(exp) = &mut self.expiry {
                exp.note_insert(obj);
            }
            if let Some(tel) = &self.telemetry {
                tel.inserts.inc();
                tel.inserted_bytes.add(added);
            }
        }
        while let Some((t, b)) = self.evict_buf.pop() {
            self.ledger_sub(t, b);
            if let Some(tel) = &self.telemetry {
                tel.evictions.inc();
                tel.evicted_bytes.add(b);
            }
        }
        hit
    }

    /// Serve a request *without* inserting on miss (the balancer refused
    /// admission — multi-tenant occupancy-cap enforcement). Hit/miss
    /// accounting is identical to [`Self::serve`].
    #[inline]
    pub fn serve_no_insert(&mut self, obj: ObjectId) -> bool {
        self.serve_no_insert_for(0, obj)
    }

    /// Placement-aware [`Self::serve_no_insert`].
    #[inline]
    pub fn serve_no_insert_for(&mut self, tenant: TenantId, obj: ObjectId) -> bool {
        if self.expiry.is_some() {
            self.expire_on_access(tenant, obj);
        }
        let idx = self.route_for(tenant, obj);
        self.instances[idx].lookup_only(obj)
    }

    /// Lazy expiry check for `obj` on the access path: if its policy ran
    /// out, remove the resident copy at the routed instance and debit the
    /// owner's resident ledger row, so the following lookup misses like
    /// any cold object. Only called with expiry armed.
    fn expire_on_access(&mut self, tenant: TenantId, obj: ObjectId) {
        let idx = self.route_for(tenant, obj);
        let expired = match &mut self.expiry {
            Some(exp) => exp.check_expired(obj),
            None => return,
        };
        if !expired {
            return;
        }
        if let Some((bytes, owner)) = self.instances[idx].remove_entry(obj) {
            self.ledger_sub(owner, bytes);
            if let Some(exp) = &mut self.expiry {
                exp.record_expiry(bytes);
            }
            if let Some(tel) = &self.telemetry {
                tel.ttl_expirations.inc();
                tel.ttl_expired_bytes.add(bytes);
            }
        }
    }

    /// Epoch-boundary expiry sweep (never on the request path): drain
    /// every expired policy and remove any still-resident copies — stale
    /// duplicates left behind by slot moves included — keeping the
    /// resident ledger exact. Returns `(entries removed, bytes freed)`;
    /// a no-op when expiry is off.
    pub fn expire_sweep(&mut self) -> (u64, u64) {
        let objs = match &mut self.expiry {
            Some(exp) => exp.take_expired(),
            None => return (0, 0),
        };
        let mut count = 0u64;
        let mut bytes = 0u64;
        for obj in objs {
            for inst in &mut self.instances {
                if let Some((b, owner)) = inst.remove_entry(obj) {
                    self.tenant_resident[owner as usize] =
                        self.tenant_resident[owner as usize].saturating_sub(b);
                    count += 1;
                    bytes += b;
                }
            }
        }
        if count > 0 {
            if let Some(exp) = &mut self.expiry {
                exp.expirations += count;
                exp.expired_bytes += bytes;
            }
            if let Some(tel) = &self.telemetry {
                tel.ttl_expirations.add(count);
                tel.ttl_expired_bytes.add(bytes);
            }
        }
        (count, bytes)
    }

    /// Physical resident bytes of `tenant` across the cluster (O(1): the
    /// ledger row).
    #[inline]
    pub fn tenant_resident_bytes(&self, tenant: TenantId) -> u64 {
        self.tenant_resident.get(tenant as usize).copied().unwrap_or(0)
    }

    /// Non-zero ledger rows as `(tenant, resident bytes)`.
    pub fn tenant_residents(&self) -> Vec<(TenantId, u64)> {
        self.tenant_resident
            .iter()
            .enumerate()
            .filter(|&(_, &b)| b > 0)
            .map(|(t, &b)| (t as TenantId, b))
            .collect()
    }

    /// Sum of the ledger rows — equals [`Self::used`] by invariant (the
    /// placement property suite pins this).
    pub fn ledger_residents(&self) -> u64 {
        self.tenant_resident.iter().sum()
    }

    /// The configured placement policy kind.
    pub fn placement_kind(&self) -> PlacementKind {
        self.placement.kind()
    }

    /// Instance pins of `tenant`, when the placement policy pins.
    pub fn pins_of(&self, tenant: TenantId) -> Option<&[u32]> {
        self.placement.pins(tenant)
    }

    /// Epoch boundary: hand the fresh grants to the placement policy
    /// (re-pin subsets / recompute partition floors) and install any
    /// per-instance floors. A no-op under `shared` placement — the
    /// stores are never touched, keeping the default bit-identical.
    pub fn apply_grants(&mut self, grants: &[TenantGrant]) {
        let n = self.instances.len();
        self.placement.on_grants(grants, n, self.capacity_per_instance);
        if let Some(floors) = self.placement.instance_floors() {
            for inst in &mut self.instances {
                inst.set_tenant_floors(floors);
            }
        }
    }

    /// A tenant is retiring: release its placement state (pins, floors)
    /// and re-install the remaining floors on every instance so the
    /// departed tenant's protection is actually gone. Runs at epoch
    /// boundaries as part of the drain; a no-op under `shared` placement.
    pub fn release_tenant(&mut self, tenant: TenantId) {
        self.placement.release(tenant);
        if let Some(floors) = self.placement.instance_floors() {
            for inst in &mut self.instances {
                inst.set_tenant_floors(floors);
            }
        }
    }

    /// Shed `tenant` down to `cap_bytes` resident: evict its coldest
    /// entries, instance by instance, until the ledger row fits the cap.
    /// Returns the bytes freed. Runs at epoch boundaries under grant
    /// enforcement — never on the request path.
    pub fn shed_tenant(&mut self, tenant: TenantId, cap_bytes: u64) -> u64 {
        let resident = self.tenant_resident_bytes(tenant);
        if resident <= cap_bytes {
            return 0;
        }
        let mut want = resident - cap_bytes;
        let mut freed_total = 0u64;
        for inst in &mut self.instances {
            if want == 0 {
                break;
            }
            let have = inst.tenant_bytes_of(tenant);
            if have == 0 {
                continue;
            }
            let freed = inst.evict_tenant(tenant, want.min(have));
            want = want.saturating_sub(freed);
            freed_total += freed;
        }
        if freed_total > 0 {
            self.ledger_sub(tenant, freed_total);
        }
        freed_total
    }

    /// Placement snapshot for the `PLACEMENT` serve command.
    pub fn placement_snapshot(&self) -> PlacementSnapshot {
        let tenants = self
            .tenant_residents()
            .into_iter()
            .map(|(tenant, resident_bytes)| PlacementTenantRow {
                tenant,
                resident_bytes,
                pins: self.placement.pins(tenant).map(|p| p.to_vec()),
            })
            .collect();
        PlacementSnapshot { policy: self.placement.kind(), tenants }
    }

    /// Whether the responsible instance currently holds `obj`.
    pub fn contains(&self, obj: ObjectId) -> bool {
        self.instances[self.route(obj)].contains(obj)
    }

    /// Whether *any* instance holds `obj` — used to count spurious misses
    /// (present somewhere, but not where routing points).
    pub fn resident_anywhere(&self, obj: ObjectId) -> bool {
        self.instances.iter().any(|i| i.contains(obj))
    }

    /// Whether an instance *other than* `except` holds `obj` (stale copy
    /// left behind by a slot move).
    pub fn resident_elsewhere(&self, obj: ObjectId, except: usize) -> bool {
        self.instances
            .iter()
            .enumerate()
            .any(|(i, inst)| i != except && inst.contains(obj))
    }

    /// Slots currently owned by instance index `idx`.
    pub fn slots_of_instance(&self, idx: usize) -> usize {
        self.slot_owner.iter().filter(|&&o| o as usize == idx).count()
    }

    /// Resize the cluster to `target` instances (Algorithm 2 line 8 side
    /// effect). Adding: each new server receives `slots/new_total` randomly
    /// chosen slots. Removing: the victims' slots scatter uniformly over
    /// the survivors (their residents leave the per-tenant ledger with
    /// them). Returns slots moved.
    pub fn resize(&mut self, target: u32) -> u64 {
        let target = target.max(1) as usize;
        let before = self.instances.len();
        if target == before {
            return 0;
        }
        self.resizes += 1;
        let mut moved = 0u64;
        if target > before {
            for _ in before..target {
                let new_idx = self.instances.len() as u32;
                self.instances.push(CacheInstance::new(
                    self.next_id,
                    self.eviction,
                    self.capacity_per_instance,
                    mix64(self.next_id as u64) ^ 0x51AB,
                ));
                self.next_id += 1;
                // Transfer the expected share of slots: pick each slot with
                // probability 1/(current server count).
                let n_now = self.instances.len() as u32;
                let share = self.hash_slots / n_now;
                let mut candidates: Vec<u32> = (0..self.hash_slots).collect();
                self.rng.shuffle(&mut candidates);
                for &slot in candidates.iter().take(share as usize) {
                    if self.slot_owner[slot as usize] != new_idx {
                        self.slot_owner[slot as usize] = new_idx;
                        moved += 1;
                    }
                }
            }
        } else {
            // Remove the highest-index instances; scatter their slots.
            while self.instances.len() > target {
                let victim = (self.instances.len() - 1) as u32;
                let survivors = victim; // indices 0..victim remain
                for slot in 0..self.hash_slots as usize {
                    if self.slot_owner[slot] == victim {
                        self.slot_owner[slot] = self.rng.below(survivors as u64) as u32;
                        moved += 1;
                    }
                }
                // The decommissioned node's residents leave the ledger.
                let gone = self.instances.pop().expect("len > target >= 1");
                for t in 0..self.tenant_resident.len() {
                    let b = gone.tenant_bytes_of(t as TenantId);
                    if b > 0 {
                        self.ledger_sub(t as TenantId, b);
                    }
                }
            }
        }
        self.slots_moved += moved;
        moved
    }

    /// Per-instance snapshot for Fig. 9 (slots / requests / misses,
    /// normalized inside [`BalanceTracker`]).
    pub fn balance_snapshot(&self) -> Vec<(usize, u64, u64)> {
        (0..self.instances.len())
            .map(|i| {
                (
                    self.slots_of_instance(i),
                    self.instances[i].requests,
                    self.instances[i].stats.misses,
                )
            })
            .collect()
    }

    /// Reset per-epoch counters on every instance.
    pub fn reset_epoch_stats(&mut self) {
        for i in &mut self.instances {
            i.reset_epoch_stats();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn mk(n: u32) -> Cluster {
        Cluster::new(&ClusterConfig::default(), 1000 * 1000, n)
    }

    fn mk_placed(n: u32, placement: PlacementKind) -> Cluster {
        let mut cfg = ClusterConfig::default();
        cfg.placement = placement;
        Cluster::new(&cfg, 1000 * 1000, n)
    }

    #[test]
    fn slots_partition_completely() {
        let c = mk(4);
        let total: usize = (0..4).map(|i| c.slots_of_instance(i)).sum();
        assert_eq!(total, 16384);
        // Roughly balanced: each within 15% of 4096.
        for i in 0..4 {
            let s = c.slots_of_instance(i) as f64;
            assert!((s - 4096.0).abs() / 4096.0 < 0.15, "server {i}: {s}");
        }
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let c = mk(3);
        for obj in 0..1000u64 {
            let r = c.route(obj);
            assert!(r < 3);
            assert_eq!(r, c.route(obj));
            // Shared placement: route_for agrees with route for any tenant.
            assert_eq!(c.route_for(0, obj), r);
            assert_eq!(c.route_for(5, obj), r);
        }
    }

    #[test]
    fn serve_hits_after_insert() {
        let mut c = mk(2);
        assert!(!c.serve(42, 100));
        assert!(c.serve(42, 100));
        assert_eq!(c.used(), 100);
        assert_eq!(c.tenant_resident_bytes(0), 100);
        assert_eq!(c.ledger_residents(), c.used());
    }

    #[test]
    fn ledger_tracks_inserts_and_evictions() {
        let mut c = mk(1);
        // Fill past capacity: 15 objects of 100 KB into a 1 MB node.
        for obj in 0..15u64 {
            c.serve_for((obj % 3) as TenantId, obj, 100_000);
        }
        assert_eq!(c.ledger_residents(), c.used());
        let total: u64 = (0..3).map(|t| c.tenant_resident_bytes(t)).sum();
        assert_eq!(total, c.used());
        assert!(c.used() <= 1_000_000);
        // Denied admissions never touch the ledger.
        let before = c.ledger_residents();
        c.serve_no_insert_for(1, 999_999);
        assert_eq!(c.ledger_residents(), before);
    }

    #[test]
    fn shed_tenant_binds_the_ledger_row() {
        let mut c = mk(2);
        for obj in 0..10u64 {
            c.serve_for(1, obj, 50_000);
            c.serve_for(2, 1000 + obj, 50_000);
        }
        assert_eq!(c.tenant_resident_bytes(1), 500_000);
        let freed = c.shed_tenant(1, 200_000);
        assert_eq!(freed, 300_000);
        assert_eq!(c.tenant_resident_bytes(1), 200_000);
        assert_eq!(c.tenant_resident_bytes(2), 500_000, "other tenants untouched");
        assert_eq!(c.ledger_residents(), c.used());
        // Already under the cap: nothing happens.
        assert_eq!(c.shed_tenant(1, 200_000), 0);
    }

    #[test]
    fn shrink_drops_victims_from_the_ledger() {
        let mut c = mk(4);
        for obj in 0..40u64 {
            c.serve_for((obj % 2) as TenantId, obj, 50_000);
        }
        assert_eq!(c.ledger_residents(), c.used());
        c.resize(2);
        assert_eq!(c.ledger_residents(), c.used(), "ledger must follow the shrink");
    }

    #[test]
    fn pinned_placement_confines_tenants_to_their_subsets() {
        let mut c = mk_placed(4, PlacementKind::HashSlotPinned);
        assert_eq!(c.placement_kind(), PlacementKind::HashSlotPinned);
        // Before any grants: shared routing (bit-identical warmup).
        for obj in 0..100u64 {
            assert_eq!(c.route_for(1, obj), c.route(obj));
        }
        // Grants: tenant 1 → 1 instance, tenant 2 → 2 instances.
        c.apply_grants(&[
            TenantGrant { tenant: 1, granted_bytes: 1_000_000, reserved_bytes: 1_000_000 },
            TenantGrant { tenant: 2, granted_bytes: 2_000_000, reserved_bytes: 0 },
        ]);
        let p1 = c.pins_of(1).unwrap().to_vec();
        let p2 = c.pins_of(2).unwrap().to_vec();
        assert_eq!(p1.len(), 1);
        assert_eq!(p2.len(), 2);
        assert!(p1.iter().all(|i| !p2.contains(i)));
        for obj in 0..500u64 {
            assert!(p1.contains(&(c.route_for(1, obj) as u32)));
            assert!(p2.contains(&(c.route_for(2, obj) as u32)));
        }
        // The snapshot surfaces the pins.
        c.serve_for(1, 7, 100);
        let snap = c.placement_snapshot();
        assert_eq!(snap.policy, PlacementKind::HashSlotPinned);
        let row = snap.tenants.iter().find(|r| r.tenant == 1).unwrap();
        assert_eq!(row.resident_bytes, 100);
        assert_eq!(row.pins.as_deref(), Some(&p1[..]));
    }

    #[test]
    fn partition_placement_installs_floors() {
        let mut c = mk_placed(2, PlacementKind::SlabPartition);
        // Routing stays shared.
        for obj in 0..100u64 {
            assert_eq!(c.route_for(3, obj), c.route(obj));
        }
        c.apply_grants(&[TenantGrant {
            tenant: 1,
            granted_bytes: 800_000,
            reserved_bytes: 800_000,
        }]);
        // Tenant 1 fills toward its per-instance floor (400 KB each); a
        // foreign flood may take only its *pooled* overage — the floored
        // share on every instance must survive.
        for obj in 0..8u64 {
            c.serve_for(1, obj, 100_000);
        }
        let protected: u64 = c
            .instances()
            .iter()
            .map(|i| i.tenant_bytes_of(1).min(400_000))
            .sum();
        assert!(protected > 0);
        for obj in 100..160u64 {
            c.serve_for(2, obj, 100_000);
        }
        assert!(
            c.tenant_resident_bytes(1) >= protected,
            "floors must protect tenant 1: {} < {protected}",
            c.tenant_resident_bytes(1)
        );
        assert_eq!(c.ledger_residents(), c.used());
    }

    #[test]
    fn ttl_expiry_misses_and_debits_the_ledger() {
        use std::time::Duration;
        let mut c = mk(2);
        c.enable_ttl_expiry(Duration::from_millis(30));
        assert!(!c.serve_for(1, 42, 100), "cold miss");
        assert!(c.serve_for(1, 42, 100), "hit renews the policy");
        std::thread::sleep(Duration::from_millis(45));
        assert!(!c.serve_for(1, 42, 100), "expired entry reads as a miss");
        assert_eq!(c.ledger_residents(), c.used(), "expiry must debit the ledger");
        assert_eq!(c.expiry_stats(), Some((1, 100)));
        // The miss reinserted the object with a fresh policy.
        assert!(c.serve_for(1, 42, 100));
        // The epoch-boundary sweep reaps without an access.
        std::thread::sleep(Duration::from_millis(45));
        let (n, b) = c.expire_sweep();
        assert_eq!((n, b), (1, 100));
        assert_eq!(c.used(), 0);
        assert_eq!(c.ledger_residents(), 0);
        // Expiry off: the sweep is a no-op.
        let mut plain = mk(1);
        assert_eq!(plain.expire_sweep(), (0, 0));
        assert_eq!(plain.expiry_stats(), None);
    }

    #[test]
    fn grow_moves_expected_share() {
        let mut c = mk(4);
        let moved = c.resize(5);
        // New server should own ≈ 16384/5 ≈ 3276 slots.
        let share = c.slots_of_instance(4) as f64;
        assert!((share - 3276.8).abs() / 3276.8 < 0.05, "share={share}");
        assert!(moved > 0);
        assert_eq!(c.len(), 5);
        let total: usize = (0..5).map(|i| c.slots_of_instance(i)).sum();
        assert_eq!(total, 16384);
    }

    #[test]
    fn shrink_scatters_slots() {
        let mut c = mk(5);
        c.resize(3);
        assert_eq!(c.len(), 3);
        let total: usize = (0..3).map(|i| c.slots_of_instance(i)).sum();
        assert_eq!(total, 16384);
        for i in 0..3 {
            assert!(c.slots_of_instance(i) > 3000, "server {i} starved");
        }
    }

    #[test]
    fn resize_to_same_is_noop() {
        let mut c = mk(4);
        assert_eq!(c.resize(4), 0);
        assert_eq!(c.resizes, 0);
    }

    #[test]
    fn spurious_miss_after_resize() {
        let mut c = mk(2);
        // Fill with objects, then grow; some objects now route elsewhere
        // while the copies are still resident on the old owner.
        for obj in 0..2000u64 {
            c.serve(obj, 10);
        }
        c.resize(3);
        let mut spurious = 0;
        for obj in 0..2000u64 {
            if !c.contains(obj) && c.resident_anywhere(obj) {
                spurious += 1;
            }
        }
        // With 1/3 of slots moved, a sizeable fraction must be spurious.
        assert!(spurious > 200, "spurious={spurious}");
    }

    #[test]
    fn min_one_instance() {
        let mut c = mk(2);
        c.resize(0);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn instance_ids_never_reused() {
        let mut c = mk(2);
        c.resize(4);
        c.resize(2);
        c.resize(4);
        let ids: Vec<u32> = c.instances().iter().map(|i| i.id).collect();
        // First two survive; later adds got fresh ids (2,3 then 4,5).
        assert_eq!(ids[0], 0);
        assert_eq!(ids[1], 1);
        assert_eq!(ids[2], 4);
        assert_eq!(ids[3], 5);
    }
}
