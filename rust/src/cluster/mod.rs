//! Horizontally scalable cache cluster behind a Redis-style two-step
//! hash-slot scheme (§6.2, Fig. 9): 16384 slots; object keys hash into a
//! slot; each slot is assigned to a server. Adding a server transfers
//! randomly selected slots to it; removing one scatters its slots over
//! the survivors.
//!
//! Slot moves create **spurious misses** (§5.2): the object is resident on
//! the old owner, but requests now route to the new owner, which misses.
//! We model this faithfully — stale copies linger on the old owner until
//! its LRU churns them out.

mod balance;

pub use balance::{BalanceSnapshot, BalanceTracker};

use crate::cache::CacheInstance;
use crate::config::{ClusterConfig, EvictionKind};
use crate::{mix64, ObjectId};
use crate::util::rng::Pcg;

/// A homogeneous cluster of cache instances plus the slot map.
pub struct Cluster {
    instances: Vec<CacheInstance>,
    /// slot → index into `instances`.
    slot_owner: Vec<u32>,
    hash_slots: u32,
    eviction: EvictionKind,
    capacity_per_instance: u64,
    next_id: u32,
    rng: Pcg,
    /// Cumulative slots moved by resizes (each move risks spurious misses).
    pub slots_moved: u64,
    /// Number of resize events that changed the instance count.
    pub resizes: u64,
}

impl Cluster {
    /// Create a cluster of `n ≥ 1` instances.
    pub fn new(cfg: &ClusterConfig, capacity_per_instance: u64, n: u32) -> Self {
        let n = n.max(1);
        let mut rng = Pcg::seed_from_u64(cfg.seed);
        let mut instances = Vec::with_capacity(n as usize);
        for id in 0..n {
            instances.push(CacheInstance::new(id, cfg.eviction, capacity_per_instance, cfg.seed));
        }
        // Initial assignment: round-robin then shuffle, so each server owns
        // ~slots/n with random placement (as Redis' random assignment).
        let mut slot_owner: Vec<u32> = (0..cfg.hash_slots).map(|s| s % n).collect();
        rng.shuffle(&mut slot_owner);
        Cluster {
            instances,
            slot_owner,
            hash_slots: cfg.hash_slots,
            eviction: cfg.eviction,
            capacity_per_instance,
            next_id: n,
            rng,
            slots_moved: 0,
            resizes: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    pub fn instances(&self) -> &[CacheInstance] {
        &self.instances
    }

    pub fn instances_mut(&mut self) -> &mut [CacheInstance] {
        &mut self.instances
    }

    pub fn capacity_per_instance(&self) -> u64 {
        self.capacity_per_instance
    }

    /// Total bytes resident across instances.
    pub fn used(&self) -> u64 {
        self.instances.iter().map(|i| i.used()).sum()
    }

    /// Hash slot of an object key (two-step scheme, step 1).
    #[inline]
    pub fn slot_of(&self, obj: ObjectId) -> u32 {
        (mix64(obj) % self.hash_slots as u64) as u32
    }

    /// Index of the instance responsible for `obj` (step 2).
    #[inline]
    pub fn route(&self, obj: ObjectId) -> usize {
        self.slot_owner[self.slot_of(obj) as usize] as usize
    }

    /// Serve a request through the slot map. Returns `true` on hit.
    #[inline]
    pub fn serve(&mut self, obj: ObjectId, size: u64) -> bool {
        let idx = self.route(obj);
        self.instances[idx].serve(obj, size)
    }

    /// Serve a request *without* inserting on miss (the balancer refused
    /// admission — multi-tenant occupancy-cap enforcement). Hit/miss
    /// accounting is identical to [`Self::serve`].
    #[inline]
    pub fn serve_no_insert(&mut self, obj: ObjectId) -> bool {
        let idx = self.route(obj);
        self.instances[idx].lookup_only(obj)
    }

    /// Whether the responsible instance currently holds `obj`.
    pub fn contains(&self, obj: ObjectId) -> bool {
        self.instances[self.route(obj)].contains(obj)
    }

    /// Whether *any* instance holds `obj` — used to count spurious misses
    /// (present somewhere, but not where routing points).
    pub fn resident_anywhere(&self, obj: ObjectId) -> bool {
        self.instances.iter().any(|i| i.contains(obj))
    }

    /// Whether an instance *other than* `except` holds `obj` (stale copy
    /// left behind by a slot move).
    pub fn resident_elsewhere(&self, obj: ObjectId, except: usize) -> bool {
        self.instances
            .iter()
            .enumerate()
            .any(|(i, inst)| i != except && inst.contains(obj))
    }

    /// Slots currently owned by instance index `idx`.
    pub fn slots_of_instance(&self, idx: usize) -> usize {
        self.slot_owner.iter().filter(|&&o| o as usize == idx).count()
    }

    /// Resize the cluster to `target` instances (Algorithm 2 line 8 side
    /// effect). Adding: each new server receives `slots/new_total` randomly
    /// chosen slots. Removing: the victims' slots scatter uniformly over
    /// the survivors. Returns slots moved.
    pub fn resize(&mut self, target: u32) -> u64 {
        let target = target.max(1) as usize;
        let before = self.instances.len();
        if target == before {
            return 0;
        }
        self.resizes += 1;
        let mut moved = 0u64;
        if target > before {
            for _ in before..target {
                let new_idx = self.instances.len() as u32;
                self.instances.push(CacheInstance::new(
                    self.next_id,
                    self.eviction,
                    self.capacity_per_instance,
                    mix64(self.next_id as u64) ^ 0x51AB,
                ));
                self.next_id += 1;
                // Transfer the expected share of slots: pick each slot with
                // probability 1/(current server count).
                let n_now = self.instances.len() as u32;
                let share = self.hash_slots / n_now;
                let mut candidates: Vec<u32> = (0..self.hash_slots).collect();
                self.rng.shuffle(&mut candidates);
                for &slot in candidates.iter().take(share as usize) {
                    if self.slot_owner[slot as usize] != new_idx {
                        self.slot_owner[slot as usize] = new_idx;
                        moved += 1;
                    }
                }
            }
        } else {
            // Remove the highest-index instances; scatter their slots.
            while self.instances.len() > target {
                let victim = (self.instances.len() - 1) as u32;
                let survivors = victim; // indices 0..victim remain
                for slot in 0..self.hash_slots as usize {
                    if self.slot_owner[slot] == victim {
                        self.slot_owner[slot] = self.rng.below(survivors as u64) as u32;
                        moved += 1;
                    }
                }
                self.instances.pop();
            }
        }
        self.slots_moved += moved;
        moved
    }

    /// Per-instance snapshot for Fig. 9 (slots / requests / misses,
    /// normalized inside [`BalanceTracker`]).
    pub fn balance_snapshot(&self) -> Vec<(usize, u64, u64)> {
        (0..self.instances.len())
            .map(|i| {
                (
                    self.slots_of_instance(i),
                    self.instances[i].requests,
                    self.instances[i].stats.misses,
                )
            })
            .collect()
    }

    /// Reset per-epoch counters on every instance.
    pub fn reset_epoch_stats(&mut self) {
        for i in &mut self.instances {
            i.reset_epoch_stats();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn mk(n: u32) -> Cluster {
        Cluster::new(&ClusterConfig::default(), 1000 * 1000, n)
    }

    #[test]
    fn slots_partition_completely() {
        let c = mk(4);
        let total: usize = (0..4).map(|i| c.slots_of_instance(i)).sum();
        assert_eq!(total, 16384);
        // Roughly balanced: each within 15% of 4096.
        for i in 0..4 {
            let s = c.slots_of_instance(i) as f64;
            assert!((s - 4096.0).abs() / 4096.0 < 0.15, "server {i}: {s}");
        }
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let c = mk(3);
        for obj in 0..1000u64 {
            let r = c.route(obj);
            assert!(r < 3);
            assert_eq!(r, c.route(obj));
        }
    }

    #[test]
    fn serve_hits_after_insert() {
        let mut c = mk(2);
        assert!(!c.serve(42, 100));
        assert!(c.serve(42, 100));
        assert_eq!(c.used(), 100);
    }

    #[test]
    fn grow_moves_expected_share() {
        let mut c = mk(4);
        let moved = c.resize(5);
        // New server should own ≈ 16384/5 ≈ 3276 slots.
        let share = c.slots_of_instance(4) as f64;
        assert!((share - 3276.8).abs() / 3276.8 < 0.05, "share={share}");
        assert!(moved > 0);
        assert_eq!(c.len(), 5);
        let total: usize = (0..5).map(|i| c.slots_of_instance(i)).sum();
        assert_eq!(total, 16384);
    }

    #[test]
    fn shrink_scatters_slots() {
        let mut c = mk(5);
        c.resize(3);
        assert_eq!(c.len(), 3);
        let total: usize = (0..3).map(|i| c.slots_of_instance(i)).sum();
        assert_eq!(total, 16384);
        for i in 0..3 {
            assert!(c.slots_of_instance(i) > 3000, "server {i} starved");
        }
    }

    #[test]
    fn resize_to_same_is_noop() {
        let mut c = mk(4);
        assert_eq!(c.resize(4), 0);
        assert_eq!(c.resizes, 0);
    }

    #[test]
    fn spurious_miss_after_resize() {
        let mut c = mk(2);
        // Fill with objects, then grow; some objects now route elsewhere
        // while the copies are still resident on the old owner.
        for obj in 0..2000u64 {
            c.serve(obj, 10);
        }
        c.resize(3);
        let mut spurious = 0;
        for obj in 0..2000u64 {
            if !c.contains(obj) && c.resident_anywhere(obj) {
                spurious += 1;
            }
        }
        // With 1/3 of slots moved, a sizeable fraction must be spurious.
        assert!(spurious > 200, "spurious={spurious}");
    }

    #[test]
    fn min_one_instance() {
        let mut c = mk(2);
        c.resize(0);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn instance_ids_never_reused() {
        let mut c = mk(2);
        c.resize(4);
        c.resize(2);
        c.resize(4);
        let ids: Vec<u32> = c.instances().iter().map(|i| i.id).collect();
        // First two survive; later adds got fresh ids (2,3 then 4,5).
        assert_eq!(ids[0], 0);
        assert_eq!(ids[1], 1);
        assert_eq!(ids[2], 4);
        assert_eq!(ids[3], 5);
    }
}
