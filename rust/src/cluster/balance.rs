//! Fig. 9 — load balance across servers: per-interval min/max of slots,
//! requests and misses per server, normalized by the per-server
//! expectation. The paper reports slots within ±2.5%, misses up to +10%,
//! requests up to +30% of the mean.

use crate::metrics::TimeSeries;
use crate::TimeUs;

/// One interval's normalized spread for a metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Spread {
    /// min(server metric) / mean(server metric); 1.0 when perfectly even.
    pub min_norm: f64,
    /// max(server metric) / mean(server metric).
    pub max_norm: f64,
}

impl Spread {
    fn of(values: &[u64]) -> Option<Spread> {
        if values.is_empty() {
            return None;
        }
        let sum: u64 = values.iter().sum();
        if sum == 0 {
            return None;
        }
        let mean = sum as f64 / values.len() as f64;
        let min = *values.iter().min().unwrap() as f64;
        let max = *values.iter().max().unwrap() as f64;
        Some(Spread { min_norm: min / mean, max_norm: max / mean })
    }
}

/// Per-epoch snapshot of all three spreads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BalanceSnapshot {
    pub t: TimeUs,
    pub servers: usize,
    pub slots: Option<Spread>,
    pub requests: Option<Spread>,
    pub misses: Option<Spread>,
}

/// Accumulates snapshots into the six Fig. 9 series.
#[derive(Debug, Default)]
pub struct BalanceTracker {
    pub slots_min: TimeSeries,
    pub slots_max: TimeSeries,
    pub requests_min: TimeSeries,
    pub requests_max: TimeSeries,
    pub misses_min: TimeSeries,
    pub misses_max: TimeSeries,
    snapshots: Vec<BalanceSnapshot>,
}

impl BalanceTracker {
    pub fn new() -> Self {
        let mut t = BalanceTracker::default();
        t.slots_min = TimeSeries::new("slots_min");
        t.slots_max = TimeSeries::new("slots_max");
        t.requests_min = TimeSeries::new("requests_min");
        t.requests_max = TimeSeries::new("requests_max");
        t.misses_min = TimeSeries::new("misses_min");
        t.misses_max = TimeSeries::new("misses_max");
        t
    }

    /// Record one epoch's `(slots, requests, misses)` per server.
    pub fn record(&mut self, t: TimeUs, per_server: &[(usize, u64, u64)]) -> BalanceSnapshot {
        let slots: Vec<u64> = per_server.iter().map(|x| x.0 as u64).collect();
        let reqs: Vec<u64> = per_server.iter().map(|x| x.1).collect();
        let miss: Vec<u64> = per_server.iter().map(|x| x.2).collect();
        let snap = BalanceSnapshot {
            t,
            servers: per_server.len(),
            slots: Spread::of(&slots),
            requests: Spread::of(&reqs),
            misses: Spread::of(&miss),
        };
        if let Some(s) = snap.slots {
            self.slots_min.push(t, s.min_norm);
            self.slots_max.push(t, s.max_norm);
        }
        if let Some(s) = snap.requests {
            self.requests_min.push(t, s.min_norm);
            self.requests_max.push(t, s.max_norm);
        }
        if let Some(s) = snap.misses {
            self.misses_min.push(t, s.min_norm);
            self.misses_max.push(t, s.max_norm);
        }
        self.snapshots.push(snap);
        snap
    }

    pub fn snapshots(&self) -> &[BalanceSnapshot] {
        &self.snapshots
    }

    /// Worst (largest) max_norm observed for each metric across the run.
    pub fn worst(&self) -> (f64, f64, f64) {
        (
            self.slots_max.max().unwrap_or(1.0),
            self.requests_max.max().unwrap_or(1.0),
            self.misses_max.max().unwrap_or(1.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_of_even_load_is_one() {
        let s = Spread::of(&[100, 100, 100]).unwrap();
        assert_eq!(s.min_norm, 1.0);
        assert_eq!(s.max_norm, 1.0);
    }

    #[test]
    fn spread_detects_imbalance() {
        let s = Spread::of(&[50, 100, 150]).unwrap();
        assert!((s.min_norm - 0.5).abs() < 1e-12);
        assert!((s.max_norm - 1.5).abs() < 1e-12);
    }

    #[test]
    fn spread_handles_degenerate_inputs() {
        assert!(Spread::of(&[]).is_none());
        assert!(Spread::of(&[0, 0]).is_none());
        let one = Spread::of(&[7]).unwrap();
        assert_eq!(one.min_norm, 1.0);
        assert_eq!(one.max_norm, 1.0);
    }

    #[test]
    fn tracker_accumulates_series() {
        let mut t = BalanceTracker::new();
        t.record(0, &[(10, 100, 5), (10, 200, 15)]);
        t.record(100, &[(12, 150, 9), (8, 150, 11)]);
        assert_eq!(t.snapshots().len(), 2);
        assert_eq!(t.requests_max.len(), 2);
        let (ws, wr, wm) = t.worst();
        assert!(ws >= 1.0 && wr > 1.3 && wm > 1.4);
    }

    #[test]
    fn single_server_is_perfectly_balanced() {
        let mut t = BalanceTracker::new();
        let snap = t.record(0, &[(16384, 1000, 30)]);
        assert_eq!(snap.slots.unwrap().max_norm, 1.0);
        assert_eq!(snap.requests.unwrap().max_norm, 1.0);
    }
}
