//! The analytic planner: estimate per-content popularity over the epoch,
//! bucket it, evaluate the AOT cost model, and size the cluster at the
//! model-predicted optimum.
//!
//! This is the L1/L2/L3 integration point and an *ablation* against the
//! paper's stochastic-approximation controller: the SA controller needs no
//! popularity estimates and is O(1) per request; the planner pays an
//! O(K log K) sort per epoch (K = distinct objects seen) in exchange for
//! jumping straight to the model optimum when traffic is IRM-like.

use super::{reference_curves, CostCurveModel, CostCurves};
use crate::config::{Config, CostConfig};
use crate::scaler::{EpochSizer, PolicyWork};
use crate::{ObjectId, TimeUs};
use crate::util::fasthash::FastMap;

/// Per-epoch popularity estimator: exact counts in a hash map, reset at
/// each epoch boundary. (A production deployment could swap a sketch in;
/// the planner already isolates it behind this type.)
#[derive(Debug, Default)]
pub struct PopularityEstimator {
    counts: FastMap<ObjectId, (u32, u32)>, // obj -> (requests, size)
    epoch_start: TimeUs,
}

impl PopularityEstimator {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&mut self, obj: ObjectId, size: u64) {
        let e = self.counts.entry(obj).or_insert((0, size as u32));
        e.0 += 1;
    }

    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Drain the epoch into bucketed per-content statistics.
    pub fn drain(&mut self, now: TimeUs, n_buckets: usize, cost: &CostConfig) -> BucketedStats {
        let epoch_secs = crate::us_to_secs(now.saturating_sub(self.epoch_start)).max(1.0);
        let mut items: Vec<(u32, u32)> = self.counts.values().copied().collect();
        self.counts.clear();
        self.epoch_start = now;
        // Hottest first: head buckets get one object each, the tail is
        // aggregated — preserving the head of the Zipf curve exactly.
        items.sort_unstable_by(|a, b| b.0.cmp(&a.0));
        BucketedStats::build(&items, n_buckets, epoch_secs, cost)
    }
}

/// Bucketed arrays matching the artifact input layout.
#[derive(Debug, Clone)]
pub struct BucketedStats {
    pub lam: Vec<f32>,
    pub miss_cost: Vec<f32>,
    pub storage_rate: Vec<f32>,
    pub size: Vec<f32>,
    pub weight: Vec<f32>,
    /// Total request rate represented (diagnostic).
    pub total_rate: f64,
}

impl BucketedStats {
    /// `items` sorted by descending count: the first `n_buckets − tail`
    /// objects occupy one bucket each; the remainder is pooled into the
    /// tail buckets by rank slices.
    pub fn build(
        items: &[(u32, u32)],
        n_buckets: usize,
        epoch_secs: f64,
        cost: &CostConfig,
    ) -> BucketedStats {
        let n = n_buckets.max(1);
        let mut out = BucketedStats {
            lam: vec![0.0; n],
            miss_cost: vec![0.0; n],
            storage_rate: vec![0.0; n],
            size: vec![0.0; n],
            weight: vec![0.0; n],
            total_rate: 0.0,
        };
        if items.is_empty() {
            return out;
        }
        // Head: one object per bucket while both last.
        let head = items.len().min(n.saturating_sub(1).max(1));
        for (b, &(count, size)) in items.iter().take(head).enumerate() {
            let lam = count as f64 / epoch_secs;
            out.lam[b] = lam as f32;
            out.miss_cost[b] = cost.miss_cost(size as u64) as f32;
            out.storage_rate[b] = cost.storage_rate(size as u64) as f32;
            out.size[b] = size as f32;
            out.weight[b] = 1.0;
            out.total_rate += lam;
        }
        // Tail: pool everything else into the final bucket with averaged
        // rate/size (homogenized tail — the classic IRM bucketing).
        if items.len() > head {
            let tail = &items[head..];
            let count_sum: f64 = tail.iter().map(|x| x.0 as f64).sum();
            let size_mean: f64 =
                tail.iter().map(|x| x.1 as f64).sum::<f64>() / tail.len() as f64;
            let lam_mean = count_sum / epoch_secs / tail.len() as f64;
            let b = n - 1;
            out.lam[b] = lam_mean as f32;
            out.miss_cost[b] = cost.miss_cost(size_mean as u64) as f32;
            out.storage_rate[b] = cost.storage_rate(size_mean as u64) as f32;
            out.size[b] = size_mean as f32;
            out.weight[b] = tail.len() as f32;
            out.total_rate += count_sum / epoch_secs;
        }
        out
    }
}

/// One planning decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanDecision {
    /// Model-optimal TTL, seconds.
    pub t_star_secs: f64,
    /// Predicted cost rate at the optimum, $/s.
    pub cost_rate: f64,
    /// Predicted steady-state virtual size at the optimum, bytes.
    pub vsize_bytes: f64,
    /// Cluster size implied by the virtual size.
    pub instances: u32,
}

/// Wraps the artifact (or the Rust oracle) + the T grid.
pub struct Planner {
    model: Option<CostCurveModel>,
    n: usize,
    t_grid: Vec<f32>,
}

impl Planner {
    /// Geometric T grid from 1 s to `t_max` over `g` points (dense where
    /// the cost curve bends).
    pub fn t_grid(g: usize, t_max: f64) -> Vec<f32> {
        let g = g.max(2);
        (0..g)
            .map(|i| {
                if i == 0 {
                    0.0
                } else {
                    let f = (i - 1) as f64 / (g - 2).max(1) as f64;
                    (t_max.max(2.0).powf(f)) as f32
                }
            })
            .collect()
    }

    /// Load the AOT artifact; `Err` only on a malformed artifact — a
    /// *missing* artifact falls back to the Rust oracle (useful for tests;
    /// `make artifacts` enables the PJRT path).
    pub fn load(dir: impl AsRef<std::path::Path>, t_max: f64) -> Self {
        match CostCurveModel::load(dir, None) {
            Ok(m) => {
                let g = m.g;
                let n = m.n;
                Planner { model: Some(m), n, t_grid: Self::t_grid(g, t_max) }
            }
            Err(_) => Planner { model: None, n: 1024, t_grid: Self::t_grid(128, t_max) },
        }
    }

    /// Oracle-only planner (no PJRT) with explicit shapes.
    pub fn oracle(n: usize, g: usize, t_max: f64) -> Self {
        Planner { model: None, n, t_grid: Self::t_grid(g, t_max) }
    }

    pub fn uses_artifact(&self) -> bool {
        self.model.is_some()
    }

    pub fn n_buckets(&self) -> usize {
        self.n
    }

    /// Evaluate the cost curves for bucketed stats.
    pub fn curves(&self, stats: &BucketedStats) -> crate::Result<CostCurves> {
        match &self.model {
            Some(m) => m.evaluate(
                &stats.lam,
                &stats.miss_cost,
                &stats.storage_rate,
                &stats.size,
                &stats.weight,
                &self.t_grid,
            ),
            None => Ok(reference_curves(
                &stats.lam,
                &stats.miss_cost,
                &stats.storage_rate,
                &stats.size,
                &stats.weight,
                &self.t_grid,
            )),
        }
    }

    /// Full decision: argmin T, implied size and instance count.
    pub fn plan(&self, stats: &BucketedStats, instance_bytes: u64) -> crate::Result<PlanDecision> {
        let curves = self.curves(stats)?;
        let i = curves.argmin_cost();
        let vsize = curves.vsize[i] as f64;
        Ok(PlanDecision {
            t_star_secs: curves.t_grid[i] as f64,
            cost_rate: curves.cost[i] as f64,
            vsize_bytes: vsize,
            instances: (vsize / instance_bytes.max(1) as f64).round() as u32,
        })
    }
}

/// Model-driven epoch sizer (the `PolicyKind::Analytic` ablation).
pub struct AnalyticSizer {
    estimator: PopularityEstimator,
    planner: Planner,
    cost: CostConfig,
    instance_bytes: u64,
    min_instances: u32,
    max_instances: u32,
    last_plan: Option<PlanDecision>,
}

impl AnalyticSizer {
    pub fn new(cfg: &Config, planner: Planner) -> Self {
        AnalyticSizer {
            estimator: PopularityEstimator::new(),
            planner,
            cost: cfg.cost.clone(),
            instance_bytes: cfg.cost.instance.ram_bytes,
            min_instances: cfg.scaler.min_instances.max(1),
            max_instances: cfg.scaler.max_instances.max(1),
            last_plan: None,
        }
    }

    /// Build with the default artifacts dir.
    pub fn from_config(cfg: &Config) -> Self {
        let planner = Planner::load(super::artifacts_dir(), cfg.controller.t_max_secs);
        Self::new(cfg, planner)
    }

    pub fn last_plan(&self) -> Option<PlanDecision> {
        self.last_plan
    }
}

impl EpochSizer for AnalyticSizer {
    fn on_request(&mut self, req: &crate::trace::Request) -> PolicyWork {
        let obj = crate::tenant::scoped_object(req.tenant, req.obj);
        self.estimator.record(obj, req.size_bytes());
        PolicyWork { units: 2, shadow_hit: None, admit: true }
    }

    fn decide(&mut self, now: TimeUs) -> u32 {
        let stats = self
            .estimator
            .drain(now, self.planner.n_buckets(), &self.cost);
        match self.planner.plan(&stats, self.instance_bytes) {
            Ok(plan) => {
                let n = plan.instances.clamp(self.min_instances, self.max_instances);
                self.last_plan = Some(plan);
                n
            }
            Err(_) => self
                .last_plan
                .map(|p| p.instances.clamp(self.min_instances, self.max_instances))
                .unwrap_or(self.min_instances),
        }
    }

    fn name(&self) -> &'static str {
        "analytic"
    }

    fn ttl_secs(&self) -> Option<f64> {
        self.last_plan.map(|p| p.t_star_secs)
    }

    fn shadow_size(&self) -> Option<u64> {
        self.last_plan.map(|p| p.vsize_bytes as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::HOUR;

    #[test]
    fn t_grid_shape() {
        let g = Planner::t_grid(16, 3600.0);
        assert_eq!(g.len(), 16);
        assert_eq!(g[0], 0.0);
        assert!((g[1] - 1.0).abs() < 1e-6);
        assert!((g[15] - 3600.0).abs() / 3600.0 < 1e-5);
        for w in g.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bucketing_head_exact_tail_pooled() {
        let cost = CostConfig::default();
        let items: Vec<(u32, u32)> = (0..100).map(|i| (1000 - i * 10, 1000)).collect();
        let s = BucketedStats::build(&items, 8, 100.0, &cost);
        // 7 head buckets hold the 7 hottest; bucket 7 pools the other 93.
        assert_eq!(s.weight[0], 1.0);
        assert_eq!(s.weight[7], 93.0);
        assert!(s.lam[0] > s.lam[1]);
        assert!((s.lam[0] - 10.0).abs() < 1e-6); // 1000 reqs / 100 s
        let rate_sum: f64 = items.iter().map(|x| x.0 as f64 / 100.0).sum();
        assert!((s.total_rate - rate_sum).abs() / rate_sum < 1e-9);
    }

    #[test]
    fn bucketing_handles_empty_and_small() {
        let cost = CostConfig::default();
        let s = BucketedStats::build(&[], 4, 10.0, &cost);
        assert_eq!(s.total_rate, 0.0);
        let s2 = BucketedStats::build(&[(5, 100)], 4, 10.0, &cost);
        assert!((s2.lam[0] - 0.5).abs() < 1e-6);
        assert_eq!(s2.weight[1], 0.0);
    }

    #[test]
    fn oracle_planner_finds_interior_optimum() {
        // A population where neither T=0 nor T=∞ is optimal: hot objects
        // worth caching, cold giants not.
        let cost = CostConfig::default();
        let mut items: Vec<(u32, u32)> = Vec::new();
        for _ in 0..50 {
            items.push((3600, 10_000)); // 1 r/s, 10 KB — cache these
        }
        for _ in 0..5000 {
            items.push((1, 20_000_000)); // one-hit 20 MB — do not cache
        }
        let stats = BucketedStats::build(&items, 128, 3600.0, &cost);
        let planner = Planner::oracle(128, 64, 24.0 * 3600.0);
        let plan = planner.plan(&stats, cost.instance.ram_bytes).unwrap();
        assert!(plan.t_star_secs > 0.0, "T*=0 would cache nothing");
        assert!(
            plan.t_star_secs < 24.0 * 3600.0,
            "T*=Tmax would store the giants"
        );
        assert!(plan.vsize_bytes > 0.0);
    }

    #[test]
    fn analytic_sizer_full_epoch_cycle() {
        let mut cfg = Config::default();
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.cost.instance.dollars_per_hour = 0.017 * 1.0e6 / 555.0e6;
        cfg.scaler.max_instances = 50;
        let planner = Planner::oracle(256, 64, cfg.controller.t_max_secs);
        let mut s = AnalyticSizer::new(&cfg, planner);
        // Hot working set of ~3 MB requested many times in the epoch.
        for round in 0..50u64 {
            for i in 0..30u64 {
                s.on_request(&crate::trace::Request::new(round, i, 100_000));
            }
        }
        let n = s.decide(HOUR);
        assert!(n >= 2, "n={n}, plan={:?}", s.last_plan());
        assert!(s.ttl_secs().unwrap() > 0.0);
        // Second epoch with no traffic: plan size collapses.
        let n2 = s.decide(2 * HOUR);
        assert_eq!(n2, cfg.scaler.min_instances);
    }
}
