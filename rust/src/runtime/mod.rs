//! PJRT runtime: loads the AOT-compiled JAX/Pallas cost-model artifact
//! (HLO text, see `python/compile/aot.py`) and exposes it to the
//! coordinator as the **analytic planner**.
//!
//! The artifact evaluates the paper's IRM cost model (eq. 4) on bucketed
//! per-content statistics:
//!
//! ```text
//! cost(T)  = Σ_i w_i ( c_i + (λ_i m_i − c_i) e^{−λ_i T} )     [$ / s]
//! vsize(T) = Σ_i w_i s_i (1 − e^{−λ_i T})                     [bytes]
//! missrate(T) = Σ_i w_i λ_i e^{−λ_i T}                        [1 / s]
//! ```
//!
//! over a grid of T values. Python runs only at build time (`make
//! artifacts`); this module executes the compiled HLO on the PJRT CPU
//! client from the Rust side — never on the request path, only at epoch
//! boundaries.

mod planner;

pub use planner::{AnalyticSizer, BucketedStats, PlanDecision, Planner, PopularityEstimator};

use crate::Result;
use std::path::{Path, PathBuf};

/// Shape manifest entry (mirrors python/compile/aot.py output).
///
/// The manifest is a plain-text file `artifacts/manifest.txt` with one
/// whitespace-separated record per line: `name n g path dtype`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub n: usize,
    pub g: usize,
    pub path: String,
    pub dtype: String,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let p = dir.as_ref().join("manifest.txt");
        let text = std::fs::read_to_string(&p)
            .map_err(|e| anyhow::anyhow!("manifest {}: {e}; run `make artifacts`", p.display()))?;
        Self::parse(&text)
    }

    /// Parse the manifest text format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(
                parts.len() == 5,
                "manifest line {}: expected `name n g path dtype`, got {line:?}",
                lineno + 1
            );
            artifacts.push(ArtifactSpec {
                name: parts[0].to_string(),
                n: parts[1].parse()?,
                g: parts[2].parse()?,
                path: parts[3].to_string(),
                dtype: parts[4].to_string(),
            });
        }
        Ok(Manifest { artifacts })
    }

    /// Render back to the manifest text format.
    pub fn render(&self) -> String {
        let mut out = String::from("# name n g path dtype\n");
        for a in &self.artifacts {
            out.push_str(&format!("{} {} {} {} {}\n", a.name, a.n, a.g, a.path, a.dtype));
        }
        out
    }

    /// Find the cost-curve artifact with bucket count `n`, or the largest
    /// available if `n` is None.
    pub fn find_cost_curve(&self, n: Option<usize>) -> Option<&ArtifactSpec> {
        let mut specs: Vec<&ArtifactSpec> = self
            .artifacts
            .iter()
            .filter(|a| a.name == "cost_curve")
            .collect();
        specs.sort_by_key(|a| a.n);
        match n {
            Some(n) => specs.into_iter().find(|a| a.n == n),
            None => specs.into_iter().last(),
        }
    }
}

/// The default artifacts directory (workspace-relative), overridable via
/// `ELASTICTL_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("ELASTICTL_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Walk up from cwd looking for `artifacts/manifest.json` (tests run
    // from the workspace root; binaries may run elsewhere).
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Evaluated curves for one planning call.
#[derive(Debug, Clone)]
pub struct CostCurves {
    /// T grid, seconds.
    pub t_grid: Vec<f32>,
    /// $/s at each T.
    pub cost: Vec<f32>,
    /// Expected virtual size (bytes) at each T.
    pub vsize: Vec<f32>,
    /// Misses/s at each T.
    pub missrate: Vec<f32>,
}

impl CostCurves {
    /// Index of the minimum-cost grid point.
    pub fn argmin_cost(&self) -> usize {
        self.cost
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// A loaded, compiled cost-curve executable.
pub struct CostCurveModel {
    exe: xla::PjRtLoadedExecutable,
    pub n: usize,
    pub g: usize,
}

impl CostCurveModel {
    /// Load + compile the artifact for bucket count `n` (or largest).
    pub fn load(dir: impl AsRef<Path>, n: Option<usize>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let spec = manifest
            .find_cost_curve(n)
            .ok_or_else(|| anyhow::anyhow!("no cost_curve artifact (n={n:?}) in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(dir.join(&spec.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(CostCurveModel { exe, n: spec.n, g: spec.g })
    }

    /// Evaluate the curves. All per-bucket arrays must have length `n`;
    /// `t_grid` must have length `g`.
    pub fn evaluate(
        &self,
        lam: &[f32],
        miss_cost: &[f32],
        storage_rate: &[f32],
        size: &[f32],
        weight: &[f32],
        t_grid: &[f32],
    ) -> Result<CostCurves> {
        for (name, a) in [
            ("lam", lam),
            ("miss_cost", miss_cost),
            ("storage_rate", storage_rate),
            ("size", size),
            ("weight", weight),
        ] {
            anyhow::ensure!(a.len() == self.n, "{name}: len {} != n {}", a.len(), self.n);
        }
        anyhow::ensure!(t_grid.len() == self.g, "t_grid: len {} != g {}", t_grid.len(), self.g);

        let args = [
            xla::Literal::vec1(lam),
            xla::Literal::vec1(miss_cost),
            xla::Literal::vec1(storage_rate),
            xla::Literal::vec1(size),
            xla::Literal::vec1(weight),
            xla::Literal::vec1(t_grid),
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (cost, vsize, missrate) = result.to_tuple3()?;
        Ok(CostCurves {
            t_grid: t_grid.to_vec(),
            cost: cost.to_vec::<f32>()?,
            vsize: vsize.to_vec::<f32>()?,
            missrate: missrate.to_vec::<f32>()?,
        })
    }
}

/// Pure-Rust oracle of the same model — used for validating the artifact
/// round-trip and as a fallback when artifacts are absent.
pub fn reference_curves(
    lam: &[f32],
    miss_cost: &[f32],
    storage_rate: &[f32],
    size: &[f32],
    weight: &[f32],
    t_grid: &[f32],
) -> CostCurves {
    let mut cost = vec![0f32; t_grid.len()];
    let mut vsize = vec![0f32; t_grid.len()];
    let mut missrate = vec![0f32; t_grid.len()];
    for (g, &t) in t_grid.iter().enumerate() {
        let (mut c_acc, mut v_acc, mut m_acc) = (0f64, 0f64, 0f64);
        for i in 0..lam.len() {
            let (l, m, c, s, w) = (
                lam[i] as f64,
                miss_cost[i] as f64,
                storage_rate[i] as f64,
                size[i] as f64,
                weight[i] as f64,
            );
            let e = (-l * t as f64).exp();
            c_acc += w * (c + (l * m - c) * e);
            v_acc += w * s * (1.0 - e);
            m_acc += w * l * e;
        }
        cost[g] = c_acc as f32;
        vsize[g] = v_acc as f32;
        missrate[g] = m_acc as f32;
    }
    CostCurves { t_grid: t_grid.to_vec(), cost, vsize, missrate }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_inputs(
        n: usize,
        g: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let lam: Vec<f32> = (0..n).map(|i| 1.0 / (1.0 + i as f32)).collect();
        let m = vec![1.4676e-7f32; n];
        let c: Vec<f32> = (0..n).map(|i| 8.5e-15 * (1000.0 + i as f32 * 10.0)).collect();
        let s: Vec<f32> = (0..n).map(|i| 1000.0 + i as f32 * 10.0).collect();
        let w = vec![1.0f32; n];
        let t: Vec<f32> = (0..g).map(|i| i as f32 * 10.0).collect();
        (lam, m, c, s, w, t)
    }

    #[test]
    fn reference_limits_match_eq4() {
        let (lam, m, c, s, w, _) = toy_inputs(16, 4);
        // T=0: cost = Σ λ m (all misses); T→∞: cost = Σ c.
        let t = vec![0.0f32, 1e9];
        let cur = reference_curves(&lam, &m, &c, &s, &w, &t);
        let all_miss: f32 = lam.iter().zip(&m).map(|(l, mm)| l * mm).sum();
        let all_store: f32 = c.iter().sum();
        assert!((cur.cost[0] - all_miss).abs() / all_miss < 1e-5);
        assert!((cur.cost[1] - all_store).abs() / all_store < 1e-4);
        // vsize at T=0 is 0; at ∞ is Σ s.
        assert_eq!(cur.vsize[0], 0.0);
        let total_s: f32 = s.iter().sum();
        assert!((cur.vsize[1] - total_s).abs() / total_s < 1e-5);
    }

    #[test]
    fn reference_missrate_decreases_in_t() {
        let (lam, m, c, s, w, t) = toy_inputs(8, 16);
        let cur = reference_curves(&lam, &m, &c, &s, &w, &t);
        for win in cur.missrate.windows(2) {
            assert!(win[1] <= win[0] + 1e-9);
        }
    }

    #[test]
    fn argmin_picks_minimum() {
        let curves = CostCurves {
            t_grid: vec![0.0, 1.0, 2.0],
            cost: vec![3.0, 1.0, 2.0],
            vsize: vec![0.0; 3],
            missrate: vec![0.0; 3],
        };
        assert_eq!(curves.argmin_cost(), 1);
    }

    #[test]
    fn manifest_roundtrip() {
        let m = Manifest {
            artifacts: vec![
                ArtifactSpec {
                    name: "cost_curve".into(),
                    n: 1024,
                    g: 128,
                    path: "cost_curve_n1024_g128.hlo.txt".into(),
                    dtype: "f32".into(),
                },
                ArtifactSpec {
                    name: "cost_curve".into(),
                    n: 4096,
                    g: 256,
                    path: "cost_curve_n4096_g256.hlo.txt".into(),
                    dtype: "f32".into(),
                },
            ],
        };
        let dir = crate::util::tempdir::tempdir().unwrap();
        std::fs::write(dir.path().join("manifest.txt"), m.render()).unwrap();
        let back = Manifest::load(dir.path()).unwrap();
        assert_eq!(back.artifacts.len(), 2);
        assert_eq!(back.find_cost_curve(None).unwrap().n, 4096);
        assert_eq!(back.find_cost_curve(Some(1024)).unwrap().g, 128);
        assert!(back.find_cost_curve(Some(999)).is_none());
        assert!(Manifest::parse("bad line here").is_err());
        assert!(Manifest::load(dir.path().join("nope")).is_err());
    }

    // Artifact-dependent tests live in rust/tests/runtime_artifacts.rs and
    // skip gracefully when `make artifacts` has not run.
}
