//! Per-content TTL policy — the paper's §7 future-work direction:
//! "potential improvements can come from TTL policies that use different
//! TTL values for different contents (as TTL-OPT does) selecting the
//! timer value on the basis of a forecast for the next inter-arrival
//! time."
//!
//! Implementation: per object, an EWMA of observed inter-arrival times
//! forecasts the next gap Δ̂. Mimicking TTL-OPT's decision rule
//! (Algorithm 1) with the forecast in place of clairvoyance:
//!
//! * store iff the *predicted* storage cost of the gap is below the miss
//!   cost: `c_i · Δ̂ · safety < m_i`;
//! * if stored, set the content's own TTL to `Δ̂ · safety` (enough to
//!   bridge the predicted gap, with head-room for forecast error).
//!
//! First-sight objects have no gap estimate: by default they are NOT
//! stored — the 2-LRU/ghost admission idea the paper cites in §3 ([22]):
//! the first request only creates metadata; a content is admitted once a
//! gap forecast exists. (`bootstrap_cap_secs > 0` switches to optimistic
//! break-even-capped bootstrap storage instead, which loses money on
//! one-hit-wonder-heavy traces.)
//!
//! Everything stays O(1) per request: a hash-map entry per live ghost
//! plus the same FIFO-calendar trick for expiry — per-content deadlines
//! are no less ordered than the global-TTL ones, so the same lazy-tail
//! approximation applies.

use crate::config::CostConfig;
use crate::metrics::HitMiss;
use crate::util::fasthash::FastMap;
use crate::{secs_to_us, us_to_secs, ObjectId, TimeUs};

/// Tuning knobs of the forecast policy.
#[derive(Debug, Clone)]
pub struct PerContentConfig {
    /// EWMA factor for inter-arrival estimates.
    pub gap_alpha: f64,
    /// Multiplicative head-room on the forecast gap.
    pub safety: f64,
    /// Hard TTL cap, seconds.
    pub t_max_secs: f64,
    /// Cap on the bootstrap (first-sight) TTL, seconds. 0 (default)
    /// means first-sight objects are tracked but not stored (2-LRU-style
    /// admission).
    pub bootstrap_cap_secs: f64,
}

impl Default for PerContentConfig {
    fn default() -> Self {
        PerContentConfig {
            gap_alpha: 0.3,
            safety: 1.5,
            t_max_secs: 6.0 * 3600.0,
            bootstrap_cap_secs: 0.0,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Tracked {
    /// Requests observed for this object.
    requests: u32,
    /// Last request time (for gap measurement).
    last_seen: TimeUs,
    /// EWMA of inter-arrival gaps, seconds. 0 = no estimate yet.
    gap_secs: f64,
    /// Current eviction deadline if resident, else 0.
    expire_at: TimeUs,
    size: u64,
    resident: bool,
}

/// Per-content TTL virtual cache (vertically billed like the ideal cache).
pub struct PerContentTtl {
    cfg: PerContentConfig,
    cost: CostConfig,
    objects: FastMap<ObjectId, Tracked>,
    /// Resident bytes (lazily maintained on the expiry sweep).
    vsize: u64,
    /// FIFO of (deadline, obj) in insertion order for the lazy sweep.
    queue: std::collections::VecDeque<(TimeUs, ObjectId)>,
    pub stats: HitMiss,
}

impl PerContentTtl {
    pub fn new(cfg: PerContentConfig, cost: CostConfig) -> Self {
        PerContentTtl {
            cfg,
            cost,
            objects: FastMap::default(),
            vsize: 0,
            queue: std::collections::VecDeque::new(),
            stats: HitMiss::default(),
        }
    }

    pub fn vsize(&self) -> u64 {
        self.vsize
    }

    pub fn tracked(&self) -> usize {
        self.objects.len()
    }

    /// Break-even residence time `m_i / c_i` for an object, seconds.
    fn break_even_secs(&self, size: u64) -> f64 {
        let rate = self.cost.storage_rate(size).max(1e-30);
        self.cost.miss_cost(size) / rate
    }

    /// TTL decision for an object with forecast gap `gap_secs` (0 = none).
    fn ttl_secs_for(&self, size: u64, gap_secs: f64) -> f64 {
        if gap_secs <= 0.0 {
            // Bootstrap: break-even-bounded optimism.
            return self
                .break_even_secs(size)
                .min(self.cfg.bootstrap_cap_secs)
                .min(self.cfg.t_max_secs);
        }
        let horizon = gap_secs * self.cfg.safety;
        // Algorithm 1's test with the forecast standing in for the oracle.
        let predicted_storage = self.cost.storage_rate(size) * horizon;
        if predicted_storage < self.cost.miss_cost(size) {
            horizon.min(self.cfg.t_max_secs)
        } else {
            0.0
        }
    }

    /// Drop expired residents from the sweep queue.
    fn sweep(&mut self, now: TimeUs) {
        while let Some(&(deadline, obj)) = self.queue.front() {
            if deadline > now {
                break;
            }
            self.queue.pop_front();
            if let Some(t) = self.objects.get_mut(&obj) {
                // Only evict if this queue entry is the *current* deadline
                // (renewals push new entries; stale ones are skipped).
                if t.resident && t.expire_at == deadline {
                    t.resident = false;
                    self.vsize -= t.size;
                }
            }
        }
    }

    /// Handle a request; returns `true` on (virtual) hit.
    pub fn on_request(&mut self, now: TimeUs, obj: ObjectId, size: u64) -> bool {
        self.sweep(now);
        let be = self.break_even_secs(size); // immutable pre-compute
        let cfg_safety = self.cfg.safety;
        let gap_alpha = self.cfg.gap_alpha;
        let entry = self.objects.entry(obj).or_insert(Tracked {
            requests: 0,
            last_seen: 0,
            gap_secs: 0.0,
            expire_at: 0,
            size,
            resident: false,
        });
        let first_sight = entry.requests == 0;
        entry.requests = entry.requests.saturating_add(1);
        // Update the gap forecast.
        if !first_sight {
            let gap = us_to_secs(now.saturating_sub(entry.last_seen));
            entry.gap_secs = if entry.gap_secs == 0.0 {
                gap
            } else {
                entry.gap_secs + gap_alpha * (gap - entry.gap_secs)
            };
        }
        entry.last_seen = now;
        let hit = entry.resident && entry.expire_at > now;
        if hit {
            self.stats.record(true);
        } else {
            self.stats.record(false);
            if entry.resident {
                // Expired but not yet swept: treat as evicted now.
                entry.resident = false;
                self.vsize -= entry.size;
            }
        }
        // (Re)new the residency decision with the fresh forecast.
        let gap_secs = entry.gap_secs;
        let ttl = {
            // inline ttl_secs_for to avoid double borrow
            if gap_secs <= 0.0 {
                be.min(self.cfg.bootstrap_cap_secs).min(self.cfg.t_max_secs)
            } else {
                let horizon = gap_secs * cfg_safety;
                if horizon < be {
                    horizon.min(self.cfg.t_max_secs)
                } else {
                    0.0
                }
            }
        };
        if ttl > 0.0 {
            let deadline = now + secs_to_us(ttl);
            if !entry.resident {
                entry.resident = true;
                self.vsize += entry.size;
            }
            entry.expire_at = deadline;
            self.queue.push_back((deadline, obj));
        } else if entry.resident {
            entry.resident = false;
            self.vsize -= entry.size;
        }
        hit
    }
}

/// Run the per-content policy over a trace with ideal (vertical) billing —
/// comparable to `sim::run_ideal_ttl` and to TTL-OPT.
pub fn run_per_content(
    cfg: &PerContentConfig,
    cost: &CostConfig,
    trace: &[crate::trace::Request],
) -> PerContentResult {
    let mut pc = PerContentTtl::new(cfg.clone(), cost.clone());
    let mut costs = crate::cost::CostTracker::new(cost.clone());
    let per_byte_sec = cost.storage_cost_per_byte_sec();
    let mut last_ts = 0;
    for r in trace {
        let dt = us_to_secs(r.ts.saturating_sub(last_ts));
        costs.record_storage_dollars(pc.vsize() as f64 * per_byte_sec * dt);
        last_ts = r.ts;
        if !pc.on_request(r.ts, r.obj, r.size_bytes()) {
            costs.record_miss(r.size_bytes());
        }
    }
    PerContentResult {
        requests: trace.len() as u64,
        hits: pc.stats.hits,
        storage_cost: costs.storage_total(),
        miss_cost: costs.miss_total(),
        total_cost: costs.total(),
    }
}

/// Summary of a per-content run.
#[derive(Debug, Clone, Copy)]
pub struct PerContentResult {
    pub requests: u64,
    pub hits: u64,
    pub storage_cost: f64,
    pub miss_cost: f64,
    pub total_cost: f64,
}

impl PerContentResult {
    pub fn miss_ratio(&self) -> f64 {
        1.0 - self.hits as f64 / self.requests.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SECOND;

    fn mk() -> PerContentTtl {
        PerContentTtl::new(PerContentConfig::default(), CostConfig::default())
    }

    #[test]
    fn periodic_object_becomes_all_hits() {
        let mut pc = mk();
        // Perfectly periodic small object: after the forecast stabilizes,
        // every request hits.
        let mut hits = 0;
        for k in 0..50u64 {
            if pc.on_request(k * 10 * SECOND, 1, 1000) {
                hits += 1;
            }
        }
        assert!(hits >= 48, "hits={hits}"); // at most the first two miss
    }

    #[test]
    fn giant_with_long_gaps_is_not_stored() {
        let mut pc = mk();
        // 50 MB object re-requested every 2 hours: storing costs more
        // than the miss (break-even for 50 MB ≈ 345 s).
        let size = 50_000_000;
        let mut hits = 0;
        for k in 0..10u64 {
            if pc.on_request(k * 2 * crate::HOUR, 7, size) {
                hits += 1;
            }
        }
        assert_eq!(hits, 0);
        // And it does not occupy the virtual cache between requests
        // (bootstrap may hold it briefly; after the first gap estimate it
        // must be dropped).
        assert_eq!(pc.vsize(), 0, "giant retained");
    }

    #[test]
    fn vsize_tracks_residency() {
        let mut pc = mk();
        // 2-LRU admission: first sight is metadata-only.
        pc.on_request(0, 1, 1000);
        assert_eq!(pc.vsize(), 0, "first sight must not be stored");
        // Second request creates the gap forecast and admits the object.
        pc.on_request(10 * SECOND, 1, 1000);
        assert!(pc.vsize() > 0, "admitted object not stored");
        // Sweep far in the future: everything expired.
        pc.sweep(100 * crate::HOUR);
        assert_eq!(pc.vsize(), 0);

        // Optimistic bootstrap mode stores at first sight.
        let mut cfg = PerContentConfig::default();
        cfg.bootstrap_cap_secs = 600.0;
        let mut pc2 = PerContentTtl::new(cfg, CostConfig::default());
        pc2.on_request(0, 1, 1000);
        assert!(pc2.vsize() > 0, "bootstrap mode should store");
    }

    #[test]
    fn beats_global_ttl_on_mixed_periodicities() {
        // Two populations with very different periods defeat any single T;
        // per-content forecasts should land between global-TTL and OPT.
        use crate::config::{Config, PolicyKind};
        use crate::sim::run_ideal_ttl;
        use crate::trace::{Request, VecSource};
        use crate::ttlopt::solve;

        let mut trace: Vec<Request> = Vec::new();
        // fast population: 200 objects every 30 s; slow: 200 small objects
        // every 2 h (cheap to keep) interleaved with 2000 one-hit giants.
        for k in 0..240u64 {
            for o in 0..200u64 {
                trace.push(Request::new(k * 30 * SECOND + o, o, 20_000));
            }
        }
        for k in 0..2u64 {
            for o in 0..200u64 {
                trace.push(Request::new(k * 2 * crate::HOUR + 7200 + o, 1000 + o, 4_000));
            }
        }
        for g in 0..2000u64 {
            trace.push(Request::new(g * 3 * SECOND + 13, 10_000 + g, 30_000_000));
        }
        trace.sort_unstable_by_key(|r| r.ts);

        let cost = CostConfig::default();
        let pc = run_per_content(&PerContentConfig::default(), &cost, &trace);

        let mut cfg = Config::with_policy(PolicyKind::IdealTtl);
        cfg.cost = cost.clone();
        let global = run_ideal_ttl(&cfg, &mut VecSource::new(trace.clone()));
        let opt = solve(&trace, &cost);

        assert!(
            pc.total_cost < global.total_cost,
            "per-content {} !< global {}",
            pc.total_cost,
            global.total_cost
        );
        assert!(
            pc.total_cost >= opt.total_cost - 1e-12,
            "per-content {} beat OPT {}?!",
            pc.total_cost,
            opt.total_cost
        );
    }
}
