//! The O(1) FIFO-calendar TTL ghost store (§5.1).
//!
//! A proper TTL calendar needs ordered insertion (O(log M)) because the
//! timer value changes over time: `t_n + T(t_n)` is not monotone in `n`.
//! The paper's trick: keep ghosts in a list ordered by *last request time*
//! (which IS monotone — renewal moves a ghost to the head), and evict from
//! the tail while the tail's timer has expired, stopping at the first
//! unexpired ghost. Ghosts whose timer already lapsed may therefore
//! survive a little longer when a ghost ahead of them has a longer
//! deadline; §5.1 verifies experimentally that this has negligible impact
//! (we verify the same in `rust/tests/fifo_vs_ideal.rs`).
//!
//! Implementation: intrusive doubly linked list over a slab with a free
//! list — zero allocation in steady state, O(1) per operation amortized.

use crate::util::fasthash::FastMap;
use crate::{ObjectId, TimeUs};

const NIL: u32 = u32::MAX;

/// One ghost: content metadata plus the measurement window used by the
/// delayed eq. (7) update (Fig. 3).
#[derive(Debug, Clone, Copy)]
pub struct VNode {
    pub obj: ObjectId,
    pub size: u64,
    /// Eviction deadline: last request time + timer-at-that-time.
    pub expire_at: TimeUs,
    /// Measurement window start (the miss that inserted the ghost).
    pub window_start: TimeUs,
    /// Timer value when the window opened (µs) — the `T(t_n)` of eq. (7).
    pub window_ttl: TimeUs,
    /// Hits observed within the window — `h_{r(n)}`.
    pub window_hits: u32,
    /// Whether the eq. (7) update for this window is still owed.
    pub update_pending: bool,
    prev: u32,
    next: u32,
}

/// Outcome of [`FifoTtlCache::touch`].
pub enum TouchResult<'a> {
    /// Live ghost: renewed, node returned for window bookkeeping.
    Hit(&'a mut VNode),
    /// Ghost had expired; it was collected now (fire its pending update).
    Expired(VNode),
    /// No ghost for this object.
    Absent,
}

/// FIFO-calendar TTL cache over ghosts.
pub struct FifoTtlCache {
    map: FastMap<ObjectId, u32>,
    nodes: Vec<VNode>,
    free: Vec<u32>,
    head: u32, // most recently requested
    tail: u32, // least recently requested (eviction scan point)
    vsize: u64,
    evictions: u64,
}

impl FifoTtlCache {
    pub fn new() -> Self {
        FifoTtlCache {
            map: FastMap::default(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            vsize: 0,
            evictions: 0,
        }
    }

    /// Sum of resident ghost sizes (lazy expiry: includes ghosts whose
    /// timer lapsed but that the tail scan has not reached yet).
    pub fn vsize(&self) -> u64 {
        self.vsize
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn contains(&self, obj: ObjectId) -> bool {
        self.map.contains_key(&obj)
    }

    #[inline]
    fn unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.nodes[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.nodes[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    #[inline]
    fn push_front(&mut self, idx: u32) {
        self.nodes[idx as usize].prev = NIL;
        self.nodes[idx as usize].next = self.head;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Renew `obj` at `now` with timer `ttl`: move to head, refresh the
    /// deadline. [`TouchResult::Hit`] carries the node for window
    /// bookkeeping; an expired ghost (deadline lapsed but not yet reached
    /// by the tail scan) is collected lazily and returned as
    /// [`TouchResult::Expired`] so the caller can fire its pending eq. (7)
    /// update — this is Fig. 3 case (b) with the "eviction" happening at
    /// touch time instead of at the tail scan.
    pub fn touch(&mut self, now: TimeUs, obj: ObjectId, ttl: TimeUs) -> TouchResult<'_> {
        let Some(&idx) = self.map.get(&obj) else {
            return TouchResult::Absent;
        };
        if self.nodes[idx as usize].expire_at <= now {
            // Lazily collect the expired ghost: it must behave exactly as
            // if it had been evicted on time (§5.1's approximation is only
            // about *when* memory is reclaimed, not hit/miss semantics).
            let node = self.remove_idx(idx);
            return TouchResult::Expired(node);
        }
        self.unlink(idx);
        self.push_front(idx);
        let n = &mut self.nodes[idx as usize];
        n.expire_at = now + ttl;
        TouchResult::Hit(n)
    }

    /// Insert a fresh ghost at the head (a virtual miss just occurred).
    pub fn insert(&mut self, now: TimeUs, obj: ObjectId, size: u64, ttl: TimeUs) {
        debug_assert!(!self.map.contains_key(&obj));
        let node = VNode {
            obj,
            size,
            expire_at: now + ttl,
            window_start: now,
            window_ttl: ttl,
            window_hits: 0,
            update_pending: true,
            prev: NIL,
            next: NIL,
        };
        let idx = match self.free.pop() {
            Some(i) => {
                self.nodes[i as usize] = node;
                i
            }
            None => {
                let i = self.nodes.len() as u32;
                self.nodes.push(node);
                i
            }
        };
        self.map.insert(obj, idx);
        self.push_front(idx);
        self.vsize += size;
    }

    fn remove_idx(&mut self, idx: u32) -> VNode {
        let node = self.nodes[idx as usize];
        self.unlink(idx);
        self.map.remove(&node.obj);
        self.free.push(idx);
        self.vsize -= node.size;
        self.evictions += 1;
        node
    }

    /// Pop expired ghosts from the tail, calling `on_evict` for each
    /// (the controller applies pending eq. (7) updates there — Fig. 3
    /// case b). Stops at the first unexpired tail ghost: the FIFO
    /// approximation.
    pub fn evict_expired(&mut self, now: TimeUs, mut on_evict: impl FnMut(&VNode)) -> usize {
        let mut n = 0;
        while self.tail != NIL {
            let idx = self.tail;
            if self.nodes[idx as usize].expire_at > now {
                break;
            }
            let node = self.remove_idx(idx);
            on_evict(&node);
            n += 1;
        }
        n
    }

    /// Walk the list head→tail (test helper).
    pub fn iter_recency(&self) -> impl Iterator<Item = &VNode> + '_ {
        struct It<'a> {
            c: &'a FifoTtlCache,
            cur: u32,
        }
        impl<'a> Iterator for It<'a> {
            type Item = &'a VNode;
            fn next(&mut self) -> Option<Self::Item> {
                if self.cur == NIL {
                    return None;
                }
                let n = &self.c.nodes[self.cur as usize];
                self.cur = n.next;
                Some(n)
            }
        }
        It { c: self, cur: self.head }
    }

    /// Exact unexpired byte count (O(M) — tests only; production code uses
    /// the lazy [`Self::vsize`]).
    pub fn exact_unexpired_bytes(&self, now: TimeUs) -> u64 {
        self.iter_recency()
            .filter(|n| n.expire_at > now)
            .map(|n| n.size)
            .sum()
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.vsize = 0;
    }
}

impl Default for FifoTtlCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SECOND;

    const TTL: TimeUs = 10 * SECOND;

    #[test]
    fn insert_touch_expire_cycle() {
        let mut c = FifoTtlCache::new();
        c.insert(0, 1, 100, TTL);
        assert_eq!(c.vsize(), 100);
        assert!(matches!(c.touch(5 * SECOND, 1, TTL), TouchResult::Hit(_)));
        // renewal pushed deadline to 15s
        assert!(matches!(c.touch(14 * SECOND, 1, TTL), TouchResult::Hit(_)));
        // deadline 24s; at 24s it's expired (inclusive)
        assert!(matches!(c.touch(24 * SECOND, 1, TTL), TouchResult::Expired(_)));
        assert_eq!(c.vsize(), 0, "lazy collection on touch removes the ghost");
        assert!(matches!(c.touch(25 * SECOND, 1, TTL), TouchResult::Absent));
    }

    #[test]
    fn tail_eviction_in_recency_order() {
        let mut c = FifoTtlCache::new();
        for i in 0..5u64 {
            c.insert(i * SECOND, i, 10, TTL);
        }
        // touch object 0 so it moves to the head
        assert!(matches!(c.touch(5 * SECOND, 0, TTL), TouchResult::Hit(_)));
        let order: Vec<u64> = c.iter_recency().map(|n| n.obj).collect();
        assert_eq!(order, vec![0, 4, 3, 2, 1]);
        // at t=13s: deadlines are 1→11s, 2→12s, 3→13s (expired); 4→14s, 0→15s
        let mut evicted = Vec::new();
        c.evict_expired(13 * SECOND, |n| evicted.push(n.obj));
        assert_eq!(evicted, vec![1, 2, 3]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.vsize(), 20);
    }

    #[test]
    fn fifo_approximation_can_defer_eviction() {
        // The FIFO scan stops at an unexpired tail ghost even if a deeper
        // ghost has expired (out-of-order deadlines from a shrinking TTL).
        let mut c = FifoTtlCache::new();
        c.insert(0, 1, 10, 100 * SECOND); // deadline 100s, at the tail
        c.insert(SECOND, 2, 10, SECOND); // deadline 2s, at the head
        let n = c.evict_expired(50 * SECOND, |_| {});
        assert_eq!(n, 0, "tail (deadline 100s) blocks the scan");
        assert_eq!(c.vsize(), 20, "lazy vsize still counts the expired ghost");
        assert_eq!(c.exact_unexpired_bytes(50 * SECOND), 10);
        // But a touch of the expired ghost still misses (and is collected
        // with its window intact for the pending update):
        match c.touch(50 * SECOND, 2, TTL) {
            TouchResult::Expired(n) => assert!(n.update_pending),
            _ => panic!("expected Expired"),
        }
    }

    #[test]
    fn window_state_initialized_on_insert() {
        let mut c = FifoTtlCache::new();
        c.insert(7 * SECOND, 9, 55, TTL);
        let n = c.iter_recency().next().unwrap();
        assert_eq!(n.window_start, 7 * SECOND);
        assert_eq!(n.window_ttl, TTL);
        assert_eq!(n.window_hits, 0);
        assert!(n.update_pending);
    }

    #[test]
    fn pending_update_fires_on_eviction() {
        let mut c = FifoTtlCache::new();
        c.insert(0, 1, 100, TTL);
        let mut fired = Vec::new();
        c.evict_expired(TTL, |n| fired.push((n.obj, n.update_pending)));
        assert_eq!(fired, vec![(1, true)]);
    }

    #[test]
    fn free_list_bounds_slab_growth() {
        let mut c = FifoTtlCache::new();
        for round in 0..50u64 {
            for i in 0..10u64 {
                c.insert(round * 100 * SECOND + i, round * 10 + i, 1, SECOND);
            }
            c.evict_expired((round * 100 + 50) * SECOND, |_| {});
        }
        assert!(c.nodes.len() <= 32, "slab grew to {}", c.nodes.len());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn zero_ttl_ghost_is_immediately_dead() {
        let mut c = FifoTtlCache::new();
        c.insert(5, 1, 10, 0);
        assert!(matches!(c.touch(5, 1, 0), TouchResult::Expired(_)));
        assert_eq!(c.len(), 0);
    }
}
