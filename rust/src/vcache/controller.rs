//! The stochastic-approximation TTL controller — eq. (5)/(7) of §4.1/§5.1.
//!
//! Upon the closure of a content's measurement window (the interval
//! `[t_n, t_n + T(t_n)]` opened by the miss at `t_n`), the timer is nudged
//! along the negative cost gradient:
//!
//! ```text
//! T ← Π_[0,Tmax]( T + ε(n) · ( λ̂·m_i − c_i ) ),   λ̂ = h_i / T(t_n)
//! ```
//!
//! `λ̂·m_i` is the (estimated) miss-cost saving rate of keeping the object;
//! `c_i = s_i·c` is its storage cost rate. Misses of hot objects push `T`
//! up; storage burnt on cold objects pushes it down. The expected
//! correction equals `−dC/dT` up to a positive factor (Proposition 1).
//!
//! Two gain modes: the paper's plain ε(n) (constant or Robbins–Monro), and
//! a scale-free *normalized* mode that divides the correction by a running
//! mean of its magnitude — same sign structure, no eps0 retuning when the
//! cost catalog changes.

use crate::config::{ControllerConfig, GainSchedule};
use crate::metrics::Ewma;
use crate::{secs_to_us, us_to_secs, TimeUs};

/// One applied correction, for diagnostics/experiments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorrectionSample {
    /// λ̂·m − c, in $/s.
    pub raw: f64,
    /// Seconds actually added to T after gain/normalization/projection.
    pub applied_secs: f64,
}

/// Stochastic-approximation timer state.
#[derive(Debug, Clone)]
pub struct TtlController {
    t_secs: f64,
    t_min: f64,
    t_max: f64,
    /// Enforcement clamp (≤ `t_max`): the largest timer the owner is
    /// currently *allowed* to run (the multi-tenant grant feedback of
    /// [`crate::tenant`]). Equal to `t_max` when unclamped; the iterate is
    /// projected onto `[t_min, cap]`, so eq. (7) keeps estimating the
    /// unconstrained gradient while the timer converges to the largest
    /// affordable value instead of thrashing above it.
    cap_secs: f64,
    gain: GainSchedule,
    normalized: bool,
    step_secs: f64,
    magnitude: Ewma,
    /// Updates consumed calibrating the gain before the iterate moves
    /// (normalized mode). A slow EWMA then keeps ε quasi-constant over any
    /// local window, preserving the E[correction] = 0 equilibrium of the
    /// plain eq. (7) (per-sample normalization would bias it toward sign
    /// balance), while still tracking magnitude-regime changes.
    warmup_remaining: u32,
    n_updates: u64,
    last: Option<CorrectionSample>,
}

/// Updates used to estimate the typical correction magnitude before any
/// movement (normalized mode).
const GAIN_WARMUP_UPDATES: u32 = 200;
/// Per-update movement cap, in units of `step_secs` (guards against a
/// single outlier sample jumping across the projection interval).
const MAX_STEP_FACTOR: f64 = 100.0;

impl TtlController {
    pub fn new(cfg: &ControllerConfig) -> Self {
        TtlController {
            t_secs: cfg.t_init_secs.clamp(cfg.t_min_secs.max(0.0), cfg.t_max_secs),
            t_min: cfg.t_min_secs.max(0.0),
            t_max: cfg.t_max_secs,
            cap_secs: cfg.t_max_secs,
            gain: cfg.gain,
            normalized: cfg.normalized,
            step_secs: cfg.normalized_step_secs,
            magnitude: Ewma::new(cfg.normalized_ewma_alpha),
            warmup_remaining: if cfg.normalized { GAIN_WARMUP_UPDATES } else { 0 },
            n_updates: 0,
            last: None,
        }
    }

    /// Current timer, seconds.
    #[inline]
    pub fn ttl_secs(&self) -> f64 {
        self.t_secs
    }

    /// Current timer, microseconds.
    #[inline]
    pub fn ttl_us(&self) -> TimeUs {
        secs_to_us(self.t_secs)
    }

    pub fn updates(&self) -> u64 {
        self.n_updates
    }

    pub fn last_correction(&self) -> Option<CorrectionSample> {
        self.last
    }

    /// The active enforcement clamp, if one binds below `t_max`.
    pub fn cap_secs(&self) -> Option<f64> {
        if self.cap_secs < self.t_max {
            Some(self.cap_secs)
        } else {
            None
        }
    }

    /// Clamp the timer to at most `cap` seconds (projected into
    /// `[t_min, t_max]`). Takes effect immediately: the current iterate is
    /// pulled down if it sits above the new cap.
    pub fn set_cap_secs(&mut self, cap: f64) {
        self.cap_secs = cap.max(self.t_min).min(self.t_max);
        if self.t_secs > self.cap_secs {
            self.t_secs = self.cap_secs;
        }
    }

    /// Remove the enforcement clamp (the projection interval returns to
    /// the configured `[t_min, t_max]`).
    pub fn clear_cap(&mut self) {
        self.cap_secs = self.t_max;
    }

    /// Apply eq. (7) for a closed measurement window: `hits` hits were
    /// observed over a window of `window_ttl` µs for an object with
    /// storage rate `storage_rate` ($/s) and miss cost `miss_cost` ($).
    pub fn apply_window(
        &mut self,
        hits: u32,
        window_ttl: TimeUs,
        storage_rate: f64,
        miss_cost: f64,
    ) {
        // λ̂ = h / T(t_n). Guard tiny windows (T → 0 would make the
        // estimator degenerate); 100 ms floor keeps λ̂ finite while leaving
        // the projection interval untouched.
        let window_secs = us_to_secs(window_ttl).max(0.1);
        let lambda_hat = hits as f64 / window_secs;
        self.apply_correction(lambda_hat * miss_cost - storage_rate);
    }

    /// Apply a raw correction `λ̂·m − c` ($/s) through gain, optional
    /// auto-scaling, and projection.
    pub fn apply_correction(&mut self, raw: f64) {
        let applied = if self.normalized {
            // Scale-free plain eq. (7): a *constant* ε chosen so the mean
            // correction magnitude moves T by `step_secs`. The magnitude
            // is estimated over a warmup during which the iterate holds
            // still; afterwards ε is frozen, so every sample keeps its
            // relative weight and the update stays unbiased.
            self.magnitude.update(raw.abs());
            if self.warmup_remaining > 0 {
                self.warmup_remaining -= 1;
                0.0
            } else {
                // ε adapts *slowly* (the EWMA's alpha spreads over many
                // hundreds of samples), so over any window where the
                // sample mix is stationary all corrections share one gain
                // — locally the plain eq. (7) — while the controller can
                // still re-scale between regimes where magnitudes differ
                // by orders (T seconds vs hours).
                let eps = self.step_secs / self.magnitude.get().unwrap_or(1e-30).max(1e-30);
                let g = self.gain_factor();
                (eps * g * raw).clamp(
                    -MAX_STEP_FACTOR * self.step_secs,
                    MAX_STEP_FACTOR * self.step_secs,
                )
            }
        } else {
            self.gain.gain(self.n_updates) * raw
        };
        let before = self.t_secs;
        self.t_secs = (self.t_secs + applied).clamp(self.t_min, self.cap_secs);
        self.n_updates += 1;
        self.last = Some(CorrectionSample { raw, applied_secs: self.t_secs - before });
    }

    /// In normalized mode the schedule still shapes the step over time
    /// (constant → 1.0; polynomial → decaying factor relative to eps0).
    fn gain_factor(&self) -> f64 {
        match self.gain {
            GainSchedule::Constant { .. } => 1.0,
            GainSchedule::Polynomial { eps0, .. } => {
                self.gain.gain(self.n_updates) / eps0.max(1e-30)
            }
        }
    }

    /// Reset the iterate (tests / epoch experiments).
    pub fn set_ttl_secs(&mut self, t: f64) {
        self.t_secs = t.clamp(self.t_min, self.cap_secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ControllerConfig;

    fn cfg_plain(eps0: f64) -> ControllerConfig {
        ControllerConfig {
            t_init_secs: 100.0,
            t_min_secs: 0.0,
            t_max_secs: 1000.0,
            gain: GainSchedule::Constant { eps0 },
            normalized: false,
            ..ControllerConfig::default()
        }
    }

    #[test]
    fn positive_correction_raises_ttl() {
        let mut c = TtlController::new(&cfg_plain(1.0));
        c.apply_correction(5.0);
        assert_eq!(c.ttl_secs(), 105.0);
        assert_eq!(c.updates(), 1);
        assert_eq!(c.last_correction().unwrap().applied_secs, 5.0);
    }

    #[test]
    fn projection_clamps_both_ends() {
        let mut c = TtlController::new(&cfg_plain(1.0));
        c.apply_correction(1e9);
        assert_eq!(c.ttl_secs(), 1000.0);
        c.apply_correction(-1e12);
        assert_eq!(c.ttl_secs(), 0.0);
    }

    #[test]
    fn window_estimator_signs() {
        // Hot small object: λ̂·m >> c → positive step.
        let mut c = TtlController::new(&cfg_plain(1e9));
        let t0 = c.ttl_secs();
        c.apply_window(100, 10 * crate::SECOND, 1e-12, 1e-7);
        assert!(c.ttl_secs() > t0);

        // Cold huge object: 0 hits → correction = −c < 0.
        let mut c2 = TtlController::new(&cfg_plain(1e9));
        let t0 = c2.ttl_secs();
        c2.apply_window(0, 10 * crate::SECOND, 1e-7, 1e-7);
        assert!(c2.ttl_secs() < t0);
    }

    #[test]
    fn tiny_window_guarded() {
        let mut c = TtlController::new(&cfg_plain(1.0));
        // window_ttl = 0 must not produce NaN/inf
        c.apply_window(5, 0, 0.0, 1.0);
        assert!(c.ttl_secs().is_finite());
    }

    #[test]
    fn normalized_mode_warmup_then_balanced_steps() {
        let cfg = ControllerConfig {
            t_init_secs: 100.0,
            t_max_secs: 1000.0,
            normalized: true,
            normalized_step_secs: 2.0,
            ..ControllerConfig::default()
        };
        let mut c = TtlController::new(&cfg);
        // Warmup: the iterate must not move.
        for i in 0..200 {
            let raw = if i % 2 == 0 { 1e-9 } else { -1e-9 };
            c.apply_correction(raw);
            assert_eq!(c.ttl_secs(), 100.0, "moved during warmup");
        }
        // Post-warmup: ε is frozen; equal-magnitude alternating samples
        // cancel exactly and each step is ≈ step_secs.
        for i in 0..100 {
            let raw = if i % 2 == 0 { 1e-9 } else { -1e-9 };
            c.apply_correction(raw);
            let s = c.last_correction().unwrap().applied_secs.abs();
            assert!((s - 2.0).abs() < 0.1, "step {s}");
        }
        assert!((c.ttl_secs() - 100.0).abs() < 3.0);
    }

    #[test]
    fn normalized_mode_preserves_magnitude_asymmetry() {
        // Frequent small negatives vs rare large positives with equal
        // expectation must keep T roughly stationary — the unbiasedness
        // property the per-sample normalization destroyed.
        let cfg = ControllerConfig {
            t_init_secs: 500.0,
            t_max_secs: 10_000.0,
            normalized: true,
            normalized_step_secs: 2.0,
            ..ControllerConfig::default()
        };
        let mut c = TtlController::new(&cfg);
        // E[corr] = 0: 9 × (−1e-10) + 1 × (+9e-10) per block of 10.
        for _ in 0..2000 {
            for k in 0..10 {
                c.apply_correction(if k == 9 { 9e-10 } else { -1e-10 });
            }
        }
        assert!(
            (c.ttl_secs() - 500.0).abs() < 100.0,
            "drifted to {}",
            c.ttl_secs()
        );
    }

    #[test]
    fn robbins_monro_steps_decay() {
        let cfg = ControllerConfig {
            t_init_secs: 100.0,
            t_max_secs: 1e6,
            gain: GainSchedule::Polynomial { eps0: 10.0, exponent: 0.7 },
            normalized: false,
            ..ControllerConfig::default()
        };
        let mut c = TtlController::new(&cfg);
        c.apply_correction(1.0);
        let s1 = c.last_correction().unwrap().applied_secs;
        for _ in 0..99 {
            c.apply_correction(1.0);
        }
        let s100 = c.last_correction().unwrap().applied_secs;
        assert!(s100 < s1 / 5.0, "s1={s1} s100={s100}");
    }

    #[test]
    fn enforcement_cap_projects_and_clears() {
        let mut c = TtlController::new(&cfg_plain(1.0));
        assert_eq!(c.cap_secs(), None, "fresh controller is unclamped");
        // An immediate pull-down, then corrections project onto the cap.
        c.set_cap_secs(50.0);
        assert_eq!(c.ttl_secs(), 50.0);
        assert_eq!(c.cap_secs(), Some(50.0));
        c.apply_correction(1e9);
        assert_eq!(c.ttl_secs(), 50.0, "cap must bound the iterate");
        // The cap never leaves [t_min, t_max].
        c.set_cap_secs(1e12);
        assert_eq!(c.cap_secs(), None);
        c.apply_correction(1e12);
        assert_eq!(c.ttl_secs(), 1000.0, "back to the configured t_max");
        // Clearing restores the configured interval.
        c.set_cap_secs(10.0);
        c.clear_cap();
        assert_eq!(c.cap_secs(), None);
        c.apply_correction(1e12);
        assert_eq!(c.ttl_secs(), 1000.0);
    }

    #[test]
    fn init_clamped_to_projection_interval() {
        let cfg = ControllerConfig {
            t_init_secs: 5000.0,
            t_max_secs: 100.0,
            ..ControllerConfig::default()
        };
        let c = TtlController::new(&cfg);
        assert_eq!(c.ttl_secs(), 100.0);
    }
}
