//! The paper's core mechanism (§4, §5.1): a **virtual TTL cache with
//! renewal** storing only metadata ("ghosts"), whose timer `T` is adapted
//! by stochastic approximation so the virtual size tracks the cache size
//! minimizing storage + miss cost.
//!
//! * [`TtlController`] — the eq. (7) update rule with delayed application
//!   (Fig. 3), gain schedules and `[0, T_max]` projection.
//! * [`FifoTtlCache`] — the O(1) implementation: the calendar is a FIFO
//!   (a recency-ordered intrusive list), so expired ghosts may linger
//!   briefly instead of paying O(log M) for an ordered calendar.
//! * [`VirtualCache`] — glues the two together and exposes the per-request
//!   entry point the load balancer calls.

mod controller;
mod fifo_ttl;
mod per_content;

pub use controller::{CorrectionSample, TtlController};
pub use fifo_ttl::{FifoTtlCache, TouchResult};
pub use per_content::{run_per_content, PerContentConfig, PerContentResult, PerContentTtl};

use crate::config::{ControllerConfig, CostConfig};
use crate::metrics::HitMiss;
use crate::{ObjectId, TimeUs};

/// Outcome of one request against the virtual cache.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VcOutcome {
    /// Virtual hit: the ghost was present and unexpired.
    pub hit: bool,
    /// Timer value (seconds) after any updates triggered by this request.
    pub ttl_secs: f64,
    /// Virtual cache size (bytes) after this request.
    pub vsize: u64,
}

/// Virtual cache: FIFO-calendar ghost store + TTL controller.
pub struct VirtualCache {
    cache: FifoTtlCache,
    controller: TtlController,
    cost: CostConfig,
    pub stats: HitMiss,
}

impl VirtualCache {
    pub fn new(ctrl_cfg: &ControllerConfig, cost: CostConfig) -> Self {
        VirtualCache {
            cache: FifoTtlCache::new(),
            controller: TtlController::new(ctrl_cfg),
            cost,
            stats: HitMiss::default(),
        }
    }

    /// Current timer value, seconds.
    pub fn ttl_secs(&self) -> f64 {
        self.controller.ttl_secs()
    }

    /// Current timer value, microseconds.
    pub fn ttl_us(&self) -> TimeUs {
        self.controller.ttl_us()
    }

    /// Virtual size in bytes (sum of resident ghosts, lazily expired).
    pub fn vsize(&self) -> u64 {
        self.cache.vsize()
    }

    /// Resident ghost count.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.len() == 0
    }

    /// Number of controller updates applied so far.
    pub fn updates(&self) -> u64 {
        self.controller.updates()
    }

    pub fn controller(&self) -> &TtlController {
        &self.controller
    }

    /// The active enforcement TTL clamp, if one binds (see
    /// [`TtlController::set_cap_secs`]).
    pub fn ttl_cap_secs(&self) -> Option<f64> {
        self.controller.cap_secs()
    }

    /// Clamp this cache's timer to at most `cap` seconds (multi-tenant
    /// grant enforcement). Newly inserted ghosts immediately use the
    /// clamped timer; resident ghosts keep their original deadline and age
    /// out naturally, so the virtual size converges to the affordable
    /// level instead of dropping discontinuously.
    pub fn set_ttl_cap_secs(&mut self, cap: f64) {
        self.controller.set_cap_secs(cap);
    }

    /// Remove the enforcement TTL clamp.
    pub fn clear_ttl_cap(&mut self) {
        self.controller.clear_cap();
    }

    /// Handle one request (Algorithm 2 lines 1–6). O(1) amortized: the
    /// expired-tail scan is paid for by the insertions that created those
    /// ghosts.
    pub fn on_request(&mut self, now: TimeUs, obj: ObjectId, size: u64) -> VcOutcome {
        // Evict expired ghosts from the FIFO tail, applying any pending
        // controller updates (Fig. 3 case b: update at eviction).
        let cost = &self.cost;
        let ctrl = &mut self.controller;
        self.cache.evict_expired(now, |node| {
            if node.update_pending {
                ctrl.apply_window(
                    node.window_hits,
                    node.window_ttl,
                    cost.storage_rate(node.size),
                    cost.miss_cost(node.size),
                );
            }
        });

        let ttl_us = self.controller.ttl_us();
        let hit = match self.cache.touch(now, obj, ttl_us) {
            TouchResult::Hit(node) => {
                // Window bookkeeping (Fig. 3 case a: first hit after the
                // measurement window closes triggers the delayed update).
                if node.update_pending {
                    let window_end = node.window_start + node.window_ttl;
                    if now > window_end {
                        self.controller.apply_window(
                            node.window_hits,
                            node.window_ttl,
                            self.cost.storage_rate(node.size),
                            self.cost.miss_cost(node.size),
                        );
                        node.update_pending = false;
                    } else {
                        node.window_hits += 1;
                    }
                }
                true
            }
            TouchResult::Expired(node) => {
                // Fig. 3 case b with the eviction materializing at touch
                // time: the ghost's timer lapsed before this request, so
                // it is a miss — but its measurement window (possibly with
                // hits) still owes its eq. (7) update.
                if node.update_pending {
                    self.controller.apply_window(
                        node.window_hits,
                        node.window_ttl,
                        self.cost.storage_rate(node.size),
                        self.cost.miss_cost(node.size),
                    );
                }
                self.cache.insert(now, obj, size, self.controller.ttl_us());
                false
            }
            TouchResult::Absent => {
                // Virtual miss: insert ghost, start a measurement window at
                // the current timer value (§5.1: estimation starts when the
                // content is stored).
                self.cache.insert(now, obj, size, ttl_us);
                false
            }
        };
        self.stats.record(hit);
        VcOutcome { hit, ttl_secs: self.controller.ttl_secs(), vsize: self.cache.vsize() }
    }

    /// Force expiry processing without a request (epoch boundaries).
    pub fn expire(&mut self, now: TimeUs) {
        let cost = &self.cost;
        let ctrl = &mut self.controller;
        self.cache.evict_expired(now, |node| {
            if node.update_pending {
                ctrl.apply_window(
                    node.window_hits,
                    node.window_ttl,
                    cost.storage_rate(node.size),
                    cost.miss_cost(node.size),
                );
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ControllerConfig, GainSchedule};
    use crate::{SECOND};

    fn mk(t_init: f64) -> VirtualCache {
        let ctrl = ControllerConfig {
            t_init_secs: t_init,
            normalized: true,
            normalized_step_secs: 1.0,
            ..ControllerConfig::default()
        };
        VirtualCache::new(&ctrl, CostConfig::default())
    }

    #[test]
    fn miss_then_hit() {
        let mut vc = mk(60.0);
        let o1 = vc.on_request(0, 1, 1000);
        assert!(!o1.hit);
        assert_eq!(o1.vsize, 1000);
        let o2 = vc.on_request(SECOND, 1, 1000);
        assert!(o2.hit);
        assert_eq!(vc.stats.hits, 1);
        assert_eq!(vc.stats.misses, 1);
    }

    #[test]
    fn ghost_expires_after_ttl() {
        let mut vc = mk(10.0);
        vc.on_request(0, 1, 1000);
        // Request far beyond the timer: the ghost expired → miss.
        let o = vc.on_request(100 * SECOND, 1, 1000);
        assert!(!o.hit);
    }

    #[test]
    fn popular_object_drives_ttl_up() {
        // Bursty hot objects whose miss savings dominate storage cost
        // produce positive corrections: λ̂·m >> c_i. Each burst (3 requests
        // 2 s apart) records hits in the measurement window; the gap lets
        // the ghost expire so the *next* burst opens a fresh window —
        // generating a continuing stream of positive updates (one per
        // object per residency, as in §5.1).
        let mut vc = mk(5.0);
        let t0 = vc.ttl_secs();
        let mut events: Vec<(u64, u64)> = Vec::new();
        for cycle in 0..60u64 {
            for obj in 0..30u64 {
                let base = cycle * 20 * SECOND + obj * 13; // stagger
                for k in 0..3u64 {
                    events.push((base + k * 2 * SECOND, obj));
                }
            }
        }
        events.sort_unstable(); // the cache requires a monotone clock
        for (ts, obj) in events {
            vc.on_request(ts, obj, 100);
        }
        // Updates flow until T outgrows the burst gap (then the hot set
        // stays resident and stops missing — the intended steady state);
        // enough fire to clear the 200-update gain warmup with room.
        assert!(vc.updates() > 220, "only {} updates", vc.updates());
        assert!(
            vc.ttl_secs() > t0,
            "ttl should grow: {} -> {}",
            t0,
            vc.ttl_secs()
        );
    }

    #[test]
    fn cold_large_objects_drive_ttl_down() {
        let mut vc = mk(100.0);
        let t0 = vc.ttl_secs();
        // Stream of one-hit wonders, each large: window closes with 0 hits
        // at eviction → correction = −c_i < 0.
        let mut now = 0;
        for i in 0..2000u64 {
            vc.on_request(now, i, 10 * 1024 * 1024);
            now += SECOND;
        }
        assert!(vc.updates() > 0);
        assert!(
            vc.ttl_secs() < t0,
            "ttl should shrink: {} -> {}",
            t0,
            vc.ttl_secs()
        );
    }

    #[test]
    fn vsize_tracks_insertions_and_expiry() {
        let mut vc = mk(10.0);
        vc.on_request(0, 1, 100);
        vc.on_request(0, 2, 200);
        assert_eq!(vc.vsize(), 300);
        vc.expire(3600 * SECOND);
        assert_eq!(vc.vsize(), 0);
        assert_eq!(vc.len(), 0);
    }

    #[test]
    fn plain_eq7_mode_also_moves() {
        // Un-normalized eq. (7) with a large constant gain.
        let ctrl = ControllerConfig {
            t_init_secs: 30.0,
            normalized: false,
            gain: GainSchedule::Constant { eps0: 5.0e9 },
            ..ControllerConfig::default()
        };
        let mut vc = VirtualCache::new(&ctrl, CostConfig::default());
        let mut now = 0;
        for _ in 0..300 {
            vc.on_request(now, 7, 1000);
            now += 2 * SECOND;
        }
        assert!(vc.updates() > 0);
        assert!(vc.ttl_secs() != 30.0);
    }
}
