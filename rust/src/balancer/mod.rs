//! The load balancer (§5.2): the single component on the request path.
//! It routes requests to cluster instances via the hash-slot map, inserts
//! on miss (after the simulated origin fetch), feeds each request to the
//! sizing policy's shadow structure, and at epoch boundaries applies the
//! policy's decision by resizing the cluster.
//!
//! Multi-tenant traces route on `(tenant, key)`: the tenant id is folded
//! into the hash-slot key ([`crate::tenant::scoped_object`]), so tenants
//! share the physical cluster without key collisions, and the policy's
//! shadow update is dispatched with the full request so per-tenant
//! controllers can claim it. Tenant 0 routes bit-for-bit like the
//! pre-tenant balancer.
//!
//! Mirrors the paper's custom mcrouter-like tool. Per-request cost:
//! routing O(1) + policy shadow work (O(1) for TTL, O(log M) for MRC) —
//! the Fig. 1 comparison is exactly these code paths.

use crate::admission::AdmissionFilter;
use crate::cluster::{Cluster, ClusterTelemetry};
use crate::config::Config;
use crate::cost::MissAccountant;
use crate::metrics::HitMiss;
use crate::scaler::EpochSizer;
use crate::telemetry::{Counter, TelemetryRegistry, Timer};
use crate::tenant::scoped_object;
use crate::trace::Request;
use crate::{TenantId, TimeUs};

/// Serve-path latency sampling stride: the `elastictl_serve_ns` timer
/// reads the clock on one request in this many (two `Instant::now()`
/// calls per sample would dominate an O(1) request path if taken on
/// every request; 1-in-64 keeps the distribution honest at < 2% of the
/// paths clocked).
const SERVE_SAMPLE_STRIDE: u64 = 64;

/// Pre-resolved balancer telemetry handles (request counters + the
/// per-stage epoch timers). Absent by default: the untelemetered
/// request path never touches them.
struct BalancerTelemetry {
    requests: Counter,
    hits: Counter,
    misses: Counter,
    spurious: Counter,
    denied: Counter,
    filter_denied: Counter,
    /// Sampled end-to-end `handle` latency (1 in [`SERVE_SAMPLE_STRIDE`]).
    serve_ns: Timer,
    /// Epoch stage: the policy's sizing decision (arbiter included).
    epoch_decide_ns: Timer,
    /// Epoch stage: placement re-pin / re-partition from fresh grants.
    epoch_placement_ns: Timer,
    /// Epoch stage: targeted shedding of over-cap tenants.
    epoch_shed_ns: Timer,
}

/// Outcome of one request through the balancer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Served {
    /// Physical hit at the responsible instance.
    pub hit: bool,
    /// The miss was *spurious*: the object is resident on some instance,
    /// but slot reassignment routed the request elsewhere (§5.2).
    pub spurious: bool,
    /// The request's object was admitted (on a miss, the fetched object
    /// was inserted). `false` when the tenant overran its occupancy cap
    /// under grant enforcement, or when the configured admission filter
    /// voted against the insert.
    pub admitted: bool,
    /// Policy work units performed (Fig. 1 proxy).
    pub work_units: u32,
}

/// The mcrouter-like front.
pub struct Balancer {
    pub cluster: Cluster,
    sizer: Box<dyn EpochSizer>,
    /// Total requests handled.
    pub requests: u64,
    /// Physical misses (including spurious).
    pub misses: u64,
    /// Spurious misses observed after resizes.
    pub spurious_misses: u64,
    /// Requests whose insert was refused by the policy's admission
    /// verdict (multi-tenant occupancy-cap enforcement).
    pub denied_admissions: u64,
    /// Requests whose insert was refused by the admission filter
    /// (`[admission] filter`) — disjoint from `denied_admissions`: a
    /// request denied by both counts only as a grant-cap denial (the
    /// filter's verdict is moot when the insert was already refused).
    pub filter_denials: u64,
    /// Cumulative policy work units.
    pub work_units: u64,
    /// Per-tenant hit/miss counters, indexed by tenant id (grown on
    /// demand; single-tenant traces only ever touch slot 0).
    tenant_stats: Vec<HitMiss>,
    /// Optional admission filter (`None` by default: the request path
    /// is bit-identical to the pre-filter balancer).
    filter: Option<Box<dyn AdmissionFilter>>,
    /// Cached `filter.needs_ttl()` so the hot path branches on a bool
    /// instead of a virtual call when the filter is TTL-blind.
    filter_needs_ttl: bool,
    /// Per-tenant filter denials, indexed by tenant id (grown on
    /// demand) — the journal's `filter_denials` source.
    tenant_filter_denials: Vec<u64>,
    /// Telemetry handles (`None` = off, zero request-path overhead).
    telemetry: Option<BalancerTelemetry>,
    /// Shedding at the most recent epoch boundary:
    /// `(tenant, resident bytes before, bytes freed)` — the decision
    /// journal's source for `shed_bytes`.
    last_epoch_shed: Vec<(TenantId, u64, u64)>,
}

impl Balancer {
    pub fn new(cluster: Cluster, sizer: Box<dyn EpochSizer>) -> Self {
        Balancer {
            cluster,
            sizer,
            requests: 0,
            misses: 0,
            spurious_misses: 0,
            denied_admissions: 0,
            filter_denials: 0,
            work_units: 0,
            tenant_stats: Vec::new(),
            filter: None,
            filter_needs_ttl: false,
            tenant_filter_denials: Vec::new(),
            telemetry: None,
            last_epoch_shed: Vec::new(),
        }
    }

    /// Install an admission filter ahead of the insert path. `None`
    /// (the default) keeps the balancer bit-identical to the
    /// pre-filter request path.
    pub fn set_filter(&mut self, filter: Option<Box<dyn AdmissionFilter>>) {
        self.filter_needs_ttl = filter.as_ref().map(|f| f.needs_ttl()).unwrap_or(false);
        self.filter = filter;
    }

    /// The installed admission filter's name, if any.
    pub fn filter_name(&self) -> Option<&'static str> {
        self.filter.as_ref().map(|f| f.name())
    }

    /// Attach telemetry: resolve the balancer's and cluster's handles
    /// from `registry` (once — the hot path records through them at
    /// O(1)) and forward the registry to the sizing policy for its
    /// per-stage epoch timers.
    pub fn attach_telemetry(&mut self, registry: &mut TelemetryRegistry) {
        self.sizer.attach_telemetry(registry);
        self.cluster.set_telemetry(ClusterTelemetry::resolve(registry));
        self.telemetry = Some(BalancerTelemetry {
            requests: registry.counter("elastictl_requests_total"),
            hits: registry.counter("elastictl_hits_total"),
            misses: registry.counter("elastictl_misses_total"),
            spurious: registry.counter("elastictl_spurious_misses_total"),
            denied: registry.counter("elastictl_denied_admissions_total"),
            filter_denied: registry.counter("elastictl_filter_denials_total"),
            serve_ns: registry.timer("elastictl_serve_ns"),
            epoch_decide_ns: registry.timer("elastictl_epoch_decide_ns"),
            epoch_placement_ns: registry.timer("elastictl_epoch_placement_ns"),
            epoch_shed_ns: registry.timer("elastictl_epoch_shed_ns"),
        });
    }

    /// Shedding performed at the most recent epoch boundary:
    /// `(tenant, resident bytes before, bytes freed)`.
    pub fn last_epoch_shed(&self) -> &[(TenantId, u64, u64)] {
        &self.last_epoch_shed
    }

    /// Build a balancer from config (initial size = policy's first guess
    /// for elastic policies, `fixed_instances` otherwise).
    pub fn from_config(cfg: &Config, sizer: Box<dyn EpochSizer>, initial: u32) -> Self {
        let cluster = Cluster::new(&cfg.cluster, cfg.cost.instance.ram_bytes, initial);
        let mut b = Self::new(cluster, sizer);
        b.set_filter(crate::admission::build_filter(cfg));
        b
    }

    pub fn sizer(&self) -> &dyn EpochSizer {
        self.sizer.as_ref()
    }

    /// Handle one request: feed the tenant's physical occupancy to the
    /// policy, run its shadow update (which doubles as the admission
    /// verdict under grant enforcement), route via the placement policy
    /// on `(tenant, key)`, serve, account, feed the physical outcome back.
    ///
    /// Generic over the miss-billing sink: the monolithic engine passes
    /// its [`crate::cost::CostTracker`]; shard workers pass a local
    /// coalescing ledger merged exactly at the epoch barrier.
    pub fn handle<M: MissAccountant>(&mut self, req: &Request, costs: &mut M) -> Served {
        self.requests += 1;
        // Sampled serve-latency clock: with telemetry off (or off-stride)
        // no clock is read and no handle is touched.
        let serve_t0 = match &self.telemetry {
            Some(_) if self.requests % SERVE_SAMPLE_STRIDE == 0 => {
                Some(std::time::Instant::now())
            }
            _ => None,
        };
        // O(1) ledger read: resident-byte-binding policies compare the
        // tenant's physical occupancy against its cap in `on_request`.
        self.sizer
            .note_physical(req.tenant, self.cluster.tenant_resident_bytes(req.tenant));
        let work = self.sizer.on_request(req);
        self.work_units += work.units as u64;
        // Admission-filter vote: the filter observes every request (an
        // Mth-request sketch must count hits too, or a popular key's
        // count would freeze once resident) but only gates the insert
        // below. TTL-pricing filters get the tenant's current timer; a
        // TTL-blind filter skips even that O(1) lookup.
        let filter_ok = match self.filter.as_mut() {
            Some(f) => {
                let ttl = if self.filter_needs_ttl {
                    self.sizer.tenant_ttl_secs(req.tenant)
                } else {
                    None
                };
                f.observe(req, ttl)
            }
            None => true,
        };
        let admit = work.admit && filter_ok;

        let obj = scoped_object(req.tenant, req.obj);
        let routed = self.cluster.route_for(req.tenant, obj);
        // A refused admission still serves the request (the origin fetch
        // happens either way) — it only skips the insert, bounding how
        // far a tenant can push resident bytes beyond its granted share
        // of the shared cluster (re-admissions of its virtually-resident
        // set stay exempt: that is repair traffic its grant already
        // covers, and overage is reclaimed by targeted shedding at the
        // epoch boundary instead).
        let hit = if admit {
            self.cluster.serve_for(req.tenant, obj, req.size_bytes())
        } else {
            self.cluster.serve_no_insert_for(req.tenant, obj)
        };
        if !hit {
            // Count only denials that actually suppressed an insert (a
            // physical hit needed none), matching the per-tenant
            // `denied_admissions` in the enforcement rows. A grant-cap
            // denial shadows the filter's verdict: the two counters
            // partition the suppressed inserts.
            if !work.admit {
                self.denied_admissions += 1;
            } else if !filter_ok {
                self.filter_denials += 1;
                let i = req.tenant as usize;
                if self.tenant_filter_denials.len() <= i {
                    self.tenant_filter_denials.resize(i + 1, 0);
                }
                self.tenant_filter_denials[i] += 1;
            }
        }
        let mut spurious = false;
        if !hit {
            self.misses += 1;
            costs.record_miss_for(req.tenant, req.size_bytes());
            // The miss is spurious iff another instance still holds a stale
            // copy (the slot moved under it). The routed instance is
            // excluded: `serve` just inserted the object there. Checked
            // only on misses; bounded by the instance count.
            if self.cluster.resident_elsewhere(obj, routed) {
                spurious = true;
                self.spurious_misses += 1;
            }
        }
        let i = req.tenant as usize;
        if self.tenant_stats.len() <= i {
            self.tenant_stats.resize(i + 1, HitMiss::default());
        }
        self.tenant_stats[i].record(hit);
        // Close the loop: SLO measurement + admission-budget charging.
        self.sizer.on_served(req, hit, &work);
        if let Some(tel) = &self.telemetry {
            tel.requests.inc();
            if hit {
                tel.hits.inc();
            } else {
                tel.misses.inc();
            }
            if spurious {
                tel.spurious.inc();
            }
            if !hit {
                if !work.admit {
                    tel.denied.inc();
                } else if !filter_ok {
                    tel.filter_denied.inc();
                }
            }
            if let Some(t0) = serve_t0 {
                tel.serve_ns.record_ns(t0.elapsed().as_nanos() as u64);
            }
        }
        Served { hit, spurious, admitted: admit, work_units: work.units }
    }

    /// Epoch boundary: ask the policy for `I(k+1)`, resize, run the
    /// placement maintenance (re-pin / re-partition from the fresh
    /// grants, then shed tenants past their binding occupancy caps),
    /// drain retiring tenants, and return the new size. The *ending*
    /// epoch is billed by the caller at the size that was active (§2.3's
    /// synchronous billing).
    pub fn end_epoch(&mut self, now: TimeUs) -> u32 {
        self.last_epoch_shed.clear();
        // Reap entries whose real TTL ran out without being accessed
        // (server runtime; a no-op — not even a branch per entry — when
        // expiry is off).
        self.cluster.expire_sweep();
        // Age the admission filter's sketch (halve counts) once per
        // epoch — mirrored by `begin_epoch_shard` on the sharded path.
        if let Some(f) = self.filter.as_mut() {
            f.end_epoch();
        }
        let decide_timer = self.telemetry.as_ref().map(|t| t.epoch_decide_ns.clone());
        let target = match decide_timer {
            Some(timer) => timer.time(|| self.sizer.decide(now)),
            None => self.sizer.decide(now),
        };
        self.cluster.resize(target);
        self.apply_enforcement();
        self.drain_retiring(now);
        self.cluster.len() as u32
    }

    /// Post-resize placement maintenance, shared by [`Self::end_epoch`]
    /// and the sharded barrier ([`Self::finish_epoch_shard`]): re-pin /
    /// re-partition from the policy's fresh grants, then shed tenants
    /// past their binding occupancy caps.
    fn apply_enforcement(&mut self) {
        if let Some(rows) = self.sizer.enforcement() {
            let grants: Vec<crate::placement::TenantGrant> = rows
                .iter()
                .filter(|r| r.decided)
                .map(|r| crate::placement::TenantGrant {
                    tenant: r.tenant,
                    granted_bytes: r.granted_bytes,
                    reserved_bytes: r.reserved_bytes,
                })
                .collect();
            if !grants.is_empty() {
                let place_timer =
                    self.telemetry.as_ref().map(|t| t.epoch_placement_ns.clone());
                match place_timer {
                    Some(timer) => timer.time(|| self.cluster.apply_grants(&grants)),
                    None => self.cluster.apply_grants(&grants),
                }
            }
            // Binding caps: bring every over-cap tenant back to its grant
            // by evicting its own coldest entries (targeted shedding).
            let shed_timer = self.telemetry.as_ref().map(|t| t.epoch_shed_ns.clone());
            let shed = |cluster: &mut Cluster, log: &mut Vec<(TenantId, u64, u64)>| {
                for r in &rows {
                    if r.enforced {
                        if let Some(cap) = r.cap_bytes {
                            let before = cluster.tenant_resident_bytes(r.tenant);
                            let freed = cluster.shed_tenant(r.tenant, cap);
                            if freed > 0 {
                                log.push((r.tenant, before, freed));
                            }
                        }
                    }
                }
            };
            match shed_timer {
                Some(timer) => {
                    timer.time(|| shed(&mut self.cluster, &mut self.last_epoch_shed))
                }
                None => shed(&mut self.cluster, &mut self.last_epoch_shed),
            }
        }
    }

    /// Shard-side first half of the epoch barrier, mirroring the opening
    /// of [`Self::end_epoch`] exactly (shed log cleared, expired entries
    /// reaped) but *reporting* the policy's per-tenant demand rows
    /// instead of deciding locally — the front merges every shard's rows
    /// into the one arbiter decision. `None` means the policy cannot
    /// shard (no demand-row representation); the engine falls back to a
    /// single engine in that case.
    pub fn begin_epoch_shard(&mut self, now: TimeUs) -> Option<Vec<crate::tenant::TenantDemand>> {
        self.last_epoch_shed.clear();
        self.cluster.expire_sweep();
        // Exactly one sketch aging per barrier, mirroring `end_epoch`
        // (the finish half must not age again).
        if let Some(f) = self.filter.as_mut() {
            f.end_epoch();
        }
        self.sizer.shard_demands(now)
    }

    /// Shard-side second half of the epoch barrier: apply the front's
    /// split of its single decision — this shard's slice of the grants,
    /// then the cluster resize to this shard's slice of the instance
    /// target — and run the same placement maintenance + retirement
    /// drain [`Self::end_epoch`] runs, in the same order. Returns the
    /// shard cluster's new size.
    pub fn finish_epoch_shard(
        &mut self,
        now: TimeUs,
        target: u32,
        allocs: &[crate::tenant::TenantAllocation],
    ) -> u32 {
        self.sizer.shard_apply_grants(allocs);
        self.cluster.resize(target);
        self.apply_enforcement();
        self.drain_retiring(now);
        self.cluster.len() as u32
    }

    /// Retirement drain: a draining tenant's placement state is released
    /// and its whole ledger row shed (cap 0). Once the row reads zero
    /// the policy transitions it to Retired and the engine reconciles
    /// its bill. Not gated on `enforce_grants` — retiring must reclaim
    /// memory even when grants are reporting-only. Runs at every epoch
    /// boundary, and once more when the engine finishes so a retirement
    /// landing in the final partial epoch still reconciles.
    pub fn drain_retiring(&mut self, now: TimeUs) {
        for t in self.sizer.draining() {
            self.cluster.release_tenant(t);
            let before = self.cluster.tenant_resident_bytes(t);
            let freed = self.cluster.shed_tenant(t, 0);
            if freed > 0 {
                self.last_epoch_shed.push((t, before, freed));
            }
            if self.cluster.tenant_resident_bytes(t) == 0 {
                self.sizer.note_drained(t, now);
            }
        }
    }

    /// Admit (or update) a tenant mid-run (delegates to the policy).
    pub fn admit_tenant(
        &mut self,
        spec: crate::tenant::TenantSpec,
        now: TimeUs,
    ) -> crate::Result<crate::tenant::AdmitOutcome> {
        self.sizer.admit_tenant(spec, now)
    }

    /// Begin retiring a tenant mid-run (delegates to the policy).
    pub fn retire_tenant(&mut self, tenant: TenantId, now: TimeUs) -> crate::Result<()> {
        self.sizer.retire_tenant(tenant, now)
    }

    /// Tenants whose drain completed since the last call.
    pub fn take_retired(&mut self) -> Vec<TenantId> {
        self.sizer.take_retired()
    }

    /// Per-tenant lifecycle records, when the policy tracks them.
    pub fn lifecycle(&self) -> Option<Vec<(TenantId, crate::tenant::Lifecycle)>> {
        self.sizer.lifecycle()
    }

    /// The spec currently registered for `tenant`, when the policy keeps
    /// a registry.
    pub fn tenant_spec(&self, tenant: TenantId) -> Option<crate::tenant::TenantSpec> {
        self.sizer.tenant_spec(tenant)
    }

    /// Overall miss ratio so far.
    pub fn miss_ratio(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.misses as f64 / self.requests as f64
        }
    }

    /// Per-tenant counters, indexed by tenant id (empty slots for ids the
    /// trace never used).
    pub fn tenant_stats(&self) -> &[HitMiss] {
        &self.tenant_stats
    }

    /// Counters for one tenant (zero if never seen).
    pub fn tenant_stats_of(&self, t: TenantId) -> HitMiss {
        self.tenant_stats
            .get(t as usize)
            .copied()
            .unwrap_or_default()
    }

    /// Cumulative admission-filter denials for one tenant (zero if the
    /// filter never refused it, or no filter is configured).
    pub fn tenant_filter_denials_of(&self, t: TenantId) -> u64 {
        self.tenant_filter_denials
            .get(t as usize)
            .copied()
            .unwrap_or(0)
    }

    /// Cumulative admission-filter denials, indexed by tenant id (empty
    /// slots for tenants the filter never refused).
    pub fn tenant_filter_denials(&self) -> &[u64] {
        &self.tenant_filter_denials
    }

    /// Policy diagnostics for the figure series.
    pub fn ttl_secs(&self) -> Option<f64> {
        self.sizer.ttl_secs()
    }

    pub fn shadow_size(&self) -> Option<u64> {
        self.sizer.shadow_size()
    }

    /// Per-tenant timers, when the policy runs one controller per tenant.
    pub fn tenant_ttls(&self) -> Option<Vec<(TenantId, f64)>> {
        self.sizer.tenant_ttls()
    }

    /// Per-tenant enforcement state, when the policy arbitrates tenants.
    pub fn tenant_enforcement(&self) -> Option<Vec<crate::tenant::TenantEnforcement>> {
        self.sizer.enforcement()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, PolicyKind};
    use crate::cost::CostTracker;
    use crate::scaler::make_sizer;
    use crate::SECOND;

    fn mk(policy: PolicyKind, initial: u32) -> (Balancer, CostTracker) {
        let cfg = Config::with_policy(policy);
        let sizer = make_sizer(&cfg);
        let b = Balancer::from_config(&cfg, sizer, initial);
        let c = CostTracker::new(cfg.cost.clone());
        (b, c)
    }

    fn req(ts: u64, obj: u64, size: u32) -> Request {
        Request::new(ts, obj, size)
    }

    #[test]
    fn miss_then_hit_with_accounting() {
        let (mut b, mut c) = mk(PolicyKind::Fixed, 2);
        let r = req(0, 1, 1000);
        let s1 = b.handle(&r, &mut c);
        assert!(!s1.hit);
        let s2 = b.handle(&req(SECOND, 1, 1000), &mut c);
        assert!(s2.hit);
        assert_eq!(b.requests, 2);
        assert_eq!(b.misses, 1);
        assert!(c.miss_total() > 0.0);
        assert!((b.miss_ratio() - 0.5).abs() < 1e-12);
        // Everything landed on tenant 0's counters.
        assert_eq!(b.tenant_stats_of(0).total(), 2);
        assert_eq!(b.tenant_stats_of(1).total(), 0);
    }

    #[test]
    fn fixed_policy_never_resizes() {
        let (mut b, mut c) = mk(PolicyKind::Fixed, 8);
        for i in 0..100u64 {
            b.handle(&req(i, i, 100), &mut c);
        }
        assert_eq!(b.end_epoch(crate::HOUR), 8);
        assert_eq!(b.cluster.resizes, 0);
    }

    #[test]
    fn ttl_policy_resizes_cluster() {
        let cfg = Config::with_policy(PolicyKind::Ttl);
        let mut ctrl_cfg = cfg.clone();
        ctrl_cfg.controller.t_init_secs = 7200.0; // sticky ghosts
        let sizer = make_sizer(&ctrl_cfg);
        let mut b = Balancer::from_config(&ctrl_cfg, sizer, 1);
        let mut c = CostTracker::new(ctrl_cfg.cost.clone());
        let inst = ctrl_cfg.cost.instance.ram_bytes;
        // ~3 instances worth of distinct objects.
        for i in 0..30u64 {
            b.handle(&req(i * SECOND, i, (inst / 10) as u32), &mut c);
        }
        let n = b.end_epoch(40 * SECOND);
        assert!(n >= 2, "n={n}");
        assert!(b.cluster.resizes >= 1);
        assert!(b.ttl_secs().is_some());
        assert!(b.shadow_size().unwrap() > 0);
    }

    #[test]
    fn tenants_do_not_collide_on_shared_cluster() {
        // The same tenant-local key from two tenants must be two distinct
        // physical objects — and tenant stats must separate them.
        let (mut b, mut c) = mk(PolicyKind::Fixed, 4);
        let s1 = b.handle(&req(0, 42, 100).with_tenant(1), &mut c);
        assert!(!s1.hit);
        let s2 = b.handle(&req(1, 42, 100).with_tenant(2), &mut c);
        assert!(!s2.hit, "tenant 2 must not hit tenant 1's object");
        let s3 = b.handle(&req(2, 42, 100).with_tenant(1), &mut c);
        assert!(s3.hit);
        assert_eq!(b.tenant_stats_of(1).hits, 1);
        assert_eq!(b.tenant_stats_of(1).misses, 1);
        assert_eq!(b.tenant_stats_of(2).misses, 1);
        assert_eq!(b.tenant_stats_of(0).total(), 0);
    }

    #[test]
    fn tenant_policy_reports_per_tenant_ttls() {
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.tenants = vec![
            crate::tenant::TenantSpec::new(0, "a"),
            crate::tenant::TenantSpec::new(1, "b").with_multiplier(2.0),
        ];
        let sizer = make_sizer(&cfg);
        let mut b = Balancer::from_config(&cfg, sizer, 1);
        let mut c = CostTracker::new(cfg.cost.clone());
        b.handle(&req(0, 1, 100).with_tenant(0), &mut c);
        b.handle(&req(1, 1, 100).with_tenant(1), &mut c);
        let ttls = b.tenant_ttls().expect("tenant policy exposes ttls");
        assert_eq!(ttls.len(), 2);
    }

    #[test]
    fn denied_admissions_skip_the_insert() {
        // An enforcing tenant policy with a tiny capacity: after the
        // first epoch decision caps the flood tenant, its misses must
        // stop materializing as inserts — repeated requests keep missing.
        let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
        cfg.controller.t_init_secs = 3600.0;
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 1;
        cfg.scaler.enforce_grants = true;
        cfg.tenants = vec![
            crate::tenant::TenantSpec::new(1, "gold").with_multiplier(10.0),
            crate::tenant::TenantSpec::new(2, "flood").with_multiplier(0.1),
        ];
        let sizer = make_sizer(&cfg);
        let mut b = Balancer::from_config(&cfg, sizer, 1);
        let mut c = CostTracker::new(cfg.cost.clone());
        // Flood demand far past the 1 MB capacity; gold takes a slice.
        for i in 0..30u64 {
            b.handle(&req(i * SECOND, i, 100_000).with_tenant(2), &mut c);
        }
        for i in 0..5u64 {
            b.handle(&req(30 * SECOND + i, i, 100_000).with_tenant(1), &mut c);
        }
        assert_eq!(b.denied_admissions, 0, "no caps before the first epoch");
        b.end_epoch(31 * SECOND);
        // Next epoch: flood blows through its budget; the denials skip
        // inserts, so a denied object stays a miss on re-request.
        let before = b.denied_admissions;
        for i in 0..30u64 {
            b.handle(&req(32 * SECOND + i, 1000 + i, 100_000).with_tenant(2), &mut c);
        }
        assert!(b.denied_admissions > before, "flood must be refused");
        let s = b.handle(&req(33 * SECOND, 1029, 100_000).with_tenant(2), &mut c);
        assert!(!s.hit, "denied object must not have been inserted");
        // Gold keeps admitting within its grant.
        let s = b.handle(&req(34 * SECOND, 3, 100_000).with_tenant(1), &mut c);
        assert!(s.admitted);
        assert!(b.tenant_enforcement().is_some());
    }

    #[test]
    fn filter_denials_skip_the_insert() {
        // A 2nd-request filter under the default policy: the first
        // observation of every key is refused (served, not inserted),
        // the second admits — so the third request of a key is the
        // first that can physically hit.
        let mut cfg = Config::with_policy(PolicyKind::Fixed);
        cfg.admission.filter = crate::config::AdmissionKind::MthRequest;
        cfg.admission.m = 2;
        let sizer = make_sizer(&cfg);
        let mut b = Balancer::from_config(&cfg, sizer, 2);
        let mut c = CostTracker::new(cfg.cost.clone());
        assert_eq!(b.filter_name(), Some("mth_request"));
        let s1 = b.handle(&req(0, 7, 1000), &mut c);
        assert!(!s1.hit && !s1.admitted, "first sight must be refused");
        assert_eq!(b.filter_denials, 1);
        assert_eq!(b.denied_admissions, 0, "filter denials are separate");
        let s2 = b.handle(&req(SECOND, 7, 1000), &mut c);
        assert!(!s2.hit, "object was never inserted");
        assert!(s2.admitted, "2nd observation reaches M=2");
        let s3 = b.handle(&req(2 * SECOND, 7, 1000), &mut c);
        assert!(s3.hit, "admitted insert must serve the 3rd request");
        assert_eq!(b.filter_denials, 1);
        assert_eq!(b.tenant_filter_denials_of(0), 1);
        assert_eq!(b.tenant_filter_denials_of(1), 0);
    }

    #[test]
    fn spurious_misses_detected_after_grow() {
        let (mut b, mut c) = mk(PolicyKind::Fixed, 2);
        for i in 0..3000u64 {
            b.handle(&req(i, i % 1500, 100), &mut c);
        }
        // Force a manual resize (bypassing the fixed policy) and replay.
        b.cluster.resize(5);
        let before = b.spurious_misses;
        for i in 0..1500u64 {
            b.handle(&req(4000 + i, i, 100), &mut c);
        }
        assert!(
            b.spurious_misses > before,
            "no spurious misses after resize"
        );
    }

    #[test]
    fn work_units_accumulate() {
        let (mut b, mut c) = mk(PolicyKind::Mrc, 2);
        for i in 0..500u64 {
            b.handle(&req(i, i % 100, 100), &mut c);
        }
        assert!(b.work_units > 500, "MRC must cost >1 unit/request");
        let (mut b2, mut c2) = mk(PolicyKind::Fixed, 2);
        for i in 0..500u64 {
            b2.handle(&req(i, i % 100, 100), &mut c2);
        }
        assert_eq!(b2.work_units, 500);
        assert!(b.work_units > 2 * b2.work_units);
    }
}
