//! Composable admission filters — *should this miss be inserted at all?*
//!
//! The paper's controller decides *how long* to keep objects (the TTL
//! timer) but admits every miss, so one-hit-wonder-heavy traces pay
//! storage for bytes that never hit again. This module adds the missing
//! axis as a config-selectable layer under every
//! [`crate::config::PolicyKind`] (`[admission] filter = ...`), threaded
//! through [`crate::balancer::Balancer::handle`] alongside the grant-cap
//! denial: a denied insert still serves the miss
//! ([`crate::cluster::Cluster::serve_no_insert_for`]), it just never
//! occupies cluster RAM.
//!
//! Two O(1)-per-request filters (the paper's own complexity constraint):
//!
//! * [`MthRequestFilter`] — *cache on Mth request* (Carlsson & Eager,
//!   arXiv 1812.07264): a per-`(tenant, key)` request-count gate backed
//!   by a fixed-size 4-bit counting sketch. A key's insert is admitted
//!   on (or, under cell collisions, before) its Mth observed request;
//!   epoch boundaries halve every counter so stale popularity decays.
//!   The sketch is direct-indexed by the *same* hash the shard router
//!   uses (`mix64(scoped_object(tenant, key))`), so with a power-of-two
//!   cell count every pair of colliding keys also co-shards for
//!   power-of-two shard counts — per-shard sketches are bit-identical
//!   to the monolithic one (pinned by `sharded_parity`).
//! * [`KeepCostFilter`] — *to keep or not to keep* (Le Scouarnec et
//!   al., arXiv 1312.0499): admit iff the expected miss dollars saved
//!   by caching (`multiplier × m_o`) are at least the expected storage
//!   dollars of holding the object for the tenant's current TTL
//!   (`threshold × s_o × c × T_i`). Stateless; reads the tenant's live
//!   timer via [`crate::scaler::EpochSizer::tenant_ttl_secs`].
//!
//! Sketch guarantees (pinned by `tests/admission_properties.rs`):
//! counters never under-count (one cell per key, increments only, so a
//! key's cell is at least its true observation count → admission never
//! happens *later* than the true Mth request), collisions only admit
//! *early* at a rate bounded by the sketch load factor, aging halves
//! every counter exactly (floor), and state stays at the configured
//! byte budget regardless of unique-key count.

#![warn(missing_docs)]

use crate::config::{AdmissionKind, Config, CostConfig};
use crate::tenant::scoped_object;
use crate::trace::Request;
use crate::{ObjectId, TenantId};

/// Saturation ceiling of one 4-bit sketch counter. `[admission] m` is
/// validated to stay at or below this, so a saturated cell always admits.
pub const SKETCH_COUNTER_MAX: u8 = 15;

/// An admission-side vote on one request, consulted by the balancer
/// after the policy's own verdict (grant caps, draining tenants). The
/// combined verdict is the AND of both: the filter can only *suppress*
/// inserts the policy would have allowed, never force one.
pub trait AdmissionFilter {
    /// Observe one request and vote on inserting it if it misses. Runs
    /// on the hot path for *every* request (hits included — the Mth
    /// sketch counts observations, not misses); must be O(1).
    ///
    /// `ttl_secs` is the requesting tenant's current timer (only
    /// fetched when [`AdmissionFilter::needs_ttl`] says so); `None`
    /// means the policy keeps no timer and TTL-priced filters stay
    /// inert (admit).
    fn observe(&mut self, req: &Request, ttl_secs: Option<f64>) -> bool;

    /// Whether [`AdmissionFilter::observe`] wants the tenant's current
    /// TTL. The balancer skips the timer lookup entirely when this is
    /// false, keeping the Mth-request hot path free of it.
    fn needs_ttl(&self) -> bool {
        false
    }

    /// Epoch boundary: age the filter state (the Mth sketch halves its
    /// counters). Called once per boundary by both the monolithic
    /// balancer and each shard worker, so sharded and monolithic
    /// sketches age in lockstep.
    fn end_epoch(&mut self);

    /// Stable filter name (`mth_request` | `keep_cost`).
    fn name(&self) -> &'static str;

    /// Bytes of filter state — constant for the run, whatever the
    /// unique-key count (pinned by `admission_properties`).
    fn state_bytes(&self) -> usize;
}

/// Build the configured filter, if any (`[admission] filter`, default
/// `none` → `None`: the request path stays bit-identical to the seed).
pub fn build_filter(cfg: &Config) -> Option<Box<dyn AdmissionFilter>> {
    match cfg.admission.filter {
        AdmissionKind::None => None,
        AdmissionKind::MthRequest => Some(Box::new(MthRequestFilter::from_config(cfg))),
        AdmissionKind::KeepCost => Some(Box::new(KeepCostFilter::from_config(cfg))),
    }
}

/// *Cache on Mth request*: a fixed-size 4-bit counting sketch over
/// `(tenant, key)`, admitting an insert once the key's cell has seen M
/// observations. One hash, one cell per key (a direct-indexed, depth-1
/// counting Bloom filter): collisions can only *over*-count, so the
/// filter never admits later than the true Mth request.
pub struct MthRequestFilter {
    /// Packed 4-bit counters, two per byte (`2 × cells.len()` cells).
    cells: Vec<u8>,
    /// Cell-index mask (`cell_count - 1`; the count is a power of two).
    mask: u64,
    /// Per-tenant M overrides, dense by tenant id; missing → default.
    m: Vec<u8>,
    /// `[admission] m` — admit on the Mth observed request.
    default_m: u8,
}

impl MthRequestFilter {
    /// A sketch of `sketch_bytes` (rounded up to a power of two, min 2)
    /// admitting on the `m`th observed request (clamped to
    /// 1..=[`SKETCH_COUNTER_MAX`]).
    pub fn new(sketch_bytes: usize, m: u32) -> MthRequestFilter {
        let bytes = sketch_bytes.max(2).next_power_of_two();
        MthRequestFilter {
            cells: vec![0u8; bytes],
            mask: (bytes as u64 * 2) - 1,
            m: Vec::new(),
            default_m: m.clamp(1, SKETCH_COUNTER_MAX as u32) as u8,
        }
    }

    /// Build from `[admission]` (sketch size, default M, per-tenant
    /// `admission_m` overrides).
    pub fn from_config(cfg: &Config) -> MthRequestFilter {
        let mut f = MthRequestFilter::new(cfg.admission.sketch_bytes as usize, cfg.admission.m);
        for o in &cfg.admission.overrides {
            if let Some(m) = o.m {
                f.set_tenant_m(o.tenant, m);
            }
        }
        f
    }

    /// Override one tenant's M (clamped to 1..=[`SKETCH_COUNTER_MAX`]).
    pub fn set_tenant_m(&mut self, tenant: TenantId, m: u32) {
        let i = tenant as usize;
        if self.m.len() <= i {
            let d = self.default_m;
            self.m.resize(i + 1, d);
        }
        self.m[i] = m.clamp(1, SKETCH_COUNTER_MAX as u32) as u8;
    }

    /// The M in force for `tenant`.
    #[inline]
    pub fn m_of(&self, tenant: TenantId) -> u8 {
        self.m.get(tenant as usize).copied().unwrap_or(self.default_m)
    }

    /// Cell index of `(tenant, obj)` — the shard router's hash
    /// (`mix64 ∘ scoped_object`) masked to the cell count, so colliding
    /// keys share their low bits and therefore their shard.
    #[inline]
    fn cell_of(&self, tenant: TenantId, obj: ObjectId) -> usize {
        (crate::mix64(scoped_object(tenant, obj)) & self.mask) as usize
    }

    /// Saturating-increment the cell; returns the post-increment count.
    #[inline]
    fn bump(&mut self, cell: usize) -> u8 {
        let byte = &mut self.cells[cell >> 1];
        if cell & 1 == 0 {
            let v = *byte & 0x0F;
            if v < SKETCH_COUNTER_MAX {
                *byte = (*byte & 0xF0) | (v + 1);
                v + 1
            } else {
                SKETCH_COUNTER_MAX
            }
        } else {
            let v = *byte >> 4;
            if v < SKETCH_COUNTER_MAX {
                *byte = (*byte & 0x0F) | ((v + 1) << 4);
                v + 1
            } else {
                SKETCH_COUNTER_MAX
            }
        }
    }

    /// Current sketch count for `(tenant, obj)` — a diagnostic read for
    /// tests and tooling; the hot path never calls it.
    pub fn count(&self, tenant: TenantId, obj: ObjectId) -> u8 {
        let cell = self.cell_of(tenant, obj);
        let byte = self.cells[cell >> 1];
        if cell & 1 == 0 {
            byte & 0x0F
        } else {
            byte >> 4
        }
    }

    /// Number of 4-bit cells.
    pub fn cell_count(&self) -> usize {
        self.cells.len() * 2
    }
}

impl AdmissionFilter for MthRequestFilter {
    #[inline]
    fn observe(&mut self, req: &Request, _ttl_secs: Option<f64>) -> bool {
        let cell = self.cell_of(req.tenant, req.obj);
        self.bump(cell) >= self.m_of(req.tenant)
    }

    fn end_epoch(&mut self) {
        // Exact halving (floor) of every 4-bit counter, both nibbles at
        // once: popularity decays geometrically across epochs, so the
        // sketch tracks the recent request mix instead of all history.
        for b in &mut self.cells {
            let hi = (*b >> 4) >> 1;
            let lo = (*b & 0x0F) >> 1;
            *b = (hi << 4) | lo;
        }
    }

    fn name(&self) -> &'static str {
        "mth_request"
    }

    fn state_bytes(&self) -> usize {
        self.cells.len()
    }
}

/// *To keep or not to keep*: admit iff the expected miss dollars of not
/// caching (`multiplier × m_o`) are at least `threshold ×` the expected
/// storage dollars of holding the object for the tenant's current TTL
/// (`s_o × c × T_i`). Stateless and exact — no sketch, no aging.
pub struct KeepCostFilter {
    cost: CostConfig,
    per_byte_sec: f64,
    /// Per-tenant threshold overrides, dense by tenant id.
    thresholds: Vec<f64>,
    default_threshold: f64,
    /// Per-tenant miss-cost multipliers from the roster, dense by id.
    multipliers: Vec<f64>,
}

impl KeepCostFilter {
    /// Build from the cost catalog and `[admission] keep_threshold`
    /// (with per-tenant `keep_threshold` overrides and the roster's
    /// miss-cost multipliers).
    pub fn from_config(cfg: &Config) -> KeepCostFilter {
        let mut f = KeepCostFilter {
            per_byte_sec: cfg.cost.storage_cost_per_byte_sec(),
            cost: cfg.cost.clone(),
            thresholds: Vec::new(),
            default_threshold: cfg.admission.keep_threshold,
            multipliers: Vec::new(),
        };
        for t in &cfg.tenants {
            f.set_multiplier(t.id, t.miss_cost_multiplier);
        }
        for o in &cfg.admission.overrides {
            if let Some(th) = o.keep_threshold {
                f.set_threshold(o.tenant, th);
            }
        }
        f
    }

    /// Direct constructor for tests/tools: catalog costs, one global
    /// threshold, no per-tenant state.
    pub fn new(cost: CostConfig, threshold: f64) -> KeepCostFilter {
        KeepCostFilter {
            per_byte_sec: cost.storage_cost_per_byte_sec(),
            cost,
            thresholds: Vec::new(),
            default_threshold: threshold,
            multipliers: Vec::new(),
        }
    }

    /// Override one tenant's keep threshold.
    pub fn set_threshold(&mut self, tenant: TenantId, threshold: f64) {
        let i = tenant as usize;
        if self.thresholds.len() <= i {
            let d = self.default_threshold;
            self.thresholds.resize(i + 1, d);
        }
        self.thresholds[i] = threshold;
    }

    /// Set one tenant's miss-cost multiplier (roster tenants get theirs
    /// at construction; strays default to 1.0).
    pub fn set_multiplier(&mut self, tenant: TenantId, multiplier: f64) {
        let i = tenant as usize;
        if self.multipliers.len() <= i {
            self.multipliers.resize(i + 1, 1.0);
        }
        self.multipliers[i] = multiplier;
    }

    #[inline]
    fn threshold_of(&self, tenant: TenantId) -> f64 {
        self.thresholds
            .get(tenant as usize)
            .copied()
            .unwrap_or(self.default_threshold)
    }

    #[inline]
    fn multiplier_of(&self, tenant: TenantId) -> f64 {
        self.multipliers.get(tenant as usize).copied().unwrap_or(1.0)
    }
}

impl AdmissionFilter for KeepCostFilter {
    #[inline]
    fn observe(&mut self, req: &Request, ttl_secs: Option<f64>) -> bool {
        // No timer (fixed/MRC policies before their first decision, or
        // policies that keep none): the expected residency is unknown,
        // so the filter stays inert rather than guessing.
        let Some(ttl) = ttl_secs else { return true };
        let size = req.size_bytes();
        let miss = self.multiplier_of(req.tenant) * self.cost.miss_cost(size);
        let storage = size as f64 * self.per_byte_sec * ttl;
        miss >= self.threshold_of(req.tenant) * storage
    }

    fn needs_ttl(&self) -> bool {
        true
    }

    fn end_epoch(&mut self) {}

    fn name(&self) -> &'static str {
        "keep_cost"
    }

    fn state_bytes(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AdmissionKind;

    #[test]
    fn mth_admits_on_the_mth_observation() {
        let mut f = MthRequestFilter::new(1 << 14, 3);
        let req = |i: u64| Request::new(i, 42, 1000);
        assert!(!f.observe(&req(0), None));
        assert!(!f.observe(&req(1), None));
        assert!(f.observe(&req(2), None), "3rd observation admits");
        assert!(f.observe(&req(3), None), "and it stays admitted");
        assert_eq!(f.count(0, 42), 4);
    }

    #[test]
    fn m_of_one_admits_immediately() {
        let mut f = MthRequestFilter::new(1 << 14, 1);
        assert!(f.observe(&Request::new(0, 7, 10), None));
    }

    #[test]
    fn per_tenant_m_overrides_apply() {
        let mut f = MthRequestFilter::new(1 << 14, 2);
        f.set_tenant_m(3, 1);
        assert_eq!(f.m_of(3), 1);
        assert_eq!(f.m_of(0), 2);
        assert_eq!(f.m_of(999), 2);
        assert!(f.observe(&Request::new(0, 9, 10).with_tenant(3), None));
        assert!(!f.observe(&Request::new(0, 9, 10), None), "tenant 0 still gated");
    }

    #[test]
    fn aging_halves_counters() {
        let mut f = MthRequestFilter::new(1 << 14, 15);
        for i in 0..5 {
            f.observe(&Request::new(i, 1, 10), None);
        }
        for i in 0..9 {
            f.observe(&Request::new(i, 2, 10), None);
        }
        assert_eq!(f.count(0, 1), 5);
        assert_eq!(f.count(0, 2), 9);
        f.end_epoch();
        assert_eq!(f.count(0, 1), 2);
        assert_eq!(f.count(0, 2), 4);
        f.end_epoch();
        assert_eq!(f.count(0, 1), 1);
        assert_eq!(f.count(0, 2), 2);
    }

    #[test]
    fn counters_saturate_and_still_admit() {
        let mut f = MthRequestFilter::new(1 << 14, 15);
        for i in 0..40 {
            f.observe(&Request::new(i, 5, 10), None);
        }
        assert_eq!(f.count(0, 5), SKETCH_COUNTER_MAX);
        assert!(f.observe(&Request::new(40, 5, 10), None));
    }

    #[test]
    fn keep_cost_prices_the_ttl_window() {
        let mut cost = CostConfig::default();
        cost.miss_cost_dollars = 1e-6;
        let sps = cost.storage_cost_per_byte_sec();
        let mut f = KeepCostFilter::new(cost, 1.0);
        // Break-even TTL for a 1 MB object: miss == size * c * T.
        let size = 1_000_000u32;
        let t_even = 1e-6 / (size as f64 * sps);
        let req = Request::new(0, 1, size);
        assert!(f.observe(&req, Some(t_even * 0.5)), "cheap storage: keep");
        assert!(!f.observe(&req, Some(t_even * 2.0)), "long TTL: drop");
        assert!(f.observe(&req, None), "no timer: filter stays inert");
    }

    #[test]
    fn build_filter_dispatches_on_config() {
        let mut cfg = Config::default();
        assert!(build_filter(&cfg).is_none(), "default: no filter");
        cfg.admission.filter = AdmissionKind::MthRequest;
        assert_eq!(build_filter(&cfg).unwrap().name(), "mth_request");
        cfg.admission.filter = AdmissionKind::KeepCost;
        let f = build_filter(&cfg).unwrap();
        assert_eq!(f.name(), "keep_cost");
        assert!(f.needs_ttl());
    }
}
