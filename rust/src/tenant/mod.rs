//! Multi-tenant cost-aware provisioning (Memshare-style, Cidon et al.):
//! one shared elastic cluster fronting many applications with different
//! miss costs and traffic patterns.
//!
//! The paper's controller optimizes a single aggregate workload. Real
//! in-memory cache deployments are multi-tenant, and the dollars at stake
//! differ wildly per tenant — a miss that re-runs a pricey backend query
//! is worth orders of magnitude more than a miss on a batch scan. This
//! module adds the tenant dimension without giving up the paper's O(1)
//! request path:
//!
//! * [`TenantRegistry`] — per-tenant id, miss-cost multiplier and traffic
//!   class ([`TenantSpec`], [`TrafficClass`]).
//! * [`ControllerBank`] — one §4 stochastic-approximation
//!   [`VirtualCache`] per tenant. Each controller sees its tenant's
//!   *scaled* miss cost, so each timer `T_i` converges to that tenant's
//!   own storage/miss balance point.
//! * [`Arbiter`] — at each epoch boundary, folds the per-tenant shadow
//!   sizes into the shared cluster sizing decision. Cost awareness is
//!   embedded in the demands themselves (an expensive-miss tenant's
//!   controller holds ghosts longer, so its shadow demand is bigger) —
//!   that is what steers the instance count. When the aggregate demand
//!   exceeds the cluster cap, the arbiter additionally *attributes* the
//!   capped capacity to tenants in descending miss-cost order; today
//!   these grants are reporting/diagnostics (surfaced via
//!   [`TenantTtlSizer::allocations`]), not a feedback signal into the
//!   controllers — per-tenant admission enforcement is a ROADMAP item.
//! * [`TenantTtlSizer`] — the [`EpochSizer`] gluing the three together;
//!   [`crate::balancer::Balancer`] dispatches each request's shadow
//!   update to the right controller via the request's tenant id.
//!
//! Physical placement stays tenant-agnostic: the balancer routes on
//! `(tenant, key)` by folding the tenant into the hash-slot key
//! ([`scoped_object`]), so tenants share instances but never collide.

use crate::config::{Config, ControllerConfig, CostConfig, ScalerConfig};
use crate::scaler::{EpochSizer, PolicyWork};
use crate::trace::Request;
use crate::vcache::VirtualCache;
use crate::{ObjectId, TenantId, TimeUs};

/// Traffic class of a tenant — a coarse service-level label, reported in
/// ledgers and usable by operators to pick miss-cost multipliers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Latency-sensitive request/response traffic (misses are expensive).
    Interactive,
    /// Ordinary web/CDN traffic.
    Standard,
    /// Throughput-oriented batch/scan traffic (misses are cheap).
    Bulk,
}

impl TrafficClass {
    pub fn as_str(self) -> &'static str {
        match self {
            TrafficClass::Interactive => "interactive",
            TrafficClass::Standard => "standard",
            TrafficClass::Bulk => "bulk",
        }
    }

    pub fn parse(s: &str) -> crate::Result<TrafficClass> {
        Ok(match s {
            "interactive" => TrafficClass::Interactive,
            "standard" => TrafficClass::Standard,
            "bulk" => TrafficClass::Bulk,
            other => anyhow::bail!("unknown traffic class {other} (interactive|standard|bulk)"),
        })
    }
}

/// Static description of one tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub id: TenantId,
    pub name: String,
    /// Multiplier applied to the catalog per-miss cost for this tenant
    /// (its misses cost `multiplier × m_o` dollars).
    pub miss_cost_multiplier: f64,
    pub class: TrafficClass,
}

impl TenantSpec {
    pub fn new(id: TenantId, name: impl Into<String>) -> TenantSpec {
        TenantSpec {
            id,
            name: name.into(),
            miss_cost_multiplier: 1.0,
            class: TrafficClass::Standard,
        }
    }

    pub fn with_multiplier(mut self, m: f64) -> TenantSpec {
        self.miss_cost_multiplier = m;
        self
    }

    pub fn with_class(mut self, class: TrafficClass) -> TenantSpec {
        self.class = class;
        self
    }
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec::new(0, "default")
    }
}

/// The set of known tenants. Lookup is a linear scan — registries hold a
/// handful of tenants, and the hot path goes through [`ControllerBank`]'s
/// dense index instead.
#[derive(Debug, Clone, Default)]
pub struct TenantRegistry {
    specs: Vec<TenantSpec>,
}

impl TenantRegistry {
    pub fn new() -> TenantRegistry {
        TenantRegistry { specs: Vec::new() }
    }

    /// A registry holding only the default tenant 0 (the single-workload
    /// configuration every pre-tenant trace maps onto).
    pub fn single_tenant() -> TenantRegistry {
        TenantRegistry { specs: vec![TenantSpec::default()] }
    }

    /// Build from specs; a later spec with a duplicate id replaces the
    /// earlier one.
    pub fn from_specs(specs: impl IntoIterator<Item = TenantSpec>) -> TenantRegistry {
        let mut reg = TenantRegistry::new();
        for s in specs {
            reg.register(s);
        }
        reg
    }

    pub fn register(&mut self, spec: TenantSpec) {
        match self.specs.iter_mut().find(|s| s.id == spec.id) {
            Some(slot) => *slot = spec,
            None => self.specs.push(spec),
        }
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &TenantSpec> {
        self.specs.iter()
    }

    pub fn get(&self, id: TenantId) -> Option<&TenantSpec> {
        self.specs.iter().find(|s| s.id == id)
    }

    /// Miss-cost multiplier for `id` (1.0 for unknown tenants).
    pub fn multiplier(&self, id: TenantId) -> f64 {
        self.get(id).map(|s| s.miss_cost_multiplier).unwrap_or(1.0)
    }
}

/// Fold a tenant id into an object id so tenants sharing physical
/// instances never collide on keys, while tenant 0 (single-workload
/// traces) keeps its ids — and therefore its routing — bit-for-bit
/// unchanged. XOR with a per-tenant mixed constant is a bijection per
/// tenant, so it preserves each tenant's key-space structure.
#[inline]
pub fn scoped_object(tenant: TenantId, obj: ObjectId) -> ObjectId {
    if tenant == 0 {
        obj
    } else {
        obj ^ crate::mix64(tenant as u64)
    }
}

/// One §4 virtual-TTL-cache controller per tenant, with O(1) dispatch by
/// tenant id (dense index vector; unknown tenants are admitted lazily
/// with default cost).
pub struct ControllerBank {
    ctrl: ControllerConfig,
    /// Base (multiplier-1) cost catalog.
    cost: CostConfig,
    registry: TenantRegistry,
    /// `(tenant, controller)` in registration order.
    slots: Vec<(TenantId, VirtualCache)>,
    /// tenant id → slot index (`u32::MAX` = absent), grown on demand.
    index: Vec<u32>,
}

impl ControllerBank {
    pub fn new(ctrl: &ControllerConfig, cost: CostConfig, registry: TenantRegistry) -> Self {
        let mut bank = ControllerBank {
            ctrl: ctrl.clone(),
            cost,
            registry: TenantRegistry::new(),
            slots: Vec::new(),
            index: Vec::new(),
        };
        for spec in registry.iter() {
            bank.admit(spec.clone());
        }
        bank
    }

    /// Per-tenant cost view: the miss side is scaled by the tenant's
    /// multiplier, which is what makes each controller converge to its
    /// own `T_i` (eq. 7's corrections are `λ̂·m_i − c_i`).
    fn scaled_cost(&self, multiplier: f64) -> CostConfig {
        let mut c = self.cost.clone();
        c.miss_cost_dollars *= multiplier;
        c
    }

    fn admit(&mut self, spec: TenantSpec) {
        let vc = VirtualCache::new(&self.ctrl, self.scaled_cost(spec.miss_cost_multiplier));
        let slot = self.slots.len() as u32;
        let id = spec.id as usize;
        if self.index.len() <= id {
            self.index.resize(id + 1, u32::MAX);
        }
        self.index[id] = slot;
        self.slots.push((spec.id, vc));
        self.registry.register(spec);
    }

    pub fn registry(&self) -> &TenantRegistry {
        &self.registry
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The controller for `tenant`, creating one (default spec, multiplier
    /// 1.0) the first time an unregistered tenant shows up.
    #[inline]
    pub fn controller_mut(&mut self, tenant: TenantId) -> &mut VirtualCache {
        let id = tenant as usize;
        let slot = self.index.get(id).copied().unwrap_or(u32::MAX);
        let slot = if slot == u32::MAX {
            self.admit(TenantSpec::new(tenant, format!("tenant{tenant}")));
            self.slots.len() as u32 - 1
        } else {
            slot
        };
        &mut self.slots[slot as usize].1
    }

    pub fn get(&self, tenant: TenantId) -> Option<&VirtualCache> {
        let slot = self.index.get(tenant as usize).copied()?;
        if slot == u32::MAX {
            return None;
        }
        Some(&self.slots[slot as usize].1)
    }

    pub fn iter(&self) -> impl Iterator<Item = (TenantId, &VirtualCache)> {
        self.slots.iter().map(|(t, vc)| (*t, vc))
    }

    /// Run expiry (and any pending controller updates) on every tenant.
    pub fn expire_all(&mut self, now: TimeUs) {
        for (_, vc) in &mut self.slots {
            vc.expire(now);
        }
    }

    /// Sum of per-tenant virtual sizes, bytes.
    pub fn total_vsize(&self) -> u64 {
        self.slots.iter().map(|(_, vc)| vc.vsize()).sum()
    }

    /// `(tenant, T_i seconds)` for every tenant.
    pub fn ttls(&self) -> Vec<(TenantId, f64)> {
        self.slots.iter().map(|(t, vc)| (*t, vc.ttl_secs())).collect()
    }
}

/// One tenant's share of an epoch sizing decision.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantAllocation {
    pub tenant: TenantId,
    /// Shadow (virtual cache) demand at the epoch boundary, bytes.
    pub demand_bytes: u64,
    /// Bytes granted by the arbiter (= demand unless the cap binds).
    pub granted_bytes: u64,
    /// Miss-cost weight used for contention ordering.
    pub weight: f64,
}

/// Cost-aware capacity arbiter: Algorithm 2's `ROUND(VC.size / S_p)`
/// generalized to the multi-tenant aggregate, with weighted trimming when
/// the instance cap binds.
#[derive(Debug, Clone)]
pub struct Arbiter {
    instance_bytes: u64,
    min_instances: u32,
    max_instances: u32,
}

impl Arbiter {
    pub fn new(instance_bytes: u64, scaler: &ScalerConfig) -> Arbiter {
        Arbiter {
            instance_bytes: instance_bytes.max(1),
            min_instances: scaler.min_instances.max(1),
            max_instances: scaler.max_instances.max(1),
        }
    }

    /// Fold `(tenant, demand_bytes, weight)` triples into the next cluster
    /// size plus the per-tenant grants. The size is
    /// `clamp(round(Σdemand / S_p))`; grants equal demands unless the
    /// aggregate exceeds the cap, in which case the capped capacity is
    /// attributed to higher-weight (more miss-cost-sensitive) tenants
    /// first. Grants are an accounting/reporting output — enforcement
    /// (capping what a squeezed tenant may actually occupy) is left to a
    /// future admission layer (see ROADMAP).
    pub fn decide(&self, demands: &[(TenantId, u64, f64)]) -> (u32, Vec<TenantAllocation>) {
        let total: u64 = demands.iter().map(|&(_, d, _)| d).sum();
        let raw = (total as f64 / self.instance_bytes as f64).round() as u32;
        let n = raw.clamp(self.min_instances, self.max_instances);

        let mut allocs: Vec<TenantAllocation> = demands
            .iter()
            .map(|&(tenant, demand_bytes, weight)| TenantAllocation {
                tenant,
                demand_bytes,
                granted_bytes: demand_bytes,
                weight,
            })
            .collect();
        if raw > self.max_instances {
            // The cap binds: hand out capacity in descending miss-cost
            // weight (ties: bigger demand first), so the squeeze lands on
            // the tenants whose misses are cheapest.
            let mut order: Vec<usize> = (0..allocs.len()).collect();
            order.sort_by(|&a, &b| {
                allocs[b]
                    .weight
                    .partial_cmp(&allocs[a].weight)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(allocs[b].demand_bytes.cmp(&allocs[a].demand_bytes))
            });
            let mut remaining = self.max_instances as u64 * self.instance_bytes;
            for i in order {
                let grant = allocs[i].demand_bytes.min(remaining);
                allocs[i].granted_bytes = grant;
                remaining -= grant;
            }
        }
        (n, allocs)
    }
}

/// Multi-tenant version of Algorithm 2: the balancer feeds each request to
/// its tenant's controller; the arbiter sizes the shared cluster from the
/// aggregate shadow demand at each epoch boundary.
pub struct TenantTtlSizer {
    bank: ControllerBank,
    arbiter: Arbiter,
    last_allocations: Vec<TenantAllocation>,
}

impl TenantTtlSizer {
    pub fn new(
        ctrl: &ControllerConfig,
        cost: CostConfig,
        registry: TenantRegistry,
        instance_bytes: u64,
        scaler: &ScalerConfig,
    ) -> Self {
        TenantTtlSizer {
            bank: ControllerBank::new(ctrl, cost, registry),
            arbiter: Arbiter::new(instance_bytes, scaler),
            last_allocations: Vec::new(),
        }
    }

    /// Build from config; an empty `cfg.tenants` list falls back to the
    /// single default tenant (plus lazy admission of any ids the trace
    /// actually carries).
    pub fn from_config(cfg: &Config) -> Self {
        let registry = if cfg.tenants.is_empty() {
            TenantRegistry::single_tenant()
        } else {
            TenantRegistry::from_specs(cfg.tenants.iter().cloned())
        };
        Self::new(
            &cfg.controller,
            cfg.cost.clone(),
            registry,
            cfg.cost.instance.ram_bytes,
            &cfg.scaler,
        )
    }

    pub fn bank(&self) -> &ControllerBank {
        &self.bank
    }

    /// Per-tenant grants from the most recent epoch decision.
    pub fn allocations(&self) -> &[TenantAllocation] {
        &self.last_allocations
    }
}

impl EpochSizer for TenantTtlSizer {
    fn on_request(&mut self, req: &Request) -> PolicyWork {
        let vc = self.bank.controller_mut(req.tenant);
        let out = vc.on_request(req.ts, req.obj, req.size_bytes());
        // hash + route (1) + bank dispatch (1) + vcache list ops (≈2):
        // constant, one unit over the single-tenant TTL path.
        PolicyWork { units: 4, shadow_hit: Some(out.hit) }
    }

    fn decide(&mut self, now: TimeUs) -> u32 {
        self.bank.expire_all(now);
        let demands: Vec<(TenantId, u64, f64)> = self
            .bank
            .iter()
            .map(|(t, vc)| (t, vc.vsize(), self.bank.registry().multiplier(t)))
            .collect();
        let (n, allocs) = self.arbiter.decide(&demands);
        self.last_allocations = allocs;
        n
    }

    fn name(&self) -> &'static str {
        "tenant_ttl"
    }

    /// Demand-weighted mean of the per-tenant timers (diagnostic series).
    fn ttl_secs(&self) -> Option<f64> {
        let mut wsum = 0.0;
        let mut tsum = 0.0;
        let mut count = 0usize;
        let mut plain = 0.0;
        for (_, vc) in self.bank.iter() {
            let w = vc.vsize() as f64;
            wsum += w;
            tsum += w * vc.ttl_secs();
            plain += vc.ttl_secs();
            count += 1;
        }
        if count == 0 {
            None
        } else if wsum > 0.0 {
            Some(tsum / wsum)
        } else {
            Some(plain / count as f64)
        }
    }

    fn shadow_size(&self) -> Option<u64> {
        Some(self.bank.total_vsize())
    }

    fn tenant_ttls(&self) -> Option<Vec<(TenantId, f64)>> {
        Some(self.bank.ttls())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::{HOUR, SECOND};

    fn specs_3() -> Vec<TenantSpec> {
        vec![
            TenantSpec::new(0, "api")
                .with_multiplier(3.0)
                .with_class(TrafficClass::Interactive),
            TenantSpec::new(1, "web"),
            TenantSpec::new(2, "batch")
                .with_multiplier(0.3)
                .with_class(TrafficClass::Bulk),
        ]
    }

    #[test]
    fn registry_lookup_and_override() {
        let mut reg = TenantRegistry::from_specs(specs_3());
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.get(0).unwrap().name, "api");
        assert_eq!(reg.multiplier(2), 0.3);
        assert_eq!(reg.multiplier(999), 1.0);
        reg.register(TenantSpec::new(1, "web2").with_multiplier(2.0));
        assert_eq!(reg.len(), 3, "duplicate id must replace, not append");
        assert_eq!(reg.get(1).unwrap().name, "web2");
        assert_eq!(reg.multiplier(1), 2.0);
    }

    #[test]
    fn traffic_class_round_trip() {
        for c in [
            TrafficClass::Interactive,
            TrafficClass::Standard,
            TrafficClass::Bulk,
        ] {
            assert_eq!(TrafficClass::parse(c.as_str()).unwrap(), c);
        }
        assert!(TrafficClass::parse("nope").is_err());
    }

    #[test]
    fn scoped_object_separates_tenants_but_not_tenant_zero() {
        // Tenant 0 is the identity: legacy routing is unchanged.
        for obj in 0..100u64 {
            assert_eq!(scoped_object(0, obj), obj);
        }
        // Distinct tenants map the same key apart, bijectively per tenant.
        let a: std::collections::HashSet<u64> =
            (0..1000u64).map(|o| scoped_object(1, o)).collect();
        assert_eq!(a.len(), 1000);
        let collisions = (0..1000u64)
            .filter(|&o| scoped_object(1, o) == scoped_object(2, o))
            .count();
        assert_eq!(collisions, 0);
    }

    #[test]
    fn bank_dispatches_per_tenant_and_admits_strays() {
        let cfg = Config::default();
        let mut bank = ControllerBank::new(
            &cfg.controller,
            cfg.cost.clone(),
            TenantRegistry::from_specs(specs_3()),
        );
        assert_eq!(bank.len(), 3);
        bank.controller_mut(0).on_request(0, 7, 1000);
        bank.controller_mut(2).on_request(0, 7, 500);
        assert_eq!(bank.get(0).unwrap().vsize(), 1000);
        assert_eq!(bank.get(2).unwrap().vsize(), 500);
        assert_eq!(bank.get(1).unwrap().vsize(), 0);
        // A tenant nobody registered still gets a controller.
        bank.controller_mut(17).on_request(0, 1, 64);
        assert_eq!(bank.len(), 4);
        assert_eq!(bank.get(17).unwrap().vsize(), 64);
        assert_eq!(bank.total_vsize(), 1564);
        bank.expire_all(2 * crate::DAY);
        assert_eq!(bank.total_vsize(), 0);
    }

    #[test]
    fn bank_scales_miss_cost_per_tenant() {
        // The high-multiplier tenant's controller must see a larger miss
        // cost, driving its TTL above the low-multiplier tenant's under
        // the *same* request pattern.
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 30.0;
        let mut bank = ControllerBank::new(
            &cfg.controller,
            cfg.cost.clone(),
            TenantRegistry::from_specs(vec![
                TenantSpec::new(1, "hot").with_multiplier(10.0),
                TenantSpec::new(2, "cold").with_multiplier(0.1),
            ]),
        );
        // Identical traffic into both controllers: each object is
        // requested at cycle start and 20 s later, then left to expire
        // until the next 60 s cycle. Every residency closes a one-hit
        // window, so λ̂ ≈ 1/T and the correction sign is decided by the
        // tenant's miss cost: λ̂·(10·m) ≫ c_100KB > λ̂·(0.1·m).
        let mut events: Vec<(u64, u64)> = Vec::new();
        for k in 0..200u64 {
            for obj in 0..20u64 {
                events.push((k * 60 * SECOND + obj, obj));
                events.push((k * 60 * SECOND + 20 * SECOND + obj, obj));
            }
        }
        events.sort_unstable();
        for (ts, obj) in events {
            bank.controller_mut(1).on_request(ts, obj, 100_000);
            bank.controller_mut(2).on_request(ts, obj, 100_000);
        }
        let t_hot = bank.get(1).unwrap().ttl_secs();
        let t_cold = bank.get(2).unwrap().ttl_secs();
        assert!(
            t_hot > t_cold,
            "expensive-miss tenant should hold longer: hot={t_hot} cold={t_cold}"
        );
        assert!(bank.get(1).unwrap().updates() > 200, "too few updates");
    }

    #[test]
    fn arbiter_sums_demands_and_clamps() {
        let cfg = Config::default();
        let mut scaler = cfg.scaler.clone();
        scaler.min_instances = 1;
        scaler.max_instances = 4;
        let arb = Arbiter::new(1_000_000, &scaler);
        // Under the cap: everyone granted in full, size = round(total/S).
        let (n, allocs) = arb.decide(&[(0, 1_400_000, 3.0), (1, 700_000, 1.0)]);
        assert_eq!(n, 2);
        assert!(allocs.iter().all(|a| a.granted_bytes == a.demand_bytes));
        // Over the cap: total 9 MB → raw 9 > max 4. High-weight tenant is
        // granted first; the cheap tenant absorbs the squeeze.
        let (n, allocs) =
            arb.decide(&[(0, 3_000_000, 3.0), (1, 6_000_000, 0.3)]);
        assert_eq!(n, 4);
        let a0 = allocs.iter().find(|a| a.tenant == 0).unwrap();
        let a1 = allocs.iter().find(|a| a.tenant == 1).unwrap();
        assert_eq!(a0.granted_bytes, 3_000_000);
        assert_eq!(a1.granted_bytes, 1_000_000);
        // Empty demand set still yields the floor.
        let (n, _) = arb.decide(&[]);
        assert_eq!(n, scaler.min_instances);
    }

    #[test]
    fn tenant_sizer_sizes_shared_cluster_from_aggregate() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 3600.0; // sticky ghosts
        cfg.tenants = specs_3();
        let inst = cfg.cost.instance.ram_bytes;
        let mut s = TenantTtlSizer::from_config(&cfg);
        assert_eq!(s.name(), "tenant_ttl");
        // ~1 instance worth of ghosts per tenant → aggregate ≈ 3.
        let obj_size = inst / 10;
        for i in 0..10u64 {
            for t in 0..3u16 {
                let req = Request::new(i * SECOND, i, obj_size as u32)
                    .with_tenant(t);
                s.on_request(&req);
            }
        }
        let n = s.decide(20 * SECOND);
        assert_eq!(n, 3, "aggregate demand should need 3 instances");
        assert_eq!(s.allocations().len(), 3);
        assert!(s.shadow_size().unwrap() > 2 * inst);
        let ttls = s.tenant_ttls().unwrap();
        assert_eq!(ttls.len(), 3);
        assert!(s.ttl_secs().is_some());
    }

    #[test]
    fn single_tenant_fallback_matches_default_registry() {
        let cfg = Config::default();
        let mut s = TenantTtlSizer::from_config(&cfg);
        assert_eq!(s.bank().len(), 1);
        let req = Request::new(0, 1, 1000);
        s.on_request(&req);
        assert_eq!(s.shadow_size(), Some(1000));
        let n = s.decide(HOUR);
        assert_eq!(n, cfg.scaler.min_instances.max(1));
    }
}
